"""Export golden test vectors for the native Rust backend.

Runs the pure-jnp reference oracles (`kernels.ref`) and the backbone
(`models.backbone`) on small fixed-seed inputs and dumps inputs + expected
outputs as JSON under ``rust/tests/golden/``.  The Rust test
``rust/tests/native_golden.rs`` replays them through the native backend and
asserts agreement to 1e-5 — with no artifacts, no PJRT, no skips.

Regenerate (from ``python/``):

    python -m compile.export_golden [--out ../rust/tests/golden]

The JSON files are committed so `cargo test` never needs Python.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import ref
from .models import backbone

DEFAULT_OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "..", "..", "rust", "tests", "golden")


def tensor(x) -> dict:
    """A tensor as {shape, data} with full f32 precision."""
    a = np.asarray(x, dtype=np.float32)
    return {"shape": list(a.shape),
            "data": [float(v) for v in a.reshape(-1)]}


def itensor(x) -> dict:
    a = np.asarray(x, dtype=np.int32)
    return {"shape": list(a.shape), "data": [int(v) for v in a.reshape(-1)]}


def _keystr(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def named_params(tree) -> list:
    """Flatten a param tree to AOT-style named tensors (checkpoint names)."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        entry = tensor(leaf)
        entry["name"] = "params/" + _keystr(path)
        out.append(entry)
    return out


def dump(out_dir: str, name: str, obj: dict) -> None:
    path = os.path.join(out_dir, name)
    with open(path, "w") as f:
        json.dump(obj, f)
    print(f"wrote {path} ({os.path.getsize(path)} bytes)")


# ---------------------------------------------------------------------------
# mixer-level cases (Algorithms 5/7 — the log-space-trained sequential math)
# ---------------------------------------------------------------------------

def mingru_cases(key) -> dict:
    cases = []
    for i, (b, t, d) in enumerate([(1, 1, 1), (2, 4, 3), (1, 12, 5)]):
        k1, k2, k3, key = jax.random.split(key, 4)
        k = jax.random.normal(k1, (b, t, d), jnp.float32) * 2.0
        pre = jax.random.normal(k2, (b, t, d), jnp.float32) * 2.0
        h0 = jax.random.uniform(k3, (b, d), jnp.float32, 0.1, 1.5)
        if i == 0:
            h0 = jnp.full((b, d), 0.5, jnp.float32)  # the decode resting state
        h = ref.mingru_sequential(k, pre, h0)
        cases.append({"k": tensor(k), "pre": tensor(pre), "h0": tensor(h0),
                      "h": tensor(h)})
    return {"doc": "minGRU Algorithm 5: z=sigmoid(k), h'=(1-z)h+z*g(pre)",
            "cases": cases}


def minlstm_cases(key) -> dict:
    cases = []
    for b, t, d in [(1, 1, 2), (2, 5, 3), (1, 10, 4)]:
        k1, k2, k3, k4, key = jax.random.split(key, 5)
        p = jax.random.normal(k1, (b, t, d), jnp.float32) * 2.0
        k = jax.random.normal(k2, (b, t, d), jnp.float32) * 2.0
        pre = jax.random.normal(k3, (b, t, d), jnp.float32) * 2.0
        h0 = jax.random.uniform(k4, (b, d), jnp.float32, 0.1, 1.5)
        h = ref.minlstm_sequential(p, k, pre, h0)
        cases.append({"p": tensor(p), "k": tensor(k), "pre": tensor(pre),
                      "h0": tensor(h0), "h": tensor(h)})
    return {"doc": "minLSTM Algorithm 7: f'=f/(f+i), i'=i/(f+i), "
                   "h'=f'h+i'*g(pre)",
            "cases": cases}


def scan_cases(key) -> dict:
    log_cases = []
    for b, t, d in [(1, 3, 2), (2, 70, 3)]:  # 70 straddles a chunk boundary
        k1, k2, k3, key = jax.random.split(key, 4)
        log_a = jax.random.uniform(k1, (b, t, d), jnp.float32, -5.0, 0.0)
        log_b = jax.random.uniform(k2, (b, t, d), jnp.float32, -5.0, 1.0)
        log_h0 = jax.random.uniform(k3, (b, d), jnp.float32, -2.0, 0.5)
        h = ref.log_linear_recurrence(log_a, log_b, log_h0)
        if t <= 16:
            # cross-check the algorithm on short sequences only: the jnp
            # Heinsen form underflows in f32 once cumsum(log_a) is large
            h2 = ref.heinsen_scan_log(log_a, log_b, log_h0)
            np.testing.assert_allclose(np.asarray(h), np.asarray(h2),
                                       rtol=2e-4, atol=2e-5)
        log_cases.append({"log_a": tensor(log_a), "log_b": tensor(log_b),
                          "log_h0": tensor(log_h0), "h": tensor(h)})
    lin_cases = []
    for b, t, d in [(2, 6, 2), (1, 33, 3)]:
        k1, k2, k3, key = jax.random.split(key, 4)
        a = jax.random.uniform(k1, (b, t, d), jnp.float32, -1.05, 1.05)
        bb = jax.random.normal(k2, (b, t, d), jnp.float32)
        h0 = jax.random.normal(k3, (b, d), jnp.float32)
        h = ref.linear_recurrence(a, bb, h0)
        lin_cases.append({"a": tensor(a), "b": tensor(bb), "h0": tensor(h0),
                          "h": tensor(h)})
    return {"doc": "core recurrence v_t = a_t*v_{t-1} + b_t "
                   "(log-space and real-space forms)",
            "log": log_cases, "linear": lin_cases}


# ---------------------------------------------------------------------------
# backbone-level cases (full model forward + decode chain)
# ---------------------------------------------------------------------------

def backbone_case(key, cfg: dict, x, discrete: bool) -> dict:
    cfg = backbone.with_defaults(cfg)
    kp, key = jax.random.split(key)
    params = backbone.init(kp, cfg)
    logits_par, _ = backbone.apply_parallel(params, cfg, x, train=False)
    B = x.shape[0]
    T = x.shape[1]
    state = backbone.init_state(cfg, B)
    steps = []
    for t in range(T):
        x_t = x[:, t] if discrete else x[:, t, :]
        logits_t, state = backbone.apply_step(params, cfg, x_t, state)
        steps.append(logits_t)
    logits_step = jnp.stack(steps, axis=1)
    np.testing.assert_allclose(np.asarray(logits_par),
                               np.asarray(logits_step),
                               rtol=2e-3, atol=2e-4)
    return {
        "cfg": {k: v for k, v in cfg.items() if v is not None},
        "params": named_params(params),
        "x": itensor(x) if discrete else tensor(x),
        "logits_parallel": tensor(logits_par),
        "logits_step": tensor(logits_step),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()
    out = os.path.abspath(args.out)
    os.makedirs(out, exist_ok=True)

    key = jax.random.PRNGKey(20260728)
    k1, k2, k3, k4, k5, k6, k7 = jax.random.split(key, 7)

    dump(out, "mingru_cells.json", mingru_cases(k1))
    dump(out, "minlstm_cells.json", minlstm_cases(k2))
    dump(out, "scan_cases.json", scan_cases(k3))

    # full backbone, discrete tokens, conv + mlp on (quickstart-shaped)
    cfg = dict(kind="mingru", n_layers=2, d_model=8, expansion=2,
               vocab_in=11, vocab_out=11, conv=True, mlp=True, mlp_mult=2,
               dropout=0.0, max_len=16)
    x = jax.random.randint(k4, (2, 6), 0, 11, jnp.int32)
    dump(out, "backbone_mingru.json", backbone_case(k5, cfg, x, True))

    # minLSTM with forget bias, continuous features (RL-shaped), bare blocks
    cfg2 = dict(kind="minlstm", n_layers=1, d_model=6, expansion=1,
                vocab_in=None, input_dim=4, vocab_out=3, conv=False,
                mlp=False, dropout=0.0, forget_bias=1.0, max_len=16)
    x2 = jax.random.normal(k6, (2, 5, 4), jnp.float32)
    dump(out, "backbone_minlstm.json", backbone_case(k7, cfg2, x2, False))

    # The two native comparison-matrix mixers draw from a separate master
    # key so every file above stays byte-identical across regenerations.
    key8 = jax.random.PRNGKey(20260808)
    k8a, k8b, k8c, k8d = jax.random.split(key8, 4)

    # S6-lite selective scan (input-dependent decay), discrete tokens
    cfg3 = dict(kind="s6", n_layers=2, d_model=8, expansion=2,
                vocab_in=11, vocab_out=11, conv=False, mlp=False,
                dropout=0.0, max_len=16)
    x3 = jax.random.randint(k8a, (2, 6), 0, 11, jnp.int32)
    dump(out, "backbone_s6lite.json", backbone_case(k8b, cfg3, x3, True))

    # causal transformer: learned positions + KV cache; T <= max_len so
    # the native sliding-window ring never diverges from the JAX cache
    cfg4 = dict(kind="transformer", n_layers=2, d_model=8, n_heads=4,
                vocab_in=11, vocab_out=11, conv=False, mlp=False,
                dropout=0.0, max_len=16)
    x4 = jax.random.randint(k8c, (2, 6), 0, 11, jnp.int32)
    dump(out, "backbone_transformer.json",
         backbone_case(k8d, cfg4, x4, True))


if __name__ == "__main__":
    main()
