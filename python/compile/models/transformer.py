"""Causal multi-head self-attention mixer — the Transformer baseline of
Figure 2 (nanoGPT-style).  Positional information is added by the backbone
(learned absolute embeddings).

Step mode keeps a fixed-capacity KV cache of length ``cfg["max_len"]`` so the
decode executable has static shapes; positions beyond the write cursor are
masked out.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from . import layers

NEG_INF = -1e30


def init(key, cfg: dict) -> dict:
    d = cfg["d_model"]
    k1, k2 = jax.random.split(key)
    return {
        "qkv": layers.dense_init(k1, d, 3 * d),
        "proj": layers.dense_init(k2, d, d, scale=0.02),
    }


def init_state(cfg: dict, batch: int) -> dict:
    d, L = cfg["d_model"], cfg["max_len"]
    return {
        "k": jnp.zeros((batch, L, d), jnp.float32),
        "v": jnp.zeros((batch, L, d), jnp.float32),
        # number of valid cache entries (scalar; shared across the batch)
        "len": jnp.zeros((), jnp.int32),
    }


def _split_heads(x: jax.Array, n_heads: int) -> jax.Array:
    B, T, D = x.shape
    return x.reshape(B, T, n_heads, D // n_heads).transpose(0, 2, 1, 3)


def _merge_heads(x: jax.Array) -> jax.Array:
    B, H, T, Dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(B, T, H * Dh)


def parallel(p: dict, cfg: dict, x: jax.Array, state0: dict | None = None):
    """Full causal attention over (B, T, d).  Returns (y, prefilled cache)."""
    B, T, D = x.shape
    H = cfg.get("n_heads", 4)
    qkv = layers.dense(p["qkv"], x)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    qh, kh, vh = (_split_heads(t, H) for t in (q, k, v))
    scores = jnp.einsum("bhtd,bhsd->bhts", qh, kh) / math.sqrt(D // H)
    mask = jnp.tril(jnp.ones((T, T), bool))
    scores = jnp.where(mask, scores, NEG_INF)
    att = jax.nn.softmax(scores, axis=-1)
    y = layers.dense(p["proj"], _merge_heads(jnp.einsum("bhts,bhsd->bhtd",
                                                        att, vh)))
    # prefill the decode cache
    L = cfg["max_len"]
    kc = jnp.zeros((B, L, D), jnp.float32).at[:, :T].set(k)
    vc = jnp.zeros((B, L, D), jnp.float32).at[:, :T].set(v)
    state = {"k": kc, "v": vc, "len": jnp.asarray(T, jnp.int32)}
    return y, state


def step(p: dict, cfg: dict, x_t: jax.Array, state: dict):
    """Single-token decode against the KV cache.  x_t: (B, d)."""
    B, D = x_t.shape
    H = cfg.get("n_heads", 4)
    L = cfg["max_len"]
    qkv = layers.dense(p["qkv"], x_t)
    q, k, v = jnp.split(qkv, 3, axis=-1)

    pos = state["len"]
    kc = jax.lax.dynamic_update_slice(state["k"], k[:, None, :],
                                      (0, pos, 0))
    vc = jax.lax.dynamic_update_slice(state["v"], v[:, None, :],
                                      (0, pos, 0))

    qh = q.reshape(B, H, D // H)
    kh = kc.reshape(B, L, H, D // H).transpose(0, 2, 1, 3)
    vh = vc.reshape(B, L, H, D // H).transpose(0, 2, 1, 3)
    scores = jnp.einsum("bhd,bhsd->bhs", qh, kh) / math.sqrt(D // H)
    valid = jnp.arange(L) <= pos
    scores = jnp.where(valid[None, None, :], scores, NEG_INF)
    att = jax.nn.softmax(scores, axis=-1)
    y = jnp.einsum("bhs,bhsd->bhd", att, vh).reshape(B, D)
    y = layers.dense(p["proj"], y)
    return y, {"k": kc, "v": vc, "len": pos + 1}
