"""Traditional GRU mixer (Cho et al., 2014; Section 2.2) — the sequential
BPTT baseline of Figures 1/3/4.

Gates depend on h_{t-1}, so both training and inference run a `lax.scan`
over time (linear depth — this is precisely the bottleneck the paper's
minimal models remove).  Interface matches the other mixers; parallel()
here *is* the sequential rollout.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers


def d_hidden(cfg: dict) -> int:
    return int(cfg["d_model"] * cfg.get("expansion", 1))


def init(key, cfg: dict) -> dict:
    d = cfg["d_model"]
    dh = d_hidden(cfg)
    keys = jax.random.split(key, 4)
    # Linear_{d_h}([x_t, h_{t-1}]) for each of z, r, h~ — implemented as a
    # single fused (d + d_h) → 3·d_h projection like PyTorch's GRU.
    return {
        "wx": layers.dense_init(keys[0], d, 3 * dh),
        "wh": layers.dense_init(keys[1], dh, 3 * dh),
        "down": layers.dense_init(keys[2], dh, d),
    }


def init_state(cfg: dict, batch: int) -> jax.Array:
    return jnp.zeros((batch, d_hidden(cfg)), jnp.float32)


def _cell(p: dict, dh: int, x_proj_t: jax.Array, h: jax.Array) -> jax.Array:
    """One GRU step given the precomputed input projection (B, 3·dh)."""
    hz = h @ p["wh"]["w"][:, :dh] + p["wh"]["b"][:dh]
    hr = h @ p["wh"]["w"][:, dh:2 * dh] + p["wh"]["b"][dh:2 * dh]
    z = jax.nn.sigmoid(x_proj_t[..., :dh] + hz)
    r = jax.nn.sigmoid(x_proj_t[..., dh:2 * dh] + hr)
    hh = (r * h) @ p["wh"]["w"][:, 2 * dh:] + p["wh"]["b"][2 * dh:]
    h_tilde = jnp.tanh(x_proj_t[..., 2 * dh:] + hh)
    return (1.0 - z) * h + z * h_tilde


def parallel(p: dict, cfg: dict, x: jax.Array, h0: jax.Array | None = None):
    """Sequential rollout over (B, T, d) — BPTT when differentiated."""
    B = x.shape[0]
    dh = d_hidden(cfg)
    if h0 is None:
        h0 = init_state(cfg, B)
    x_proj = layers.dense(p["wx"], x)                     # (B, T, 3·dh)

    def f(h, xp_t):
        h_new = _cell(p, dh, xp_t, h)
        return h_new, h_new

    _, hs = jax.lax.scan(f, h0, jnp.moveaxis(x_proj, 1, 0))
    hs = jnp.moveaxis(hs, 0, 1)
    return layers.dense(p["down"], hs), hs[:, -1, :]


def step(p: dict, cfg: dict, x_t: jax.Array, h: jax.Array):
    dh = d_hidden(cfg)
    x_proj = layers.dense(p["wx"], x_t)
    h_new = _cell(p, dh, x_proj, h)
    return layers.dense(p["down"], h_new), h_new
