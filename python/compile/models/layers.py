"""Shared neural-net building blocks (pure functional, dict params).

The paper's architecture (Appendix C.2): pre-norm residual blocks of
``[RMSNorm → Conv4 → mixer]`` optionally followed by ``[RMSNorm → MLP]``,
with a down-projection inside each mixer for expanded hidden states.

Everything is a plain function over a dict-of-arrays parameter tree so the
whole model lowers cleanly to a single HLO module.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# dense / embedding
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, *, scale: float | None = None,
               bias: float = 0.0) -> dict:
    """LeCun-normal weights (PyTorch-default-like), constant bias."""
    if scale is None:
        scale = 1.0 / math.sqrt(d_in)
    w = jax.random.normal(key, (d_in, d_out), jnp.float32) * scale
    return {"w": w, "b": jnp.full((d_out,), bias, jnp.float32)}


def dense(p: dict, x: jax.Array) -> jax.Array:
    return x @ p["w"] + p["b"]


def embedding_init(key, vocab: int, d: int) -> dict:
    return {"w": jax.random.normal(key, (vocab, d), jnp.float32) * 0.02}


def embed(p: dict, ids: jax.Array) -> jax.Array:
    return jnp.take(p["w"], ids, axis=0)


# ---------------------------------------------------------------------------
# normalization
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int) -> dict:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(p: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * p["scale"]


# ---------------------------------------------------------------------------
# temporal depthwise causal conv, kernel size 4 (the Mamba/xLSTM "Conv4")
# ---------------------------------------------------------------------------

CONV_K = 4


def conv4_init(key, d: int, k: int = CONV_K) -> dict:
    w = jax.random.normal(key, (k, d), jnp.float32) / math.sqrt(k)
    return {"w": w, "b": jnp.zeros((d,), jnp.float32)}


def conv4(p: dict, x: jax.Array) -> jax.Array:
    """Causal depthwise conv over time.  x: (B, T, D) → (B, T, D).

    y_t = b + Σ_{j=0..k-1} w_j ⊙ x_{t-k+1+j}  (zero padding on the left).
    Implemented as k shifted adds — cheap, fusion-friendly, and exactly
    matches the step-mode ring buffer.
    """
    k = p["w"].shape[0]
    B, T, D = x.shape
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    y = jnp.zeros_like(x) + p["b"]
    for j in range(k):
        y = y + xp[:, j:j + T, :] * p["w"][j]
    return jax.nn.silu(y)


def conv4_step(p: dict, buf: jax.Array, x_t: jax.Array):
    """Step mode.  buf: (B, k-1, D) previous inputs; x_t: (B, D).

    Returns (y_t, new_buf)."""
    k = p["w"].shape[0]
    window = jnp.concatenate([buf, x_t[:, None, :]], axis=1)  # (B, k, D)
    y = jnp.einsum("bkd,kd->bd", window, p["w"]) + p["b"]
    return jax.nn.silu(y), window[:, 1:, :]


def conv4_state(batch: int, d: int, k: int = CONV_K) -> jax.Array:
    return jnp.zeros((batch, k - 1, d), jnp.float32)


def conv4_final_state(x: jax.Array, k: int = CONV_K) -> jax.Array:
    """The buffer a parallel pass leaves behind: last k-1 inputs."""
    B, T, D = x.shape
    xp = jnp.pad(x, ((0, 0), (max(0, (k - 1) - T), 0), (0, 0)))
    return xp[:, -(k - 1):, :]


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_init(key, d: int, mult: int = 4) -> dict:
    k1, k2 = jax.random.split(key)
    return {"up": dense_init(k1, d, mult * d),
            "down": dense_init(k2, mult * d, d)}


def mlp(p: dict, x: jax.Array) -> jax.Array:
    return dense(p["down"], jax.nn.gelu(dense(p["up"], x)))


# ---------------------------------------------------------------------------
# dropout (deterministic given a key; `train` is a static flag)
# ---------------------------------------------------------------------------

def dropout(key, x: jax.Array, rate: float, train: bool) -> jax.Array:
    if not train or rate <= 0.0:
        return x
    keep = jax.random.bernoulli(key, 1.0 - rate, x.shape)
    return jnp.where(keep, x / (1.0 - rate), 0.0)
