"""Traditional LSTM mixer (Hochreiter & Schmidhuber, 1997; Section 2.1) —
the second sequential BPTT baseline.  State is (h, c)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers


def d_hidden(cfg: dict) -> int:
    return int(cfg["d_model"] * cfg.get("expansion", 1))


def init(key, cfg: dict) -> dict:
    d = cfg["d_model"]
    dh = d_hidden(cfg)
    keys = jax.random.split(key, 3)
    # Fused (x, h) → 4·dh projections: order [i, f, o, c~] like PyTorch.
    return {
        "wx": layers.dense_init(keys[0], d, 4 * dh),
        "wh": layers.dense_init(keys[1], dh, 4 * dh),
        "down": layers.dense_init(keys[2], dh, d),
    }


def init_state(cfg: dict, batch: int) -> dict:
    dh = d_hidden(cfg)
    return {"h": jnp.zeros((batch, dh), jnp.float32),
            "c": jnp.zeros((batch, dh), jnp.float32)}


def _cell(p: dict, dh: int, x_proj_t: jax.Array, h: jax.Array, c: jax.Array):
    gates = x_proj_t + h @ p["wh"]["w"] + p["wh"]["b"]
    i = jax.nn.sigmoid(gates[..., :dh])
    f = jax.nn.sigmoid(gates[..., dh:2 * dh])
    o = jax.nn.sigmoid(gates[..., 2 * dh:3 * dh])
    c_tilde = jnp.tanh(gates[..., 3 * dh:])
    c_new = f * c + i * c_tilde
    h_new = o * jnp.tanh(c_new)
    return h_new, c_new


def parallel(p: dict, cfg: dict, x: jax.Array, h0: dict | None = None):
    B = x.shape[0]
    dh = d_hidden(cfg)
    if h0 is None:
        h0 = init_state(cfg, B)
    x_proj = layers.dense(p["wx"], x)

    def f(carry, xp_t):
        h, c = carry
        h_new, c_new = _cell(p, dh, xp_t, h, c)
        return (h_new, c_new), h_new

    (hT, cT), hs = jax.lax.scan(f, (h0["h"], h0["c"]),
                                jnp.moveaxis(x_proj, 1, 0))
    hs = jnp.moveaxis(hs, 0, 1)
    return layers.dense(p["down"], hs), {"h": hT, "c": cT}


def step(p: dict, cfg: dict, x_t: jax.Array, state: dict):
    dh = d_hidden(cfg)
    x_proj = layers.dense(p["wx"], x_t)
    h_new, c_new = _cell(p, dh, x_proj, state["h"], state["c"])
    return layers.dense(p["down"], h_new), {"h": h_new, "c": c_new}
