"""Backbone: embeds inputs, stacks residual blocks around a mixer, projects
to the output vocabulary — the paper's minimalistic architecture (App. C.2):

    x → Embed [+pos if transformer]
      → N × [ RMSNorm → (Conv4) → mixer → +residual
              (RMSNorm → MLP → +residual) ]
      → RMSNorm → Head

Config keys (a plain dict, mirrored in artifacts/manifest.json):
    kind        'mingru' | 'minlstm' | 'gru' | 'lstm' | 's6' | 'transformer'
    n_layers    blocks
    d_model     residual width
    expansion   α: mixer hidden d_h = α·d_model (ignored by transformer)
    vocab_in    input vocabulary (None → continuous input of `input_dim`)
    input_dim   continuous feature width (RL)
    vocab_out   output head width (classes / vocab / action-dim)
    conv, mlp   block components (Table 6 ablation switches)
    mlp_mult    MLP expansion
    dropout     dropout rate (applied to residual branches)
    max_len     maximum sequence length (positional table / KV cache)
    n_heads     attention heads
    forget_bias minLSTM forget-gate bias init (Figure 5)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers, mingru, minlstm, gru, lstm, s6lite, transformer

MIXERS = {
    "mingru": mingru,
    "minlstm": minlstm,
    "gru": gru,
    "lstm": lstm,
    "s6": s6lite,
    "transformer": transformer,
}

DEFAULTS = dict(expansion=1, conv=False, mlp=False, mlp_mult=4, dropout=0.0,
                n_heads=4, forget_bias=0.0, vocab_in=None, input_dim=None)


def with_defaults(cfg: dict) -> dict:
    out = dict(DEFAULTS)
    out.update(cfg)
    return out


def init(key, cfg: dict) -> dict:
    cfg = with_defaults(cfg)
    mixer = MIXERS[cfg["kind"]]
    d = cfg["d_model"]
    n = cfg["n_layers"]
    keys = jax.random.split(key, 3 * n + 4)

    params: dict = {}
    if cfg["vocab_in"] is not None:
        params["embed"] = layers.embedding_init(keys[0], cfg["vocab_in"], d)
    else:
        params["in_proj"] = layers.dense_init(keys[0], cfg["input_dim"], d)
    if cfg["kind"] == "transformer":
        params["pos"] = layers.embedding_init(keys[1], cfg["max_len"], d)

    blocks = []
    for i in range(n):
        kb = keys[2 + 3 * i:5 + 3 * i]
        block = {"ln1": layers.rmsnorm_init(d),
                 "mixer": mixer.init(kb[0], cfg)}
        if cfg["conv"]:
            block["conv"] = layers.conv4_init(kb[1], d)
        if cfg["mlp"]:
            block["ln2"] = layers.rmsnorm_init(d)
            block["mlp"] = layers.mlp_init(kb[2], d, cfg["mlp_mult"])
        blocks.append(block)
    params["blocks"] = blocks
    params["ln_f"] = layers.rmsnorm_init(d)
    params["head"] = layers.dense_init(keys[-1], d, cfg["vocab_out"],
                                       scale=0.02)
    return params


def init_state(cfg: dict, batch: int) -> dict:
    cfg = with_defaults(cfg)
    mixer = MIXERS[cfg["kind"]]
    layers_state = []
    for _ in range(cfg["n_layers"]):
        st = {"mixer": mixer.init_state(cfg, batch)}
        if cfg["conv"]:
            st["conv"] = layers.conv4_state(batch, cfg["d_model"])
        layers_state.append(st)
    return {"pos": jnp.zeros((), jnp.int32), "layers": layers_state}


def _embed_in(params: dict, cfg: dict, x: jax.Array) -> jax.Array:
    if cfg["vocab_in"] is not None:
        return layers.embed(params["embed"], x)
    return layers.dense(params["in_proj"], x)


def apply_parallel(params: dict, cfg: dict, x: jax.Array, *,
                   train: bool = False, rng: jax.Array | None = None):
    """Parallel (training) mode.  x: (B, T) int32 or (B, T, F) float32.

    Returns (logits: (B, T, vocab_out), final decode state)."""
    cfg = with_defaults(cfg)
    mixer = MIXERS[cfg["kind"]]
    h = _embed_in(params, cfg, x)
    B, T = h.shape[0], h.shape[1]
    if cfg["kind"] == "transformer":
        h = h + params["pos"]["w"][None, :T, :]

    if rng is None:
        rng = jax.random.PRNGKey(0)

    states = []
    for i, block in enumerate(params["blocks"]):
        u = layers.rmsnorm(block["ln1"], h)
        st: dict = {}
        if cfg["conv"]:
            st["conv"] = layers.conv4_final_state(u)
            u = layers.conv4(block["conv"], u)
        y, mstate = mixer.parallel(block["mixer"], cfg, u)
        st["mixer"] = mstate
        h = h + layers.dropout(jax.random.fold_in(rng, 2 * i), y,
                               cfg["dropout"], train)
        if cfg["mlp"]:
            z = layers.mlp(block["mlp"], layers.rmsnorm(block["ln2"], h))
            h = h + layers.dropout(jax.random.fold_in(rng, 2 * i + 1), z,
                                   cfg["dropout"], train)
        states.append(st)

    logits = layers.dense(params["head"], layers.rmsnorm(params["ln_f"], h))
    state = {"pos": jnp.asarray(T, jnp.int32), "layers": states}
    return logits, state


def apply_step(params: dict, cfg: dict, x_t: jax.Array, state: dict):
    """Sequential (decode) mode.  x_t: (B,) int32 or (B, F) float32.

    Returns (logits_t: (B, vocab_out), new state)."""
    cfg = with_defaults(cfg)
    mixer = MIXERS[cfg["kind"]]
    h = _embed_in(params, cfg, x_t)
    if cfg["kind"] == "transformer":
        h = h + jnp.take(params["pos"]["w"], state["pos"], axis=0)

    new_layers = []
    for block, st in zip(params["blocks"], state["layers"]):
        u = layers.rmsnorm(block["ln1"], h)
        new_st: dict = {}
        if cfg["conv"]:
            u, new_st["conv"] = layers.conv4_step(block["conv"], st["conv"], u)
        y, new_st["mixer"] = mixer.step(block["mixer"], cfg, u, st["mixer"])
        h = h + y
        if cfg["mlp"]:
            h = h + layers.mlp(block["mlp"], layers.rmsnorm(block["ln2"], h))
        new_layers.append(new_st)

    logits = layers.dense(params["head"], layers.rmsnorm(params["ln_f"], h))
    return logits, {"pos": state["pos"] + 1, "layers": new_layers}
