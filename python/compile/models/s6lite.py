"""S6-lite mixer — our stand-in for Mamba's selective state-space model.

Captures the property the paper's comparison hinges on (Section 4.2):
*input-dependent* diagonal transitions, trained with the same parallel-scan
kernel:

    Δ_t = softplus(W_Δ x_t + b_Δ)              (input-dependent step size)
    a_t = exp(-Δ_t ⊙ exp(A_log))               (diagonal transition ∈ (0,1))
    b_t = Δ_t ⊙ (W_B x_t)                      (input-dependent injection)
    h_t = a_t ⊙ h_{t-1} + b_t                  (scan_linear Pallas kernel)
    y_t = W_down (h_t ⊙ silu(W_g x_t))         (gated output, as in Mamba)

This is the ZOH-discretized diagonal selective SSM with scalar-per-channel
state (the "S6" recurrence of Gu & Dao 2024, without the state-expansion
B/C outer products, which don't change the scan structure).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels.vjp import scan_linear_ad
from . import layers


def d_hidden(cfg: dict) -> int:
    return int(cfg["d_model"] * cfg.get("expansion", 1))


def init(key, cfg: dict) -> dict:
    d = cfg["d_model"]
    dh = d_hidden(cfg)
    kd_, kb, kg, ko, ka = jax.random.split(key, 5)
    # A_log initialized so transitions start near exp(-Δ): S4D-real-style.
    a_log = jnp.log(jnp.linspace(1.0, 8.0, dh, dtype=jnp.float32))
    return {
        "dt": layers.dense_init(kd_, d, dh, bias=-1.0),  # softplus(-1)≈0.31
        "b": layers.dense_init(kb, d, dh),
        "gate": layers.dense_init(kg, d, dh),
        "down": layers.dense_init(ko, dh, d),
        "a_log": a_log,
    }


def init_state(cfg: dict, batch: int) -> jax.Array:
    return jnp.zeros((batch, d_hidden(cfg)), jnp.float32)


def _coeffs(p: dict, x: jax.Array):
    dt = jax.nn.softplus(layers.dense(p["dt"], x))
    a = jnp.exp(-dt * jnp.exp(p["a_log"]))
    b = dt * layers.dense(p["b"], x)
    return a, b


def parallel(p: dict, cfg: dict, x: jax.Array, h0: jax.Array | None = None):
    B = x.shape[0]
    if h0 is None:
        h0 = init_state(cfg, B)
    a, b = _coeffs(p, x)
    h = scan_linear_ad(a, b, h0)
    gate = jax.nn.silu(layers.dense(p["gate"], x))
    return layers.dense(p["down"], h * gate), h[:, -1, :]


def step(p: dict, cfg: dict, x_t: jax.Array, h: jax.Array):
    a, b = _coeffs(p, x_t)
    h_new = a * h + b
    gate = jax.nn.silu(layers.dense(p["gate"], x_t))
    return layers.dense(p["down"], h_new * gate), h_new
