"""minLSTM mixer (Section 3.2, length-independence scaling) — parallel mode
via the fused Pallas kernel, sequential mode (Algorithm 7) for decode.

`forget_bias` (Figure 5 / Appendix D.4): a constant added to the forget-gate
pre-activation bias at init, pushing f_t → 1 early in training to promote
information retention.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels import ref
from ..kernels.vjp import minlstm_scan_ad
from . import layers

H0_VALUE = 0.5


def d_hidden(cfg: dict) -> int:
    return int(cfg["d_model"] * cfg.get("expansion", 1))


def init(key, cfg: dict) -> dict:
    d = cfg["d_model"]
    dh = d_hidden(cfg)
    kf, ki, kh, kd = jax.random.split(key, 4)
    fb = float(cfg.get("forget_bias", 0.0))
    return {
        "linear_f": layers.dense_init(kf, d, dh, bias=fb),
        "linear_i": layers.dense_init(ki, d, dh),
        "linear_h": layers.dense_init(kh, d, dh),
        "down": layers.dense_init(kd, dh, d),
    }


def init_state(cfg: dict, batch: int) -> jax.Array:
    return jnp.full((batch, d_hidden(cfg)), H0_VALUE, jnp.float32)


def parallel(p: dict, cfg: dict, x: jax.Array, h0: jax.Array | None = None):
    """x: (B, T, d) → (y: (B, T, d), h_T: (B, d_h))."""
    B = x.shape[0]
    if h0 is None:
        h0 = init_state(cfg, B)
    pf = layers.dense(p["linear_f"], x)
    ki = layers.dense(p["linear_i"], x)
    pre = layers.dense(p["linear_h"], x)
    h = minlstm_scan_ad(pf, ki, pre, h0)
    return layers.dense(p["down"], h), h[:, -1, :]


def step(p: dict, cfg: dict, x_t: jax.Array, h: jax.Array):
    """Algorithm 7: f' = f/(f+i), i' = i/(f+i); h' = f'h + i'·g(pre)."""
    f = jax.nn.sigmoid(layers.dense(p["linear_f"], x_t))
    i = jax.nn.sigmoid(layers.dense(p["linear_i"], x_t))
    pre = layers.dense(p["linear_h"], x_t)
    denom = f + i
    h_new = (f / denom) * h + (i / denom) * ref.g(pre)
    return layers.dense(p["down"], h_new), h_new
