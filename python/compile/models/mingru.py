"""minGRU mixer (Section 3.1) — parallel mode via the fused Pallas kernel,
sequential mode (Algorithm 5) for decode.

Parameters: O(2·d_h·d_x) for the gates plus the down-projection for the
expanded state (Appendix C.2), vs GRU's O(3·d_h(d_x+d_h)).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels import ref
from ..kernels.vjp import mingru_scan_ad
from . import layers

# The initial hidden state must be positive for the log-space formulation;
# g(0) = 0.5 is the natural "zero-input" resting value.
H0_VALUE = 0.5


def d_hidden(cfg: dict) -> int:
    return int(cfg["d_model"] * cfg.get("expansion", 1))


def init(key, cfg: dict) -> dict:
    d = cfg["d_model"]
    dh = d_hidden(cfg)
    kz, kh, kd = jax.random.split(key, 3)
    return {
        "linear_z": layers.dense_init(kz, d, dh),
        "linear_h": layers.dense_init(kh, d, dh),
        "down": layers.dense_init(kd, dh, d),
    }


def init_state(cfg: dict, batch: int) -> jax.Array:
    return jnp.full((batch, d_hidden(cfg)), H0_VALUE, jnp.float32)


def parallel(p: dict, cfg: dict, x: jax.Array, h0: jax.Array | None = None):
    """x: (B, T, d) → (y: (B, T, d), h_T: (B, d_h))."""
    B = x.shape[0]
    if h0 is None:
        h0 = init_state(cfg, B)
    k = layers.dense(p["linear_z"], x)
    pre = layers.dense(p["linear_h"], x)
    h = mingru_scan_ad(k, pre, h0)
    return layers.dense(p["down"], h), h[:, -1, :]


def step(p: dict, cfg: dict, x_t: jax.Array, h: jax.Array):
    """x_t: (B, d), h: (B, d_h) → (y_t: (B, d), h': (B, d_h)).

    Algorithm 5 verbatim: z = σ(k); h' = (1-z)h + z·g(pre)."""
    k = layers.dense(p["linear_z"], x_t)
    pre = layers.dense(p["linear_h"], x_t)
    z = jax.nn.sigmoid(k)
    h_new = (1.0 - z) * h + z * ref.g(pre)
    return layers.dense(p["down"], h_new), h_new
