"""L2: JAX model definitions (paper architecture + baselines)."""

from . import (layers, mingru, minlstm, gru, lstm, s6lite, transformer,
               backbone)  # noqa: F401
from .backbone import (MIXERS, init, init_state, apply_parallel,
                       apply_step, with_defaults)  # noqa: F401
