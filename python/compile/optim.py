"""Pure-JAX AdamW with global-norm gradient clipping.

No optax in this environment, so the optimizer is implemented directly —
which also keeps the exported train-step HLO fully self-contained: the Rust
coordinator passes a learning-rate scalar and never sees a gradient.

State layout: {"step": i32 scalar, "m": tree-like params, "v": tree-like
params}. The flattened (m, v) leaves are exported alongside the parameters
so the coordinator can checkpoint/restore optimizer state too.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def init(params) -> dict:
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"step": jnp.zeros((), jnp.int32),
            "m": zeros,
            "v": jax.tree_util.tree_map(jnp.zeros_like, params)}


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(g)) for g in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), norm


def adamw_update(params, grads, opt_state: dict, lr: jax.Array, *,
                 betas=(0.9, 0.999), eps: float = 1e-8,
                 weight_decay: float = 0.0, clip_norm: float = 0.0):
    """One AdamW step.  `lr` is a traced scalar (host-driven schedule).

    Returns (new_params, new_opt_state, grad_norm)."""
    b1, b2 = betas
    if clip_norm and clip_norm > 0:
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
    else:
        gnorm = global_norm(grads)

    step = opt_state["step"] + 1
    sf = step.astype(jnp.float32)
    bc1 = 1.0 - jnp.power(b1, sf)
    bc2 = 1.0 - jnp.power(b2, sf)

    def upd(p, g, m, v):
        m_new = b1 * m + (1.0 - b1) * g
        v_new = b2 * v + (1.0 - b2) * jnp.square(g)
        m_hat = m_new / bc1
        v_hat = v_new / bc2
        p_new = p - lr * (m_hat / (jnp.sqrt(v_hat) + eps) + weight_decay * p)
        return p_new, m_new, v_new

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(opt_state["m"])
    flat_v = jax.tree_util.tree_leaves(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"step": step, "m": new_m, "v": new_v}, gnorm
