"""L1 §Perf: kernel structure report — VMEM footprint and critical-path
depth per (block_n, time_chunk) configuration.

`interpret=True` gives CPU-numpy wallclock only (not a TPU proxy), so the
optimization target is *structural*: stay under the VMEM budget while
minimizing depth = T/time_chunk (sequential carry) + log2(time_chunk)
(Hillis–Steele ladder).  Larger chunks cut carry steps but grow tiles;
the default (block_n=256, time_chunk=128) sits on the knee.

Usage: python -m compile.kernel_report [T ...]
"""

from __future__ import annotations

import sys

from .kernels import scan

VMEM_BUDGET = 16 * 1024 * 1024  # typical TPU core VMEM


def report(lengths: list[int]) -> str:
    rows = []
    for tc in [32, 64, 128, 256, 512]:
        for bn in [128, 256, 512]:
            vmem = scan.vmem_bytes(bn, tc)
            depths = [scan.depth_estimate(t, tc) for t in lengths]
            rows.append((tc, bn, vmem, depths,
                         vmem <= VMEM_BUDGET // 4))
    head = f"{'chunk':>6} {'block_n':>8} {'vmem':>12} " + \
        " ".join(f"depth@T={t:<6}" for t in lengths) + "  fits(<4MiB)"
    lines = [head, "-" * len(head)]
    for tc, bn, vmem, depths, fits in rows:
        lines.append(
            f"{tc:>6} {bn:>8} {vmem:>12,} "
            + " ".join(f"{d:>13}" for d in depths)
            + f"  {'yes' if fits else 'NO'}")
    return "\n".join(lines)


def main() -> int:
    lengths = [int(a) for a in sys.argv[1:]] or [256, 1024, 4096]
    print(report(lengths))
    print(f"\ndefault config: block_n={scan.DEFAULT_BLOCK_N}, "
          f"time_chunk={scan.DEFAULT_TIME_CHUNK} "
          f"(vmem {scan.vmem_bytes():,} B)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
