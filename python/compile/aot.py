"""AOT exporter: lowers every registered variant (exports.py) to HLO *text*
plus a manifest the Rust coordinator consumes.

HLO text — NOT serialized protos — is the interchange format: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the published `xla` crate) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Executable calling conventions (mirrored in rust/src/runtime/):

    init:     (seed i32[], forget_bias f32[])
                → (params..., opt...)
    train:    (params..., opt..., x, targets, mask, lr f32[], drop_seed i32[])
                → (params..., opt..., loss f32[], grad_norm f32[])
    eval:     (params..., x, targets, mask)
                → (loss, token_acc, seq_acc)      [masked_ce]
                → (loss,)                         [masked_mse]
    step:     (params..., x_t, state...) → (logits, state'...)
    prefill:  (params..., x) → (last_logits, state...)

Usage: python -m compile.aot --out ../artifacts [--only GROUP|NAME ...]
                             [--force] [--list] [--mem-analysis]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import exports, tasks
from .kernels import scan as scan_kernel
from .kernels import vjp as kernel_vjp
from .models import backbone

S = jax.ShapeDtypeStruct
F32, I32 = jnp.float32, jnp.int32


# ---------------------------------------------------------------------------
# lowering helpers
# ---------------------------------------------------------------------------

def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _dtype_name(dt) -> str:
    return {"float32": "f32", "int32": "i32", "uint32": "u32"}[str(dt)]


def _keystr(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def leaf_specs(tree) -> list:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [{"name": _keystr(path),
             "shape": list(leaf.shape),
             "dtype": _dtype_name(leaf.dtype)} for path, leaf in flat]


def io_spec(shape, dtype) -> dict:
    return {"shape": list(shape), "dtype": _dtype_name(jnp.dtype(dtype))}


def _lower_write(fn, arg_specs, path: str, force: bool) -> float:
    """Lower fn at arg_specs, write HLO text; returns elapsed seconds."""
    if os.path.exists(path) and not force:
        return 0.0
    t0 = time.time()
    # keep_unused: the calling convention is positional — arguments that a
    # particular variant doesn't use (e.g. forget_bias for minGRU, the
    # dropout seed when dropout=0) must still be parameters of the HLO.
    lowered = jax.jit(fn, keep_unused=True).lower(*arg_specs)
    text = to_hlo_text(lowered)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        f.write(text)
    os.replace(tmp, path)
    return time.time() - t0


# ---------------------------------------------------------------------------
# per-variant export
# ---------------------------------------------------------------------------

def batch_specs(cfg: dict, task: str, B: int, T: int):
    """(x, targets, mask) ShapeDtypeStructs for a (B, T) batch."""
    if cfg["vocab_in"] is not None:
        x = S((B, T), I32)
    else:
        x = S((B, T, cfg["input_dim"]), F32)
    if task == "masked_ce":
        tgt = S((B, T), I32)
    else:
        tgt = S((B, T, cfg["vocab_out"]), F32)
    mask = S((B, T), F32)
    return x, tgt, mask


def export_variant(name: str, spec: dict, outdir: str, force: bool,
                   mem_analysis: bool) -> dict:
    cfg = backbone.with_defaults(spec["cfg"])
    task = spec["task"]
    B, T = spec["batch"], spec["seq_len"]
    files_wanted = spec["files"]

    init_fn = tasks.make_init(cfg)
    params_s, opt_s = jax.eval_shape(init_fn, S((), I32), S((), F32))
    flat_p, pdef = jax.tree_util.tree_flatten(params_s)
    flat_o, odef = jax.tree_util.tree_flatten(opt_s)
    n_p, n_o = len(flat_p), len(flat_o)

    entry = {
        "group": spec["group"], "cfg": cfg, "task": task,
        "batch": B, "seq_len": T,
        "optim": spec["optim"], "workload": spec["workload"],
        "params": leaf_specs(params_s), "opt": leaf_specs(opt_s),
        "files": {},
        "depth": {
            "parallel_scan": scan_kernel.depth_estimate(T),
            "sequential": T,
        },
        "kernel": {
            "block_n": kernel_vjp.CONFIG["block_n"],
            "time_chunk": kernel_vjp.CONFIG["time_chunk"],
            "vmem_bytes": scan_kernel.vmem_bytes(
                kernel_vjp.CONFIG["block_n"],
                kernel_vjp.CONFIG["time_chunk"]),
        },
    }
    elapsed = 0.0

    # ---- init -------------------------------------------------------------
    def init_flat(seed, fb):
        p, o = init_fn(seed, fb)
        return tuple(jax.tree_util.tree_leaves(p)) + \
            tuple(jax.tree_util.tree_leaves(o))

    fname = f"{name}.init.hlo.txt"
    elapsed += _lower_write(init_flat, (S((), I32), S((), F32)),
                            os.path.join(outdir, fname), force)
    entry["files"]["init"] = fname

    # ---- train ------------------------------------------------------------
    if files_wanted.get("train"):
        ts = tasks.make_train_step(cfg, task, **spec["optim"])

        def train_flat(*args):
            p = pdef.unflatten(list(args[:n_p]))
            o = odef.unflatten(list(args[n_p:n_p + n_o]))
            x, tgt, mask, lr, seed = args[n_p + n_o:]
            p2, o2, loss, gn = ts(p, o, x, tgt, mask, lr, seed)
            return tuple(jax.tree_util.tree_leaves(p2)) + \
                tuple(jax.tree_util.tree_leaves(o2)) + (loss, gn)

        x_s, tgt_s, mask_s = batch_specs(cfg, task, B, T)
        arg_specs = tuple(flat_p) + tuple(flat_o) + \
            (x_s, tgt_s, mask_s, S((), F32), S((), I32))
        fname = f"{name}.train.hlo.txt"
        elapsed += _lower_write(train_flat, arg_specs,
                                os.path.join(outdir, fname), force)
        entry["files"]["train"] = fname
        entry["io"] = {"x": io_spec(x_s.shape, x_s.dtype),
                       "targets": io_spec(tgt_s.shape, tgt_s.dtype),
                       "mask": io_spec(mask_s.shape, mask_s.dtype)}

        if mem_analysis:
            try:
                compiled = jax.jit(train_flat).lower(*arg_specs).compile()
                ma = compiled.memory_analysis()
                entry["memory"] = {
                    "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
                    "argument_bytes": int(
                        getattr(ma, "argument_size_in_bytes", 0)),
                    "output_bytes": int(
                        getattr(ma, "output_size_in_bytes", 0)),
                    "generated_code_bytes": int(
                        getattr(ma, "generated_code_size_in_bytes", 0)),
                }
            except Exception as e:  # pragma: no cover - best effort
                entry["memory"] = {"error": str(e)}

    # ---- eval -------------------------------------------------------------
    if files_wanted.get("eval"):
        es = tasks.make_eval_step(cfg, task)
        entry["files"]["eval"] = []
        for (eb, et) in files_wanted["eval"]:
            def eval_flat(*args):
                p = pdef.unflatten(list(args[:n_p]))
                x, tgt, mask = args[n_p:]
                return es(p, x, tgt, mask)

            x_s, tgt_s, mask_s = batch_specs(cfg, task, eb, et)
            fname = f"{name}.eval.b{eb}.t{et}.hlo.txt"
            elapsed += _lower_write(
                eval_flat, tuple(flat_p) + (x_s, tgt_s, mask_s),
                os.path.join(outdir, fname), force)
            entry["files"]["eval"].append(
                {"batch": eb, "seq_len": et, "file": fname,
                 "x": io_spec(x_s.shape, x_s.dtype),
                 "targets": io_spec(tgt_s.shape, tgt_s.dtype)})

    # ---- decode step ------------------------------------------------------
    if files_wanted.get("step"):
        ds = tasks.make_decode_step(cfg)
        entry["files"]["step"] = []
        for sb in files_wanted["step"]:
            state_s = jax.eval_shape(lambda b=sb: backbone.init_state(cfg, b))
            flat_s, sdef = jax.tree_util.tree_flatten(state_s)
            n_s = len(flat_s)

            def step_flat(*args, _sdef=sdef, _n_s=n_s):
                p = pdef.unflatten(list(args[:n_p]))
                x_t = args[n_p]
                st = _sdef.unflatten(list(args[n_p + 1:n_p + 1 + _n_s]))
                logits, st2 = ds(p, x_t, st)
                return (logits,) + tuple(jax.tree_util.tree_leaves(st2))

            if cfg["vocab_in"] is not None:
                xt_s = S((sb,), I32)
            else:
                xt_s = S((sb, cfg["input_dim"]), F32)
            fname = f"{name}.step.b{sb}.hlo.txt"
            elapsed += _lower_write(
                step_flat, tuple(flat_p) + (xt_s,) + tuple(flat_s),
                os.path.join(outdir, fname), force)
            entry["files"]["step"].append(
                {"batch": sb, "file": fname,
                 "x": io_spec(xt_s.shape, xt_s.dtype),
                 "state": leaf_specs(state_s)})

    # ---- prefill ----------------------------------------------------------
    if files_wanted.get("prefill"):
        pf = tasks.make_prefill(cfg)
        entry["files"]["prefill"] = []
        for (pb, pt) in files_wanted["prefill"]:
            state_s = jax.eval_shape(lambda b=pb: backbone.init_state(cfg, b))

            def prefill_flat(*args):
                p = pdef.unflatten(list(args[:n_p]))
                x = args[n_p]
                logits, st = pf(p, x)
                return (logits[:, -1, :],) + \
                    tuple(jax.tree_util.tree_leaves(st))

            if cfg["vocab_in"] is not None:
                x_s = S((pb, pt), I32)
            else:
                x_s = S((pb, pt, cfg["input_dim"]), F32)
            fname = f"{name}.prefill.b{pb}.t{pt}.hlo.txt"
            elapsed += _lower_write(prefill_flat, tuple(flat_p) + (x_s,),
                                    os.path.join(outdir, fname), force)
            entry["files"]["prefill"].append(
                {"batch": pb, "seq_len": pt, "file": fname,
                 "x": io_spec(x_s.shape, x_s.dtype),
                 "state": leaf_specs(state_s)})

    entry["lower_seconds"] = round(elapsed, 2)
    return entry


# ---------------------------------------------------------------------------
# main
# ---------------------------------------------------------------------------

def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="AOT-export model variants to HLO text")
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", nargs="*", default=None,
                    help="variant names or group names to export")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--mem-analysis", action="store_true",
                    help="compile fig1 train steps and record memory stats")
    args = ap.parse_args(argv)

    grp = exports.groups()
    if args.list:
        for g, names in sorted(grp.items()):
            print(f"{g}: {len(names)} variants")
            for n in names:
                print(f"  {n}")
        return 0

    if args.only:
        selected = []
        for sel in args.only:
            if sel in grp:
                selected.extend(grp[sel])
            elif sel in exports.VARIANTS:
                selected.append(sel)
            else:
                print(f"unknown variant/group: {sel}", file=sys.stderr)
                return 1
    else:
        selected = list(exports.VARIANTS)

    os.makedirs(args.out, exist_ok=True)
    manifest_path = os.path.join(args.out, "manifest.json")
    manifest = {"variants": {}, "scan_config": dict(kernel_vjp.CONFIG)}
    if os.path.exists(manifest_path):
        try:
            with open(manifest_path) as f:
                manifest["variants"] = json.load(f).get("variants", {})
        except Exception:
            pass

    t0 = time.time()
    for i, name in enumerate(selected):
        spec = exports.VARIANTS[name]
        entry = export_variant(name, spec, args.out, args.force,
                               args.mem_analysis and spec["group"] == "fig1")
        manifest["variants"][name] = entry
        print(f"[{i + 1}/{len(selected)}] {name} "
              f"({entry['lower_seconds']}s)", flush=True)

    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"exported {len(selected)} variants in {time.time() - t0:.1f}s "
          f"→ {manifest_path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
