"""L1 Pallas kernels: chunked parallel scans for `v_t = a_t ⊙ v_{t-1} + b_t`.

Two variants, both used by the paper:

* ``scan_log``    — the numerically-stable log-space scan (Appendix B /
                    Heinsen 2023).  Inputs are ``log(a)``/``log(b)``; all
                    values are positive in real space.  Used by minGRU and
                    minLSTM.
* ``scan_linear`` — the vanilla real-space scan (Section 2.3).  Coefficients
                    and values are unconstrained.  Used by the S6-lite
                    baseline and the vanilla (Appendix A) minRNNs.

Kernel structure (the TPU mapping, run here under ``interpret=True``):

* Sequences are canonicalized to ``(T, N)`` with ``N = batch · hidden`` —
  the recurrence is elementwise over channels, so batch and hidden fuse
  into one vectorized axis (TPU: lanes/sublanes of the VPU; there are no
  matmuls in the scan itself, projections stay in L2 where XLA's `dot`
  already targets the MXU).
* ``grid = (N/block_n, T/time_chunk)`` with time innermost: Pallas grids
  iterate sequentially over the trailing axis, so per-(channel-tile)
  carries can live in revisited output blocks (the standard accumulator
  pattern).  Each grid step holds a ``(time_chunk, block_n)`` tile of each
  operand in VMEM.
* Within a tile the prefix combine is a **Hillis–Steele doubling ladder**
  (log2(time_chunk) fully-vectorized steps) — this is the "parallel" in
  parallel scan; the sequential carry across chunks costs O(T/time_chunk)
  depth, so total depth is O(T/tc + log tc) instead of BPTT's O(T).
* VMEM per grid step ≈ 3 · time_chunk · block_n · 4 B (operands + output)
  plus 2 · block_n · 4 B of carry.  Defaults (128 × 256) ≈ 0.4 MiB — far
  under the ~16 MiB VMEM budget; see DESIGN.md §Perf.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# A large-but-finite stand-in for log(0): keeps padded positions inert
# without generating inf - inf = nan in intermediate expressions.
LOG_ZERO = -1e30

DEFAULT_BLOCK_N = 256
DEFAULT_TIME_CHUNK = 128


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


# ---------------------------------------------------------------------------
# In-kernel prefix ladders (operate on (tc, bn) tiles, axis 0 = time)
# ---------------------------------------------------------------------------

def _prefix_logaddexp(x: jax.Array, tc: int) -> jax.Array:
    """Inclusive prefix logsumexp along axis 0 via Hillis–Steele doubling."""
    acc = x
    shift = 1
    while shift < tc:
        prev = jnp.concatenate(
            [jnp.full((shift, acc.shape[1]), LOG_ZERO, acc.dtype),
             acc[:-shift]], axis=0)
        acc = jnp.logaddexp(acc, prev)
        shift *= 2
    return acc


def _prefix_affine(a: jax.Array, b: jax.Array, tc: int):
    """Inclusive prefix composition of affine maps v ↦ a·v + b along axis 0.

    Returns (A, B) with A_t = ∏_{i≤t} a_i and B_t = scan of b (zero init),
    via the associative composition (a2,b2)∘(a1,b1) = (a1·a2, a2·b1 + b2).
    """
    A, B = a, b
    shift = 1
    while shift < tc:
        pad_a = jnp.ones((shift, A.shape[1]), A.dtype)
        pad_b = jnp.zeros((shift, B.shape[1]), B.dtype)
        A_prev = jnp.concatenate([pad_a, A[:-shift]], axis=0)
        B_prev = jnp.concatenate([pad_b, B[:-shift]], axis=0)
        B = A * B_prev + B
        A = A * A_prev
        shift *= 2
    return A, B


# ---------------------------------------------------------------------------
# Log-space scan kernel
# ---------------------------------------------------------------------------

def _scan_log_kernel(la_ref, lb_ref, lh0_ref, o_ref, ca_ref, cl_ref, *,
                     time_chunk: int):
    """One (channel-tile, time-chunk) grid step of the log-space scan.

    ca_ref: running cumulative log-coefficient A (per channel)
    cl_ref: running log-state  log(h_{chunk start - 1})-style accumulator,
            specifically S = log Σ exp(log_b_i - A_i) including log_h0.
    """
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        ca_ref[...] = jnp.zeros_like(ca_ref)
        cl_ref[...] = lh0_ref[...]

    carry_a = ca_ref[...]          # (bn,)
    carry_l = cl_ref[...]          # (bn,)

    la = la_ref[...]               # (tc, bn)
    lb = lb_ref[...]

    a_star = jnp.cumsum(la, axis=0)              # local Σ log a
    x = lb - a_star                              # log(b_i / ∏_{≤i} a)
    p = _prefix_logaddexp(x, time_chunk)         # local prefix lse
    # global S_t = logaddexp(carry_l, p_t - carry_a)
    s = jnp.logaddexp(carry_l[None, :], p - carry_a[None, :])
    log_h = (carry_a[None, :] + a_star) + s
    o_ref[...] = jnp.exp(log_h)

    ca_ref[...] = carry_a + a_star[-1]
    cl_ref[...] = s[-1]


def scan_log(log_a: jax.Array, log_b: jax.Array, log_h0: jax.Array, *,
             block_n: int = DEFAULT_BLOCK_N,
             time_chunk: int = DEFAULT_TIME_CHUNK,
             interpret: bool = True) -> jax.Array:
    """Parallel log-space scan.  log_a, log_b: (B, T, D); log_h0: (B, D).

    Returns h (real space, positive): (B, T, D) — h_1..h_T of
    h_t = a_t ⊙ h_{t-1} + b_t with h_0 = exp(log_h0).
    """
    B, T, D = log_a.shape
    assert log_b.shape == (B, T, D) and log_h0.shape == (B, D)

    # canonicalize to (T, N)
    la = jnp.moveaxis(log_a, 1, 0).reshape(T, B * D)
    lb = jnp.moveaxis(log_b, 1, 0).reshape(T, B * D)
    lh0 = log_h0.reshape(B * D)

    N = B * D
    tc = min(time_chunk, _ceil_to(T, 1))
    tc = 1 << max(0, math.ceil(math.log2(min(tc, T))))  # power of two ≤ chunk
    bn = min(block_n, N)

    Tp, Np = _ceil_to(T, tc), _ceil_to(N, bn)
    la = jnp.pad(la, ((0, Tp - T), (0, Np - N)))               # log a = 0 ⇒ a = 1
    lb = jnp.pad(lb, ((0, Tp - T), (0, Np - N)),
                 constant_values=LOG_ZERO)                     # b = 0
    lh0 = jnp.pad(lh0, (0, Np - N))

    grid = (Np // bn, Tp // tc)
    out_shapes = [
        jax.ShapeDtypeStruct((Tp, Np), la.dtype),   # h
        jax.ShapeDtypeStruct((Np,), la.dtype),      # carry A
        jax.ShapeDtypeStruct((Np,), la.dtype),      # carry S
    ]
    h, _, _ = pl.pallas_call(
        functools.partial(_scan_log_kernel, time_chunk=tc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tc, bn), lambda c, t: (t, c)),
            pl.BlockSpec((tc, bn), lambda c, t: (t, c)),
            pl.BlockSpec((bn,), lambda c, t: (c,)),
        ],
        out_specs=[
            pl.BlockSpec((tc, bn), lambda c, t: (t, c)),
            pl.BlockSpec((bn,), lambda c, t: (c,)),
            pl.BlockSpec((bn,), lambda c, t: (c,)),
        ],
        out_shape=out_shapes,
        interpret=interpret,
    )(la, lb, lh0)

    h = h[:T, :N].reshape(T, B, D)
    return jnp.moveaxis(h, 0, 1)


# ---------------------------------------------------------------------------
# Real-space (vanilla) scan kernel
# ---------------------------------------------------------------------------

def _scan_linear_kernel(a_ref, b_ref, h0_ref, o_ref, ch_ref, *,
                        time_chunk: int):
    """One grid step of the vanilla scan: h = A_t · carry + B_t."""
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        ch_ref[...] = h0_ref[...]

    carry = ch_ref[...]                       # (bn,)
    A, Bv = _prefix_affine(a_ref[...], b_ref[...], time_chunk)
    h = A * carry[None, :] + Bv
    o_ref[...] = h
    ch_ref[...] = h[-1]


def scan_linear(a: jax.Array, b: jax.Array, h0: jax.Array, *,
                block_n: int = DEFAULT_BLOCK_N,
                time_chunk: int = DEFAULT_TIME_CHUNK,
                interpret: bool = True) -> jax.Array:
    """Parallel real-space scan.  a, b: (B, T, D); h0: (B, D) → h: (B, T, D)."""
    B, T, D = a.shape
    assert b.shape == (B, T, D) and h0.shape == (B, D)

    at = jnp.moveaxis(a, 1, 0).reshape(T, B * D)
    bt = jnp.moveaxis(b, 1, 0).reshape(T, B * D)
    h0f = h0.reshape(B * D)

    N = B * D
    tc = 1 << max(0, math.ceil(math.log2(min(time_chunk, T))))
    bn = min(block_n, N)
    Tp, Np = _ceil_to(T, tc), _ceil_to(N, bn)
    at = jnp.pad(at, ((0, Tp - T), (0, Np - N)), constant_values=1.0)
    bt = jnp.pad(bt, ((0, Tp - T), (0, Np - N)))
    h0f = jnp.pad(h0f, (0, Np - N))

    grid = (Np // bn, Tp // tc)
    out_shapes = [
        jax.ShapeDtypeStruct((Tp, Np), at.dtype),
        jax.ShapeDtypeStruct((Np,), at.dtype),
    ]
    h, _ = pl.pallas_call(
        functools.partial(_scan_linear_kernel, time_chunk=tc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tc, bn), lambda c, t: (t, c)),
            pl.BlockSpec((tc, bn), lambda c, t: (t, c)),
            pl.BlockSpec((bn,), lambda c, t: (c,)),
        ],
        out_specs=[
            pl.BlockSpec((tc, bn), lambda c, t: (t, c)),
            pl.BlockSpec((bn,), lambda c, t: (c,)),
        ],
        out_shape=out_shapes,
        interpret=interpret,
    )(at, bt, h0f)

    h = h[:T, :N].reshape(T, B, D)
    return jnp.moveaxis(h, 0, 1)


# ---------------------------------------------------------------------------
# VMEM / roofline estimation (used by DESIGN.md §Perf and tests)
# ---------------------------------------------------------------------------

def vmem_bytes(block_n: int = DEFAULT_BLOCK_N,
               time_chunk: int = DEFAULT_TIME_CHUNK,
               n_operands: int = 3, dtype_bytes: int = 4) -> int:
    """Per-grid-step VMEM footprint of the scan kernel (operands + output +
    carries + one ladder temp)."""
    tile = time_chunk * block_n * dtype_bytes
    carries = 2 * block_n * dtype_bytes
    return (n_operands + 1) * tile + carries


def depth_estimate(seq_len: int, time_chunk: int = DEFAULT_TIME_CHUNK) -> int:
    """Critical-path depth of the chunked scan (vs. seq_len for BPTT)."""
    tc = 1 << max(0, math.ceil(math.log2(min(time_chunk, seq_len))))
    chunks = _ceil_to(seq_len, tc) // tc
    return chunks + int(math.log2(tc))
