"""Pure-jnp reference oracles for the L1 Pallas kernels.

Everything here is the *sequential* math from the paper's Appendix A/B,
implemented with `jax.lax.scan` (i.e. exactly the BPTT formulation the
parallel kernels must match). These functions are the single source of
truth for correctness: pytest sweeps the Pallas kernels against them.

Shapes follow the paper's convention: `(batch, time, hidden)` for
sequences, `(batch, hidden)` for per-step states.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Core recurrence: v_t = a_t ⊙ v_{t-1} + b_t   (Section 2.3)
# ---------------------------------------------------------------------------

def linear_recurrence(a: jax.Array, b: jax.Array, h0: jax.Array) -> jax.Array:
    """Sequential v_t = a_t * v_{t-1} + b_t with v_0 = h0.

    a, b: (B, T, D); h0: (B, D).  Returns h: (B, T, D) = v_1..v_T.
    """

    def step(carry, ab):
        a_t, b_t = ab
        v = a_t * carry + b_t
        return v, v

    # scan over time: move T to the front
    aT = jnp.moveaxis(a, 1, 0)
    bT = jnp.moveaxis(b, 1, 0)
    _, hT = jax.lax.scan(step, h0, (aT, bT))
    return jnp.moveaxis(hT, 0, 1)


def log_linear_recurrence(log_a: jax.Array, log_b: jax.Array,
                          log_h0: jax.Array) -> jax.Array:
    """Sequential evaluation of the log-space recurrence (Appendix B.1).

    Computes h_t where log(h_t) = logaddexp(log_a_t + log_h_{t-1}, log_b_t),
    i.e. h_t = a_t * h_{t-1} + b_t with all quantities positive.
    Returns h (real space), shape (B, T, D).
    """

    def step(carry, ab):
        la, lb = ab
        lh = jnp.logaddexp(la + carry, lb)
        return lh, lh

    laT = jnp.moveaxis(log_a, 1, 0)
    lbT = jnp.moveaxis(log_b, 1, 0)
    _, lhT = jax.lax.scan(step, log_h0, (laT, lbT))
    return jnp.exp(jnp.moveaxis(lhT, 0, 1))


def heinsen_scan_log(log_a: jax.Array, log_b: jax.Array,
                     log_h0: jax.Array) -> jax.Array:
    """Parallel-form (but jnp, not Pallas) Heinsen (2023) log-space scan.

    Used to cross-check the *algorithm* independently of the kernel:
        a_star_t   = cumsum(log_a)            (prefix products in log space)
        log_h_t    = a_star_t + logcumsumexp(log_b - a_star, with log_h0 at t=0)
    """
    a_star = jnp.cumsum(log_a, axis=1)  # (B, T, D)
    # prepend the initial state as a value with zero accumulated coefficient
    x = jnp.concatenate([log_h0[:, None, :], log_b - a_star], axis=1)
    # logcumsumexp along time, stabilized by the per-channel global max
    # (a running max cannot be factored out of the cumulative sum)
    m = jnp.max(x, axis=1, keepdims=True)
    lcse = jnp.log(jnp.cumsum(jnp.exp(x - m), axis=1)) + m
    return jnp.exp(a_star + lcse[:, 1:, :])


# ---------------------------------------------------------------------------
# g(): the positivity-ensuring activation of Appendix B (Listing 6)
# ---------------------------------------------------------------------------

def g(x: jax.Array) -> jax.Array:
    """g(x) = x + 0.5 for x >= 0 else sigmoid(x) — continuous, positive."""
    return jnp.where(x >= 0, x + 0.5, jax.nn.sigmoid(x))


def log_g(x: jax.Array) -> jax.Array:
    """log(g(x)) computed stably (Listing 6)."""
    return jnp.where(x >= 0, jnp.log(jnp.maximum(x, 0) + 0.5),
                     -jax.nn.softplus(-x))


# ---------------------------------------------------------------------------
# minGRU (Algorithms 1/2 vanilla, 5/6 log-space)
# ---------------------------------------------------------------------------

def mingru_sequential(k: jax.Array, h_tilde_pre: jax.Array,
                      h0: jax.Array) -> jax.Array:
    """Sequential log-space-trained minGRU (Algorithm 5).

    k:           pre-activation of the update gate, z_t = sigmoid(k_t); (B,T,D)
    h_tilde_pre: pre-activation of the candidate, h~_t = g(pre);        (B,T,D)
    h0:          initial hidden state (positive);                        (B,D)
    """
    z = jax.nn.sigmoid(k)
    h_tilde = g(h_tilde_pre)

    def step(carry, zh):
        z_t, ht_t = zh
        h = (1.0 - z_t) * carry + z_t * ht_t
        return h, h

    zT = jnp.moveaxis(z, 1, 0)
    hT = jnp.moveaxis(h_tilde, 1, 0)
    _, out = jax.lax.scan(step, h0, (zT, hT))
    return jnp.moveaxis(out, 0, 1)


def mingru_log_inputs(k: jax.Array, h_tilde_pre: jax.Array, h0: jax.Array):
    """The (log_a, log_b, log_h0) triple fed to the log-space scan for minGRU.

    log(1 - z_t) = -softplus(k_t);  log(z_t) = -softplus(-k_t)
    log(b_t)     = log(z_t) + log(g(pre_t))
    """
    log_coeffs = -jax.nn.softplus(k)
    log_z = -jax.nn.softplus(-k)
    log_b = log_z + log_g(h_tilde_pre)
    log_h0 = jnp.log(h0)
    return log_coeffs, log_b, log_h0


def mingru_vanilla_sequential(k: jax.Array, h_tilde: jax.Array,
                              h0: jax.Array) -> jax.Array:
    """Vanilla minGRU (Algorithm 1): candidate NOT passed through g()."""
    z = jax.nn.sigmoid(k)

    def step(carry, zh):
        z_t, ht_t = zh
        h = (1.0 - z_t) * carry + z_t * ht_t
        return h, h

    zT = jnp.moveaxis(z, 1, 0)
    hT = jnp.moveaxis(h_tilde, 1, 0)
    _, out = jax.lax.scan(step, h0, (zT, hT))
    return jnp.moveaxis(out, 0, 1)


# ---------------------------------------------------------------------------
# minLSTM (Algorithms 3/4 vanilla, 7/8 log-space; length-independent scaling)
# ---------------------------------------------------------------------------

def minlstm_sequential(p: jax.Array, k: jax.Array, h_tilde_pre: jax.Array,
                       h0: jax.Array) -> jax.Array:
    """Sequential log-space-trained minLSTM (Algorithm 7).

    p: forget-gate pre-activation, f_t = sigmoid(p_t)
    k: input-gate  pre-activation, i_t = sigmoid(k_t)
    Normalized: f' = f/(f+i), i' = i/(f+i);  h~ = g(pre).
    """
    f = jax.nn.sigmoid(p)
    i = jax.nn.sigmoid(k)
    fp = f / (f + i)
    ip = i / (f + i)
    h_tilde = g(h_tilde_pre)

    def step(carry, fih):
        f_t, i_t, ht_t = fih
        h = f_t * carry + i_t * ht_t
        return h, h

    fT = jnp.moveaxis(fp, 1, 0)
    iT = jnp.moveaxis(ip, 1, 0)
    hT = jnp.moveaxis(h_tilde, 1, 0)
    _, out = jax.lax.scan(step, h0, (fT, iT, hT))
    return jnp.moveaxis(out, 0, 1)


def minlstm_log_inputs(p: jax.Array, k: jax.Array, h_tilde_pre: jax.Array,
                       h0: jax.Array):
    """(log_a, log_b, log_h0) for minLSTM per Algorithm 8.

    diff      = softplus(-p) - softplus(-k)
    log f'    = -softplus(diff)
    log i'    = -softplus(-diff)
    """
    diff = jax.nn.softplus(-p) - jax.nn.softplus(-k)
    log_f = -jax.nn.softplus(diff)
    log_i = -jax.nn.softplus(-diff)
    log_b = log_i + log_g(h_tilde_pre)
    log_h0 = jnp.log(h0)
    return log_f, log_b, log_h0
