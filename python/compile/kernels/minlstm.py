"""Fused minLSTM Pallas kernel (Algorithm 8, log-space parallel mode,
length-independence scaling).

Same structure as the minGRU kernel; the gate math differs:
    diff   = softplus(-p) - softplus(-k)      (p: forget pre-act, k: input)
    log f' = -softplus(diff)
    log i' = -softplus(-diff)
    log b  = log i' + log g(pre)
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .scan import (LOG_ZERO, DEFAULT_BLOCK_N, DEFAULT_TIME_CHUNK,
                   _prefix_logaddexp, _ceil_to)
from .mingru import _softplus, _log_g


def _minlstm_kernel(p_ref, k_ref, pre_ref, lh0_ref, o_ref, ca_ref, cl_ref, *,
                    time_chunk: int):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        ca_ref[...] = jnp.zeros_like(ca_ref)
        cl_ref[...] = lh0_ref[...]

    diff = _softplus(-p_ref[...]) - _softplus(-k_ref[...])
    la = -_softplus(diff)                         # log f'
    lb = -_softplus(-diff) + _log_g(pre_ref[...])  # log i' + log g(pre)

    carry_a = ca_ref[...]
    carry_l = cl_ref[...]
    a_star = jnp.cumsum(la, axis=0)
    s = jnp.logaddexp(carry_l[None, :],
                      _prefix_logaddexp(lb - a_star, time_chunk)
                      - carry_a[None, :])
    o_ref[...] = jnp.exp((carry_a[None, :] + a_star) + s)
    ca_ref[...] = carry_a + a_star[-1]
    cl_ref[...] = s[-1]


def minlstm_scan(p: jax.Array, k: jax.Array, h_tilde_pre: jax.Array,
                 h0: jax.Array, *,
                 block_n: int = DEFAULT_BLOCK_N,
                 time_chunk: int = DEFAULT_TIME_CHUNK,
                 interpret: bool = True) -> jax.Array:
    """Fused parallel-mode minLSTM with length-independence scaling.

    p, k, h_tilde_pre: (B, T, D) forget / input / candidate pre-activations.
    h0: (B, D) positive initial state.
    Returns h: (B, T, D) — matches ref.minlstm_sequential.
    """
    B, T, D = p.shape
    assert k.shape == (B, T, D) and h_tilde_pre.shape == (B, T, D)
    assert h0.shape == (B, D)

    pf = jnp.moveaxis(p, 1, 0).reshape(T, B * D)
    kf = jnp.moveaxis(k, 1, 0).reshape(T, B * D)
    cf = jnp.moveaxis(h_tilde_pre, 1, 0).reshape(T, B * D)
    lh0 = jnp.log(h0).reshape(B * D)

    N = B * D
    tc = 1 << max(0, math.ceil(math.log2(min(time_chunk, T))))
    bn = min(block_n, N)
    Tp, Np = _ceil_to(T, tc), _ceil_to(N, bn)
    pf = jnp.pad(pf, ((0, Tp - T), (0, Np - N)))
    kf = jnp.pad(kf, ((0, Tp - T), (0, Np - N)))
    cf = jnp.pad(cf, ((0, Tp - T), (0, Np - N)), constant_values=LOG_ZERO / 2)
    lh0 = jnp.pad(lh0, (0, Np - N))

    grid = (Np // bn, Tp // tc)
    out_shapes = [
        jax.ShapeDtypeStruct((Tp, Np), pf.dtype),
        jax.ShapeDtypeStruct((Np,), pf.dtype),
        jax.ShapeDtypeStruct((Np,), pf.dtype),
    ]
    h, _, _ = pl.pallas_call(
        functools.partial(_minlstm_kernel, time_chunk=tc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tc, bn), lambda c, t: (t, c)),
            pl.BlockSpec((tc, bn), lambda c, t: (t, c)),
            pl.BlockSpec((tc, bn), lambda c, t: (t, c)),
            pl.BlockSpec((bn,), lambda c, t: (c,)),
        ],
        out_specs=[
            pl.BlockSpec((tc, bn), lambda c, t: (t, c)),
            pl.BlockSpec((bn,), lambda c, t: (c,)),
            pl.BlockSpec((bn,), lambda c, t: (c,)),
        ],
        out_shape=out_shapes,
        interpret=interpret,
    )(pf, kf, cf, lh0)

    h = h[:T, :N].reshape(T, B, D)
    return jnp.moveaxis(h, 0, 1)
