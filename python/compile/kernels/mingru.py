"""Fused minGRU Pallas kernel (Algorithm 6, log-space parallel mode).

Fuses the gate math (softplus / log-g) with the chunked log-space scan so a
single kernel pass reads the two pre-activations and writes the hidden
states — on TPU this avoids materializing log-space intermediates in HBM
(the L2 graph only materializes the two Linear outputs, which feed the MXU).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .scan import (LOG_ZERO, DEFAULT_BLOCK_N, DEFAULT_TIME_CHUNK,
                   _prefix_logaddexp, _ceil_to)


def _softplus(x):
    return jnp.logaddexp(x, 0.0)


def _log_g(x):
    """log(g(x)) with g(x) = x + 0.5 (x ≥ 0) else sigmoid(x) — Listing 6."""
    return jnp.where(x >= 0, jnp.log(jnp.maximum(x, 0.0) + 0.5),
                     -_softplus(-x))


def _mingru_kernel(k_ref, pre_ref, lh0_ref, o_ref, ca_ref, cl_ref, *,
                   time_chunk: int):
    """Gate math + log-space scan, one (channel-tile, time-chunk) step.

    k_ref:   update-gate pre-activation tile (z = sigmoid(k))
    pre_ref: candidate pre-activation tile  (h~ = g(pre))
    """
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        ca_ref[...] = jnp.zeros_like(ca_ref)
        cl_ref[...] = lh0_ref[...]

    k = k_ref[...]
    la = -_softplus(k)                 # log(1 - z)
    lb = -_softplus(-k) + _log_g(pre_ref[...])   # log z + log g(pre)

    carry_a = ca_ref[...]
    carry_l = cl_ref[...]
    a_star = jnp.cumsum(la, axis=0)
    p = _prefix_logaddexp(lb - a_star, time_chunk)
    s = jnp.logaddexp(carry_l[None, :], p - carry_a[None, :])
    o_ref[...] = jnp.exp((carry_a[None, :] + a_star) + s)
    ca_ref[...] = carry_a + a_star[-1]
    cl_ref[...] = s[-1]


def mingru_scan(k: jax.Array, h_tilde_pre: jax.Array, h0: jax.Array, *,
                block_n: int = DEFAULT_BLOCK_N,
                time_chunk: int = DEFAULT_TIME_CHUNK,
                interpret: bool = True) -> jax.Array:
    """Fused parallel-mode minGRU.

    k, h_tilde_pre: (B, T, D) gate/candidate pre-activations.
    h0: (B, D) positive initial hidden state.
    Returns h: (B, T, D) — matches ref.mingru_sequential.
    """
    B, T, D = k.shape
    assert h_tilde_pre.shape == (B, T, D) and h0.shape == (B, D)

    kf = jnp.moveaxis(k, 1, 0).reshape(T, B * D)
    pf = jnp.moveaxis(h_tilde_pre, 1, 0).reshape(T, B * D)
    lh0 = jnp.log(h0).reshape(B * D)

    N = B * D
    tc = 1 << max(0, math.ceil(math.log2(min(time_chunk, T))))
    bn = min(block_n, N)
    Tp, Np = _ceil_to(T, tc), _ceil_to(N, bn)
    # padding: k → +inf would be awkward; use large k so z≈1, and pre s.t.
    # log g(pre) = LOG_ZERO — instead simply pad k with 0 and mask by
    # slicing the output (padded chunks never contribute to real outputs
    # because they come after all real time steps and channels).
    kf = jnp.pad(kf, ((0, Tp - T), (0, Np - N)))
    pf = jnp.pad(pf, ((0, Tp - T), (0, Np - N)), constant_values=LOG_ZERO / 2)
    lh0 = jnp.pad(lh0, (0, Np - N))

    grid = (Np // bn, Tp // tc)
    out_shapes = [
        jax.ShapeDtypeStruct((Tp, Np), kf.dtype),
        jax.ShapeDtypeStruct((Np,), kf.dtype),
        jax.ShapeDtypeStruct((Np,), kf.dtype),
    ]
    h, _, _ = pl.pallas_call(
        functools.partial(_mingru_kernel, time_chunk=tc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tc, bn), lambda c, t: (t, c)),
            pl.BlockSpec((tc, bn), lambda c, t: (t, c)),
            pl.BlockSpec((bn,), lambda c, t: (c,)),
        ],
        out_specs=[
            pl.BlockSpec((tc, bn), lambda c, t: (t, c)),
            pl.BlockSpec((bn,), lambda c, t: (c,)),
            pl.BlockSpec((bn,), lambda c, t: (c,)),
        ],
        out_shape=out_shapes,
        interpret=interpret,
    )(kf, pf, lh0)

    h = h[:T, :N].reshape(T, B, D)
    return jnp.moveaxis(h, 0, 1)
