"""Differentiable wrappers around the Pallas scan kernels.

`pallas_call` has no automatic reverse-mode derivative, and even if it did,
differentiating through the Hillis–Steele ladder would materialize an
O(T·log T) tape. The adjoint of the linear recurrence

    h_t = a_t ⊙ h_{t-1} + b_t

is itself a *reverse* linear recurrence over the incoming cotangents g_t:

    λ_t = g_t + a_{t+1} ⊙ λ_{t+1},     λ_T = g_T
    ∂b_t = λ_t      ∂a_t = λ_t ⊙ h_{t-1}      ∂h_0 = a_1 ⊙ λ_1

so the backward pass runs the same chunked Pallas kernel on time-reversed
inputs — forward and backward are both parallel scans, which is exactly the
training-efficiency story of the paper.

The fused minGRU / minLSTM wrappers push the chain rule through the gate
math analytically (the same expressions BPTT over Algorithm 5/7 produces),
keeping the backward pass a single reverse scan plus elementwise ops.

Block sizes are read from the module-level ``CONFIG`` so the functions stay
pure array→array (as `jax.custom_vjp` requires); `aot.py` may tune CONFIG
before lowering.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import scan as _scan
from . import mingru as _mingru
from . import minlstm as _minlstm

CONFIG = {
    "block_n": _scan.DEFAULT_BLOCK_N,
    "time_chunk": _scan.DEFAULT_TIME_CHUNK,
    "interpret": True,
}


def _kw():
    return dict(block_n=CONFIG["block_n"], time_chunk=CONFIG["time_chunk"],
                interpret=CONFIG["interpret"])


def _reverse_scan(a: jax.Array, g: jax.Array) -> jax.Array:
    """λ_t = g_t + a_{t+1} λ_{t+1} computed with the forward kernel on
    time-reversed inputs.  a, g: (B, T, D) → λ: (B, T, D)."""
    B, T, D = a.shape
    # reverse time; in reversed coordinates s, λ̂_s = ĝ_s + a_rev[s-1]·λ̂_{s-1},
    # so the coefficient sequence is a_rev delayed by one step (the first
    # coefficient multiplies the zero initial carry and is irrelevant).
    a_rev = jnp.flip(a, axis=1)
    a_shift = jnp.concatenate([jnp.ones((B, 1, D), a.dtype), a_rev[:, :-1]],
                              axis=1)
    # λ_rev_s = a_shift_s · λ_rev_{s-1} + g_rev_s with λ_rev_0 = 0 start
    lam_rev = _scan.scan_linear(a_shift, jnp.flip(g, axis=1),
                                jnp.zeros((B, D), a.dtype), **_kw())
    return jnp.flip(lam_rev, axis=1)


# ---------------------------------------------------------------------------
# scan_linear
# ---------------------------------------------------------------------------

@jax.custom_vjp
def scan_linear_ad(a, b, h0):
    return _scan.scan_linear(a, b, h0, **_kw())


def _scan_linear_fwd(a, b, h0):
    h = _scan.scan_linear(a, b, h0, **_kw())
    return h, (a, h, h0)


def _scan_linear_bwd(res, g):
    a, h, h0 = res
    lam = _reverse_scan(a, g)
    h_prev = jnp.concatenate([h0[:, None, :], h[:, :-1, :]], axis=1)
    da = lam * h_prev
    db = lam
    dh0 = a[:, 0, :] * lam[:, 0, :]
    return da, db, dh0


scan_linear_ad.defvjp(_scan_linear_fwd, _scan_linear_bwd)


# ---------------------------------------------------------------------------
# scan_log (positive-domain recurrence; cotangents flow in real space)
# ---------------------------------------------------------------------------

@jax.custom_vjp
def scan_log_ad(log_a, log_b, log_h0):
    return _scan.scan_log(log_a, log_b, log_h0, **_kw())


def _scan_log_fwd(log_a, log_b, log_h0):
    h = _scan.scan_log(log_a, log_b, log_h0, **_kw())
    return h, (log_a, log_b, log_h0, h)


def _scan_log_bwd(res, g):
    log_a, log_b, log_h0, h = res
    a = jnp.exp(log_a)
    lam = _reverse_scan(a, g)
    h0 = jnp.exp(log_h0)
    h_prev = jnp.concatenate([h0[:, None, :], h[:, :-1, :]], axis=1)
    # ∂/∂log_a = ∂/∂a · a, etc. (chain through the exp parameterization)
    dlog_a = lam * h_prev * a
    dlog_b = lam * jnp.exp(log_b)
    dlog_h0 = a[:, 0, :] * lam[:, 0, :] * h0
    return dlog_a, dlog_b, dlog_h0


scan_log_ad.defvjp(_scan_log_fwd, _scan_log_bwd)


# ---------------------------------------------------------------------------
# fused minGRU
# ---------------------------------------------------------------------------

def _sigmoid(x):
    return jax.nn.sigmoid(x)


def _g(x):
    return jnp.where(x >= 0, x + 0.5, _sigmoid(x))


def _g_prime(x):
    s = _sigmoid(x)
    return jnp.where(x >= 0, jnp.ones_like(x), s * (1.0 - s))


@jax.custom_vjp
def mingru_scan_ad(k, pre, h0):
    """Differentiable fused minGRU: h_t = (1-z_t)h_{t-1} + z_t g(pre_t)."""
    return _mingru.mingru_scan(k, pre, h0, **_kw())


def _mingru_fwd(k, pre, h0):
    h = _mingru.mingru_scan(k, pre, h0, **_kw())
    return h, (k, pre, h0, h)


def _mingru_bwd(res, g_out):
    k, pre, h0, h = res
    z = _sigmoid(k)
    a = 1.0 - z
    lam = _reverse_scan(a, g_out)
    h_prev = jnp.concatenate([h0[:, None, :], h[:, :-1, :]], axis=1)
    htil = _g(pre)
    dk = lam * (htil - h_prev) * z * (1.0 - z)
    dpre = lam * z * _g_prime(pre)
    dh0 = a[:, 0, :] * lam[:, 0, :]
    return dk, dpre, dh0


mingru_scan_ad.defvjp(_mingru_fwd, _mingru_bwd)


# ---------------------------------------------------------------------------
# fused minLSTM
# ---------------------------------------------------------------------------

@jax.custom_vjp
def minlstm_scan_ad(p, k, pre, h0):
    """Differentiable fused minLSTM: h_t = f'_t h_{t-1} + i'_t g(pre_t)
    with f' = σ(-diff), i' = σ(diff), diff = softplus(-p) - softplus(-k)."""
    return _minlstm.minlstm_scan(p, k, pre, h0, **_kw())


def _minlstm_fwd(p, k, pre, h0):
    h = _minlstm.minlstm_scan(p, k, pre, h0, **_kw())
    return h, (p, k, pre, h0, h)


def _minlstm_bwd(res, g_out):
    p, k, pre, h0, h = res
    diff = jax.nn.softplus(-p) - jax.nn.softplus(-k)
    ip = _sigmoid(diff)           # i'
    fp = 1.0 - ip                 # f'
    lam = _reverse_scan(fp, g_out)
    h_prev = jnp.concatenate([h0[:, None, :], h[:, :-1, :]], axis=1)
    htil = _g(pre)
    ddiff = lam * (htil - h_prev) * ip * fp
    # d diff / dp = -σ(-p); d diff / dk = σ(-k)
    dp = ddiff * (-_sigmoid(-p))
    dk = ddiff * _sigmoid(-k)
    dpre = lam * ip * _g_prime(pre)
    dh0 = fp[:, 0, :] * lam[:, 0, :]
    return dp, dk, dpre, dh0


minlstm_scan_ad.defvjp(_minlstm_fwd, _minlstm_bwd)
