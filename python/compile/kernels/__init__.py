"""L1: Pallas kernels for the parallel-scan hot spot of minGRU / minLSTM.

Public surface:
    scan.scan_log / scan.scan_linear    — generic chunked parallel scans
    mingru.mingru_scan                  — fused gate+scan, Algorithm 6
    minlstm.minlstm_scan                — fused gate+scan, Algorithm 8
    ref.*                               — sequential pure-jnp oracles
"""

from . import ref, scan, mingru, minlstm  # noqa: F401
from .scan import scan_log, scan_linear, vmem_bytes, depth_estimate  # noqa: F401
from .mingru import mingru_scan  # noqa: F401
from .minlstm import minlstm_scan  # noqa: F401
