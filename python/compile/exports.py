"""Variant registry: every artifact the coordinator can load, keyed by the
paper experiment it serves.  This file is the single source of truth for
shapes — `aot.py` lowers from it and `artifacts/manifest.json` mirrors it
for the Rust side.

Scales are chosen for a single-CPU-core PJRT testbed (see DESIGN.md §2 —
we reproduce relationships, not absolute T4 numbers); every entry records
the paper's original scale in ``workload``.
"""

from __future__ import annotations

# ---------------------------------------------------------------------------
# Experiment groups.  A variant spec:
#   cfg       — backbone config (models/backbone.py)
#   task      — 'masked_ce' | 'masked_mse'
#   batch     — training batch
#   seq_len   — training sequence length
#   files     — which executables to export:
#               'train', 'eval' (list of (batch, T)), 'step' (list of batch),
#               'prefill' (list of (batch, T))
#   optim     — weight_decay / clip_norm
#   workload  — generator description for the Rust data layer
# ---------------------------------------------------------------------------

VARIANTS: dict[str, dict] = {}


def _add(name: str, **spec):
    assert name not in VARIANTS, name
    VARIANTS[name] = spec


# --- quickstart: tiny LM used by examples/quickstart.rs and tests ----------

_add("quickstart",
     group="quickstart",
     cfg=dict(kind="mingru", n_layers=1, d_model=32, expansion=2,
              vocab_in=64, vocab_out=64, conv=True, mlp=True, dropout=0.0,
              max_len=96),
     task="masked_ce", batch=4, seq_len=64,
     files=dict(train=True, eval=[(4, 64)], step=[1, 4],
                prefill=[(4, 64)]),
     optim=dict(weight_decay=0.0, clip_norm=1.0),
     workload=dict(kind="char_lm", vocab=64, paper_scale="n/a (smoke)"))


# --- Figure 1: training cost vs sequence length ----------------------------

FIG1_KINDS = ["mingru", "minlstm", "gru", "lstm", "s6"]
FIG1_LENGTHS = [64, 128, 256, 512, 1024]

for kind in FIG1_KINDS:
    for T in FIG1_LENGTHS:
        _add(f"fig1_{kind}_t{T}",
             group="fig1",
             cfg=dict(kind=kind, n_layers=1, d_model=64, expansion=1,
                      vocab_in=16, vocab_out=16, conv=False, mlp=False,
                      dropout=0.0, max_len=T),
             task="masked_ce", batch=8, seq_len=T,
             files=dict(train=True),
             optim=dict(weight_decay=0.0, clip_norm=1.0),
             workload=dict(kind="random_tokens", vocab=16,
                           paper_scale="B=64, T up to 4096, T4 GPU"))


# --- Tables 1 & 2: Selective Copying ---------------------------------------

SC = dict(seq_len=272, ctx_len=256, n_data=16, vocab=16)

for kind in ["mingru", "minlstm"]:
    for n_layers in [1, 2, 3]:
        _add(f"tab1_{kind}_l{n_layers}",
             group="tab1",
             cfg=dict(kind=kind, n_layers=n_layers, d_model=32, expansion=4,
                      vocab_in=SC["vocab"], vocab_out=SC["vocab"],
                      conv=False, mlp=False, dropout=0.1,
                      max_len=SC["seq_len"]),
             task="masked_ce", batch=16, seq_len=SC["seq_len"],
             files=dict(train=True, eval=[(16, SC["seq_len"])]),
             optim=dict(weight_decay=0.0, clip_norm=1.0),
             workload=dict(kind="selective_copy", **SC,
                           paper_scale="T=4096, 400k steps, exp. factor 6"))


# --- Figure 2 (+ Figure 5): character language modelling -------------------

LM = dict(vocab=64, seq_len=256)
# positional table / KV-cache capacity must cover the longest prefill
# context (Figure 3 sweeps up to 1024) plus decode headroom
LM_MAX_LEN = 1024 + 64
FIG2_KINDS = ["mingru", "minlstm", "s6", "transformer"]

for kind in FIG2_KINDS:
    conv = kind != "transformer"
    _add(f"fig2_{kind}",
         group="fig2",
         cfg=dict(kind=kind, n_layers=3, d_model=128,
                  expansion=(2 if conv else 1),
                  vocab_in=LM["vocab"], vocab_out=LM["vocab"],
                  conv=conv, mlp=True, dropout=0.2, n_heads=4,
                  max_len=LM_MAX_LEN),
         task="masked_ce", batch=8, seq_len=LM["seq_len"],
         files=dict(train=True, eval=[(8, LM["seq_len"])],
                    step=[1, 8, 32],
                    prefill=[(8, 64), (8, 256), (8, 1024)]),
         optim=dict(weight_decay=0.0, clip_norm=0.25),
         workload=dict(kind="char_lm", vocab=LM["vocab"],
                       paper_scale="Shakespeare 1.0M chars, d=384, B=64"))

# traditional RNN LM variants: used by Figures 3/4 (inference) — init + decode
for kind in ["gru", "lstm"]:
    _add(f"infer_{kind}",
         group="fig34",
         cfg=dict(kind=kind, n_layers=3, d_model=128, expansion=2,
                  vocab_in=LM["vocab"], vocab_out=LM["vocab"],
                  conv=True, mlp=True, dropout=0.0,
                  max_len=LM_MAX_LEN),
         task="masked_ce", batch=8, seq_len=LM["seq_len"],
         files=dict(step=[1, 8, 32], prefill=[(8, 64), (8, 256), (8, 1024)]),
         optim=dict(weight_decay=0.0, clip_norm=1.0),
         workload=dict(kind="char_lm", vocab=LM["vocab"],
                       paper_scale="batch 8..64, ctx up to 2048, T4"))


# --- Tables 4 & 5: Chomsky Hierarchy ---------------------------------------

CHOMSKY_TASKS = ["bucket_sort", "missing_duplicate", "cycle_nav",
                 "even_pairs", "majority", "majority_count"]
CH = dict(train_len=64, eval_lens=[64, 128, 288], vocab=16)

for task_name in CHOMSKY_TASKS:
    for kind in ["minlstm", "mingru"]:
        _add(f"chm_{task_name}_{kind}",
             group="tab45",
             cfg=dict(kind=kind, n_layers=2, d_model=64, expansion=2,
                      vocab_in=CH["vocab"], vocab_out=CH["vocab"],
                      conv=True, mlp=False, dropout=0.0,
                      max_len=max(CH["eval_lens"])),
             task="masked_ce", batch=32, seq_len=CH["train_len"],
             files=dict(train=True,
                        eval=[(32, L) for L in CH["eval_lens"]]),
             optim=dict(weight_decay=0.01, clip_norm=1.0),
             workload=dict(kind=f"chomsky/{task_name}", **CH,
                           paper_scale="train len<=40, eval 40-256, 500k steps"))


# --- Long Range Arena (Tables 4/5) + Table 6 ablation ----------------------

LRA = {
    "listops": dict(seq_len=256, vocab_in=20, n_classes=10, batch=16,
                    d_model=64, n_layers=2),
    "retrieval": dict(seq_len=512, vocab_in=32, n_classes=2, batch=8,
                      d_model=64, n_layers=2),
    "gimage": dict(seq_len=256, vocab_in=32, n_classes=10, batch=8,
                   d_model=96, n_layers=2),
}

for task_name, w in LRA.items():
    _add(f"lra_{task_name}_minlstm",
         group="tab45",
         cfg=dict(kind="minlstm", n_layers=w["n_layers"],
                  d_model=w["d_model"], expansion=2,
                  vocab_in=w["vocab_in"], vocab_out=max(w["n_classes"], 2),
                  conv=True, mlp=True, dropout=0.1, max_len=w["seq_len"]),
         task="masked_ce", batch=w["batch"], seq_len=w["seq_len"],
         files=dict(train=True, eval=[(w["batch"], w["seq_len"])]),
         optim=dict(weight_decay=0.05, clip_norm=1.0),
         workload=dict(kind=f"lra/{task_name}", **w,
                       paper_scale="T 1024-4000, 250k steps, 6-8 blocks"))

# Table 6: minLSTM on ListOps, ± Conv ± MLP
for suffix, conv, use_mlp in [("plain", False, False), ("conv", True, False),
                              ("mlp", False, True)]:
    w = LRA["listops"]
    _add(f"tab6_listops_{suffix}",
         group="tab6",
         cfg=dict(kind="minlstm", n_layers=w["n_layers"],
                  d_model=w["d_model"], expansion=2,
                  vocab_in=w["vocab_in"], vocab_out=w["n_classes"],
                  conv=conv, mlp=use_mlp, dropout=0.1, max_len=w["seq_len"]),
         task="masked_ce", batch=w["batch"], seq_len=w["seq_len"],
         files=dict(train=True, eval=[(w["batch"], w["seq_len"])]),
         optim=dict(weight_decay=0.05, clip_norm=1.0),
         workload=dict(kind="lra/listops", **w,
                       paper_scale="Table 6 ablation"))
# (the +Conv+MLP row is lra_listops_minlstm itself)


# --- Table 3: offline RL (Decision-minRNN) ---------------------------------

RL_ENVS = {
    "pointmass": dict(obs_dim=4, act_dim=2),
    "pendulum": dict(obs_dim=3, act_dim=1),
    "walker1d": dict(obs_dim=6, act_dim=2),
}
RL_CTX = 32

for env, dims in RL_ENVS.items():
    for kind in ["mingru", "minlstm"]:
        feat = 1 + dims["obs_dim"] + dims["act_dim"]  # rtg ⊕ obs ⊕ prev act
        _add(f"rl_{env}_{kind}",
             group="tab3",
             cfg=dict(kind=kind, n_layers=3, d_model=64, expansion=2,
                      vocab_in=None, input_dim=feat,
                      vocab_out=dims["act_dim"],
                      conv=False, mlp=True, dropout=0.1, max_len=RL_CTX),
             task="masked_mse", batch=16, seq_len=RL_CTX,
             files=dict(train=True, eval=[(16, RL_CTX)], step=[1]),
             optim=dict(weight_decay=1e-4, clip_norm=1.0),
             workload=dict(kind=f"rl/{env}", ctx=RL_CTX, **dims,
                           paper_scale="D4RL MuJoCo, 100k steps, B=64"))


def groups() -> dict[str, list[str]]:
    out: dict[str, list[str]] = {}
    for name, spec in VARIANTS.items():
        out.setdefault(spec["group"], []).append(name)
    return out
