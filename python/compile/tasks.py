"""Task graphs: loss functions and the train / eval / decode step builders
that `aot.py` lowers to HLO.

Two task families cover every experiment in the paper:

* ``masked_ce``  — masked cross-entropy over discrete targets.  Subsumes
  language modelling (mask = all ones), Selective Copying (mask = the 16
  answer positions), Chomsky transduction (mask = answer span) and LRA
  classification (mask = final position, targets = class id).
* ``masked_mse`` — masked mean-squared error over continuous targets
  (Decision-Transformer-style action regression for the RL experiments).

Exported signatures (flat, see aot.py):
    train_step(params, opt, tokens/feats, targets, mask, lr, drop_seed)
        → (params', opt', loss, grad_norm)
    eval_step(params, tokens/feats, targets, mask)
        → (loss, token_acc, seq_acc)        (ce)
        → (loss,)                            (mse)
    decode_step(params, token/feat, state) → (logits, state')
    prefill(params, tokens/feats) → (logits, state)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .models import backbone
from . import optim


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def masked_ce_loss(logits: jax.Array, targets: jax.Array,
                   mask: jax.Array) -> jax.Array:
    """logits: (B,T,V); targets: (B,T) int32; mask: (B,T) float32."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def masked_ce_metrics(logits, targets, mask):
    """(loss, token_acc, seq_acc): seq_acc counts an example correct only if
    *every* masked position is correct — the Selective-Copy / Chomsky
    accuracy criterion."""
    loss = masked_ce_loss(logits, targets, mask)
    pred = jnp.argmax(logits, axis=-1)
    correct = (pred == targets).astype(jnp.float32) * mask
    token_acc = jnp.sum(correct) / jnp.maximum(jnp.sum(mask), 1.0)
    per_seq_ok = jnp.sum(correct, axis=1) >= jnp.sum(mask, axis=1) - 1e-6
    has_mask = jnp.sum(mask, axis=1) > 0
    seq_acc = jnp.sum(jnp.where(has_mask, per_seq_ok.astype(jnp.float32), 0.0)
                      ) / jnp.maximum(jnp.sum(has_mask.astype(jnp.float32)),
                                      1.0)
    return loss, token_acc, seq_acc


def masked_mse_loss(pred: jax.Array, targets: jax.Array,
                    mask: jax.Array) -> jax.Array:
    """pred/targets: (B,T,A); mask: (B,T)."""
    se = jnp.sum(jnp.square(pred - targets), axis=-1)
    return jnp.sum(se * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# ---------------------------------------------------------------------------
# step builders (close over a static cfg)
# ---------------------------------------------------------------------------

def make_loss_fn(cfg: dict, task: str, train: bool):
    def loss_fn(params, x, targets, mask, rng):
        logits, _ = backbone.apply_parallel(params, cfg, x, train=train,
                                            rng=rng)
        if task == "masked_ce":
            return masked_ce_loss(logits, targets, mask)
        return masked_mse_loss(logits, targets, mask)
    return loss_fn


def make_train_step(cfg: dict, task: str, *, weight_decay: float = 0.0,
                    clip_norm: float = 1.0):
    loss_fn = make_loss_fn(cfg, task, train=True)

    def train_step(params, opt_state, x, targets, mask, lr, drop_seed):
        rng = jax.random.PRNGKey(drop_seed.astype(jnp.uint32))
        loss, grads = jax.value_and_grad(loss_fn)(params, x, targets, mask,
                                                  rng)
        new_params, new_opt, gnorm = optim.adamw_update(
            params, grads, opt_state, lr,
            weight_decay=weight_decay, clip_norm=clip_norm)
        return new_params, new_opt, loss, gnorm

    return train_step


def make_eval_step(cfg: dict, task: str):
    def eval_step(params, x, targets, mask):
        logits, _ = backbone.apply_parallel(params, cfg, x, train=False)
        if task == "masked_ce":
            return masked_ce_metrics(logits, targets, mask)
        return (masked_mse_loss(logits, targets, mask),)
    return eval_step


def make_decode_step(cfg: dict):
    def decode_step(params, x_t, state):
        return backbone.apply_step(params, cfg, x_t, state)
    return decode_step


def make_prefill(cfg: dict):
    def prefill(params, x):
        logits, state = backbone.apply_parallel(params, cfg, x, train=False)
        return logits, state
    return prefill


def make_init(cfg: dict):
    """init(seed, forget_bias) → (params, opt_state).

    forget_bias is a traced input so Figure 5's sweep shares one artifact:
    it is added to the minLSTM forget-gate bias after the static init."""
    def init_fn(seed, forget_bias):
        key = jax.random.PRNGKey(seed.astype(jnp.uint32))
        params = backbone.init(key, cfg)
        if cfg.get("kind") == "minlstm":
            for block in params["blocks"]:
                b = block["mixer"]["linear_f"]["b"]
                block["mixer"]["linear_f"]["b"] = b + forget_bias
        return params, optim.init(params)
    return init_fn
