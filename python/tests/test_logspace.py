"""Appendix B: the log-space formulation's numerical-stability claims.

The vanilla parallel form (cumprod/cumsum in real space) underflows for
long sequences with small coefficients; the log-space kernel must not.
"""

import jax
import jax.numpy as jnp
import numpy as np

from compile.kernels import mingru, minlstm, ref, scan


def test_logspace_survives_long_saturated_gates():
    """z ≈ 1 everywhere ⇒ (1 - z) ≈ 0 ⇒ cumprod underflows in real space,
    but the hidden state itself stays well-scaled."""
    B, T, D = 1, 512, 4
    k = jnp.full((B, T, D), 8.0)          # z = σ(8) ≈ 0.99966
    pre = jnp.ones((B, T, D))             # g(1) = 1.5
    h0 = jnp.full((B, D), 0.5)
    h = mingru.mingru_scan(k, pre, h0, time_chunk=64)
    assert bool(jnp.all(jnp.isfinite(h)))
    # with z≈1 the state tracks the candidate: h ≈ g(1) = 1.5
    np.testing.assert_allclose(h[:, -1], 1.5, rtol=1e-2)

    # naive real-space evaluation of the Heinsen decomposition: the
    # cumulative product of (1 - z) underflows to exactly 0 in f32
    a = 1.0 - jax.nn.sigmoid(k)
    a_star = jnp.cumprod(a, axis=1)
    assert float(a_star[0, -1, 0]) == 0.0, \
        "real-space prefix product should underflow (motivates log-space)"


def test_logspace_survives_tiny_forget_gates():
    """minLSTM with extreme forget/input asymmetry stays finite."""
    B, T, D = 1, 384, 3
    p = jnp.full((B, T, D), -12.0)   # forget ≈ 0
    kk = jnp.full((B, T, D), 12.0)   # input ≈ 1
    pre = jnp.zeros((B, T, D))       # g(0) = 0.5
    h0 = jnp.full((B, D), 0.5)
    h = minlstm.minlstm_scan(p, kk, pre, h0, time_chunk=64)
    assert bool(jnp.all(jnp.isfinite(h)))
    # f' ≈ 0, i' ≈ 1 ⇒ h_t ≈ g(0) = 0.5
    np.testing.assert_allclose(h[:, -1], 0.5, rtol=1e-3)


def test_long_sequence_agreement_with_sequential():
    """T = 2048 (paper-scale half) log-space kernel vs lax.scan oracle."""
    rng = np.random.default_rng(0)
    B, T, D = 1, 2048, 2
    k = jnp.asarray(rng.normal(0, 2, (B, T, D)).astype(np.float32))
    pre = jnp.asarray(rng.normal(0, 2, (B, T, D)).astype(np.float32))
    h0 = jnp.full((B, D), 0.5)
    want = ref.mingru_sequential(k, pre, h0)
    got = mingru.mingru_scan(k, pre, h0, time_chunk=128)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_gradients_finite_under_saturation():
    from compile.kernels import vjp

    B, T, D = 1, 256, 2
    k = jnp.full((B, T, D), 9.0)
    pre = jnp.full((B, T, D), -9.0)
    h0 = jnp.full((B, D), 0.5)

    def loss(k, pre, h0):
        return jnp.sum(vjp.mingru_scan_ad(k, pre, h0))

    g = jax.grad(loss, argnums=(0, 1, 2))(k, pre, h0)
    for t in g:
        assert bool(jnp.all(jnp.isfinite(t)))


def test_scan_log_extreme_dynamic_range():
    """Values spanning e^{±30} in real space still come back accurate."""
    B, T, D = 1, 64, 1
    rng = np.random.default_rng(1)
    log_a = jnp.asarray(rng.uniform(-1.0, 0.0, (B, T, D))
                        .astype(np.float32))
    log_b = jnp.asarray(rng.uniform(-30, 30, (B, T, D)).astype(np.float32))
    log_h0 = jnp.zeros((B, D))
    got = scan.scan_log(log_a, log_b, log_h0, time_chunk=16)
    want = ref.log_linear_recurrence(log_a, log_b, log_h0)
    np.testing.assert_allclose(
        jnp.log(got), jnp.log(want), rtol=1e-4, atol=1e-4)
