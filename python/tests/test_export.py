"""AOT export path: registry consistency, HLO text emission, manifest
round-trip, and the flat calling convention."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot, exports, tasks
from compile.models import backbone


def test_registry_names_and_groups_unique_and_wellformed():
    groups = exports.groups()
    assert "fig1" in groups and "tab1" in groups and "fig2" in groups
    total = sum(len(v) for v in groups.values())
    assert total == len(exports.VARIANTS)
    for name, spec in exports.VARIANTS.items():
        assert spec["task"] in ("masked_ce", "masked_mse"), name
        cfg = backbone.with_defaults(spec["cfg"])
        assert cfg["kind"] in backbone.MIXERS, name
        assert spec["batch"] >= 1 and spec["seq_len"] >= 1
        assert "workload" in spec and "kind" in spec["workload"], name


def test_every_group_covers_its_experiment():
    groups = exports.groups()
    # fig1: 5 kinds × 5 lengths
    assert len(groups["fig1"]) == 25
    # tab1: 2 kinds × 3 layer counts
    assert len(groups["tab1"]) == 6
    # tab45 includes 6 chomsky tasks × 2 kinds + 3 LRA
    assert len(groups["tab45"]) == 15
    # tab3: 3 envs × 2 kinds
    assert len(groups["tab3"]) == 6


def test_eval_shape_param_specs_stable():
    """Flattening must be deterministic — the Rust side indexes by order."""
    spec = exports.VARIANTS["quickstart"]
    cfg = backbone.with_defaults(spec["cfg"])
    init_fn = tasks.make_init(cfg)
    s = jax.ShapeDtypeStruct((), jnp.int32)
    f = jax.ShapeDtypeStruct((), jnp.float32)
    a1, _ = jax.eval_shape(init_fn, s, f)
    a2, _ = jax.eval_shape(init_fn, s, f)
    l1 = aot.leaf_specs(a1)
    l2 = aot.leaf_specs(a2)
    assert l1 == l2
    names = [x["name"] for x in l1]
    assert len(names) == len(set(names)), "leaf names must be unique"
    assert all(x["dtype"] in ("f32", "i32") for x in l1)


def test_export_writes_hlo_text_and_manifest(tmp_path):
    out = str(tmp_path)
    rc = aot.main(["--out", out, "--only", "quickstart"])
    assert rc == 0
    files = os.listdir(out)
    assert "manifest.json" in files
    hlo = [f for f in files if f.endswith(".hlo.txt")]
    # init + train + eval + 2 steps + prefill
    assert len(hlo) >= 6, hlo
    text = open(os.path.join(out, "quickstart.train.hlo.txt")).read()
    assert text.startswith("HloModule"), "must be HLO text, not proto"
    m = json.load(open(os.path.join(out, "manifest.json")))
    v = m["variants"]["quickstart"]
    assert v["task"] == "masked_ce"
    assert len(v["params"]) > 0
    # opt state = step + m + v per param leaf
    assert len(v["opt"]) == 2 * len(v["params"]) + 1
    # skip-if-exists: second run lowers nothing
    rc = aot.main(["--out", out, "--only", "quickstart"])
    assert rc == 0
    m2 = json.load(open(os.path.join(out, "manifest.json")))
    assert m2["variants"]["quickstart"]["lower_seconds"] == 0


def test_unknown_selector_fails():
    assert aot.main(["--out", "/tmp/x_unused", "--only", "nope"]) == 1


@pytest.mark.parametrize("name", ["fig1_gru_t64", "rl_pointmass_mingru",
                                  "chm_majority_minlstm"])
def test_variant_shapes_lower(tmp_path, name):
    """A representative variant from each family lowers end to end."""
    rc = aot.main(["--out", str(tmp_path), "--only", name])
    assert rc == 0
    m = json.load(open(tmp_path / "manifest.json"))
    assert name in m["variants"]
