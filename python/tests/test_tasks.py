"""Task-graph builders: train/eval/decode step semantics at the exact flat
signatures aot.py exports."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import tasks
from compile.kernels import vjp
from compile.models import backbone

vjp.CONFIG.update(block_n=64, time_chunk=16)


def cfg_ce(**kw):
    c = dict(kind="mingru", n_layers=1, d_model=16, expansion=2,
             vocab_in=10, vocab_out=10, dropout=0.0, max_len=24)
    c.update(kw)
    return backbone.with_defaults(c)


def cfg_mse(**kw):
    c = dict(kind="minlstm", n_layers=1, d_model=16, expansion=2,
             vocab_in=None, input_dim=5, vocab_out=3, mlp=True,
             dropout=0.0, max_len=24)
    c.update(kw)
    return backbone.with_defaults(c)


def test_train_step_signature_and_determinism():
    cfg = cfg_ce()
    init = tasks.make_init(cfg)
    params, opt = init(jnp.asarray(0, jnp.int32), jnp.asarray(0.0))
    ts = tasks.make_train_step(cfg, "masked_ce", clip_norm=1.0)
    x = jax.random.randint(jax.random.PRNGKey(0), (2, 12), 0, 10)
    y = jnp.roll(x, -1, axis=1)
    m = jnp.ones((2, 12))
    out1 = ts(params, opt, x, y, m, jnp.asarray(1e-3),
              jnp.asarray(7, jnp.int32))
    out2 = ts(params, opt, x, y, m, jnp.asarray(1e-3),
              jnp.asarray(7, jnp.int32))
    assert float(out1[2]) == float(out2[2]), "train step must be pure"
    # optimizer step counter advanced exactly once
    assert int(out1[1]["step"]) == 1


def test_grad_norm_reported_and_clipped():
    cfg = cfg_ce()
    init = tasks.make_init(cfg)
    params, opt = init(jnp.asarray(0, jnp.int32), jnp.asarray(0.0))
    ts = tasks.make_train_step(cfg, "masked_ce", clip_norm=0.5)
    x = jax.random.randint(jax.random.PRNGKey(0), (2, 12), 0, 10)
    y = jnp.roll(x, -1, axis=1)
    m = jnp.ones((2, 12))
    _, _, _, gnorm = ts(params, opt, x, y, m, jnp.asarray(1e-3),
                        jnp.asarray(0, jnp.int32))
    # reported norm is the raw pre-clip norm; must be positive and finite
    assert float(gnorm) > 0 and np.isfinite(float(gnorm))


def test_eval_step_shapes_ce_and_mse():
    cfg = cfg_ce()
    init = tasks.make_init(cfg)
    params, _ = init(jnp.asarray(0, jnp.int32), jnp.asarray(0.0))
    es = tasks.make_eval_step(cfg, "masked_ce")
    x = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, 10)
    loss, tok, seq = es(params, x, x, jnp.ones((2, 12)))
    for v in (loss, tok, seq):
        assert v.shape == ()

    cfg2 = cfg_mse()
    init2 = tasks.make_init(cfg2)
    p2, _ = init2(jnp.asarray(0, jnp.int32), jnp.asarray(0.0))
    es2 = tasks.make_eval_step(cfg2, "masked_mse")
    xf = jax.random.normal(jax.random.PRNGKey(2), (2, 12, 5))
    tf = jax.random.normal(jax.random.PRNGKey(3), (2, 12, 3))
    (loss2,) = es2(p2, xf, tf, jnp.ones((2, 12)))
    assert loss2.shape == ()
    assert float(loss2) > 0


def test_mse_task_trains():
    cfg = cfg_mse()
    init = tasks.make_init(cfg)
    params, opt = init(jnp.asarray(0, jnp.int32), jnp.asarray(0.0))
    ts = tasks.make_train_step(cfg, "masked_mse")
    xf = jax.random.normal(jax.random.PRNGKey(2), (4, 12, 5))
    # learnable mapping: target = first 3 input dims
    tf = xf[..., :3]
    m = jnp.ones((4, 12))
    first = None
    for i in range(25):
        params, opt, loss, _ = ts(params, opt, xf, tf, m,
                                  jnp.asarray(3e-3),
                                  jnp.asarray(i, jnp.int32))
        if first is None:
            first = float(loss)
    assert float(loss) < first * 0.8, f"{first} → {float(loss)}"


def test_decode_step_matches_parallel_for_masked_positions():
    cfg = cfg_ce()
    init = tasks.make_init(cfg)
    params, _ = init(jnp.asarray(3, jnp.int32), jnp.asarray(0.0))
    ds = tasks.make_decode_step(cfg)
    pf = tasks.make_prefill(cfg)
    x = jax.random.randint(jax.random.PRNGKey(4), (2, 10), 0, 10)
    full_logits, state = pf(params, x)
    # the task-level prefill returns full logits (aot.py slices [:, -1]
    # when exporting); the last position feeds decode
    full, _ = backbone.apply_parallel(params, cfg, x)
    np.testing.assert_allclose(full_logits, full, rtol=1e-5, atol=1e-5)
    last_logits = full_logits[:, -1]
    # decode continues consistently
    nxt = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)
    logits2, _ = ds(params, nxt, state)
    x_ext = jnp.concatenate([x, nxt[:, None]], axis=1)
    full2, _ = backbone.apply_parallel(params, cfg, x_ext)
    np.testing.assert_allclose(logits2, full2[:, -1], rtol=2e-4, atol=2e-4)


def test_mask_zero_positions_never_affect_loss():
    cfg = cfg_ce()
    init = tasks.make_init(cfg)
    params, _ = init(jnp.asarray(0, jnp.int32), jnp.asarray(0.0))
    loss_fn = tasks.make_loss_fn(cfg, "masked_ce", train=False)
    x = jax.random.randint(jax.random.PRNGKey(5), (2, 12), 0, 10)
    y = jnp.roll(x, -1, axis=1)
    m = jnp.zeros((2, 12)).at[:, :4].set(1.0)
    base = loss_fn(params, x, y, m, jax.random.PRNGKey(0))
    y_perturbed = y.at[:, 8:].set(0)
    pert = loss_fn(params, x, y_perturbed, m, jax.random.PRNGKey(0))
    assert float(base) == float(pert)


@pytest.mark.parametrize("kind", ["mingru", "minlstm", "s6", "transformer"])
def test_all_parallel_kinds_build_train_graphs(kind):
    cfg = cfg_ce(kind=kind, conv=(kind != "transformer"), mlp=True)
    init = tasks.make_init(cfg)
    s = jax.ShapeDtypeStruct
    params_s, opt_s = jax.eval_shape(init, s((), jnp.int32),
                                     s((), jnp.float32))
    assert len(jax.tree_util.tree_leaves(params_s)) > 0
    assert len(jax.tree_util.tree_leaves(opt_s)) \
        == 2 * len(jax.tree_util.tree_leaves(params_s)) + 1
