"""L1 correctness: Pallas kernels vs the sequential jnp oracles (ref.py),
swept over shapes/blockings with hypothesis."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import mingru, minlstm, ref, scan

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=25,
    suppress_health_check=[hypothesis.HealthCheck.too_slow])
hypothesis.settings.load_profile("ci")


def rand(rng, *shape, lo=-2.0, hi=2.0):
    return jnp.asarray(rng.uniform(lo, hi, size=shape).astype(np.float32))


shapes = st.tuples(st.integers(1, 3),      # B
                   st.integers(1, 70),     # T
                   st.integers(1, 9))      # D
blockings = st.tuples(st.sampled_from([2, 4, 8, 32]),   # block_n
                      st.sampled_from([4, 8, 16, 64]))  # time_chunk


@hypothesis.given(shapes, blockings, st.integers(0, 2**31 - 1))
def test_scan_linear_matches_sequential(shape, blocking, seed):
    B, T, D = shape
    bn, tc = blocking
    rng = np.random.default_rng(seed)
    a = rand(rng, B, T, D, lo=-1.0, hi=1.0)
    b = rand(rng, B, T, D)
    h0 = rand(rng, B, D)
    want = ref.linear_recurrence(a, b, h0)
    got = scan.scan_linear(a, b, h0, block_n=bn, time_chunk=tc)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@hypothesis.given(shapes, blockings, st.integers(0, 2**31 - 1))
def test_scan_log_matches_sequential(shape, blocking, seed):
    B, T, D = shape
    bn, tc = blocking
    rng = np.random.default_rng(seed)
    log_a = rand(rng, B, T, D, lo=-3.0, hi=0.0)   # a ∈ (0, 1]
    log_b = rand(rng, B, T, D, lo=-3.0, hi=3.0)
    log_h0 = rand(rng, B, D, lo=-2.0, hi=2.0)
    want = ref.log_linear_recurrence(log_a, log_b, log_h0)
    got = scan.scan_log(log_a, log_b, log_h0, block_n=bn, time_chunk=tc)
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)


@hypothesis.given(shapes, blockings, st.integers(0, 2**31 - 1))
def test_mingru_kernel_matches_algorithm5(shape, blocking, seed):
    B, T, D = shape
    bn, tc = blocking
    rng = np.random.default_rng(seed)
    k = rand(rng, B, T, D, lo=-4.0, hi=4.0)
    pre = rand(rng, B, T, D, lo=-4.0, hi=4.0)
    h0 = rand(rng, B, D, lo=0.05, hi=2.0)
    want = ref.mingru_sequential(k, pre, h0)
    got = mingru.mingru_scan(k, pre, h0, block_n=bn, time_chunk=tc)
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)


@hypothesis.given(shapes, blockings, st.integers(0, 2**31 - 1))
def test_minlstm_kernel_matches_algorithm7(shape, blocking, seed):
    B, T, D = shape
    bn, tc = blocking
    rng = np.random.default_rng(seed)
    p = rand(rng, B, T, D, lo=-4.0, hi=4.0)
    k = rand(rng, B, T, D, lo=-4.0, hi=4.0)
    pre = rand(rng, B, T, D, lo=-4.0, hi=4.0)
    h0 = rand(rng, B, D, lo=0.05, hi=2.0)
    want = ref.minlstm_sequential(p, k, pre, h0)
    got = minlstm.minlstm_scan(p, k, pre, h0, block_n=bn, time_chunk=tc)
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)


def test_heinsen_identity_cross_check():
    """The jnp Heinsen formulation agrees with the kernel and the scan."""
    rng = np.random.default_rng(0)
    B, T, D = 2, 33, 5
    log_a = rand(rng, B, T, D, lo=-2.0, hi=0.0)
    log_b = rand(rng, B, T, D)
    log_h0 = rand(rng, B, D)
    a = ref.heinsen_scan_log(log_a, log_b, log_h0)
    b = ref.log_linear_recurrence(log_a, log_b, log_h0)
    c = scan.scan_log(log_a, log_b, log_h0, block_n=4, time_chunk=8)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(c, b, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("T", [1, 2, 3, 127, 128, 129])
def test_edge_sequence_lengths(T):
    rng = np.random.default_rng(T)
    B, D = 2, 3
    a = rand(rng, B, T, D, lo=-1.0, hi=1.0)
    b = rand(rng, B, T, D)
    h0 = rand(rng, B, D)
    got = scan.scan_linear(a, b, h0)
    want = ref.linear_recurrence(a, b, h0)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_g_positivity_and_continuity():
    x = jnp.linspace(-10, 10, 2001)
    g = ref.g(x)
    assert bool(jnp.all(g > 0)), "g must be positive"
    # continuity at 0: g(0-) = σ(0) = 0.5 = g(0+)
    np.testing.assert_allclose(float(ref.g(jnp.asarray(0.0))), 0.5)
    np.testing.assert_allclose(ref.log_g(x), jnp.log(g), rtol=1e-5,
                               atol=1e-5)


def test_vmem_estimate_under_budget():
    # the default blocking must fit comfortably in a 16 MiB VMEM
    assert scan.vmem_bytes() < 4 * 1024 * 1024


def test_depth_estimate_monotone_and_log():
    d512 = scan.depth_estimate(512)
    d4096 = scan.depth_estimate(4096)
    assert d512 < 512, "parallel depth must beat BPTT"
    assert d4096 < 4096
    assert d4096 <= 8 * d512, "depth growth should be ~linear in chunks"


class TestGradients:
    """Custom VJPs vs autodiff through the sequential reference."""

    def check(self, fn_ad, fn_ref, args, tol=2e-3):
        def loss_ad(*a):
            return jnp.sum(jnp.tanh(fn_ad(*a)))

        def loss_ref(*a):
            return jnp.sum(jnp.tanh(fn_ref(*a)))

        ga = jax.grad(loss_ad, argnums=tuple(range(len(args))))(*args)
        gr = jax.grad(loss_ref, argnums=tuple(range(len(args))))(*args)
        for x, y in zip(ga, gr):
            np.testing.assert_allclose(x, y, rtol=tol, atol=tol)

    def test_scan_linear_vjp(self):
        from compile.kernels import vjp
        rng = np.random.default_rng(0)
        B, T, D = 2, 21, 3
        a = rand(rng, B, T, D, lo=0.05, hi=0.95)
        b = rand(rng, B, T, D)
        h0 = rand(rng, B, D)
        self.check(vjp.scan_linear_ad, ref.linear_recurrence, (a, b, h0))

    def test_mingru_vjp(self):
        from compile.kernels import vjp
        rng = np.random.default_rng(1)
        B, T, D = 2, 17, 4
        k = rand(rng, B, T, D)
        pre = rand(rng, B, T, D)
        h0 = rand(rng, B, D, lo=0.1, hi=1.0)
        self.check(vjp.mingru_scan_ad, ref.mingru_sequential, (k, pre, h0))

    def test_minlstm_vjp(self):
        from compile.kernels import vjp
        rng = np.random.default_rng(2)
        B, T, D = 2, 17, 4
        p = rand(rng, B, T, D)
        k = rand(rng, B, T, D)
        pre = rand(rng, B, T, D)
        h0 = rand(rng, B, D, lo=0.1, hi=1.0)
        self.check(vjp.minlstm_scan_ad, ref.minlstm_sequential,
                   (p, k, pre, h0))

    def test_scan_log_vjp(self):
        from compile.kernels import vjp
        rng = np.random.default_rng(3)
        B, T, D = 2, 13, 3
        la = rand(rng, B, T, D, lo=-2.0, hi=0.0)
        lb = rand(rng, B, T, D)
        lh0 = rand(rng, B, D)
        self.check(vjp.scan_log_ad, ref.log_linear_recurrence,
                   (la, lb, lh0), tol=5e-3)
