"""L2: model semantics — parameter counts (the paper's efficiency claim),
parallel/sequential equivalence per mixer, backbone wiring, task losses."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import optim, tasks
from compile.kernels import vjp
from compile.models import backbone

vjp.CONFIG.update(block_n=64, time_chunk=16)

KINDS = ["mingru", "minlstm", "gru", "lstm", "s6", "transformer"]


def count_params(tree):
    return sum(x.size for x in jax.tree_util.tree_leaves(tree))


def make_cfg(kind, **kw):
    cfg = dict(kind=kind, n_layers=2, d_model=16, expansion=2, vocab_in=12,
               vocab_out=12, conv=False, mlp=False, dropout=0.0, max_len=40)
    cfg.update(kw)
    return backbone.with_defaults(cfg)


# ---------------------------------------------------------------------------
# Section 3 parameter-count claims
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("alpha,expect", [(1, 0.33), (2, 0.22), (3, 0.17),
                                          (4, 0.13)])
def test_mingru_parameter_ratio_vs_gru(alpha, expect):
    """minGRU ≈ O(2·dh·dx) vs GRU O(3·dh(dx+dh)) — paper §3.1.3 ratios."""
    from compile.models import gru, mingru
    d = 32
    cfg = make_cfg("mingru", d_model=d, expansion=alpha)
    key = jax.random.PRNGKey(0)
    # compare the recurrent projections only (exclude the shared down-proj,
    # which exists for both under state expansion)
    p_min = mingru.init(key, cfg)
    p_gru = gru.init(key, cfg)
    n_min = count_params({k: v for k, v in p_min.items() if k != "down"})
    n_gru = count_params({k: v for k, v in p_gru.items() if k != "down"})
    ratio = n_min / n_gru
    assert abs(ratio - expect) < 0.04, f"α={alpha}: ratio {ratio:.3f}"


@pytest.mark.parametrize("alpha,expect", [(1, 0.38), (2, 0.25), (3, 0.19),
                                          (4, 0.15)])
def test_minlstm_parameter_ratio_vs_lstm(alpha, expect):
    from compile.models import lstm, minlstm
    d = 32
    cfg = make_cfg("minlstm", d_model=d, expansion=alpha)
    key = jax.random.PRNGKey(0)
    p_min = minlstm.init(key, cfg)
    p_lstm = lstm.init(key, cfg)
    n_min = count_params({k: v for k, v in p_min.items() if k != "down"})
    n_lstm = count_params({k: v for k, v in p_lstm.items() if k != "down"})
    ratio = n_min / n_lstm
    assert abs(ratio - expect) < 0.04, f"α={alpha}: ratio {ratio:.3f}"


# ---------------------------------------------------------------------------
# parallel ≡ sequential for every mixer and backbone option set
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("conv,mlp", [(False, False), (True, True)])
def test_parallel_sequential_equivalence(kind, conv, mlp):
    cfg = make_cfg(kind, conv=conv, mlp=mlp)
    key = jax.random.PRNGKey(1)
    params = backbone.init(key, cfg)
    B, T = 2, 19
    x = jax.random.randint(jax.random.PRNGKey(2), (B, T), 0, 12)
    logits_par, _ = backbone.apply_parallel(params, cfg, x)
    state = backbone.init_state(cfg, B)
    outs = []
    for t in range(T):
        lt, state = backbone.apply_step(params, cfg, x[:, t], state)
        outs.append(lt)
    logits_seq = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(logits_par, logits_seq, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("kind", KINDS)
def test_prefill_state_continues_decode(kind):
    """prefill(x[:t]) then step(x[t]) == parallel logits at t."""
    cfg = make_cfg(kind)
    key = jax.random.PRNGKey(3)
    params = backbone.init(key, cfg)
    B, T = 2, 12
    x = jax.random.randint(jax.random.PRNGKey(4), (B, T), 0, 12)
    full, _ = backbone.apply_parallel(params, cfg, x)
    _, st = backbone.apply_parallel(params, cfg, x[:, :T - 1])
    last, _ = backbone.apply_step(params, cfg, x[:, T - 1], st)
    np.testing.assert_allclose(full[:, -1], last, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# continuous-input (RL) path
# ---------------------------------------------------------------------------

def test_continuous_input_regression():
    cfg = backbone.with_defaults(dict(
        kind="mingru", n_layers=2, d_model=16, expansion=2, vocab_in=None,
        input_dim=7, vocab_out=2, mlp=True, dropout=0.0, max_len=16))
    key = jax.random.PRNGKey(0)
    params = backbone.init(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 16, 7))
    out, _ = backbone.apply_parallel(params, cfg, x)
    assert out.shape == (3, 16, 2)
    # sequential
    st = backbone.init_state(cfg, 3)
    o, _ = backbone.apply_step(params, cfg, x[:, 0], st)
    np.testing.assert_allclose(o, out[:, 0], rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# losses / metrics / optimizer
# ---------------------------------------------------------------------------

def test_masked_ce_ignores_unmasked():
    logits = jnp.zeros((1, 4, 5)).at[0, 0, 2].set(100.0)
    targets = jnp.asarray([[2, 0, 0, 0]], jnp.int32)
    mask = jnp.asarray([[1.0, 0, 0, 0]])
    loss = tasks.masked_ce_loss(logits, targets, mask)
    assert float(loss) < 1e-3
    # flipping an unmasked target changes nothing
    loss2 = tasks.masked_ce_loss(
        logits, targets.at[0, 3].set(4), mask)
    assert float(loss) == float(loss2)


def test_seq_acc_requires_all_positions():
    # 2 masked positions; one correct, one wrong → token acc .5, seq acc 0
    logits = jnp.zeros((1, 2, 4))
    logits = logits.at[0, 0, 1].set(10.0).at[0, 1, 2].set(10.0)
    targets = jnp.asarray([[1, 3]], jnp.int32)
    mask = jnp.ones((1, 2))
    loss, tok, seq = tasks.masked_ce_metrics(logits, targets, mask)
    assert abs(float(tok) - 0.5) < 1e-6
    assert float(seq) == 0.0
    # fix the second position → seq acc 1
    _, _, seq2 = tasks.masked_ce_metrics(
        logits, targets.at[0, 1].set(2), mask)
    assert float(seq2) == 1.0


def test_adamw_reduces_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = optim.init(params)

    def loss(p):
        return jnp.sum(jnp.square(p["w"]))

    for _ in range(200):
        g = jax.grad(loss)(params)
        params, opt, _ = optim.adamw_update(params, g, opt,
                                            jnp.asarray(0.1))
    assert float(loss(params)) < 1e-2
    assert int(opt["step"]) == 200


def test_grad_clip_norm():
    g = {"a": jnp.asarray([3.0, 4.0])}
    clipped, norm = optim.clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 5.0) < 1e-5
    cn = optim.global_norm(clipped)
    assert abs(float(cn) - 1.0) < 1e-4


def test_train_step_decreases_loss_all_kinds():
    for kind in ["mingru", "minlstm"]:
        cfg = make_cfg(kind, conv=True, mlp=True, dropout=0.1)
        init_fn = tasks.make_init(cfg)
        params, opt = init_fn(jnp.asarray(0, jnp.int32), jnp.asarray(0.0))
        ts = tasks.make_train_step(cfg, "masked_ce")
        x = jax.random.randint(jax.random.PRNGKey(0), (4, 20), 0, 12)
        y = jnp.roll(x, -1, axis=1)
        m = jnp.ones((4, 20))
        first = None
        for i in range(15):
            params, opt, loss, _ = ts(params, opt, x, y, m,
                                      jnp.asarray(1e-2),
                                      jnp.asarray(i, jnp.int32))
            if first is None:
                first = float(loss)
        assert float(loss) < first, f"{kind}: {first} → {float(loss)}"


def test_forget_bias_shifts_minlstm_init():
    cfg = make_cfg("minlstm")
    init_fn = tasks.make_init(cfg)
    p0, _ = init_fn(jnp.asarray(0, jnp.int32), jnp.asarray(0.0))
    p4, _ = init_fn(jnp.asarray(0, jnp.int32), jnp.asarray(4.0))
    b0 = p0["blocks"][0]["mixer"]["linear_f"]["b"]
    b4 = p4["blocks"][0]["mixer"]["linear_f"]["b"]
    np.testing.assert_allclose(b4 - b0, 4.0, rtol=1e-6)
    # weights unaffected
    np.testing.assert_allclose(p0["blocks"][0]["mixer"]["linear_f"]["w"],
                               p4["blocks"][0]["mixer"]["linear_f"]["w"])


def test_dropout_only_in_train_mode():
    cfg = make_cfg("mingru", dropout=0.5)
    params = backbone.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 12)
    a, _ = backbone.apply_parallel(params, cfg, x, train=False)
    b, _ = backbone.apply_parallel(params, cfg, x, train=False)
    np.testing.assert_allclose(a, b)
    c, _ = backbone.apply_parallel(params, cfg, x, train=True,
                                   rng=jax.random.PRNGKey(2))
    assert not np.allclose(a, c), "dropout should perturb training forward"
