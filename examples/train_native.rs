//! Artifact-free training demo: the native Rust trainer (log-space scan
//! VJP + AdamW) learns a Chomsky-hierarchy task end-to-end, checkpoints,
//! and serves the result through the native inference backend — no
//! Python, no XLA, no artifacts.
//!
//!     cargo run --release --example train_native

use minrnn::backend::native::NativeTrainer;
use minrnn::backend::{NativeBackend, NativeInit, NativeModel};
use minrnn::config::{Schedule, TrainConfig};
use minrnn::coordinator::server::{serve, Request};
use minrnn::coordinator::{data_source, trainer};
use minrnn::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    minrnn::util::logging::init();

    // a small minGRU backbone sized for the shared 16-symbol token map
    let model = NativeModel::init_random(&NativeInit {
        kind: "mingru".to_string(),
        n_layers: 2,
        d_model: 48,
        vocab_in: Some(16),
        vocab_out: 16,
        ..Default::default()
    }, 0)?;
    let mut nt = NativeTrainer::new(model, "even_pairs_native");

    let (batch, seq_len) = (16usize, 48usize);
    let mut data = data_source("chomsky/even_pairs", batch, seq_len, None)?;
    let ckpt_dir = std::env::temp_dir().join("minrnn_train_native_demo");
    let cfg = TrainConfig {
        steps: 200,
        lr: 3e-3,
        schedule: Schedule::Constant,
        eval_every: 50,
        log_every: 25,
        checkpoint: Some(ckpt_dir.clone()),
        ..Default::default()
    };
    let report = trainer::run_loop(&mut nt, &cfg, 0, data.as_mut())?;
    let (_, first_loss) = report.loss_curve[0];
    println!("trained {} steps: loss {:.3} -> {:.3} ({:.1} steps/s)",
             report.steps_run, first_loss, report.final_loss,
             report.steps_per_sec);
    if let Some(eval) = report.final_eval {
        println!("final eval: loss {:.3}, token_acc {:.3}, seq_acc {:.3}",
                 eval.loss, eval.token_acc, eval.seq_acc);
    }

    // the training checkpoint serves directly through native inference
    let ckpt = ckpt_dir.join("even_pairs_native.final.ckpt");
    let backend = NativeBackend::from_checkpoint(&ckpt)?;
    let mut rng = Rng::new(7);
    let requests: Vec<Request> = (0..6).map(|i| Request {
        id: i,
        prompt: (0..4 + rng.usize_below(4))
            .map(|_| 2 + rng.below(2) as i32).collect(),
        n_tokens: 8,
        session: None,
    }).collect();
    let stats = serve(&backend, requests, 0.8, 0)?;
    println!("served {} requests at {:.1} tok/s from the trained \
              checkpoint", stats.responses.len(),
             stats.throughput_tok_s());
    assert!(report.final_loss < first_loss,
            "training must reduce the loss");
    println!("train_native OK");
    Ok(())
}
