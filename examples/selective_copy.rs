//! Selective Copying (Tables 1–2 workload): train minGRU with 1 vs 3
//! layers and show the layer effect the paper highlights in Table 1.
//!
//!     make artifacts && cargo run --release --example selective_copy [steps]

use std::path::Path;
use std::rc::Rc;

use minrnn::config::{Schedule, TrainConfig};
use minrnn::coordinator::data_source_for;
use minrnn::coordinator::trainer::Trainer;
use minrnn::runtime::{Manifest, Model, Runtime};
use minrnn::util::table::Table;

fn main() -> anyhow::Result<()> {
    minrnn::util::logging::init();
    let steps: usize = std::env::args().nth(1)
        .and_then(|s| s.parse().ok()).unwrap_or(200);

    let rt = Runtime::cpu()?;
    let manifest = Rc::new(Manifest::load(Path::new("artifacts"))?);
    let mut table = Table::new(
        "Selective Copying: effect of depth (Table 1 trend)",
        &["model", "layers", "token acc", "seq acc"]);

    for layers in [1usize, 3] {
        let model = Model::open(&rt, manifest.clone(),
                                &format!("tab1_mingru_l{layers}"))?;
        let mut data = data_source_for(&model.variant)?;
        let cfg = TrainConfig {
            variant: model.variant.name.clone(),
            steps,
            lr: 1e-3,
            schedule: Schedule::WarmupCosine { warmup: steps / 10 },
            eval_every: steps,
            eval_batches: 8,
            log_every: (steps / 10).max(1),
            ..Default::default()
        };
        let trainer = Trainer::new(&model, cfg);
        let mut state = model.init(0, 0.0)?;
        let report = trainer.run(&mut state, data.as_mut())?;
        let ev = report.final_eval.unwrap_or_default();
        table.row(vec!["minGRU".into(), layers.to_string(),
                       format!("{:.3}", ev.token_acc),
                       format!("{:.3}", ev.seq_acc)]);
    }
    println!("{}", table.render());
    Ok(())
}
