//! Chomsky Hierarchy length generalization (Tables 4/5 workload): train
//! minLSTM on Even Pairs with short sequences, evaluate far beyond the
//! training lengths.
//!
//!     make artifacts && cargo run --release --example chomsky_generalization

use std::path::Path;
use std::rc::Rc;

use minrnn::config::{Schedule, TrainConfig};
use minrnn::coordinator::trainer::{FnSource, Trainer};
use minrnn::data::chomsky;
use minrnn::runtime::{Manifest, Model, Runtime};
use minrnn::util::rng::Rng;
use minrnn::util::table::Table;

fn main() -> anyhow::Result<()> {
    minrnn::util::logging::init();
    let steps: usize = std::env::args().nth(1)
        .and_then(|s| s.parse().ok()).unwrap_or(150);

    let rt = Runtime::cpu()?;
    let manifest = Rc::new(Manifest::load(Path::new("artifacts"))?);
    let model = Model::open(&rt, manifest, "chm_even_pairs_minlstm")?;
    let train_t = model.variant.seq_len;
    let b = model.variant.batch;

    let task = chomsky::by_name("even_pairs").unwrap();
    let train_max = task.max_content_for(train_t);
    let mut src = FnSource {
        f: move |rng: &mut Rng| {
            let task = chomsky::EvenPairs;
            chomsky::batch(&task, rng, b, train_t, 1,
                           chomsky::ChomskyTask::max_content_for(
                               &task, train_t))
        },
    };
    let cfg = TrainConfig {
        variant: model.variant.name.clone(),
        steps,
        lr: 1e-3,
        schedule: Schedule::WarmupCosine { warmup: steps / 10 },
        eval_every: 0,
        log_every: (steps / 10).max(1),
        ..Default::default()
    };
    let trainer = Trainer::new(&model, cfg);
    let mut state = model.init(0, 1.0)?;
    trainer.run(&mut state, &mut src)?;

    let mut table = Table::new(
        &format!("Even Pairs: trained on content ≤ {train_max}, \
                  evaluated beyond"),
        &["eval T", "content range", "seq acc"]);
    let mut rng = Rng::new(99);
    for ef in &model.variant.eval_files {
        let eval_max = task.max_content_for(ef.seq_len);
        let lo = if ef.seq_len > train_t { train_max + 1 } else { 1 };
        let lo = lo.min(eval_max);
        let mut acc = 0.0;
        let n = 6;
        for _ in 0..n {
            let batch = chomsky::batch(task.as_ref(), &mut rng, ef.batch,
                                       ef.seq_len, lo, eval_max);
            acc += model.eval(&state, &batch)?.seq_acc / n as f32;
        }
        table.row(vec![ef.seq_len.to_string(),
                       format!("{lo}..{eval_max}"),
                       format!("{acc:.3}")]);
    }
    println!("{}", table.render());
    Ok(())
}
