//! End-to-end headline run (DESIGN.md §Deliverables): train the Figure-2
//! minGRU character language model on the ~1M-char synthetic corpus for a
//! few hundred steps, log the loss curve, compare against minLSTM, and
//! sample text.  Results land in results/e2e_lm.md and EXPERIMENTS.md
//! quotes them.
//!
//!     make artifacts && cargo run --release --example lm_shakespeare [steps]

use std::path::Path;
use std::rc::Rc;

use minrnn::bench_harness::lm::LmSource;
use minrnn::config::{Schedule, TrainConfig};
use minrnn::coordinator::{infer, trainer::Trainer};
use minrnn::data::corpus::CharVocab;
use minrnn::runtime::{Manifest, Model, PjrtBackend, Runtime};
use minrnn::util::rng::Rng;
use minrnn::util::table::{fnum, Table};

fn main() -> anyhow::Result<()> {
    minrnn::util::logging::init();
    let steps: usize = std::env::args().nth(1)
        .and_then(|s| s.parse().ok()).unwrap_or(300);

    let rt = Runtime::cpu()?;
    let manifest = Rc::new(Manifest::load(Path::new("artifacts"))?);
    let mut table = Table::new(
        &format!("End-to-end char-LM training ({steps} steps, B=8, T=256, \
                  3 layers, d=128)"),
        &["model", "step", "train loss", "test loss"]);

    for kind in ["mingru", "minlstm"] {
        let model = Model::open(&rt, manifest.clone(),
                                &format!("fig2_{kind}"))?;
        let mut src = LmSource::new(model.variant.batch,
                                    model.variant.seq_len);
        let cfg = TrainConfig {
            variant: model.variant.name.clone(),
            steps,
            lr: 1e-3,
            schedule: Schedule::WarmupCosine { warmup: steps / 10 },
            eval_every: (steps / 10).max(1),
            eval_batches: 2,
            log_every: (steps / 20).max(1),
            ..Default::default()
        };
        let trainer = Trainer::new(&model, cfg);
        let mut state = model.init(0, 0.0)?;
        let report = trainer.run(&mut state, &mut src)?;

        let losses: std::collections::BTreeMap<usize, f32> =
            report.loss_curve.iter().cloned().collect();
        for (step, ev) in &report.eval_curve {
            let train_l = losses.range(..=step).next_back()
                .map(|(_, &l)| l).unwrap_or(f32::NAN);
            table.row(vec![kind.into(), step.to_string(),
                           fnum(train_l as f64), fnum(ev.loss as f64)]);
        }
        println!("{kind}: best test loss {:.4} @ step {} \
                  ({:.2} steps/s)",
                 report.best_eval_loss, report.best_eval_step,
                 report.steps_per_sec);
        assert!(report.best_eval_loss
                < report.eval_curve.first().unwrap().1.loss,
                "{kind}: test loss did not improve");

        // sample a continuation through the decode path
        let vocab = CharVocab::new();
        let mut rng = Rng::new(7);
        let backend = PjrtBackend::new(&model, &state.params);
        let out = infer::generate(&backend, &vocab.encode("The "), 120, 0.8,
                                  &mut rng)?;
        println!("{kind} sample: {:?}\n", vocab.decode(&out));
    }

    println!("{}", table.render());
    std::fs::create_dir_all("results")?;
    std::fs::write("results/e2e_lm.md", table.render_markdown())?;
    println!("wrote results/e2e_lm.md");
    Ok(())
}
