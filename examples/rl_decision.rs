//! Offline RL (Table 3 workload): build a Medium-Expert dataset in the
//! PointMass simulator, train Decision-minGRU on it, and roll the policy
//! out in the live environment with return conditioning.
//!
//!     make artifacts && cargo run --release --example rl_decision [steps]

use std::path::Path;
use std::rc::Rc;

use minrnn::config::{Schedule, TrainConfig};
use minrnn::coordinator::infer::rollout_decision;
use minrnn::coordinator::trainer::{FnSource, Trainer};
use minrnn::data::rl::{normalized_score, OfflineDataset, Regime};
use minrnn::runtime::{Manifest, Model, PjrtBackend, Runtime};
use minrnn::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    minrnn::util::logging::init();
    let steps: usize = std::env::args().nth(1)
        .and_then(|s| s.parse().ok()).unwrap_or(200);

    let rt = Runtime::cpu()?;
    let manifest = Rc::new(Manifest::load(Path::new("artifacts"))?);
    let model = Model::open(&rt, manifest, "rl_pointmass_mingru")?;
    let (b, ctx) = (model.variant.batch, model.variant.seq_len);

    println!("building Medium-Expert offline dataset (PointMass)...");
    let ds = OfflineDataset::build("pointmass", Regime::MediumExpert, 120, 0);
    let returns: Vec<f32> = ds.episodes.iter().map(|e| e.ret()).collect();
    println!("dataset: {} episodes, return range [{:.1}, {:.1}]",
             ds.episodes.len(),
             returns.iter().cloned().fold(f32::MAX, f32::min),
             returns.iter().cloned().fold(f32::MIN, f32::max));

    let ds_train = OfflineDataset::build("pointmass", Regime::MediumExpert,
                                         120, 0);
    let mut src = FnSource {
        f: move |rng: &mut Rng| ds_train.batch(rng, b, ctx),
    };
    let cfg = TrainConfig {
        variant: model.variant.name.clone(),
        steps,
        lr: 1e-3,
        schedule: Schedule::WarmupCosine { warmup: steps / 10 },
        eval_every: 0,
        log_every: (steps / 10).max(1),
        ..Default::default()
    };
    let trainer = Trainer::new(&model, cfg);
    let mut state = model.init(0, 0.0)?;
    trainer.run(&mut state, &mut src)?;

    let target = ds.target_return();
    println!("rolling out with target return {target:.1}...");
    let backend = PjrtBackend::new(&model, &state.params);
    let mut total = 0f32;
    let n = 6;
    for k in 0..n {
        let ret = rollout_decision(&backend, &ds, target, 1000 + k)?;
        println!("  rollout {k}: raw return {ret:.1}");
        total += ret;
    }
    let raw = total / n as f32;
    let score = normalized_score("pointmass", raw, 0);
    println!("mean raw return {raw:.1} → expert-normalized score {score:.1} \
              (0 = random policy, 100 = expert)");
    Ok(())
}
