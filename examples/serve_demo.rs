//! Serving demo: dynamic batching over the fixed-batch decode executables
//! (the L3 "coordinator as request router" face of the system).
//!
//!     make artifacts && cargo run --release --example serve_demo

use std::path::Path;
use std::rc::Rc;

use minrnn::coordinator::server::{serve, Request};
use minrnn::runtime::{Manifest, Model, PjrtBackend, Runtime};
use minrnn::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    minrnn::util::logging::init();
    let rt = Runtime::cpu()?;
    let manifest = Rc::new(Manifest::load(Path::new("artifacts"))?);
    let model = Model::open(&rt, manifest, "fig2_mingru")?;
    let state = model.init(0, 0.0)?;

    let mut rng = Rng::new(3);
    let requests: Vec<Request> = (0..20).map(|i| Request {
        id: i,
        prompt: (0..6 + rng.usize_below(10))
            .map(|_| rng.below(64) as i32).collect(),
        n_tokens: 12,
        session: None,
    }).collect();

    let backend = PjrtBackend::new(&model, &state.params);
    let stats = serve(&backend, requests, 0.8, 0)?;
    println!("served {} requests, {} tokens, {:.2}s total",
             stats.responses.len(), stats.tokens_generated, stats.total_s);
    println!("throughput: {:.1} tok/s", stats.throughput_tok_s());
    println!("mean latency: {:.1} ms", stats.mean_latency_s() * 1e3);
    for r in stats.responses.iter().take(5) {
        println!("  req {:2}: batch {} queue {:.1}ms service {:.1}ms \
                  tokens {:?}",
                 r.id, r.batch, r.queue_s * 1e3, r.service_s * 1e3,
                 &r.tokens[..r.tokens.len().min(6)]);
    }
    assert!(stats.responses.iter().all(|r| r.tokens.len() == 12));
    println!("serve_demo OK");
    Ok(())
}
