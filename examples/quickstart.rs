//! Quickstart: load the AOT artifacts, train a tiny minGRU char-LM for a
//! few steps, evaluate, sample text, and round-trip a checkpoint.
//!
//!     make artifacts && cargo run --release --example quickstart

use std::path::Path;
use std::rc::Rc;

use minrnn::config::TrainConfig;
use minrnn::coordinator::{infer, trainer::Trainer};
use minrnn::coordinator::data_source_for;
use minrnn::data::corpus::CharVocab;
use minrnn::runtime::{Manifest, Model, PjrtBackend, Runtime};
use minrnn::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    minrnn::util::logging::init();
    let rt = Runtime::cpu()?;
    println!("PJRT platform: {}", rt.platform());

    let manifest = Rc::new(Manifest::load(Path::new("artifacts"))?);
    let model = Model::open(&rt, manifest, "quickstart")?;
    println!("variant {}: {} parameter tensors ({} scalars)",
             model.variant.name, model.variant.n_params(),
             model.variant.param_elements());

    // 1. initialize on device via the exported init graph
    let mut state = model.init(42, 0.0)?;

    // 2. train briefly on the synthetic char corpus
    let cfg = TrainConfig {
        variant: "quickstart".into(),
        steps: 30,
        lr: 2e-3,
        eval_every: 15,
        log_every: 5,
        ..Default::default()
    };
    let trainer = Trainer::new(&model, cfg);
    let mut data = data_source_for(&model.variant)?;
    let report = trainer.run(&mut state, data.as_mut())?;
    println!("loss {:.3} → {:.3} over {} steps ({:.1} steps/s)",
             report.loss_curve.first().map(|x| x.1).unwrap_or(0.0),
             report.final_loss, report.steps_run, report.steps_per_sec);
    assert!(report.final_loss < report.loss_curve[0].1,
            "loss did not decrease");

    // 3. sample from the model through the sequential decode path
    let vocab = CharVocab::new();
    let mut rng = Rng::new(0);
    let prompt = vocab.encode("The ");
    let backend = PjrtBackend::new(&model, &state.params);
    let tokens = infer::generate(&backend, &prompt, 60, 0.9, &mut rng)?;
    println!("sample: {:?}", vocab.decode(&tokens));

    // 4. checkpoint round-trip
    let dir = std::env::temp_dir().join("minrnn_quickstart");
    std::fs::create_dir_all(&dir)?;
    let ckpt = dir.join("quickstart.ckpt");
    model.save_checkpoint(&state, &ckpt)?;
    let restored = model.load_checkpoint(&ckpt)?;
    println!("checkpoint round-trip OK (step {})", restored.step);
    println!("quickstart OK");
    Ok(())
}
