//! Dependency-free HTTP/1.1 front-end over the sharded serving tier —
//! hand-rolled on [`std::net::TcpListener`], in the spirit of the
//! vendored-crate policy: no hyper, no tokio, no serde.
//!
//! Endpoints (all JSON, via [`crate::util::json`]):
//!
//! * `POST /v1/submit` — `{"prompt": [i32…], "n_tokens": N,
//!   "session": S?}` → `{"id", "tokens", "queue_s", "service_s",
//!   "batch"}`.  The connection blocks until the tokens are generated;
//!   greedy output is bit-identical to an in-process
//!   [`super::scheduler::SubmitHandle`] submission (pinned by
//!   `tests/http_props.rs`).
//! * `GET /v1/stats` — live [`super::server::ServeStats`] wire shape
//!   ([`super::server::ServeStats::to_json`]) plus `"replicas"`.
//! * `GET /v1/health` — `{"health", "replicas"}`, cheap enough for a
//!   load-balancer probe.
//! * `POST /v1/reload` — `{"checkpoint": "path"}`; rolls the checkpoint
//!   across the replicas one at a time ([`super::shard::Shard::reload`])
//!   with zero dropped requests.
//! * `POST /v1/shutdown` — graceful drain; the process's
//!   [`HttpServer::wait`] then returns the final stats.
//!
//! Error responses are `{"error": …, "kind": …}` where `error` is the
//! uniform [`std::fmt::Display`] rendering of the typed error
//! ([`super::scheduler::SubmitError`], or the checkpoint
//! [`crate::util::io::LoadError`] surfaced through the reload reply) —
//! no ad-hoc `format!` per call site.  Submission errors map onto
//! status codes: empty prompt → 400, queue full / shutting down → 503,
//! expired → 504, failed → 500.
//!
//! The concurrency model is deliberately boring: one accept loop, one
//! thread per connection (each request blocks on its replica anyway),
//! one request per connection (`Connection: close`).  The interesting
//! concurrency — batching, routing, hot-swap — lives in
//! [`super::shard`].

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, Context, Result};

use crate::util::json::{self, Json};
use crate::{log_info, log_warn};

use super::scheduler::SubmitError;
use super::server::ServeStats;
use super::shard::Shard;

/// Largest accepted request body.  Prompts are token-id arrays, so even
/// a book-length prompt is far below this; anything bigger is a client
/// bug or abuse.
const MAX_BODY_BYTES: usize = 16 << 20;

/// How long a connection may dribble its request in before we hang up.
const READ_TIMEOUT: Duration = Duration::from_secs(30);

/// The serving tier's network front door: an accept loop owning a
/// [`Shard`].  Bind, then either [`HttpServer::wait`] (deployments park
/// here; returns the final drained stats after a shutdown request) or
/// keep the handle around and [`HttpServer::stop`] from the same
/// process (tests).
pub struct HttpServer {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept: Option<JoinHandle<Result<ServeStats>>>,
}

impl HttpServer {
    /// Bind `addr` (e.g. `127.0.0.1:8080`; port 0 picks a free port —
    /// read it back from [`HttpServer::addr`]) and start serving the
    /// shard.
    pub fn bind(addr: &str, shard: Shard) -> Result<HttpServer> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding http server to {addr}"))?;
        let local = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let flag = Arc::clone(&shutdown);
        let accept = std::thread::Builder::new()
            .name("http-accept".to_string())
            .spawn(move || accept_loop(listener, shard, flag))?;
        log_info!("http: serving on {local} (POST /v1/submit, GET \
                   /v1/stats, GET /v1/health, POST /v1/reload, POST \
                   /v1/shutdown)");
        Ok(HttpServer { addr: local, shutdown, accept: Some(accept) })
    }

    /// The bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Ask the server to stop from this process: equivalent to
    /// `POST /v1/shutdown` without the socket round-trip.
    pub fn stop(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        wake(self.addr);
    }

    /// Block until the server shuts down (via `POST /v1/shutdown` or
    /// [`HttpServer::stop`]) and every replica drains, then return the
    /// merged lifetime [`ServeStats`].
    pub fn wait(mut self) -> Result<ServeStats> {
        let accept = self.accept.take()
            .ok_or_else(|| anyhow!("http server already waited on"))?;
        accept.join().map_err(|_| anyhow!("http accept loop panicked"))?
    }
}

/// Unblock an accept loop that is parked in `accept()` by completing
/// one throwaway connection.
fn wake(addr: SocketAddr) {
    let _ = TcpStream::connect(addr);
}

fn accept_loop(listener: TcpListener, shard: Shard,
               shutdown: Arc<AtomicBool>) -> Result<ServeStats> {
    let shard = Arc::new(shard);
    let addr = listener.local_addr()?;
    let mut workers: Vec<JoinHandle<()>> = Vec::new();
    for conn in listener.incoming() {
        if shutdown.load(Ordering::SeqCst) {
            break;
        }
        let stream = match conn {
            Ok(s) => s,
            Err(e) => {
                log_warn!("http: accept failed: {e}");
                continue;
            }
        };
        let conn_shard = Arc::clone(&shard);
        let conn_flag = Arc::clone(&shutdown);
        workers.push(std::thread::spawn(move || {
            if let Err(e) = handle_connection(stream, &conn_shard,
                                              &conn_flag, addr) {
                log_warn!("http: connection error: {e:#}");
            }
        }));
        // reap finished handlers so the vec tracks live connections only
        workers.retain(|w| !w.is_finished());
    }
    for w in workers {
        let _ = w.join();
    }
    let shard = Arc::try_unwrap(shard)
        .map_err(|_| anyhow!("a connection still holds the shard after \
                              shutdown"))?;
    log_info!("http: draining replicas");
    shard.shutdown()
}

/// Serve exactly one request on `stream` and close it.
fn handle_connection(mut stream: TcpStream, shard: &Shard,
                     shutdown: &AtomicBool, addr: SocketAddr) -> Result<()> {
    stream.set_read_timeout(Some(READ_TIMEOUT))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    if reader.read_line(&mut line)? == 0 {
        return Ok(()); // bare connect (e.g. the shutdown wake); fine
    }
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();
    // headers: only Content-Length matters to us
    let mut content_len = 0usize;
    loop {
        let mut header = String::new();
        if reader.read_line(&mut header)? == 0 {
            break;
        }
        let header = header.trim();
        if header.is_empty() {
            break;
        }
        if let Some((key, val)) = header.split_once(':') {
            if key.trim().eq_ignore_ascii_case("content-length") {
                content_len = val.trim().parse().map_err(
                    |_| anyhow!("bad Content-Length '{}'", val.trim()))?;
            }
        }
    }
    if content_len > MAX_BODY_BYTES {
        return respond(&mut stream, 413, &json::obj(vec![
            ("error", json::s("request body too large")),
            ("kind", json::s("body_too_large")),
        ]));
    }
    let mut body = vec![0u8; content_len];
    reader.read_exact(&mut body)?;
    let (status, payload, stop) = route(&method, &path, &body, shard);
    respond(&mut stream, status, &payload)?;
    if stop {
        // flag first, then complete one connection to unpark accept()
        shutdown.store(true, Ordering::SeqCst);
        wake(addr);
    }
    Ok(())
}

/// Dispatch one parsed request.  Returns `(status, body, shutdown?)`.
fn route(method: &str, path: &str, body: &[u8], shard: &Shard)
         -> (u16, Json, bool) {
    match (method, path) {
        ("POST", "/v1/submit") => {
            let (status, payload) = submit(body, shard);
            (status, payload, false)
        }
        ("GET", "/v1/stats") => {
            let mut stats = shard.stats().to_json();
            if let Json::Obj(pairs) = &mut stats {
                pairs.push(("replicas".to_string(),
                            json::num(shard.replicas() as f64)));
            }
            (200, stats, false)
        }
        ("GET", "/v1/health") => {
            let health = shard.stats().health;
            (200, json::obj(vec![
                ("health", json::s(&health.to_string())),
                ("replicas", json::num(shard.replicas() as f64)),
            ]), false)
        }
        ("POST", "/v1/reload") => match reload(body, shard) {
            Ok(n) => (200, json::obj(vec![
                ("reloaded", json::num(n as f64)),
            ]), false),
            Err((status, e)) => (status, json::obj(vec![
                ("error", json::s(&e)),
                ("kind", json::s("reload_failed")),
            ]), false),
        },
        ("POST", "/v1/shutdown") => {
            (200, json::obj(vec![("draining", Json::Bool(true))]), true)
        }
        ("GET" | "POST", p) if ["/v1/submit", "/v1/stats", "/v1/health",
                                "/v1/reload", "/v1/shutdown"]
            .contains(&p) => {
            (405, json::obj(vec![
                ("error", json::s(&format!("method {method} not allowed \
                                            on {p}"))),
                ("kind", json::s("method_not_allowed")),
            ]), false)
        }
        _ => (404, json::obj(vec![
            ("error", json::s(&format!("no such endpoint: {method} \
                                        {path}"))),
            ("kind", json::s("not_found")),
        ]), false),
    }
}

/// `POST /v1/submit` body → shard submission → response body.
fn submit(body: &[u8], shard: &Shard) -> (u16, Json) {
    let parsed = match parse_submit(body) {
        Ok(p) => p,
        Err(e) => {
            return (400, json::obj(vec![
                ("error", json::s(&e)),
                ("kind", json::s("bad_request")),
            ]));
        }
    };
    let (prompt, n_tokens, session) = parsed;
    match shard.submit(prompt, n_tokens, session) {
        Ok(r) => (200, json::obj(vec![
            ("id", json::num(r.id as f64)),
            ("tokens", Json::Arr(
                r.tokens.iter().map(|&t| json::num(t as f64)).collect())),
            ("queue_s", json::num(r.queue_s)),
            ("service_s", json::num(r.service_s)),
            ("batch", json::num(r.batch as f64)),
        ])),
        // the typed error's Display rendering *is* the error body
        Err(e) => {
            let (status, kind) = match &e {
                SubmitError::EmptyPrompt { .. } => (400, "empty_prompt"),
                SubmitError::QueueFull(_) => (503, "queue_full"),
                SubmitError::Closed(_) => (503, "shutting_down"),
                SubmitError::Expired { .. } => (504, "expired"),
                SubmitError::Failed { .. } => (500, "failed"),
            };
            (status, json::obj(vec![
                ("error", json::s(&e.to_string())),
                ("kind", json::s(kind)),
            ]))
        }
    }
}

type SubmitBody = (Vec<i32>, usize, Option<u64>);

fn parse_submit(body: &[u8]) -> std::result::Result<SubmitBody, String> {
    let text = std::str::from_utf8(body)
        .map_err(|_| "body is not utf-8".to_string())?;
    let v = json::parse(text).map_err(|e| format!("bad json: {e}"))?;
    let prompt = v.get("prompt").and_then(Json::as_arr)
        .ok_or("missing 'prompt' (array of token ids)")?
        .iter()
        .map(|t| t.as_i64().map(|x| x as i32))
        .collect::<Option<Vec<i32>>>()
        .ok_or("'prompt' must contain only integer token ids")?;
    let n_tokens = v.get("n_tokens").and_then(Json::as_usize)
        .ok_or("missing 'n_tokens' (tokens to generate)")?;
    if n_tokens == 0 {
        return Err("'n_tokens' must be >= 1".to_string());
    }
    let session = match v.get("session") {
        None | Some(Json::Null) => None,
        Some(s) => Some(s.as_i64().map(|x| x as u64)
            .ok_or("'session' must be an integer id")?),
    };
    Ok((prompt, n_tokens, session))
}

/// `POST /v1/reload` body → rolling swap.  A load failure keeps the old
/// model serving and reports the typed load error's rendering.
fn reload(body: &[u8], shard: &Shard)
          -> std::result::Result<usize, (u16, String)> {
    let text = std::str::from_utf8(body)
        .map_err(|_| (400, "body is not utf-8".to_string()))?;
    let v = json::parse(text).map_err(|e| (400, format!("bad json: {e}")))?;
    let ckpt = v.get("checkpoint").and_then(Json::as_str)
        .ok_or_else(|| (400, "missing 'checkpoint' (path to an MRNN \
                             checkpoint)".to_string()))?;
    shard.reload(std::path::Path::new(ckpt))
        .map_err(|e| (500, format!("{e:#}")))
}

fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

fn respond(stream: &mut TcpStream, status: u16, payload: &Json)
           -> Result<()> {
    let body = json::to_string(payload);
    write!(stream,
           "HTTP/1.1 {status} {}\r\nContent-Type: application/json\r\n\
            Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
           reason(status), body.len())?;
    stream.flush()?;
    Ok(())
}
