//! Sharded multi-replica serving: N scheduler replicas — one
//! [`NativeBackend`] plus one session cache each — behind a
//! consistent-hash router, with rolling checkpoint hot-swap.
//!
//! This is the layer that turns the in-process
//! [`super::scheduler::SubmitHandle`] into a system the HTTP front-end
//! ([`super::http`]) can put on the network:
//!
//! * **Routing.**  Requests are routed by [`HashRing`] on their session
//!   key, so a returning conversation's turns land on the replica
//!   holding its O(1) decode state — the paper's constant-state
//!   advantage only pays off if the state is *found*.  Session-less
//!   requests spread by request id.
//! * **Isolation.**  Each replica is one OS thread owning its own
//!   backend, scheduler and [`SessionCache`]
//!   (`PJRT` handles are not `Send`, so the sharded tier is
//!   native-only); replicas exchange nothing but jobs and stats.
//! * **Hot-swap.**  [`Shard::reload`] rolls a new MRNN checkpoint across
//!   the replicas one at a time: the replica stops admitting, drains its
//!   in-flight generation, swaps backends, and resumes — requests that
//!   arrived meanwhile wait in its bounded inbox, so a rolling reload
//!   completes with zero dropped requests (`responses + expired +
//!   failed == submitted` holds across the swap; `tests/http_props.rs`
//!   pins it).  A checkpoint that fails to load
//!   ([`crate::util::io::LoadError`]) leaves the old model serving.
//!
//! The replica loop itself is a pump: it services its inbox and the
//! scheduler's [`super::scheduler::Scheduler::step`] in turns, draining
//! per-request outcomes to their waiting submitters as they land.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::backend::{NativeBackend, NativeInit, NativeModel};
use crate::runtime::backend::MAX_DYNAMIC_BATCH;
use crate::util::rng::splitmix64;
use crate::util::threads::{BoundedQueue, PushError};
use crate::{log_info, log_warn};

use super::scheduler::{Backpressure, Scheduler, SubmitError};
use super::server::{Request, Response, ServeConfig, ServeStats};
use super::session_cache::SessionCache;
use super::supervisor::panic_message;

/// Virtual nodes per replica on the [`HashRing`].  More vnodes smooth
/// the key distribution and shrink the slice of sessions a membership
/// change remaps; 64 keeps the imbalance under a few percent for small
/// replica counts while the ring stays a cache-line-scale binary search.
pub const DEFAULT_VNODES: usize = 64;

// ---------------------------------------------------------------------------
// consistent hashing
// ---------------------------------------------------------------------------

/// Consistent-hash ring over replica indices.
///
/// Each member contributes `vnodes` points (splitmix64 of member ×
/// vnode); a key routes to the owner of the first point clockwise from
/// the key's own hash.  The property that makes this worth it over
/// `key % n`: adding or removing a member only remaps the keys owned by
/// the affected ring segments — every other session keeps its replica,
/// and therefore its cached decode state (property-tested in
/// `tests/http_props.rs`).
pub struct HashRing {
    /// `(point, member)`, sorted — the ring flattened at 0.
    points: Vec<(u64, usize)>,
}

impl HashRing {
    /// Ring over an explicit member set (distinct indices).
    pub fn new(members: &[usize], vnodes: usize) -> HashRing {
        assert!(!members.is_empty(), "a hash ring needs >= 1 member");
        assert!(vnodes >= 1, "a hash ring needs >= 1 vnode per member");
        let mut points = Vec::with_capacity(members.len() * vnodes);
        for &m in members {
            for v in 0..vnodes {
                // one deterministic point per (member, vnode); the seed
                // layout keeps every member's vnode family disjoint
                let mut x = ((m as u64) << 32) ^ v as u64;
                points.push((splitmix64(&mut x), m));
            }
        }
        points.sort_unstable();
        HashRing { points }
    }

    /// Ring over replicas `0..n`.
    pub fn for_replicas(n: usize, vnodes: usize) -> HashRing {
        let members: Vec<usize> = (0..n).collect();
        HashRing::new(&members, vnodes)
    }

    /// The member owning `key`'s ring segment.
    pub fn route(&self, key: u64) -> usize {
        let mut x = key;
        let h = splitmix64(&mut x);
        let i = self.points.partition_point(|&(p, _)| p < h);
        // past the last point wraps to the first — it's a ring
        self.points[i % self.points.len()].1
    }
}

// ---------------------------------------------------------------------------
// model source
// ---------------------------------------------------------------------------

/// Where a replica's model comes from.  Every replica builds its *own*
/// backend instance from this (replicas live on their own threads and
/// share nothing), and [`Shard::reload`] swaps in
/// `ModelSource::Checkpoint`s at runtime.
#[derive(Clone, Debug)]
pub enum ModelSource {
    /// Load an MRNN checkpoint from disk.
    Checkpoint(PathBuf),
    /// Deterministic seeded random init — demos, tests, and the
    /// bit-identical in-process reference for the loopback property.
    Fresh(NativeInit, u64),
}

impl ModelSource {
    /// Instantiate one backend from this source.
    pub fn build(&self) -> Result<NativeBackend> {
        match self {
            ModelSource::Checkpoint(p) => NativeBackend::from_checkpoint(p),
            ModelSource::Fresh(init, seed) => {
                Ok(NativeBackend::new(NativeModel::init_random(init, *seed)?))
            }
        }
    }
}

// ---------------------------------------------------------------------------
// replica jobs
// ---------------------------------------------------------------------------

/// Per-request outcome a submitter blocks on.
type SubmitResult = std::result::Result<Response, SubmitError>;

/// What flows through a replica inbox.  Submissions carry their reply
/// channel so the replica can answer each request individually; control
/// jobs (stats, reload) ride the same queue and are therefore ordered
/// with respect to the traffic around them.
enum Job {
    Submit { req: Request, reply: mpsc::Sender<SubmitResult> },
    Stats { reply: mpsc::Sender<ServeStats> },
    Reload { ckpt: PathBuf, reply: mpsc::Sender<Result<(), String>> },
}

/// Outcomes drained from the current scheduler generation, kept so live
/// stats and the generation's final accounting both see them exactly
/// once.
#[derive(Default)]
struct Drained {
    responses: Vec<Response>,
    expired: Vec<u64>,
    failed: Vec<u64>,
}

/// Drain every outcome the scheduler produced since the last call,
/// answer the waiting submitters, and record the outcomes for this
/// generation's accounting.
fn deliver(sched: &mut Scheduler<'_, NativeBackend>,
           waiters: &mut HashMap<u64, mpsc::Sender<SubmitResult>>,
           done: &mut Drained, attempts: u32) {
    for r in sched.take_completed() {
        if let Some(tx) = waiters.remove(&r.id) {
            let _ = tx.send(Ok(r.clone()));
        }
        done.responses.push(r);
    }
    for id in sched.take_expired() {
        if let Some(tx) = waiters.remove(&id) {
            let _ = tx.send(Err(SubmitError::Expired { id }));
        }
        done.expired.push(id);
    }
    for id in sched.take_failed() {
        if let Some(tx) = waiters.remove(&id) {
            let _ = tx.send(Err(SubmitError::Failed { id, attempts }));
        }
        done.failed.push(id);
    }
}

/// A replica thread: own backend, own session cache, one scheduler
/// *generation* per model — a reload closes the current generation,
/// drains it, swaps the backend, and opens the next.  Returns the
/// replica's lifetime [`ServeStats`] once the shard shuts down.
fn run_replica(idx: usize, mut backend: NativeBackend, cfg: ServeConfig,
               inbox: Arc<BoundedQueue<Job>>) -> Result<ServeStats> {
    let cache_name = format!("sessions.r{idx}");
    let cache = cfg.open_session_cache(&cache_name).map(RefCell::new);
    let mut opts = cfg.scheduler_opts();
    // This thread is the scheduler's only producer *and* its consumer: a
    // blocking push would deadlock the pump, so the scheduler queue runs
    // in reject mode and admission is gated on queue_len below (the
    // operator-configured backpressure applies at the shard inbox).
    opts.backpressure = Backpressure::Reject;
    if opts.lanes.is_none() {
        // open-loop serving: provision the full lane budget up front so
        // requests trickling in one by one still share a batch
        opts.lanes = Some(cfg.max_batch.min(MAX_DYNAMIC_BATCH).max(1));
    }
    let attempts = opts.retry_limit + 1;
    let mut total = ServeStats::default();
    let mut shutting_down = false;
    while !shutting_down {
        let (mut sched, handle) = Scheduler::new(&backend, opts.clone())?;
        if let Some(c) = &cache {
            sched.set_session_cache(c);
        }
        let mut waiters: HashMap<u64, mpsc::Sender<SubmitResult>> =
            HashMap::new();
        let mut done = Drained::default();
        let mut reload: Option<(PathBuf, mpsc::Sender<Result<(), String>>)> =
            None;
        loop {
            // Admit inbox jobs while the scheduler queue has room.  Once
            // a reload arrives, admission stops but the inbox keeps
            // queueing — those requests ride out the swap and are served
            // by the next generation, so the rollout drops nothing.
            while reload.is_none() && handle.queue_len() < opts.queue_depth {
                let Some(job) = inbox.try_pop() else { break };
                match job {
                    Job::Submit { req, reply } => {
                        let id = req.id;
                        match handle.submit(req) {
                            Ok(()) => {
                                waiters.insert(id, reply);
                            }
                            Err(e) => {
                                let _ = reply.send(Err(e));
                            }
                        }
                    }
                    Job::Stats { reply } => {
                        // lifetime totals + this generation so far
                        let mut snap = total.clone();
                        let mut live = sched.stats_snapshot();
                        live.responses.extend(done.responses.iter().cloned());
                        live.expired.extend(done.expired.iter().copied());
                        live.failed.extend(done.failed.iter().copied());
                        snap.merge(live);
                        let _ = reply.send(snap);
                    }
                    Job::Reload { ckpt, reply } => {
                        handle.close();
                        reload = Some((ckpt, reply));
                    }
                }
            }
            let worked = sched.step()?;
            deliver(&mut sched, &mut waiters, &mut done, attempts);
            if worked {
                continue;
            }
            if reload.is_some() {
                break; // generation drained; swap below
            }
            if !inbox.is_empty() {
                continue; // jobs deferred while the queue was full
            }
            // idle: park until a job arrives or the shard shuts down
            if !inbox.wait_ready() {
                handle.close();
                while sched.step()? {
                    deliver(&mut sched, &mut waiters, &mut done, attempts);
                }
                deliver(&mut sched, &mut waiters, &mut done, attempts);
                shutting_down = true;
                break;
            }
        }
        // fold the finished generation into the lifetime totals,
        // restoring the outcomes drained to waiters along the way
        let mut gen_stats = sched.into_stats();
        gen_stats.responses.extend(done.responses);
        gen_stats.expired.extend(done.expired);
        gen_stats.failed.extend(done.failed);
        total.merge(gen_stats);
        if let Some((ckpt, reply)) = reload {
            match NativeBackend::from_checkpoint(&ckpt) {
                Ok(swapped) => {
                    log_info!("replica {idx}: hot-swapped {}",
                              ckpt.display());
                    backend = swapped;
                    let _ = reply.send(Ok(()));
                }
                Err(e) => {
                    // the old model keeps serving; the typed load error
                    // renders into the reply for the HTTP error path
                    log_warn!("replica {idx}: reload failed, keeping old \
                               model: {e:#}");
                    let _ = reply.send(Err(format!("{e:#}")));
                }
            }
        }
    }
    if let Some(c) = &cache {
        cfg.save_session_cache(&cache_name, &c.borrow())?;
    }
    Ok(total)
}

// ---------------------------------------------------------------------------
// the shard
// ---------------------------------------------------------------------------

/// N replica threads behind a consistent-hash router.  `Shard` is
/// `Sync`: the HTTP tier shares one instance across its connection
/// threads and every call routes through the replica inboxes.
pub struct Shard {
    ring: HashRing,
    inboxes: Vec<Arc<BoundedQueue<Job>>>,
    threads: Vec<JoinHandle<Result<ServeStats>>>,
    /// Request ids are assigned here so they are unique shard-wide —
    /// the id doubles as the routing key for session-less requests.
    next_id: AtomicU64,
    backpressure: Backpressure,
}

impl Shard {
    /// Build the replicas (each from its own [`ModelSource::build`]
    /// call, so a bad checkpoint fails here rather than killing replica
    /// threads later) and start their serving loops.
    pub fn new(source: &ModelSource, cfg: &ServeConfig, replicas: usize)
               -> Result<Shard> {
        if replicas == 0 {
            return Err(anyhow!("--replicas must be >= 1"));
        }
        let depth = cfg.scheduler_opts().queue_depth;
        let mut inboxes = Vec::with_capacity(replicas);
        let mut threads = Vec::with_capacity(replicas);
        for idx in 0..replicas {
            let backend = source.build()?;
            let inbox = Arc::new(BoundedQueue::new(depth));
            let thread_inbox = Arc::clone(&inbox);
            let thread_cfg = cfg.clone();
            threads.push(std::thread::Builder::new()
                .name(format!("replica-{idx}"))
                .spawn(move || {
                    run_replica(idx, backend, thread_cfg, thread_inbox)
                })?);
            inboxes.push(inbox);
        }
        log_info!("shard: {replicas} replica(s), {} vnodes/replica, inbox \
                   depth {depth}", DEFAULT_VNODES);
        Ok(Shard {
            ring: HashRing::for_replicas(replicas, DEFAULT_VNODES),
            inboxes,
            threads,
            next_id: AtomicU64::new(0),
            backpressure: cfg.backpressure,
        })
    }

    pub fn replicas(&self) -> usize {
        self.inboxes.len()
    }

    /// Submit one request and block until its outcome.  Sessions pin to
    /// their ring segment (their cached decode state lives on that
    /// replica); session-less requests spread by their shard-assigned
    /// id.  The configured [`Backpressure`] applies at the replica
    /// inbox: `Block` parks this caller, `Reject` fails fast with
    /// [`SubmitError::QueueFull`].
    pub fn submit(&self, prompt: Vec<i32>, n_tokens: usize,
                  session: Option<u64>) -> SubmitResult {
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        if prompt.is_empty() {
            return Err(SubmitError::EmptyPrompt { id });
        }
        let replica = self.ring.route(session.unwrap_or(id));
        let (tx, rx) = mpsc::channel();
        let req = Request { id, prompt, n_tokens, session };
        let job = Job::Submit { req, reply: tx };
        let pushed = match self.backpressure {
            Backpressure::Block => self.inboxes[replica].push(job),
            Backpressure::Reject => self.inboxes[replica].try_push(job),
        };
        if let Err(e) = pushed {
            return Err(match e {
                PushError::Full(Job::Submit { req, .. }) => {
                    SubmitError::QueueFull(req)
                }
                PushError::Closed(Job::Submit { req, .. }) => {
                    SubmitError::Closed(req)
                }
                _ => unreachable!("submit jobs come back as submit jobs"),
            });
        }
        match rx.recv() {
            Ok(outcome) => outcome,
            // the replica died with the request in flight
            Err(_) => Err(SubmitError::Failed { id, attempts: 0 }),
        }
    }

    /// Live aggregate stats across all replicas (each replica's lifetime
    /// totals plus its in-flight generation).
    pub fn stats(&self) -> ServeStats {
        let mut agg = ServeStats::default();
        for inbox in &self.inboxes {
            let (tx, rx) = mpsc::channel();
            if inbox.push(Job::Stats { reply: tx }).is_err() {
                continue; // shutting down; report what the rest say
            }
            if let Ok(s) = rx.recv() {
                agg.merge(s);
            }
        }
        agg
    }

    /// Roll `ckpt` across the replicas **one at a time**: each drains
    /// its in-flight generation, swaps backends, and acks before the
    /// next replica starts, so at most one replica is out of rotation
    /// and queued requests (held in the replica inboxes) are never
    /// dropped.  On a load failure the replica keeps its old model and
    /// the rollout stops with an error naming how many replicas had
    /// already swapped.  Returns the number of replicas swapped.
    pub fn reload(&self, ckpt: &Path) -> Result<usize> {
        for (i, inbox) in self.inboxes.iter().enumerate() {
            let (tx, rx) = mpsc::channel();
            inbox.push(Job::Reload { ckpt: ckpt.to_path_buf(), reply: tx })
                .map_err(|_| anyhow!("replica {i} is shut down"))?;
            match rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(msg)) => {
                    return Err(anyhow!(
                        "replica {i} failed to load {} ({i} replica(s) \
                         already swapped, all still serving): {msg}",
                        ckpt.display()));
                }
                Err(_) => {
                    return Err(anyhow!("replica {i} died during reload"));
                }
            }
        }
        Ok(self.inboxes.len())
    }

    /// Close every inbox, drain the replicas, and return the merged
    /// lifetime stats.  In-flight and inbox-queued requests are served
    /// before their replica exits — shutdown is a drain, not a drop.
    pub fn shutdown(self) -> Result<ServeStats> {
        for inbox in &self.inboxes {
            inbox.close();
        }
        let mut agg = ServeStats::default();
        let mut first_err: Option<anyhow::Error> = None;
        for (i, t) in self.threads.into_iter().enumerate() {
            match t.join() {
                Ok(Ok(stats)) => agg.merge(stats),
                Ok(Err(e)) => {
                    if first_err.is_none() {
                        first_err = Some(anyhow!("replica {i}: {e:#}"));
                    }
                }
                Err(p) => {
                    if first_err.is_none() {
                        first_err = Some(anyhow!("replica {i} panicked: {}",
                                                 panic_message(p)));
                    }
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(agg),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_init(vocab: usize) -> NativeInit {
        NativeInit {
            vocab_in: Some(vocab),
            vocab_out: vocab,
            d_model: 8,
            n_layers: 1,
            ..Default::default()
        }
    }

    #[test]
    fn ring_covers_every_member_and_is_deterministic() {
        let ring = HashRing::for_replicas(3, DEFAULT_VNODES);
        let again = HashRing::for_replicas(3, DEFAULT_VNODES);
        let mut owned = [0usize; 3];
        for key in 0..3000u64 {
            let m = ring.route(key);
            assert_eq!(m, again.route(key), "routing must be deterministic");
            owned[m] += 1;
        }
        // every member owns a nontrivial share (vnodes smooth the split)
        for (m, n) in owned.iter().enumerate() {
            assert!(*n > 300, "member {m} owns only {n}/3000 keys");
        }
    }

    #[test]
    fn shard_serves_and_shuts_down_clean() {
        let cfg = ServeConfig::new().temperature(0.0).seed(3).max_batch(4)
            .build().unwrap();
        let source = ModelSource::Fresh(tiny_init(16), 3);
        let shard = Shard::new(&source, &cfg, 2).unwrap();
        assert_eq!(shard.replicas(), 2);
        for i in 0..6u64 {
            let resp = shard
                .submit(vec![1 + (i % 5) as i32, 2], 3, Some(i % 3))
                .unwrap();
            assert_eq!(resp.tokens.len(), 3);
        }
        // empty prompts are rejected at the shard door, like everywhere
        assert!(matches!(shard.submit(vec![], 1, None),
                         Err(SubmitError::EmptyPrompt { .. })));
        let live = shard.stats();
        assert_eq!(live.responses.len(), 6);
        let stats = shard.shutdown().unwrap();
        assert_eq!(stats.responses.len(), 6);
        assert_eq!(stats.submitted,
                   stats.responses.len() + stats.expired.len()
                       + stats.failed.len());
    }

    #[test]
    fn same_session_routes_to_same_replica_and_hits_cache() {
        let cfg = ServeConfig::new().temperature(0.0).seed(5).max_batch(4)
            .session_cache(1 << 20).build().unwrap();
        let source = ModelSource::Fresh(tiny_init(16), 5);
        let shard = Shard::new(&source, &cfg, 3).unwrap();
        // two turns of the same conversation: the second extends the
        // first's prompt, so it can only warm-start if it landed on the
        // replica caching turn one's exported state
        let prompt: Vec<i32> = vec![1, 2, 3, 4, 5, 6, 7, 8];
        let turn1 = shard.submit(prompt.clone(), 2, Some(42)).unwrap();
        let mut turn2_prompt = prompt;
        turn2_prompt.extend(&turn1.tokens);
        turn2_prompt.push(9);
        shard.submit(turn2_prompt, 2, Some(42)).unwrap();
        let stats = shard.shutdown().unwrap();
        assert!(stats.session_hits >= 1,
                "turn 2 should warm-start from turn 1's exported state \
                 (hits={}, misses={})",
                stats.session_hits, stats.session_misses);
        assert!(stats.prefill_tokens_saved > 0);
    }
}
