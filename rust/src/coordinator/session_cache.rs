//! Constant-state session cache for the serving stack.
//!
//! The paper's recurrence gives minGRU/minLSTM a decode state that is a
//! few KB per layer and O(1) in context length — unlike a transformer KV
//! cache, a whole conversation's state fits in a hash-map entry.  This
//! module turns that into the serving tier's warm-start path: a
//! returning session's next turn becomes a cache lookup instead of a
//! prefill.
//!
//! * **Keying.**  Entries are content-addressed by a rolling hash of the
//!   token prefix they cover and verified against the stored tokens (a
//!   hash collision can never serve the wrong state); a `session id →
//!   latest prefix` map realizes the `(session, prefix)` key on top —
//!   [`SessionCache::lookup`] checks the session's own latest entry
//!   first, then scans for the longest cached prefix of the prompt.
//! * **Shared-prefix dedup.**  Two sessions with the same system prompt
//!   hash to the same entry: the prefix is prefilled once, the state is
//!   stored once ([`std::sync::Arc`]), and every later request clones
//!   the `Arc`, not the bytes.
//! * **LRU + byte budget.**  Entries are evicted least-recently-used
//!   once the byte budget is exceeded; an entry larger than the whole
//!   budget is refused outright.
//! * **Persistence.**  [`SessionCache::save`] /
//!   [`SessionCache::load`] round-trip the cache through a small binary
//!   format (magic `MRSC`, CRC32 trailer, durable tmp+fsync+rename via
//!   [`crate::util::io::commit_durable`]), so sessions survive a server
//!   restart.  Snapshots carry the exporting model's fingerprint; a
//!   cache loaded against a different architecture simply never hits.
//!   A cache file is an *optimization*, never a dependency:
//!   [`SessionCache::load_or_recover`] turns an unreadable or corrupt
//!   file into a logged warning plus a cold (empty) cache — and deletes
//!   the bad file so the next save starts clean — instead of failing
//!   serve startup.
//!
//! The cache stores whatever [`Backend::export_state`] produced and
//! never interprets the bytes; all model knowledge lives behind the
//! trait.
//!
//! [`Backend::export_state`]: crate::runtime::Backend::export_state

use std::collections::HashMap;
use std::io::Read;
use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::log_warn;
use crate::runtime::backend::SessionState;
use crate::util::io::{commit_durable, crc32};
use crate::util::rng::splitmix64;

pub const MAGIC: &[u8; 4] = b"MRSC";
/// Version 2 appends a CRC32 trailer (torn-write detection) and commits
/// through [`commit_durable`]; version-1 files are still read.
pub const VERSION: u32 = 2;

/// Fixed per-entry bookkeeping charged against the byte budget on top of
/// the state bytes and the covered tokens.
const ENTRY_OVERHEAD: usize = 64;

/// Rolling prefix hash: fold each token through `splitmix64` so the hash
/// of `tokens[..k+1]` is computable from the hash of `tokens[..k]`.
pub fn prefix_hash(tokens: &[i32]) -> u64 {
    let mut h = 0u64;
    for &t in tokens {
        h = extend_hash(h, t);
    }
    h
}

#[inline]
fn extend_hash(h: u64, tok: i32) -> u64 {
    let mut s = h ^ (tok as u32 as u64);
    splitmix64(&mut s)
}

/// Lifetime counters; exposed through `ServeStats` per serving run.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub insertions: u64,
    pub evictions: u64,
}

struct Entry {
    /// The exact token prefix this state covers — lookup verifies these
    /// against the prompt, so a hash collision degrades to a miss.
    tokens: Vec<i32>,
    state: Arc<SessionState>,
    last_used: u64,
    /// Budget charge: state bytes + token bytes + [`ENTRY_OVERHEAD`].
    bytes: usize,
}

/// LRU store of exported per-lane decode states, keyed by token prefix
/// (content-addressed) with a session-id pointer map on top.  See the
/// module docs for the design.
pub struct SessionCache {
    store: HashMap<u64, Entry>,
    /// session id → prefix hash of the session's most recent state.
    sessions: HashMap<u64, u64>,
    budget: usize,
    used: usize,
    tick: u64,
    stats: CacheStats,
}

impl SessionCache {
    /// An empty cache with a byte budget (`--session-cache-mb` × 2^20).
    pub fn new(budget_bytes: usize) -> SessionCache {
        SessionCache {
            store: HashMap::new(),
            sessions: HashMap::new(),
            budget: budget_bytes,
            used: 0,
            tick: 0,
            stats: CacheStats::default(),
        }
    }

    pub fn len(&self) -> usize {
        self.store.len()
    }

    pub fn is_empty(&self) -> bool {
        self.store.is_empty()
    }

    pub fn used_bytes(&self) -> usize {
        self.used
    }

    pub fn budget_bytes(&self) -> usize {
        self.budget
    }

    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    fn touch(&mut self, hash: u64) {
        self.tick += 1;
        if let Some(e) = self.store.get_mut(&hash) {
            e.last_used = self.tick;
        }
    }

    /// Longest usable cached prefix of `prompt`: returns
    /// `(covered, state)` where `state` is the decode state after
    /// consuming `prompt[..covered]`.  `covered` is capped at
    /// `prompt.len() - 1` — the admitted lane must still feed at least
    /// one prompt token to produce the logits it samples from.  Entries
    /// are verified token-for-token and against `fingerprint` (the
    /// serving model's [`Backend::state_fingerprint`]), so neither a
    /// hash collision nor a stale on-disk cache from another
    /// architecture can ever serve a wrong state — both degrade to a
    /// miss.
    ///
    /// [`Backend::state_fingerprint`]:
    ///     crate::runtime::Backend::state_fingerprint
    pub fn lookup(&mut self, session: Option<u64>, prompt: &[i32],
                  fingerprint: u64)
                  -> Option<(usize, Arc<SessionState>)> {
        let usable = |e: &Entry, k: usize| {
            e.tokens.len() == k && e.tokens[..] == prompt[..k]
                && e.state.fingerprint == fingerprint
        };
        // fast path: the session's own latest state, if it is a prefix
        let by_session = session.and_then(|s| self.sessions.get(&s))
            .copied();
        if let Some(h) = by_session {
            if let Some(e) = self.store.get(&h) {
                let k = e.tokens.len();
                if k < prompt.len() && usable(e, k) {
                    let state = Arc::clone(&e.state);
                    self.touch(h);
                    self.stats.hits += 1;
                    return Some((k, state));
                }
            }
        }
        // longest cached prefix: rolling hashes ascending, scan descending
        if prompt.len() > 1 {
            let mut hashes = Vec::with_capacity(prompt.len() - 1);
            let mut h = 0u64;
            for &t in &prompt[..prompt.len() - 1] {
                h = extend_hash(h, t);
                hashes.push(h); // hashes[k-1] = hash of prompt[..k]
            }
            for k in (1..prompt.len()).rev() {
                let h = hashes[k - 1];
                let Some(e) = self.store.get(&h) else { continue };
                if !usable(e, k) {
                    continue;
                }
                let state = Arc::clone(&e.state);
                self.touch(h);
                self.stats.hits += 1;
                return Some((k, state));
            }
        }
        self.stats.misses += 1;
        None
    }

    /// Store the decode state covering exactly `tokens`.  A duplicate
    /// prefix refreshes the existing entry instead of storing a second
    /// copy (shared-prefix dedup); oversized entries are refused; the
    /// least-recently-used entries are evicted until the budget holds.
    pub fn insert(&mut self, session: Option<u64>, tokens: &[i32],
                  state: SessionState) {
        if tokens.is_empty() {
            return;
        }
        let hash = prefix_hash(tokens);
        if let Some(e) = self.store.get(&hash) {
            if e.tokens[..] == tokens[..] {
                // dedup: decode is deterministic given the prefix, so
                // the stored state is already this state
                self.touch(hash);
                if let Some(s) = session {
                    self.sessions.insert(s, hash);
                }
                return;
            }
            // hash collision with different tokens: keep the resident
            // entry, drop the newcomer (lookup verifies tokens anyway)
            return;
        }
        let bytes =
            state.bytes.len() + tokens.len() * 4 + ENTRY_OVERHEAD;
        if bytes > self.budget {
            return; // would evict the whole cache for one entry
        }
        self.tick += 1;
        self.store.insert(hash, Entry {
            tokens: tokens.to_vec(),
            state: Arc::new(state),
            last_used: self.tick,
            bytes,
        });
        self.used += bytes;
        self.stats.insertions += 1;
        if let Some(s) = session {
            self.sessions.insert(s, hash);
        }
        while self.used > self.budget {
            let Some((&victim, _)) = self.store.iter()
                .min_by_key(|(_, e)| e.last_used) else { break };
            let gone = self.store.remove(&victim).expect("victim exists");
            self.used -= gone.bytes;
            self.stats.evictions += 1;
            self.sessions.retain(|_, h| *h != victim);
        }
    }

    /// Persist every live entry (and the session pointer map) to `path`
    /// durably ([`commit_durable`]: tmp + fsync + rename + parent-dir
    /// fsync, CRC32 trailer), oldest-first so a reload preserves the LRU
    /// order.
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut entries: Vec<(&u64, &Entry)> = self.store.iter().collect();
        entries.sort_by_key(|(_, e)| e.last_used);
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&VERSION.to_le_bytes());
        buf.extend_from_slice(&(entries.len() as u32).to_le_bytes());
        for (_, e) in &entries {
            buf.extend_from_slice(&(e.tokens.len() as u32).to_le_bytes());
            for &t in &e.tokens {
                buf.extend_from_slice(&t.to_le_bytes());
            }
            let raw = e.state.to_bytes();
            buf.extend_from_slice(&(raw.len() as u32).to_le_bytes());
            buf.extend_from_slice(&raw);
        }
        buf.extend_from_slice(&(self.sessions.len() as u32).to_le_bytes());
        for (&s, &h) in &self.sessions {
            buf.extend_from_slice(&s.to_le_bytes());
            buf.extend_from_slice(&h.to_le_bytes());
        }
        let crc = crc32(&buf);
        buf.extend_from_slice(&crc.to_le_bytes());
        commit_durable(path, &buf)
            .with_context(|| format!("save session cache {}",
                                     path.display()))
    }

    /// Load a cache saved by [`SessionCache::save`], re-checking every
    /// record against corruption (and, for v2 files, the whole payload
    /// against the CRC32 trailer); entries beyond `budget_bytes` evict
    /// LRU exactly as live inserts would.
    pub fn load(path: &Path, budget_bytes: usize) -> Result<SessionCache> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("open {}", path.display()))?;
        if bytes.len() < 12 {
            bail!("{}: truncated session cache ({} bytes is shorter than \
                   the header)", path.display(), bytes.len());
        }
        if &bytes[..4] != MAGIC {
            bail!("{}: not a MRSC session cache", path.display());
        }
        let version = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
        let body: &[u8] = match version {
            1 => &bytes[8..],
            VERSION => {
                let (payload, trailer) = bytes.split_at(bytes.len() - 4);
                let want = u32::from_le_bytes(trailer.try_into().unwrap());
                let got = crc32(payload);
                if want != got {
                    bail!("{}: corrupt session cache (CRC mismatch: \
                           trailer {want:08x}, computed {got:08x})",
                          path.display());
                }
                &payload[8..]
            }
            v => bail!("{}: session-cache version mismatch (file is v{v}, \
                        this reader supports v1..=v{VERSION})",
                       path.display()),
        };
        let mut r: &[u8] = body;
        let mut cache = SessionCache::new(budget_bytes);
        let n = read_u32(&mut r)? as usize;
        if n > 1 << 20 {
            bail!("corrupt session cache: {n} entries");
        }
        for _ in 0..n {
            let n_tok = read_u32(&mut r)? as usize;
            if n_tok == 0 || n_tok > 1 << 24 {
                bail!("corrupt session cache: token count {n_tok}");
            }
            let mut raw = vec![0u8; n_tok * 4];
            r.read_exact(&mut raw)?;
            let tokens: Vec<i32> = raw.chunks_exact(4)
                .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            let len = read_u32(&mut r)? as usize;
            if len > 1 << 30 {
                bail!("corrupt session cache: state length {len}");
            }
            let mut raw = vec![0u8; len];
            r.read_exact(&mut raw)?;
            let state = SessionState::from_bytes(&raw)
                .with_context(|| format!("{}: bad session state",
                                         path.display()))?;
            cache.insert(None, &tokens, state);
        }
        let n_sessions = read_u32(&mut r)? as usize;
        if n_sessions > 1 << 20 {
            bail!("corrupt session cache: {n_sessions} sessions");
        }
        for _ in 0..n_sessions {
            let s = read_u64(&mut r)?;
            let h = read_u64(&mut r)?;
            if cache.store.contains_key(&h) {
                cache.sessions.insert(s, h);
            }
        }
        // loading is not serving activity; counters start clean
        cache.stats = CacheStats::default();
        Ok(cache)
    }

    /// [`SessionCache::load`], downgraded from fatal to best-effort: a
    /// missing file yields a fresh cache; an unreadable or corrupt file
    /// is logged, **deleted** (so the next save starts clean rather than
    /// tripping on the same bad bytes forever), counted as an eviction,
    /// and replaced by a fresh cache.  Serve startup must never die on a
    /// cache file — the cache is an optimization, not state of record.
    pub fn load_or_recover(path: &Path, budget_bytes: usize)
                           -> SessionCache {
        if !path.exists() {
            return SessionCache::new(budget_bytes);
        }
        match SessionCache::load(path, budget_bytes) {
            Ok(cache) => cache,
            Err(e) => {
                log_warn!("discarding session cache {}: {e:#}",
                          path.display());
                if let Err(rm) = std::fs::remove_file(path) {
                    log_warn!("could not delete bad session cache {}: \
                               {rm}", path.display());
                }
                let mut cache = SessionCache::new(budget_bytes);
                cache.stats.evictions += 1;
                cache
            }
        }
    }
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(fp: u64, n: usize) -> SessionState {
        SessionState { fingerprint: fp, bytes: vec![7u8; n] }
    }

    #[test]
    fn lookup_returns_longest_verified_prefix() {
        let mut c = SessionCache::new(1 << 20);
        c.insert(None, &[1, 2], snap(42, 8));
        c.insert(None, &[1, 2, 3, 4], snap(42, 8));
        // longest prefix wins ...
        let (k, s) = c.lookup(None, &[1, 2, 3, 4, 5, 6], 42).unwrap();
        assert_eq!(k, 4);
        assert_eq!(s.bytes.len(), 8);
        // ... capped at prompt.len()-1: the lane still needs a token to
        // feed for its sampling logits
        let (k, _) = c.lookup(None, &[1, 2, 3, 4, 5], 42).unwrap();
        assert_eq!(k, 4);
        let (k, _) = c.lookup(None, &[1, 2, 3, 4], 42).unwrap();
        assert_eq!(k, 2, "full-prompt entry must not be returned");
        // wrong fingerprint and diverging tokens both miss cleanly
        assert!(c.lookup(None, &[1, 2, 3, 4, 5], 99).is_none());
        assert!(c.lookup(None, &[9, 9, 9], 42).is_none());
        let st = c.stats();
        assert_eq!(st.hits, 3);
        assert_eq!(st.misses, 2);
    }

    #[test]
    fn session_pointer_fast_path_and_dedup() {
        let mut c = SessionCache::new(1 << 20);
        // two sessions share one prompt prefix: stored once
        c.insert(Some(1), &[5, 6, 7], snap(1, 16));
        c.insert(Some(2), &[5, 6, 7], snap(1, 16));
        assert_eq!(c.len(), 1);
        assert_eq!(c.stats().insertions, 1);
        let (k, a) = c.lookup(Some(1), &[5, 6, 7, 8], 1).unwrap();
        let (_, b) = c.lookup(Some(2), &[5, 6, 7, 9], 1).unwrap();
        assert_eq!(k, 3);
        // the state payload is shared, not copied
        assert!(Arc::ptr_eq(&a, &b));
    }

    #[test]
    fn lru_eviction_respects_byte_budget() {
        // each entry charges ~ 64 + 3*4 + 100 bytes; budget fits two
        let mut c = SessionCache::new(2 * (ENTRY_OVERHEAD + 12 + 100));
        c.insert(Some(1), &[1, 1, 1], snap(0, 100));
        c.insert(Some(2), &[2, 2, 2], snap(0, 100));
        assert_eq!(c.len(), 2);
        // touch entry 1 so entry 2 is the LRU victim
        assert!(c.lookup(Some(1), &[1, 1, 1, 0], 0).is_some());
        c.insert(Some(3), &[3, 3, 3], snap(0, 100));
        assert_eq!(c.len(), 2);
        assert_eq!(c.stats().evictions, 1);
        assert!(c.lookup(Some(2), &[2, 2, 2, 0], 0).is_none(),
                "LRU entry should have been evicted");
        assert!(c.lookup(Some(1), &[1, 1, 1, 0], 0).is_some());
        assert!(c.lookup(Some(3), &[3, 3, 3, 0], 0).is_some());
        assert!(c.used_bytes() <= c.budget_bytes());
        // an entry bigger than the whole budget is refused outright
        c.insert(None, &[4, 4, 4], snap(0, 10_000));
        assert!(c.lookup(None, &[4, 4, 4, 0], 0).is_none());
    }

    #[test]
    fn disk_roundtrip_preserves_entries_and_sessions() {
        let dir = std::env::temp_dir().join("minrnn_session_cache_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("sessions.mrsc");
        let mut c = SessionCache::new(1 << 20);
        c.insert(Some(7), &[1, 2, 3], snap(42, 32));
        c.insert(None, &[9, 8], snap(42, 32));
        c.save(&path).unwrap();
        let mut back = SessionCache::load(&path, 1 << 20).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back.stats(), CacheStats::default());
        let (k, s) = back.lookup(Some(7), &[1, 2, 3, 4], 42).unwrap();
        assert_eq!(k, 3);
        assert_eq!(s.bytes, vec![7u8; 32]);
        assert!(back.lookup(None, &[9, 8, 0], 42).is_some());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn load_rejects_corrupt_files() {
        let dir = std::env::temp_dir().join("minrnn_session_cache_bad");
        std::fs::create_dir_all(&dir).unwrap();
        let bad = dir.join("bad.mrsc");
        std::fs::write(&bad, b"NOPE....").unwrap();
        assert!(SessionCache::load(&bad, 1 << 20).is_err());
        // truncation mid-entry must error, not panic or mis-parse
        let good = dir.join("trunc.mrsc");
        let mut c = SessionCache::new(1 << 20);
        c.insert(None, &[1, 2, 3], snap(1, 64));
        c.save(&good).unwrap();
        let bytes = std::fs::read(&good).unwrap();
        std::fs::write(&good, &bytes[..bytes.len() - 9]).unwrap();
        assert!(SessionCache::load(&good, 1 << 20).is_err());
        std::fs::remove_file(&bad).unwrap();
        std::fs::remove_file(&good).unwrap();
    }

    #[test]
    fn legacy_v1_cache_files_still_load() {
        let dir = std::env::temp_dir().join("minrnn_session_cache_v1");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("v1.mrsc");
        let mut c = SessionCache::new(1 << 20);
        c.insert(Some(3), &[4, 5, 6], snap(9, 24));
        c.save(&path).unwrap();
        // rewrite as a v1 file: version stamp 1, no CRC trailer
        let bytes = std::fs::read(&path).unwrap();
        let mut v1 = bytes[..bytes.len() - 4].to_vec();
        v1[4..8].copy_from_slice(&1u32.to_le_bytes());
        std::fs::write(&path, &v1).unwrap();
        let mut back = SessionCache::load(&path, 1 << 20).unwrap();
        assert!(back.lookup(Some(3), &[4, 5, 6, 7], 9).is_some());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn load_or_recover_deletes_corrupt_file_and_serves_cold() {
        let dir = std::env::temp_dir().join("minrnn_session_cache_rec");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("sessions.mrsc");
        // missing file: fresh cache, no eviction counted
        let c = SessionCache::load_or_recover(&path, 1 << 20);
        assert!(c.is_empty());
        assert_eq!(c.stats().evictions, 0);
        // corrupt file: warn, delete, fresh cache, eviction counted
        std::fs::write(&path, b"MRSCgarbage-that-is-not-a-cache").unwrap();
        let c = SessionCache::load_or_recover(&path, 1 << 20);
        assert!(c.is_empty());
        assert_eq!(c.stats().evictions, 1);
        assert!(!path.exists(), "bad cache file must be deleted");
        // a valid file round-trips unchanged through the same entry point
        let mut live = SessionCache::new(1 << 20);
        live.insert(Some(1), &[1, 2], snap(5, 16));
        live.save(&path).unwrap();
        let mut back = SessionCache::load_or_recover(&path, 1 << 20);
        assert!(back.lookup(Some(1), &[1, 2, 3], 5).is_some());
        std::fs::remove_file(&path).unwrap();
    }
}
