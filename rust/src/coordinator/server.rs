//! Serving layer: request queue + dynamic batcher + continuous batched
//! decode, generic over [`Backend`].
//!
//! PJRT handles are not `Send`, so the serving loop owns the backend and
//! requests are plain host data.  The batcher picks the lane count via
//! [`Backend::plan_batch`] capped at [`ServeOpts::max_batch`], then
//! decodes every admitted request in **lockstep**: one `decode_step` per
//! wall-clock tick advances all lanes, prompt tokens are consumed
//! lane-wise (RNN decode is O(1)/token), idle lanes are padded with an
//! active-mask, and sampling continues until each lane has its requested
//! tokens.
//!
//! Backends that implement [`Backend::reset_lane`] (native) additionally
//! get **continuous batching**: the moment a lane finishes, its slot is
//! re-seeded with the next queued request mid-flight, so a long request
//! no longer holds the whole batch hostage.  Backends without lane reset
//! (PJRT artifacts) fall back to run-to-completion batches.

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::runtime::backend::MAX_DYNAMIC_BATCH;
use crate::runtime::Backend;
use crate::tensor::Tensor;
use crate::util::rng::Rng;
use crate::util::stats;

use super::infer::sample_logits;

pub use crate::runtime::backend::plan_batch;

#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub n_tokens: usize,
}

#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<i32>,
    /// Seconds spent waiting in queue before this request was admitted
    /// into a decode lane.
    pub queue_s: f64,
    /// Seconds from lane admission to this request's completion.
    pub service_s: f64,
    /// Lane count of the batch this request was served in.
    pub batch: usize,
}

pub struct ServeStats {
    pub responses: Vec<Response>,
    pub total_s: f64,
    pub tokens_generated: usize,
}

impl ServeStats {
    pub fn throughput_tok_s(&self) -> f64 {
        self.tokens_generated as f64 / self.total_s.max(1e-9)
    }

    pub fn mean_latency_s(&self) -> f64 {
        if self.responses.is_empty() {
            return 0.0;
        }
        self.responses.iter().map(|r| r.queue_s + r.service_s).sum::<f64>()
            / self.responses.len() as f64
    }

    /// p95 end-to-end latency (queue + service) across responses.
    pub fn p95_latency_s(&self) -> f64 {
        if self.responses.is_empty() {
            return 0.0;
        }
        let lat: Vec<f64> = self.responses.iter()
            .map(|r| r.queue_s + r.service_s).collect();
        stats::percentile(&lat, 95.0)
    }
}

/// Serving knobs beyond the request list.
#[derive(Clone, Debug)]
pub struct ServeOpts {
    pub temperature: f32,
    pub seed: u64,
    /// Upper bound on lanes decoded in lockstep (`--max-batch`).
    pub max_batch: usize,
}

impl Default for ServeOpts {
    fn default() -> Self {
        ServeOpts { temperature: 0.8, seed: 0, max_batch: MAX_DYNAMIC_BATCH }
    }
}

/// One occupied decode lane.
struct Lane {
    req: Request,
    enqueued: Instant,
    admitted: Instant,
    /// Prompt cursor.
    pos: usize,
    out: Vec<i32>,
}

impl Lane {
    /// Admit a queued request into a lane (used at batch formation and at
    /// continuous-batching refill — keep the bookkeeping in one place).
    fn admit(req: Request, enqueued: Instant) -> Lane {
        Lane { req, enqueued, admitted: Instant::now(), pos: 0,
               out: Vec::new() }
    }

    fn active(&self) -> bool {
        self.pos < self.req.prompt.len() || self.out.len() < self.req.n_tokens
    }

    fn next_input(&self) -> i32 {
        if self.pos < self.req.prompt.len() {
            self.req.prompt[self.pos]
        } else {
            self.out.last().copied()
                .unwrap_or_else(|| *self.req.prompt.last().unwrap_or(&0))
        }
    }

    fn finish(self, bsize: usize, done: Instant) -> Response {
        Response {
            id: self.req.id,
            tokens: self.out,
            queue_s: (self.admitted - self.enqueued).as_secs_f64(),
            service_s: (done - self.admitted).as_secs_f64(),
            batch: bsize,
        }
    }
}

/// Serve a workload of requests to completion with default options
/// (PR-1 signature, kept for callers and tests).  No lane cap: PR-1
/// behavior planned straight from the queue length, so a fixed-batch
/// PJRT backend exporting executables wider than [`MAX_DYNAMIC_BATCH`]
/// still fills every lane (native backends self-cap via `plan_batch`).
pub fn serve<B: Backend>(backend: &B, requests: Vec<Request>,
                         temperature: f32, seed: u64) -> Result<ServeStats> {
    serve_opts(backend, requests,
               &ServeOpts { temperature, seed, max_batch: usize::MAX })
}

/// Serve a workload of requests to completion using dynamic batching,
/// lockstep decode, and (when the backend supports lane reset)
/// continuous lane refill.
pub fn serve_opts<B: Backend>(backend: &B, requests: Vec<Request>,
                              opts: &ServeOpts) -> Result<ServeStats> {
    if opts.max_batch == 0 {
        return Err(anyhow!("--max-batch must be >= 1"));
    }
    if backend.plan_batch(1).is_none() {
        return Err(anyhow!("backend '{}' exposes no decode batch sizes",
                           backend.name()));
    }
    // Validate up front so serving agrees with `infer::generate`, which
    // rejects empty prompts: `Lane::next_input` would otherwise silently
    // substitute token 0 for an empty-prompt request.
    if let Some(r) = requests.iter().find(|r| r.prompt.is_empty()) {
        return Err(anyhow!(
            "request {} has an empty prompt; every request needs at least \
             one prompt token", r.id));
    }
    let mut rng = Rng::new(opts.seed);
    let mut queue: VecDeque<(Request, Instant)> =
        requests.into_iter().map(|r| (r, Instant::now())).collect();
    let mut responses = Vec::new();
    let mut tokens_generated = 0usize;
    let t_start = Instant::now();

    while let Some(bsize) =
        backend.plan_batch(queue.len().min(opts.max_batch)) {
        let mut state = backend.decode_state(bsize)?;
        // Admit at most max_batch requests even when a fixed-size (PJRT)
        // backend pads up to an exported lane count above the cap — the
        // extra lanes stay idle padding.
        let mut lanes: Vec<Option<Lane>> = (0..bsize)
            .map(|lane| {
                if lane >= opts.max_batch {
                    return None;
                }
                queue.pop_front()
                    .map(|(req, enqueued)| Lane::admit(req, enqueued))
            })
            .collect();

        loop {
            // lane-wise input tokens; idle/padding lanes feed 0
            let mut xs = vec![0i32; bsize];
            let mut any_active = false;
            for (lane, slot) in lanes.iter().enumerate() {
                if let Some(l) = slot {
                    if l.active() {
                        xs[lane] = l.next_input();
                        any_active = true;
                    }
                }
            }
            if !any_active {
                break;
            }

            let x = Tensor::i32(vec![bsize], xs);
            let (logits, new_state) = backend.decode_step(&x, state)?;
            state = new_state;

            // consume logits: lanes past their prompt sample a token;
            // finished lanes respond and (continuous batching) refill
            let vocab = logits.dims[1];
            let rows = logits.data.as_f32()
                .ok_or_else(|| anyhow!("logits not f32"))?;
            for lane in 0..bsize {
                let Some(l) = lanes[lane].as_mut() else {
                    continue;
                };
                if l.pos < l.req.prompt.len() {
                    l.pos += 1;
                    if l.pos < l.req.prompt.len() {
                        continue;
                    }
                    // prompt just finished → this step's logits sample
                }
                if l.pos >= l.req.prompt.len()
                    && l.out.len() < l.req.n_tokens {
                    let row = &rows[lane * vocab..(lane + 1) * vocab];
                    let tok = sample_logits(row, opts.temperature, &mut rng)
                        as i32;
                    l.out.push(tok);
                    tokens_generated += 1;
                }
                if !l.active() {
                    let done = Instant::now();
                    let finished = lanes[lane].take().unwrap();
                    responses.push(finished.finish(bsize, done));
                    if !queue.is_empty()
                        && backend.reset_lane(&mut state, lane) {
                        let (req, enqueued) = queue.pop_front().unwrap();
                        lanes[lane] = Some(Lane::admit(req, enqueued));
                    }
                }
            }
        }

        // run-to-completion fallback: any still-occupied lanes (there are
        // none — the loop drains them) plus whatever remains in the queue
        // go through the outer re-plan.
        for slot in lanes.into_iter().flatten() {
            let done = Instant::now();
            responses.push(slot.finish(bsize, done));
        }
    }

    Ok(ServeStats {
        responses,
        total_s: t_start.elapsed().as_secs_f64(),
        tokens_generated,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{NativeBackend, NativeInit, NativeModel};

    // plan_batch's policy test lives with the function in
    // runtime::backend; here we exercise the serving loop itself.
    // Lockstep-batched vs per-request sequential agreement is
    // property-tested in rust/tests/parallel_props.rs.

    fn tiny_backend(vocab: usize, seed: u64) -> NativeBackend {
        let model = NativeModel::init_random(&NativeInit {
            vocab_in: Some(vocab),
            vocab_out: vocab,
            d_model: 8,
            n_layers: 1,
            ..Default::default()
        }, seed).unwrap();
        NativeBackend::new(model)
    }

    #[test]
    fn serve_native_end_to_end() {
        // dynamic-batched serving with zero artifacts
        let backend = tiny_backend(32, 5);
        let mut rng = Rng::new(0);
        let requests: Vec<Request> = (0..6).map(|i| Request {
            id: i,
            prompt: (0..2 + rng.usize_below(4))
                .map(|_| rng.below(32) as i32).collect(),
            n_tokens: 5,
        }).collect();
        let stats = serve(&backend, requests, 1.0, 0).unwrap();
        assert_eq!(stats.responses.len(), 6);
        assert!(stats.responses.iter().all(|r| r.tokens.len() == 5));
        assert_eq!(stats.tokens_generated, 30);
        assert!(stats.responses.iter()
                .all(|r| r.tokens.iter().all(|&t| (0..32).contains(&t))));
        assert!(stats.p95_latency_s() >= 0.0);
    }

    #[test]
    fn continuous_refill_serves_more_requests_than_lanes() {
        // 9 requests through 2 lanes: finished lanes must be re-seeded
        // from the queue (native backend supports reset_lane)
        let backend = tiny_backend(16, 11);
        let requests: Vec<Request> = (0..9).map(|i| Request {
            id: i,
            prompt: vec![1 + (i % 5) as i32, 2],
            n_tokens: 3 + (i % 3) as usize,
        }).collect();
        let want_tokens: usize = requests.iter().map(|r| r.n_tokens).sum();
        let stats = serve_opts(&backend, requests, &ServeOpts {
            temperature: 0.7,
            seed: 3,
            max_batch: 2,
        }).unwrap();
        assert_eq!(stats.responses.len(), 9);
        assert_eq!(stats.tokens_generated, want_tokens);
        assert!(stats.responses.iter().all(|r| r.batch == 2));
        for r in &stats.responses {
            assert_eq!(r.tokens.len(), 3 + (r.id % 3) as usize, "req {}",
                       r.id);
        }
    }

    #[test]
    fn empty_prompt_requests_are_rejected_up_front() {
        // serve must agree with infer::generate instead of silently
        // feeding token 0 into the empty lane
        let backend = tiny_backend(16, 2);
        let err = serve_opts(&backend, vec![
            Request { id: 0, prompt: vec![1, 2], n_tokens: 2 },
            Request { id: 7, prompt: vec![], n_tokens: 2 },
        ], &ServeOpts::default());
        let msg = format!("{:#}", err.unwrap_err());
        assert!(msg.contains("request 7") && msg.contains("empty prompt"),
                "unhelpful error: {msg}");
    }

    #[test]
    fn max_batch_zero_is_rejected() {
        let backend = tiny_backend(16, 1);
        let err = serve_opts(&backend, vec![Request {
            id: 0,
            prompt: vec![1],
            n_tokens: 1,
        }], &ServeOpts { max_batch: 0, ..Default::default() });
        assert!(err.is_err());
    }
}
