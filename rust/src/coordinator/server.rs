//! Serving layer: request/response types, serving statistics, and the
//! synchronous serve API — a thin wrapper over the async admission
//! scheduler in [`coordinator::scheduler`](super::scheduler).
//!
//! The decode loop itself lives in [`super::scheduler::Scheduler`]: it
//! decodes every admitted request in **lockstep** (one `decode_step` per
//! wall-clock tick advances all lanes, prompt tokens are consumed
//! lane-wise, idle lanes are padding) and, on backends that implement
//! [`Backend::reset_lane`] (native), admits queued requests into free
//! lanes **mid-decode** — continuous batching, so a long request never
//! holds the batch hostage and work submitted after decoding started
//! still joins the running batch.  Backends without lane reset (PJRT
//! artifacts) fall back to run-to-completion batches.
//!
//! [`serve`] / [`serve_opts`] keep the original submit-everything-up-front
//! contract: they push the whole `Vec<Request>` through the scheduler's
//! admission queue, close it, and drain — token-for-token identical to
//! the PR-2 loop (greedy batched == per-request sequential decode is
//! property-tested in `rust/tests/parallel_props.rs`; async interleaved
//! admission in `rust/tests/scheduler_props.rs`).
//!
//! PJRT handles are not `Send`, so the serving loop owns the backend and
//! requests are plain host data.

use std::cell::RefCell;
use std::fmt;

use anyhow::{anyhow, Result};

use crate::runtime::backend::MAX_DYNAMIC_BATCH;
use crate::runtime::Backend;
use crate::util::stats;

use super::scheduler::{Backpressure, Scheduler, SchedulerOpts};
use super::session_cache::SessionCache;

pub use crate::runtime::backend::plan_batch;

/// One unit of serving work: generate `n_tokens` continuation tokens for
/// `prompt`.  `n_tokens` doubles as the per-request max-new-tokens cap —
/// the lane frees the moment it is reached.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub n_tokens: usize,
    /// Conversation id for the session cache ([`serve_with_cache`] /
    /// [`super::scheduler::Scheduler::set_session_cache`]): requests
    /// carrying a session id export their final decode state on
    /// completion so the session's next turn skips re-prefilling the
    /// shared history.  `None` opts out of the completion export (the
    /// request still benefits from shared-prefix hits).
    pub session: Option<u64>,
}

/// A completed request, with its latency split into the two phases that
/// matter for capacity planning: time *queued* (waiting for a lane) vs
/// time *in service* (decoding).
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<i32>,
    /// Seconds spent waiting in queue before this request was admitted
    /// into a decode lane.
    pub queue_s: f64,
    /// Seconds from lane admission to this request's completion.
    pub service_s: f64,
    /// Lane count of the batch this request was served in.
    pub batch: usize,
}

/// Health of a serving run, as reported in [`ServeStats::health`].
///
/// * `Healthy` — no decode failures, no supervisor restarts.
/// * `Degraded` — the run completed, but something was absorbed along
///   the way: failed requests, decode retries, session-import
///   downgrades, or a supervisor restart.  Surviving traffic was served
///   (bit-identically for greedy decode), capacity or latency may have
///   suffered.
/// * `Draining` — the supervisor exhausted its restart budget and is
///   completing in-flight work without accepting recovery restarts; the
///   operator should expect the process to need attention.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Health {
    #[default]
    Healthy,
    Degraded,
    Draining,
}

impl fmt::Display for Health {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Health::Healthy => "healthy",
            Health::Degraded => "degraded",
            Health::Draining => "draining",
        })
    }
}

/// Aggregate statistics for one serving run (one [`serve_opts`] call or
/// one open-ended scheduler run).
///
/// Every latency accessor on this type returns `0.0` when `responses` is
/// empty — an idle server reports zero latency rather than panicking
/// inside the percentile sort or returning a 0/0 NaN mean; the
/// `empty_response_set_reports_zero_latencies` test pins that contract.
pub struct ServeStats {
    pub responses: Vec<Response>,
    pub total_s: f64,
    pub tokens_generated: usize,
    /// Requests accepted into the admission queue.  After a graceful
    /// drain, `submitted == responses.len() + expired.len() +
    /// failed.len()` — nothing is lost (rejected submissions never enter
    /// the queue and are counted separately).
    pub submitted: usize,
    /// Requests admitted into a decode lane (equals `responses.len()`
    /// after a full drain).
    pub admitted: usize,
    /// Submissions refused at the admission queue under
    /// [`Backpressure::Reject`] backpressure.
    pub rejected: usize,
    /// Ids of requests dropped because their queue-wait deadline passed
    /// before a lane freed up.  Expired requests are never half-served.
    pub expired: Vec<u64>,
    /// Peak admission-queue depth observed over the run.
    pub max_queue_depth: usize,
    /// Lockstep batches formed.  `1` means everything was served by a
    /// single continuously-refilled batch (the async-admission case);
    /// fixed backends without lane reset re-plan per batch.
    pub batches_started: usize,
    /// Session-cache lookups that warm-started a lane from a cached
    /// state (zero when no cache is attached or the backend cannot
    /// import state).
    pub session_hits: usize,
    /// Session-cache lookups that found nothing usable; the lane
    /// prefilled from scratch.  `session_hits + session_misses` equals
    /// the number of admissions that consulted the cache.
    pub session_misses: usize,
    /// Cache entries evicted (LRU, byte budget) during this run.
    pub session_evictions: usize,
    /// Prompt tokens whose prefill was skipped thanks to cache hits —
    /// the tentpole saving: each is one `decode_step` that never ran.
    pub prefill_tokens_saved: usize,
    /// Ids of requests dropped after exhausting their decode-retry
    /// budget (`SubmitError::Failed`): a request whose decode panicked or
    /// errored on every attempt, in quarantined isolation included.
    /// Failure is per-request — surviving lanes are unaffected.
    pub failed: Vec<u64>,
    /// Decode attempts that were retried after a transient failure
    /// (requeue + replay, with exponential backoff between batches).
    pub retries: usize,
    /// Session-cache imports that failed (corrupt state, import error)
    /// and were degraded to a cold prefill instead of failing the
    /// request.  These also count as `session_misses`.
    pub session_degraded: usize,
    /// Times the supervisor restarted the scheduler after a crash
    /// (always 0 without `--supervised`).
    pub restarts: usize,
    /// Overall health classification of the run; see [`Health`].
    pub health: Health,
}

impl ServeStats {
    fn mean_of<F: Fn(&Response) -> f64>(&self, f: F) -> f64 {
        if self.responses.is_empty() {
            return 0.0;
        }
        self.responses.iter().map(f).sum::<f64>()
            / self.responses.len() as f64
    }

    fn p95_of<F: Fn(&Response) -> f64>(&self, f: F) -> f64 {
        if self.responses.is_empty() {
            return 0.0;
        }
        let xs: Vec<f64> = self.responses.iter().map(f).collect();
        stats::percentile(&xs, 95.0)
    }

    pub fn throughput_tok_s(&self) -> f64 {
        self.tokens_generated as f64 / self.total_s.max(1e-9)
    }

    /// Mean end-to-end latency (queue + service); `0.0` with no responses.
    pub fn mean_latency_s(&self) -> f64 {
        self.mean_of(|r| r.queue_s + r.service_s)
    }

    /// p95 end-to-end latency (queue + service) across responses; `0.0`
    /// with no responses.
    pub fn p95_latency_s(&self) -> f64 {
        self.p95_of(|r| r.queue_s + r.service_s)
    }

    /// Mean time spent waiting for a lane; `0.0` with no responses.
    pub fn mean_queue_s(&self) -> f64 {
        self.mean_of(|r| r.queue_s)
    }

    /// p95 time spent waiting for a lane; `0.0` with no responses.
    pub fn p95_queue_s(&self) -> f64 {
        self.p95_of(|r| r.queue_s)
    }

    /// Mean decode (in-lane) time; `0.0` with no responses.
    pub fn mean_service_s(&self) -> f64 {
        self.mean_of(|r| r.service_s)
    }

    /// p95 decode (in-lane) time; `0.0` with no responses.
    pub fn p95_service_s(&self) -> f64 {
        self.p95_of(|r| r.service_s)
    }
}

/// Serving knobs beyond the request list.
#[derive(Clone, Debug)]
pub struct ServeOpts {
    pub temperature: f32,
    pub seed: u64,
    /// Upper bound on lanes decoded in lockstep (`--max-batch`).
    pub max_batch: usize,
}

impl Default for ServeOpts {
    fn default() -> Self {
        ServeOpts { temperature: 0.8, seed: 0, max_batch: MAX_DYNAMIC_BATCH }
    }
}

/// Serve a workload of requests to completion with default options
/// (PR-1 signature, kept for callers and tests).  No lane cap: PR-1
/// behavior planned straight from the queue length, so a fixed-batch
/// PJRT backend exporting executables wider than [`MAX_DYNAMIC_BATCH`]
/// still fills every lane (native backends self-cap via `plan_batch`).
///
/// ```
/// use minrnn::backend::{NativeBackend, NativeInit, NativeModel};
/// use minrnn::coordinator::server::{serve, Request};
///
/// let model = NativeModel::init_random(&NativeInit {
///     vocab_in: Some(16), vocab_out: 16, d_model: 8, n_layers: 1,
///     ..Default::default()
/// }, 0).unwrap();
/// let backend = NativeBackend::new(model);
/// let stats = serve(&backend, vec![
///     Request { id: 0, prompt: vec![1, 2, 3], n_tokens: 4, session: None },
///     Request { id: 1, prompt: vec![4], n_tokens: 2, session: None },
/// ], 0.8, 0).unwrap();
/// assert_eq!(stats.responses.len(), 2);
/// assert_eq!(stats.tokens_generated, 6);
/// ```
pub fn serve<B: Backend>(backend: &B, requests: Vec<Request>,
                         temperature: f32, seed: u64) -> Result<ServeStats> {
    serve_opts(backend, requests,
               &ServeOpts { temperature, seed, max_batch: usize::MAX })
}

/// Serve a workload of requests to completion using dynamic batching,
/// lockstep decode, and (when the backend supports lane reset)
/// continuous lane refill.
///
/// This is the synchronous facade over [`super::scheduler::Scheduler`]:
/// submit everything, close the queue, drain.  For admitting requests
/// while decoding is already underway, use the scheduler directly via
/// [`super::scheduler::SubmitHandle`].
pub fn serve_opts<B: Backend>(backend: &B, requests: Vec<Request>,
                              opts: &ServeOpts) -> Result<ServeStats> {
    serve_inner(backend, requests, opts, None)
}

/// [`serve_opts`] with a [`SessionCache`] attached: admitted lanes
/// warm-start from cached per-lane decode states (skipping the covered
/// prompt prefix) and completed requests carrying a [`Request::session`]
/// id export their state back into the cache for the next turn.  The
/// cache is borrowed, not owned, so one cache can span many serve calls
/// — and, via `save`/`load`, many server restarts.  On backends without
/// state export the cache stays inert and every request prefills
/// normally.
pub fn serve_with_cache<B: Backend>(backend: &B, requests: Vec<Request>,
                                    opts: &ServeOpts,
                                    cache: &RefCell<SessionCache>)
                                    -> Result<ServeStats> {
    serve_inner(backend, requests, opts, Some(cache))
}

fn serve_inner<B: Backend>(backend: &B, requests: Vec<Request>,
                           opts: &ServeOpts,
                           cache: Option<&RefCell<SessionCache>>)
                           -> Result<ServeStats> {
    if opts.max_batch == 0 {
        return Err(anyhow!("--max-batch must be >= 1"));
    }
    if backend.plan_batch(1).is_none() {
        return Err(anyhow!("backend '{}' exposes no decode batch sizes",
                           backend.name()));
    }
    // Validate up front so serving agrees with `infer::generate`, which
    // rejects empty prompts: a lane would otherwise silently substitute
    // token 0 for an empty-prompt request.
    if let Some(r) = requests.iter().find(|r| r.prompt.is_empty()) {
        return Err(anyhow!(
            "request {} has an empty prompt; every request needs at least \
             one prompt token", r.id));
    }
    let (mut scheduler, handle) = Scheduler::new(backend, SchedulerOpts {
        serve: opts.clone(),
        // everything is submitted before the drain starts, so the queue
        // must hold the whole workload without blocking this thread
        queue_depth: requests.len().max(1),
        backpressure: Backpressure::Block,
        default_deadline: None,
        lanes: None, // plan from the backlog, like the PR-2 loop
        ..Default::default()
    })?;
    if let Some(c) = cache {
        scheduler.set_session_cache(c);
    }
    for req in requests {
        handle.submit(req).map_err(|e| anyhow!("{e}"))?;
    }
    handle.close();
    scheduler.run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{NativeBackend, NativeInit, NativeModel};
    use crate::util::rng::Rng;

    // plan_batch's policy test lives with the function in
    // runtime::backend; here we exercise the serving facade itself.
    // Lockstep-batched vs per-request sequential agreement is
    // property-tested in rust/tests/parallel_props.rs, async interleaved
    // admission in rust/tests/scheduler_props.rs.

    fn tiny_backend(vocab: usize, seed: u64) -> NativeBackend {
        let model = NativeModel::init_random(&NativeInit {
            vocab_in: Some(vocab),
            vocab_out: vocab,
            d_model: 8,
            n_layers: 1,
            ..Default::default()
        }, seed).unwrap();
        NativeBackend::new(model)
    }

    #[test]
    fn serve_native_end_to_end() {
        // dynamic-batched serving with zero artifacts
        let backend = tiny_backend(32, 5);
        let mut rng = Rng::new(0);
        let requests: Vec<Request> = (0..6).map(|i| Request {
            id: i,
            prompt: (0..2 + rng.usize_below(4))
                .map(|_| rng.below(32) as i32).collect(),
            n_tokens: 5,
            session: None,
        }).collect();
        let stats = serve(&backend, requests, 1.0, 0).unwrap();
        assert_eq!(stats.responses.len(), 6);
        assert!(stats.responses.iter().all(|r| r.tokens.len() == 5));
        assert_eq!(stats.tokens_generated, 30);
        assert!(stats.responses.iter()
                .all(|r| r.tokens.iter().all(|&t| (0..32).contains(&t))));
        assert!(stats.p95_latency_s() >= 0.0);
        // the facade fills the admission accounting too
        assert_eq!(stats.submitted, 6);
        assert_eq!(stats.admitted, 6);
        assert_eq!(stats.rejected, 0);
        assert!(stats.expired.is_empty());
        assert!(stats.max_queue_depth >= 1);
        assert!(stats.batches_started >= 1);
        // a fault-free run is Healthy with nothing failed or retried
        assert!(stats.failed.is_empty());
        assert_eq!(stats.retries, 0);
        assert_eq!(stats.session_degraded, 0);
        assert_eq!(stats.health, Health::Healthy);
        assert_eq!(stats.health.to_string(), "healthy");
    }

    #[test]
    fn continuous_refill_serves_more_requests_than_lanes() {
        // 9 requests through 2 lanes: finished lanes must be re-seeded
        // from the queue (native backend supports reset_lane)
        let backend = tiny_backend(16, 11);
        let requests: Vec<Request> = (0..9).map(|i| Request {
            id: i,
            prompt: vec![1 + (i % 5) as i32, 2],
            n_tokens: 3 + (i % 3) as usize,
            session: None,
        }).collect();
        let want_tokens: usize = requests.iter().map(|r| r.n_tokens).sum();
        let stats = serve_opts(&backend, requests, &ServeOpts {
            temperature: 0.7,
            seed: 3,
            max_batch: 2,
        }).unwrap();
        assert_eq!(stats.responses.len(), 9);
        assert_eq!(stats.tokens_generated, want_tokens);
        assert!(stats.responses.iter().all(|r| r.batch == 2));
        for r in &stats.responses {
            assert_eq!(r.tokens.len(), 3 + (r.id % 3) as usize, "req {}",
                       r.id);
        }
        // lane refill, not batch restart: one continuously-refilled batch
        assert_eq!(stats.batches_started, 1);
    }

    #[test]
    fn empty_prompt_requests_are_rejected_up_front() {
        // serve must agree with infer::generate instead of silently
        // feeding token 0 into the empty lane
        let backend = tiny_backend(16, 2);
        let err = serve_opts(&backend, vec![
            Request { id: 0, prompt: vec![1, 2], n_tokens: 2,
                      session: None },
            Request { id: 7, prompt: vec![], n_tokens: 2, session: None },
        ], &ServeOpts::default());
        let msg = format!("{:#}", err.unwrap_err());
        assert!(msg.contains("request 7") && msg.contains("empty prompt"),
                "unhelpful error: {msg}");
    }

    #[test]
    fn max_batch_zero_is_rejected() {
        let backend = tiny_backend(16, 1);
        let err = serve_opts(&backend, vec![Request {
            id: 0,
            prompt: vec![1],
            n_tokens: 1,
            session: None,
        }], &ServeOpts { max_batch: 0, ..Default::default() });
        assert!(err.is_err());
    }

    #[test]
    fn empty_response_set_reports_zero_latencies() {
        // the documented edge case: every latency accessor returns 0.0 on
        // an idle run instead of panicking inside percentile() or
        // returning NaN from a 0/0 mean
        let stats = ServeStats {
            responses: Vec::new(),
            total_s: 0.25,
            tokens_generated: 0,
            submitted: 0,
            admitted: 0,
            rejected: 0,
            expired: Vec::new(),
            max_queue_depth: 0,
            batches_started: 0,
            session_hits: 0,
            session_misses: 0,
            session_evictions: 0,
            prefill_tokens_saved: 0,
            failed: Vec::new(),
            retries: 0,
            session_degraded: 0,
            restarts: 0,
            health: Health::Healthy,
        };
        assert_eq!(stats.mean_latency_s(), 0.0);
        assert_eq!(stats.p95_latency_s(), 0.0);
        assert_eq!(stats.mean_queue_s(), 0.0);
        assert_eq!(stats.p95_queue_s(), 0.0);
        assert_eq!(stats.mean_service_s(), 0.0);
        assert_eq!(stats.p95_service_s(), 0.0);
        assert_eq!(stats.throughput_tok_s(), 0.0);
        // serving zero requests through the facade is also well-defined
        let backend = tiny_backend(16, 8);
        let empty = serve(&backend, Vec::new(), 1.0, 0).unwrap();
        assert!(empty.responses.is_empty());
        assert_eq!(empty.p95_latency_s(), 0.0);
    }

    #[test]
    fn queue_and_service_latency_split_is_consistent() {
        let backend = tiny_backend(16, 13);
        let requests: Vec<Request> = (0..5).map(|i| Request {
            id: i,
            prompt: vec![1, 2, 3],
            n_tokens: 4,
            session: None,
        }).collect();
        let stats = serve_opts(&backend, requests, &ServeOpts {
            temperature: 0.5,
            seed: 1,
            max_batch: 2, // forces some requests to wait in queue
        }).unwrap();
        for r in &stats.responses {
            assert!(r.queue_s >= 0.0 && r.service_s > 0.0, "req {}", r.id);
        }
        let eps = 1e-12;
        assert!(stats.mean_latency_s()
                >= stats.mean_queue_s() + stats.mean_service_s() - eps);
    }
}
