//! Serving layer: request queue + dynamic batcher + continuous batched
//! decode over the fixed-batch step executables.
//!
//! PJRT handles are not `Send`, so the serving loop owns the runtime and
//! requests are plain host data.  The batcher picks the largest exported
//! batch size that the queue can fill (padding idle lanes), the decode
//! loop runs all lanes in lockstep — prompt tokens are consumed lane-wise
//! (RNN decode is O(1)/token), then sampling continues until each lane has
//! its requested tokens.

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::runtime::Model;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

use super::infer::sample_logits;

#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub n_tokens: usize,
}

#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<i32>,
    /// Seconds spent waiting in queue before the batch started.
    pub queue_s: f64,
    /// Seconds from batch start to this request's completion.
    pub service_s: f64,
    /// Batch size this request was served in.
    pub batch: usize,
}

/// Picks batch sizes: largest exported size ≤ queue length, else the
/// smallest exported size (padding idle lanes) once anything is waiting.
pub fn plan_batch(queue_len: usize, available: &[usize]) -> Option<usize> {
    if queue_len == 0 {
        return None;
    }
    let mut sizes: Vec<usize> = available.to_vec();
    sizes.sort_unstable();
    sizes.iter().rev().find(|&&b| b <= queue_len).copied()
        .or_else(|| sizes.first().copied())
}

pub struct ServeStats {
    pub responses: Vec<Response>,
    pub total_s: f64,
    pub tokens_generated: usize,
}

impl ServeStats {
    pub fn throughput_tok_s(&self) -> f64 {
        self.tokens_generated as f64 / self.total_s.max(1e-9)
    }

    pub fn mean_latency_s(&self) -> f64 {
        if self.responses.is_empty() {
            return 0.0;
        }
        self.responses.iter().map(|r| r.queue_s + r.service_s).sum::<f64>()
            / self.responses.len() as f64
    }
}

/// Serve a workload of requests to completion using dynamic batching.
pub fn serve(model: &Model, params: &[xla::Literal],
             requests: Vec<Request>, temperature: f32,
             seed: u64) -> Result<ServeStats> {
    let available: Vec<usize> = model.variant.step_files.iter()
        .map(|s| s.batch).collect();
    if available.is_empty() {
        return Err(anyhow!("variant {} exports no step executables",
                           model.variant.name));
    }
    let mut rng = Rng::new(seed);
    let mut queue: VecDeque<(Request, Instant)> =
        requests.into_iter().map(|r| (r, Instant::now())).collect();
    let mut responses = Vec::new();
    let mut tokens_generated = 0usize;
    let t_start = Instant::now();

    while let Some(bsize) = plan_batch(queue.len(), &available) {
        let take = bsize.min(queue.len());
        let batch: Vec<(Request, Instant)> =
            (0..take).filter_map(|_| queue.pop_front()).collect();
        let batch_start = Instant::now();

        // lane state
        let mut state = model.decode_state_zeros(bsize)?;
        let mut pos = vec![0usize; bsize];            // prompt cursor
        let mut done_at: Vec<Option<Instant>> = vec![None; bsize];
        let mut outputs: Vec<Vec<i32>> = vec![Vec::new(); bsize];
        let mut last_logits: Option<Tensor> = None;

        loop {
            // build the lane-wise input token vector
            let mut xs = vec![0i32; bsize];
            let mut any_active = false;
            for lane in 0..bsize {
                if lane >= batch.len() {
                    continue; // padding lane
                }
                let req = &batch[lane].0;
                if pos[lane] < req.prompt.len() {
                    xs[lane] = req.prompt[pos[lane]];
                    any_active = true;
                } else if outputs[lane].len() < req.n_tokens {
                    // feed the last sampled token
                    xs[lane] = outputs[lane].last().copied()
                        .unwrap_or_else(|| *req.prompt.last().unwrap_or(&0));
                    any_active = true;
                }
            }
            if !any_active {
                break;
            }

            let x = Tensor::i32(vec![bsize], xs);
            let (logits, new_state) = model.decode_step(params, &x, state)?;
            state = new_state;

            // consume logits: lanes past their prompt sample a token
            let vocab = logits.dims[1];
            let rows = logits.data.as_f32()
                .ok_or_else(|| anyhow!("logits not f32"))?;
            for lane in 0..bsize.min(batch.len()) {
                let req = &batch[lane].0;
                if pos[lane] < req.prompt.len() {
                    pos[lane] += 1;
                    if pos[lane] < req.prompt.len() {
                        continue;
                    }
                    // prompt just finished → next step samples
                }
                if pos[lane] >= req.prompt.len()
                    && outputs[lane].len() < req.n_tokens {
                    let row = &rows[lane * vocab..(lane + 1) * vocab];
                    let tok = sample_logits(row, temperature, &mut rng)
                        as i32;
                    outputs[lane].push(tok);
                    tokens_generated += 1;
                    if outputs[lane].len() == req.n_tokens
                        && done_at[lane].is_none() {
                        done_at[lane] = Some(Instant::now());
                    }
                }
            }
            last_logits = Some(logits);
        }
        let _ = last_logits;

        for (lane, (req, enqueued)) in batch.into_iter().enumerate() {
            let finished = done_at[lane].unwrap_or_else(Instant::now);
            responses.push(Response {
                id: req.id,
                tokens: std::mem::take(&mut outputs[lane]),
                queue_s: (batch_start - enqueued).as_secs_f64(),
                service_s: (finished - batch_start).as_secs_f64(),
                batch: bsize,
            });
        }
    }

    Ok(ServeStats {
        responses,
        total_s: t_start.elapsed().as_secs_f64(),
        tokens_generated,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_batch_policy() {
        let avail = [1usize, 8, 32];
        assert_eq!(plan_batch(0, &avail), None);
        assert_eq!(plan_batch(1, &avail), Some(1));
        assert_eq!(plan_batch(7, &avail), Some(1));
        assert_eq!(plan_batch(8, &avail), Some(8));
        assert_eq!(plan_batch(31, &avail), Some(8));
        assert_eq!(plan_batch(100, &avail), Some(32));
        // only large batches exported → pad up
        assert_eq!(plan_batch(3, &[8]), Some(8));
    }
}
