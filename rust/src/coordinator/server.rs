//! Serving layer: request queue + dynamic batcher + continuous batched
//! decode, generic over [`Backend`].
//!
//! PJRT handles are not `Send`, so the serving loop owns the backend and
//! requests are plain host data.  The batcher picks the batch size via
//! [`Backend::plan_batch`] — for the artifact backend that is the largest
//! exported batch the queue can fill (padding idle lanes); the native
//! backend forms exact-fit batches.  The decode loop runs all lanes in
//! lockstep — prompt tokens are consumed lane-wise (RNN decode is
//! O(1)/token), then sampling continues until each lane has its requested
//! tokens.

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::runtime::Backend;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

use super::infer::sample_logits;

pub use crate::runtime::backend::plan_batch;

#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub n_tokens: usize,
}

#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<i32>,
    /// Seconds spent waiting in queue before the batch started.
    pub queue_s: f64,
    /// Seconds from batch start to this request's completion.
    pub service_s: f64,
    /// Batch size this request was served in.
    pub batch: usize,
}

pub struct ServeStats {
    pub responses: Vec<Response>,
    pub total_s: f64,
    pub tokens_generated: usize,
}

impl ServeStats {
    pub fn throughput_tok_s(&self) -> f64 {
        self.tokens_generated as f64 / self.total_s.max(1e-9)
    }

    pub fn mean_latency_s(&self) -> f64 {
        if self.responses.is_empty() {
            return 0.0;
        }
        self.responses.iter().map(|r| r.queue_s + r.service_s).sum::<f64>()
            / self.responses.len() as f64
    }
}

/// Serve a workload of requests to completion using dynamic batching.
pub fn serve<B: Backend>(backend: &B, requests: Vec<Request>,
                         temperature: f32, seed: u64) -> Result<ServeStats> {
    if backend.plan_batch(1).is_none() {
        return Err(anyhow!("backend '{}' exposes no decode batch sizes",
                           backend.name()));
    }
    let mut rng = Rng::new(seed);
    let mut queue: VecDeque<(Request, Instant)> =
        requests.into_iter().map(|r| (r, Instant::now())).collect();
    let mut responses = Vec::new();
    let mut tokens_generated = 0usize;
    let t_start = Instant::now();

    while let Some(bsize) = backend.plan_batch(queue.len()) {
        let take = bsize.min(queue.len());
        let batch: Vec<(Request, Instant)> =
            (0..take).filter_map(|_| queue.pop_front()).collect();
        let batch_start = Instant::now();

        // lane state
        let mut state = backend.decode_state(bsize)?;
        let mut pos = vec![0usize; bsize];            // prompt cursor
        let mut done_at: Vec<Option<Instant>> = vec![None; bsize];
        let mut outputs: Vec<Vec<i32>> = vec![Vec::new(); bsize];

        loop {
            // build the lane-wise input token vector
            let mut xs = vec![0i32; bsize];
            let mut any_active = false;
            for lane in 0..bsize {
                if lane >= batch.len() {
                    continue; // padding lane
                }
                let req = &batch[lane].0;
                if pos[lane] < req.prompt.len() {
                    xs[lane] = req.prompt[pos[lane]];
                    any_active = true;
                } else if outputs[lane].len() < req.n_tokens {
                    // feed the last sampled token
                    xs[lane] = outputs[lane].last().copied()
                        .unwrap_or_else(|| *req.prompt.last().unwrap_or(&0));
                    any_active = true;
                }
            }
            if !any_active {
                break;
            }

            let x = Tensor::i32(vec![bsize], xs);
            let (logits, new_state) = backend.decode_step(&x, state)?;
            state = new_state;

            // consume logits: lanes past their prompt sample a token
            let vocab = logits.dims[1];
            let rows = logits.data.as_f32()
                .ok_or_else(|| anyhow!("logits not f32"))?;
            for lane in 0..bsize.min(batch.len()) {
                let req = &batch[lane].0;
                if pos[lane] < req.prompt.len() {
                    pos[lane] += 1;
                    if pos[lane] < req.prompt.len() {
                        continue;
                    }
                    // prompt just finished → next step samples
                }
                if pos[lane] >= req.prompt.len()
                    && outputs[lane].len() < req.n_tokens {
                    let row = &rows[lane * vocab..(lane + 1) * vocab];
                    let tok = sample_logits(row, temperature, &mut rng)
                        as i32;
                    outputs[lane].push(tok);
                    tokens_generated += 1;
                    if outputs[lane].len() == req.n_tokens
                        && done_at[lane].is_none() {
                        done_at[lane] = Some(Instant::now());
                    }
                }
            }
        }

        for (lane, (req, enqueued)) in batch.into_iter().enumerate() {
            let finished = done_at[lane].unwrap_or_else(Instant::now);
            responses.push(Response {
                id: req.id,
                tokens: std::mem::take(&mut outputs[lane]),
                queue_s: (batch_start - enqueued).as_secs_f64(),
                service_s: (finished - batch_start).as_secs_f64(),
                batch: bsize,
            });
        }
    }

    Ok(ServeStats {
        responses,
        total_s: t_start.elapsed().as_secs_f64(),
        tokens_generated,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{NativeBackend, NativeInit, NativeModel};

    // plan_batch's policy test lives with the function in
    // runtime::backend; here we exercise the serving loop itself.

    #[test]
    fn serve_native_end_to_end() {
        // dynamic-batched serving with zero artifacts
        let model = NativeModel::init_random(&NativeInit {
            vocab_in: Some(32),
            vocab_out: 32,
            d_model: 8,
            n_layers: 1,
            ..Default::default()
        }, 5).unwrap();
        let backend = NativeBackend::new(model);
        let mut rng = Rng::new(0);
        let requests: Vec<Request> = (0..6).map(|i| Request {
            id: i,
            prompt: (0..2 + rng.usize_below(4))
                .map(|_| rng.below(32) as i32).collect(),
            n_tokens: 5,
        }).collect();
        let stats = serve(&backend, requests, 1.0, 0).unwrap();
        assert_eq!(stats.responses.len(), 6);
        assert!(stats.responses.iter().all(|r| r.tokens.len() == 5));
        assert_eq!(stats.tokens_generated, 30);
        assert!(stats.responses.iter()
                .all(|r| r.tokens.iter().all(|&t| (0..32).contains(&t))));
    }
}
