//! Serving layer: request/response types, serving statistics, and the
//! synchronous serve API — a thin wrapper over the async admission
//! scheduler in [`coordinator::scheduler`](super::scheduler).
//!
//! The decode loop itself lives in [`super::scheduler::Scheduler`]: it
//! decodes every admitted request in **lockstep** (one `decode_step` per
//! wall-clock tick advances all lanes, prompt tokens are consumed
//! lane-wise, idle lanes are padding) and, on backends that implement
//! [`Backend::reset_lane`] (native), admits queued requests into free
//! lanes **mid-decode** — continuous batching, so a long request never
//! holds the batch hostage and work submitted after decoding started
//! still joins the running batch.  Backends without lane reset (PJRT
//! artifacts) fall back to run-to-completion batches.
//!
//! Every entrypoint funnels through one [`ServeConfig`]: a builder
//! holding the full serving knob set (sampling, lane cap, admission
//! queue, backpressure, deadlines, retries, session cache).  The CLI
//! parses its flags into a `ServeConfig` ([`ServeConfig::from_cli`]) and
//! the HTTP tier ([`super::http`] / [`super::shard`]) consumes the same
//! struct, so a request takes provably the same code path whether it
//! arrives as a flag-built synthetic workload or a network submission.
//! [`ServeConfig::run`] keeps the original submit-everything-up-front
//! contract: it pushes the whole `Vec<Request>` through the scheduler's
//! admission queue, closes it, and drains — token-for-token identical to
//! the PR-2 loop (greedy batched == per-request sequential decode is
//! property-tested in `rust/tests/parallel_props.rs`; async interleaved
//! admission in `rust/tests/scheduler_props.rs`).  The pre-redesign trio
//! [`serve`] / [`serve_opts`] / [`serve_with_cache`] survives as thin
//! deprecated shims over it.
//!
//! PJRT handles are not `Send`, so the serving loop owns the backend and
//! requests are plain host data.

use std::cell::RefCell;
use std::fmt;
use std::path::PathBuf;
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::log_info;
use crate::runtime::backend::MAX_DYNAMIC_BATCH;
use crate::runtime::Backend;
use crate::util::cli::Parsed;
use crate::util::json::{self, Json};
use crate::util::stats;
use crate::util::faults;

use super::scheduler::{Backpressure, Scheduler, SchedulerOpts};
use super::session_cache::SessionCache;

pub use crate::runtime::backend::plan_batch;

/// One unit of serving work: generate `n_tokens` continuation tokens for
/// `prompt`.  `n_tokens` doubles as the per-request max-new-tokens cap —
/// the lane frees the moment it is reached.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub n_tokens: usize,
    /// Conversation id for the session cache ([`serve_with_cache`] /
    /// [`super::scheduler::Scheduler::set_session_cache`]): requests
    /// carrying a session id export their final decode state on
    /// completion so the session's next turn skips re-prefilling the
    /// shared history.  `None` opts out of the completion export (the
    /// request still benefits from shared-prefix hits).
    pub session: Option<u64>,
}

/// A completed request, with its latency split into the two phases that
/// matter for capacity planning: time *queued* (waiting for a lane) vs
/// time *in service* (decoding).
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<i32>,
    /// Seconds spent waiting in queue before this request was admitted
    /// into a decode lane.
    pub queue_s: f64,
    /// Seconds from lane admission to this request's completion.
    pub service_s: f64,
    /// Lane count of the batch this request was served in.
    pub batch: usize,
}

/// Health of a serving run, as reported in [`ServeStats::health`].
///
/// * `Healthy` — no decode failures, no supervisor restarts.
/// * `Degraded` — the run completed, but something was absorbed along
///   the way: failed requests, decode retries, session-import
///   downgrades, or a supervisor restart.  Surviving traffic was served
///   (bit-identically for greedy decode), capacity or latency may have
///   suffered.
/// * `Draining` — the supervisor exhausted its restart budget and is
///   completing in-flight work without accepting recovery restarts; the
///   operator should expect the process to need attention.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Health {
    #[default]
    Healthy,
    Degraded,
    Draining,
}

impl fmt::Display for Health {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Health::Healthy => "healthy",
            Health::Degraded => "degraded",
            Health::Draining => "draining",
        })
    }
}

/// Aggregate statistics for one serving run (one [`serve_opts`] call or
/// one open-ended scheduler run).
///
/// Every latency accessor on this type returns `0.0` when `responses` is
/// empty — an idle server reports zero latency rather than panicking
/// inside the percentile sort or returning a 0/0 NaN mean; the
/// `empty_response_set_reports_zero_latencies` test pins that contract.
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    pub responses: Vec<Response>,
    pub total_s: f64,
    pub tokens_generated: usize,
    /// Requests accepted into the admission queue.  After a graceful
    /// drain, `submitted == responses.len() + expired.len() +
    /// failed.len()` — nothing is lost (rejected submissions never enter
    /// the queue and are counted separately).
    pub submitted: usize,
    /// Requests admitted into a decode lane (equals `responses.len()`
    /// after a full drain).
    pub admitted: usize,
    /// Submissions refused at the admission queue under
    /// [`Backpressure::Reject`] backpressure.
    pub rejected: usize,
    /// Ids of requests dropped because their queue-wait deadline passed
    /// before a lane freed up.  Expired requests are never half-served.
    pub expired: Vec<u64>,
    /// Peak admission-queue depth observed over the run.
    pub max_queue_depth: usize,
    /// Lockstep batches formed.  `1` means everything was served by a
    /// single continuously-refilled batch (the async-admission case);
    /// fixed backends without lane reset re-plan per batch.
    pub batches_started: usize,
    /// Session-cache lookups that warm-started a lane from a cached
    /// state (zero when no cache is attached or the backend cannot
    /// import state).
    pub session_hits: usize,
    /// Session-cache lookups that found nothing usable; the lane
    /// prefilled from scratch.  `session_hits + session_misses` equals
    /// the number of admissions that consulted the cache.
    pub session_misses: usize,
    /// Cache entries evicted (LRU, byte budget) during this run.
    pub session_evictions: usize,
    /// Prompt tokens whose prefill was skipped thanks to cache hits —
    /// the tentpole saving: each is one `decode_step` that never ran.
    pub prefill_tokens_saved: usize,
    /// Ids of requests dropped after exhausting their decode-retry
    /// budget (`SubmitError::Failed`): a request whose decode panicked or
    /// errored on every attempt, in quarantined isolation included.
    /// Failure is per-request — surviving lanes are unaffected.
    pub failed: Vec<u64>,
    /// Decode attempts that were retried after a transient failure
    /// (requeue + replay, with exponential backoff between batches).
    pub retries: usize,
    /// Session-cache imports that failed (corrupt state, import error)
    /// and were degraded to a cold prefill instead of failing the
    /// request.  These also count as `session_misses`.
    pub session_degraded: usize,
    /// Times the supervisor restarted the scheduler after a crash
    /// (always 0 without `--supervised`).
    pub restarts: usize,
    /// Overall health classification of the run; see [`Health`].
    pub health: Health,
}

impl ServeStats {
    fn mean_of<F: Fn(&Response) -> f64>(&self, f: F) -> f64 {
        if self.responses.is_empty() {
            return 0.0;
        }
        self.responses.iter().map(f).sum::<f64>()
            / self.responses.len() as f64
    }

    fn p95_of<F: Fn(&Response) -> f64>(&self, f: F) -> f64 {
        if self.responses.is_empty() {
            return 0.0;
        }
        let xs: Vec<f64> = self.responses.iter().map(f).collect();
        stats::percentile(&xs, 95.0)
    }

    pub fn throughput_tok_s(&self) -> f64 {
        self.tokens_generated as f64 / self.total_s.max(1e-9)
    }

    /// Mean end-to-end latency (queue + service); `0.0` with no responses.
    pub fn mean_latency_s(&self) -> f64 {
        self.mean_of(|r| r.queue_s + r.service_s)
    }

    /// p95 end-to-end latency (queue + service) across responses; `0.0`
    /// with no responses.
    pub fn p95_latency_s(&self) -> f64 {
        self.p95_of(|r| r.queue_s + r.service_s)
    }

    /// Mean time spent waiting for a lane; `0.0` with no responses.
    pub fn mean_queue_s(&self) -> f64 {
        self.mean_of(|r| r.queue_s)
    }

    /// p95 time spent waiting for a lane; `0.0` with no responses.
    pub fn p95_queue_s(&self) -> f64 {
        self.p95_of(|r| r.queue_s)
    }

    /// Mean decode (in-lane) time; `0.0` with no responses.
    pub fn mean_service_s(&self) -> f64 {
        self.mean_of(|r| r.service_s)
    }

    /// p95 decode (in-lane) time; `0.0` with no responses.
    pub fn p95_service_s(&self) -> f64 {
        self.p95_of(|r| r.service_s)
    }

    /// Fold another run's accounting into this one.  The sharded tier
    /// aggregates per-replica stats with this, and each replica folds a
    /// finished scheduler generation (a hot-swap drain boundary) into its
    /// lifetime totals.  Counters add and id/latency vectors concatenate;
    /// `total_s` takes the max because the merged runs execute
    /// concurrently (so throughput stays honest); `health` takes the
    /// worst of the two.
    pub fn merge(&mut self, other: ServeStats) {
        self.responses.extend(other.responses);
        self.total_s = self.total_s.max(other.total_s);
        self.tokens_generated += other.tokens_generated;
        self.submitted += other.submitted;
        self.admitted += other.admitted;
        self.rejected += other.rejected;
        self.expired.extend(other.expired);
        self.max_queue_depth = self.max_queue_depth.max(other.max_queue_depth);
        self.batches_started += other.batches_started;
        self.session_hits += other.session_hits;
        self.session_misses += other.session_misses;
        self.session_evictions += other.session_evictions;
        self.prefill_tokens_saved += other.prefill_tokens_saved;
        self.failed.extend(other.failed);
        self.retries += other.retries;
        self.session_degraded += other.session_degraded;
        self.restarts += other.restarts;
        self.health = match (self.health, other.health) {
            (Health::Draining, _) | (_, Health::Draining) => Health::Draining,
            (Health::Degraded, _) | (_, Health::Degraded) => Health::Degraded,
            _ => Health::Healthy,
        };
    }

    /// The `GET /v1/stats` wire shape: every counter plus the derived
    /// latency/throughput accessors, encoded with the dependency-free
    /// [`crate::util::json`] encoder.  `responses` flattens to a count
    /// (the per-response latency split stays server-side); `expired` and
    /// `failed` keep their request ids so a client can correlate drops.
    pub fn to_json(&self) -> Json {
        let ids =
            |v: &[u64]| Json::Arr(v.iter().map(|&x| json::num(x as f64)).collect());
        json::obj(vec![
            ("responses", json::num(self.responses.len() as f64)),
            ("submitted", json::num(self.submitted as f64)),
            ("admitted", json::num(self.admitted as f64)),
            ("rejected", json::num(self.rejected as f64)),
            ("expired", ids(&self.expired)),
            ("failed", ids(&self.failed)),
            ("tokens_generated", json::num(self.tokens_generated as f64)),
            ("total_s", json::num(self.total_s)),
            ("throughput_tok_s", json::num(self.throughput_tok_s())),
            ("mean_latency_s", json::num(self.mean_latency_s())),
            ("p95_latency_s", json::num(self.p95_latency_s())),
            ("mean_queue_s", json::num(self.mean_queue_s())),
            ("p95_queue_s", json::num(self.p95_queue_s())),
            ("mean_service_s", json::num(self.mean_service_s())),
            ("p95_service_s", json::num(self.p95_service_s())),
            ("max_queue_depth", json::num(self.max_queue_depth as f64)),
            ("batches_started", json::num(self.batches_started as f64)),
            ("session_hits", json::num(self.session_hits as f64)),
            ("session_misses", json::num(self.session_misses as f64)),
            ("session_evictions", json::num(self.session_evictions as f64)),
            ("prefill_tokens_saved",
             json::num(self.prefill_tokens_saved as f64)),
            ("retries", json::num(self.retries as f64)),
            ("session_degraded", json::num(self.session_degraded as f64)),
            ("restarts", json::num(self.restarts as f64)),
            ("health", json::s(&self.health.to_string())),
        ])
    }
}

/// Serving knobs beyond the request list.
#[derive(Clone, Debug)]
pub struct ServeOpts {
    pub temperature: f32,
    pub seed: u64,
    /// Upper bound on lanes decoded in lockstep (`--max-batch`).
    pub max_batch: usize,
}

impl Default for ServeOpts {
    fn default() -> Self {
        ServeOpts { temperature: 0.8, seed: 0, max_batch: MAX_DYNAMIC_BATCH }
    }
}

/// The full serving knob set, builder-style — the single configuration
/// type behind every serve entrypoint.
///
/// The CLI parses its `serve` flags into one of these
/// ([`ServeConfig::from_cli`]) and the network tier
/// ([`super::shard::Shard`] behind [`super::http::HttpServer`]) clones
/// the same struct into each replica, so a request is handled by
/// provably the same code path whether it arrived as a `--requests N`
/// synthetic workload or a `POST /v1/submit` body.  The pre-redesign
/// trio [`serve`] / [`serve_opts`] / [`serve_with_cache`] survives as
/// deprecated shims that build a `ServeConfig` and call
/// [`ServeConfig::run`] / [`ServeConfig::run_with_cache`].
///
/// ```
/// use minrnn::backend::{NativeBackend, NativeInit, NativeModel};
/// use minrnn::coordinator::server::{Request, ServeConfig};
///
/// let model = NativeModel::init_random(&NativeInit {
///     vocab_in: Some(16), vocab_out: 16, d_model: 8, n_layers: 1,
///     ..Default::default()
/// }, 0).unwrap();
/// let backend = NativeBackend::new(model);
/// let cfg = ServeConfig::new().temperature(0.0).seed(1).build().unwrap();
/// let stats = cfg.run(&backend, vec![
///     Request { id: 0, prompt: vec![1, 2, 3], n_tokens: 4, session: None },
///     Request { id: 1, prompt: vec![4], n_tokens: 2, session: None },
/// ]).unwrap();
/// assert_eq!(stats.responses.len(), 2);
/// assert_eq!(stats.tokens_generated, 6);
/// ```
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Sampling temperature (`0` = greedy argmax, the bit-identical mode).
    pub temperature: f32,
    /// Sampling seed (also the supervisor's backoff-jitter seed).
    pub seed: u64,
    /// Upper bound on lanes decoded in lockstep (`--max-batch`).
    pub max_batch: usize,
    /// Admission-queue capacity.  `None` sizes the queue from the
    /// workload in [`ServeConfig::run`] (submit-all-then-drain never
    /// blocks the caller) and defaults to 64 for open-ended schedulers.
    pub queue_depth: Option<usize>,
    /// Producer behavior on a full admission queue.
    pub backpressure: Backpressure,
    /// Per-request queue-wait deadline; queued past it → dropped, never
    /// half-served.
    pub deadline: Option<Duration>,
    /// Lane budget provisioned up front (`None` = plan from the
    /// backlog).  Open-loop drivers set `Some(max_batch)` so requests
    /// trickling in one by one still share a batch.
    pub lanes: Option<usize>,
    /// Decode retries per request beyond its first attempt.
    pub retry_limit: u32,
    /// Session-cache byte budget (`0` = cache off unless `session_dir`
    /// is set, in which case a 1 MiB floor applies).
    pub session_cache_bytes: usize,
    /// Directory persisting session caches across runs.
    pub session_dir: Option<PathBuf>,
    /// Deterministic fault-injection spec (the `--faults` /
    /// `MINRNN_FAULTS` grammar); installed process-wide by
    /// [`ServeConfig::build`].
    pub faults: Option<String>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            temperature: 0.8,
            seed: 0,
            max_batch: MAX_DYNAMIC_BATCH,
            queue_depth: None,
            backpressure: Backpressure::Block,
            deadline: None,
            lanes: None,
            retry_limit: 2,
            session_cache_bytes: 0,
            session_dir: None,
            faults: None,
        }
    }
}

impl ServeConfig {
    pub fn new() -> ServeConfig {
        ServeConfig::default()
    }

    pub fn temperature(mut self, t: f32) -> Self {
        self.temperature = t;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn max_batch(mut self, n: usize) -> Self {
        self.max_batch = n;
        self
    }

    pub fn queue_depth(mut self, depth: usize) -> Self {
        self.queue_depth = Some(depth);
        self
    }

    pub fn backpressure(mut self, bp: Backpressure) -> Self {
        self.backpressure = bp;
        self
    }

    pub fn deadline(mut self, d: Option<Duration>) -> Self {
        self.deadline = d;
        self
    }

    pub fn lanes(mut self, lanes: Option<usize>) -> Self {
        self.lanes = lanes;
        self
    }

    pub fn retry_limit(mut self, n: u32) -> Self {
        self.retry_limit = n;
        self
    }

    /// Session-cache byte budget; `0` disables caching (unless a
    /// [`ServeConfig::session_dir`] is set).
    pub fn session_cache(mut self, bytes: usize) -> Self {
        self.session_cache_bytes = bytes;
        self
    }

    pub fn session_dir(mut self, dir: Option<PathBuf>) -> Self {
        self.session_dir = dir;
        self
    }

    /// Fault-injection spec, e.g. `"seed=7,decode=0.01"`.
    pub fn faults(mut self, spec: &str) -> Self {
        self.faults = Some(spec.to_string());
        self
    }

    /// Validate the knob set and install the fault plan (if any).  An
    /// unset fault spec leaves any already-installed plan (e.g. from
    /// `MINRNN_FAULTS`) untouched.
    pub fn build(self) -> Result<ServeConfig> {
        if self.max_batch == 0 {
            return Err(anyhow!("max_batch must be >= 1"));
        }
        if self.queue_depth == Some(0) {
            return Err(anyhow!("queue_depth must be >= 1"));
        }
        if self.lanes == Some(0) {
            return Err(anyhow!("lanes must be >= 1"));
        }
        if let Some(spec) = &self.faults {
            faults::install(faults::parse(spec)
                .map_err(|e| anyhow!("faults spec: {e}"))?);
        }
        Ok(self)
    }

    /// Parse the `minrnn serve` flag set into a config (the CLI half of
    /// "CLI and HTTP are the same code path").  Mode-specific knobs the
    /// caller still owns: `lanes` (open-loop drivers want
    /// `Some(max_batch)`) and the workload shape (`--requests`,
    /// `--arrival-rate`, `--sessions`).
    pub fn from_cli(p: &Parsed) -> Result<ServeConfig> {
        let backpressure = match p.req("backpressure")? {
            "block" => Backpressure::Block,
            "reject" => Backpressure::Reject,
            other => return Err(anyhow!(
                "--backpressure expects block | reject, got '{other}'")),
        };
        let deadline_ms = p.u64("deadline-ms")?;
        let mut cfg = ServeConfig::new()
            .temperature(p.f32("temperature")?)
            .seed(p.u64("seed")?)
            .max_batch(p.usize("max-batch")?)
            .queue_depth(p.usize("queue-depth")?)
            .backpressure(backpressure)
            .deadline(if deadline_ms > 0 {
                Some(Duration::from_millis(deadline_ms))
            } else {
                None
            })
            .retry_limit(p.u64("retry-limit")? as u32)
            .session_cache(p.usize("session-cache-mb")? << 20)
            .session_dir(p.get("session-dir").map(PathBuf::from));
        if let Some(spec) = p.get("faults") {
            cfg = cfg.faults(spec);
        }
        cfg.build()
    }

    /// Just the sampling knobs, as the scheduler's [`ServeOpts`].
    pub fn sampling(&self) -> ServeOpts {
        ServeOpts {
            temperature: self.temperature,
            seed: self.seed,
            max_batch: self.max_batch,
        }
    }

    /// [`SchedulerOpts`] for an open-ended scheduler (async CLI driver,
    /// shard replicas): requests keep arriving while decode runs, so the
    /// queue depth comes from the config (default 64), not the workload.
    pub fn scheduler_opts(&self) -> SchedulerOpts {
        SchedulerOpts {
            serve: self.sampling(),
            queue_depth: self.queue_depth.unwrap_or(64).max(1),
            backpressure: self.backpressure,
            default_deadline: self.deadline,
            lanes: self.lanes,
            retry_limit: self.retry_limit,
        }
    }

    /// Whether this config asks for a session cache at all.
    pub fn cache_enabled(&self) -> bool {
        self.session_cache_bytes > 0 || self.session_dir.is_some()
    }

    /// Persistence path for the cache named `name` (replicas use
    /// distinct names so their caches do not clobber each other).
    pub fn session_file(&self, name: &str) -> Option<PathBuf> {
        self.session_dir.as_ref().map(|d| d.join(format!("{name}.mrsc")))
    }

    /// Build the configured session cache, warm-loading `name`'s
    /// persisted file if a `session_dir` is set.  A corrupt cache file
    /// is discarded (with a warning inside `load_or_recover`) and the
    /// cache starts cold — never a startup failure.  `None` when
    /// caching is off.
    pub fn open_session_cache(&self, name: &str) -> Option<SessionCache> {
        if !self.cache_enabled() {
            return None;
        }
        let budget = self.session_cache_bytes.max(1 << 20);
        Some(match self.session_file(name) {
            Some(f) => {
                let c = SessionCache::load_or_recover(&f, budget);
                if c.len() > 0 {
                    log_info!("session cache: loaded {} entries ({} KiB) \
                               from {}", c.len(), c.used_bytes() >> 10,
                              f.display());
                }
                c
            }
            None => SessionCache::new(budget),
        })
    }

    /// Persist `cache` to `name`'s file under `session_dir` (no-op
    /// without one), creating the directory if needed.
    pub fn save_session_cache(&self, name: &str, cache: &SessionCache)
                              -> Result<()> {
        if let Some(f) = self.session_file(name) {
            if let Some(dir) = f.parent() {
                std::fs::create_dir_all(dir)?;
            }
            cache.save(&f)?;
            log_info!("session cache: saved {} entries ({} KiB) to {}",
                      cache.len(), cache.used_bytes() >> 10, f.display());
        }
        Ok(())
    }

    /// Serve a workload of requests to completion: submit everything,
    /// close the queue, drain — the synchronous facade over
    /// [`super::scheduler::Scheduler`], using dynamic batching, lockstep
    /// decode, and (when the backend supports lane reset) continuous
    /// lane refill.  For admitting requests while decoding is already
    /// underway, use the scheduler directly via
    /// [`super::scheduler::SubmitHandle`] — or the network tier.
    pub fn run<B: Backend>(&self, backend: &B, requests: Vec<Request>)
                           -> Result<ServeStats> {
        self.run_with_cache(backend, requests, None)
    }

    /// [`ServeConfig::run`] with an externally owned [`SessionCache`]
    /// attached: admitted lanes warm-start from cached per-lane decode
    /// states (skipping the covered prompt prefix) and completed
    /// requests carrying a [`Request::session`] id export their state
    /// back for the next turn.  The cache is borrowed, not owned, so one
    /// cache can span many runs — and, via `save`/`load`, many server
    /// restarts.  On backends without state export the cache stays inert
    /// and every request prefills normally.
    pub fn run_with_cache<B: Backend>(&self, backend: &B,
                                      requests: Vec<Request>,
                                      cache: Option<&RefCell<SessionCache>>)
                                      -> Result<ServeStats> {
        if self.max_batch == 0 {
            return Err(anyhow!("max_batch must be >= 1"));
        }
        if backend.plan_batch(1).is_none() {
            return Err(anyhow!("backend '{}' exposes no decode batch sizes",
                               backend.name()));
        }
        // Validate up front so serving agrees with `infer::generate`,
        // which rejects empty prompts: a lane would otherwise silently
        // substitute token 0 for an empty-prompt request.
        if let Some(r) = requests.iter().find(|r| r.prompt.is_empty()) {
            return Err(anyhow!(
                "request {} has an empty prompt; every request needs at \
                 least one prompt token", r.id));
        }
        let (mut scheduler, handle) = Scheduler::new(backend, SchedulerOpts {
            serve: self.sampling(),
            // everything is submitted before the drain starts, so the
            // queue must hold the whole workload without blocking this
            // thread, whatever depth an open-ended tier would use
            queue_depth: self.queue_depth.unwrap_or(0)
                .max(requests.len()).max(1),
            backpressure: Backpressure::Block,
            default_deadline: self.deadline,
            lanes: self.lanes, // None = plan from the backlog (PR-2 loop)
            retry_limit: self.retry_limit,
        })?;
        if let Some(c) = cache {
            scheduler.set_session_cache(c);
        }
        for req in requests {
            handle.submit(req).map_err(|e| anyhow!("{e}"))?;
        }
        handle.close();
        scheduler.run()
    }
}

/// Serve a workload of requests to completion with default options
/// (PR-1 signature, kept for callers and tests).  No lane cap: PR-1
/// behavior planned straight from the queue length, so a fixed-batch
/// PJRT backend exporting executables wider than [`MAX_DYNAMIC_BATCH`]
/// still fills every lane (native backends self-cap via `plan_batch`).
#[deprecated(since = "0.2.0",
             note = "use ServeConfig::new()…build()?.run(backend, requests)")]
pub fn serve<B: Backend>(backend: &B, requests: Vec<Request>,
                         temperature: f32, seed: u64) -> Result<ServeStats> {
    ServeConfig::new()
        .temperature(temperature)
        .seed(seed)
        .max_batch(usize::MAX)
        .build()?
        .run(backend, requests)
}

/// Serve a workload with explicit [`ServeOpts`] (pre-[`ServeConfig`]
/// signature, kept for callers and tests).
#[deprecated(since = "0.2.0",
             note = "use ServeConfig::new()…build()?.run(backend, requests)")]
pub fn serve_opts<B: Backend>(backend: &B, requests: Vec<Request>,
                              opts: &ServeOpts) -> Result<ServeStats> {
    ServeConfig::new()
        .temperature(opts.temperature)
        .seed(opts.seed)
        .max_batch(opts.max_batch)
        .build()?
        .run(backend, requests)
}

/// Serve with a [`SessionCache`] attached (pre-[`ServeConfig`]
/// signature, kept for callers and tests).
#[deprecated(since = "0.2.0",
             note = "use ServeConfig::new()…build()?\
                     .run_with_cache(backend, requests, Some(cache))")]
pub fn serve_with_cache<B: Backend>(backend: &B, requests: Vec<Request>,
                                    opts: &ServeOpts,
                                    cache: &RefCell<SessionCache>)
                                    -> Result<ServeStats> {
    ServeConfig::new()
        .temperature(opts.temperature)
        .seed(opts.seed)
        .max_batch(opts.max_batch)
        .build()?
        .run_with_cache(backend, requests, Some(cache))
}

#[cfg(test)]
// The pre-ServeConfig entrypoints are exercised on purpose: the shims
// must keep their historical behavior until they are removed.
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::backend::{NativeBackend, NativeInit, NativeModel};
    use crate::util::rng::Rng;

    // plan_batch's policy test lives with the function in
    // runtime::backend; here we exercise the serving facade itself.
    // Lockstep-batched vs per-request sequential agreement is
    // property-tested in rust/tests/parallel_props.rs, async interleaved
    // admission in rust/tests/scheduler_props.rs.

    fn tiny_backend(vocab: usize, seed: u64) -> NativeBackend {
        let model = NativeModel::init_random(&NativeInit {
            vocab_in: Some(vocab),
            vocab_out: vocab,
            d_model: 8,
            n_layers: 1,
            ..Default::default()
        }, seed).unwrap();
        NativeBackend::new(model)
    }

    #[test]
    fn serve_native_end_to_end() {
        // dynamic-batched serving with zero artifacts
        let backend = tiny_backend(32, 5);
        let mut rng = Rng::new(0);
        let requests: Vec<Request> = (0..6).map(|i| Request {
            id: i,
            prompt: (0..2 + rng.usize_below(4))
                .map(|_| rng.below(32) as i32).collect(),
            n_tokens: 5,
            session: None,
        }).collect();
        let stats = serve(&backend, requests, 1.0, 0).unwrap();
        assert_eq!(stats.responses.len(), 6);
        assert!(stats.responses.iter().all(|r| r.tokens.len() == 5));
        assert_eq!(stats.tokens_generated, 30);
        assert!(stats.responses.iter()
                .all(|r| r.tokens.iter().all(|&t| (0..32).contains(&t))));
        assert!(stats.p95_latency_s() >= 0.0);
        // the facade fills the admission accounting too
        assert_eq!(stats.submitted, 6);
        assert_eq!(stats.admitted, 6);
        assert_eq!(stats.rejected, 0);
        assert!(stats.expired.is_empty());
        assert!(stats.max_queue_depth >= 1);
        assert!(stats.batches_started >= 1);
        // a fault-free run is Healthy with nothing failed or retried
        assert!(stats.failed.is_empty());
        assert_eq!(stats.retries, 0);
        assert_eq!(stats.session_degraded, 0);
        assert_eq!(stats.health, Health::Healthy);
        assert_eq!(stats.health.to_string(), "healthy");
    }

    #[test]
    fn continuous_refill_serves_more_requests_than_lanes() {
        // 9 requests through 2 lanes: finished lanes must be re-seeded
        // from the queue (native backend supports reset_lane)
        let backend = tiny_backend(16, 11);
        let requests: Vec<Request> = (0..9).map(|i| Request {
            id: i,
            prompt: vec![1 + (i % 5) as i32, 2],
            n_tokens: 3 + (i % 3) as usize,
            session: None,
        }).collect();
        let want_tokens: usize = requests.iter().map(|r| r.n_tokens).sum();
        let stats = serve_opts(&backend, requests, &ServeOpts {
            temperature: 0.7,
            seed: 3,
            max_batch: 2,
        }).unwrap();
        assert_eq!(stats.responses.len(), 9);
        assert_eq!(stats.tokens_generated, want_tokens);
        assert!(stats.responses.iter().all(|r| r.batch == 2));
        for r in &stats.responses {
            assert_eq!(r.tokens.len(), 3 + (r.id % 3) as usize, "req {}",
                       r.id);
        }
        // lane refill, not batch restart: one continuously-refilled batch
        assert_eq!(stats.batches_started, 1);
    }

    #[test]
    fn empty_prompt_requests_are_rejected_up_front() {
        // serve must agree with infer::generate instead of silently
        // feeding token 0 into the empty lane
        let backend = tiny_backend(16, 2);
        let err = serve_opts(&backend, vec![
            Request { id: 0, prompt: vec![1, 2], n_tokens: 2,
                      session: None },
            Request { id: 7, prompt: vec![], n_tokens: 2, session: None },
        ], &ServeOpts::default());
        let msg = format!("{:#}", err.unwrap_err());
        assert!(msg.contains("request 7") && msg.contains("empty prompt"),
                "unhelpful error: {msg}");
    }

    #[test]
    fn max_batch_zero_is_rejected() {
        let backend = tiny_backend(16, 1);
        let err = serve_opts(&backend, vec![Request {
            id: 0,
            prompt: vec![1],
            n_tokens: 1,
            session: None,
        }], &ServeOpts { max_batch: 0, ..Default::default() });
        assert!(err.is_err());
    }

    #[test]
    fn empty_response_set_reports_zero_latencies() {
        // the documented edge case: every latency accessor returns 0.0 on
        // an idle run instead of panicking inside percentile() or
        // returning NaN from a 0/0 mean
        let stats = ServeStats {
            responses: Vec::new(),
            total_s: 0.25,
            tokens_generated: 0,
            submitted: 0,
            admitted: 0,
            rejected: 0,
            expired: Vec::new(),
            max_queue_depth: 0,
            batches_started: 0,
            session_hits: 0,
            session_misses: 0,
            session_evictions: 0,
            prefill_tokens_saved: 0,
            failed: Vec::new(),
            retries: 0,
            session_degraded: 0,
            restarts: 0,
            health: Health::Healthy,
        };
        assert_eq!(stats.mean_latency_s(), 0.0);
        assert_eq!(stats.p95_latency_s(), 0.0);
        assert_eq!(stats.mean_queue_s(), 0.0);
        assert_eq!(stats.p95_queue_s(), 0.0);
        assert_eq!(stats.mean_service_s(), 0.0);
        assert_eq!(stats.p95_service_s(), 0.0);
        assert_eq!(stats.throughput_tok_s(), 0.0);
        // serving zero requests through the facade is also well-defined
        let backend = tiny_backend(16, 8);
        let empty = serve(&backend, Vec::new(), 1.0, 0).unwrap();
        assert!(empty.responses.is_empty());
        assert_eq!(empty.p95_latency_s(), 0.0);
    }

    #[test]
    fn serve_config_and_deprecated_shims_agree_token_for_token() {
        // the shims are thin: a greedy ServeConfig::run and the old
        // serve() must produce bit-identical responses
        let backend = tiny_backend(32, 9);
        let mk = || -> Vec<Request> {
            (0..5).map(|i| Request {
                id: i,
                prompt: vec![1 + i as i32, 2, 3],
                n_tokens: 4,
                session: None,
            }).collect()
        };
        let old = serve(&backend, mk(), 0.0, 7).unwrap();
        let new = ServeConfig::new().temperature(0.0).seed(7)
            .max_batch(usize::MAX).build().unwrap()
            .run(&backend, mk()).unwrap();
        let sorted = |s: &ServeStats| {
            let mut v: Vec<(u64, Vec<i32>)> = s.responses.iter()
                .map(|r| (r.id, r.tokens.clone())).collect();
            v.sort();
            v
        };
        assert_eq!(sorted(&old), sorted(&new));
    }

    #[test]
    fn serve_config_builder_validates() {
        assert!(ServeConfig::new().max_batch(0).build().is_err());
        assert!(ServeConfig::new().queue_depth(0).build().is_err());
        assert!(ServeConfig::new().lanes(Some(0)).build().is_err());
        assert!(ServeConfig::new().faults("no-such-knob=1").build().is_err());
        let cfg = ServeConfig::new().queue_depth(8).retry_limit(1)
            .build().unwrap();
        assert_eq!(cfg.scheduler_opts().queue_depth, 8);
        assert_eq!(cfg.scheduler_opts().retry_limit, 1);
        // no queue depth set: open-ended schedulers get the default,
        // run() sizes from the workload instead
        assert_eq!(ServeConfig::new().build().unwrap()
                   .scheduler_opts().queue_depth, 64);
    }

    #[test]
    fn serve_stats_merge_and_json_roundtrip() {
        let mut a = ServeStats {
            submitted: 3,
            admitted: 3,
            tokens_generated: 12,
            total_s: 1.0,
            max_queue_depth: 2,
            health: Health::Healthy,
            ..Default::default()
        };
        let b = ServeStats {
            submitted: 2,
            admitted: 1,
            tokens_generated: 4,
            total_s: 0.5,
            max_queue_depth: 5,
            expired: vec![41],
            failed: vec![42],
            health: Health::Degraded,
            ..Default::default()
        };
        a.merge(b);
        assert_eq!(a.submitted, 5);
        assert_eq!(a.tokens_generated, 16);
        assert_eq!(a.total_s, 1.0); // concurrent runs: max, not sum
        assert_eq!(a.max_queue_depth, 5);
        assert_eq!(a.expired, vec![41]);
        assert_eq!(a.failed, vec![42]);
        assert_eq!(a.health, Health::Degraded);
        // the /v1/stats wire shape survives the dependency-free encoder
        let text = json::to_string(&a.to_json());
        let back = json::parse(&text).unwrap();
        assert_eq!(back.req("submitted").unwrap().as_usize(), Some(5));
        assert_eq!(back.req("health").unwrap().as_str(), Some("degraded"));
        assert_eq!(back.req("failed").unwrap().as_arr().unwrap().len(), 1);
    }

    #[test]
    fn queue_and_service_latency_split_is_consistent() {
        let backend = tiny_backend(16, 13);
        let requests: Vec<Request> = (0..5).map(|i| Request {
            id: i,
            prompt: vec![1, 2, 3],
            n_tokens: 4,
            session: None,
        }).collect();
        let stats = serve_opts(&backend, requests, &ServeOpts {
            temperature: 0.5,
            seed: 1,
            max_batch: 2, // forces some requests to wait in queue
        }).unwrap();
        for r in &stats.responses {
            assert!(r.queue_s >= 0.0 && r.service_s > 0.0, "req {}", r.id);
        }
        let eps = 1e-12;
        assert!(stats.mean_latency_s()
                >= stats.mean_queue_s() + stats.mean_service_s() - eps);
    }
}
