//! Inference engine: sequential decode (Algorithm 5/7 steps), parallel
//! prefill for context ingestion, sampling, and the DT-style RL rollout
//! used for Table 3 scoring.
//!
//! Everything is generic over [`Backend`], so the same code drives the
//! PJRT artifact executables and the native pure-Rust model.

use anyhow::{anyhow, Result};

use crate::data::rl::envs;
use crate::data::rl::OfflineDataset;
use crate::runtime::Backend;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Softmax sampling with temperature from a logits row.
///
/// NaN logits (a poisoned model, an overflowed activation) must not crash
/// the server: they are treated as `-inf` — never sampled, never greedy —
/// and an all-NaN row deterministically yields token 0.
pub fn sample_logits(logits: &[f32], temperature: f32,
                     rng: &mut Rng) -> usize {
    if temperature <= 1e-6 {
        // explicit scan instead of max_by + partial_cmp().unwrap(), which
        // panics on NaN; `v > best` is false for NaN, so NaN never wins
        let mut best = f32::NEG_INFINITY;
        let mut arg = 0usize;
        for (i, &v) in logits.iter().enumerate() {
            if v > best {
                best = v;
                arg = i;
            }
        }
        return arg;
    }
    let max = logits.iter().cloned().fold(f32::MIN, f32::max);
    let weights: Vec<f64> = logits.iter()
        .map(|&l| {
            if l.is_nan() {
                0.0
            } else if l == f32::INFINITY {
                // saturated logit: (inf - inf) would be NaN; sample
                // uniformly among the +inf entries instead
                1.0
            } else {
                (((l - max) / temperature) as f64).exp()
            }
        })
        .collect();
    if weights.iter().all(|&w| w <= 0.0) {
        return 0;
    }
    rng.categorical(&weights)
}

/// Autoregressive generation for a single prompt (batch-1 decode).
///
/// The prompt is consumed token-by-token through the decode step (RNN
/// decode is O(1)/token, so sequential prompt ingestion is exactly what
/// Figure 3 measures for traditional RNNs; parallel models can use
/// [`Backend::prefill`] when the backend supports the context shape).
pub fn generate<B: Backend>(backend: &B, prompt: &[i32], n_tokens: usize,
                            temperature: f32, rng: &mut Rng)
                            -> Result<Vec<i32>> {
    let mut state = backend.decode_state(1)?;
    let mut logits = Tensor::zeros_f32(vec![1, 1]);
    if prompt.is_empty() {
        return Err(anyhow!("empty prompt"));
    }
    for &tok in prompt {
        let x = Tensor::i32(vec![1], vec![tok]);
        let (l, s) = backend.decode_step(&x, state)?;
        logits = l;
        state = s;
    }
    let mut out = Vec::with_capacity(n_tokens);
    for i in 0..n_tokens {
        let row = logits.data.as_f32()
            .ok_or_else(|| anyhow!("logits not f32"))?;
        let next = sample_logits(row, temperature, rng) as i32;
        out.push(next);
        if i + 1 < n_tokens {
            // the last sampled token needs no further forward pass
            let x = Tensor::i32(vec![1], vec![next]);
            let (l, s) = backend.decode_step(&x, state)?;
            logits = l;
            state = s;
        }
    }
    Ok(out)
}

/// Decision-Transformer-style policy rollout in a live environment:
/// condition on a target return-to-go, feed (rtg, obs, prev action)
/// features through the decode step, execute the predicted action.
/// Returns the raw episode return.
pub fn rollout_decision<B: Backend>(backend: &B, ds: &OfflineDataset,
                                    target_return: f32, seed: u64)
                                    -> Result<f32> {
    let mut env = envs::by_name(&ds.env_name)
        .ok_or_else(|| anyhow!("unknown env {}", ds.env_name))?;
    let mut rng = Rng::new(seed);
    let mut obs = env.reset(&mut rng);
    let mut state = backend.decode_state(1)?;
    let mut rtg = target_return;
    let mut prev_action = vec![0f32; ds.act_dim];
    let mut total = 0f32;
    loop {
        let mut feat = Vec::with_capacity(ds.feature_dim());
        feat.push(rtg / ds.rtg_scale);
        feat.extend(ds.norm_obs(&obs));
        feat.extend(&prev_action);
        let x = Tensor::f32(vec![1, ds.feature_dim()], feat);
        let (pred, s) = backend.decode_step(&x, state)?;
        state = s;
        let action: Vec<f32> = pred.data.as_f32()
            .ok_or_else(|| anyhow!("action not f32"))?
            .iter().map(|&a| a.clamp(-1.0, 1.0)).collect();
        let (o, r, done) = env.step(&action);
        obs = o;
        total += r;
        rtg -= r;
        prev_action = action;
        if done {
            break;
        }
    }
    Ok(total)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{NativeBackend, NativeInit, NativeModel};

    #[test]
    fn sampling_greedy_and_stochastic() {
        let mut rng = Rng::new(0);
        let logits = [0.0f32, 5.0, 1.0];
        assert_eq!(sample_logits(&logits, 0.0, &mut rng), 1);
        // at temperature 1 the argmax should still dominate
        let mut hits = [0usize; 3];
        for _ in 0..500 {
            hits[sample_logits(&logits, 1.0, &mut rng)] += 1;
        }
        assert!(hits[1] > 400, "{hits:?}");
        assert!(hits[0] + hits[2] > 0);
    }

    #[test]
    fn nan_logits_never_panic_or_win() {
        // regression: the greedy path's partial_cmp().unwrap() panicked on
        // NaN, turning a poisoned model into a server crash
        let mut rng = Rng::new(1);
        let poisoned = [0.5f32, f32::NAN, 2.0, f32::NAN];
        assert_eq!(sample_logits(&poisoned, 0.0, &mut rng), 2);
        for _ in 0..200 {
            let t = sample_logits(&poisoned, 1.0, &mut rng);
            assert!(t != 1 && t != 3, "sampled a NaN logit");
        }
        // fully poisoned rows fall back to token 0, deterministically
        let all_nan = [f32::NAN; 4];
        assert_eq!(sample_logits(&all_nan, 0.0, &mut rng), 0);
        assert_eq!(sample_logits(&all_nan, 1.0, &mut rng), 0);
        // -inf everywhere (fully masked) also stays in bounds
        let all_neg = [f32::NEG_INFINITY; 3];
        assert_eq!(sample_logits(&all_neg, 0.0, &mut rng), 0);
        assert_eq!(sample_logits(&all_neg, 1.0, &mut rng), 0);
        // a +inf logit must win, not poison the weights with inf - inf
        let sat = [0.0f32, f32::INFINITY, 4.0];
        assert_eq!(sample_logits(&sat, 0.0, &mut rng), 1);
        for _ in 0..50 {
            assert_eq!(sample_logits(&sat, 1.0, &mut rng), 1);
        }
    }

    #[test]
    fn generate_runs_on_the_native_backend() {
        // artifact-free end-to-end decode through the generic path
        let model = NativeModel::init_random(&NativeInit {
            vocab_in: Some(16),
            vocab_out: 16,
            d_model: 8,
            ..Default::default()
        }, 1).unwrap();
        let backend = NativeBackend::new(model);
        let mut rng = Rng::new(0);
        let out = generate(&backend, &[1, 2, 3], 12, 1.0, &mut rng).unwrap();
        assert_eq!(out.len(), 12);
        assert!(out.iter().all(|&t| (0..16).contains(&t)));
        assert!(generate(&backend, &[], 4, 1.0, &mut rng).is_err());
    }
}
