//! Training loop: host-side batching, LR scheduling, periodic evaluation,
//! early stopping, and checkpointing, generic over
//! [`crate::runtime::TrainBackend`] — the same loop drives the AOT PJRT
//! train-step executable ([`PjrtTrain`]) and the native Rust trainer
//! (`backend::NativeTrainer`), so training works with or without
//! artifacts.
//!
//! **Durability.**  Checkpointing is crash-safe end to end: every save
//! commits through `util::io` (tmp + fsync + rename + parent-dir fsync,
//! CRC32 trailer), [`CheckpointRing`] retains the last
//! `cfg.keep_checkpoints` periodic checkpoints plus an atomically
//! updated `<label>.LATEST` pointer, and [`recover_checkpoint`] walks
//! pointer → ring (newest first) → best → final, returning the newest
//! checkpoint that actually *parses and passes its CRC* — so a `kill
//! -9` or torn write during a save costs at most `checkpoint_every`
//! steps of progress, never the run.  Checkpoint IO failures inside
//! [`run_loop`] are logged and skipped, not fatal: a full disk degrades
//! durability, it does not kill training.

use std::collections::VecDeque;
use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::Result;

use crate::config::TrainConfig;
use crate::runtime::{EvalMetrics, Model, PjrtTrain, TrainBackend,
                     TrainState};
use crate::tensor::Batch;
use crate::util::io;
use crate::util::rng::Rng;
use crate::util::stats::Ema;
use crate::{log_info, log_warn};

/// Anything that can produce training / evaluation batches.
pub trait DataSource {
    fn train_batch(&mut self, rng: &mut Rng) -> Batch;
    /// Defaults to a fresh training batch (on-the-fly tasks).
    fn eval_batch(&mut self, rng: &mut Rng) -> Batch {
        self.train_batch(rng)
    }
}

/// Closure-backed data source.
pub struct FnSource<F: FnMut(&mut Rng) -> Batch> {
    pub f: F,
}

impl<F: FnMut(&mut Rng) -> Batch> DataSource for FnSource<F> {
    fn train_batch(&mut self, rng: &mut Rng) -> Batch {
        (self.f)(rng)
    }
}

#[derive(Clone, Debug, Default)]
pub struct TrainReport {
    /// (step, raw loss) at every log point.
    pub loss_curve: Vec<(usize, f32)>,
    /// (step, eval metrics) at every eval point.
    pub eval_curve: Vec<(usize, EvalMetrics)>,
    pub final_loss: f32,
    pub best_eval_loss: f32,
    pub best_eval_step: usize,
    pub final_eval: Option<EvalMetrics>,
    pub steps_per_sec: f64,
    pub steps_run: usize,
}

/// Retained-checkpoint ring: keeps the newest `keep` periodic
/// checkpoints (`<label>.step<N>.ckpt`) plus an atomically committed
/// `<label>.LATEST` pointer naming the most recent one.  Adopts any ring
/// files already in `dir`, so a resumed run keeps pruning where the
/// crashed one left off.
pub struct CheckpointRing {
    dir: PathBuf,
    label: String,
    keep: usize,
    ring: VecDeque<PathBuf>,
}

impl CheckpointRing {
    pub fn new(dir: &Path, label: &str, keep: usize) -> CheckpointRing {
        let label = label.replace('/', "_");
        let mut adopted: Vec<PathBuf> = Vec::new();
        if let Ok(rd) = std::fs::read_dir(dir) {
            let prefix = format!("{label}.step");
            for entry in rd.flatten() {
                let name = entry.file_name().to_string_lossy().into_owned();
                if name.starts_with(&prefix) && name.ends_with(".ckpt") {
                    adopted.push(entry.path());
                }
            }
        }
        // step numbers are zero-padded: lexicographic == chronological
        adopted.sort();
        CheckpointRing {
            dir: dir.to_path_buf(),
            label,
            keep: keep.max(1),
            ring: adopted.into(),
        }
    }

    pub fn len(&self) -> usize {
        self.ring.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ring.is_empty()
    }

    /// Path of the `LATEST` pointer file.
    pub fn latest_path(&self) -> PathBuf {
        self.dir.join(format!("{}.LATEST", self.label))
    }

    /// Save a checkpoint for `step`, durably repoint `LATEST` at it,
    /// then prune the oldest ring entries beyond `keep`.  Ordering
    /// matters: the pointer only moves *after* the new checkpoint is on
    /// stable storage, and pruning happens last, so a crash anywhere in
    /// between leaves at least one valid checkpoint reachable by
    /// [`recover_checkpoint`].
    pub fn commit(&mut self, backend: &dyn TrainBackend, step: usize)
                  -> Result<PathBuf> {
        let name = format!("{}.step{step:08}.ckpt", self.label);
        let path = self.dir.join(&name);
        backend.save_checkpoint(&path)?;
        io::commit_durable(&self.latest_path(), name.as_bytes())?;
        self.ring.push_back(path.clone());
        while self.ring.len() > self.keep {
            if let Some(old) = self.ring.pop_front() {
                let _ = std::fs::remove_file(old);
            }
        }
        Ok(path)
    }
}

/// Find the newest *valid* checkpoint for `label` in `dir`: try the
/// `LATEST` pointer's target, then ring files newest-first, then
/// `<label>.best.ckpt` and `<label>.final.ckpt`.  Each candidate is
/// fully parsed (including the CRC trailer) before being returned;
/// invalid ones — a torn write from a crashed save, a stale pointer —
/// are logged and skipped.  `None` means nothing recoverable exists.
pub fn recover_checkpoint(dir: &Path, label: &str) -> Option<PathBuf> {
    let label = label.replace('/', "_");
    let mut candidates: Vec<PathBuf> = Vec::new();
    let pointer = dir.join(format!("{label}.LATEST"));
    if let Ok(name) = std::fs::read_to_string(&pointer) {
        let name = name.trim();
        if !name.is_empty() && !name.contains(['/', '\\']) {
            candidates.push(dir.join(name));
        }
    }
    let mut ring: Vec<PathBuf> = Vec::new();
    if let Ok(rd) = std::fs::read_dir(dir) {
        let prefix = format!("{label}.step");
        for entry in rd.flatten() {
            let name = entry.file_name().to_string_lossy().into_owned();
            if name.starts_with(&prefix) && name.ends_with(".ckpt") {
                ring.push(entry.path());
            }
        }
    }
    ring.sort();
    candidates.extend(ring.into_iter().rev());
    candidates.push(dir.join(format!("{label}.best.ckpt")));
    candidates.push(dir.join(format!("{label}.final.ckpt")));
    let mut seen = std::collections::HashSet::new();
    for p in candidates {
        if !seen.insert(p.clone()) || !p.is_file() {
            continue;
        }
        match io::load(&p) {
            Ok(_) => return Some(p),
            Err(e) => log_warn!("skipping invalid checkpoint: {e:#}"),
        }
    }
    None
}

/// Run `cfg.steps` optimizer steps against any [`TrainBackend`]: cosine
/// (or constant) LR from `cfg`, EMA-smoothed logging, periodic evaluation
/// with best-checkpoint saving, early stopping after `patience`
/// non-improving evals (0 = never).  With `cfg.checkpoint_every > 0` a
/// [`CheckpointRing`] additionally commits every N steps for crash
/// recovery.  All checkpoint IO is best-effort: a failed save is logged
/// and training continues.
pub fn run_loop(backend: &mut dyn TrainBackend, cfg: &TrainConfig,
                patience: usize, data: &mut dyn DataSource)
                -> Result<TrainReport> {
    let mut rng = Rng::new(cfg.seed ^ 0x7124_11);
    let mut eval_rng = Rng::new(cfg.seed ^ 0xEEE1);
    let mut report = TrainReport {
        best_eval_loss: f32::INFINITY,
        ..Default::default()
    };
    let mut ema = Ema::new(0.1);
    let mut evals_since_best = 0usize;
    let mut ring = match &cfg.checkpoint {
        Some(dir) if cfg.checkpoint_every > 0 => {
            std::fs::create_dir_all(dir)?;
            Some(CheckpointRing::new(dir, backend.name(),
                                     cfg.keep_checkpoints))
        }
        _ => None,
    };
    let t0 = Instant::now();

    for step in 0..cfg.steps {
        let batch = data.train_batch(&mut rng);
        let lr = cfg.lr_at(step);
        let drop_seed = (cfg.seed as i32)
            ^ (step as i32).wrapping_mul(2654435761u32 as i32);
        let m = backend.train_step(&batch, lr, drop_seed)?;
        let smooth = ema.push(m.loss as f64);
        if step % cfg.log_every.max(1) == 0 || step + 1 == cfg.steps {
            report.loss_curve.push((step, m.loss));
            log_info!("{} step {step:5} loss {:.4} (ema {:.4}) \
                       gnorm {:.3} lr {:.2e}",
                      backend.name(), m.loss, smooth, m.grad_norm, lr);
        }
        report.final_loss = m.loss;

        if let Some(r) = ring.as_mut() {
            if (step + 1) % cfg.checkpoint_every == 0 {
                if let Err(e) = r.commit(&*backend, step + 1) {
                    log_warn!("checkpoint commit at step {} failed \
                               (training continues): {e:#}", step + 1);
                }
            }
        }

        let do_eval = cfg.eval_every > 0 && backend.supports_eval()
            && ((step + 1) % cfg.eval_every == 0 || step + 1 == cfg.steps);
        if do_eval {
            let em = evaluate(backend, cfg, data, &mut eval_rng)?;
            report.eval_curve.push((step + 1, em));
            log_info!("{} eval@{}: loss {:.4} tok_acc {:.3} seq_acc {:.3}",
                      backend.name(), step + 1, em.loss, em.token_acc,
                      em.seq_acc);
            if em.loss < report.best_eval_loss {
                report.best_eval_loss = em.loss;
                report.best_eval_step = step + 1;
                evals_since_best = 0;
                if let Some(dir) = &cfg.checkpoint {
                    let p = dir.join(format!("{}.best.ckpt",
                                             backend.name()));
                    let saved = std::fs::create_dir_all(dir)
                        .map_err(anyhow::Error::from)
                        .and_then(|()| backend.save_checkpoint(&p));
                    if let Err(e) = saved {
                        log_warn!("best-checkpoint save failed (training \
                                   continues): {e:#}");
                    }
                }
            } else {
                evals_since_best += 1;
                if patience > 0 && evals_since_best >= patience {
                    log_info!("early stop at step {} (patience {patience})",
                              step + 1);
                    report.steps_run = step + 1;
                    break;
                }
            }
            report.final_eval = Some(em);
        }
        report.steps_run = step + 1;
    }

    report.steps_per_sec =
        report.steps_run as f64 / t0.elapsed().as_secs_f64();
    if let Some(dir) = &cfg.checkpoint {
        let p = dir.join(format!("{}.final.ckpt", backend.name()));
        let saved = std::fs::create_dir_all(dir)
            .map_err(anyhow::Error::from)
            .and_then(|()| backend.save_checkpoint(&p));
        if let Err(e) = saved {
            log_warn!("final-checkpoint save failed: {e:#}");
        }
    }
    Ok(report)
}

/// Average eval metrics over `cfg.eval_batches` fresh batches.
pub fn evaluate(backend: &dyn TrainBackend, cfg: &TrainConfig,
                data: &mut dyn DataSource, rng: &mut Rng)
                -> Result<EvalMetrics> {
    let n = cfg.eval_batches.max(1);
    let mut acc = EvalMetrics::default();
    for _ in 0..n {
        let b = data.eval_batch(rng);
        let m = backend.eval(&b)?;
        acc.loss += m.loss / n as f32;
        acc.token_acc += m.token_acc / n as f32;
        acc.seq_acc += m.seq_acc / n as f32;
    }
    Ok(acc)
}

/// PJRT-facing facade (the PR-1 API): pairs an opened artifact [`Model`]
/// with a [`TrainConfig`] and drives [`run_loop`] over a [`PjrtTrain`]
/// borrowing the caller's [`TrainState`].
pub struct Trainer<'m, 'rt> {
    pub model: &'m Model<'rt>,
    pub cfg: TrainConfig,
    /// Stop if eval loss hasn't improved for this many evals (0 = never).
    pub patience: usize,
}

impl<'m, 'rt> Trainer<'m, 'rt> {
    pub fn new(model: &'m Model<'rt>, cfg: TrainConfig) -> Self {
        Trainer { model, cfg, patience: 0 }
    }

    /// Run the configured number of steps; returns the report and leaves
    /// the trained state in `state`.
    pub fn run(&self, state: &mut TrainState, data: &mut dyn DataSource)
               -> Result<TrainReport> {
        let mut backend = PjrtTrain { model: self.model, state };
        run_loop(&mut backend, &self.cfg, self.patience, data)
    }

    /// Average eval metrics over `eval_batches` fresh batches.
    pub fn evaluate(&self, state: &mut TrainState, data: &mut dyn DataSource,
                    rng: &mut Rng) -> Result<EvalMetrics> {
        let backend = PjrtTrain { model: self.model, state };
        evaluate(&backend, &self.cfg, data, rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::StepMetrics;
    use crate::util::io::NamedTensor;

    /// Minimal [`TrainBackend`] whose checkpoints are tiny valid MRNN
    /// files — just enough to exercise the ring and recovery.
    struct StubBackend;

    impl TrainBackend for StubBackend {
        fn name(&self) -> &str {
            "stub"
        }
        fn train_step(&mut self, _: &Batch, _: f32, _: i32)
                      -> Result<StepMetrics> {
            unreachable!("ring tests never step")
        }
        fn supports_eval(&self) -> bool {
            false
        }
        fn eval(&self, _: &Batch) -> Result<EvalMetrics> {
            unreachable!("ring tests never eval")
        }
        fn save_checkpoint(&self, path: &Path) -> Result<()> {
            io::save(path, &[NamedTensor::i32("step", vec![], vec![1])])
        }
    }

    fn fresh_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("minrnn_ring_{tag}"));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn ring_prunes_to_keep_and_tracks_latest() {
        let dir = fresh_dir("prune");
        let mut ring = CheckpointRing::new(&dir, "stub", 2);
        for step in [10usize, 20, 30] {
            ring.commit(&StubBackend, step).unwrap();
        }
        assert_eq!(ring.len(), 2);
        assert!(!dir.join("stub.step00000010.ckpt").exists(),
                "oldest ring entry must be pruned");
        assert!(dir.join("stub.step00000020.ckpt").exists());
        assert!(dir.join("stub.step00000030.ckpt").exists());
        let latest = std::fs::read_to_string(dir.join("stub.LATEST"))
            .unwrap();
        assert_eq!(latest.trim(), "stub.step00000030.ckpt");
        // a new ring over the same dir adopts the survivors
        let adopted = CheckpointRing::new(&dir, "stub", 2);
        assert_eq!(adopted.len(), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn recovery_skips_corrupt_and_falls_back_newest_first() {
        let dir = fresh_dir("recover");
        let mut ring = CheckpointRing::new(&dir, "stub", 3);
        ring.commit(&StubBackend, 10).unwrap();
        ring.commit(&StubBackend, 20).unwrap();
        // LATEST points at step 20; corrupt it as a torn write would
        let newest = dir.join("stub.step00000020.ckpt");
        let mut bytes = std::fs::read(&newest).unwrap();
        let n = bytes.len();
        bytes.truncate(n - 3);
        std::fs::write(&newest, &bytes).unwrap();
        let got = recover_checkpoint(&dir, "stub").unwrap();
        assert_eq!(got, dir.join("stub.step00000010.ckpt"),
                   "recovery must fall back to the newest valid file");
        // nothing valid at all -> None
        let empty = fresh_dir("recover_empty");
        assert!(recover_checkpoint(&empty, "stub").is_none());
        let _ = std::fs::remove_dir_all(&dir);
        let _ = std::fs::remove_dir_all(&empty);
    }

    #[test]
    fn recovery_prefers_ring_over_best_and_final() {
        let dir = fresh_dir("prefer");
        StubBackend.save_checkpoint(&dir.join("stub.best.ckpt")).unwrap();
        StubBackend.save_checkpoint(&dir.join("stub.final.ckpt")).unwrap();
        assert_eq!(recover_checkpoint(&dir, "stub").unwrap(),
                   dir.join("stub.best.ckpt"));
        let mut ring = CheckpointRing::new(&dir, "stub", 2);
        ring.commit(&StubBackend, 5).unwrap();
        assert_eq!(recover_checkpoint(&dir, "stub").unwrap(),
                   dir.join("stub.step00000005.ckpt"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
