//! Training loop: host-side batching, LR scheduling, periodic evaluation,
//! early stopping, and checkpointing, generic over
//! [`crate::runtime::TrainBackend`] — the same loop drives the AOT PJRT
//! train-step executable ([`PjrtTrain`]) and the native Rust trainer
//! (`backend::NativeTrainer`), so training works with or without
//! artifacts.

use std::time::Instant;

use anyhow::Result;

use crate::config::TrainConfig;
use crate::runtime::{EvalMetrics, Model, PjrtTrain, TrainBackend,
                     TrainState};
use crate::tensor::Batch;
use crate::util::rng::Rng;
use crate::util::stats::Ema;
use crate::log_info;

/// Anything that can produce training / evaluation batches.
pub trait DataSource {
    fn train_batch(&mut self, rng: &mut Rng) -> Batch;
    /// Defaults to a fresh training batch (on-the-fly tasks).
    fn eval_batch(&mut self, rng: &mut Rng) -> Batch {
        self.train_batch(rng)
    }
}

/// Closure-backed data source.
pub struct FnSource<F: FnMut(&mut Rng) -> Batch> {
    pub f: F,
}

impl<F: FnMut(&mut Rng) -> Batch> DataSource for FnSource<F> {
    fn train_batch(&mut self, rng: &mut Rng) -> Batch {
        (self.f)(rng)
    }
}

#[derive(Clone, Debug, Default)]
pub struct TrainReport {
    /// (step, raw loss) at every log point.
    pub loss_curve: Vec<(usize, f32)>,
    /// (step, eval metrics) at every eval point.
    pub eval_curve: Vec<(usize, EvalMetrics)>,
    pub final_loss: f32,
    pub best_eval_loss: f32,
    pub best_eval_step: usize,
    pub final_eval: Option<EvalMetrics>,
    pub steps_per_sec: f64,
    pub steps_run: usize,
}

/// Run `cfg.steps` optimizer steps against any [`TrainBackend`]: cosine
/// (or constant) LR from `cfg`, EMA-smoothed logging, periodic evaluation
/// with best-checkpoint saving, early stopping after `patience`
/// non-improving evals (0 = never).
pub fn run_loop(backend: &mut dyn TrainBackend, cfg: &TrainConfig,
                patience: usize, data: &mut dyn DataSource)
                -> Result<TrainReport> {
    let mut rng = Rng::new(cfg.seed ^ 0x7124_11);
    let mut eval_rng = Rng::new(cfg.seed ^ 0xEEE1);
    let mut report = TrainReport {
        best_eval_loss: f32::INFINITY,
        ..Default::default()
    };
    let mut ema = Ema::new(0.1);
    let mut evals_since_best = 0usize;
    let t0 = Instant::now();

    for step in 0..cfg.steps {
        let batch = data.train_batch(&mut rng);
        let lr = cfg.lr_at(step);
        let drop_seed = (cfg.seed as i32)
            ^ (step as i32).wrapping_mul(2654435761u32 as i32);
        let m = backend.train_step(&batch, lr, drop_seed)?;
        let smooth = ema.push(m.loss as f64);
        if step % cfg.log_every.max(1) == 0 || step + 1 == cfg.steps {
            report.loss_curve.push((step, m.loss));
            log_info!("{} step {step:5} loss {:.4} (ema {:.4}) \
                       gnorm {:.3} lr {:.2e}",
                      backend.name(), m.loss, smooth, m.grad_norm, lr);
        }
        report.final_loss = m.loss;

        let do_eval = cfg.eval_every > 0 && backend.supports_eval()
            && ((step + 1) % cfg.eval_every == 0 || step + 1 == cfg.steps);
        if do_eval {
            let em = evaluate(backend, cfg, data, &mut eval_rng)?;
            report.eval_curve.push((step + 1, em));
            log_info!("{} eval@{}: loss {:.4} tok_acc {:.3} seq_acc {:.3}",
                      backend.name(), step + 1, em.loss, em.token_acc,
                      em.seq_acc);
            if em.loss < report.best_eval_loss {
                report.best_eval_loss = em.loss;
                report.best_eval_step = step + 1;
                evals_since_best = 0;
                if let Some(dir) = &cfg.checkpoint {
                    std::fs::create_dir_all(dir)?;
                    backend.save_checkpoint(
                        &dir.join(format!("{}.best.ckpt", backend.name())))?;
                }
            } else {
                evals_since_best += 1;
                if patience > 0 && evals_since_best >= patience {
                    log_info!("early stop at step {} (patience {patience})",
                              step + 1);
                    report.steps_run = step + 1;
                    break;
                }
            }
            report.final_eval = Some(em);
        }
        report.steps_run = step + 1;
    }

    report.steps_per_sec =
        report.steps_run as f64 / t0.elapsed().as_secs_f64();
    if let Some(dir) = &cfg.checkpoint {
        std::fs::create_dir_all(dir)?;
        backend.save_checkpoint(
            &dir.join(format!("{}.final.ckpt", backend.name())))?;
    }
    Ok(report)
}

/// Average eval metrics over `cfg.eval_batches` fresh batches.
pub fn evaluate(backend: &dyn TrainBackend, cfg: &TrainConfig,
                data: &mut dyn DataSource, rng: &mut Rng)
                -> Result<EvalMetrics> {
    let n = cfg.eval_batches.max(1);
    let mut acc = EvalMetrics::default();
    for _ in 0..n {
        let b = data.eval_batch(rng);
        let m = backend.eval(&b)?;
        acc.loss += m.loss / n as f32;
        acc.token_acc += m.token_acc / n as f32;
        acc.seq_acc += m.seq_acc / n as f32;
    }
    Ok(acc)
}

/// PJRT-facing facade (the PR-1 API): pairs an opened artifact [`Model`]
/// with a [`TrainConfig`] and drives [`run_loop`] over a [`PjrtTrain`]
/// borrowing the caller's [`TrainState`].
pub struct Trainer<'m, 'rt> {
    pub model: &'m Model<'rt>,
    pub cfg: TrainConfig,
    /// Stop if eval loss hasn't improved for this many evals (0 = never).
    pub patience: usize,
}

impl<'m, 'rt> Trainer<'m, 'rt> {
    pub fn new(model: &'m Model<'rt>, cfg: TrainConfig) -> Self {
        Trainer { model, cfg, patience: 0 }
    }

    /// Run the configured number of steps; returns the report and leaves
    /// the trained state in `state`.
    pub fn run(&self, state: &mut TrainState, data: &mut dyn DataSource)
               -> Result<TrainReport> {
        let mut backend = PjrtTrain { model: self.model, state };
        run_loop(&mut backend, &self.cfg, self.patience, data)
    }

    /// Average eval metrics over `eval_batches` fresh batches.
    pub fn evaluate(&self, state: &mut TrainState, data: &mut dyn DataSource,
                    rng: &mut Rng) -> Result<EvalMetrics> {
        let backend = PjrtTrain { model: self.model, state };
        evaluate(&backend, &self.cfg, data, rng)
    }
}
