//! Training loop: drives the AOT train-step executable with host-side
//! batching, LR scheduling, periodic evaluation, early stopping, and
//! checkpointing.  One PJRT call per optimizer step — gradients never
//! reach the host.

use std::time::Instant;

use anyhow::Result;

use crate::config::TrainConfig;
use crate::runtime::{EvalMetrics, Model, TrainState};
use crate::tensor::Batch;
use crate::util::rng::Rng;
use crate::util::stats::Ema;
use crate::log_info;

/// Anything that can produce training / evaluation batches.
pub trait DataSource {
    fn train_batch(&mut self, rng: &mut Rng) -> Batch;
    /// Defaults to a fresh training batch (on-the-fly tasks).
    fn eval_batch(&mut self, rng: &mut Rng) -> Batch {
        self.train_batch(rng)
    }
}

/// Closure-backed data source.
pub struct FnSource<F: FnMut(&mut Rng) -> Batch> {
    pub f: F,
}

impl<F: FnMut(&mut Rng) -> Batch> DataSource for FnSource<F> {
    fn train_batch(&mut self, rng: &mut Rng) -> Batch {
        (self.f)(rng)
    }
}

#[derive(Clone, Debug, Default)]
pub struct TrainReport {
    /// (step, raw loss) at every log point.
    pub loss_curve: Vec<(usize, f32)>,
    /// (step, eval metrics) at every eval point.
    pub eval_curve: Vec<(usize, EvalMetrics)>,
    pub final_loss: f32,
    pub best_eval_loss: f32,
    pub best_eval_step: usize,
    pub final_eval: Option<EvalMetrics>,
    pub steps_per_sec: f64,
    pub steps_run: usize,
}

pub struct Trainer<'m, 'rt> {
    pub model: &'m Model<'rt>,
    pub cfg: TrainConfig,
    /// Stop if eval loss hasn't improved for this many evals (0 = never).
    pub patience: usize,
}

impl<'m, 'rt> Trainer<'m, 'rt> {
    pub fn new(model: &'m Model<'rt>, cfg: TrainConfig) -> Self {
        Trainer { model, cfg, patience: 0 }
    }

    /// Run the configured number of steps; returns the report and leaves
    /// the trained state in `state`.
    pub fn run(&self, state: &mut TrainState, data: &mut dyn DataSource)
               -> Result<TrainReport> {
        let mut rng = Rng::new(self.cfg.seed ^ 0x7124_11);
        let mut eval_rng = Rng::new(self.cfg.seed ^ 0xEEE1);
        let mut report = TrainReport {
            best_eval_loss: f32::INFINITY,
            ..Default::default()
        };
        let mut ema = Ema::new(0.1);
        let mut evals_since_best = 0usize;
        let t0 = Instant::now();

        for step in 0..self.cfg.steps {
            let batch = data.train_batch(&mut rng);
            let lr = self.cfg.lr_at(step);
            let m = self.model.train_step(state, &batch, lr,
                                          (self.cfg.seed as i32)
                                          ^ (step as i32).wrapping_mul(2654435761u32 as i32))?;
            let smooth = ema.push(m.loss as f64);
            if step % self.cfg.log_every.max(1) == 0
                || step + 1 == self.cfg.steps {
                report.loss_curve.push((step, m.loss));
                log_info!("{} step {step:5} loss {:.4} (ema {:.4}) \
                           gnorm {:.3} lr {:.2e}",
                          self.model.variant.name, m.loss, smooth,
                          m.grad_norm, lr);
            }
            report.final_loss = m.loss;

            let do_eval = self.cfg.eval_every > 0
                && !self.model.variant.eval_files.is_empty()
                && ((step + 1) % self.cfg.eval_every == 0
                    || step + 1 == self.cfg.steps);
            if do_eval {
                let em = self.evaluate(state, data, &mut eval_rng)?;
                report.eval_curve.push((step + 1, em));
                log_info!("{} eval@{}: loss {:.4} tok_acc {:.3} \
                           seq_acc {:.3}",
                          self.model.variant.name, step + 1, em.loss,
                          em.token_acc, em.seq_acc);
                if em.loss < report.best_eval_loss {
                    report.best_eval_loss = em.loss;
                    report.best_eval_step = step + 1;
                    evals_since_best = 0;
                    if let Some(dir) = &self.cfg.checkpoint {
                        std::fs::create_dir_all(dir)?;
                        self.model.save_checkpoint(
                            state, &dir.join(format!(
                                "{}.best.ckpt", self.model.variant.name)))?;
                    }
                } else {
                    evals_since_best += 1;
                    if self.patience > 0 && evals_since_best >= self.patience {
                        log_info!("early stop at step {} (patience {})",
                                  step + 1, self.patience);
                        report.steps_run = step + 1;
                        break;
                    }
                }
                report.final_eval = Some(em);
            }
            report.steps_run = step + 1;
        }

        report.steps_per_sec =
            report.steps_run as f64 / t0.elapsed().as_secs_f64();
        if let Some(dir) = &self.cfg.checkpoint {
            std::fs::create_dir_all(dir)?;
            self.model.save_checkpoint(
                state,
                &dir.join(format!("{}.final.ckpt",
                                  self.model.variant.name)))?;
        }
        Ok(report)
    }

    /// Average eval metrics over `eval_batches` fresh batches.
    pub fn evaluate(&self, state: &TrainState, data: &mut dyn DataSource,
                    rng: &mut Rng) -> Result<EvalMetrics> {
        let n = self.cfg.eval_batches.max(1);
        let mut acc = EvalMetrics::default();
        for _ in 0..n {
            let b = data.eval_batch(rng);
            let m = self.model.eval(state, &b)?;
            acc.loss += m.loss / n as f32;
            acc.token_acc += m.token_acc / n as f32;
            acc.seq_acc += m.seq_acc / n as f32;
        }
        Ok(acc)
    }
}
