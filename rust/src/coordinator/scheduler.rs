//! Async admission-controlled serving: a queued scheduler that admits
//! requests **mid-decode**.
//!
//! The PR-2 serving loop (`coordinator::server::serve_opts`) batches a
//! `Vec<Request>` handed in up front; a deployment could not add work
//! while a batch was decoding.  This module splits serving into a
//! producer/consumer pair around a [`BoundedQueue`]:
//!
//! * [`SubmitHandle`] — the cloneable, `Send` producer side.  Any thread
//!   submits [`Request`]s with configurable backpressure ([`Backpressure`]:
//!   block until space, or reject-when-full) and an optional per-request
//!   queue-wait deadline; [`SubmitHandle::close`] starts a graceful drain.
//! * [`Scheduler`] — the consumer.  It owns the backend reference and runs
//!   the lockstep batched decode loop *continuously*: between decode steps
//!   it admits newly queued requests into free lanes via
//!   [`Backend::reset_lane`], so a request submitted long after decoding
//!   started joins the running batch instead of waiting for it to finish.
//!   Backends without lane reset (PJRT artifacts) fall back to
//!   run-to-completion batches with admission at batch formation only.
//!
//! The scheduler is deliberately a *pump*: [`Scheduler::step`] performs one
//! admission pass plus one lockstep decode step and never blocks, which is
//! what makes the async path deterministic enough to property-test
//! (`rust/tests/scheduler_props.rs` interleaves submissions and steps in
//! randomized orders and asserts greedy output is bit-identical to
//! per-request sequential decode).  [`Scheduler::run`] wraps the pump in
//! the blocking drive loop a real deployment wants: decode while there is
//! work, sleep on the queue while idle, return [`ServeStats`] once the
//! queue is closed and drained.
//!
//! A [`SessionCache`] can be attached with
//! [`Scheduler::set_session_cache`]: because minGRU/minLSTM decode state
//! is a few KB and O(1) in context, admitted lanes can import a cached
//! state covering a verified prompt prefix and skip that prefix's
//! prefill entirely — see `coordinator::session_cache`.
//!
//! PJRT handles are not `Send`, so the scheduler (like the PR-2 loop)
//! stays on the thread that owns the backend; only plain-data requests
//! cross threads.  The sequential `serve_opts` API survives as a thin
//! wrapper: submit everything, close, run — token-for-token identical to
//! the PR-2 behavior.
//!
//! **Self-healing.**  A failing `decode_step` — transient error or
//! panic — never takes the scheduler down.  The step runs under
//! [`std::panic::catch_unwind`]; because `decode_step` consumes the
//! batch state by value, a failed step's lane states are gone, so every
//! occupied lane is *requeued as a replay*: its generated-so-far tokens
//! are folded into the prompt (greedy decode is batch-composition
//! invariant — property-pinned in `rust/tests/scheduler_props.rs` — so
//! replayed output is bit-identical) and the lane retries in a fresh
//! batch after an exponential backoff with deterministic jitter.  A
//! *panicking* batch additionally quarantines its lanes: each retries in
//! a single-lane batch, so a poisoned request (NaN weights it alone
//! trips over, adversarial input) can only fail itself.  Lanes that
//! exhaust [`SchedulerOpts::retry_limit`] are dropped into
//! [`ServeStats::failed`] ([`SubmitError::Failed`]) — the drain
//! invariant becomes `submitted == responses + expired + failed`.
//! Session-cache import failures degrade to a cold prefill and are
//! counted in [`ServeStats::session_degraded`], never fatal.  With
//! temperature > 0 a replay consumes the sampling RNG in a different
//! order than an uninterrupted run; only greedy output is pinned
//! bit-exact under faults.
//!
//! ```
//! use minrnn::backend::{NativeBackend, NativeInit, NativeModel};
//! use minrnn::coordinator::scheduler::{Scheduler, SchedulerOpts};
//! use minrnn::coordinator::server::Request;
//!
//! let model = NativeModel::init_random(&NativeInit {
//!     vocab_in: Some(16), vocab_out: 16, d_model: 8, n_layers: 1,
//!     ..Default::default()
//! }, 0).unwrap();
//! let backend = NativeBackend::new(model);
//! let (scheduler, handle) =
//!     Scheduler::new(&backend, SchedulerOpts::default()).unwrap();
//! // producers (any thread) submit; close() starts the graceful drain
//! handle.submit(Request {
//!     id: 0, prompt: vec![1, 2], n_tokens: 3, session: None,
//! }).unwrap();
//! handle.submit(Request {
//!     id: 1, prompt: vec![3], n_tokens: 2, session: None,
//! }).unwrap();
//! handle.close();
//! let stats = scheduler.run().unwrap();
//! assert_eq!(stats.responses.len(), 2);
//! assert_eq!(stats.tokens_generated, 5);
//! ```

use std::cell::RefCell;
use std::collections::VecDeque;
use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::log_warn;
use crate::runtime::Backend;
use crate::tensor::Tensor;
use crate::util::faults;
use crate::util::rng::{splitmix64, Rng};
use crate::util::threads::{BoundedQueue, PushError};

use super::infer::sample_logits;
use super::server::{Health, Request, Response, ServeOpts, ServeStats};
use super::session_cache::SessionCache;
use super::supervisor::panic_message;

/// How often (in prompt tokens) a decoding lane snapshots its state into
/// an attached session cache, in addition to the snapshot one token
/// before the prompt ends.  Periodic snapshots are what let a *different*
/// request sharing only part of the prompt (a common system prefix) hit
/// the cache.
const SNAPSHOT_EVERY: usize = 8;

// ---------------------------------------------------------------------------
// options
// ---------------------------------------------------------------------------

/// What a producer experiences when the admission queue is full.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backpressure {
    /// [`SubmitHandle::submit`] blocks until a slot frees up (closed-loop
    /// producers, and the sequential `serve_opts` wrapper).
    Block,
    /// [`SubmitHandle::submit`] fails fast with [`SubmitError::QueueFull`],
    /// handing the request back (open-loop producers that would rather
    /// shed load than build an unbounded backlog).
    Reject,
}

/// Scheduler configuration beyond the per-batch [`ServeOpts`] knobs.
#[derive(Clone, Debug)]
pub struct SchedulerOpts {
    /// Sampling / lane-cap options shared with the sequential path.
    pub serve: ServeOpts,
    /// Admission queue capacity (`--queue-depth`; ≥ 1).  Requests beyond
    /// it wait in the producer ([`Backpressure::Block`]) or are refused
    /// ([`Backpressure::Reject`]).
    pub queue_depth: usize,
    pub backpressure: Backpressure,
    /// Queue-wait budget applied to every submission that does not carry
    /// its own ([`SubmitHandle::submit_with_deadline`]).  A request still
    /// queued when its deadline passes is dropped (recorded in
    /// [`ServeStats::expired`]), never half-served.
    pub default_deadline: Option<Duration>,
    /// Decode-lane count for continuous admission.  `None` sizes the batch
    /// from the backlog at batch formation, exactly like the sequential
    /// path (right for submit-all-then-drain); `Some(n)` provisions `n`
    /// lanes up front so requests trickling in one by one still share a
    /// batch (right for open-loop serving).  Capped at
    /// [`ServeOpts::max_batch`] either way.
    pub lanes: Option<usize>,
    /// Decode attempts a request gets beyond the first (`--retry-limit`):
    /// a lane caught in a failed or panicked decode step is requeued and
    /// replayed up to this many times before it is dropped into
    /// [`ServeStats::failed`].
    pub retry_limit: u32,
}

impl Default for SchedulerOpts {
    fn default() -> Self {
        SchedulerOpts {
            serve: ServeOpts::default(),
            queue_depth: 64,
            backpressure: Backpressure::Block,
            default_deadline: None,
            lanes: None,
            retry_limit: 2,
        }
    }
}

// ---------------------------------------------------------------------------
// submission side
// ---------------------------------------------------------------------------

/// Why a submission was refused.  The request is handed back where
/// possible so the producer can retry or re-route it.
#[derive(Debug)]
pub enum SubmitError {
    /// Empty prompts are rejected at the door, agreeing with
    /// `infer::generate` (a lane would otherwise silently decode from
    /// token 0).
    EmptyPrompt { id: u64 },
    /// The queue is at capacity under [`Backpressure::Reject`].
    QueueFull(Request),
    /// [`SubmitHandle::close`] was already called.
    Closed(Request),
    /// The request's decode failed (error or panic) on every attempt,
    /// retry budget included.  Reported through [`ServeStats::failed`];
    /// surviving lanes are unaffected.
    Failed { id: u64, attempts: u32 },
    /// The request's queue-wait deadline passed before a lane freed up
    /// ([`ServeStats::expired`]); it was never half-served.  Raised by
    /// drivers that deliver per-request outcomes (the sharded network
    /// tier) — the scheduler itself reports expiry only through stats.
    Expired { id: u64 },
}

impl fmt::Display for SubmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SubmitError::EmptyPrompt { id } => write!(
                f, "request {id} has an empty prompt; every request needs \
                    at least one prompt token"),
            SubmitError::QueueFull(r) => write!(
                f, "request {} rejected: admission queue is full", r.id),
            SubmitError::Closed(r) => write!(
                f, "request {} refused: scheduler is shutting down", r.id),
            SubmitError::Failed { id, attempts } => write!(
                f, "request {id} failed after {attempts} decode attempts"),
            SubmitError::Expired { id } => write!(
                f, "request {id} expired in queue before a lane freed up"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// State shared between the producer handles and the scheduler.
/// `submitted` and the peak queue depth live *inside* the queue (counted
/// under its lock), so a drain can never observe an item whose
/// accounting has not landed yet; only the rejected tally — which never
/// becomes visible to the consumer — is a plain atomic.
struct Shared {
    queue: BoundedQueue<Submission>,
    rejected: AtomicUsize,
}

/// One queued request plus its admission bookkeeping.
struct Submission {
    req: Request,
    enqueued: Instant,
    deadline: Option<Duration>,
    /// Decode attempts consumed so far (0 for fresh submissions; bumped
    /// each time a failed step requeues the lane).
    strikes: u32,
    /// Quarantine flag: a lane requeued by a *panicking* step must retry
    /// in a single-lane batch so it can only take down itself.
    isolated: bool,
    /// Generated tokens already folded into `req.prompt` by replays; the
    /// response strips them back out of the prompt.
    replayed: usize,
}

/// Cloneable, `Send` producer side of the scheduler: submit requests from
/// any thread while the consumer decodes, then [`SubmitHandle::close`] to
/// start the graceful drain.
#[derive(Clone)]
pub struct SubmitHandle {
    shared: Arc<Shared>,
    backpressure: Backpressure,
    default_deadline: Option<Duration>,
}

impl SubmitHandle {
    /// Submit one request using the configured [`Backpressure`] and the
    /// scheduler's default deadline.
    pub fn submit(&self, req: Request) -> Result<(), SubmitError> {
        self.submit_with_deadline(req, self.default_deadline)
    }

    /// Submit with an explicit queue-wait deadline (`None` = wait
    /// forever), overriding [`SchedulerOpts::default_deadline`].
    pub fn submit_with_deadline(&self, req: Request,
                                deadline: Option<Duration>)
                                -> Result<(), SubmitError> {
        if req.prompt.is_empty() {
            return Err(SubmitError::EmptyPrompt { id: req.id });
        }
        let sub = Submission { req, enqueued: Instant::now(), deadline,
                               strikes: 0, isolated: false, replayed: 0 };
        let pushed = match self.backpressure {
            Backpressure::Block => self.shared.queue.push(sub),
            Backpressure::Reject => self.shared.queue.try_push(sub),
        };
        match pushed {
            // the queue itself counts accepted pushes and peak depth
            // under its lock, so nothing to record here
            Ok(_depth) => Ok(()),
            Err(PushError::Full(sub)) => {
                self.shared.rejected.fetch_add(1, Ordering::SeqCst);
                Err(SubmitError::QueueFull(sub.req))
            }
            Err(PushError::Closed(sub)) => Err(SubmitError::Closed(sub.req)),
        }
    }

    /// Requests currently waiting for a lane (racy snapshot).
    pub fn queue_len(&self) -> usize {
        self.shared.queue.len()
    }

    /// Stop accepting submissions and let the scheduler drain: every
    /// already-queued request is still served (or expired by its
    /// deadline), then [`Scheduler::run`] returns.  Idempotent; wakes a
    /// scheduler blocked on an empty queue.
    pub fn close(&self) {
        self.shared.queue.close();
    }
}

// ---------------------------------------------------------------------------
// decode lanes
// ---------------------------------------------------------------------------

/// One occupied decode lane (the PR-2 bookkeeping, moved here so the
/// sequential wrapper and the async scheduler share one implementation).
struct Lane {
    req: Request,
    enqueued: Instant,
    admitted: Instant,
    /// Prompt cursor.
    pos: usize,
    out: Vec<i32>,
    /// Decode attempts consumed (carried through requeues).
    strikes: u32,
    /// Generated tokens living inside `req.prompt` from earlier replays.
    replayed: usize,
}

impl Lane {
    /// Admit a queued request into a lane (used at batch formation and at
    /// continuous-admission refill — keep the bookkeeping in one place).
    fn admit(sub: Submission) -> Lane {
        Lane { req: sub.req, enqueued: sub.enqueued,
               admitted: Instant::now(), pos: 0, out: Vec::new(),
               strikes: sub.strikes, replayed: sub.replayed }
    }

    fn active(&self) -> bool {
        self.pos < self.req.prompt.len()
            || self.replayed + self.out.len() < self.req.n_tokens
    }

    fn next_input(&self) -> i32 {
        if self.pos < self.req.prompt.len() {
            self.req.prompt[self.pos]
        } else {
            self.out.last().copied()
                .unwrap_or_else(|| *self.req.prompt.last().unwrap_or(&0))
        }
    }

    /// Convert an in-flight lane back into a queued submission that
    /// *replays* its progress after a failed decode step: the tokens
    /// generated so far move into the prompt (greedy decode is
    /// batch-composition invariant, so re-deriving the remaining tokens
    /// in a different batch yields bit-identical output) and `replayed`
    /// records how many, so [`Lane::finish`] still reports exactly the
    /// requested continuation.
    fn requeue(mut self, isolated: bool) -> Submission {
        let replayed = self.replayed + self.out.len();
        self.req.prompt.extend_from_slice(&self.out);
        Submission {
            req: self.req,
            enqueued: self.enqueued,
            // the original deadline bounded *queue wait before first
            // admission*; a replayed lane was already admitted once
            deadline: None,
            strikes: self.strikes,
            isolated,
            replayed,
        }
    }

    fn finish(self, bsize: usize, done: Instant) -> Response {
        // replays folded earlier output into the prompt; hand it back as
        // output so the response is indistinguishable from a clean run
        let mut tokens: Vec<i32> =
            self.req.prompt[self.req.prompt.len() - self.replayed..]
            .to_vec();
        tokens.extend_from_slice(&self.out);
        Response {
            id: self.req.id,
            tokens,
            queue_s: (self.admitted - self.enqueued).as_secs_f64(),
            service_s: (done - self.admitted).as_secs_f64(),
            batch: bsize,
        }
    }
}

// ---------------------------------------------------------------------------
// the scheduler
// ---------------------------------------------------------------------------

/// Consumer side: owns the decode loop.  Create with [`Scheduler::new`],
/// feed it through the returned [`SubmitHandle`], and either drive it
/// manually with [`Scheduler::step`] (tests, custom event loops) or hand
/// it the thread with [`Scheduler::run`].
pub struct Scheduler<'b, B: Backend> {
    backend: &'b B,
    opts: SchedulerOpts,
    shared: Arc<Shared>,
    rng: Rng,
    /// Submissions popped but not admitted (a lane reset that reneged);
    /// consulted before the queue so FIFO order is preserved.  Stays
    /// empty in normal operation — backlog lives in the bounded queue,
    /// where backpressure can see it.
    pending: VecDeque<Submission>,
    /// Current batch, `None` between batches.
    state: Option<B::State>,
    bsize: usize,
    lanes: Vec<Option<Lane>>,
    /// Whether the backend re-seeds lanes in place (continuous admission).
    continuous: bool,
    /// Optional session cache ([`Scheduler::set_session_cache`]): admitted
    /// lanes warm-start from it, decoding lanes snapshot into it.
    cache: Option<&'b RefCell<SessionCache>>,
    cache_hits: usize,
    cache_misses: usize,
    prefill_saved: usize,
    cache_evictions_at_attach: u64,
    responses: Vec<Response>,
    expired: Vec<u64>,
    tokens_generated: usize,
    admitted: usize,
    batches_started: usize,
    t_start: Instant,
    /// Whether the current batch is a single quarantined lane retrying
    /// alone after a panic (no refill while it runs).
    isolated_batch: bool,
    /// Ids dropped after exhausting their decode-retry budget.
    failed: Vec<u64>,
    /// Lane requeues performed after failed decode steps.
    retries: usize,
    /// Decode steps that failed or panicked (all lanes of the batch
    /// counted once).
    decode_failures: usize,
    /// Session-cache imports degraded to cold prefill.
    session_degraded: usize,
    /// Consecutive failed decode steps (drives exponential backoff;
    /// reset by the first successful step).
    consec_failures: u32,
    /// Backoff to sleep before the next step; set by a failed step,
    /// consumed by [`Scheduler::run`] so [`Scheduler::step`] itself
    /// never blocks.
    backoff: Option<Duration>,
}

impl<'b, B: Backend> Scheduler<'b, B> {
    /// Validate the configuration and wire up the admission queue.
    pub fn new(backend: &'b B, opts: SchedulerOpts)
               -> Result<(Scheduler<'b, B>, SubmitHandle)> {
        if opts.serve.max_batch == 0 {
            return Err(anyhow!("max_batch must be >= 1"));
        }
        if let Some(0) = opts.lanes {
            return Err(anyhow!("lanes must be >= 1 when set"));
        }
        if backend.plan_batch(1).is_none() {
            return Err(anyhow!("backend '{}' exposes no decode batch sizes",
                               backend.name()));
        }
        let shared = Arc::new(Shared {
            queue: BoundedQueue::new(opts.queue_depth),
            rejected: AtomicUsize::new(0),
        });
        let handle = SubmitHandle {
            shared: Arc::clone(&shared),
            backpressure: opts.backpressure,
            default_deadline: opts.default_deadline,
        };
        let rng = Rng::new(opts.serve.seed);
        let continuous = backend.lane_reset_supported();
        Ok((Scheduler {
            backend,
            opts,
            shared,
            rng,
            pending: VecDeque::new(),
            state: None,
            bsize: 0,
            lanes: Vec::new(),
            continuous,
            cache: None,
            cache_hits: 0,
            cache_misses: 0,
            prefill_saved: 0,
            cache_evictions_at_attach: 0,
            responses: Vec::new(),
            expired: Vec::new(),
            tokens_generated: 0,
            admitted: 0,
            batches_started: 0,
            t_start: Instant::now(),
            isolated_batch: false,
            failed: Vec::new(),
            retries: 0,
            decode_failures: 0,
            session_degraded: 0,
            consec_failures: 0,
            backoff: None,
        }, handle))
    }

    /// Attach a session cache.  Admitted lanes try to warm-start from it
    /// (import a cached state covering a verified prompt prefix, skipping
    /// that prefix's prefill) and decoding lanes snapshot back into it —
    /// periodically through the prompt (shared-prefix dedup) and, for
    /// requests carrying a [`Request::session`] id, on completion (the
    /// multi-turn path).  On backends without state export
    /// ([`Backend::state_fingerprint`] `== None`, e.g. PJRT artifacts)
    /// the cache stays inert and every request falls back to a normal
    /// prefill.
    pub fn set_session_cache(&mut self, cache: &'b RefCell<SessionCache>) {
        self.cache_evictions_at_attach = cache.borrow().stats().evictions;
        self.cache = Some(cache);
    }

    /// Batches formed so far (1 after a full run means every request was
    /// served by one continuously-refilled batch — the async-admission
    /// acceptance property).
    pub fn batches_started(&self) -> usize {
        self.batches_started
    }

    /// Lanes currently decoding a request.
    pub fn active_lanes(&self) -> usize {
        self.lanes.iter().flatten().filter(|l| l.active()).count()
    }

    /// Requests completed so far and not yet drained by
    /// [`Scheduler::take_completed`].
    pub fn completed(&self) -> usize {
        self.responses.len()
    }

    /// Drain the responses completed since the last drain (or the
    /// start).  A pump-style driver — the sharded serving tier — calls
    /// this after each [`Scheduler::step`] to deliver every response to
    /// its waiter as it lands, instead of waiting for the final
    /// [`ServeStats`].  Drained responses are the caller's to account
    /// for: they no longer appear in [`Scheduler::stats_snapshot`] or
    /// the stats returned by [`Scheduler::run`] /
    /// [`Scheduler::into_stats`].
    pub fn take_completed(&mut self) -> Vec<Response> {
        std::mem::take(&mut self.responses)
    }

    /// Drain the ids of requests that expired in queue since the last
    /// drain (same contract as [`Scheduler::take_completed`]).
    pub fn take_expired(&mut self) -> Vec<u64> {
        std::mem::take(&mut self.expired)
    }

    /// Drain the ids of requests failed past their retry budget since
    /// the last drain (same contract as [`Scheduler::take_completed`]).
    pub fn take_failed(&mut self) -> Vec<u64> {
        std::mem::take(&mut self.failed)
    }

    /// Non-destructive view of the accounting so far — the live
    /// `GET /v1/stats` answer for a scheduler that is still running.
    /// Outcomes already drained via the `take_*` methods are *not*
    /// re-counted here; an incrementally draining driver merges this
    /// snapshot into its own cumulative stats
    /// ([`ServeStats::merge`]).
    pub fn stats_snapshot(&self) -> ServeStats {
        ServeStats {
            responses: self.responses.clone(),
            total_s: self.t_start.elapsed().as_secs_f64(),
            tokens_generated: self.tokens_generated,
            submitted: self.shared.queue.accepted(),
            admitted: self.admitted,
            rejected: self.shared.rejected.load(Ordering::SeqCst),
            expired: self.expired.clone(),
            max_queue_depth: self.shared.queue.peak_depth(),
            batches_started: self.batches_started,
            session_hits: self.cache_hits,
            session_misses: self.cache_misses,
            session_evictions: self.cache
                .map(|c| (c.borrow().stats().evictions
                          - self.cache_evictions_at_attach) as usize)
                .unwrap_or(0),
            prefill_tokens_saved: self.prefill_saved,
            failed: self.failed.clone(),
            retries: self.retries,
            session_degraded: self.session_degraded,
            restarts: 0,
            health: if self.decode_failures == 0
                && self.session_degraded == 0 {
                Health::Healthy
            } else {
                Health::Degraded
            },
        }
    }

    /// Final accounting for an externally pumped scheduler.  The sharded
    /// tier drives [`Scheduler::step`] itself (it cannot park in
    /// [`Scheduler::run`] because it also services its replica inbox),
    /// so it consumes the scheduler here once the queue is closed and
    /// drained.
    pub fn into_stats(mut self) -> ServeStats {
        self.take_stats()
    }

    /// Pop the next live submission, dropping (and recording) any whose
    /// queue-wait deadline has passed.
    fn pop_live(&mut self) -> Option<Submission> {
        loop {
            let sub = match self.pending.pop_front() {
                Some(s) => s,
                None => self.shared.queue.try_pop()?,
            };
            if let Some(d) = sub.deadline {
                if sub.enqueued.elapsed() >= d {
                    self.expired.push(sub.req.id);
                    continue;
                }
            }
            return Some(sub);
        }
    }

    /// Start a new batch from the backlog.  Returns `false` when no live
    /// submission is waiting.
    ///
    /// Plans *before* popping: only the requests that actually fit the
    /// planned lanes leave the bounded queue, so overflow keeps pressing
    /// on `queue_depth` where backpressure and the depth metric can see
    /// it (draining the whole backlog into a private buffer would let
    /// producers submit `queue_depth` more behind the configured bound).
    fn form_batch(&mut self) -> Result<bool> {
        let cap = self.opts.serve.max_batch;
        // Plan like the sequential path (from the whole backlog) unless a
        // fixed lane count was requested for open-loop serving.
        let backlog = self.pending.len() + self.shared.queue.len();
        if backlog == 0 {
            return Ok(false);
        }
        // A quarantined submission (requeued by a panicking step) decodes
        // alone, so a poisoned request can only fail itself.  Isolated
        // submissions only ever live at the front of `pending`.
        let isolated =
            self.pending.front().map_or(false, |s| s.isolated);
        let want = if isolated {
            1
        } else {
            self.opts.lanes.unwrap_or(backlog).min(cap)
        };
        let bsize = self.backend.plan_batch(want).ok_or_else(|| anyhow!(
            "backend '{}' refused to plan a batch for {want} requests",
            self.backend.name()))?;
        // Admit at most max_batch requests even when a fixed-size (PJRT)
        // backend pads up to an exported lane count above the cap — the
        // extra lanes stay idle padding.
        let limit = if isolated { 1 } else { bsize.min(cap) };
        let mut lanes: Vec<Option<Lane>> = (0..bsize).map(|_| None).collect();
        let mut admitted = 0usize;
        for slot in lanes.iter_mut().take(limit) {
            if admitted > 0
                && self.pending.front().map_or(false, |s| s.isolated) {
                // never mix a quarantined request into a shared batch
                break;
            }
            let Some(sub) = self.pop_live() else { break };
            *slot = Some(Lane::admit(sub));
            admitted += 1;
        }
        if admitted == 0 {
            // the entire backlog expired in queue; no batch to run
            return Ok(false);
        }
        self.state = Some(self.backend.decode_state(bsize)?);
        self.bsize = bsize;
        self.batches_started += 1;
        self.lanes = lanes;
        self.admitted += admitted;
        self.isolated_batch = isolated;
        for lane in 0..self.lanes.len() {
            self.restore_lane(lane);
        }
        Ok(true)
    }

    /// Warm-start a freshly admitted lane from the session cache: on a
    /// verified prefix hit the cached lane state is imported and the
    /// prompt cursor skips the covered tokens, turning most of the
    /// prefill into a lookup.  Counts a miss (and decodes from scratch)
    /// when the cache holds nothing usable; a no-op without an attached
    /// cache or on backends that cannot import state.
    fn restore_lane(&mut self, lane: usize) {
        let Some(cache) = self.cache else { return };
        let Some(fp) = self.backend.state_fingerprint() else { return };
        let Some(l) = self.lanes[lane].as_mut() else { return };
        if l.pos != 0 {
            return; // already decoding; nothing to warm-start
        }
        let hit =
            cache.borrow_mut().lookup(l.req.session, &l.req.prompt, fp);
        let Some((covered, snap)) = hit else {
            self.cache_misses += 1;
            return;
        };
        let state = self.state.as_mut().expect("admitted lane has state");
        match self.backend.import_state(state, lane, &snap) {
            Ok(()) => {
                l.pos = covered;
                self.cache_hits += 1;
                self.prefill_saved += covered;
            }
            Err(e) => {
                // a bad cached state degrades this lane to a cold
                // prefill — counted, logged, never fatal to the request
                self.cache_misses += 1;
                self.session_degraded += 1;
                log_warn!("session import failed for request {} \
                           (degrading to cold prefill): {e:#}",
                          l.req.id);
            }
        }
    }

    /// Mid-decode admission: seed free lanes of the running batch from the
    /// queue via [`Backend::reset_lane`].  No-op on fixed backends.
    fn refill_lanes(&mut self) {
        if !self.continuous || self.state.is_none() || self.isolated_batch {
            return;
        }
        let limit = self.bsize.min(self.opts.serve.max_batch);
        for lane in 0..limit {
            if self.lanes[lane].is_some() {
                continue;
            }
            if self.pending.front().map_or(false, |s| s.isolated) {
                // a quarantined submission must start its own batch
                return;
            }
            let Some(sub) = self.pop_live() else { return };
            let state = self.state.as_mut().expect("checked above");
            if !self.backend.reset_lane(state, lane) {
                // the backend reneged on lane_reset_supported(); keep the
                // request queued for the next batch instead of losing it
                self.pending.push_front(sub);
                return;
            }
            self.lanes[lane] = Some(Lane::admit(sub));
            self.admitted += 1;
            self.restore_lane(lane);
        }
    }

    /// Drop a fully drained batch (every lane idle).
    fn retire_batch(&mut self) {
        // Safety flush: the consume loop responds and clears lanes the
        // moment they finish, so occupied lanes here are unreachable —
        // but a response must never be lost to a logic slip.
        for slot in self.lanes.iter_mut() {
            if let Some(l) = slot.take() {
                let done = Instant::now();
                self.responses.push(l.finish(self.bsize, done));
            }
        }
        self.state = None;
        self.lanes = Vec::new();
        self.bsize = 0;
        self.isolated_batch = false;
    }

    /// A decode step failed (`poisoned == false`: transient `Err`) or
    /// panicked (`poisoned == true`).  `decode_step` consumed the batch
    /// state, so the in-flight lane states are gone: convert every
    /// occupied lane back into a replaying [`Submission`]
    /// ([`Lane::requeue`]) at the front of `pending`, drop lanes that
    /// are out of retry budget into [`ServeStats::failed`], and arm an
    /// exponential backoff (deterministic jitter keyed off the serve
    /// seed) for [`Scheduler::run`] to sleep before the retry batch.
    /// Panicked lanes are quarantined: each replays in a single-lane
    /// batch.
    fn recover_failed_step(&mut self, poisoned: bool, why: &str) {
        self.decode_failures += 1;
        self.consec_failures += 1;
        let mut resubs: Vec<Submission> = Vec::new();
        for slot in self.lanes.iter_mut() {
            let Some(l) = slot.take() else { continue };
            let mut sub = l.requeue(poisoned);
            sub.strikes += 1;
            if sub.strikes > self.opts.retry_limit {
                let err = SubmitError::Failed {
                    id: sub.req.id, attempts: sub.strikes,
                };
                log_warn!("{err}: {why}");
                self.failed.push(sub.req.id);
                continue;
            }
            self.retries += 1;
            resubs.push(sub);
        }
        // push_front in reverse keeps FIFO order among the survivors
        for sub in resubs.into_iter().rev() {
            self.pending.push_front(sub);
        }
        self.state = None;
        self.lanes = Vec::new();
        self.bsize = 0;
        self.isolated_batch = false;
        let shift = self.consec_failures.saturating_sub(1).min(6);
        let base_us = 200u64 << shift;
        let mut key = self.opts.serve.seed
            ^ (self.decode_failures as u64)
                .wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let jitter_us = splitmix64(&mut key) % (base_us / 2 + 1);
        self.backoff = Some(Duration::from_micros(base_us + jitter_us));
        log_warn!("decode step {} ({why}); requeued surviving lanes, \
                   backing off {}us",
                  if poisoned { "panicked" } else { "failed" },
                  base_us + jitter_us);
    }

    /// One scheduler pump: an admission pass (batch formation or
    /// mid-decode lane refill) plus at most one lockstep decode step.
    /// Never blocks.  Returns `false` when there was nothing to do — no
    /// active lane and no live queued request ([`Scheduler::run`] then
    /// sleeps on the queue).
    pub fn step(&mut self) -> Result<bool> {
        if self.state.is_none() {
            if !self.form_batch()? {
                return Ok(false);
            }
        } else {
            self.refill_lanes();
        }

        // lane-wise input tokens; idle/padding lanes feed 0
        let bsize = self.bsize;
        let mut xs = vec![0i32; bsize];
        let mut any_active = false;
        for (lane, slot) in self.lanes.iter().enumerate() {
            if let Some(l) = slot {
                if l.active() {
                    xs[lane] = l.next_input();
                    any_active = true;
                }
            }
        }
        if !any_active {
            // drained batch: retire it so the next step can re-plan
            self.retire_batch();
            return Ok(true);
        }

        let x = Tensor::i32(vec![bsize], xs);
        let state = self.state.take().expect("active batch has state");
        // the decode step is the only place model code runs; isolate it
        // so neither an Err nor a panic (poisoned request, injected
        // fault) can take the scheduler down with lanes in flight
        let backend = self.backend;
        let outcome =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                faults::maybe_decode_panic();
                faults::maybe_latency();
                backend.decode_step(&x, state)
            }));
        let (logits, new_state) = match outcome {
            Ok(Ok(pair)) => {
                self.consec_failures = 0;
                pair
            }
            Ok(Err(e)) => {
                self.recover_failed_step(false, &format!("{e:#}"));
                return Ok(true);
            }
            Err(payload) => {
                let msg = panic_message(payload);
                self.recover_failed_step(true, &msg);
                return Ok(true);
            }
        };
        self.state = Some(new_state);

        // consume logits: lanes past their prompt sample a token;
        // finished lanes respond and free their lane for the next
        // admission pass
        let vocab = logits.dims[1];
        let rows = logits.data.as_f32()
            .ok_or_else(|| anyhow!("logits not f32"))?;
        let temperature = self.opts.serve.temperature;
        let caching = self.cache.is_some()
            && self.backend.state_fingerprint().is_some();
        // (lane, session, covered tokens) to export once the loop is
        // done: a finished lane's bookkeeping is gone, but its state row
        // stays untouched until the next admission pass.
        let mut exports: Vec<(usize, Option<u64>, Vec<i32>)> = Vec::new();
        for lane in 0..bsize {
            let Some(l) = self.lanes[lane].as_mut() else {
                continue;
            };
            if l.pos < l.req.prompt.len() {
                l.pos += 1;
                if l.pos < l.req.prompt.len() {
                    // mid-prompt: after the increment the lane state
                    // covers exactly prompt[..pos].  Snapshot
                    // periodically (shared-prefix dedup) and one token
                    // before the prompt ends (so rerunning the same
                    // prompt hits — a lane must keep one prompt token to
                    // feed for its first sampling logits).
                    if caching
                        && (l.pos % SNAPSHOT_EVERY == 0
                            || l.pos + 1 == l.req.prompt.len()) {
                        exports.push((lane, None,
                                      l.req.prompt[..l.pos].to_vec()));
                    }
                    continue;
                }
                // prompt just finished → this step's logits sample
            }
            if l.pos >= l.req.prompt.len()
                && l.replayed + l.out.len() < l.req.n_tokens {
                let row = &rows[lane * vocab..(lane + 1) * vocab];
                let tok = sample_logits(row, temperature, &mut self.rng)
                    as i32;
                l.out.push(tok);
                self.tokens_generated += 1;
            }
            if !l.active() {
                let done = Instant::now();
                let finished = self.lanes[lane].take().unwrap();
                if caching && finished.req.session.is_some() {
                    // the final sampled token was never fed through
                    // decode_step, so the lane state covers
                    // prompt ++ out[..len-1] — exactly the prefix of a
                    // follow-up turn that extends this conversation
                    let n = finished.out.len().saturating_sub(1);
                    let mut toks = finished.req.prompt.clone();
                    toks.extend_from_slice(&finished.out[..n]);
                    exports.push((lane, finished.req.session, toks));
                }
                self.responses.push(finished.finish(bsize, done));
            }
        }
        if let Some(cache) = self.cache {
            let state = self.state.as_ref().expect("active batch has state");
            for (lane, session, toks) in exports {
                if let Ok(snap) = self.backend.export_state(state, lane) {
                    cache.borrow_mut().insert(session, &toks, snap);
                }
            }
        }
        Ok(true)
    }

    /// Drive the scheduler to completion: decode while there is work,
    /// block on the admission queue while idle, and return once the queue
    /// is closed and fully drained.  This is the thread a deployment
    /// parks on the backend.
    pub fn run(mut self) -> Result<ServeStats> {
        loop {
            // a failed decode step armed a backoff: sleep it off here so
            // the pump-style step() stays non-blocking for tests
            if let Some(d) = self.backoff.take() {
                std::thread::sleep(d);
            }
            if self.step()? {
                continue;
            }
            // idle: sleep until a submission arrives or the queue closes
            if !self.shared.queue.wait_ready() {
                break;
            }
        }
        Ok(self.take_stats())
    }

    /// Final accounting, called once the queue is closed and drained.
    /// Takes `&mut self` (moving the collections out) because the `Drop`
    /// impl below forbids moving fields out of a consumed `self`.
    fn take_stats(&mut self) -> ServeStats {
        ServeStats {
            responses: std::mem::take(&mut self.responses),
            total_s: self.t_start.elapsed().as_secs_f64(),
            tokens_generated: self.tokens_generated,
            submitted: self.shared.queue.accepted(),
            admitted: self.admitted,
            rejected: self.shared.rejected.load(Ordering::SeqCst),
            expired: std::mem::take(&mut self.expired),
            max_queue_depth: self.shared.queue.peak_depth(),
            batches_started: self.batches_started,
            session_hits: self.cache_hits,
            session_misses: self.cache_misses,
            session_evictions: self.cache
                .map(|c| (c.borrow().stats().evictions
                          - self.cache_evictions_at_attach) as usize)
                .unwrap_or(0),
            prefill_tokens_saved: self.prefill_saved,
            failed: std::mem::take(&mut self.failed),
            retries: self.retries,
            session_degraded: self.session_degraded,
            // restarts belong to the supervisor; it stamps them onto the
            // stats of the generation that finally completes
            restarts: 0,
            health: if self.decode_failures == 0
                && self.session_degraded == 0 {
                Health::Healthy
            } else {
                Health::Degraded
            },
        }
    }
}

/// The consumer going away — error propagation out of [`Scheduler::run`],
/// a panic, or simply dropping a pump-style scheduler — must never leave
/// producers blocked in [`SubmitHandle::submit`] on a queue nobody will
/// ever drain again.  Closing here wakes them all with
/// [`SubmitError::Closed`]; close is idempotent, so the normal
/// producer-initiated shutdown path is unaffected.
impl<B: Backend> Drop for Scheduler<'_, B> {
    fn drop(&mut self) {
        self.shared.queue.close();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{NativeBackend, NativeInit, NativeModel};

    // The async-vs-sequential equivalence, drain, and late-admission
    // properties live in rust/tests/scheduler_props.rs; here we cover the
    // submission-side contracts.

    fn tiny_backend(vocab: usize, seed: u64) -> NativeBackend {
        let model = NativeModel::init_random(&NativeInit {
            vocab_in: Some(vocab),
            vocab_out: vocab,
            d_model: 8,
            n_layers: 1,
            ..Default::default()
        }, seed).unwrap();
        NativeBackend::new(model)
    }

    fn req(id: u64) -> Request {
        Request { id, prompt: vec![1, 2], n_tokens: 2, session: None }
    }

    #[test]
    fn empty_prompt_is_rejected_at_submit() {
        let backend = tiny_backend(16, 0);
        let (_sched, handle) =
            Scheduler::new(&backend, SchedulerOpts::default()).unwrap();
        let err = handle
            .submit(Request {
                id: 9, prompt: vec![], n_tokens: 1, session: None,
            })
            .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("request 9") && msg.contains("empty prompt"),
                "unhelpful error: {msg}");
        assert_eq!(handle.queue_len(), 0);
    }

    #[test]
    fn reject_backpressure_hands_the_request_back() {
        let backend = tiny_backend(16, 1);
        let (sched, handle) = Scheduler::new(&backend, SchedulerOpts {
            queue_depth: 1,
            backpressure: Backpressure::Reject,
            ..Default::default()
        }).unwrap();
        handle.submit(req(0)).unwrap();
        match handle.submit(req(1)) {
            Err(SubmitError::QueueFull(r)) => assert_eq!(r.id, 1),
            other => panic!("expected QueueFull, got {other:?}"),
        }
        handle.close();
        let stats = sched.run().unwrap();
        assert_eq!(stats.responses.len(), 1);
        assert_eq!(stats.rejected, 1);
        assert_eq!(stats.admitted, 1);
        assert!(stats.max_queue_depth >= 1);
    }

    #[test]
    fn submit_after_close_is_refused() {
        let backend = tiny_backend(16, 2);
        let (sched, handle) =
            Scheduler::new(&backend, SchedulerOpts::default()).unwrap();
        handle.submit(req(0)).unwrap();
        handle.close();
        match handle.submit(req(1)) {
            Err(SubmitError::Closed(r)) => assert_eq!(r.id, 1),
            other => panic!("expected Closed, got {other:?}"),
        }
        let stats = sched.run().unwrap();
        assert_eq!(stats.responses.len(), 1);
    }

    #[test]
    fn zero_deadline_expires_in_queue() {
        let backend = tiny_backend(16, 3);
        let (sched, handle) =
            Scheduler::new(&backend, SchedulerOpts::default()).unwrap();
        handle.submit(req(0)).unwrap();
        handle.submit_with_deadline(req(7), Some(Duration::ZERO)).unwrap();
        handle.close();
        let stats = sched.run().unwrap();
        // the zero-deadline request must be dropped as expired, not served
        assert_eq!(stats.responses.len(), 1);
        assert_eq!(stats.responses[0].id, 0);
        assert_eq!(stats.expired, vec![7]);
        // the drain-accounting invariant: every submission is accounted
        // for as served or expired
        assert_eq!(stats.submitted, 2);
        assert_eq!(stats.submitted,
                   stats.responses.len() + stats.expired.len());
    }

    #[test]
    fn invalid_options_are_rejected() {
        let backend = tiny_backend(16, 4);
        assert!(Scheduler::new(&backend, SchedulerOpts {
            serve: ServeOpts { max_batch: 0, ..Default::default() },
            ..Default::default()
        }).is_err());
        assert!(Scheduler::new(&backend, SchedulerOpts {
            lanes: Some(0),
            ..Default::default()
        }).is_err());
    }

    // ---- self-healing -----------------------------------------------------

    use std::cell::Cell;

    use crate::runtime::backend::SessionState;

    /// Delegates to a [`NativeBackend`] but makes the first `remaining`
    /// decode steps fail — with an `Err` (transient fault) or a panic
    /// (poisoned batch).  Process-local, so unlike `util::faults` it is
    /// safe in the shared unit-test binary.
    struct FlakyBackend {
        inner: NativeBackend,
        remaining: Cell<u32>,
        panics: bool,
    }

    impl Backend for FlakyBackend {
        type State = <NativeBackend as Backend>::State;

        fn name(&self) -> &str {
            "flaky"
        }
        fn step_batches(&self) -> Vec<usize> {
            self.inner.step_batches()
        }
        fn decode_state(&self, batch: usize) -> Result<Self::State> {
            self.inner.decode_state(batch)
        }
        fn decode_step(&self, x: &Tensor, state: Self::State)
                       -> Result<(Tensor, Self::State)> {
            if self.remaining.get() > 0 {
                self.remaining.set(self.remaining.get() - 1);
                if self.panics {
                    panic!("injected poisoned decode");
                }
                anyhow::bail!("injected transient decode failure");
            }
            self.inner.decode_step(x, state)
        }
        fn prefill(&self, x: &Tensor) -> Result<(Tensor, Self::State)> {
            self.inner.prefill(x)
        }
        fn reset_lane(&self, state: &mut Self::State, lane: usize) -> bool {
            self.inner.reset_lane(state, lane)
        }
        fn lane_reset_supported(&self) -> bool {
            self.inner.lane_reset_supported()
        }
        fn state_fingerprint(&self) -> Option<u64> {
            self.inner.state_fingerprint()
        }
        fn export_state(&self, state: &Self::State, lane: usize)
                        -> Result<SessionState> {
            self.inner.export_state(state, lane)
        }
        fn import_state(&self, state: &mut Self::State, lane: usize,
                        snap: &SessionState) -> Result<()> {
            self.inner.import_state(state, lane, snap)
        }
    }

    fn flaky(seed: u64, remaining: u32, panics: bool) -> FlakyBackend {
        let model = NativeModel::init_random(&NativeInit {
            vocab_in: Some(16),
            vocab_out: 16,
            d_model: 8,
            n_layers: 1,
            ..Default::default()
        }, seed).unwrap();
        FlakyBackend {
            inner: NativeBackend::new(model),
            remaining: Cell::new(remaining),
            panics,
        }
    }

    fn greedy_run(backend: &FlakyBackend) -> ServeStats {
        let (sched, handle) = Scheduler::new(backend, SchedulerOpts {
            serve: ServeOpts { temperature: 0.0, seed: 0, max_batch: 4 },
            ..Default::default()
        }).unwrap();
        for i in 0..4u64 {
            handle.submit(Request {
                id: i,
                prompt: vec![1 + i as i32, 2, 3],
                n_tokens: 5,
                session: None,
            }).unwrap();
        }
        handle.close();
        sched.run().unwrap()
    }

    #[test]
    fn transient_decode_errors_retry_to_bit_identical_greedy_output() {
        let clean = greedy_run(&flaky(21, 0, false));
        // the first two decode steps fail; with retry_limit 2 every lane
        // is requeued twice and the third attempt carries them through
        let faulty = greedy_run(&flaky(21, 2, false));
        assert_eq!(clean.responses.len(), 4);
        assert_eq!(faulty.responses.len(), 4);
        assert!(faulty.failed.is_empty());
        assert!(faulty.retries > 0, "the failed steps must retry");
        assert_eq!(faulty.health, Health::Degraded);
        assert_eq!(clean.health, Health::Healthy);
        for c in &clean.responses {
            let f = faulty.responses.iter().find(|r| r.id == c.id)
                .expect("every request must still complete");
            assert_eq!(f.tokens, c.tokens,
                       "replayed greedy output must be bit-identical \
                        (req {})", c.id);
        }
    }

    #[test]
    fn poisoned_batches_fail_alone_after_retry_budget() {
        // quiet the default panic hook: every injected panic would
        // otherwise spray a backtrace into the test output
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(|_| {}));
        // every decode panics: all requests must fail cleanly (scheduler
        // survives, drain invariant holds) after 1 + retry_limit attempts
        let backend = flaky(3, u32::MAX, true);
        let stats = greedy_run(&backend);
        std::panic::set_hook(prev);
        assert!(stats.responses.is_empty());
        let mut failed = stats.failed.clone();
        failed.sort_unstable();
        assert_eq!(failed, vec![0, 1, 2, 3]);
        assert_eq!(stats.submitted,
                   stats.responses.len() + stats.expired.len()
                       + stats.failed.len(),
                   "drain invariant must extend to failed requests");
        assert!(stats.retries > 0);
    }
}
