//! Restart-with-backoff supervision for the serving loop.
//!
//! The scheduler already self-heals *within* a run (failed decode steps
//! retry, poisoned requests are quarantined — see
//! [`super::scheduler`]); this module covers the failure class above
//! it: the whole serving generation dying, by panic or by error, in
//! code the scheduler cannot catch.  [`supervise`] runs a
//! caller-supplied serving generation in a `catch_unwind` loop,
//! restarting it with exponential backoff (plus deterministic jitter)
//! until it completes or the restart budget is exhausted.
//!
//! The generation closure receives the restart ordinal, so the caller
//! can rebuild per-generation state (a fresh [`Scheduler`], the
//! still-unserved requests).  Warm recovery comes from composition, not
//! magic: the PR-6 session store outlives generations — the CLI path
//! (`minrnn serve --supervised`) keeps one `SessionCache` across
//! restarts (and on disk via `--session-dir`), so a restarted
//! generation warm-starts returning sessions instead of re-prefilling.
//!
//! Outcome is surfaced through [`ServeStats`]: `restarts` counts
//! recoveries, and [`Health`] is downgraded to `Degraded` after any
//! restart, or `Draining` when the budget ran out along the way (the
//! run completed, but the supervisor had stopped offering restarts).
//!
//! [`Scheduler`]: super::scheduler::Scheduler
//! [`Health`]: super::server::Health

use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::log_warn;
use crate::util::rng::splitmix64;

use super::server::{Health, ServeStats};

/// Supervision knobs (`minrnn serve --supervised`).
#[derive(Clone, Debug)]
pub struct SupervisorOpts {
    /// Crash recoveries offered before the supervisor gives up
    /// (`--max-restarts`).
    pub max_restarts: u32,
    /// First restart delay; doubles per consecutive restart (capped at
    /// `base << 6`), with deterministic jitter keyed off `seed`.
    pub backoff_base: Duration,
    /// Seed for the jitter (shared with the serve seed so a run's
    /// timing is reproducible).
    pub seed: u64,
}

impl Default for SupervisorOpts {
    fn default() -> Self {
        SupervisorOpts {
            max_restarts: 3,
            backoff_base: Duration::from_millis(50),
            seed: 0,
        }
    }
}

/// Render a `catch_unwind` payload as the panic message when it is one
/// (`panic!("...")` / `panic!(format!)` payloads are `&str` / `String`),
/// falling back to a placeholder for exotic payload types.
pub fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Delay before restart number `restart` (1-based): exponential in the
/// restart ordinal with deterministic jitter in `[0, base/2]` — the
/// same shape as the scheduler's intra-run retry backoff, one level up.
pub fn backoff_delay(base: Duration, seed: u64, restart: u32) -> Duration {
    let shift = restart.saturating_sub(1).min(6);
    let backoff = base.saturating_mul(1 << shift);
    let mut key = seed
        ^ (restart as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    let jitter_ns = if backoff.is_zero() {
        0
    } else {
        splitmix64(&mut key) % (backoff.as_nanos() as u64 / 2 + 1)
    };
    backoff + Duration::from_nanos(jitter_ns)
}

/// Run serving generations under restart supervision.  `generation(n)`
/// runs the n-th attempt (0 = first) to completion; a panic or `Err`
/// consumes one restart from the budget and re-invokes it after
/// [`backoff_delay`].  The stats of the generation that completes are
/// stamped with the restart count and the final [`Health`]:
///
/// * 0 restarts → the generation's own health (it may still be
///   `Degraded` from intra-run retries);
/// * ≥ 1 restart → at least `Degraded`;
/// * budget exhausted, then success → `Draining` (the operator should
///   expect this process to need attention);
/// * budget exhausted, then another failure → `Err`.
pub fn supervise<F>(opts: &SupervisorOpts, mut generation: F)
                    -> Result<ServeStats>
where
    F: FnMut(u32) -> Result<ServeStats>,
{
    let mut restarts = 0u32;
    loop {
        let draining = restarts >= opts.max_restarts;
        let attempt = restarts;
        let outcome =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                generation(attempt)
            }));
        let failure = match outcome {
            Ok(Ok(mut stats)) => {
                stats.restarts = restarts as usize;
                if draining {
                    stats.health = Health::Draining;
                } else if restarts > 0 && stats.health == Health::Healthy {
                    stats.health = Health::Degraded;
                }
                return Ok(stats);
            }
            Ok(Err(e)) => format!("{e:#}"),
            Err(payload) => format!("panic: {}", panic_message(payload)),
        };
        if draining {
            return Err(anyhow!(
                "supervised serve gave up after {restarts} restart(s); \
                 last failure: {failure}"));
        }
        restarts += 1;
        let delay = backoff_delay(opts.backoff_base, opts.seed, restarts);
        log_warn!("serving generation {attempt} died ({failure}); \
                   restart {restarts}/{} in {:.1}ms",
                  opts.max_restarts, delay.as_secs_f64() * 1e3);
        std::thread::sleep(delay);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn opts() -> SupervisorOpts {
        // zero base -> zero backoff: tests never sleep
        SupervisorOpts {
            max_restarts: 3,
            backoff_base: Duration::ZERO,
            seed: 7,
        }
    }

    fn stats() -> ServeStats {
        ServeStats {
            responses: Vec::new(),
            total_s: 0.0,
            tokens_generated: 0,
            submitted: 0,
            admitted: 0,
            rejected: 0,
            expired: Vec::new(),
            max_queue_depth: 0,
            batches_started: 0,
            session_hits: 0,
            session_misses: 0,
            session_evictions: 0,
            prefill_tokens_saved: 0,
            failed: Vec::new(),
            retries: 0,
            session_degraded: 0,
            restarts: 0,
            health: Health::Healthy,
        }
    }

    #[test]
    fn first_try_success_stays_healthy() {
        let got = supervise(&opts(), |n| {
            assert_eq!(n, 0);
            Ok(stats())
        }).unwrap();
        assert_eq!(got.restarts, 0);
        assert_eq!(got.health, Health::Healthy);
    }

    #[test]
    fn panics_and_errors_are_restarted_until_success() {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(|_| {}));
        let got = supervise(&opts(), |n| match n {
            0 => panic!("generation zero dies"),
            1 => Err(anyhow!("generation one errors")),
            n => {
                assert_eq!(n, 2);
                Ok(stats())
            }
        });
        std::panic::set_hook(prev);
        let got = got.unwrap();
        assert_eq!(got.restarts, 2);
        assert_eq!(got.health, Health::Degraded,
                   "a restarted run must not report Healthy");
    }

    #[test]
    fn budget_exhaustion_drains_then_gives_up() {
        // success on the post-budget attempt completes as Draining
        let got = supervise(&opts(), |n| {
            if n < 3 {
                Err(anyhow!("still failing"))
            } else {
                Ok(stats())
            }
        }).unwrap();
        assert_eq!(got.restarts, 3);
        assert_eq!(got.health, Health::Draining);
        // one more failure past the budget is terminal
        let err = supervise(&opts(), |_| -> Result<ServeStats> {
            Err(anyhow!("hopeless"))
        }).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("gave up after 3 restart(s)")
                    && msg.contains("hopeless"),
                "unhelpful error: {msg}");
    }

    #[test]
    fn backoff_is_exponential_deterministic_and_capped() {
        let base = Duration::from_millis(10);
        let d1 = backoff_delay(base, 42, 1);
        let d4 = backoff_delay(base, 42, 4);
        assert!(d1 >= base && d1 <= base * 3 / 2);
        assert!(d4 >= base * 8 && d4 <= base * 12);
        // deterministic: same inputs, same delay
        assert_eq!(d4, backoff_delay(base, 42, 4));
        // capped at base << 6 (plus jitter)
        let d99 = backoff_delay(base, 42, 99);
        assert!(d99 <= base * 64 * 3 / 2);
        assert_eq!(backoff_delay(Duration::ZERO, 1, 5), Duration::ZERO);
    }

    #[test]
    fn panic_payloads_render_as_messages() {
        assert_eq!(panic_message(Box::new("static str")), "static str");
        assert_eq!(panic_message(Box::new(String::from("owned"))), "owned");
        assert_eq!(panic_message(Box::new(17u32)),
                   "non-string panic payload");
    }
}
