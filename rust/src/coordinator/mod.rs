//! Coordinator: CLI entrypoints, training orchestration ([`trainer`]),
//! the inference engine ([`infer`]), the serving stack ([`server`] for
//! the [`server::ServeConfig`] facade, [`scheduler`] for async
//! admission-controlled serving, [`session_cache`] for constant-state
//! session warm-starts, [`supervisor`] for restart-with-backoff serve
//! supervision, [`shard`] for consistent-hash-routed multi-replica
//! serving, [`http`] for the dependency-free network front-end), and
//! the experiment registry.

pub mod http;
pub mod infer;
pub mod scheduler;
pub mod server;
pub mod session_cache;
pub mod shard;
pub mod supervisor;
pub mod trainer;

use std::cell::RefCell;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{anyhow, bail, Result};

use crate::backend::{NativeBackend, NativeInit, NativeModel, NativeTrainer};
use crate::bench_harness::{self, Ctx};
use crate::config::TrainConfig;
use crate::data::corpus::CharVocab;
use crate::runtime::{Manifest, Model, PjrtBackend, Runtime};
use crate::util::cli::{Command, Parsed};
use crate::util::faults;
use crate::util::rng::Rng;
use crate::{log_info, log_warn};

/// Experiment registry: id → description.
pub const EXPERIMENTS: &[(&str, &str)] = &[
    ("fig1", "training runtime/speedup/memory vs sequence length"),
    ("tab1", "layers vs accuracy on Selective Copying"),
    ("tab2", "Selective Copying vs modern baselines"),
    ("tab3", "offline RL (D4RL-style), expert-normalized scores"),
    ("fig2", "character LM learning curves"),
    ("tab45", "Chomsky Hierarchy + Long Range Arena"),
    ("tab6", "architecture ablation on ListOps"),
    ("fig3", "inference runtime with context tokens"),
    ("fig4", "decode-step runtime, minimal vs traditional RNNs"),
    ("fig5", "minLSTM forget-gate bias initialization"),
];

pub fn run_experiment(ctx: &Ctx, id: &str) -> Result<()> {
    match id {
        "fig1" => bench_harness::fig1::run(ctx),
        "tab1" => bench_harness::selective::run_tab1(ctx),
        "tab2" => bench_harness::selective::run_tab2(ctx),
        "tab3" => bench_harness::rl::run(ctx),
        "fig2" => bench_harness::lm::run_fig2(ctx),
        "tab45" => bench_harness::chomsky_lra::run_tab45(ctx),
        "tab6" => bench_harness::chomsky_lra::run_tab6(ctx),
        "fig3" => bench_harness::inference::run_fig3(ctx),
        "fig4" => bench_harness::inference::run_fig4(ctx),
        "fig5" => bench_harness::lm::run_fig5(ctx),
        other => Err(anyhow!("unknown experiment '{other}'; known: {}",
                             EXPERIMENTS.iter().map(|(n, _)| *n)
                             .collect::<Vec<_>>().join(", "))),
    }
}

// ---------------------------------------------------------------------------
// CLI
// ---------------------------------------------------------------------------

const USAGE: &str = "minrnn — Were RNNs All We Needed? (minGRU/minLSTM)

Subcommands:
  list                         list artifact variants
  info <variant>               show a variant's manifest entry
  train <variant|workload>     train a variant (pjrt) or workload (native)
  generate [variant]           sample text from a (trained) LM variant
  serve [variant]              dynamic-batching serving demo (--async for
                               the admission-queue scheduler)
  rollout <env>                roll out a trained RL policy (native)
  quantize <ckpt>              convert a checkpoint's dense weights to
                               per-tile int8 (inference-only)
  bench                        native-backend throughput benchmark
  compare <workload>           train every mixer kind (mingru, minlstm,
                               s6lite, transformer) on one workload and
                               print the paper-style comparison table
  experiment <id>|all          regenerate a paper table/figure
  experiments                  list experiment ids
  perf <variant>               profile the train-step hot path (L3 vs XLA)

`train`, `generate`, and `serve` take `--backend pjrt|native`: `pjrt`
runs the AOT XLA artifacts; `native` runs the pure-Rust CPU
implementation and needs no artifacts.  Native training
(`train --backend native <workload>`) runs the log-space scan VJP + AdamW
in Rust on the full workload matrix — char_lm / random_tokens /
selective_copy / chomsky/<task> (masked CE), lra/<task> (pooled
classification), rl/<env> (masked-MSE action regression) — with
`--dropout` honored on the residual branches; native inference loads
weights with --resume or samples from a seeded random init sized by
--kind/--layers/--d-model/--expansion (`--kind` selects the sequence
mixer: mingru | minlstm | s6lite | transformer; the transformer also
takes --max-len/--n-heads and keeps O(context) per-lane KV state, the
recurrent kinds keep O(1) state).  `rollout` drives a
natively-trained rl/<env> checkpoint in its live environment
(Decision-Transformer-style serving).  `quantize <ckpt>` rewrites a
native checkpoint's dense weights as per-tile-scaled int8 (default
output `<ckpt>.int8.ckpt`), self-checks the quantized logits against
the f32 source on a seeded probe batch, and refuses to emit a
checkpoint over the error budget; quantized checkpoints serve and
generate normally (state/cache stays f32) but cannot resume training.
`train`, `generate`, `serve`, and
`bench` take `--threads N` (or MINRNN_THREADS) to size the native thread
pool; `serve` takes `--max-batch` to cap lockstep decode lanes.
`serve --async` routes the synthetic workload through the admission
scheduler instead of handing it over up front: an open-loop driver thread
submits at `--arrival-rate` req/s into a `--queue-depth`-bounded queue
(`--backpressure block|reject`, optional `--deadline-ms` queue-wait
budget) while the decode loop admits requests into free lanes mid-flight.
`serve --session-cache-mb N` attaches the constant-state session cache
(minGRU/minLSTM decode state is a few KB, O(1) in context): lanes
warm-start from cached states covering a verified prompt prefix and skip
that prefix's prefill; `--sessions K` tags the synthetic workload with K
round-robin conversation ids, `--session-dir P` persists the cache across
runs, and the hit/miss/evict counters land in the serve report.
`serve --http HOST:PORT` (native backend only) puts the serving tier on
the network instead of running a synthetic workload: `--replicas N`
scheduler replicas (one model + session cache each) behind a
consistent-hash router keyed on the session id, fronted by a
dependency-free HTTP/1.1 server exposing POST /v1/submit,
GET /v1/stats, GET /v1/health, POST /v1/reload (rolling checkpoint
hot-swap with zero dropped requests), and POST /v1/shutdown (graceful
drain).  All serve entrypoints — flag-driven and HTTP — parse into the
same ServeConfig, so they are one code path.

Robustness: native training with `--checkpoint <dir> --checkpoint-every N`
commits a crash-recovery checkpoint (fsync'd, CRC-trailered) to a ring of
`--keep-checkpoints` files every N steps; `--resume <dir>` resumes from
the newest checkpoint in the ring that still validates, skipping torn or
corrupt files.  The async scheduler retries transiently-failing decode
steps (`--retry-limit`, exponential backoff) and quarantines requests
that keep failing so they fail alone; `serve --supervised` additionally
restarts a crashed serving run up to `--max-restarts` times,
warm-recovering sessions from the session cache.  `--faults <spec>` (or
MINRNN_FAULTS) installs a deterministic fault-injection plan for chaos
testing, e.g. `seed=7,io_write=@3,decode=0.01` — see src/util/faults.rs
for the grammar.
Run `minrnn <subcommand> --help` for options.";

pub fn cli_main(args: Vec<String>) -> i32 {
    crate::util::logging::init();
    match faults::init_from_env().and_then(|()| dispatch(args)) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("{e:#}");
            1
        }
    }
}

fn dispatch(args: Vec<String>) -> Result<()> {
    let Some(sub) = args.first().cloned() else {
        println!("{USAGE}");
        return Ok(());
    };
    let rest = &args[1..];
    match sub.as_str() {
        "list" => cmd_list(rest),
        "info" => cmd_info(rest),
        "train" => cmd_train(rest),
        "generate" => cmd_generate(rest),
        "serve" => cmd_serve(rest),
        "rollout" => cmd_rollout(rest),
        "quantize" => cmd_quantize(rest),
        "bench" => cmd_bench(rest),
        "compare" => cmd_compare(rest),
        "experiment" => cmd_experiment(rest),
        "perf" => cmd_perf(rest),
        "experiments" => {
            for (id, desc) in EXPERIMENTS {
                println!("{id:8} {desc}");
            }
            Ok(())
        }
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => Err(anyhow!("unknown subcommand '{other}'\n\n{USAGE}")),
    }
}

fn artifacts_opt(cmd: Command) -> Command {
    cmd.opt("artifacts", Some("artifacts"), "artifacts directory")
}

/// Open the artifact manifest.  A non-default `--artifacts` path wins;
/// the default `artifacts` falls back to `$MINRNN_ARTIFACTS` when set
/// (an explicit `--artifacts artifacts` is indistinguishable from the
/// default and gets the same fallback).  Missing manifests produce the
/// remedy message instead of a raw file-not-found.
fn open_manifest(dir: &str) -> Result<Rc<Manifest>> {
    use crate::runtime::backend as rtb;
    let root = if dir == "artifacts" {
        rtb::artifacts_root()
    } else {
        PathBuf::from(dir)
    };
    if !rtb::artifacts_available_at(&root) {
        return Err(anyhow!("looked in {}: {}", root.display(),
                           crate::runtime::ARTIFACTS_HELP));
    }
    Ok(Rc::new(Manifest::load(&root)?))
}

fn cmd_list(args: &[String]) -> Result<()> {
    let cmd = artifacts_opt(Command::new("list", "list artifact variants"));
    let p = cmd.parse(args)?;
    let manifest = open_manifest(p.req("artifacts")?)?;
    println!("{:30} {:8} {:>7} {:>8} {:>10}",
             "variant", "group", "batch", "seq_len", "params");
    for v in manifest.variants.values() {
        println!("{:30} {:8} {:>7} {:>8} {:>10}",
                 v.name, v.group, v.batch, v.seq_len, v.param_elements());
    }
    Ok(())
}

fn cmd_info(args: &[String]) -> Result<()> {
    let cmd = artifacts_opt(Command::new("info", "show variant details"))
        .positional("variant", "variant name");
    let p = cmd.parse(args)?;
    let manifest = open_manifest(p.req("artifacts")?)?;
    let name = p.pos.first()
        .ok_or_else(|| anyhow!("usage: minrnn info <variant>"))?;
    let v = manifest.variant(name)?;
    println!("variant   {}", v.name);
    println!("group     {}", v.group);
    println!("task      {}", v.task);
    println!("workload  {}", v.workload_kind());
    println!("batch     {}   seq_len {}", v.batch, v.seq_len);
    println!("params    {} leaves, {} elements",
             v.n_params(), v.param_elements());
    println!("depth     parallel {}  sequential {}",
             v.depth_parallel, v.depth_sequential);
    println!("files:");
    println!("  init    {}", v.init_file);
    if let Some(t) = &v.train_file {
        println!("  train   {t}");
    }
    for e in &v.eval_files {
        println!("  eval    {} (b{} t{})", e.file, e.batch, e.seq_len);
    }
    for s in &v.step_files {
        println!("  step    {} (b{})", s.file, s.batch);
    }
    for f in &v.prefill_files {
        println!("  prefill {} (b{} t{})", f.file, f.batch, f.seq_len);
    }
    Ok(())
}

fn train_command() -> Command {
    artifacts_opt(Command::new("train", "train a variant on its workload"))
        .opt("steps", Some("200"), "optimizer steps")
        .opt("lr", Some("0.001"), "peak learning rate")
        .opt("seed", Some("0"), "seed")
        .opt("forget-bias", Some("0"), "minLSTM forget-gate bias init")
        .opt("dropout", Some("0"),
             "residual-branch dropout rate (native backend; 0 = off)")
        .opt("eval-every", Some("50"), "steps between evals (0 = off)")
        .opt("checkpoint", None, "directory for checkpoints")
        .opt("checkpoint-every", Some("0"),
             "native: commit a crash-recovery checkpoint to the retained \
              ring every N steps (0 = only best/final)")
        .opt("keep-checkpoints", Some("3"),
             "native: ring checkpoints retained (best/final kept \
              separately)")
        .opt("resume", None,
             "checkpoint file to resume from (native: a directory resumes \
              from its newest valid ring checkpoint)")
        .opt("faults", None,
             "deterministic fault-injection spec for chaos testing, e.g. \
              seed=7,io_write=@3 (see src/util/faults.rs)")
        .opt("config", None, "JSON config file (CLI overrides it)")
        .flag("constant-lr", "disable warmup+cosine schedule")
        .opt("backend", None,
             "training backend: pjrt | native (default: config file \
              `backend` key, else pjrt)")
        .opt("batch", Some("32"), "native: batch size")
        .opt("seq-len", Some("64"), "native: sequence length")
        .opt("kind", Some("mingru"), "native fresh-init mixer: \
             mingru | minlstm | s6lite | transformer")
        .opt("layers", Some("2"), "native fresh-init layer count")
        .opt("d-model", Some("64"), "native fresh-init residual width")
        .opt("expansion", Some("1"), "native fresh-init hidden expansion")
        .opt("max-len", Some("0"),
             "transformer: positional table / KV-cache capacity \
              (0 = seq-len)")
        .opt("n-heads", Some("4"),
             "transformer: attention heads (must divide d-model)")
        .flag("conv", "native fresh-init: temporal conv4 per block")
        .flag("mlp", "native fresh-init: MLP per block")
        .opt("threads", None,
             "native thread-pool size (default: MINRNN_THREADS, else all \
              cores)")
        .positional("variant", "artifact variant (pjrt) or workload \
                     (native: char_lm, random_tokens, selective_copy, \
                     chomsky/<task>, lra/<task>, rl/<env>)")
}

/// Build the workload data source for a variant from its manifest entry.
pub fn data_source_for(v: &crate::runtime::Variant)
                       -> Result<Box<dyn trainer::DataSource>> {
    data_source(&v.workload_kind(), v.batch, v.seq_len, Some(&v.workload))
}

/// Build a data source from a workload kind alone (`char_lm`,
/// `random_tokens`, `selective_copy`, `chomsky/<task>`, `lra/<task>`,
/// `rl/<env>`).  `workload` carries optional manifest extras (vocab,
/// ctx_len, ...); without it, shape-dependent defaults are derived from
/// `(b, t)` — this is the path `minrnn train --backend native` uses, where
/// no artifact manifest exists.
pub fn data_source(kind: &str, b: usize, t: usize,
                   workload: Option<&crate::util::json::Json>)
                   -> Result<Box<dyn trainer::DataSource>> {
    use crate::data::{chomsky, random_tokens, rl, selective_copy};
    let extra = |key: &str| workload.and_then(|w| w.get(key));
    if kind == "char_lm" {
        let src = bench_harness::lm::LmSource::new(b, t);
        return Ok(Box::new(src));
    }
    if kind == "random_tokens" {
        let vocab = extra("vocab").and_then(|x| x.as_i64())
            .unwrap_or(16) as i32;
        return Ok(Box::new(trainer::FnSource {
            f: move |rng: &mut Rng| random_tokens::batch(rng, b, t, vocab),
        }));
    }
    if kind == "selective_copy" {
        // default geometry: 16 data tokens (the paper's setup) inside the
        // configured sequence length
        let n_data = extra("n_data").and_then(|x| x.as_usize())
            .unwrap_or_else(|| 16.min((t / 2).max(1)));
        if t <= n_data {
            bail!("selective_copy needs seq_len > n_data ({t} <= {n_data})");
        }
        let ctx_len = extra("ctx_len").and_then(|x| x.as_usize())
            .unwrap_or(t - n_data);
        let task = selective_copy::SelectiveCopy::new(ctx_len, n_data);
        return Ok(Box::new(trainer::FnSource {
            f: move |rng: &mut Rng| task.batch(rng, b),
        }));
    }
    if let Some(task_name) = kind.strip_prefix("chomsky/") {
        let task = chomsky::by_name(task_name)
            .ok_or_else(|| anyhow!("unknown chomsky task {task_name}"))?;
        return Ok(Box::new(trainer::FnSource {
            f: move |rng: &mut Rng| {
                let max_c = task.max_content_for(t);
                chomsky::batch(task.as_ref(), rng, b, t, 1, max_c)
            },
        }));
    }
    if let Some(task_name) = kind.strip_prefix("lra/") {
        // LraSource derives generator sizes from t; a too-short sequence
        // must fail here, not as a usize underflow mid-loop
        let min_t = bench_harness::chomsky_lra::LraSource
            ::min_seq_len(task_name);
        if t < min_t {
            bail!("lra/{task_name} needs seq_len >= {min_t} (got {t})");
        }
        let src = bench_harness::chomsky_lra::LraSource {
            kind: task_name.to_string(),
            batch: b,
            t,
        };
        return Ok(Box::new(src));
    }
    if let Some(env) = kind.strip_prefix("rl/") {
        let ds = rl::OfflineDataset::build(env, rl::Regime::Medium,
                                           RL_EPISODES, RL_SEED);
        return Ok(Box::new(trainer::FnSource {
            f: move |rng: &mut Rng| ds.batch(rng, b, t),
        }));
    }
    Err(anyhow!("no data source for workload '{kind}'"))
}

/// Offline-RL dataset defaults shared by `train --backend native rl/<env>`
/// and `minrnn rollout`, so a rollout rebuilds the exact normalization
/// statistics the training batches used.
pub const RL_EPISODES: usize = 100;
pub const RL_SEED: u64 = 0;

/// What a workload needs from the native trainer: which fused loss head,
/// the input layer (token embedding or continuous projection), and the
/// output width.  This is the native stand-in for a manifest entry's
/// `task`/`workload` fields — derived from the workload name alone, so
/// `minrnn train --backend native` works from nothing.
#[derive(Clone, Debug)]
pub struct WorkloadSpec {
    pub head: crate::backend::Head,
    /// Token vocabulary for discrete inputs.
    pub vocab_in: Option<usize>,
    /// Feature width for continuous inputs (RL).
    pub input_dim: Option<usize>,
    /// Head width: vocabulary, class count, or action dimension.
    pub out_dim: usize,
}

/// Resolve the [`WorkloadSpec`] of a native-trainable workload, or a
/// clear up-front error naming the supported set — the train loop must
/// never discover an unsupported combination mid-step as a dtype bail.
pub fn native_workload(kind: &str) -> Result<WorkloadSpec> {
    use crate::backend::Head;
    let discrete = |vocab: usize| WorkloadSpec {
        head: Head::MaskedCe,
        vocab_in: Some(vocab),
        input_dim: None,
        out_dim: vocab,
    };
    if kind == "char_lm" {
        return Ok(discrete(CharVocab::new().size()));
    }
    // selective_copy, chomsky/*, and random_tokens all use the shared
    // 16-symbol token map
    if kind == "selective_copy" || kind == "random_tokens"
        || kind.starts_with("chomsky/") {
        return Ok(discrete(16));
    }
    if let Some(task) = kind.strip_prefix("lra/") {
        let (vocab_in, n_classes) = crate::data::lra::task_dims(task)
            .ok_or_else(|| anyhow!(
                "unknown LRA task '{task}' (expected listops, retrieval, \
                 or gimage)"))?;
        return Ok(WorkloadSpec {
            head: Head::SeqClassify,
            vocab_in: Some(vocab_in),
            input_dim: None,
            out_dim: n_classes,
        });
    }
    if let Some(env_name) = kind.strip_prefix("rl/") {
        let env = crate::data::rl::envs::by_name(env_name)
            .ok_or_else(|| anyhow!(
                "unknown RL env '{env_name}' (expected pointmass, \
                 pendulum, or walker1d)"))?;
        return Ok(WorkloadSpec {
            head: Head::MaskedMse,
            vocab_in: None,
            // DT features per step: [rtg, obs (normalized), prev action]
            input_dim: Some(1 + env.obs_dim() + env.act_dim()),
            out_dim: env.act_dim(),
        });
    }
    Err(anyhow!(
        "train --backend native supports char_lm, random_tokens, \
         selective_copy, chomsky/<task>, lra/<task>, and rl/<env> \
         workloads (got '{kind}')"))
}

impl WorkloadSpec {
    /// Check a model (fresh init or `--resume`d checkpoint) against this
    /// workload before the first step, so mismatches surface as one clear
    /// error instead of a mid-loop dtype/shape failure.
    pub fn validate(&self, model: &NativeModel, workload: &str)
                    -> Result<()> {
        use crate::backend::native::model::InputLayer;
        match (&model.input, self.vocab_in, self.input_dim) {
            (InputLayer::Embed(e), Some(v), _) => {
                if e.vocab < v {
                    bail!("workload '{workload}' uses {v} token ids but \
                           the model embeds only {}; retrain or resume a \
                           matching checkpoint", e.vocab);
                }
            }
            (InputLayer::Proj(p), _, Some(f)) => {
                if p.d_in != f {
                    bail!("workload '{workload}' feeds {f}-dim features \
                           but the model projects {}-dim inputs", p.d_in);
                }
            }
            (InputLayer::Embed(_), None, _) => bail!(
                "workload '{workload}' ({} head) feeds continuous \
                 features, but the model embeds discrete tokens — its \
                 checkpoint was trained for a token workload", self.head),
            (InputLayer::Proj(_), Some(_), _) => bail!(
                "workload '{workload}' feeds discrete tokens, but the \
                 model projects continuous features — its checkpoint was \
                 trained for an rl/* workload"),
            _ => unreachable!("spec has vocab_in or input_dim"),
        }
        let need_exact = matches!(self.head,
                                  crate::backend::Head::MaskedMse
                                  | crate::backend::Head::SeqClassify);
        if (need_exact && model.vocab_out != self.out_dim)
            || model.vocab_out < self.out_dim {
            bail!("workload '{workload}' needs a {}-wide {} head but the \
                   model head is {}-wide", self.out_dim, self.head,
                  model.vocab_out);
        }
        Ok(())
    }
}

fn cmd_train(args: &[String]) -> Result<()> {
    let p = train_command().parse(args)?;
    apply_faults_opt(&p)?;
    let mut cfg = TrainConfig::default();
    cfg.apply_cli(&p)?;
    let variant = p.pos.first()
        .ok_or_else(|| anyhow!("usage: minrnn train <variant|workload>"))?
        .clone();
    cfg.variant = variant.clone();
    cfg.artifacts = PathBuf::from(p.req("artifacts")?);

    let backend = cfg.backend.clone();
    let report = match backend.as_str() {
        "native" => {
            apply_threads_opt(&p)?;
            let spec = native_workload(&variant)?;
            let mut nt = native_trainer(&p, &cfg, &variant, &spec)?;
            let mut data = data_source(&variant, p.usize("batch")?,
                                       p.usize("seq-len")?, None)?;
            trainer::run_loop(&mut nt, &cfg, 0, data.as_mut())?
        }
        "pjrt" => {
            if cfg.dropout > 0.0 {
                return Err(anyhow!(
                    "--dropout {} has no effect with --backend pjrt: the \
                     artifact's train step bakes its dropout rate in at \
                     export time (python/compile/exports.py) — re-export \
                     the variant, or train with --backend native",
                    cfg.dropout));
            }
            let rt = Runtime::cpu()?;
            let manifest = open_manifest(cfg.artifacts.to_str().unwrap())?;
            let model = Model::open(&rt, manifest, &variant)?;
            let mut data = data_source_for(&model.variant)?;
            let mut state = match &cfg.resume {
                Some(path) => model.load_checkpoint(path)?,
                None => model.init(cfg.seed as i32, cfg.forget_bias)?,
            };
            let trainer = trainer::Trainer::new(&model, cfg);
            trainer.run(&mut state, data.as_mut())?
        }
        other => return Err(anyhow!(
            "unknown backend '{other}' (expected pjrt | native)")),
    };
    log_info!("done: final loss {:.4}, best eval {:.4} @ step {}, \
               {:.2} steps/s",
              report.final_loss, report.best_eval_loss,
              report.best_eval_step, report.steps_per_sec);
    Ok(())
}

/// Build the native trainer for `cmd_train`: resume a full training
/// checkpoint (params + Adam moments) or start from a seeded random init
/// sized by the workload's [`WorkloadSpec`]; either way the model is
/// validated against the workload before the first step, and the spec's
/// head plus the configured dropout rate are installed.
fn native_trainer(p: &Parsed, cfg: &TrainConfig, workload: &str,
                  spec: &WorkloadSpec) -> Result<NativeTrainer> {
    let mut nt = match &cfg.resume {
        Some(path) => resume_native(path, workload)?,
        None => {
            let init = NativeInit {
                kind: p.req("kind")?.to_string(),
                n_layers: p.usize("layers")?,
                d_model: p.usize("d-model")?,
                expansion: p.usize("expansion")?,
                vocab_in: spec.vocab_in,
                input_dim: spec.input_dim,
                vocab_out: spec.out_dim,
                conv: p.flag("conv"),
                mlp: p.flag("mlp"),
                mlp_mult: 4,
                forget_bias: cfg.forget_bias,
                max_len: match p.usize("max-len")? {
                    0 => p.usize("seq-len")?,
                    n => n,
                },
                n_heads: p.usize("n-heads")?,
            };
            log_info!("native training: fresh {} init ({} layers, d={}, \
                       out={}) with the {} head on '{workload}'",
                      init.kind, init.n_layers, init.d_model, spec.out_dim,
                      spec.head);
            NativeTrainer::new(NativeModel::init_random(&init, cfg.seed)?,
                               workload)
        }
    };
    spec.validate(&nt.model, workload)?;
    nt.head = spec.head;
    nt.drop_rate = cfg.dropout;
    Ok(nt)
}

/// Resolve `--resume` for the native trainer.  A directory picks the
/// newest *valid* checkpoint for this workload via
/// [`trainer::recover_checkpoint`] (skipping torn or corrupt files); a
/// file that fails to load falls back to recovery in its parent
/// directory — a crash mid-commit must not strand a run behind one bad
/// file when the ring still holds a good one.
fn resume_native(path: &Path, workload: &str) -> Result<NativeTrainer> {
    let label = workload.replace('/', "_");
    if path.is_dir() {
        let ckpt = trainer::recover_checkpoint(path, &label)
            .ok_or_else(|| anyhow!(
                "no valid '{label}' checkpoint to resume in {}",
                path.display()))?;
        log_info!("resuming from recovered checkpoint {}", ckpt.display());
        return NativeTrainer::from_checkpoint(&ckpt, workload);
    }
    match NativeTrainer::from_checkpoint(path, workload) {
        Ok(nt) => Ok(nt),
        Err(e) => {
            let dir = path.parent()
                .filter(|d| !d.as_os_str().is_empty())
                .unwrap_or(Path::new("."));
            match trainer::recover_checkpoint(dir, &label) {
                Some(ckpt) if ckpt != *path => {
                    log_warn!("--resume {}: {e:#}; falling back to {}",
                              path.display(), ckpt.display());
                    NativeTrainer::from_checkpoint(&ckpt, workload)
                }
                _ => Err(e),
            }
        }
    }
}

/// Options shared by the backend-selectable inference subcommands.
fn backend_opts(cmd: Command) -> Command {
    cmd.opt("backend", None,
            "inference backend: pjrt | native (default: config file \
             `backend` key, else pjrt)")
        .opt("config", None, "JSON config file (`backend` key honored)")
        .opt("resume", None, "checkpoint to load (default: fresh init)")
        .opt("kind", Some("mingru"),
             "native fresh-init mixer: mingru | minlstm | s6lite | \
              transformer")
        .opt("layers", Some("2"), "native fresh-init layer count")
        .opt("d-model", Some("64"), "native fresh-init residual width")
        .opt("expansion", Some("1"), "native fresh-init hidden expansion")
        .opt("max-len", Some("256"),
             "transformer: positional table / KV-cache capacity")
        .opt("n-heads", Some("4"),
             "transformer: attention heads (must divide d-model)")
        .opt("threads", None,
             "native thread-pool size (default: MINRNN_THREADS, else all \
              cores)")
}

/// Install a `--faults` injection plan (same grammar as the
/// `MINRNN_FAULTS` environment variable, which it overrides) before the
/// command body runs.  No-op when the option is absent.
fn apply_faults_opt(p: &Parsed) -> Result<()> {
    if let Some(spec) = p.get("faults") {
        faults::install(faults::parse(spec)
            .map_err(|e| anyhow!("--faults: {e}"))?);
    }
    Ok(())
}

/// Apply `--threads N` to the native backend's global pool before any
/// kernel touches it.  No-op when the option is absent.
fn apply_threads_opt(p: &Parsed) -> Result<()> {
    if let Some(v) = p.get("threads") {
        let n: usize = v.parse()
            .map_err(|_| anyhow!("--threads expects a positive integer, \
                                  got '{v}'"))?;
        if n == 0 {
            return Err(anyhow!("--threads must be >= 1"));
        }
        let effective = crate::util::threads::set_threads(n);
        if effective != n {
            log_info!("threads capped at {effective} (pool already built)");
        }
    }
    Ok(())
}

/// Backend selection: explicit `--backend` wins, then the config file's
/// `backend` key, then "pjrt" — the standard `TrainConfig` precedence.
fn resolve_backend(p: &Parsed) -> Result<String> {
    let mut cfg = TrainConfig::default();
    cfg.apply_cli(p)?;
    Ok(cfg.backend)
}

/// A positional variant names a PJRT artifact; with the native backend it
/// would be silently ignored — refuse instead of sampling a random init
/// the user will mistake for the trained model.
fn reject_variant_for_native(p: &Parsed) -> Result<()> {
    if let Some(v) = p.pos.first() {
        return Err(anyhow!(
            "variant '{v}' selects a PJRT artifact and has no effect with \
             --backend native; drop it, and load trained weights via \
             --resume <ckpt> (default: seeded random init)"));
    }
    Ok(())
}

/// Build the native backend from --resume or a seeded random init.
fn native_backend(p: &Parsed, vocab: usize) -> Result<NativeBackend> {
    match p.get("resume") {
        Some(path) => {
            let backend = NativeBackend::from_checkpoint(Path::new(path))?;
            log_info!("native backend: loaded {} from {path} \
                       ({} state bytes/lane)",
                      backend.model.kind_summary(),
                      backend.model.lane_state_bytes());
            Ok(backend)
        }
        None => {
            let cfg = NativeInit {
                kind: p.req("kind")?.to_string(),
                n_layers: p.usize("layers")?,
                d_model: p.usize("d-model")?,
                expansion: p.usize("expansion")?,
                vocab_in: Some(vocab),
                vocab_out: vocab,
                max_len: p.usize("max-len")?,
                n_heads: p.usize("n-heads")?,
                ..Default::default()
            };
            let model = NativeModel::init_random(&cfg, p.u64("seed")?)?;
            log_info!("native backend: fresh {} init (d={}, {} state \
                       bytes/lane)",
                      model.kind_summary(), cfg.d_model,
                      model.lane_state_bytes());
            Ok(NativeBackend::new(model))
        }
    }
}

fn cmd_generate(args: &[String]) -> Result<()> {
    let cmd = backend_opts(artifacts_opt(
        Command::new("generate", "sample text from an LM variant")))
        .opt("prompt", Some("The "), "prompt text")
        .opt("tokens", Some("200"), "tokens to generate")
        .opt("temperature", Some("0.8"), "sampling temperature")
        .opt("seed", Some("0"), "sampling seed")
        .positional("variant", "LM variant (pjrt backend only)");
    let p = cmd.parse(args)?;
    apply_threads_opt(&p)?;
    let vocab = CharVocab::new();
    let prompt = vocab.encode(p.req("prompt")?);
    let mut rng = Rng::new(p.u64("seed")?);
    let out = match resolve_backend(&p)?.as_str() {
        "native" => {
            reject_variant_for_native(&p)?;
            let backend = native_backend(&p, vocab.size())?;
            infer::generate(&backend, &prompt, p.usize("tokens")?,
                            p.f32("temperature")?, &mut rng)?
        }
        "pjrt" => {
            let variant = p.pos.first().ok_or_else(
                || anyhow!("usage: minrnn generate <variant> \
                            (or --backend native)"))?;
            let rt = Runtime::cpu()?;
            let manifest = open_manifest(p.req("artifacts")?)?;
            let model = Model::open(&rt, manifest, variant)?;
            let state = match p.get("resume") {
                Some(path) => model.load_checkpoint(Path::new(path))?,
                None => model.init(p.get("seed").unwrap().parse()?, 0.0)?,
            };
            let backend = PjrtBackend::new(&model, &state.params);
            infer::generate(&backend, &prompt, p.usize("tokens")?,
                            p.f32("temperature")?, &mut rng)?
        }
        other => return Err(anyhow!(
            "unknown backend '{other}' (expected pjrt | native)")),
    };
    println!("{}{}", p.req("prompt")?, vocab.decode(&out));
    Ok(())
}

/// Synthetic serve workload.  `sessions > 0` tags requests with
/// round-robin conversation ids (`--sessions K`) so a session cache can
/// export completion states; `0` leaves them session-less.
fn synthetic_requests(rng: &mut Rng, n: usize, n_tokens: usize,
                      vocab: usize, sessions: usize)
                      -> Vec<server::Request> {
    (0..n).map(|i| server::Request {
        id: i as u64,
        prompt: (0..8 + rng.usize_below(8))
            .map(|_| rng.below(vocab as u64) as i32).collect(),
        n_tokens,
        session: if sessions > 0 {
            Some((i % sessions) as u64)
        } else {
            None
        },
    }).collect()
}

fn report_serve(stats: &server::ServeStats) {
    println!("served {} requests / {} tokens in {:.2}s",
             stats.responses.len(), stats.tokens_generated, stats.total_s);
    println!("throughput {:.1} tok/s, mean latency {:.1} ms \
              (queue {:.1} + decode {:.1}), p95 {:.1} ms",
             stats.throughput_tok_s(), stats.mean_latency_s() * 1e3,
             stats.mean_queue_s() * 1e3, stats.mean_service_s() * 1e3,
             stats.p95_latency_s() * 1e3);
    println!("admission: {} submitted, {} admitted, {} rejected, {} \
              expired, peak queue depth {}, {} batch(es) formed",
             stats.submitted, stats.admitted, stats.rejected,
             stats.expired.len(), stats.max_queue_depth,
             stats.batches_started);
    let mut batches: Vec<usize> = stats.responses.iter().map(|r| r.batch)
        .collect();
    batches.sort_unstable();
    batches.dedup();
    println!("batch sizes used: {batches:?}");
    if stats.session_hits + stats.session_misses > 0 {
        println!("session cache: {} hits / {} lookups, {} prefill tokens \
                  saved, {} evictions",
                 stats.session_hits,
                 stats.session_hits + stats.session_misses,
                 stats.prefill_tokens_saved, stats.session_evictions);
    }
    if stats.retries > 0 || !stats.failed.is_empty()
        || stats.session_degraded > 0 || stats.restarts > 0 {
        println!("recovery: {} retried decode attempt(s), {} failed \
                  request(s), {} degraded session import(s), {} \
                  supervisor restart(s)",
                 stats.retries, stats.failed.len(),
                 stats.session_degraded, stats.restarts);
    }
    println!("health: {}", stats.health);
}

/// Drive the async scheduler with an open-loop arrival process: a
/// submitter thread feeds `requests` through a [`scheduler::SubmitHandle`]
/// at `--arrival-rate` req/s (0 = as fast as possible) while the decode
/// loop runs on this thread — the backend (PJRT handles are not `Send`)
/// never crosses threads, only plain-data requests do.
fn serve_async<B: crate::runtime::Backend>(
    backend: &B, requests: Vec<server::Request>, cfg: &server::ServeConfig,
    cache: Option<&RefCell<session_cache::SessionCache>>, rate: f64)
    -> Result<server::ServeStats> {
    if rate < 0.0 {
        return Err(anyhow!("--arrival-rate must be >= 0"));
    }
    // open-loop serving: provision the full lane budget up front so
    // requests trickling in one by one still share a batch
    let mut opts = cfg.scheduler_opts();
    if opts.lanes.is_none() {
        opts.lanes = Some(cfg.max_batch);
    }
    let queue_depth = opts.queue_depth;
    let backpressure = opts.backpressure;
    let (mut sched, handle) = scheduler::Scheduler::new(backend, opts)?;
    if let Some(c) = cache {
        sched.set_session_cache(c);
    }
    let n = requests.len();
    log_info!("async serving: {n} requests, arrival rate {} req/s, queue \
               depth {queue_depth}, {backpressure:?} backpressure",
              if rate > 0.0 { format!("{rate:.1}") }
              else { "max".to_string() });
    let submitter = std::thread::spawn(move || {
        let mut refused = 0usize;
        for req in requests {
            if rate > 0.0 {
                std::thread::sleep(
                    std::time::Duration::from_secs_f64(1.0 / rate));
            }
            match handle.submit(req) {
                Ok(()) => {}
                Err(scheduler::SubmitError::QueueFull(_)) => refused += 1,
                Err(_) => break, // closed underneath us: stop submitting
            }
        }
        handle.close();
        refused
    });
    let stats = sched.run()?;
    let refused = submitter.join()
        .map_err(|_| anyhow!("submitter thread panicked"))?;
    debug_assert_eq!(refused, stats.rejected,
                     "producer- and scheduler-side reject counts agree");
    Ok(stats)
}

/// `serve --supervised`: run [`serve_async`] generations under
/// [`supervisor::supervise`].  A generation that dies (panic or error
/// anywhere the scheduler's own self-healing cannot reach) returns
/// nothing, so the next generation resubmits the full request list; the
/// session cache is shared across generations (and across processes via
/// `--session-dir`), so requests the dead generation completed
/// warm-start from their exported states instead of re-prefilling.
fn serve_supervised<B: crate::runtime::Backend>(
    backend: &B, requests: Vec<server::Request>, cfg: &server::ServeConfig,
    cache: Option<&RefCell<session_cache::SessionCache>>, rate: f64,
    max_restarts: u32) -> Result<server::ServeStats> {
    let sup = supervisor::SupervisorOpts {
        max_restarts,
        seed: cfg.seed,
        ..Default::default()
    };
    supervisor::supervise(&sup, |generation| {
        if generation > 0 {
            log_info!("serving generation {generation}: resubmitting {} \
                       request(s)", requests.len());
        }
        serve_async(backend, requests.clone(), cfg, cache, rate)
    })
}

fn cmd_serve(args: &[String]) -> Result<()> {
    let cmd = backend_opts(artifacts_opt(
        Command::new("serve", "dynamic-batching serving demo")))
        .opt("requests", Some("24"), "number of synthetic requests")
        .opt("tokens", Some("16"), "tokens per request")
        .opt("max-batch", Some("64"), "max lanes decoded in lockstep")
        .opt("seed", Some("0"), "seed")
        .flag("async", "serve through the async admission scheduler: an \
              open-loop driver thread submits requests while decode runs")
        .opt("queue-depth", Some("32"), "async: admission queue capacity")
        .opt("backpressure", Some("block"),
             "async: producer behavior on a full queue (block | reject)")
        .opt("arrival-rate", Some("0"),
             "async: open-loop arrival rate in requests/sec (0 = submit \
              as fast as possible)")
        .opt("deadline-ms", Some("0"),
             "async: per-request queue-wait deadline in ms (0 = none); \
              requests still queued past it are dropped, not half-served")
        .opt("retry-limit", Some("2"),
             "async: decode retries per request beyond its first attempt \
              before it is failed (transient errors requeue + replay)")
        .flag("supervised",
              "run the async scheduler under restart supervision: a \
               crashed serving run restarts with backoff, warm-recovering \
               sessions from the session cache (implies --async)")
        .opt("max-restarts", Some("3"),
             "supervised: restarts offered before the supervisor gives up")
        .opt("faults", None,
             "deterministic fault-injection spec for chaos testing, e.g. \
              seed=7,decode=0.01 (see src/util/faults.rs)")
        .opt("temperature", Some("0.8"),
             "sampling temperature (0 = greedy; required for warm-run \
              output to be bit-identical to a cold run)")
        .opt("session-cache-mb", Some("0"),
             "session-cache byte budget in MiB (0 = cache off unless \
              --session-dir is set)")
        .opt("session-dir", None,
             "directory to persist the session cache across runs \
              (loads <dir>/sessions.mrsc on start, saves it on exit)")
        .opt("sessions", Some("0"),
             "tag synthetic requests with this many round-robin \
              conversation ids (0 = session-less)")
        .flag("print-responses",
              "print each response's tokens (sorted by request id), for \
               comparing runs")
        .opt("http", None,
             "serve over HTTP on this address (host:port; native backend \
              only): --replicas scheduler replicas behind a \
              consistent-hash session router, with POST /v1/submit, GET \
              /v1/stats, GET /v1/health, POST /v1/reload (rolling \
              checkpoint hot-swap), POST /v1/shutdown")
        .opt("replicas", Some("2"),
             "http: scheduler replicas (one model + session cache each)")
        .positional("variant", "LM variant (pjrt backend only)");
    let p = cmd.parse(args)?;
    apply_threads_opt(&p)?;
    // every serve mode — sync, async, supervised, HTTP — parses into the
    // same ServeConfig (which also installs --faults); one code path
    let cfg = server::ServeConfig::from_cli(&p)?;
    if let Some(addr) = p.get("http") {
        return cmd_serve_http(&p, &cfg, addr);
    }
    let n = p.usize("requests")?;
    let n_tokens = p.usize("tokens")?;
    let supervised = p.flag("supervised");
    let is_async = p.flag("async") || supervised;
    let sessions = p.usize("sessions")?;
    let rate = p.f64("arrival-rate")?;
    let max_restarts = p.u64("max-restarts")? as u32;
    let cache = cfg.open_session_cache("sessions").map(RefCell::new);
    let mut rng = Rng::new(cfg.seed);
    let stats = match resolve_backend(&p)?.as_str() {
        "native" => {
            reject_variant_for_native(&p)?;
            let backend = native_backend(&p, CharVocab::new().size())?;
            let requests = synthetic_requests(
                &mut rng, n, n_tokens, backend.model.vocab_out, sessions);
            if supervised {
                serve_supervised(&backend, requests, &cfg, cache.as_ref(),
                                 rate, max_restarts)?
            } else if is_async {
                serve_async(&backend, requests, &cfg, cache.as_ref(), rate)?
            } else {
                cfg.run_with_cache(&backend, requests, cache.as_ref())?
            }
        }
        "pjrt" => {
            let variant = p.pos.first().ok_or_else(
                || anyhow!("usage: minrnn serve <variant> \
                            (or --backend native)"))?;
            let rt = Runtime::cpu()?;
            let manifest = open_manifest(p.req("artifacts")?)?;
            let model = Model::open(&rt, manifest, variant)?;
            let state = match p.get("resume") {
                Some(path) => model.load_checkpoint(Path::new(path))?,
                None => model.init(0, 0.0)?,
            };
            let vocab = model.variant.cfg_usize("vocab_in").unwrap_or(64);
            let requests = synthetic_requests(&mut rng, n, n_tokens, vocab,
                                              sessions);
            let backend = PjrtBackend::new(&model, &state.params);
            // the PJRT backend has no state export; an attached cache
            // stays inert and every request falls back to prefill
            if supervised {
                serve_supervised(&backend, requests, &cfg, cache.as_ref(),
                                 rate, max_restarts)?
            } else if is_async {
                serve_async(&backend, requests, &cfg, cache.as_ref(), rate)?
            } else {
                cfg.run_with_cache(&backend, requests, cache.as_ref())?
            }
        }
        other => return Err(anyhow!(
            "unknown backend '{other}' (expected pjrt | native)")),
    };
    if let Some(c) = &cache {
        cfg.save_session_cache("sessions", &c.borrow())?;
    }
    report_serve(&stats);
    if p.flag("print-responses") {
        let mut responses: Vec<_> = stats.responses.iter().collect();
        responses.sort_by_key(|r| r.id);
        for r in responses {
            let toks: Vec<String> =
                r.tokens.iter().map(|t| t.to_string()).collect();
            println!("response {}: {}", r.id, toks.join(" "));
        }
    }
    Ok(())
}

/// `minrnn serve --http HOST:PORT`: the network serving tier.  Builds a
/// [`shard::ModelSource`] from the CLI (checkpoint or seeded fresh init),
/// stands up `--replicas` scheduler replicas behind the consistent-hash
/// session router, and blocks in the HTTP accept loop until a client
/// POSTs `/v1/shutdown`.  Native backend only: PJRT handles are not
/// `Send` and cannot cross the replica worker threads.
fn cmd_serve_http(p: &Parsed, cfg: &server::ServeConfig, addr: &str)
                  -> Result<()> {
    if resolve_backend(p)?.as_str() != "native" {
        return Err(anyhow!(
            "--http requires --backend native: PJRT buffers cannot cross \
             the replica worker threads"));
    }
    reject_variant_for_native(p)?;
    let replicas = p.usize("replicas")?;
    let vocab = CharVocab::new().size();
    let source = match p.get("resume") {
        Some(path) => shard::ModelSource::Checkpoint(PathBuf::from(path)),
        None => {
            let init = NativeInit {
                kind: p.req("kind")?.to_string(),
                n_layers: p.usize("layers")?,
                d_model: p.usize("d-model")?,
                expansion: p.usize("expansion")?,
                vocab_in: Some(vocab),
                vocab_out: vocab,
                max_len: p.usize("max-len")?,
                n_heads: p.usize("n-heads")?,
                ..Default::default()
            };
            shard::ModelSource::Fresh(init, cfg.seed)
        }
    };
    let shrd = shard::Shard::new(&source, cfg, replicas)?;
    let http = http::HttpServer::bind(addr, shrd)?;
    // the smoke harness greps this line for readiness + the bound port
    println!("listening on {}", http.addr());
    let stats = http.wait()?;
    report_serve(&stats);
    Ok(())
}

/// Serve a natively-trained RL policy: load the `rl/<env>` checkpoint,
/// rebuild the offline dataset (for the normalization statistics and the
/// conditioning return the training batches used), and roll the policy
/// out in the live environment — the inference half of the Table 3 loop,
/// artifact-free.
fn cmd_rollout(args: &[String]) -> Result<()> {
    let cmd = Command::new("rollout", "roll out a trained RL policy")
        .opt("resume", None, "rl/<env> training checkpoint (required)")
        .opt("episodes", Some("3"), "rollout episodes")
        .opt("seed", Some("0"), "rollout seed")
        .opt("threads", None,
             "native thread-pool size (default: MINRNN_THREADS, else all \
              cores)")
        .positional("env", "environment: pointmass, pendulum, walker1d");
    let p = cmd.parse(args)?;
    apply_threads_opt(&p)?;
    let env = p.pos.first()
        .ok_or_else(|| anyhow!("usage: minrnn rollout <env> --resume \
                                <ckpt>"))?;
    let spec = native_workload(&format!("rl/{env}"))?;
    let ckpt = p.get("resume").ok_or_else(|| anyhow!(
        "rollout needs --resume <ckpt> (train one with `minrnn train \
         rl/{env} --backend native --checkpoint <dir>`)"))?;
    let backend = NativeBackend::from_checkpoint(Path::new(ckpt))?;
    spec.validate(&backend.model, &format!("rl/{env}"))?;

    use crate::data::rl::{self, Regime};
    let ds = rl::OfflineDataset::build(env, Regime::Medium, RL_EPISODES,
                                       RL_SEED);
    let target = ds.target_return();
    let n = p.usize("episodes")?.max(1);
    let seed = p.u64("seed")?;
    let mut total = 0f32;
    for k in 0..n {
        let ret = infer::rollout_decision(&backend, &ds, target,
                                          seed ^ (1000 + k as u64))?;
        log_info!("episode {k}: return {ret:.3}");
        total += ret;
    }
    let mean = total / n as f32;
    let score = rl::normalized_score(env, mean, seed);
    println!("{env}: mean return {mean:.3} over {n} episodes \
              (target {target:.3}, expert-normalized score {score:.1})");
    Ok(())
}

/// `minrnn quantize <ckpt>`: rewrite a checkpoint's dense weights as
/// per-tile int8 (see `backend::native::quant`).  Self-checks the
/// result against the f32 source on a seeded probe batch and refuses
/// to write a checkpoint over the golden-error budget.  The output is
/// inference-only: `serve` / `generate` / `bench` accept it, `train
/// --resume` rejects it.
fn cmd_quantize(args: &[String]) -> Result<()> {
    use crate::backend::native::quant;
    use crate::util::io;
    let cmd = Command::new("quantize",
                           "convert dense weights to per-tile int8")
        .opt("out", None,
             "output checkpoint path (default: <ckpt>.int8.ckpt)")
        .opt("threads", None,
             "native thread-pool size (default: MINRNN_THREADS, else all \
              cores)")
        .positional("ckpt", "f32 checkpoint to quantize");
    let p = cmd.parse(args)?;
    apply_threads_opt(&p)?;
    let ckpt = p.pos.first()
        .ok_or_else(|| anyhow!("usage: minrnn quantize <ckpt> [--out \
                                <path>]"))?;
    let src = Path::new(ckpt);
    let model = NativeModel::from_checkpoint(src)?;
    if model.is_quantized() {
        bail!("{} is already quantized", src.display());
    }
    let mut qm = model.clone();
    quant::quantize_model(&mut qm)?;
    let rel = quant::probe_rel_err(&model, &qm)?;
    // the CI quantize-smoke greps this line; keep it stable
    println!("quantize: max relative logit error {rel:.6} \
              (budget {})", quant::LOGIT_REL_ERR_BUDGET);
    if rel > quant::LOGIT_REL_ERR_BUDGET {
        bail!("quantized model exceeds the golden-error budget \
               ({rel:.6} > {}); keeping the f32 checkpoint",
              quant::LOGIT_REL_ERR_BUDGET);
    }
    let out = match p.get("out") {
        Some(o) => PathBuf::from(o),
        None => PathBuf::from(format!("{}.int8.ckpt", ckpt)),
    };
    io::save(&out, &qm.to_named())?;
    let (before, after) = (std::fs::metadata(src).map(|m| m.len()),
                           std::fs::metadata(&out).map(|m| m.len()));
    if let (Ok(b), Ok(a)) = (before, after) {
        log_info!("wrote {} ({} -> {} bytes, {:.0}% of f32)",
                  out.display(), b, a, 100.0 * a as f64 / b.max(1) as f64);
    } else {
        log_info!("wrote {}", out.display());
    }
    println!("quantized checkpoint: {} ({})", out.display(),
             qm.kind_summary());
    Ok(())
}

/// Native-backend throughput benchmark (`minrnn bench`): prefill tok/s,
/// decode tok/s across batch sizes and thread counts, serve p95 — written
/// to BENCH_native.json (see `bench_harness::native_throughput`).
fn cmd_bench(args: &[String]) -> Result<()> {
    let cmd = Command::new("bench", "native-backend throughput benchmark")
        .opt("threads", None,
             "native thread-pool size (default: MINRNN_THREADS, else all \
              cores)")
        .opt("kind", Some("mingru"),
             "mixer: mingru | minlstm | s6lite | transformer")
        .opt("layers", None, "layer count (default: profile)")
        .opt("d-model", None, "residual width (default: profile)")
        .opt("max-batch", None, "serve lane cap (default: profile)")
        .opt("out", Some("BENCH_native.json"), "output JSON path")
        .flag("full", "full-scale measurement (default: quick)");
    let p = cmd.parse(args)?;
    apply_threads_opt(&p)?;
    let mut cfg = if p.flag("full") {
        bench_harness::native_throughput::Config::full()
    } else {
        bench_harness::native_throughput::Config::quick()
    };
    cfg.kind = p.req("kind")?.to_string();
    if let Some(v) = p.get("layers") {
        cfg.n_layers = v.parse()?;
    }
    if let Some(v) = p.get("d-model") {
        cfg.d_model = v.parse()?;
    }
    if let Some(v) = p.get("max-batch") {
        cfg.max_batch = v.parse()?;
    }
    cfg.out = Some(PathBuf::from(p.req("out")?));
    bench_harness::native_throughput::run(&cfg)?;
    Ok(())
}

/// `minrnn compare <workload>`: train each mixer kind in the paper's
/// comparison matrix on the same workload with an identical budget and
/// print one summary row per kind — parameter count, final training
/// loss, best eval loss, steps/s, and the per-lane decode state each
/// kind carries (the recurrent kinds are O(1) in context; the
/// transformer's KV ring is O(max-len), the foil the paper measures
/// against).
fn cmd_compare(args: &[String]) -> Result<()> {
    use crate::backend::MIXER_KINDS;
    let cmd = Command::new("compare",
                           "train every mixer kind on one workload")
        .opt("steps", Some("80"), "optimizer steps per mixer")
        .opt("lr", Some("0.003"), "peak learning rate")
        .opt("seed", Some("0"), "seed (shared across kinds)")
        .opt("batch", Some("8"), "batch size")
        .opt("seq-len", Some("32"), "sequence length")
        .opt("layers", Some("2"), "layer count")
        .opt("d-model", Some("32"), "residual width")
        .opt("expansion", Some("1"),
             "hidden expansion (recurrent mixers; the transformer always \
              mixes at d-model)")
        .opt("n-heads", Some("4"),
             "transformer attention heads (must divide d-model)")
        .opt("dropout", Some("0"), "residual-branch dropout rate")
        .opt("eval-every", Some("20"), "steps between evals (0 = off)")
        .opt("faults", None,
             "deterministic fault-injection spec for chaos testing")
        .opt("threads", None,
             "native thread-pool size (default: MINRNN_THREADS, else all \
              cores)")
        .positional("workload", "native workload (char_lm, random_tokens, \
                     selective_copy, chomsky/<task>, lra/<task>, rl/<env>)");
    let p = cmd.parse(args)?;
    apply_faults_opt(&p)?;
    apply_threads_opt(&p)?;
    let workload = p.pos.first()
        .ok_or_else(|| anyhow!("usage: minrnn compare <workload>"))?
        .clone();
    let spec = native_workload(&workload)?;
    let mut cfg = TrainConfig::default();
    cfg.apply_cli(&p)?;
    cfg.backend = "native".to_string();
    cfg.variant = workload.clone();
    let (b, t) = (p.usize("batch")?, p.usize("seq-len")?);
    log_info!("compare: {} kinds x {} steps on '{workload}' \
               (b{b} t{t}, {} layers, d={})",
              MIXER_KINDS.len(), cfg.steps, p.usize("layers")?,
              p.usize("d-model")?);
    let mut rows = Vec::new();
    for kind in MIXER_KINDS {
        let init = NativeInit {
            kind: kind.to_string(),
            n_layers: p.usize("layers")?,
            d_model: p.usize("d-model")?,
            expansion: p.usize("expansion")?,
            vocab_in: spec.vocab_in,
            input_dim: spec.input_dim,
            vocab_out: spec.out_dim,
            conv: false,
            mlp: false,
            mlp_mult: 4,
            forget_bias: cfg.forget_bias,
            max_len: t.max(1),
            n_heads: p.usize("n-heads")?,
        };
        let model = NativeModel::init_random(&init, cfg.seed)?;
        let n_params: usize = model.leaves().iter().map(|v| v.len()).sum();
        let state_bytes = model.lane_state_bytes();
        let mut nt = NativeTrainer::new(model, &workload);
        nt.head = spec.head;
        nt.drop_rate = cfg.dropout;
        let mut data = data_source(&workload, b, t, None)?;
        log_info!("compare: training {kind} ({n_params} params, \
                   {state_bytes} state bytes/lane)");
        let report = trainer::run_loop(&mut nt, &cfg, 0, data.as_mut())?;
        rows.push((kind, n_params, state_bytes, report));
    }
    println!();
    println!("workload '{workload}': {} steps each, b{b} t{t}, lr {}",
             cfg.steps, cfg.lr);
    println!("{:<12} {:>9} {:>11} {:>11} {:>8} {:>12}",
             "kind", "params", "final_loss", "best_eval", "steps/s",
             "state/lane");
    for (kind, n_params, state_bytes, r) in &rows {
        println!("{:<12} {:>9} {:>11.4} {:>11.4} {:>8.1} {:>11}B",
                 kind, n_params, r.final_loss, r.best_eval_loss,
                 r.steps_per_sec, state_bytes);
    }
    Ok(())
}

/// Profile the per-step cost split of the training hot path:
/// host batch generation, input-literal construction, XLA execution,
/// output fetch + tuple decomposition.  This is the L3 §Perf measurement
/// (DESIGN.md §7): host overhead should be a small fraction of execute.
fn cmd_perf(args: &[String]) -> Result<()> {
    let cmd = artifacts_opt(Command::new("perf", "profile train hot path"))
        .opt("steps", Some("30"), "measured steps")
        .positional("variant", "artifact variant");
    let p = cmd.parse(args)?;
    let variant = p.pos.first()
        .ok_or_else(|| anyhow!("usage: minrnn perf <variant>"))?;
    let rt = Runtime::cpu()?;
    let manifest = open_manifest(p.req("artifacts")?)?;
    let model = Model::open(&rt, manifest, variant)?;
    let mut data = data_source_for(&model.variant)?;
    let mut state = model.init(0, 0.0)?;
    let mut rng = Rng::new(0);

    // warm (compile + caches)
    let warm_batch = data.train_batch(&mut rng);
    model.train_step(&mut state, &warm_batch, 1e-3, 0)?;
    rt.take_profile();

    let steps = p.usize("steps")?;
    let mut gen_s = 0.0;
    let mut lit_s = 0.0;
    let t_all = std::time::Instant::now();
    for i in 0..steps {
        let t0 = std::time::Instant::now();
        let batch = data.train_batch(&mut rng);
        gen_s += t0.elapsed().as_secs_f64();
        let t1 = std::time::Instant::now();
        let _probe = batch.x.to_literal()?; // cost of literal conversion
        lit_s += t1.elapsed().as_secs_f64();
        model.train_step(&mut state, &batch, 1e-3, i as i32)?;
    }
    let total = t_all.elapsed().as_secs_f64();
    let (exec, fetch) = rt.take_profile();
    let other = total - gen_s - exec - fetch;
    println!("variant {} — {} steps, {:.1} ms/step", variant, steps,
             total / steps as f64 * 1e3);
    let pct = |x: f64| 100.0 * x / total;
    println!("  batch generation : {:7.2} ms/step ({:4.1}%)",
             gen_s / steps as f64 * 1e3, pct(gen_s));
    println!("  XLA execute      : {:7.2} ms/step ({:4.1}%)",
             exec / steps as f64 * 1e3, pct(exec));
    println!("  output fetch     : {:7.2} ms/step ({:4.1}%)",
             fetch / steps as f64 * 1e3, pct(fetch));
    println!("  other host       : {:7.2} ms/step ({:4.1}%)",
             other / steps as f64 * 1e3, pct(other));
    println!("  (input-literal probe: {:.3} ms/step)",
             lit_s / steps as f64 * 1e3);
    Ok(())
}

fn cmd_experiment(args: &[String]) -> Result<()> {
    let cmd = artifacts_opt(
        Command::new("experiment", "regenerate a paper table/figure"))
        .flag("full", "full-scale run (default: quick)")
        .positional("id", "experiment id or 'all'");
    let p = cmd.parse(args)?;
    let id = p.pos.first()
        .ok_or_else(|| anyhow!("usage: minrnn experiment <id>|all"))?;
    if p.flag("full") {
        std::env::set_var("MINRNN_FULL", "1");
    }
    let ctx = Ctx::new(Path::new(p.req("artifacts")?))?;
    if id == "all" {
        for (eid, _) in EXPERIMENTS {
            log_info!("=== experiment {eid} ===");
            run_experiment(&ctx, eid)?;
        }
        Ok(())
    } else {
        run_experiment(&ctx, id)
    }
}
