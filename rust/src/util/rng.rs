//! Deterministic pseudo-random generation for data pipelines and tests.
//!
//! No `rand` crate offline, so this is a self-contained SplitMix64 +
//! Xoshiro256** implementation with the distributions the data layer needs
//! (uniform, normal, Zipf, permutations, categorical).  Everything is
//! reproducible from a `u64` seed; generators can be `split()` like JAX
//! keys so independent streams never correlate.

/// SplitMix64 — used for seeding and cheap stateless hashing.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Xoshiro256** PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 (recommended by the Xoshiro authors).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm),
                  splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    /// Derive an independent child stream (JAX-style key splitting).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0xA076_1D64_78BD_642F)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    #[inline]
    pub fn usize_below(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        self.range_f64(lo as f64, hi as f64) as f32
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = loop {
            let u = self.f64();
            if u > 1e-12 {
                break u;
            }
        };
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// k distinct indices from [0, n), in random order.
    pub fn choose_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        // partial Fisher–Yates over an index vector
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.usize_below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut target = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            target -= w;
            if target <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

/// Zipf-distributed sampler over ranks 1..=n with exponent s (used by the
/// synthetic corpus generator: natural-language word frequencies are
/// approximately Zipfian).
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for c in cdf.iter_mut() {
            *c /= total;
        }
        Zipf { cdf }
    }

    /// Sample a 0-based rank.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        match self.cdf.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn split_streams_differ() {
        let mut a = Rng::new(7);
        let mut c = a.split();
        let xs: Vec<u64> = (0..16).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..16).map(|_| c.next_u64()).collect();
        assert_ne!(xs, ys);
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(1);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(3);
        let m: f64 = (0..20_000).map(|_| r.f64()).sum::<f64>() / 20_000.0;
        assert!((m - 0.5).abs() < 0.01, "mean {m}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(4);
        let xs: Vec<f64> = (0..20_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / xs.len() as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn choose_distinct_is_distinct() {
        let mut r = Rng::new(5);
        for _ in 0..50 {
            let picks = r.choose_distinct(20, 8);
            let mut sorted = picks.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 8);
            assert!(picks.iter().all(|&i| i < 20));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(6);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
    }

    #[test]
    fn zipf_rank_ordering() {
        let z = Zipf::new(100, 1.1);
        let mut r = Rng::new(8);
        let mut counts = [0usize; 100];
        for _ in 0..50_000 {
            counts[z.sample(&mut r)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[10] > counts[60]);
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(9);
        let mut hits = [0usize; 3];
        for _ in 0..30_000 {
            hits[r.categorical(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(hits[2] > hits[1] && hits[1] > hits[0]);
        let frac = hits[2] as f64 / 30_000.0;
        assert!((frac - 0.7).abs() < 0.03, "frac {frac}");
    }
}
