//! Dependency-free scoped thread pool (rayon is unavailable offline).
//!
//! A small fixed crew of persistent workers executes index-space tasks
//! submitted by [`ThreadPool::run`]: the caller thread participates, tasks
//! are claimed dynamically from a shared atomic counter (so uneven work —
//! e.g. ragged scan channels — balances itself), and `run` does not return
//! until every task has finished, which is what makes it safe to hand the
//! workers closures borrowing the caller's stack.
//!
//! The native backend's hot paths (`backend::native::linalg`,
//! `backend::native::scan`) use the process-global pool ([`global`]),
//! sized by `--threads` / `MINRNN_THREADS` / available cores, in that
//! order of precedence.  Task *granularity* is always a fixed constant of
//! the kernel (row blocks, channel blocks) and never depends on the thread
//! count, so results are bit-for-bit identical whether a kernel runs on 1
//! or N threads — `rust/tests/parallel_props.rs` pins this.
//!
//! This module also hosts the crate's other dependency-free sync
//! primitives: [`BoundedQueue`], the closable bounded FIFO channel behind
//! `coordinator::scheduler`'s admission queue, and [`SlicePtr`], the
//! disjoint-range shared-write handle the kernels use.

use std::cell::Cell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

thread_local! {
    // Workers never re-enter the pool: a nested `run` on a worker executes
    // inline, which keeps nested parallelism deadlock-free by construction.
    static IN_WORKER: Cell<bool> = const { Cell::new(false) };
}

pub struct ThreadPool {
    /// Mutex-wrapped so `ThreadPool: Sync` holds on every toolchain
    /// (bare `mpsc::Sender` only became `Sync` in recent std versions);
    /// submissions are a few per `run`, so the lock is uncontended.
    sender: Option<Mutex<Sender<Job>>>,
    workers: Vec<thread::JoinHandle<()>>,
    size: usize,
    /// Current parallelism cap (1..=size); lowering it below `size`
    /// benches/serves with fewer lanes without rebuilding the pool.
    active: AtomicUsize,
}

impl ThreadPool {
    /// Pool with `threads` total lanes of parallelism (the caller thread
    /// counts as one, so `threads - 1` workers are spawned).
    pub fn new(threads: usize) -> ThreadPool {
        let size = threads.max(1);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..size - 1).map(|_| {
            let rx = Arc::clone(&rx);
            thread::spawn(move || {
                IN_WORKER.with(|f| f.set(true));
                loop {
                    let job = {
                        let guard: std::sync::MutexGuard<'_, Receiver<Job>> =
                            rx.lock().unwrap();
                        guard.recv()
                    };
                    match job {
                        Ok(job) => job(),
                        Err(_) => break, // pool dropped
                    }
                }
            })
        }).collect();
        ThreadPool {
            sender: Some(Mutex::new(tx)),
            workers,
            size,
            active: AtomicUsize::new(size),
        }
    }

    /// Total parallelism the pool was built with.
    pub fn threads(&self) -> usize {
        self.size
    }

    /// Current effective parallelism (see [`ThreadPool::set_active`]).
    pub fn active(&self) -> usize {
        self.active.load(Ordering::Relaxed).clamp(1, self.size)
    }

    /// Cap effective parallelism at `n` (clamped to `1..=threads()`),
    /// returning the value actually set.  Used by `--threads` after the
    /// global pool exists and by the throughput bench's 1-thread runs.
    pub fn set_active(&self, n: usize) -> usize {
        let n = n.clamp(1, self.size);
        self.active.store(n, Ordering::Relaxed);
        n
    }

    /// Execute `f(0), f(1), ..., f(n_tasks - 1)`, spread across the pool;
    /// returns only when all calls have finished.  The caller participates,
    /// so a 1-lane pool (or a call from inside a worker) degenerates to a
    /// plain sequential loop with zero dispatch overhead.
    ///
    /// Panics in a task are caught on the worker and re-raised here after
    /// all tasks drain.
    pub fn run<F: Fn(usize) + Sync>(&self, n_tasks: usize, f: F) {
        if n_tasks == 0 {
            return;
        }
        let helpers = if IN_WORKER.with(|c| c.get()) {
            0
        } else {
            (self.active() - 1).min(self.workers.len()).min(n_tasks - 1)
        };
        if helpers == 0 {
            for i in 0..n_tasks {
                f(i);
            }
            return;
        }
        let fobj: &(dyn Fn(usize) + Sync) = &f;
        let shared = Arc::new(RunShared {
            f: fobj as *const (dyn Fn(usize) + Sync),
            next: AtomicUsize::new(0),
            n: n_tasks,
            pending: Mutex::new(helpers),
            done: Condvar::new(),
            panicked: AtomicBool::new(false),
        });
        let sender = self.sender.as_ref().expect("pool not shut down")
            .lock().unwrap();
        for _ in 0..helpers {
            let s = Arc::clone(&shared);
            let job: Job = Box::new(move || {
                s.work();
                let mut pending = s.pending.lock().unwrap();
                *pending -= 1;
                if *pending == 0 {
                    s.done.notify_all();
                }
            });
            if sender.send(job).is_err() {
                // Channel closed mid-shutdown: the helper will never run;
                // the caller's own work loop below still covers all tasks.
                let mut pending = shared.pending.lock().unwrap();
                *pending -= 1;
            }
        }
        drop(sender);
        shared.work();
        let mut pending = shared.pending.lock().unwrap();
        while *pending > 0 {
            pending = shared.done.wait(pending).unwrap();
        }
        drop(pending);
        if shared.panicked.load(Ordering::SeqCst) {
            panic!("ThreadPool::run: a task panicked");
        }
    }

    /// [`ThreadPool::run`] over contiguous index ranges: calls
    /// `f(start, end)` for chunks `[0, chunk)`, `[chunk, 2*chunk)`, ...
    /// covering `0..n`.  Chunk boundaries are independent of the thread
    /// count, preserving bit-for-bit reproducibility of elementwise maps.
    pub fn run_chunks<F: Fn(usize, usize) + Sync>(&self, n: usize,
                                                  chunk: usize, f: F) {
        if n == 0 {
            return;
        }
        let chunk = chunk.max(1);
        let n_tasks = n.div_ceil(chunk);
        self.run(n_tasks, |ci| {
            let start = ci * chunk;
            let end = (start + chunk).min(n);
            f(start, end);
        });
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        // Closing the channel wakes every worker out of `recv`.
        drop(self.sender.take());
        for w in std::mem::take(&mut self.workers) {
            let _ = w.join();
        }
    }
}

/// State shared between the caller and its helper jobs for one `run`.
/// The raw closure pointer is sound because `run` blocks until `pending`
/// reaches zero, i.e. the borrow outlives every dereference.
struct RunShared {
    f: *const (dyn Fn(usize) + Sync),
    next: AtomicUsize,
    n: usize,
    pending: Mutex<usize>,
    done: Condvar,
    panicked: AtomicBool,
}

unsafe impl Send for RunShared {}
unsafe impl Sync for RunShared {}

impl RunShared {
    fn work(&self) {
        loop {
            let i = self.next.fetch_add(1, Ordering::Relaxed);
            if i >= self.n {
                break;
            }
            let f = unsafe { &*self.f };
            let guarded = std::panic::catch_unwind(
                std::panic::AssertUnwindSafe(|| f(i)));
            if guarded.is_err() {
                self.panicked.store(true, Ordering::SeqCst);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// disjoint-range shared writes
// ---------------------------------------------------------------------------

/// Shared handle over a mutable slice for parallel writes to *disjoint*
/// index ranges from [`ThreadPool::run`] tasks (each task owns a distinct
/// row block / channel block of the output buffer).
pub struct SlicePtr<T> {
    ptr: *mut T,
    len: usize,
}

unsafe impl<T: Send> Send for SlicePtr<T> {}
unsafe impl<T: Send> Sync for SlicePtr<T> {}

impl<T> SlicePtr<T> {
    pub fn new(s: &mut [T]) -> SlicePtr<T> {
        SlicePtr { ptr: s.as_mut_ptr(), len: s.len() }
    }

    /// Reborrow `[start, start + len)` mutably.
    ///
    /// # Safety
    ///
    /// The range must be in bounds and no two concurrent tasks may hold
    /// overlapping ranges; the underlying slice must outlive the `run`
    /// call (guaranteed when it lives on the caller's stack, since `run`
    /// joins before returning).
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice(&self, start: usize, len: usize) -> &mut [T] {
        debug_assert!(start + len <= self.len, "SlicePtr range out of bounds");
        std::slice::from_raw_parts_mut(self.ptr.add(start), len)
    }
}

// ---------------------------------------------------------------------------
// bounded closable FIFO queue (the admission channel)
// ---------------------------------------------------------------------------

/// Why a push was refused; the rejected item is handed back so the caller
/// can retry, drop, or report it (a serving queue must never swallow a
/// request silently).
#[derive(Debug)]
pub enum PushError<T> {
    /// The queue is at capacity (only returned by [`BoundedQueue::try_push`];
    /// the blocking [`BoundedQueue::push`] waits instead).
    Full(T),
    /// The queue was closed — no submission can ever be accepted again.
    Closed(T),
}

impl<T> PushError<T> {
    /// Recover the item that was refused.
    pub fn into_inner(self) -> T {
        match self {
            PushError::Full(x) | PushError::Closed(x) => x,
        }
    }
}

struct QueueInner<T> {
    items: VecDeque<T>,
    closed: bool,
    /// Pushes accepted over the queue's lifetime.  Maintained under the
    /// lock so any item visible to the consumer is already counted —
    /// metrics read after a drain can never under-report (an atomic
    /// bumped after the push would race a concurrent close + drain).
    accepted: usize,
    /// Peak depth ever observed, also exact by construction.
    peak: usize,
}

/// Dependency-free bounded multi-producer FIFO channel with explicit
/// shutdown — the sync primitive behind `coordinator::scheduler`'s
/// admission queue (std's `mpsc::SyncSender` hides the length and cannot
/// be polled from the consumer side without consuming, both of which the
/// scheduler needs for backpressure metrics and idle-blocking).
///
/// Producers choose their backpressure behavior per call:
/// [`BoundedQueue::try_push`] fails fast with [`PushError::Full`], while
/// [`BoundedQueue::push`] blocks until space frees up.  [`BoundedQueue::close`]
/// is idempotent, wakes every blocked producer and consumer, and turns the
/// queue into drain-only mode: pops keep succeeding until it is empty.
///
/// ```
/// use minrnn::util::threads::BoundedQueue;
///
/// let q: BoundedQueue<u32> = BoundedQueue::new(2);
/// q.try_push(1).unwrap();
/// q.try_push(2).unwrap();
/// assert!(q.try_push(3).is_err()); // full
/// q.close();
/// assert_eq!(q.try_pop(), Some(1)); // drains after close
/// assert_eq!(q.try_pop(), Some(2));
/// assert!(!q.wait_ready()); // closed and empty: never blocks again
/// ```
pub struct BoundedQueue<T> {
    inner: Mutex<QueueInner<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    capacity: usize,
}

impl<T> BoundedQueue<T> {
    /// A queue admitting at most `capacity` (≥ 1) waiting items.
    pub fn new(capacity: usize) -> BoundedQueue<T> {
        BoundedQueue {
            inner: Mutex::new(QueueInner {
                items: VecDeque::new(),
                closed: false,
                accepted: 0,
                peak: 0,
            }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            capacity: capacity.max(1),
        }
    }

    /// Total pushes accepted so far (exact: counted under the push lock).
    pub fn accepted(&self) -> usize {
        self.inner.lock().unwrap().accepted
    }

    /// Peak queue depth ever reached (exact: sampled under the push lock).
    pub fn peak_depth(&self) -> usize {
        self.inner.lock().unwrap().peak
    }

    /// The capacity the queue was built with.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Items currently waiting (a racy snapshot, for metrics).
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Whether [`BoundedQueue::close`] has been called (the queue may
    /// still hold items to drain).
    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }

    /// Non-blocking push: refused with [`PushError::Full`] at capacity and
    /// [`PushError::Closed`] after shutdown, handing the item back.
    /// On success returns the queue depth *including* the pushed item,
    /// read under the lock — the exact peak-depth sample racy `len()`
    /// polling cannot provide.
    pub fn try_push(&self, item: T) -> Result<usize, PushError<T>> {
        let mut inner = self.inner.lock().unwrap();
        if inner.closed {
            return Err(PushError::Closed(item));
        }
        if inner.items.len() >= self.capacity {
            return Err(PushError::Full(item));
        }
        inner.items.push_back(item);
        inner.accepted += 1;
        let depth = inner.items.len();
        inner.peak = inner.peak.max(depth);
        drop(inner);
        self.not_empty.notify_one();
        Ok(depth)
    }

    /// Blocking push: waits for space while the queue is at capacity.
    /// Fails only with [`PushError::Closed`] (shutdown races the wait).
    /// On success returns the post-push queue depth, like
    /// [`BoundedQueue::try_push`].
    pub fn push(&self, item: T) -> Result<usize, PushError<T>> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if inner.closed {
                return Err(PushError::Closed(item));
            }
            if inner.items.len() < self.capacity {
                inner.items.push_back(item);
                inner.accepted += 1;
                let depth = inner.items.len();
                inner.peak = inner.peak.max(depth);
                drop(inner);
                self.not_empty.notify_one();
                return Ok(depth);
            }
            inner = self.not_full.wait(inner).unwrap();
        }
    }

    /// Non-blocking pop (front of the FIFO).  `None` means empty — check
    /// [`BoundedQueue::is_closed`] to distinguish idle from shut down.
    pub fn try_pop(&self) -> Option<T> {
        let mut inner = self.inner.lock().unwrap();
        let item = inner.items.pop_front();
        if item.is_some() {
            drop(inner);
            self.not_full.notify_one();
        }
        item
    }

    /// Block until at least one item is waiting (`true`) or the queue is
    /// closed **and** drained (`false`, the consumer's shutdown signal).
    /// Deliberately does not pop: the scheduler wakes, then admits as many
    /// queued items as it has free lanes via [`BoundedQueue::try_pop`].
    pub fn wait_ready(&self) -> bool {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if !inner.items.is_empty() {
                return true;
            }
            if inner.closed {
                return false;
            }
            inner = self.not_empty.wait(inner).unwrap();
        }
    }

    /// Shut the queue down: no further pushes are accepted, every blocked
    /// producer and consumer wakes, and remaining items stay poppable so
    /// the consumer can drain gracefully.  Idempotent.
    pub fn close(&self) {
        let mut inner = self.inner.lock().unwrap();
        inner.closed = true;
        drop(inner);
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }
}

// ---------------------------------------------------------------------------
// process-global pool
// ---------------------------------------------------------------------------

static REQUESTED: AtomicUsize = AtomicUsize::new(0);
static GLOBAL: OnceLock<ThreadPool> = OnceLock::new();

/// Host parallelism (1 when undetectable).
pub fn available_threads() -> usize {
    thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

fn configured_threads() -> usize {
    let req = REQUESTED.load(Ordering::SeqCst);
    if req > 0 {
        return req;
    }
    if let Ok(v) = std::env::var("MINRNN_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n > 0 {
                return n;
            }
        }
    }
    available_threads()
}

/// The shared pool every native-backend kernel dispatches through.
/// First use freezes the worker count at `--threads` / `MINRNN_THREADS` /
/// available cores.
pub fn global() -> &'static ThreadPool {
    GLOBAL.get_or_init(|| ThreadPool::new(configured_threads()))
}

/// Request `n` threads (`--threads`).  Before the global pool exists this
/// sets its size exactly; afterwards it caps effective parallelism at
/// `min(n, built size)`.  Returns the effective thread count.
pub fn set_threads(n: usize) -> usize {
    let n = n.max(1);
    REQUESTED.store(n, Ordering::SeqCst);
    match GLOBAL.get() {
        Some(pool) => pool.set_active(n),
        None => n,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn run_covers_every_index_exactly_once() {
        for threads in [1usize, 2, 7] {
            let pool = ThreadPool::new(threads);
            for n in [0usize, 1, 2, 63, 64, 257] {
                let hits: Vec<AtomicUsize> =
                    (0..n).map(|_| AtomicUsize::new(0)).collect();
                pool.run(n, |i| {
                    hits[i].fetch_add(1, Ordering::SeqCst);
                });
                assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1),
                        "threads={threads} n={n}");
            }
        }
    }

    #[test]
    fn disjoint_parallel_writes_land() {
        let pool = ThreadPool::new(4);
        let n = 1000usize;
        let mut out = vec![0u64; n];
        let ptr = SlicePtr::new(out.as_mut_slice());
        pool.run_chunks(n, 37, |s, e| {
            let chunk = unsafe { ptr.slice(s, e - s) };
            for (j, v) in chunk.iter_mut().enumerate() {
                *v = (s + j) as u64 * 3;
            }
        });
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i as u64 * 3);
        }
    }

    #[test]
    fn nested_run_executes_inline() {
        let pool = ThreadPool::new(3);
        let total = AtomicU64::new(0);
        pool.run(8, |i| {
            // nested call from (possibly) a worker thread must not deadlock
            pool.run(4, |j| {
                total.fetch_add((i * 4 + j) as u64, Ordering::SeqCst);
            });
        });
        let want: u64 = (0..32u64).sum();
        assert_eq!(total.load(Ordering::SeqCst), want);
    }

    #[test]
    fn set_active_caps_parallelism() {
        let pool = ThreadPool::new(4);
        assert_eq!(pool.active(), 4);
        assert_eq!(pool.set_active(1), 1);
        // still correct, just sequential
        let total = AtomicU64::new(0);
        pool.run(100, |i| {
            total.fetch_add(i as u64, Ordering::SeqCst);
        });
        assert_eq!(total.load(Ordering::SeqCst), 4950);
        assert_eq!(pool.set_active(99), 4);
    }

    #[test]
    fn task_panic_propagates() {
        let pool = ThreadPool::new(2);
        let caught = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                pool.run(16, |i| {
                    if i == 7 {
                        panic!("boom");
                    }
                });
            }));
        assert!(caught.is_err());
        // pool still serviceable afterwards
        let total = AtomicU64::new(0);
        pool.run(10, |i| {
            total.fetch_add(i as u64, Ordering::SeqCst);
        });
        assert_eq!(total.load(Ordering::SeqCst), 45);
    }

    #[test]
    fn set_threads_reports_effective_count() {
        // only exercises the pre/post clamping logic on the global pool
        let n = set_threads(1);
        assert!(n >= 1);
        let m = set_threads(available_threads());
        assert!(m >= 1);
    }

    #[test]
    fn bounded_queue_fifo_capacity_and_close() {
        let q: BoundedQueue<u32> = BoundedQueue::new(2);
        assert_eq!(q.capacity(), 2);
        assert!(q.is_empty() && !q.is_closed());
        // push returns the post-push depth, sampled under the lock
        assert_eq!(q.try_push(1).unwrap(), 1);
        assert_eq!(q.try_push(2).unwrap(), 2);
        match q.try_push(3) {
            Err(PushError::Full(x)) => assert_eq!(x, 3),
            other => panic!("expected Full, got {other:?}"),
        }
        assert_eq!(q.len(), 2);
        assert_eq!(q.try_pop(), Some(1)); // FIFO order
        q.try_push(3).unwrap(); // space freed
        q.close();
        match q.try_push(4) {
            Err(PushError::Closed(x)) => assert_eq!(x, 4),
            other => panic!("expected Closed, got {other:?}"),
        }
        // drain-after-close
        assert!(q.is_closed());
        assert!(q.wait_ready());
        assert_eq!(q.try_pop(), Some(2));
        assert_eq!(q.try_pop(), Some(3));
        assert_eq!(q.try_pop(), None);
        assert!(!q.wait_ready());
        // lifetime accounting is exact: 3 accepted pushes, peak depth 2
        assert_eq!(q.accepted(), 3);
        assert_eq!(q.peak_depth(), 2);
    }

    #[test]
    fn bounded_queue_capacity_floor_is_one() {
        let q: BoundedQueue<u8> = BoundedQueue::new(0);
        assert_eq!(q.capacity(), 1);
        q.try_push(9).unwrap();
        assert!(q.try_push(10).is_err());
    }

    #[test]
    fn bounded_queue_blocking_push_wakes_on_pop() {
        let q = Arc::new(BoundedQueue::<u32>::new(1));
        q.try_push(0).unwrap(); // full: the producer's push must wait
        let producer = {
            let q = Arc::clone(&q);
            thread::spawn(move || q.push(1).is_ok())
        };
        thread::sleep(std::time::Duration::from_millis(10));
        assert_eq!(q.try_pop(), Some(0)); // frees space, wakes the producer
        assert!(producer.join().unwrap());
        assert_eq!(q.try_pop(), Some(1));
    }

    #[test]
    fn bounded_queue_close_wakes_blocked_producer() {
        let q = Arc::new(BoundedQueue::<u32>::new(1));
        q.try_push(7).unwrap(); // full: the next push blocks
        let producer = {
            let q = Arc::clone(&q);
            thread::spawn(move || q.push(8))
        };
        // nothing ever pops, so the producer can only be released by close
        thread::sleep(std::time::Duration::from_millis(10));
        q.close();
        match producer.join().unwrap() {
            Err(PushError::Closed(x)) => assert_eq!(x, 8),
            other => panic!("expected Closed, got {other:?}"),
        }
        assert_eq!(q.try_pop(), Some(7)); // 7 still drains
        assert_eq!(q.try_pop(), None);
    }

    #[test]
    fn bounded_queue_close_wakes_blocked_consumer() {
        let q = Arc::new(BoundedQueue::<u32>::new(4));
        let consumer = {
            let q = Arc::clone(&q);
            thread::spawn(move || q.wait_ready())
        };
        // empty queue: the consumer can only be released by close
        thread::sleep(std::time::Duration::from_millis(10));
        q.close();
        assert!(!consumer.join().unwrap());
    }
}
