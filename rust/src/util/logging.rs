//! Tiny leveled logger with env filtering (`MINRNN_LOG=debug|info|warn|error`)
//! and wall-clock timestamps relative to process start.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(1);
static START: OnceLock<Instant> = OnceLock::new();

pub fn init() {
    START.get_or_init(Instant::now);
    if let Ok(v) = std::env::var("MINRNN_LOG") {
        set_level(match v.to_ascii_lowercase().as_str() {
            "debug" => Level::Debug,
            "warn" => Level::Warn,
            "error" => Level::Error,
            _ => Level::Info,
        });
    }
}

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn enabled(level: Level) -> bool {
    level as u8 >= LEVEL.load(Ordering::Relaxed)
}

pub fn log(level: Level, msg: &str) {
    if !enabled(level) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    let tag = match level {
        Level::Debug => "DBG",
        Level::Info => "INF",
        Level::Warn => "WRN",
        Level::Error => "ERR",
    };
    eprintln!("[{t:9.3}s {tag}] {msg}");
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug,
                                   &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info,
                                   &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn,
                                   &format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_filtering() {
        init();
        set_level(Level::Warn);
        assert!(!enabled(Level::Info));
        assert!(enabled(Level::Warn));
        assert!(enabled(Level::Error));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
    }
}
