//! Declarative command-line parsing (clap is unavailable offline).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value`, repeated
//! options, positional arguments, typed accessors with defaults, and
//! auto-generated `--help` text.

use std::collections::BTreeMap;

#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
}

#[derive(Default, Clone, Debug)]
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
    pub positionals: Vec<(&'static str, &'static str)>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Command { name, about, opts: Vec::new(), positionals: Vec::new() }
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, takes_value: false,
                                 default: None });
        self
    }

    pub fn opt(mut self, name: &'static str, default: Option<&'static str>,
               help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, takes_value: true, default });
        self
    }

    pub fn positional(mut self, name: &'static str,
                      help: &'static str) -> Self {
        self.positionals.push((name, help));
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nOptions:\n", self.name, self.about);
        for o in &self.opts {
            let v = if o.takes_value { " <value>" } else { "" };
            let d = o.default.map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("  --{}{v}\n      {}{d}\n", o.name, o.help));
        }
        if !self.positionals.is_empty() {
            s.push_str("\nPositionals:\n");
            for (n, h) in &self.positionals {
                s.push_str(&format!("  <{n}>  {h}\n"));
            }
        }
        s
    }

    /// Parse `args` (without argv[0]).  Unknown options are errors.
    pub fn parse(&self, args: &[String]) -> anyhow::Result<Parsed> {
        let mut values: BTreeMap<String, Vec<String>> = BTreeMap::new();
        let mut flags: Vec<String> = Vec::new();
        let mut pos: Vec<String> = Vec::new();

        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if a == "--help" || a == "-h" {
                anyhow::bail!("{}", self.usage());
            }
            if let Some(rest) = a.strip_prefix("--") {
                let (name, inline) = match rest.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                let spec = self.opts.iter().find(|o| o.name == name)
                    .ok_or_else(|| anyhow::anyhow!(
                        "unknown option --{name}\n\n{}", self.usage()))?;
                if spec.takes_value {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            args.get(i).cloned().ok_or_else(
                                || anyhow::anyhow!(
                                    "--{name} requires a value"))?
                        }
                    };
                    values.entry(name).or_default().push(v);
                } else {
                    if inline.is_some() {
                        anyhow::bail!("--{name} takes no value");
                    }
                    flags.push(name);
                }
            } else {
                pos.push(a.clone());
            }
            i += 1;
        }

        // fill defaults
        for o in &self.opts {
            if o.takes_value && !values.contains_key(o.name) {
                if let Some(d) = o.default {
                    values.insert(o.name.to_string(), vec![d.to_string()]);
                }
            }
        }
        Ok(Parsed { values, flags, pos })
    }
}

#[derive(Debug, Default)]
pub struct Parsed {
    values: BTreeMap<String, Vec<String>>,
    flags: Vec<String>,
    pub pos: Vec<String>,
}

impl Parsed {
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).and_then(|v| v.last()).map(|s| s.as_str())
    }

    pub fn get_all(&self, name: &str) -> Vec<&str> {
        self.values.get(name).map(|v| v.iter().map(|s| s.as_str()).collect())
            .unwrap_or_default()
    }

    pub fn req(&self, name: &str) -> anyhow::Result<&str> {
        self.get(name)
            .ok_or_else(|| anyhow::anyhow!("missing required --{name}"))
    }

    pub fn usize(&self, name: &str) -> anyhow::Result<usize> {
        Ok(self.req(name)?.parse()?)
    }

    pub fn u64(&self, name: &str) -> anyhow::Result<u64> {
        Ok(self.req(name)?.parse()?)
    }

    pub fn f64(&self, name: &str) -> anyhow::Result<f64> {
        Ok(self.req(name)?.parse()?)
    }

    pub fn f32(&self, name: &str) -> anyhow::Result<f32> {
        Ok(self.req(name)?.parse()?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("train", "train a model")
            .opt("steps", Some("100"), "number of steps")
            .opt("lr", Some("0.001"), "learning rate")
            .opt("tag", None, "repeatable tag")
            .flag("verbose", "chatty")
            .positional("variant", "artifact variant")
    }

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let p = cmd().parse(&s(&["--lr", "0.01", "myvariant"])).unwrap();
        assert_eq!(p.usize("steps").unwrap(), 100);
        assert_eq!(p.f64("lr").unwrap(), 0.01);
        assert_eq!(p.pos, vec!["myvariant"]);
        assert!(!p.flag("verbose"));
    }

    #[test]
    fn equals_form_and_flags() {
        let p = cmd().parse(&s(&["--steps=7", "--verbose"])).unwrap();
        assert_eq!(p.usize("steps").unwrap(), 7);
        assert!(p.flag("verbose"));
    }

    #[test]
    fn repeated_options() {
        let p = cmd().parse(&s(&["--tag", "a", "--tag", "b"])).unwrap();
        assert_eq!(p.get_all("tag"), vec!["a", "b"]);
        assert_eq!(p.get("tag"), Some("b"));
    }

    #[test]
    fn unknown_option_errors() {
        assert!(cmd().parse(&s(&["--nope"])).is_err());
    }

    #[test]
    fn missing_value_errors() {
        assert!(cmd().parse(&s(&["--steps"])).is_err());
    }
}
