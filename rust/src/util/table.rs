//! Aligned ASCII / Markdown table rendering for experiment reports — every
//! bench prints its paper table through this.

#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(),
                   "row width != header width");
        self.rows.push(cells);
        self
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    /// Plain aligned text.
    pub fn render(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let line = |cells: &[String], w: &[usize]| -> String {
            cells.iter().enumerate()
                .map(|(i, c)| format!("{:<width$}", c, width = w[i]))
                .collect::<Vec<_>>().join("  ")
        };
        out.push_str(&line(&self.headers, &w));
        out.push('\n');
        out.push_str(&"-".repeat(w.iter().sum::<usize>() + 2 * (w.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row, &w));
            out.push('\n');
        }
        out
    }

    /// GitHub-flavoured markdown (used in EXPERIMENTS.md).
    pub fn render_markdown(&self) -> String {
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("**{}**\n\n", self.title));
        }
        out.push_str(&format!("| {} |\n", self.headers.join(" | ")));
        out.push_str(&format!("|{}\n", "---|".repeat(self.headers.len())));
        for row in &self.rows {
            out.push_str(&format!("| {} |\n", row.join(" | ")));
        }
        out
    }
}

/// Format a float compactly (3 significant-ish digits).
pub fn fnum(x: f64) -> String {
    if x == 0.0 {
        "0".into()
    } else if x.abs() >= 1000.0 {
        format!("{x:.0}")
    } else if x.abs() >= 10.0 {
        format!("{x:.1}")
    } else if x.abs() >= 0.01 {
        format!("{x:.3}")
    } else {
        format!("{x:.2e}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["model", "acc"]);
        t.row(vec!["minGRU".into(), "99.5".into()]);
        t.row(vec!["m".into(), "1".into()]);
        let r = t.render();
        assert!(r.contains("minGRU  99.5"));
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 5);
    }

    #[test]
    #[should_panic]
    fn wrong_width_panics() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn markdown_shape() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        let md = t.render_markdown();
        assert!(md.starts_with("| a | b |\n|---|---|\n| 1 | 2 |"));
    }

    #[test]
    fn fnum_ranges() {
        assert_eq!(fnum(0.0), "0");
        assert_eq!(fnum(1234.0), "1234");
        assert_eq!(fnum(12.34), "12.3");
        assert_eq!(fnum(0.1234), "0.123");
        assert_eq!(fnum(0.0001234), "1.23e-4");
    }
}
