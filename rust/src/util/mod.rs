//! Substrate layer: in-repo replacements for crates unavailable in the
//! offline build environment (clap, serde_json, rand, criterion, proptest,
//! env_logger, rayon), each with its own unit tests.

pub mod bench;
pub mod cli;
pub mod faults;
pub mod io;
pub mod json;
pub mod logging;
pub mod prop;
pub mod plot;
pub mod rng;
pub mod simd;
pub mod stats;
pub mod table;
pub mod threads;
