//! Streaming and batch statistics for benchmarks and training metrics.

/// Welford online mean/variance.
#[derive(Clone, Debug)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

/// Must match [`Welford::new`]: the derived impl zeroed min/max, so any
/// all-positive series reported `min() == 0.0` when built via `default()`.
impl Default for Welford {
    fn default() -> Self {
        Welford::new()
    }
}

impl Welford {
    pub fn new() -> Self {
        Welford { n: 0, mean: 0.0, m2: 0.0,
                  min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// 95% confidence half-width of the mean (normal approximation).
    pub fn ci95(&self) -> f64 {
        if self.n < 2 { return 0.0; }
        1.96 * self.std() / (self.n as f64).sqrt()
    }
}

/// Percentile via linear interpolation on a sorted copy.  q in [0, 100].
///
/// NaN samples are ignored and an empty (or all-NaN) input returns
/// `0.0`, matching the documented `ServeStats` contract that an idle
/// serving run reports zero latencies.  An earlier version asserted on
/// empty input and sorted with `partial_cmp(..).unwrap()`, so a single
/// NaN — e.g. a `0.0 / 0.0` rate from a zero-length run — panicked the
/// whole report.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    let mut v: Vec<f64> = xs.iter().copied()
        .filter(|x| !x.is_nan()).collect();
    if v.is_empty() {
        return 0.0;
    }
    v.sort_by(f64::total_cmp);
    let rank = q / 100.0 * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() { return 0.0; }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 { return 0.0; }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
        / (xs.len() - 1) as f64).sqrt()
}

/// Ordinary least squares y = a + b·x.  Returns (intercept, slope, r²).
pub fn linear_fit(x: &[f64], y: &[f64]) -> (f64, f64, f64) {
    assert_eq!(x.len(), y.len());
    assert!(x.len() >= 2);
    let n = x.len() as f64;
    let mx = mean(x);
    let my = mean(y);
    let sxy: f64 = x.iter().zip(y).map(|(a, b)| (a - mx) * (b - my)).sum();
    let sxx: f64 = x.iter().map(|a| (a - mx) * (a - mx)).sum();
    let b = sxy / sxx;
    let a = my - b * mx;
    let ss_res: f64 = x.iter().zip(y)
        .map(|(xi, yi)| {
            let e = yi - (a + b * xi);
            e * e
        }).sum();
    let ss_tot: f64 = y.iter().map(|yi| (yi - my) * (yi - my)).sum();
    let r2 = if ss_tot > 0.0 { 1.0 - ss_res / ss_tot } else { 1.0 };
    (a, b, r2 * n / n) // n/n keeps clippy quiet about unused n
}

/// Exponential moving average helper for loss curves.
#[derive(Clone, Debug)]
pub struct Ema {
    alpha: f64,
    value: Option<f64>,
}

impl Ema {
    pub fn new(alpha: f64) -> Self {
        Ema { alpha, value: None }
    }

    pub fn push(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => self.alpha * x + (1.0 - self.alpha) * prev,
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_batch() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.5, -3.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-12);
        assert!((w.std() - std(&xs)).abs() < 1e-12);
        assert_eq!(w.min(), -3.0);
        assert_eq!(w.max(), 16.5);
        assert_eq!(w.count(), 6);
    }

    #[test]
    fn default_matches_new() {
        // regression: the derived Default started min/max at 0.0, so an
        // all-positive series reported min = 0.0
        let mut w = Welford::default();
        w.push(3.0);
        w.push(5.0);
        assert_eq!(w.min(), 3.0);
        assert_eq!(w.max(), 5.0);
        let mut neg = Welford::default();
        neg.push(-2.0);
        assert_eq!(neg.max(), -2.0);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert!((percentile(&xs, 50.0) - 50.5).abs() < 1e-9);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert!((percentile(&xs, 99.0) - 99.01).abs() < 0.02);
    }

    #[test]
    fn percentile_survives_nan_and_empty_input() {
        // regression: sort_by(partial_cmp().unwrap()) panicked on NaN and
        // an assert rejected empty slices; both now degrade gracefully
        assert_eq!(percentile(&[f64::NAN, 1.0, 3.0], 50.0), 2.0);
        assert_eq!(percentile(&[1.0, f64::NAN, f64::NAN, 5.0], 100.0), 5.0);
        assert_eq!(percentile(&[], 95.0), 0.0);
        assert_eq!(percentile(&[f64::NAN, f64::NAN], 50.0), 0.0);
        // negative zero and negative values still order correctly under
        // total_cmp
        assert_eq!(percentile(&[-1.0, -0.0, 2.0], 0.0), -1.0);
    }

    #[test]
    fn linear_fit_exact_line() {
        let x: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let y: Vec<f64> = x.iter().map(|v| 3.0 + 2.0 * v).collect();
        let (a, b, r2) = linear_fit(&x, &y);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b - 2.0).abs() < 1e-9);
        assert!((r2 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.5);
        for _ in 0..30 {
            e.push(10.0);
        }
        assert!((e.get().unwrap() - 10.0).abs() < 1e-6);
    }
}
