//! ASCII line plots for terminal reports — loss curves (Figure 2/5) and
//! runtime-vs-length curves (Figure 1/3) render directly in bench output
//! and in results/*.md code blocks.

/// Render one or more named series into a fixed-size character grid.
/// X values need not be aligned across series; each series is drawn by
/// nearest-column mapping.
pub struct Plot {
    pub title: String,
    pub width: usize,
    pub height: usize,
    pub log_y: bool,
    series: Vec<(String, Vec<(f64, f64)>)>,
}

const MARKS: [char; 6] = ['*', 'o', '+', 'x', '#', '@'];

impl Plot {
    pub fn new(title: &str) -> Self {
        Plot { title: title.to_string(), width: 64, height: 16,
               log_y: false, series: Vec::new() }
    }

    pub fn log_y(mut self) -> Self {
        self.log_y = true;
        self
    }

    pub fn series(&mut self, name: &str, points: &[(f64, f64)]) -> &mut Self {
        self.series.push((name.to_string(), points.to_vec()));
        self
    }

    fn y_tx(&self, y: f64) -> f64 {
        if self.log_y { y.max(1e-12).ln() } else { y }
    }

    pub fn render(&self) -> String {
        let pts: Vec<(f64, f64)> = self.series.iter()
            .flat_map(|(_, p)| p.iter().cloned()).collect();
        if pts.is_empty() {
            return format!("{} (no data)\n", self.title);
        }
        let (mut x0, mut x1) = (f64::MAX, f64::MIN);
        let (mut y0, mut y1) = (f64::MAX, f64::MIN);
        for &(x, y) in &pts {
            x0 = x0.min(x);
            x1 = x1.max(x);
            let ty = self.y_tx(y);
            y0 = y0.min(ty);
            y1 = y1.max(ty);
        }
        if (x1 - x0).abs() < 1e-12 {
            x1 = x0 + 1.0;
        }
        if (y1 - y0).abs() < 1e-12 {
            y1 = y0 + 1.0;
        }
        let mut grid = vec![vec![' '; self.width]; self.height];
        for (si, (_, points)) in self.series.iter().enumerate() {
            let mark = MARKS[si % MARKS.len()];
            for &(x, y) in points {
                let cx = ((x - x0) / (x1 - x0)
                          * (self.width - 1) as f64).round() as usize;
                let ty = self.y_tx(y);
                let cy = ((ty - y0) / (y1 - y0)
                          * (self.height - 1) as f64).round() as usize;
                let row = self.height - 1 - cy.min(self.height - 1);
                grid[row][cx.min(self.width - 1)] = mark;
            }
        }
        let inv = |t: f64| if self.log_y { t.exp() } else { t };
        let mut out = String::new();
        out.push_str(&format!("{}\n", self.title));
        for (i, row) in grid.iter().enumerate() {
            let label = if i == 0 {
                format!("{:>9.3}", inv(y1))
            } else if i == self.height - 1 {
                format!("{:>9.3}", inv(y0))
            } else {
                " ".repeat(9)
            };
            out.push_str(&format!("{label} |{}|\n",
                                  row.iter().collect::<String>()));
        }
        out.push_str(&format!("{:>9} +{}+\n", "",
                              "-".repeat(self.width)));
        out.push_str(&format!("{:>10}{:<10.3}{:>width$.3}\n", "", x0, x1,
                              width = self.width - 10));
        let legend: Vec<String> = self.series.iter().enumerate()
            .map(|(i, (n, _))| format!("{} {}", MARKS[i % MARKS.len()], n))
            .collect();
        out.push_str(&format!("{:>10}{}\n", "", legend.join("   ")));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_two_series() {
        let mut p = Plot::new("losses");
        p.series("a", &[(0.0, 4.0), (50.0, 2.0), (100.0, 1.0)]);
        p.series("b", &[(0.0, 4.0), (50.0, 3.5), (100.0, 3.0)]);
        let s = p.render();
        assert!(s.contains("losses"));
        assert!(s.contains('*') && s.contains('o'));
        assert!(s.contains("* a") && s.contains("o b"));
        assert_eq!(s.lines().count(), 16 + 4);
    }

    #[test]
    fn extremes_land_on_edges() {
        let mut p = Plot::new("t");
        p.series("s", &[(0.0, 0.0), (1.0, 1.0)]);
        let s = p.render();
        let lines: Vec<&str> = s.lines().collect();
        // max y on first grid row, min y on last
        assert!(lines[1].contains('*'));
        assert!(lines[16].contains('*'));
    }

    #[test]
    fn log_scale_compresses() {
        let mut p = Plot::new("t").log_y();
        p.series("s", &[(0.0, 1.0), (1.0, 10.0), (2.0, 100.0)]);
        let s = p.render();
        // middle point should sit mid-grid on a log axis (grid rows only —
        // the legend line also contains the series mark)
        let mid_rows: Vec<usize> = s.lines().enumerate()
            .filter(|(_, l)| l.contains('|') && l.contains('*'))
            .map(|(i, _)| i).collect();
        assert_eq!(mid_rows.len(), 3);
        let gap1 = mid_rows[1] - mid_rows[0];
        let gap2 = mid_rows[2] - mid_rows[1];
        assert!((gap1 as i64 - gap2 as i64).abs() <= 1,
                "log spacing uneven: {mid_rows:?}");
    }

    #[test]
    fn empty_and_degenerate() {
        let p = Plot::new("empty");
        assert!(p.render().contains("no data"));
        let mut p2 = Plot::new("flat");
        p2.series("s", &[(0.0, 5.0), (1.0, 5.0)]);
        assert!(p2.render().contains('*'));
    }
}
