//! Binary checkpoint format (NPZ-like, little-endian, self-describing)
//! with crash-safe durability.
//!
//!   magic "MRNN" | version u32 | n_tensors u32
//!   per tensor: name_len u32 | name utf-8 | dtype u8 (0=f32, 1=i32,
//!               2=i8) | ndim u32 | dims u32[ndim] | raw data
//!   trailer (version >= 2): crc32 u32 over everything before it
//!
//! Version 3 adds the i8 dtype (quantized weight leaves); the writer
//! only stamps v3 when an i8 tensor is present, so pure-f32/i32
//! checkpoints remain byte-identical to v2 and older readers keep
//! loading them.
//!
//! Used for parameter/optimizer checkpoints and dataset caches.
//!
//! **Durability.**  [`save`] goes through [`commit_durable`]: the payload
//! is written to `<path>.tmp`, the file is fsynced, renamed over `path`,
//! and the parent directory is fsynced — rename alone survives a process
//! crash but not power loss, because neither the data nor the directory
//! entry is guaranteed on stable storage until both fsyncs land.  The
//! CRC32 trailer catches the remaining hazard: a torn write that
//! published a truncated or bit-rotted file.  [`load`] reports the three
//! failure classes distinctly, always naming the offending path:
//! *truncated* (file ends mid-record), *corrupt* (CRC mismatch or an
//! impossible field), and *version mismatch*.  Version-1 files
//! (pre-trailer) remain readable.
//!
//! Every durable-commit step is a fault-injection site
//! ([`crate::util::faults`]): `io_write`, `io_short` (tears the file),
//! `io_fsync`, `io_rename` — `rust/tests/fault_props.rs` crashes a save
//! at each and proves recovery finds a valid checkpoint.

use std::fmt;
use std::fs::File;
use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::faults::{self, Site};

pub const MAGIC: &[u8; 4] = b"MRNN";
/// Version 2 appends the CRC32 trailer; version 3 adds the i8 dtype.
/// Version-1 files are still read (no trailer to verify), and [`save`]
/// stamps the oldest version that can represent the payload (v2 unless
/// an i8 tensor forces v3).
pub const VERSION: u32 = 3;

/// Version stamped on checkpoints with no i8 tensors — byte-identical
/// output to the pre-quantization writer.
pub const VERSION_F32: u32 = 2;

/// CRC-32 (IEEE 802.3, reflected polynomial 0xEDB88320) — the trailer
/// checksum for torn-write detection.  Bitwise implementation: checkpoint
/// payloads are at most a few MB, far below where a table would matter.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in bytes {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

#[derive(Clone, Debug, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
    I8(Vec<i8>),
}

impl TensorData {
    pub fn len(&self) -> usize {
        match self {
            TensorData::F32(v) => v.len(),
            TensorData::I32(v) => v.len(),
            TensorData::I8(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            TensorData::F32(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_i32(&self) -> Option<&[i32]> {
        match self {
            TensorData::I32(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_i8(&self) -> Option<&[i8]> {
        match self {
            TensorData::I8(v) => Some(v),
            _ => None,
        }
    }
}

#[derive(Clone, Debug, PartialEq)]
pub struct NamedTensor {
    pub name: String,
    pub dims: Vec<usize>,
    pub data: TensorData,
}

impl NamedTensor {
    pub fn f32(name: &str, dims: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        NamedTensor { name: name.to_string(), dims,
                      data: TensorData::F32(data) }
    }

    pub fn i32(name: &str, dims: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        NamedTensor { name: name.to_string(), dims,
                      data: TensorData::I32(data) }
    }

    pub fn i8(name: &str, dims: Vec<usize>, data: Vec<i8>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        NamedTensor { name: name.to_string(), dims,
                      data: TensorData::I8(data) }
    }
}

/// Durably commit `payload` to `path`: write `<path>.tmp`, fsync the
/// file, rename over `path`, fsync the parent directory.  This is the
/// shared commit primitive for every on-disk format (MRNN checkpoints,
/// MRSC session caches, `LATEST` pointers); all four IO fault sites live
/// here, so chaos coverage of this one function covers every format.
pub fn commit_durable(path: &Path, payload: &[u8]) -> Result<()> {
    let tmp = path.with_extension("tmp");
    if let Some(e) = faults::io_error(Site::IoWrite) {
        return Err(e).with_context(|| format!("write {}", tmp.display()));
    }
    let mut f = File::create(&tmp)
        .with_context(|| format!("create {}", tmp.display()))?;
    if faults::io_error(Site::IoShort).is_some() {
        // simulate the torn-write hazard end to end: publish a truncated
        // file at the *final* path (as if power failed after the rename
        // but before the data reached stable storage), then report the
        // failure.  Recovery must detect the tear via the CRC trailer.
        f.write_all(&payload[..payload.len() / 2])?;
        let _ = f.sync_all();
        drop(f);
        std::fs::rename(&tmp, path)?;
        bail!("injected short write: committed {} of {} bytes to {}",
              payload.len() / 2, payload.len(), path.display());
    }
    f.write_all(payload)
        .with_context(|| format!("write {}", tmp.display()))?;
    if let Some(e) = faults::io_error(Site::IoFsync) {
        return Err(e).with_context(|| format!("fsync {}", tmp.display()));
    }
    f.sync_all().with_context(|| format!("fsync {}", tmp.display()))?;
    drop(f);
    if let Some(e) = faults::io_error(Site::IoRename) {
        return Err(e).with_context(|| format!(
            "rename {} -> {}", tmp.display(), path.display()));
    }
    std::fs::rename(&tmp, path).with_context(|| format!(
        "rename {} -> {}", tmp.display(), path.display()))?;
    // the rename is only durable once the directory entry is: fsync the
    // parent.  Directories that cannot be opened for sync (exotic
    // filesystems) degrade to the rename-only guarantee.
    if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
        if let Ok(d) = File::open(dir) {
            let _ = d.sync_all();
        }
    }
    Ok(())
}

pub fn save(path: &Path, tensors: &[NamedTensor]) -> Result<()> {
    let version = if tensors.iter()
        .any(|t| matches!(t.data, TensorData::I8(_)))
    {
        VERSION
    } else {
        VERSION_F32
    };
    let mut buf: Vec<u8> = Vec::new();
    buf.extend_from_slice(MAGIC);
    buf.extend_from_slice(&version.to_le_bytes());
    buf.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
    for t in tensors {
        let nb = t.name.as_bytes();
        buf.extend_from_slice(&(nb.len() as u32).to_le_bytes());
        buf.extend_from_slice(nb);
        match &t.data {
            TensorData::F32(_) => buf.push(0u8),
            TensorData::I32(_) => buf.push(1u8),
            TensorData::I8(_) => buf.push(2u8),
        }
        buf.extend_from_slice(&(t.dims.len() as u32).to_le_bytes());
        for &d in &t.dims {
            buf.extend_from_slice(&(d as u32).to_le_bytes());
        }
        match &t.data {
            TensorData::F32(v) => {
                for x in v {
                    buf.extend_from_slice(&x.to_le_bytes());
                }
            }
            TensorData::I32(v) => {
                for x in v {
                    buf.extend_from_slice(&x.to_le_bytes());
                }
            }
            TensorData::I8(v) => {
                for &x in v {
                    buf.push(x as u8);
                }
            }
        }
    }
    let crc = crc32(&buf);
    buf.extend_from_slice(&crc.to_le_bytes());
    commit_durable(path, &buf)
}

/// Typed classification of a checkpoint load failure.  [`load`] wraps
/// this in `anyhow` for existing callers; paths that need to *react* to
/// the class — the HTTP reload endpoint refusing a torn checkpoint while
/// keeping the old model, recovery scanning a ring for the newest file
/// that still validates — match on [`load_classified`]'s error instead
/// of grepping message strings.  Implements `Display` +
/// `std::error::Error`, so it propagates through `?` and error-response
/// encoders without ad-hoc `format!` at each call site.
#[derive(Debug)]
pub enum LoadError {
    /// The file could not be read at all (missing, permissions, IO).
    Io { path: PathBuf, source: std::io::Error },
    /// The magic bytes are wrong — some other file format.
    NotACheckpoint { path: PathBuf },
    /// The file ends mid-record (v1 files without a CRC trailer; a torn
    /// v2 file fails its CRC first and reports as [`LoadError::Corrupt`]).
    Truncated { path: PathBuf, detail: String },
    /// CRC mismatch or an impossible field value.
    Corrupt { path: PathBuf, detail: String },
    /// Written by a format revision this reader does not support.
    VersionMismatch { path: PathBuf, version: u32 },
}

impl LoadError {
    /// The offending file, whatever the failure class.
    pub fn path(&self) -> &Path {
        match self {
            LoadError::Io { path, .. }
            | LoadError::NotACheckpoint { path }
            | LoadError::Truncated { path, .. }
            | LoadError::Corrupt { path, .. }
            | LoadError::VersionMismatch { path, .. } => path,
        }
    }
}

impl fmt::Display for LoadError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            LoadError::Io { path, source } => {
                write!(f, "open {}: {source}", path.display())
            }
            LoadError::NotACheckpoint { path } => {
                write!(f, "{}: not a MRNN checkpoint", path.display())
            }
            LoadError::Truncated { path, detail } => {
                write!(f, "{}: truncated checkpoint ({detail})",
                       path.display())
            }
            LoadError::Corrupt { path, detail } => {
                write!(f, "{}: corrupt checkpoint ({detail})",
                       path.display())
            }
            LoadError::VersionMismatch { path, version } => {
                write!(f, "{}: checkpoint version mismatch (file is \
                           v{version}, this reader supports \
                           v1..=v{VERSION})", path.display())
            }
        }
    }
}

impl std::error::Error for LoadError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            LoadError::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

/// In-memory parse cursor that classifies running off the end as
/// *truncation* (distinct from corrupt-field errors), naming the path
/// and offset.
struct Cursor<'a> {
    buf: &'a [u8],
    off: usize,
    path: &'a Path,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], LoadError> {
        if n > self.buf.len() - self.off {
            return Err(LoadError::Truncated {
                path: self.path.to_path_buf(),
                detail: format!("needed {n} bytes at offset {}, only {} \
                                 remain", self.off,
                                self.buf.len() - self.off),
            });
        }
        let s = &self.buf[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, LoadError> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, LoadError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn corrupt(&self, detail: String) -> LoadError {
        LoadError::Corrupt { path: self.path.to_path_buf(), detail }
    }
}

/// [`load`] with the failure class preserved as a [`LoadError`] instead
/// of flattened into an `anyhow` message.
pub fn load_classified(path: &Path)
                       -> Result<Vec<NamedTensor>, LoadError> {
    let bytes = std::fs::read(path).map_err(|source| LoadError::Io {
        path: path.to_path_buf(), source,
    })?;
    if bytes.len() < 12 {
        return Err(LoadError::Truncated {
            path: path.to_path_buf(),
            detail: format!("{} bytes is shorter than the header",
                            bytes.len()),
        });
    }
    if &bytes[..4] != MAGIC {
        return Err(LoadError::NotACheckpoint {
            path: path.to_path_buf(),
        });
    }
    let version = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
    let body: &[u8] = match version {
        1 => &bytes[8..],
        2 | 3 => {
            let (payload, trailer) = bytes.split_at(bytes.len() - 4);
            let want = u32::from_le_bytes(trailer.try_into().unwrap());
            let got = crc32(payload);
            if want != got {
                return Err(LoadError::Corrupt {
                    path: path.to_path_buf(),
                    detail: format!("CRC mismatch: trailer {want:08x}, \
                                     computed {got:08x} — torn or \
                                     bit-rotted write"),
                });
            }
            &payload[8..]
        }
        v => return Err(LoadError::VersionMismatch {
            path: path.to_path_buf(), version: v,
        }),
    };
    let mut r = Cursor { buf: body, off: 0, path };
    let n = r.u32()? as usize;
    let mut out = Vec::with_capacity(n.min(1 << 16));
    for _ in 0..n {
        let name_len = r.u32()? as usize;
        if name_len > 1 << 20 {
            return Err(r.corrupt(format!("name length {name_len}")));
        }
        let name = String::from_utf8(r.take(name_len)?.to_vec())
            .map_err(|_| r.corrupt("name not utf-8".to_string()))?;
        let dtype = r.u8()?;
        let ndim = r.u32()? as usize;
        if ndim > 16 {
            return Err(r.corrupt(format!("ndim {ndim}")));
        }
        let mut dims = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            dims.push(r.u32()? as usize);
        }
        let count: usize = dims.iter().product();
        if count > 1 << 30 {
            return Err(r.corrupt(format!("element count {count}")));
        }
        let esize = match dtype {
            0 | 1 => 4,
            2 => 1,
            d => return Err(r.corrupt(format!("dtype {d}"))),
        };
        let raw = r.take(count * esize)?;
        let data = match dtype {
            0 => TensorData::F32(raw.chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect()),
            1 => TensorData::I32(raw.chunks_exact(4)
                .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect()),
            _ => TensorData::I8(raw.iter().map(|&b| b as i8).collect()),
        };
        out.push(NamedTensor { name, dims, data });
    }
    Ok(out)
}

pub fn load(path: &Path) -> Result<Vec<NamedTensor>> {
    Ok(load_classified(path)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("minrnn_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ckpt.bin");
        let tensors = vec![
            NamedTensor::f32("w", vec![2, 3], vec![1., 2., 3., 4., 5., 6.]),
            NamedTensor::i32("step", vec![], vec![42]),
            NamedTensor::f32("empty", vec![0], vec![]),
        ];
        save(&path, &tensors).unwrap();
        let loaded = load(&path).unwrap();
        assert_eq!(loaded, tensors);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("minrnn_io_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, b"NOPE....12345678").unwrap();
        let msg = format!("{:#}", load(&path).unwrap_err());
        assert!(msg.contains("not a MRNN checkpoint") && msg.contains("bad"),
                "unhelpful error: {msg}");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn rejects_truncated_as_truncated() {
        let dir = std::env::temp_dir().join("minrnn_io_test3");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trunc.bin");
        let tensors = vec![NamedTensor::f32("w", vec![4], vec![1.; 4])];
        save(&path, &tensors).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        // cutting the tail leaves a v2 file whose CRC no longer matches:
        // exactly the torn-write signature
        std::fs::write(&path, &bytes[..bytes.len() - 5]).unwrap();
        let msg = format!("{:#}", load(&path).unwrap_err());
        assert!(msg.contains("corrupt") && msg.contains("CRC"),
                "torn file should fail the CRC check: {msg}");
        // a v1 file (no trailer) that ends mid-record reports truncation
        let mut v1 = bytes[..bytes.len() - 4].to_vec();
        v1[4..8].copy_from_slice(&1u32.to_le_bytes());
        std::fs::write(&path, &v1[..v1.len() - 5]).unwrap();
        let msg = format!("{:#}", load(&path).unwrap_err());
        assert!(msg.contains("truncated"),
                "v1 short read should say truncated: {msg}");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn crc_catches_a_flipped_byte() {
        let dir = std::env::temp_dir().join("minrnn_io_test4");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rot.bin");
        save(&path, &[NamedTensor::f32("w", vec![8], vec![0.5; 8])])
            .unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
        let msg = format!("{:#}", load(&path).unwrap_err());
        assert!(msg.contains("corrupt") && msg.contains("CRC"),
                "bit rot must be caught by the trailer: {msg}");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn version_mismatch_is_reported_distinctly() {
        let dir = std::env::temp_dir().join("minrnn_io_test5");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("future.bin");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&99u32.to_le_bytes());
        bytes.extend_from_slice(&0u32.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let msg = format!("{:#}", load(&path).unwrap_err());
        assert!(msg.contains("version mismatch") && msg.contains("v99"),
                "unhelpful error: {msg}");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn legacy_v1_files_still_load() {
        // a v1 writer: the old format body with version 1 and no trailer
        let dir = std::env::temp_dir().join("minrnn_io_test6");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("v1.bin");
        let tensors = vec![NamedTensor::i32("step", vec![], vec![17])];
        save(&path, &tensors).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let mut v1 = bytes[..bytes.len() - 4].to_vec();
        v1[4..8].copy_from_slice(&1u32.to_le_bytes());
        std::fs::write(&path, &v1).unwrap();
        assert_eq!(load(&path).unwrap(), tensors);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn i8_tensors_roundtrip_and_bump_the_version() {
        let dir = std::env::temp_dir().join("minrnn_io_test9");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("q.bin");
        // pure-f32 payload stamps the legacy version (byte-identical to
        // the pre-quantization writer)
        save(&path, &[NamedTensor::f32("w", vec![2], vec![1., 2.])])
            .unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(u32::from_le_bytes(bytes[4..8].try_into().unwrap()),
                   VERSION_F32);
        // an i8 leaf forces v3, and the data round-trips exactly
        let tensors = vec![
            NamedTensor::i8("w/q", vec![2, 3], vec![-127, -1, 0, 1, 5, 127]),
            NamedTensor::f32("w/scale", vec![1, 1], vec![0.25]),
            NamedTensor::i32("step", vec![], vec![7]),
        ];
        save(&path, &tensors).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(u32::from_le_bytes(bytes[4..8].try_into().unwrap()),
                   VERSION);
        assert_eq!(load(&path).unwrap(), tensors);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn crc32_matches_known_vectors() {
        // IEEE CRC-32 check value for "123456789"
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn load_errors_are_classified_and_std_errors() {
        let dir = std::env::temp_dir().join("minrnn_io_test8");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("classified.bin");
        // missing file → Io, with the io::Error preserved as source()
        let err = load_classified(&path).unwrap_err();
        assert!(matches!(err, LoadError::Io { .. }), "got {err:?}");
        assert!(std::error::Error::source(&err).is_some(),
                "Io must expose its source");
        assert_eq!(err.path(), path);
        // wrong magic → NotACheckpoint
        std::fs::write(&path, b"NOPE....12345678").unwrap();
        let err = load_classified(&path).unwrap_err();
        assert!(matches!(err, LoadError::NotACheckpoint { .. }));
        assert!(err.to_string().contains("not a MRNN checkpoint"));
        // future version → VersionMismatch
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&99u32.to_le_bytes());
        bytes.extend_from_slice(&0u32.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = load_classified(&path).unwrap_err();
        assert!(matches!(err,
                         LoadError::VersionMismatch { version: 99, .. }));
        assert!(err.to_string().contains("v99"));
        // torn v2 file → Corrupt (CRC), and the anyhow wrapper keeps the
        // same message the string-matching callers rely on
        save(&path, &[NamedTensor::f32("w", vec![2], vec![1., 2.])])
            .unwrap();
        let good = std::fs::read(&path).unwrap();
        std::fs::write(&path, &good[..good.len() - 3]).unwrap();
        let err = load_classified(&path).unwrap_err();
        assert!(matches!(err, LoadError::Corrupt { .. }));
        let msg = format!("{:#}", load(&path).unwrap_err());
        assert!(msg.contains("corrupt") && msg.contains("CRC"),
                "anyhow wrapper lost the classification: {msg}");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn commit_durable_leaves_no_tmp_behind() {
        let dir = std::env::temp_dir().join("minrnn_io_test7");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("blob.bin");
        commit_durable(&path, b"hello durable world").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"hello durable world");
        assert!(!path.with_extension("tmp").exists(),
                "tmp must be renamed away");
        std::fs::remove_file(&path).unwrap();
    }
}
