//! Binary checkpoint format (NPZ-like, little-endian, self-describing).
//!
//!   magic "MRNN" | version u32 | n_tensors u32
//!   per tensor: name_len u32 | name utf-8 | dtype u8 (0=f32, 1=i32)
//!               | ndim u32 | dims u32[ndim] | raw data
//!
//! Used for parameter/optimizer checkpoints and dataset caches.

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

pub const MAGIC: &[u8; 4] = b"MRNN";
pub const VERSION: u32 = 1;

#[derive(Clone, Debug, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl TensorData {
    pub fn len(&self) -> usize {
        match self {
            TensorData::F32(v) => v.len(),
            TensorData::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            TensorData::F32(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_i32(&self) -> Option<&[i32]> {
        match self {
            TensorData::I32(v) => Some(v),
            _ => None,
        }
    }
}

#[derive(Clone, Debug, PartialEq)]
pub struct NamedTensor {
    pub name: String,
    pub dims: Vec<usize>,
    pub data: TensorData,
}

impl NamedTensor {
    pub fn f32(name: &str, dims: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        NamedTensor { name: name.to_string(), dims,
                      data: TensorData::F32(data) }
    }

    pub fn i32(name: &str, dims: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        NamedTensor { name: name.to_string(), dims,
                      data: TensorData::I32(data) }
    }
}

pub fn save(path: &Path, tensors: &[NamedTensor]) -> Result<()> {
    let tmp = path.with_extension("tmp");
    {
        let mut w = BufWriter::new(File::create(&tmp)
            .with_context(|| format!("create {}", tmp.display()))?);
        w.write_all(MAGIC)?;
        w.write_all(&VERSION.to_le_bytes())?;
        w.write_all(&(tensors.len() as u32).to_le_bytes())?;
        for t in tensors {
            let nb = t.name.as_bytes();
            w.write_all(&(nb.len() as u32).to_le_bytes())?;
            w.write_all(nb)?;
            match &t.data {
                TensorData::F32(_) => w.write_all(&[0u8])?,
                TensorData::I32(_) => w.write_all(&[1u8])?,
            }
            w.write_all(&(t.dims.len() as u32).to_le_bytes())?;
            for &d in &t.dims {
                w.write_all(&(d as u32).to_le_bytes())?;
            }
            match &t.data {
                TensorData::F32(v) => {
                    for x in v {
                        w.write_all(&x.to_le_bytes())?;
                    }
                }
                TensorData::I32(v) => {
                    for x in v {
                        w.write_all(&x.to_le_bytes())?;
                    }
                }
            }
        }
        w.flush()?;
    }
    std::fs::rename(&tmp, path)?;
    Ok(())
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

pub fn load(path: &Path) -> Result<Vec<NamedTensor>> {
    let mut r = BufReader::new(File::open(path)
        .with_context(|| format!("open {}", path.display()))?);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{}: not a MRNN checkpoint", path.display());
    }
    let version = read_u32(&mut r)?;
    if version != VERSION {
        bail!("{}: unsupported checkpoint version {version}", path.display());
    }
    let n = read_u32(&mut r)? as usize;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let name_len = read_u32(&mut r)? as usize;
        if name_len > 1 << 20 {
            bail!("corrupt checkpoint: name length {name_len}");
        }
        let mut name_bytes = vec![0u8; name_len];
        r.read_exact(&mut name_bytes)?;
        let name = String::from_utf8(name_bytes)
            .context("checkpoint name not utf-8")?;
        let mut dtype = [0u8; 1];
        r.read_exact(&mut dtype)?;
        let ndim = read_u32(&mut r)? as usize;
        if ndim > 16 {
            bail!("corrupt checkpoint: ndim {ndim}");
        }
        let mut dims = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            dims.push(read_u32(&mut r)? as usize);
        }
        let count: usize = dims.iter().product();
        if count > 1 << 30 {
            bail!("corrupt checkpoint: element count {count}");
        }
        let mut raw = vec![0u8; count * 4];
        r.read_exact(&mut raw)?;
        let data = match dtype[0] {
            0 => TensorData::F32(raw.chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect()),
            1 => TensorData::I32(raw.chunks_exact(4)
                .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect()),
            d => bail!("corrupt checkpoint: dtype {d}"),
        };
        out.push(NamedTensor { name, dims, data });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("minrnn_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ckpt.bin");
        let tensors = vec![
            NamedTensor::f32("w", vec![2, 3], vec![1., 2., 3., 4., 5., 6.]),
            NamedTensor::i32("step", vec![], vec![42]),
            NamedTensor::f32("empty", vec![0], vec![]),
        ];
        save(&path, &tensors).unwrap();
        let loaded = load(&path).unwrap();
        assert_eq!(loaded, tensors);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("minrnn_io_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, b"NOPE....").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn rejects_truncated() {
        let dir = std::env::temp_dir().join("minrnn_io_test3");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trunc.bin");
        let tensors = vec![NamedTensor::f32("w", vec![4], vec![1.; 4])];
        save(&path, &tensors).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 5]).unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }
}
