//! Mini property-based testing framework (proptest is unavailable offline).
//!
//! A `Gen<T>` produces random values from an `Rng` plus a size hint; on
//! failure the harness greedily shrinks the failing input (halving numbers,
//! truncating vectors) and reports the minimal counterexample found.

use super::rng::Rng;

pub struct Gen<T> {
    f: Box<dyn Fn(&mut Rng, usize) -> T>,
}

impl<T: 'static> Gen<T> {
    pub fn new<F: Fn(&mut Rng, usize) -> T + 'static>(f: F) -> Self {
        Gen { f: Box::new(f) }
    }

    pub fn sample(&self, rng: &mut Rng, size: usize) -> T {
        (self.f)(rng, size)
    }

    pub fn map<U: 'static, F: Fn(T) -> U + 'static>(self, f: F) -> Gen<U> {
        Gen::new(move |rng, size| f(self.sample(rng, size)))
    }
}

pub fn usize_up_to(max: usize) -> Gen<usize> {
    Gen::new(move |rng, size| rng.usize_below(max.min(size.max(1)) + 1))
}

pub fn i64_range(lo: i64, hi: i64) -> Gen<i64> {
    Gen::new(move |rng, _| lo + rng.below((hi - lo + 1) as u64) as i64)
}

pub fn f64_range(lo: f64, hi: f64) -> Gen<f64> {
    Gen::new(move |rng, _| rng.range_f64(lo, hi))
}

pub fn vec_of<T: 'static>(elem: Gen<T>, max_len: usize) -> Gen<Vec<T>> {
    Gen::new(move |rng, size| {
        let len = rng.usize_below(max_len.min(size.max(1)) + 1);
        (0..len).map(|_| elem.sample(rng, size)).collect()
    })
}

/// Values that know how to propose smaller versions of themselves.
pub trait Shrink: Clone {
    fn shrink(&self) -> Vec<Self>;
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<Self> {
        if *self == 0 { vec![] } else { vec![self / 2, self - 1] }
    }
}

impl Shrink for i64 {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self != 0 {
            out.push(self / 2);
            out.push(self - self.signum());
            if *self < 0 {
                out.push(-self);
            }
        }
        out
    }
}

impl Shrink for f64 {
    fn shrink(&self) -> Vec<Self> {
        if *self == 0.0 { vec![] } else { vec![self / 2.0, 0.0] }
    }
}

impl<T: Shrink> Shrink for Vec<T> {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.is_empty() {
            return out;
        }
        out.push(self[..self.len() / 2].to_vec());
        out.push(self[1..].to_vec());
        out.push(self[..self.len() - 1].to_vec());
        // shrink one element
        for (i, x) in self.iter().enumerate().take(4) {
            for sx in x.shrink() {
                let mut v = self.clone();
                v[i] = sx;
                out.push(v);
            }
        }
        out
    }
}

impl<A: Shrink, B: Shrink> Shrink for (A, B) {
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self.0.shrink().into_iter()
            .map(|a| (a, self.1.clone())).collect();
        out.extend(self.1.shrink().into_iter()
            .map(|b| (self.0.clone(), b)));
        out
    }
}

pub struct Config {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink_steps: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 200, seed: 0xC0FFEE, max_shrink_steps: 500 }
    }
}

/// Run `prop` over `cases` random inputs; on failure shrink and panic with
/// the minimal counterexample.
pub fn check<T, P>(gen: &Gen<T>, prop: P)
where
    T: Shrink + std::fmt::Debug + 'static,
    P: Fn(&T) -> bool,
{
    check_with(&Config::default(), gen, prop)
}

pub fn check_with<T, P>(cfg: &Config, gen: &Gen<T>, prop: P)
where
    T: Shrink + std::fmt::Debug + 'static,
    P: Fn(&T) -> bool,
{
    let mut rng = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let size = 4 + case * 64 / cfg.cases.max(1); // grow sizes over run
        let input = gen.sample(&mut rng, size);
        if !prop(&input) {
            let minimal = shrink_loop(input, &prop, cfg.max_shrink_steps);
            panic!("property failed (case {case});\
                    \n  minimal counterexample: {minimal:?}");
        }
    }
}

fn shrink_loop<T: Shrink + std::fmt::Debug, P: Fn(&T) -> bool>(
    mut failing: T, prop: &P, max_steps: usize) -> T {
    let mut steps = 0;
    'outer: while steps < max_steps {
        for candidate in failing.shrink() {
            steps += 1;
            if !prop(&candidate) {
                failing = candidate;
                continue 'outer;
            }
            if steps >= max_steps {
                break 'outer;
            }
        }
        break;
    }
    failing
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        // reversing twice is identity
        let gen = vec_of(i64_range(-100, 100), 32);
        check(&gen, |v| {
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            w == *v
        });
    }

    #[test]
    fn failing_property_shrinks() {
        // "all vectors are shorter than 3" fails; minimal example has len 3
        let gen = vec_of(i64_range(0, 10), 32);
        let result = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                check(&gen, |v| v.len() < 3);
            }));
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("minimal counterexample"), "{msg}");
        // minimal vec of len 3 printed with exactly 3 elements
        let n_commas = msg[msg.find('[').unwrap()..].matches(',').count();
        assert!(n_commas <= 3, "not shrunk: {msg}");
    }

    #[test]
    fn numeric_shrink_reaches_small() {
        let gen = i64_range(0, 1_000_000);
        let result = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                check(&gen, |&x| x < 100);
            }));
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("100"), "should shrink to 100: {msg}");
    }
}
