//! Deterministic fault injection for chaos testing the durability and
//! serving stack.
//!
//! Every injectable failure point is a **site** ([`Site`]): IO write /
//! short-write / fsync / rename errors around the checkpoint commit path
//! (`util::io::commit_durable`), decode-step panics in the async
//! scheduler, and latency spikes.  Whether an occurrence of a site fires
//! is a *pure function of `(seed, site, occurrence index)`* — the same
//! counter-based hashing the PR-4 dropout RNG uses
//! (`backend::native::autograd::drop_multiplier`) — so an injected
//! failure schedule is bit-reproducible across thread counts and runs:
//! `rust/tests/fault_props.rs` replays the exact same crashes at 1, 2,
//! and 7 threads and pins the surviving outputs.
//!
//! Faults are **disabled by default** and the disabled path is one
//! relaxed atomic load per site ([`enabled`]), inlined into the callers —
//! no plan lookup, no counter traffic, no branch beyond the load — so
//! production binaries pay nothing (the CI bench gate runs with faults
//! off and must hold its usual thresholds).  Enable with the
//! `MINRNN_FAULTS` environment variable or the `--faults` CLI option on
//! `train` / `serve`; the spec grammar is comma-separated clauses:
//!
//! ```text
//! seed=7,io_write=@3,decode=0.05,latency=0.02,latency_ms=50
//! ```
//!
//! * `seed=N` — hash seed for the firing schedule (default 0).
//! * `<site>=P` — fire each occurrence independently with probability
//!   `P` in `[0, 1]`.
//! * `<site>=@N` — fire exactly the `N`-th occurrence (0-based) of the
//!   site, once; the crash-at-every-fault-point property test iterates
//!   this over every `N`.
//! * `latency_ms=M` — duration of an injected latency spike.
//!
//! Site names: `io_write`, `io_short`, `io_fsync`, `io_rename`,
//! `decode`, `latency`.
//!
//! The plan and per-site occurrence counters are process-global (fault
//! schedules must span threads), so tests that install a plan own the
//! process: the integration suite keeps injection inside
//! `tests/fault_props.rs` (its own test binary) behind a serializing
//! lock.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use crate::util::rng::splitmix64;

/// Number of distinct fault sites (the length of [`Site::ALL`]).
pub const N_SITES: usize = 6;

/// An injectable failure point.  The discriminant indexes the rule table
/// and the per-site occurrence counter.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Site {
    /// Error before any byte of a durable commit is written.
    IoWrite = 0,
    /// Torn write: half the payload is committed to the final path, then
    /// the save errors — recovery must catch this via the CRC trailer.
    IoShort = 1,
    /// Error at the fsync between write and rename (file written but not
    /// durable; the tmp file is left behind).
    IoFsync = 2,
    /// Error at the tmp→final rename (fully written, never published).
    IoRename = 3,
    /// Panic inside the scheduler's lockstep decode step.
    Decode = 4,
    /// Latency spike (sleep) before a decode step.
    Latency = 5,
}

impl Site {
    pub const ALL: [Site; N_SITES] = [
        Site::IoWrite, Site::IoShort, Site::IoFsync, Site::IoRename,
        Site::Decode, Site::Latency,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Site::IoWrite => "io_write",
            Site::IoShort => "io_short",
            Site::IoFsync => "io_fsync",
            Site::IoRename => "io_rename",
            Site::Decode => "decode",
            Site::Latency => "latency",
        }
    }

    pub fn by_name(name: &str) -> Option<Site> {
        Site::ALL.into_iter().find(|s| s.name() == name)
    }
}

/// When a site fires: never (the default), each occurrence independently
/// with probability `rate`, or exactly occurrence `one_shot` (which takes
/// precedence over `rate`).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Rule {
    pub rate: f32,
    pub one_shot: Option<u64>,
}

impl Rule {
    /// Pure decision function: does occurrence `idx` of `site` fire under
    /// `seed`?  No state — the bit-reproducibility of the whole layer
    /// rests on this being a function of its arguments alone.
    pub fn fires(&self, seed: u64, site: Site, idx: u64) -> bool {
        if let Some(n) = self.one_shot {
            return idx == n;
        }
        if self.rate <= 0.0 {
            return false;
        }
        uniform(seed, site, idx) < self.rate
    }
}

/// Counter-based uniform draw in [0, 1): key the site stream and the
/// occurrence index into one splitmix64 state, exactly the
/// `drop_multiplier` construction.
fn uniform(seed: u64, site: Site, idx: u64) -> f32 {
    let mut s = seed
        ^ (site as u64 + 1).wrapping_mul(0xA076_1D64_78BD_642F);
    s = s.wrapping_add(idx.wrapping_mul(0x9E37_79B9_7F4A_7C15));
    let x = splitmix64(&mut s);
    (x >> 40) as f32 / (1u64 << 24) as f32
}

/// A complete injection schedule: one [`Rule`] per [`Site`] plus the
/// shared hash seed and the latency-spike duration.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultPlan {
    pub seed: u64,
    pub rules: [Rule; N_SITES],
    pub latency: Duration,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            seed: 0,
            rules: [Rule::default(); N_SITES],
            latency: Duration::from_millis(20),
        }
    }
}

impl FaultPlan {
    /// Builder convenience for tests: set one site's rule.
    pub fn with(mut self, site: Site, rule: Rule) -> Self {
        self.rules[site as usize] = rule;
        self
    }

    /// A plan that fires exactly occurrence `idx` of `site`.
    pub fn one_shot(site: Site, idx: u64) -> Self {
        FaultPlan::default()
            .with(site, Rule { rate: 0.0, one_shot: Some(idx) })
    }
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static PLAN: Mutex<Option<FaultPlan>> = Mutex::new(None);
static COUNTERS: [AtomicU64; N_SITES] = [
    AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0),
    AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0),
];

/// The disabled-path check: one relaxed load.  Every injection helper
/// returns immediately when this is false — no counters move, no lock is
/// taken — which is what makes faults-off a measurable zero overhead.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Install a plan and reset the occurrence counters (so a schedule's
/// indices mean the same thing every run).
pub fn install(plan: FaultPlan) {
    reset_counters();
    *lock_plan() = Some(plan);
    ENABLED.store(true, Ordering::SeqCst);
}

/// Disable injection and drop the plan.
pub fn clear() {
    ENABLED.store(false, Ordering::SeqCst);
    *lock_plan() = None;
    reset_counters();
}

/// Install a plan from `MINRNN_FAULTS` when the variable is set and
/// non-empty; a no-op otherwise.  Called once at CLI startup.
pub fn init_from_env() -> Result<()> {
    if let Ok(spec) = std::env::var("MINRNN_FAULTS") {
        if !spec.trim().is_empty() {
            install(parse(&spec)
                .map_err(|e| anyhow!("MINRNN_FAULTS: {e}"))?);
        }
    }
    Ok(())
}

/// Zero every per-site occurrence counter.
pub fn reset_counters() {
    for c in &COUNTERS {
        c.store(0, Ordering::SeqCst);
    }
}

/// Occurrences of `site` seen since the counters were last reset.  Test
/// hook: a faults-disabled run must leave every counter at zero.
pub fn occurrences(site: Site) -> u64 {
    COUNTERS[site as usize].load(Ordering::SeqCst)
}

fn lock_plan() -> std::sync::MutexGuard<'static, Option<FaultPlan>> {
    // a panic mid-roll (injected decode panic) must not poison the layer
    PLAN.lock().unwrap_or_else(|p| p.into_inner())
}

/// Count one occurrence of `site` and decide whether it fires; returns
/// the firing occurrence index.  The counter only advances while faults
/// are enabled.
fn roll(site: Site) -> Option<u64> {
    if !enabled() {
        return None;
    }
    let guard = lock_plan();
    let plan = guard.as_ref()?;
    let idx = COUNTERS[site as usize].fetch_add(1, Ordering::SeqCst);
    plan.rules[site as usize].fires(plan.seed, site, idx).then_some(idx)
}

/// IO fault sites: an injected `std::io::Error` naming the site and
/// occurrence, or `None` (the overwhelmingly common case).
#[inline]
pub fn io_error(site: Site) -> Option<std::io::Error> {
    if !enabled() {
        return None;
    }
    roll(site).map(|idx| std::io::Error::new(
        std::io::ErrorKind::Other,
        format!("injected {} fault (occurrence {idx})", site.name())))
}

/// Decode-step panic site: panics when the occurrence fires, exercising
/// the scheduler's `catch_unwind` isolation.
#[inline]
pub fn maybe_decode_panic() {
    if !enabled() {
        return;
    }
    if let Some(idx) = roll(Site::Decode) {
        panic!("injected decode fault (occurrence {idx})");
    }
}

/// Latency-spike site: sleeps the plan's `latency` duration when the
/// occurrence fires.
#[inline]
pub fn maybe_latency() {
    if !enabled() {
        return;
    }
    if roll(Site::Latency).is_some() {
        let d = lock_plan().as_ref()
            .map(|p| p.latency)
            .unwrap_or(Duration::ZERO);
        std::thread::sleep(d);
    }
}

/// Parse the `MINRNN_FAULTS` / `--faults` spec grammar (module docs).
pub fn parse(spec: &str) -> Result<FaultPlan> {
    let mut plan = FaultPlan::default();
    for clause in spec.split(',').map(str::trim).filter(|c| !c.is_empty()) {
        let (key, val) = clause.split_once('=').ok_or_else(|| anyhow!(
            "fault clause '{clause}' is not key=value"))?;
        match key {
            "seed" => {
                plan.seed = val.parse().map_err(|_| anyhow!(
                    "fault seed '{val}' is not an integer"))?;
            }
            "latency_ms" => {
                let ms: u64 = val.parse().map_err(|_| anyhow!(
                    "latency_ms '{val}' is not an integer"))?;
                plan.latency = Duration::from_millis(ms);
            }
            name => {
                let site = Site::by_name(name).ok_or_else(|| anyhow!(
                    "unknown fault site '{name}' (expected io_write, \
                     io_short, io_fsync, io_rename, decode, or latency)"))?;
                let rule = if let Some(n) = val.strip_prefix('@') {
                    Rule {
                        rate: 0.0,
                        one_shot: Some(n.parse().map_err(|_| anyhow!(
                            "fault occurrence '@{n}' is not an integer"))?),
                    }
                } else {
                    let rate: f32 = val.parse().map_err(|_| anyhow!(
                        "fault rate '{val}' is not a number"))?;
                    if !(0.0..=1.0).contains(&rate) {
                        bail!("fault rate {rate} out of [0, 1] for {name}");
                    }
                    Rule { rate, one_shot: None }
                };
                plan.rules[site as usize] = rule;
            }
        }
    }
    Ok(plan)
}

#[cfg(test)]
mod tests {
    use super::*;

    // The global install/enable path is exercised in
    // tests/fault_props.rs, which owns its own process; unit tests here
    // stay on the pure functions (plus one all-defaults install/clear
    // round-trip that cannot fire anything) so they can never perturb
    // concurrently-running io/scheduler unit tests.

    #[test]
    fn firing_is_a_pure_function_of_seed_site_index() {
        let r = Rule { rate: 0.3, one_shot: None };
        for idx in 0..64u64 {
            let a = r.fires(7, Site::IoWrite, idx);
            let b = r.fires(7, Site::IoWrite, idx);
            assert_eq!(a, b, "same inputs must agree at idx {idx}");
        }
        // different sites draw from different streams
        let writes: Vec<bool> =
            (0..256).map(|i| r.fires(7, Site::IoWrite, i)).collect();
        let renames: Vec<bool> =
            (0..256).map(|i| r.fires(7, Site::IoRename, i)).collect();
        assert_ne!(writes, renames, "site streams must differ");
        // and different seeds reshuffle the schedule
        let reseeded: Vec<bool> =
            (0..256).map(|i| r.fires(8, Site::IoWrite, i)).collect();
        assert_ne!(writes, reseeded, "seed must matter");
    }

    #[test]
    fn rate_bounds_fire_never_and_always() {
        let never = Rule { rate: 0.0, one_shot: None };
        let always = Rule { rate: 1.0, one_shot: None };
        for idx in 0..128u64 {
            assert!(!never.fires(3, Site::Decode, idx));
            assert!(always.fires(3, Site::Decode, idx));
        }
        // a 30% rule fires roughly 30% of the time
        let r = Rule { rate: 0.3, one_shot: None };
        let n = (0..4096u64).filter(|&i| r.fires(1, Site::Decode, i))
            .count();
        assert!((900..1600).contains(&n), "30% of 4096 ~ 1229, got {n}");
    }

    #[test]
    fn one_shot_fires_exactly_its_index() {
        let r = Rule { rate: 0.0, one_shot: Some(5) };
        let fired: Vec<u64> =
            (0..32u64).filter(|&i| r.fires(9, Site::IoFsync, i)).collect();
        assert_eq!(fired, vec![5]);
        // one_shot wins over rate
        let both = Rule { rate: 1.0, one_shot: Some(2) };
        assert!(!both.fires(0, Site::IoShort, 1));
        assert!(both.fires(0, Site::IoShort, 2));
    }

    #[test]
    fn spec_grammar_round_trips() {
        let p = parse("seed=7, io_write=@3, decode=0.05, latency=1, \
                       latency_ms=50").unwrap();
        assert_eq!(p.seed, 7);
        assert_eq!(p.rules[Site::IoWrite as usize].one_shot, Some(3));
        assert!((p.rules[Site::Decode as usize].rate - 0.05).abs() < 1e-9);
        assert_eq!(p.rules[Site::Latency as usize].rate, 1.0);
        assert_eq!(p.latency, Duration::from_millis(50));
        assert_eq!(p.rules[Site::IoRename as usize], Rule::default());
        // empty spec is the default plan
        assert_eq!(parse("").unwrap(), FaultPlan::default());
    }

    #[test]
    fn spec_errors_name_the_problem() {
        for (bad, want) in [
            ("io_write", "not key=value"),
            ("warp_core=0.5", "unknown fault site"),
            ("decode=1.5", "out of [0, 1]"),
            ("io_write=@x", "not an integer"),
            ("seed=zebra", "not an integer"),
        ] {
            let msg = parse(bad).unwrap_err().to_string();
            assert!(msg.contains(want), "'{bad}' -> '{msg}'");
        }
    }

    #[test]
    fn default_plan_install_cannot_fire_and_clears() {
        // all-default rules: enabling is observable but nothing can fire,
        // so this is safe alongside concurrently-running io tests
        install(FaultPlan::default());
        assert!(enabled());
        assert!(io_error(Site::IoWrite).is_none());
        clear();
        assert!(!enabled());
        // disabled fast path: no counter traffic at all
        let before = occurrences(Site::Decode);
        maybe_decode_panic();
        maybe_latency();
        assert_eq!(occurrences(Site::Decode), before,
                   "disabled sites must not advance counters");
    }

    #[test]
    fn site_names_round_trip() {
        for s in Site::ALL {
            assert_eq!(Site::by_name(s.name()), Some(s));
        }
        assert_eq!(Site::by_name("nope"), None);
    }
}
