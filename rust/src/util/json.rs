//! Minimal JSON parser + serializer (serde is unavailable offline).
//!
//! Full JSON grammar: objects, arrays, strings with escapes (incl. \uXXXX),
//! numbers, booleans, null.  Parsing is recursive-descent over bytes;
//! object key order is preserved (Vec of pairs) so manifests round-trip
//! deterministically.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

// ---------------------------------------------------------------------------
// accessors
// ---------------------------------------------------------------------------

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key)
                .map(|(_, v)| v),
            _ => None,
        }
    }

    /// Like `get` but returns an error naming the missing key.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key '{key}'"))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 { Some(n as usize) } else { None }
        })
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(v) => Some(v),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// Object fields as a map (for iteration in sorted order).
    pub fn to_map(&self) -> BTreeMap<String, Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().cloned().collect(),
            _ => BTreeMap::new(),
        }
    }
}

// ---------------------------------------------------------------------------
// parser
// ---------------------------------------------------------------------------

pub fn parse(text: &str) -> Result<Json, JsonError> {
    let bytes = text.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            pairs.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(pairs));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000C}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            self.i += 1;
                            let cp = self.hex4()?;
                            // surrogate pairs
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.peek() == Some(b'\\') {
                                    self.i += 1;
                                    self.expect(b'u')?;
                                    let lo = self.hex4()?;
                                    let c = 0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo - 0xDC00);
                                    char::from_u32(c)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            out.push(ch.ok_or_else(
                                || self.err("bad \\u escape"))?);
                            self.i -= 1; // compensate the += 1 below
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full UTF-8 scalar
                    let start = self.i;
                    let s = &self.b[start..];
                    let len = utf8_len(s[0]);
                    if s.len() < len {
                        return Err(self.err("bad utf-8"));
                    }
                    out.push_str(
                        std::str::from_utf8(&s[..len])
                            .map_err(|_| self.err("bad utf-8"))?);
                    self.i += len;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.peek().ok_or_else(|| self.err("eof in \\u"))?;
            v = v * 16 + (c as char).to_digit(16)
                .ok_or_else(|| self.err("bad hex"))?;
            self.i += 1;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(),
                       Some(c) if c.is_ascii_digit() || c == b'.'
                           || c == b'e' || c == b'E' || c == b'+'
                           || c == b'-') {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i]).ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

fn utf8_len(b: u8) -> usize {
    if b < 0x80 { 1 } else if b < 0xE0 { 2 } else if b < 0xF0 { 3 } else { 4 }
}

// ---------------------------------------------------------------------------
// serializer
// ---------------------------------------------------------------------------

pub fn write(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(true) => out.push_str("true"),
        Json::Bool(false) => out.push_str("false"),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => write_string(s, out),
        Json::Arr(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write(item, out);
            }
            out.push(']');
        }
        Json::Obj(pairs) => {
            out.push('{');
            for (i, (k, val)) in pairs.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_string(k, out);
                out.push(':');
                write(val, out);
            }
            out.push('}');
        }
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 =>
                out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

pub fn to_string(v: &Json) -> String {
    let mut s = String::new();
    write(v, &mut s);
    s
}

// builder conveniences ------------------------------------------------------

pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

pub fn arr_f64(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": null, "d": true},
                      "s": "hi\nthere \"q\" é"}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2].as_f64(),
                   Some(-300.0));
        assert_eq!(v.get("s").unwrap().as_str(),
                   Some("hi\nthere \"q\" é"));
        let text = to_string(&v);
        let v2 = parse(&text).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("{\"a\":1} x").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn nested_depth() {
        let mut src = String::new();
        for _ in 0..100 {
            src.push('[');
        }
        src.push('0');
        for _ in 0..100 {
            src.push(']');
        }
        assert!(parse(&src).is_ok());
    }

    #[test]
    fn surrogate_pair() {
        let v = parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("😀"));
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(to_string(&Json::Num(42.0)), "42");
        assert_eq!(to_string(&Json::Num(0.5)), "0.5");
    }

    #[test]
    fn key_order_preserved() {
        let v = parse(r#"{"z":1,"a":2}"#).unwrap();
        let keys: Vec<&str> = v.as_obj().unwrap().iter()
            .map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["z", "a"]);
    }
}
