//! Guarded SIMD lane kernels for the native hot paths.
//!
//! Two dispatch levels exist: a portable scalar fallback and an x86_64
//! AVX2 path (`std::arch` intrinsics behind runtime
//! `is_x86_feature_detected!`).  The contract every kernel here obeys —
//! and `tests/simd_props.rs` pins — is **bit-for-bit identity across
//! dispatch levels for f32**: the AVX2 bodies perform exactly the
//! per-lane operation sequence of their scalar twins (multiply then add,
//! never FMA — a fused multiply-add rounds once where the scalar code
//! rounds twice, which would break `dense_tiling_is_exact` and the
//! golden vectors), so switching levels never changes a result, only its
//! speed.
//!
//! Dispatch: [`level()`] caches [`detect_level()`] (CPU feature probe +
//! the `MINRNN_SIMD` environment variable; `MINRNN_SIMD=off` — or
//! `scalar`/`0` — pins the fallback).  [`set_forced`] overrides it for
//! tests and the bench harness.
//!
//! The transcendental kernels ([`exp_f32`]/[`log1p_f32`] and the slice
//! forms [`exp_inplace`]/[`log1p_exp_inplace`]) use Cephes-style
//! polynomials rather than libm so the scalar and vector paths share one
//! op-for-op definition; they agree with libm to a few f32 ulps (unit
//! tests below), well inside the scan's golden-error budget.  Arguments
//! are assumed non-NaN (the scan feeds finite gate values; `-inf` from
//! an empty accumulator clamps to `exp(EXP_LO) ≈ 1e-38` whose `log1p`
//! is exactly `0.0`, so `logaddexp(-inf, x) == x` still holds exactly).
//!
//! The int8 tile kernel ([`dense_tile16_q8`]) dequantizes per-tile-scaled
//! weights (see `backend::native::quant`, [`K_TILE`] input rows × 16
//! output columns per scale) inside the register tile:
//! `wde = scale * (q as f32); acc += x * wde` — the same two-rounding
//! order at both dispatch levels, so int8 results are also bit-identical
//! across levels (the *budgeted* error is int8-vs-f32, not
//! scalar-vs-vector).

use std::sync::atomic::{AtomicU8, Ordering};

/// Input rows per quantization tile (columns are tiled by the fixed
/// 16-wide output tile).  `backend::native::quant` derives its scale
/// grid from this.
pub const K_TILE: usize = 64;

/// Dispatch level for the lane kernels.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Level {
    /// Portable scalar fallback (always available).
    Scalar,
    /// x86_64 AVX2 f32x8 lanes.
    Avx2,
}

static FORCED: AtomicU8 = AtomicU8::new(0);
static DETECTED: AtomicU8 = AtomicU8::new(0);

fn code(l: Level) -> u8 {
    match l {
        Level::Scalar => 1,
        Level::Avx2 => 2,
    }
}

fn decode(c: u8) -> Option<Level> {
    match c {
        1 => Some(Level::Scalar),
        2 => Some(Level::Avx2),
        _ => None,
    }
}

/// Resolve a `MINRNN_SIMD` setting against CPU capability — pure, so
/// the env grammar is unit-testable without process-global env races.
/// `off`/`scalar`/`0` pin the fallback; anything else (including unset)
/// uses the best level the CPU supports.
pub fn parse_level(env: Option<&str>, avx2_available: bool) -> Level {
    if let Some(s) = env.map(str::trim) {
        if s.eq_ignore_ascii_case("off") || s.eq_ignore_ascii_case("scalar")
            || s == "0" {
            return Level::Scalar;
        }
    }
    if avx2_available {
        Level::Avx2
    } else {
        Level::Scalar
    }
}

/// Probe the environment: `MINRNN_SIMD` + runtime CPU feature detection.
pub fn detect_level() -> Level {
    #[cfg(target_arch = "x86_64")]
    let avx2 = std::arch::is_x86_feature_detected!("avx2");
    #[cfg(not(target_arch = "x86_64"))]
    let avx2 = false;
    parse_level(std::env::var("MINRNN_SIMD").ok().as_deref(), avx2)
}

/// The active dispatch level: a forced override ([`set_forced`]) wins,
/// else the cached [`detect_level`] probe.
pub fn level() -> Level {
    if let Some(l) = decode(FORCED.load(Ordering::Relaxed)) {
        return l;
    }
    if let Some(l) = decode(DETECTED.load(Ordering::Relaxed)) {
        return l;
    }
    let l = detect_level();
    DETECTED.store(code(l), Ordering::Relaxed);
    l
}

/// Force a dispatch level (tests / bench); `None` restores detection.
/// Forcing [`Level::Avx2`] on a CPU without AVX2 is the caller's bug.
pub fn set_forced(l: Option<Level>) {
    FORCED.store(l.map(code).unwrap_or(0), Ordering::Relaxed);
}

// ---------------------------------------------------------------------------
// Polynomial exp / log1p (shared scalar definition; AVX2 mirrors it)
// ---------------------------------------------------------------------------

/// Largest input the polynomial exp accepts before clamping (≈ ln(f32
/// MAX); above it the result saturates like libm's overflow behavior).
pub const EXP_HI: f32 = 88.72283;
/// Smallest input (≈ ln of the smallest normal); below it results clamp
/// to ~1.18e-38, which is exactly absorbed by `log1p` (→ 0.0).
pub const EXP_LO: f32 = -87.33655;

const LOG2E: f32 = 1.442695;
const LN2_HI: f32 = 0.693359375;
const LN2_LO: f32 = -2.1219444e-4;

const EXP_P0: f32 = 1.98756915e-4;
const EXP_P1: f32 = 1.3981999e-3;
const EXP_P2: f32 = 8.333452e-3;
const EXP_P3: f32 = 4.16658e-2;
const EXP_P4: f32 = 1.6666666e-1;
const EXP_P5: f32 = 5.0000001e-1;

const SQRT2: f32 = 1.4142135;

const LOG_P0: f32 = 7.0376836e-2;
const LOG_P1: f32 = -1.1514610e-1;
const LOG_P2: f32 = 1.1676998e-1;
const LOG_P3: f32 = -1.2420140e-1;
const LOG_P4: f32 = 1.4249322e-1;
const LOG_P5: f32 = -1.6668057e-1;
const LOG_P6: f32 = 2.0000714e-1;
const LOG_P7: f32 = -2.4999993e-1;
const LOG_P8: f32 = 3.3333331e-1;

/// Polynomial `e^x` (Cephes expf form): range-reduce with Cody–Waite
/// two-part ln 2, degree-6 polynomial, scale by `2^n` via exponent-bit
/// construction.  Exactly `1.0` at `x = 0`.  The op order here is the
/// normative definition the AVX2 path mirrors lane for lane.
#[inline]
pub fn exp_f32(x: f32) -> f32 {
    let x = x.min(EXP_HI).max(EXP_LO);
    let n = (x * LOG2E + 0.5).floor();
    let r = (x - n * LN2_HI) - n * LN2_LO;
    let mut p = EXP_P0;
    p = p * r + EXP_P1;
    p = p * r + EXP_P2;
    p = p * r + EXP_P3;
    p = p * r + EXP_P4;
    p = p * r + EXP_P5;
    let t = (p * r) * r;
    let y = (t + r) + 1.0;
    // 2^n: n ∈ [-126, 128] after the clamp; peel one doubling off the
    // n = 128 edge so the exponent-bit trick never overflows the field
    let hi = n > 127.0;
    let n = if hi { n - 1.0 } else { n };
    let two = if hi { 2.0f32 } else { 1.0 };
    let p2 = f32::from_bits((((n as i32) + 127) as u32) << 23);
    (y * p2) * two
}

/// Polynomial `ln(1 + y)` for `y ∈ [0, 1]` (Cephes logf form on
/// `z = 1 + y ∈ [1, 2]`).  Exactly `0.0` at `y = 0` — which is what
/// makes the branch-free `logaddexp` below exact when one operand is
/// `-inf` (or merely far below the other).  Normative op order.
#[inline]
pub fn log1p_f32(y: f32) -> f32 {
    let z = 1.0 + y;
    let big = z >= SQRT2;
    let z = if big { z * 0.5 } else { z };
    let e = if big { 1.0f32 } else { 0.0 };
    let t = z - 1.0;
    let w = t * t;
    let mut p = LOG_P0;
    p = p * t + LOG_P1;
    p = p * t + LOG_P2;
    p = p * t + LOG_P3;
    p = p * t + LOG_P4;
    p = p * t + LOG_P5;
    p = p * t + LOG_P6;
    p = p * t + LOG_P7;
    p = p * t + LOG_P8;
    let p = (p * t) * w;
    let p = p + (-0.5) * w;
    let r = (t + p) + e * LN2_LO;
    r + e * LN2_HI
}

// ---------------------------------------------------------------------------
// Slice kernels
// ---------------------------------------------------------------------------

/// `buf[i] = exp(buf[i])` with the polynomial exp, dispatched.
pub fn exp_inplace(lvl: Level, buf: &mut [f32]) {
    #[cfg(target_arch = "x86_64")]
    if lvl == Level::Avx2 {
        unsafe { avx2::exp_inplace(buf) };
        return;
    }
    let _ = lvl;
    for v in buf.iter_mut() {
        *v = exp_f32(*v);
    }
}

/// `buf[i] = log1p(exp(buf[i]))` for non-positive inputs (the
/// `logaddexp` correction term), dispatched.
pub fn log1p_exp_inplace(lvl: Level, buf: &mut [f32]) {
    #[cfg(target_arch = "x86_64")]
    if lvl == Level::Avx2 {
        unsafe { avx2::log1p_exp_inplace(buf) };
        return;
    }
    let _ = lvl;
    for v in buf.iter_mut() {
        *v = log1p_f32(exp_f32(*v));
    }
}

/// One 16-wide f32 output tile of a row × matrix product:
/// `acc[j] = bias[j] + Σ_k x[k] · w[o + k·stride + j]`, `j ∈ 0..16`,
/// accumulated in strict k order with separate multiply and add — the
/// exact loop `Dense::apply_row_cols` has always run, now dispatched.
pub fn dense_tile16(lvl: Level, x: &[f32], w: &[f32], o: usize,
                    stride: usize, bias: &[f32], acc: &mut [f32; 16]) {
    assert!(bias.len() >= 16);
    assert!(x.is_empty() || w.len() >= o + (x.len() - 1) * stride + 16);
    #[cfg(target_arch = "x86_64")]
    if lvl == Level::Avx2 {
        unsafe { avx2::dense_tile16(x, w, o, stride, bias, acc) };
        return;
    }
    let _ = lvl;
    acc.copy_from_slice(&bias[..16]);
    for (k, &xv) in x.iter().enumerate() {
        let wrow = &w[o + k * stride..o + k * stride + 16];
        for j in 0..16 {
            acc[j] += xv * wrow[j];
        }
    }
}

/// The int8 twin of [`dense_tile16`]: weights arrive as `q: i8` plus one
/// f32 scale per ([`K_TILE`] input rows × this 16-column tile), looked
/// up as `scales[(k / K_TILE) * scale_stride + scale_col]`.  Dequantize
/// then accumulate: `wde = sc * (q as f32); acc[j] += x[k] * wde` — two
/// roundings per element at both dispatch levels, so scalar and AVX2
/// int8 results match bit for bit.
#[allow(clippy::too_many_arguments)]
pub fn dense_tile16_q8(lvl: Level, x: &[f32], q: &[i8], o: usize,
                       stride: usize, scales: &[f32], scale_stride: usize,
                       scale_col: usize, bias: &[f32],
                       acc: &mut [f32; 16]) {
    assert!(bias.len() >= 16);
    assert!(x.is_empty() || q.len() >= o + (x.len() - 1) * stride + 16);
    assert!(x.is_empty()
            || scales.len() >= (x.len() - 1) / K_TILE * scale_stride
                + scale_col + 1);
    #[cfg(target_arch = "x86_64")]
    if lvl == Level::Avx2 {
        unsafe {
            avx2::dense_tile16_q8(x, q, o, stride, scales, scale_stride,
                                  scale_col, bias, acc)
        };
        return;
    }
    let _ = lvl;
    acc.copy_from_slice(&bias[..16]);
    for (k, &xv) in x.iter().enumerate() {
        let sc = scales[(k / K_TILE) * scale_stride + scale_col];
        let qrow = &q[o + k * stride..o + k * stride + 16];
        for j in 0..16 {
            let wde = sc * (qrow[j] as f32);
            acc[j] += xv * wde;
        }
    }
}

// ---------------------------------------------------------------------------
// AVX2 bodies — lane-for-lane mirrors of the scalar definitions above
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::*;
    use std::arch::x86_64::*;

    /// 8-lane mirror of [`exp_f32`]: same clamp, same Cody–Waite
    /// reduction, same Horner order, mul+add only (no FMA).
    #[inline]
    unsafe fn exp_ps(x: __m256) -> __m256 {
        let x = _mm256_max_ps(_mm256_min_ps(x, _mm256_set1_ps(EXP_HI)),
                              _mm256_set1_ps(EXP_LO));
        let n = _mm256_floor_ps(_mm256_add_ps(
            _mm256_mul_ps(x, _mm256_set1_ps(LOG2E)),
            _mm256_set1_ps(0.5)));
        let r = _mm256_sub_ps(
            _mm256_sub_ps(x, _mm256_mul_ps(n, _mm256_set1_ps(LN2_HI))),
            _mm256_mul_ps(n, _mm256_set1_ps(LN2_LO)));
        let mut p = _mm256_set1_ps(EXP_P0);
        p = _mm256_add_ps(_mm256_mul_ps(p, r), _mm256_set1_ps(EXP_P1));
        p = _mm256_add_ps(_mm256_mul_ps(p, r), _mm256_set1_ps(EXP_P2));
        p = _mm256_add_ps(_mm256_mul_ps(p, r), _mm256_set1_ps(EXP_P3));
        p = _mm256_add_ps(_mm256_mul_ps(p, r), _mm256_set1_ps(EXP_P4));
        p = _mm256_add_ps(_mm256_mul_ps(p, r), _mm256_set1_ps(EXP_P5));
        let t = _mm256_mul_ps(_mm256_mul_ps(p, r), r);
        let y = _mm256_add_ps(_mm256_add_ps(t, r), _mm256_set1_ps(1.0));
        let hi = _mm256_cmp_ps::<_CMP_GT_OQ>(n, _mm256_set1_ps(127.0));
        let n = _mm256_sub_ps(n, _mm256_and_ps(hi, _mm256_set1_ps(1.0)));
        let two = _mm256_blendv_ps(_mm256_set1_ps(1.0),
                                   _mm256_set1_ps(2.0), hi);
        let ni = _mm256_cvtps_epi32(n);
        let bits = _mm256_slli_epi32::<23>(
            _mm256_add_epi32(ni, _mm256_set1_epi32(127)));
        let p2 = _mm256_castsi256_ps(bits);
        _mm256_mul_ps(_mm256_mul_ps(y, p2), two)
    }

    /// 8-lane mirror of [`log1p_f32`].
    #[inline]
    unsafe fn log1p_ps(y: __m256) -> __m256 {
        let z = _mm256_add_ps(_mm256_set1_ps(1.0), y);
        let big = _mm256_cmp_ps::<_CMP_GE_OQ>(z, _mm256_set1_ps(SQRT2));
        let z = _mm256_mul_ps(z, _mm256_blendv_ps(_mm256_set1_ps(1.0),
                                                  _mm256_set1_ps(0.5),
                                                  big));
        let e = _mm256_and_ps(big, _mm256_set1_ps(1.0));
        let t = _mm256_sub_ps(z, _mm256_set1_ps(1.0));
        let w = _mm256_mul_ps(t, t);
        let mut p = _mm256_set1_ps(LOG_P0);
        p = _mm256_add_ps(_mm256_mul_ps(p, t), _mm256_set1_ps(LOG_P1));
        p = _mm256_add_ps(_mm256_mul_ps(p, t), _mm256_set1_ps(LOG_P2));
        p = _mm256_add_ps(_mm256_mul_ps(p, t), _mm256_set1_ps(LOG_P3));
        p = _mm256_add_ps(_mm256_mul_ps(p, t), _mm256_set1_ps(LOG_P4));
        p = _mm256_add_ps(_mm256_mul_ps(p, t), _mm256_set1_ps(LOG_P5));
        p = _mm256_add_ps(_mm256_mul_ps(p, t), _mm256_set1_ps(LOG_P6));
        p = _mm256_add_ps(_mm256_mul_ps(p, t), _mm256_set1_ps(LOG_P7));
        p = _mm256_add_ps(_mm256_mul_ps(p, t), _mm256_set1_ps(LOG_P8));
        let p = _mm256_mul_ps(_mm256_mul_ps(p, t), w);
        let p = _mm256_add_ps(p, _mm256_mul_ps(_mm256_set1_ps(-0.5), w));
        let r = _mm256_add_ps(_mm256_add_ps(t, p),
                              _mm256_mul_ps(e, _mm256_set1_ps(LN2_LO)));
        _mm256_add_ps(r, _mm256_mul_ps(e, _mm256_set1_ps(LN2_HI)))
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn exp_inplace(buf: &mut [f32]) {
        let n = buf.len();
        let ptr = buf.as_mut_ptr();
        let mut i = 0;
        while i + 8 <= n {
            let v = _mm256_loadu_ps(ptr.add(i));
            _mm256_storeu_ps(ptr.add(i), exp_ps(v));
            i += 8;
        }
        for v in &mut buf[i..] {
            *v = exp_f32(*v);
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn log1p_exp_inplace(buf: &mut [f32]) {
        let n = buf.len();
        let ptr = buf.as_mut_ptr();
        let mut i = 0;
        while i + 8 <= n {
            let v = _mm256_loadu_ps(ptr.add(i));
            _mm256_storeu_ps(ptr.add(i), log1p_ps(exp_ps(v)));
            i += 8;
        }
        for v in &mut buf[i..] {
            *v = log1p_f32(exp_f32(*v));
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn dense_tile16(x: &[f32], w: &[f32], o: usize,
                               stride: usize, bias: &[f32],
                               acc: &mut [f32; 16]) {
        let bp = bias.as_ptr();
        let mut a0 = _mm256_loadu_ps(bp);
        let mut a1 = _mm256_loadu_ps(bp.add(8));
        let wp = w.as_ptr();
        for (k, &xv) in x.iter().enumerate() {
            let xb = _mm256_set1_ps(xv);
            let row = wp.add(o + k * stride);
            let w0 = _mm256_loadu_ps(row);
            let w1 = _mm256_loadu_ps(row.add(8));
            a0 = _mm256_add_ps(a0, _mm256_mul_ps(xb, w0));
            a1 = _mm256_add_ps(a1, _mm256_mul_ps(xb, w1));
        }
        _mm256_storeu_ps(acc.as_mut_ptr(), a0);
        _mm256_storeu_ps(acc.as_mut_ptr().add(8), a1);
    }

    #[target_feature(enable = "avx2")]
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn dense_tile16_q8(x: &[f32], q: &[i8], o: usize,
                                  stride: usize, scales: &[f32],
                                  scale_stride: usize, scale_col: usize,
                                  bias: &[f32], acc: &mut [f32; 16]) {
        let bp = bias.as_ptr();
        let mut a0 = _mm256_loadu_ps(bp);
        let mut a1 = _mm256_loadu_ps(bp.add(8));
        let qp = q.as_ptr();
        for (k, &xv) in x.iter().enumerate() {
            let sc = _mm256_set1_ps(
                scales[(k / K_TILE) * scale_stride + scale_col]);
            let xb = _mm256_set1_ps(xv);
            let row = qp.add(o + k * stride);
            let qv = _mm_loadu_si128(row as *const __m128i);
            let lo = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(qv));
            let hi = _mm256_cvtepi32_ps(
                _mm256_cvtepi8_epi32(_mm_srli_si128::<8>(qv)));
            let w0 = _mm256_mul_ps(sc, lo);
            let w1 = _mm256_mul_ps(sc, hi);
            a0 = _mm256_add_ps(a0, _mm256_mul_ps(xb, w0));
            a1 = _mm256_add_ps(a1, _mm256_mul_ps(xb, w1));
        }
        _mm256_storeu_ps(acc.as_mut_ptr(), a0);
        _mm256_storeu_ps(acc.as_mut_ptr().add(8), a1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn env_grammar_pins_the_fallback() {
        assert_eq!(parse_level(Some("off"), true), Level::Scalar);
        assert_eq!(parse_level(Some("OFF"), true), Level::Scalar);
        assert_eq!(parse_level(Some("scalar"), true), Level::Scalar);
        assert_eq!(parse_level(Some("0"), true), Level::Scalar);
        assert_eq!(parse_level(Some("on"), true), Level::Avx2);
        assert_eq!(parse_level(None, true), Level::Avx2);
        assert_eq!(parse_level(None, false), Level::Scalar);
        assert_eq!(parse_level(Some("on"), false), Level::Scalar);
    }

    #[test]
    fn poly_exp_tracks_libm_to_a_few_ulps() {
        // sweep the range the scan feeds (log-space values are ≤ 0 on
        // the correction path; the output exp sees moderate magnitudes)
        let mut worst = 0.0f64;
        let mut x = -87.0f32;
        while x < 88.0 {
            let got = exp_f32(x) as f64;
            let want = (x as f64).exp();
            let rel = ((got - want) / want).abs();
            if rel > worst {
                worst = rel;
            }
            x += 0.0137;
        }
        assert!(worst < 5e-7, "poly exp rel err {worst}");
        assert_eq!(exp_f32(0.0), 1.0);
        // clamped underflow stays positive (log1p absorbs it exactly)
        assert!(exp_f32(-1e30) > 0.0);
        assert!(exp_f32(f32::NEG_INFINITY) > 0.0);
    }

    #[test]
    fn poly_log1p_tracks_libm_on_the_unit_interval() {
        let mut worst = 0.0f64;
        let mut y = 0.0f32;
        while y <= 1.0 {
            let got = log1p_f32(y) as f64;
            let want = (y as f64).ln_1p();
            let err = (got - want).abs() / want.abs().max(1e-3);
            if err > worst {
                worst = err;
            }
            y += 0.00113;
        }
        assert!(worst < 5e-7, "poly log1p rel err {worst}");
        assert_eq!(log1p_f32(0.0), 0.0);
        // the tiny clamped exp output rounds to z = 1.0 → exactly 0
        assert_eq!(log1p_f32(exp_f32(f32::NEG_INFINITY)), 0.0);
    }

    #[test]
    fn logaddexp_identity_survives_the_branch_free_form() {
        // m + log1p(exp(-|d|)) == logaddexp(a, b) to f32 accuracy
        let cases = [(-3.0f64, -3.5f64), (0.25, 0.25), (-40.0, 0.0),
                     (f64::NEG_INFINITY, -2.0)];
        for (a, b) in cases {
            let m = if a > b { a } else { b };
            let d = (-(a - b).abs()) as f32;
            let got = m + log1p_f32(exp_f32(d)) as f64;
            let want = if a == f64::NEG_INFINITY {
                b
            } else {
                let mx = a.max(b);
                mx + ((a - mx).exp() + (b - mx).exp()).ln()
            };
            assert!((got - want).abs() < 1e-6,
                    "lae({a},{b}) = {got}, want {want}");
        }
    }

    #[test]
    fn scalar_tile_matches_a_naive_product() {
        let d_in = 23;
        let stride = 40; // d_out
        let x: Vec<f32> = (0..d_in)
            .map(|i| ((i * 37 % 19) as f32 - 9.0) * 0.17).collect();
        let w: Vec<f32> = (0..d_in * stride)
            .map(|i| ((i * 53 % 31) as f32 - 15.0) * 0.061).collect();
        let bias: Vec<f32> = (0..16).map(|i| i as f32 * 0.25).collect();
        let o = 8;
        let mut acc = [0.0f32; 16];
        dense_tile16(Level::Scalar, &x, &w, o, stride, &bias, &mut acc);
        for j in 0..16 {
            let mut want = bias[j];
            for (k, &xv) in x.iter().enumerate() {
                want += xv * w[o + k * stride + j];
            }
            assert_eq!(acc[j], want, "lane {j}");
        }
    }

    #[test]
    fn q8_tile_dequantizes_with_per_tile_scales() {
        let d_in = K_TILE + 9; // spans two scale tiles
        let stride = 16;
        let x: Vec<f32> = (0..d_in).map(|i| (i % 5) as f32 - 2.0).collect();
        let q: Vec<i8> = (0..d_in * stride)
            .map(|i| ((i * 7 % 255) as i32 - 127) as i8).collect();
        let scales = [0.5f32, 0.25];
        let bias = [1.0f32; 16];
        let mut acc = [0.0f32; 16];
        dense_tile16_q8(Level::Scalar, &x, &q, 0, stride, &scales, 1, 0,
                        &bias, &mut acc);
        for j in 0..16 {
            let mut want = 1.0f32;
            for (k, &xv) in x.iter().enumerate() {
                let sc = scales[k / K_TILE];
                want += xv * (sc * (q[k * stride + j] as f32));
            }
            assert_eq!(acc[j], want, "lane {j}");
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx2_kernels_match_scalar_bit_for_bit() {
        if !std::arch::is_x86_feature_detected!("avx2") {
            eprintln!("avx2 unavailable; scalar-only box — skipping");
            return;
        }
        // transcendental slices, odd length for an unaligned tail
        let src: Vec<f32> = (0..67)
            .map(|i| -0.13 * i as f32 + 0.5 - (i % 7) as f32).collect();
        let mut a = src.clone();
        let mut b = src.clone();
        exp_inplace(Level::Scalar, &mut a);
        exp_inplace(Level::Avx2, &mut b);
        assert_eq!(a, b, "exp slice");
        let src2: Vec<f32> = (0..67).map(|i| -(i as f32) * 0.31).collect();
        let mut a = src2.clone();
        let mut b = src2;
        log1p_exp_inplace(Level::Scalar, &mut a);
        log1p_exp_inplace(Level::Avx2, &mut b);
        assert_eq!(a, b, "log1p∘exp slice");
        // dense tiles
        let d_in = 2 * K_TILE + 5;
        let stride = 48;
        let x: Vec<f32> = (0..d_in)
            .map(|i| ((i * 29 % 23) as f32 - 11.0) * 0.09).collect();
        let w: Vec<f32> = (0..d_in * stride)
            .map(|i| ((i * 41 % 37) as f32 - 18.0) * 0.031).collect();
        let bias: Vec<f32> = (0..stride).map(|i| i as f32 * 0.1).collect();
        for o in [0usize, 16, 32] {
            let mut s = [0.0f32; 16];
            let mut v = [0.0f32; 16];
            dense_tile16(Level::Scalar, &x, &w, o, stride, &bias[o..],
                         &mut s);
            dense_tile16(Level::Avx2, &x, &w, o, stride, &bias[o..],
                         &mut v);
            assert_eq!(s, v, "f32 tile at o={o}");
        }
        let q: Vec<i8> = (0..d_in * stride)
            .map(|i| ((i * 11 % 255) as i32 - 127) as i8).collect();
        let n_kt = d_in.div_ceil(K_TILE);
        let n_ct = stride / 16;
        let scales: Vec<f32> = (0..n_kt * n_ct)
            .map(|i| 0.01 + 0.003 * i as f32).collect();
        for (ct, o) in [(0usize, 0usize), (1, 16), (2, 32)] {
            let mut s = [0.0f32; 16];
            let mut v = [0.0f32; 16];
            dense_tile16_q8(Level::Scalar, &x, &q, o, stride, &scales,
                            n_ct, ct, &bias[o..], &mut s);
            dense_tile16_q8(Level::Avx2, &x, &q, o, stride, &scales,
                            n_ct, ct, &bias[o..], &mut v);
            assert_eq!(s, v, "q8 tile at o={o}");
        }
    }
}
