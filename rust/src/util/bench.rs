//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Warmup → adaptive iteration count → trimmed statistics.  Used by every
//! `rust/benches/*.rs` entry point (harness = false) and by `minrnn bench`.

use std::time::{Duration, Instant};

use super::stats;

#[derive(Clone, Debug)]
pub struct BenchConfig {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_iters: usize,
    pub max_iters: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup: Duration::from_millis(200),
            measure: Duration::from_secs(1),
            min_iters: 5,
            max_iters: 1000,
        }
    }
}

impl BenchConfig {
    /// Quick config for expensive end-to-end benches.
    pub fn quick() -> Self {
        BenchConfig {
            warmup: Duration::from_millis(50),
            measure: Duration::from_millis(300),
            min_iters: 3,
            max_iters: 50,
        }
    }
}

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub median_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn mean_ms(&self) -> f64 {
        self.mean_s * 1e3
    }

    pub fn mean_us(&self) -> f64 {
        self.mean_s * 1e6
    }

    pub fn line(&self) -> String {
        format!("{:40} {:>10.3} ms ±{:>8.3}  (median {:.3}, p95 {:.3}, n={})",
                self.name, self.mean_s * 1e3, self.std_s * 1e3,
                self.median_s * 1e3, self.p95_s * 1e3, self.iters)
    }
}

/// Run `f` under the harness.  `f` should perform one complete operation.
pub fn bench<F: FnMut()>(name: &str, cfg: &BenchConfig,
                         mut f: F) -> BenchResult {
    // warmup
    let start = Instant::now();
    while start.elapsed() < cfg.warmup {
        f();
    }
    // measure
    let mut samples: Vec<f64> = Vec::new();
    let begin = Instant::now();
    while (begin.elapsed() < cfg.measure || samples.len() < cfg.min_iters)
        && samples.len() < cfg.max_iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    summarize(name, &samples)
}

/// Summarize raw per-iteration samples (trims the top 5% as outliers when
/// enough samples exist).
pub fn summarize(name: &str, samples: &[f64]) -> BenchResult {
    assert!(!samples.is_empty());
    let mut v = samples.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let keep = if v.len() >= 20 { v.len() * 95 / 100 } else { v.len() };
    let trimmed = &v[..keep.max(1)];
    BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        mean_s: stats::mean(trimmed),
        std_s: stats::std(trimmed),
        median_s: stats::percentile(trimmed, 50.0),
        p95_s: stats::percentile(&v, 95.0),
        min_s: v[0],
    }
}

/// Current process peak RSS in bytes (VmHWM from /proc; Linux only).
pub fn peak_rss_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: u64 = rest.trim().trim_end_matches(" kB")
                .trim().parse().ok()?;
            return Some(kb * 1024);
        }
    }
    None
}

/// Current process RSS in bytes.
pub fn rss_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmRSS:") {
            let kb: u64 = rest.trim().trim_end_matches(" kB")
                .trim().parse().ok()?;
            return Some(kb * 1024);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_sleep_duration() {
        let cfg = BenchConfig {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(60),
            min_iters: 3,
            max_iters: 30,
        };
        let r = bench("sleep2ms", &cfg,
                      || std::thread::sleep(Duration::from_millis(2)));
        assert!(r.mean_ms() >= 1.8, "mean {}", r.mean_ms());
        assert!(r.mean_ms() < 12.0, "mean {}", r.mean_ms());
        assert!(r.iters >= 3);
    }

    #[test]
    fn summarize_stats() {
        let r = summarize("x", &[1.0, 2.0, 3.0]);
        assert!((r.mean_s - 2.0).abs() < 1e-12);
        assert_eq!(r.min_s, 1.0);
        assert_eq!(r.iters, 3);
    }

    #[test]
    fn rss_readable() {
        assert!(rss_bytes().unwrap() > 0);
        assert!(peak_rss_bytes().unwrap() >= rss_bytes().unwrap() / 2);
    }
}
