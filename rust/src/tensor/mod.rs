//! Host-side tensors: the currency between data generators, the PJRT
//! runtime, and checkpoints.  Thin on purpose — all heavy math happens
//! inside the AOT-compiled XLA executables; the host only builds batches
//! and interprets scalar outputs.

use anyhow::{bail, Result};

pub use crate::util::io::TensorData;

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub dims: Vec<usize>,
    pub data: TensorData,
}

impl Tensor {
    pub fn f32(dims: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len(),
                   "shape/data mismatch: {:?} vs {}", dims, data.len());
        Tensor { dims, data: TensorData::F32(data) }
    }

    pub fn i32(dims: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len(),
                   "shape/data mismatch: {:?} vs {}", dims, data.len());
        Tensor { dims, data: TensorData::I32(data) }
    }

    pub fn zeros_f32(dims: Vec<usize>) -> Self {
        let n = dims.iter().product();
        Tensor::f32(dims, vec![0.0; n])
    }

    pub fn scalar_f32(x: f32) -> Self {
        Tensor::f32(vec![], vec![x])
    }

    pub fn scalar_i32(x: i32) -> Self {
        Tensor::i32(vec![], vec![x])
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype_name(&self) -> &'static str {
        match self.data {
            TensorData::F32(_) => "f32",
            TensorData::I32(_) => "i32",
            TensorData::I8(_) => "i8",
        }
    }

    /// Convert into an XLA literal (copies; shapes become i64).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.dims.iter().map(|&d| d as i64).collect();
        let lit = match &self.data {
            TensorData::F32(v) => xla::Literal::vec1(v),
            TensorData::I32(v) => xla::Literal::vec1(v),
            TensorData::I8(_) => {
                bail!("i8 tensors are host-only (quantized weights); \
                       no XLA literal conversion")
            }
        };
        Ok(lit.reshape(&dims)?)
    }

    /// Read an XLA literal back into a host tensor.
    pub fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize)
            .collect();
        let data = match shape.ty() {
            xla::ElementType::F32 => TensorData::F32(lit.to_vec::<f32>()?),
            xla::ElementType::S32 => TensorData::I32(lit.to_vec::<i32>()?),
            ty => bail!("unsupported literal element type {ty:?}"),
        };
        Ok(Tensor { dims, data })
    }

    pub fn scalar_value_f32(&self) -> Result<f32> {
        match (&self.data, self.len()) {
            (TensorData::F32(v), 1) => Ok(v[0]),
            _ => bail!("not an f32 scalar: dims {:?}", self.dims),
        }
    }
}

/// A training/eval batch as the exported executables expect it:
/// x (tokens or features), targets, loss mask.
#[derive(Clone, Debug)]
pub struct Batch {
    pub x: Tensor,
    pub targets: Tensor,
    pub mask: Tensor,
}

impl Batch {
    pub fn batch_size(&self) -> usize {
        self.x.dims[0]
    }

    pub fn seq_len(&self) -> usize {
        self.x.dims[1]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_meta() {
        let t = Tensor::f32(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.dtype_name(), "f32");
        let s = Tensor::scalar_i32(7);
        assert_eq!(s.dims, Vec::<usize>::new());
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::f32(vec![2, 2], vec![1.0; 3]);
    }

    #[test]
    fn literal_roundtrip() {
        let t = Tensor::f32(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(back, t);

        let ti = Tensor::i32(vec![3], vec![-1, 0, 5]);
        let back = Tensor::from_literal(&ti.to_literal().unwrap()).unwrap();
        assert_eq!(back, ti);
    }
}
