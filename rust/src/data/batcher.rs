//! Epoch batcher for finite datasets: seeded shuffling, drop-last batching,
//! and length-bucketing (minimizes padding for variable-length examples —
//! the Chomsky/LRA collate path).

use crate::util::rng::Rng;

/// Shuffled index iterator over `n` examples, `batch` at a time, full
/// batches only.  Reshuffles each epoch deterministically from the seed.
pub struct EpochBatcher {
    n: usize,
    batch: usize,
    order: Vec<usize>,
    cursor: usize,
    rng: Rng,
    pub epoch: usize,
}

impl EpochBatcher {
    pub fn new(n: usize, batch: usize, seed: u64) -> Self {
        assert!(batch >= 1 && n >= batch, "need n >= batch");
        let mut b = EpochBatcher {
            n,
            batch,
            order: (0..n).collect(),
            cursor: 0,
            rng: Rng::new(seed),
            epoch: 0,
        };
        b.rng.shuffle(&mut b.order);
        b
    }

    pub fn batches_per_epoch(&self) -> usize {
        self.n / self.batch
    }

    /// Next batch of indices; rolls into a fresh shuffled epoch at the end.
    pub fn next_batch(&mut self) -> &[usize] {
        if self.cursor + self.batch > self.n {
            self.rng.shuffle(&mut self.order);
            self.cursor = 0;
            self.epoch += 1;
        }
        let out = &self.order[self.cursor..self.cursor + self.batch];
        self.cursor += self.batch;
        out
    }
}

/// Group example indices by length into buckets of `batch` so each batch
/// pads to its own maximum (classic bucketing-by-length).
pub fn length_buckets(lengths: &[usize], batch: usize,
                      seed: u64) -> Vec<Vec<usize>> {
    let mut idx: Vec<usize> = (0..lengths.len()).collect();
    let mut rng = Rng::new(seed);
    rng.shuffle(&mut idx); // tie-break randomly before the stable sort
    idx.sort_by_key(|&i| lengths[i]);
    let mut buckets: Vec<Vec<usize>> = idx.chunks(batch)
        .filter(|c| c.len() == batch)
        .map(|c| c.to_vec())
        .collect();
    rng.shuffle(&mut buckets); // randomize bucket order per epoch
    buckets
}

/// Padding waste of a batching: Σ(max_len − len) / Σ max_len.
pub fn padding_waste(lengths: &[usize], buckets: &[Vec<usize>]) -> f64 {
    let mut pad = 0usize;
    let mut total = 0usize;
    for b in buckets {
        let max = b.iter().map(|&i| lengths[i]).max().unwrap_or(0);
        for &i in b {
            pad += max - lengths[i];
            total += max;
        }
    }
    if total == 0 { 0.0 } else { pad as f64 / total as f64 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_every_index_each_epoch() {
        let mut b = EpochBatcher::new(10, 2, 0);
        let mut seen = vec![0usize; 10];
        for _ in 0..5 {
            for &i in b.next_batch().to_vec().iter() {
                seen[i] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1), "{seen:?}");
        assert_eq!(b.epoch, 0);
        b.next_batch();
        assert_eq!(b.epoch, 1);
    }

    #[test]
    fn epochs_reshuffle_deterministically() {
        let collect = |seed: u64| -> Vec<Vec<usize>> {
            let mut b = EpochBatcher::new(8, 4, seed);
            (0..4).map(|_| b.next_batch().to_vec()).collect()
        };
        assert_eq!(collect(1), collect(1));
        assert_ne!(collect(1), collect(2));
    }

    #[test]
    fn drop_last_partial() {
        let mut b = EpochBatcher::new(7, 3, 0);
        assert_eq!(b.batches_per_epoch(), 2);
        b.next_batch();
        b.next_batch();
        // third call rolls the epoch instead of returning a short batch
        assert_eq!(b.next_batch().len(), 3);
        assert_eq!(b.epoch, 1);
    }

    #[test]
    fn bucketing_reduces_padding() {
        let mut rng = Rng::new(0);
        let lengths: Vec<usize> = (0..256)
            .map(|_| 5 + rng.usize_below(200)).collect();
        let bucketed = length_buckets(&lengths, 16, 0);
        // naive: random grouping
        let naive: Vec<Vec<usize>> = (0..lengths.len()).collect::<Vec<_>>()
            .chunks(16).map(|c| c.to_vec()).collect();
        let w_bucketed = padding_waste(&lengths, &bucketed);
        let w_naive = padding_waste(&lengths, &naive);
        assert!(w_bucketed < w_naive * 0.5,
                "bucketing should halve padding: {w_bucketed} vs {w_naive}");
        // every index appears exactly once
        let mut all: Vec<usize> = bucketed.iter().flatten().copied()
            .collect();
        all.sort_unstable();
        assert_eq!(all, (0..256).collect::<Vec<_>>());
    }
}
