//! Character-level language-modelling corpus (Figure 2).
//!
//! Substitution for the Shakespeare corpus (no network access —
//! DESIGN.md §3): a deterministic synthetic English-like text source.
//! Words are built from syllables, ranked by a Zipf law (natural-language
//! frequency shape), and chained with a first-order Markov process over
//! part-of-speech-like slots so local structure exists for a model to
//! learn; sentences carry capitalization and punctuation.
//!
//! The fixed 64-symbol character vocabulary covers a–z, space, newline,
//! digits and punctuation; `CharVocab` maps chars ↔ token ids.

use crate::tensor::{Batch, Tensor};
use crate::util::rng::{Rng, Zipf};

/// Fixed character inventory (64 symbols).  Index = token id.
pub const ALPHABET: &str =
    "\n abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ.,;:!?'-01";

#[derive(Clone, Debug)]
pub struct CharVocab {
    to_id: [i32; 128],
    chars: Vec<char>,
}

impl Default for CharVocab {
    fn default() -> Self {
        Self::new()
    }
}

impl CharVocab {
    pub fn new() -> Self {
        let chars: Vec<char> = ALPHABET.chars().collect();
        assert_eq!(chars.len(), 64);
        let mut to_id = [-1i32; 128];
        for (i, &c) in chars.iter().enumerate() {
            to_id[c as usize] = i as i32;
        }
        CharVocab { to_id, chars }
    }

    pub fn size(&self) -> usize {
        self.chars.len()
    }

    pub fn encode(&self, text: &str) -> Vec<i32> {
        text.chars()
            .map(|c| {
                let idx = c as usize;
                if idx < 128 && self.to_id[idx] >= 0 {
                    self.to_id[idx]
                } else {
                    1 // unknown → space
                }
            })
            .collect()
    }

    pub fn decode(&self, ids: &[i32]) -> String {
        ids.iter()
            .map(|&i| self.chars.get(i as usize).copied().unwrap_or('?'))
            .collect()
    }
}

/// Synthetic text generator.
pub struct CorpusGen {
    lexicon: Vec<String>,
    zipf: Zipf,
}

const ONSETS: &[&str] = &["b", "c", "d", "f", "g", "h", "l", "m", "n", "p",
                          "r", "s", "t", "v", "w", "th", "st", "ch", "br",
                          "gr", "sh", "pl", ""];
const NUCLEI: &[&str] = &["a", "e", "i", "o", "u", "ea", "ou", "ai", "ee"];
const CODAS: &[&str] = &["", "n", "r", "s", "t", "l", "d", "m", "ng", "st",
                         "ck"];

impl CorpusGen {
    pub fn new(lexicon_size: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0x1ec5_1ab1);
        let mut lexicon = Vec::with_capacity(lexicon_size);
        let mut seen = std::collections::HashSet::new();
        while lexicon.len() < lexicon_size {
            let syllables = 1 + rng.usize_below(3);
            let mut w = String::new();
            for _ in 0..syllables {
                w.push_str(ONSETS[rng.usize_below(ONSETS.len())]);
                w.push_str(NUCLEI[rng.usize_below(NUCLEI.len())]);
                w.push_str(CODAS[rng.usize_below(CODAS.len())]);
            }
            if w.len() >= 2 && seen.insert(w.clone()) {
                lexicon.push(w);
            }
        }
        CorpusGen { lexicon, zipf: Zipf::new(lexicon_size, 1.05) }
    }

    /// Generate roughly `n_chars` characters of text.
    pub fn generate(&self, n_chars: usize, seed: u64) -> String {
        let mut rng = Rng::new(seed);
        let mut out = String::with_capacity(n_chars + 64);
        let mut sentence_start = true;
        let mut words_in_sentence = 0;
        let mut sentences_in_par = 0;
        while out.len() < n_chars {
            let w = &self.lexicon[self.zipf.sample(&mut rng)];
            if sentence_start {
                let mut cs = w.chars();
                if let Some(c0) = cs.next() {
                    out.extend(c0.to_uppercase());
                    out.push_str(cs.as_str());
                }
                sentence_start = false;
            } else {
                out.push_str(w);
            }
            words_in_sentence += 1;
            let end_sentence = words_in_sentence >= 4 && rng.bool(0.22)
                || words_in_sentence >= 14;
            if end_sentence {
                let p = ['.', '.', '.', '!', '?'][rng.usize_below(5)];
                out.push(p);
                sentences_in_par += 1;
                if sentences_in_par >= 3 && rng.bool(0.4) {
                    out.push('\n');
                    sentences_in_par = 0;
                } else {
                    out.push(' ');
                }
                sentence_start = true;
                words_in_sentence = 0;
            } else if rng.bool(0.08) {
                out.push(',');
                out.push(' ');
            } else {
                out.push(' ');
            }
        }
        out.truncate(n_chars);
        out
    }
}

/// Token stream + window batcher for LM training.
pub struct LmDataset {
    pub tokens: Vec<i32>,
    pub vocab: CharVocab,
}

impl LmDataset {
    /// Build the synthetic corpus (train split uses `seed`, test `seed+1`).
    pub fn synthetic(n_chars: usize, seed: u64) -> Self {
        let gen = CorpusGen::new(800, 42);
        let vocab = CharVocab::new();
        let tokens = vocab.encode(&gen.generate(n_chars, seed));
        LmDataset { tokens, vocab }
    }

    pub fn from_text(text: &str) -> Self {
        let vocab = CharVocab::new();
        let tokens = vocab.encode(text);
        LmDataset { tokens, vocab }
    }

    /// Random (x, next-char targets, all-ones mask) batch of shape (b, t).
    pub fn batch(&self, rng: &mut Rng, b: usize, t: usize) -> Batch {
        assert!(self.tokens.len() > t + 1, "corpus shorter than window");
        let mut x = Vec::with_capacity(b * t);
        let mut y = Vec::with_capacity(b * t);
        for _ in 0..b {
            let start = rng.usize_below(self.tokens.len() - t - 1);
            x.extend(&self.tokens[start..start + t]);
            y.extend(&self.tokens[start + 1..start + t + 1]);
        }
        Batch {
            x: Tensor::i32(vec![b, t], x),
            targets: Tensor::i32(vec![b, t], y),
            mask: Tensor::f32(vec![b, t], vec![1.0; b * t]),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vocab_roundtrip() {
        let v = CharVocab::new();
        assert_eq!(v.size(), 64);
        let s = "Hello, world!\nA1";
        let ids = v.encode(s);
        assert_eq!(v.decode(&ids), s);
        assert!(ids.iter().all(|&i| (0..64).contains(&i)));
    }

    #[test]
    fn generator_deterministic_and_sized() {
        let g = CorpusGen::new(200, 0);
        let a = g.generate(5000, 7);
        let b = g.generate(5000, 7);
        assert_eq!(a, b);
        assert_eq!(a.len(), 5000);
        let c = g.generate(5000, 8);
        assert_ne!(a, c);
    }

    #[test]
    fn text_has_structure() {
        let g = CorpusGen::new(300, 1);
        let text = g.generate(20_000, 3);
        assert!(text.contains(". "), "no sentence breaks");
        assert!(text.contains('\n'), "no paragraphs");
        // space frequency in a natural-ish band
        let spaces = text.chars().filter(|&c| c == ' ').count() as f64
            / text.len() as f64;
        assert!(spaces > 0.08 && spaces < 0.35, "space frac {spaces}");
    }

    #[test]
    fn zipf_head_dominates() {
        let g = CorpusGen::new(300, 1);
        let text = g.generate(50_000, 3);
        let mut counts = std::collections::HashMap::new();
        for w in text.split_whitespace() {
            *counts.entry(w.trim_matches(|c: char| !c.is_alphabetic())
                          .to_lowercase()).or_insert(0usize) += 1;
        }
        let mut freqs: Vec<usize> = counts.values().copied().collect();
        freqs.sort_unstable_by(|a, b| b.cmp(a));
        // top word much more frequent than the 50th
        assert!(freqs[0] > freqs.get(50).copied().unwrap_or(0) * 3);
    }

    #[test]
    fn lm_batch_targets_shifted() {
        let ds = LmDataset::synthetic(10_000, 0);
        let mut rng = Rng::new(2);
        let b = ds.batch(&mut rng, 3, 32);
        let x = b.x.data.as_i32().unwrap();
        let y = b.targets.data.as_i32().unwrap();
        // y[i] should equal x[i+1] within each row
        for row in 0..3 {
            for i in 0..31 {
                assert_eq!(y[row * 32 + i], x[row * 32 + i + 1]);
            }
        }
    }
}
