//! Data layer: workload generators for every experiment in the paper.
//! All generators are deterministic given a seed (util::rng) and produce
//! `tensor::Batch` triples matching the exported executables' shapes.

pub mod batcher;
pub mod chomsky;
pub mod corpus;
pub mod lra;
pub mod random_tokens;
pub mod rl;
pub mod selective_copy;
