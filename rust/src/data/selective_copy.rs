//! Selective Copying task (Gu & Dao 2024, §4.2 / Tables 1–2).
//!
//! A sequence of noise tokens with `n_data` data tokens scattered through
//! the first `ctx_len` positions; the model must reproduce the data tokens,
//! in order, at the `n_data` answer slots that follow.  Content-aware
//! gating is required: positions of the data tokens are random per sample.
//!
//! Token map (vocab 16): 0 = noise, 1 = answer-slot marker, 2..=15 = data.

use crate::tensor::{Batch, Tensor};
use crate::util::rng::Rng;

pub const NOISE: i32 = 0;
pub const MARKER: i32 = 1;
pub const DATA_MIN: i32 = 2;
pub const DATA_MAX: i32 = 15;

#[derive(Clone, Copy, Debug)]
pub struct SelectiveCopy {
    pub ctx_len: usize,
    pub n_data: usize,
}

impl SelectiveCopy {
    pub fn new(ctx_len: usize, n_data: usize) -> Self {
        assert!(n_data <= ctx_len);
        SelectiveCopy { ctx_len, n_data }
    }

    pub fn total_len(&self) -> usize {
        self.ctx_len + self.n_data
    }

    /// One example: (input, target, mask), each of length total_len().
    pub fn sample(&self, rng: &mut Rng) -> (Vec<i32>, Vec<i32>, Vec<f32>) {
        let t = self.total_len();
        let mut input = vec![NOISE; t];
        let mut target = vec![0i32; t];
        let mut mask = vec![0f32; t];

        let mut positions = rng.choose_distinct(self.ctx_len, self.n_data);
        positions.sort_unstable(); // data order = order of appearance
        let data: Vec<i32> = (0..self.n_data)
            .map(|_| DATA_MIN + rng.below((DATA_MAX - DATA_MIN + 1) as u64)
                 as i32)
            .collect();
        for (&pos, &tok) in positions.iter().zip(&data) {
            input[pos] = tok;
        }
        for (i, &tok) in data.iter().enumerate() {
            let slot = self.ctx_len + i;
            input[slot] = MARKER;
            target[slot] = tok;
            mask[slot] = 1.0;
        }
        (input, target, mask)
    }

    /// A fresh batch (on-the-fly generation, as the paper trains).
    pub fn batch(&self, rng: &mut Rng, batch_size: usize) -> Batch {
        let t = self.total_len();
        let mut x = Vec::with_capacity(batch_size * t);
        let mut y = Vec::with_capacity(batch_size * t);
        let mut m = Vec::with_capacity(batch_size * t);
        for _ in 0..batch_size {
            let (xi, yi, mi) = self.sample(rng);
            x.extend(xi);
            y.extend(yi);
            m.extend(mi);
        }
        Batch {
            x: Tensor::i32(vec![batch_size, t], x),
            targets: Tensor::i32(vec![batch_size, t], y),
            mask: Tensor::f32(vec![batch_size, t], m),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_structure() {
        let task = SelectiveCopy::new(64, 8);
        let mut rng = Rng::new(0);
        for _ in 0..50 {
            let (x, y, m) = task.sample(&mut rng);
            assert_eq!(x.len(), 72);
            // exactly 8 data tokens in the context
            let data_in_ctx: Vec<i32> = x[..64].iter().copied()
                .filter(|&t| t >= DATA_MIN).collect();
            assert_eq!(data_in_ctx.len(), 8);
            // answer slots are markers, mask only there
            assert!(x[64..].iter().all(|&t| t == MARKER));
            assert_eq!(m.iter().filter(|&&v| v > 0.0).count(), 8);
            assert!(m[..64].iter().all(|&v| v == 0.0));
            // targets at answer slots reproduce the data in order
            let answers: Vec<i32> = y[64..].to_vec();
            assert_eq!(answers, data_in_ctx);
        }
    }

    #[test]
    fn batch_shapes() {
        let task = SelectiveCopy::new(32, 4);
        let mut rng = Rng::new(1);
        let b = task.batch(&mut rng, 5);
        assert_eq!(b.x.dims, vec![5, 36]);
        assert_eq!(b.targets.dims, vec![5, 36]);
        assert_eq!(b.mask.dims, vec![5, 36]);
    }

    #[test]
    fn tokens_in_vocab() {
        let task = SelectiveCopy::new(40, 6);
        let mut rng = Rng::new(2);
        let (x, y, _) = task.sample(&mut rng);
        assert!(x.iter().all(|&t| (0..16).contains(&t)));
        assert!(y.iter().all(|&t| (0..16).contains(&t)));
    }
}
