//! Continuous-control environments — the simulation substrate replacing
//! MuJoCo/D4RL (DESIGN.md §3).  Dense rewards, fixed horizons, fully
//! deterministic dynamics given the reset state.

use crate::util::rng::Rng;

pub trait Env {
    fn name(&self) -> &'static str;
    fn obs_dim(&self) -> usize;
    fn act_dim(&self) -> usize;
    fn horizon(&self) -> usize;
    fn reset(&mut self, rng: &mut Rng) -> Vec<f32>;
    /// Returns (obs, reward, done).
    fn step(&mut self, action: &[f32]) -> (Vec<f32>, f32, bool);
}

pub fn by_name(name: &str) -> Option<Box<dyn Env>> {
    match name {
        "pointmass" => Some(Box::new(PointMass::default())),
        "pendulum" => Some(Box::new(Pendulum::default())),
        "walker1d" => Some(Box::new(Walker1dLite::default())),
        _ => None,
    }
}

fn clamp1(a: &[f32], i: usize) -> f32 {
    a.get(i).copied().unwrap_or(0.0).clamp(-1.0, 1.0)
}

// ---------------------------------------------------------------------------
// PointMass: reach the origin on a 2-D plane (HalfCheetah-slot analogue —
// smooth, easy dense-reward control).
// ---------------------------------------------------------------------------

#[derive(Default)]
pub struct PointMass {
    pos: [f32; 2],
    vel: [f32; 2],
    t: usize,
}

impl PointMass {
    fn obs(&self) -> Vec<f32> {
        vec![self.pos[0], self.pos[1], self.vel[0], self.vel[1]]
    }
}

impl Env for PointMass {
    fn name(&self) -> &'static str {
        "pointmass"
    }

    fn obs_dim(&self) -> usize {
        4
    }

    fn act_dim(&self) -> usize {
        2
    }

    fn horizon(&self) -> usize {
        100
    }

    fn reset(&mut self, rng: &mut Rng) -> Vec<f32> {
        self.pos = [rng.range_f32(-2.0, 2.0), rng.range_f32(-2.0, 2.0)];
        self.vel = [0.0, 0.0];
        self.t = 0;
        self.obs()
    }

    fn step(&mut self, action: &[f32]) -> (Vec<f32>, f32, bool) {
        let dt = 0.1;
        let a = [clamp1(action, 0), clamp1(action, 1)];
        for k in 0..2 {
            self.vel[k] = 0.95 * self.vel[k] + a[k] * dt * 4.0;
            self.pos[k] += self.vel[k] * dt;
        }
        let dist = (self.pos[0] * self.pos[0]
                    + self.pos[1] * self.pos[1]).sqrt();
        let reward = -dist - 0.05 * (a[0] * a[0] + a[1] * a[1]);
        self.t += 1;
        (self.obs(), reward, self.t >= self.horizon())
    }
}

// ---------------------------------------------------------------------------
// Pendulum swing-up (Hopper-slot analogue — requires non-greedy control:
// energy pumping before stabilization).
// ---------------------------------------------------------------------------

pub struct Pendulum {
    theta: f32,
    omega: f32,
    t: usize,
}

impl Default for Pendulum {
    fn default() -> Self {
        Pendulum { theta: std::f32::consts::PI, omega: 0.0, t: 0 }
    }
}

impl Pendulum {
    fn obs(&self) -> Vec<f32> {
        vec![self.theta.cos(), self.theta.sin(), self.omega / 8.0]
    }
}

impl Env for Pendulum {
    fn name(&self) -> &'static str {
        "pendulum"
    }

    fn obs_dim(&self) -> usize {
        3
    }

    fn act_dim(&self) -> usize {
        1
    }

    fn horizon(&self) -> usize {
        100
    }

    fn reset(&mut self, rng: &mut Rng) -> Vec<f32> {
        self.theta = std::f32::consts::PI + rng.range_f32(-0.6, 0.6);
        self.omega = rng.range_f32(-0.5, 0.5);
        self.t = 0;
        self.obs()
    }

    fn step(&mut self, action: &[f32]) -> (Vec<f32>, f32, bool) {
        let dt = 0.05;
        let (g, m, l) = (10.0f32, 1.0f32, 1.0f32);
        let torque = clamp1(action, 0) * 2.0;
        let acc = -3.0 * g / (2.0 * l) * self.theta.sin()
            + 3.0 / (m * l * l) * torque;
        // θ = 0 is upright (sin enters with a sign making 0 unstable
        // equilibrium; matches the classic gym formulation shifted by π)
        self.omega = (self.omega + acc * dt).clamp(-8.0, 8.0);
        self.theta += self.omega * dt;
        // wrap to (-π, π]
        while self.theta > std::f32::consts::PI {
            self.theta -= 2.0 * std::f32::consts::PI;
        }
        while self.theta <= -std::f32::consts::PI {
            self.theta += 2.0 * std::f32::consts::PI;
        }
        let reward = -(self.theta * self.theta
                       + 0.1 * self.omega * self.omega
                       + 0.01 * torque * torque);
        self.t += 1;
        (self.obs(), reward, self.t >= self.horizon())
    }
}

// ---------------------------------------------------------------------------
// Walker1dLite: 1-D locomotion with a mass that must keep "posture" (height
// within a band) while maximizing forward velocity (Walker2d-slot analogue).
// ---------------------------------------------------------------------------

pub struct Walker1dLite {
    vel: f32,
    height: f32,
    hvel: f32,
    phase: f32,
    t: usize,
}

impl Default for Walker1dLite {
    fn default() -> Self {
        Walker1dLite { vel: 0.0, height: 1.0, hvel: 0.0, phase: 0.0, t: 0 }
    }
}

impl Walker1dLite {
    fn obs(&self) -> Vec<f32> {
        vec![self.vel, self.height, self.hvel,
             self.phase.sin(), self.phase.cos(),
             (self.height - 1.0).abs()]
    }

    fn upright(&self) -> bool {
        self.height > 0.5 && self.height < 1.5
    }
}

impl Env for Walker1dLite {
    fn name(&self) -> &'static str {
        "walker1d"
    }

    fn obs_dim(&self) -> usize {
        6
    }

    fn act_dim(&self) -> usize {
        2
    }

    fn horizon(&self) -> usize {
        100
    }

    fn reset(&mut self, rng: &mut Rng) -> Vec<f32> {
        self.vel = 0.0;
        self.height = rng.range_f32(0.9, 1.1);
        self.hvel = rng.range_f32(-0.1, 0.1);
        self.phase = rng.range_f32(0.0, std::f32::consts::TAU);
        self.t = 0;
        self.obs()
    }

    fn step(&mut self, action: &[f32]) -> (Vec<f32>, f32, bool) {
        let dt = 0.1;
        let drive = clamp1(action, 0);   // forward drive
        let lift = clamp1(action, 1);    // posture control
        self.phase = (self.phase + dt * 6.0) % std::f32::consts::TAU;
        // forward motion only transfers efficiently when in phase and upright
        let gait = 0.5 + 0.5 * self.phase.sin();
        let eff = if self.upright() { gait } else { 0.1 };
        self.vel = 0.9 * self.vel + drive * eff * 1.2;
        // height dynamics: gravity pulls toward sagging, lift counteracts
        self.hvel = 0.8 * self.hvel + (lift - 0.3 * (self.height - 0.7)
                                       - 0.25) * dt * 8.0;
        self.height = (self.height + self.hvel * dt).clamp(0.0, 2.0);
        let reward = if self.upright() {
            self.vel - 0.05 * (drive * drive + lift * lift)
        } else {
            -1.0
        };
        self.t += 1;
        (self.obs(), reward, self.t >= self.horizon())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn envs_run_full_horizon() {
        let mut rng = Rng::new(0);
        for name in ["pointmass", "pendulum", "walker1d"] {
            let mut env = by_name(name).unwrap();
            let obs = env.reset(&mut rng);
            assert_eq!(obs.len(), env.obs_dim());
            let mut steps = 0;
            loop {
                let a = vec![0.1; env.act_dim()];
                let (obs, r, done) = env.step(&a);
                assert_eq!(obs.len(), env.obs_dim());
                assert!(r.is_finite());
                assert!(obs.iter().all(|v| v.is_finite()));
                steps += 1;
                if done {
                    break;
                }
                assert!(steps <= env.horizon(), "{name} never terminates");
            }
            assert_eq!(steps, env.horizon());
        }
    }

    #[test]
    fn pointmass_controller_reaches_goal() {
        // PD control should bring the mass near the origin
        let mut rng = Rng::new(1);
        let mut env = PointMass::default();
        let mut obs = env.reset(&mut rng);
        let mut last_r = f32::NEG_INFINITY;
        for _ in 0..100 {
            let a = vec![-1.2 * obs[0] - 0.8 * obs[2],
                         -1.2 * obs[1] - 0.8 * obs[3]];
            let (o, r, _) = env.step(&a);
            obs = o;
            last_r = r;
        }
        assert!(last_r > -0.3, "did not converge: final reward {last_r}");
    }

    #[test]
    fn reset_is_stochastic_dynamics_deterministic() {
        let mut rng1 = Rng::new(5);
        let mut rng2 = Rng::new(5);
        let mut e1 = Pendulum::default();
        let mut e2 = Pendulum::default();
        assert_eq!(e1.reset(&mut rng1), e2.reset(&mut rng2));
        let (o1, r1, _) = e1.step(&[0.5]);
        let (o2, r2, _) = e2.step(&[0.5]);
        assert_eq!(o1, o2);
        assert_eq!(r1, r2);
        // different seeds → different starts
        let mut rng3 = Rng::new(6);
        let mut e3 = Pendulum::default();
        assert_ne!(e3.reset(&mut rng3), {
            let mut rng4 = Rng::new(7);
            let mut e4 = Pendulum::default();
            e4.reset(&mut rng4)
        });
    }
}
