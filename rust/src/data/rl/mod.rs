//! Offline-RL substrate: environments, scripted policies, D4RL-style
//! datasets, and expert-normalized scoring (Table 3).

pub mod dataset;
pub mod envs;
pub mod policies;

pub use dataset::{normalized_score, OfflineDataset, Regime};
pub use envs::Env;
pub use policies::Quality;
