//! Scripted behaviour policies of graded quality, used to build the
//! Medium / Medium-Replay / Medium-Expert offline datasets (the D4RL data
//! regimes of Table 3).

use crate::util::rng::Rng;


#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Quality {
    Random,
    Medium,
    Expert,
}

/// Action for (env, quality) at an observation.  Medium = detuned expert
/// with exploration noise (scores ≈ 1/3–1/2 of expert, matching D4RL's
/// "policy scoring about one-third of an expert").
pub fn act(env_name: &str, q: Quality, obs: &[f32], rng: &mut Rng)
           -> Vec<f32> {
    match q {
        Quality::Random => random_action(env_name, rng),
        Quality::Medium => {
            let mut a = expert_action(env_name, obs);
            for v in a.iter_mut() {
                *v = (*v * 0.55 + rng.normal_f32(0.0, 0.45)).clamp(-1.0, 1.0);
            }
            a
        }
        Quality::Expert => {
            let mut a = expert_action(env_name, obs);
            for v in a.iter_mut() {
                *v = (*v + rng.normal_f32(0.0, 0.03)).clamp(-1.0, 1.0);
            }
            a
        }
    }
}

fn random_action(env_name: &str, rng: &mut Rng) -> Vec<f32> {
    let dim = match env_name {
        "pendulum" => 1,
        _ => 2,
    };
    (0..dim).map(|_| rng.range_f32(-1.0, 1.0)).collect()
}

fn expert_action(env_name: &str, obs: &[f32]) -> Vec<f32> {
    match env_name {
        "pointmass" => {
            // PD controller toward the origin
            vec![(-1.2 * obs[0] - 0.8 * obs[2]).clamp(-1.0, 1.0),
                 (-1.2 * obs[1] - 0.8 * obs[3]).clamp(-1.0, 1.0)]
        }
        "pendulum" => {
            let (cos_t, sin_t, omega_n) = (obs[0], obs[1], obs[2]);
            let omega = omega_n * 8.0;
            let theta = sin_t.atan2(cos_t);
            // energy-based swing-up far from top, PD near the top
            let a = if cos_t > 0.85 {
                -8.0 * theta - 2.0 * omega
            } else {
                // pump energy: torque along velocity direction
                let energy = 0.5 * omega * omega + 15.0 * (cos_t - 1.0);
                if energy < 0.0 { 2.5 * omega.signum() } else { -0.5 * omega }
            };
            vec![(a / 2.0).clamp(-1.0, 1.0)]
        }
        "walker1d" => {
            let (_vel, height, hvel, sin_p, _cos_p) =
                (obs[0], obs[1], obs[2], obs[3], obs[4]);
            // drive hard when the gait phase is favorable, keep posture
            let drive = if sin_p > -0.2 { 1.0 } else { 0.3 };
            let lift = (0.25 + 1.4 * (1.0 - height) - 0.6 * hvel)
                .clamp(-1.0, 1.0);
            vec![drive, lift]
        }
        _ => vec![0.0, 0.0],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rl::envs;

    fn rollout_return(env_name: &str, q: Quality, seed: u64) -> f32 {
        let mut env = envs::by_name(env_name).unwrap();
        let mut rng = Rng::new(seed);
        let mut obs = env.reset(&mut rng);
        let mut total = 0.0;
        loop {
            let a = act(env_name, q, &obs, &mut rng);
            let (o, r, done) = env.step(&a);
            obs = o;
            total += r;
            if done {
                break;
            }
        }
        total
    }

    #[test]
    fn quality_ordering_holds() {
        for name in ["pointmass", "pendulum", "walker1d"] {
            let avg = |q: Quality| -> f32 {
                (0..8).map(|s| rollout_return(name, q, s)).sum::<f32>() / 8.0
            };
            let (r, m, e) = (avg(Quality::Random), avg(Quality::Medium),
                             avg(Quality::Expert));
            assert!(e > m, "{name}: expert {e} <= medium {m}");
            assert!(m > r, "{name}: medium {m} <= random {r}");
        }
    }

    #[test]
    fn actions_bounded() {
        let mut rng = Rng::new(0);
        for name in ["pointmass", "pendulum", "walker1d"] {
            let mut env = envs::by_name(name).unwrap();
            let obs = env.reset(&mut rng);
            for q in [Quality::Random, Quality::Medium, Quality::Expert] {
                let a = act(name, q, &obs, &mut rng);
                assert_eq!(a.len(), env.act_dim());
                assert!(a.iter().all(|v| (-1.0..=1.0).contains(v)));
            }
        }
    }
}
