//! Offline RL datasets + Decision-Transformer-style batch construction
//! (Table 3).  Mirrors D4RL's three data regimes:
//!   Medium        — rollouts of the Medium policy
//!   MediumReplay  — a "replay buffer": mixture from Random → Medium
//!   MediumExpert  — half Medium, half Expert rollouts
//!
//! Sequence features per timestep: [return-to-go / scale, obs (normalized),
//! previous action]; the model regresses the current action (masked MSE).

use crate::tensor::{Batch, Tensor};
use crate::util::rng::Rng;

use super::envs::{self, Env};
use super::policies::{self, Quality};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Regime {
    Medium,
    MediumReplay,
    MediumExpert,
}

impl Regime {
    pub fn tag(&self) -> &'static str {
        match self {
            Regime::Medium => "M",
            Regime::MediumReplay => "M-R",
            Regime::MediumExpert => "M-E",
        }
    }

    pub fn all() -> [Regime; 3] {
        [Regime::Medium, Regime::MediumReplay, Regime::MediumExpert]
    }
}

#[derive(Clone, Debug)]
pub struct Episode {
    pub obs: Vec<Vec<f32>>,
    pub act: Vec<Vec<f32>>,
    pub rew: Vec<f32>,
}

impl Episode {
    pub fn len(&self) -> usize {
        self.rew.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rew.is_empty()
    }

    pub fn ret(&self) -> f32 {
        self.rew.iter().sum()
    }

    /// Return-to-go at each timestep.
    pub fn rtg(&self) -> Vec<f32> {
        let mut out = vec![0f32; self.len()];
        let mut acc = 0.0;
        for i in (0..self.len()).rev() {
            acc += self.rew[i];
            out[i] = acc;
        }
        out
    }
}

pub struct OfflineDataset {
    pub env_name: String,
    pub regime: Regime,
    pub episodes: Vec<Episode>,
    pub obs_mean: Vec<f32>,
    pub obs_std: Vec<f32>,
    pub rtg_scale: f32,
    pub obs_dim: usize,
    pub act_dim: usize,
}

fn rollout(env: &mut dyn Env, q: Quality, rng: &mut Rng) -> Episode {
    let mut obs = env.reset(rng);
    let mut ep = Episode { obs: vec![], act: vec![], rew: vec![] };
    loop {
        let a = policies::act(env.name(), q, &obs, rng);
        let (next, r, done) = env.step(&a);
        ep.obs.push(obs);
        ep.act.push(a);
        ep.rew.push(r);
        obs = next;
        if done {
            break;
        }
    }
    ep
}

impl OfflineDataset {
    /// Build a dataset of `n_episodes` rollouts under the given regime.
    pub fn build(env_name: &str, regime: Regime, n_episodes: usize,
                 seed: u64) -> Self {
        let mut env = envs::by_name(env_name)
            .unwrap_or_else(|| panic!("unknown env {env_name}"));
        let mut rng = Rng::new(seed ^ 0xD4_71);
        let mut episodes = Vec::with_capacity(n_episodes);
        for i in 0..n_episodes {
            let q = match regime {
                Regime::Medium => Quality::Medium,
                Regime::MediumExpert => {
                    if i % 2 == 0 { Quality::Medium } else { Quality::Expert }
                }
                Regime::MediumReplay => {
                    // replay: first third random-ish, middle mixed, last
                    // third medium — an improving agent's buffer
                    match 3 * i / n_episodes {
                        0 => Quality::Random,
                        1 => if rng.bool(0.5) { Quality::Random }
                             else { Quality::Medium },
                        _ => Quality::Medium,
                    }
                }
            };
            episodes.push(rollout(env.as_mut(), q, &mut rng));
        }

        let obs_dim = env.obs_dim();
        let act_dim = env.act_dim();
        let mut mean = vec![0f64; obs_dim];
        let mut count = 0usize;
        for ep in &episodes {
            for o in &ep.obs {
                for (m, &v) in mean.iter_mut().zip(o) {
                    *m += v as f64;
                }
                count += 1;
            }
        }
        for m in mean.iter_mut() {
            *m /= count.max(1) as f64;
        }
        let mut var = vec![0f64; obs_dim];
        for ep in &episodes {
            for o in &ep.obs {
                for ((v, &x), m) in var.iter_mut().zip(o).zip(&mean) {
                    *v += (x as f64 - m) * (x as f64 - m);
                }
            }
        }
        let std: Vec<f32> = var.iter()
            .map(|v| ((v / count.max(1) as f64).sqrt() as f32).max(1e-3))
            .collect();
        let max_abs_rtg = episodes.iter()
            .map(|e| e.ret().abs())
            .fold(1.0f32, f32::max);

        OfflineDataset {
            env_name: env_name.to_string(),
            regime,
            episodes,
            obs_mean: mean.iter().map(|&m| m as f32).collect(),
            obs_std: std,
            rtg_scale: max_abs_rtg,
            obs_dim,
            act_dim,
        }
    }

    pub fn feature_dim(&self) -> usize {
        1 + self.obs_dim + self.act_dim
    }

    pub fn norm_obs(&self, obs: &[f32]) -> Vec<f32> {
        obs.iter().zip(&self.obs_mean).zip(&self.obs_std)
            .map(|((&o, &m), &s)| (o - m) / s)
            .collect()
    }

    /// Best return in the dataset — used as the conditioning target.
    pub fn target_return(&self) -> f32 {
        self.episodes.iter().map(|e| e.ret()).fold(f32::MIN, f32::max)
    }

    /// DT-style training batch of shape (b, ctx): random episode windows.
    pub fn batch(&self, rng: &mut Rng, b: usize, ctx: usize) -> Batch {
        let f = self.feature_dim();
        let mut x = vec![0f32; b * ctx * f];
        let mut y = vec![0f32; b * ctx * self.act_dim];
        let mut m = vec![0f32; b * ctx];
        for bi in 0..b {
            let ep = &self.episodes[rng.usize_below(self.episodes.len())];
            let rtg = ep.rtg();
            let max_start = ep.len().saturating_sub(1);
            let start = rng.usize_below(max_start + 1);
            let window = (ep.len() - start).min(ctx);
            for k in 0..window {
                let t = start + k;
                let row = (bi * ctx + k) * f;
                x[row] = rtg[t] / self.rtg_scale;
                let no = self.norm_obs(&ep.obs[t]);
                x[row + 1..row + 1 + self.obs_dim].copy_from_slice(&no);
                if t > 0 {
                    x[row + 1 + self.obs_dim..row + f]
                        .copy_from_slice(&ep.act[t - 1]);
                }
                let yrow = (bi * ctx + k) * self.act_dim;
                y[yrow..yrow + self.act_dim].copy_from_slice(&ep.act[t]);
                m[bi * ctx + k] = 1.0;
            }
        }
        Batch {
            x: Tensor::f32(vec![b, ctx, f], x),
            targets: Tensor::f32(vec![b, ctx, self.act_dim], y),
            mask: Tensor::f32(vec![b, ctx], m),
        }
    }
}

/// Expert-normalized score per D4RL: 100·(S − S_random)/(S_expert − S_random).
pub fn normalized_score(env_name: &str, raw: f32, seed: u64) -> f32 {
    let anchor = |q: Quality| -> f32 {
        let mut env = envs::by_name(env_name).unwrap();
        let mut rng = Rng::new(seed ^ 0xA5C0);
        let n = 16;
        (0..n).map(|_| {
            let ep = rollout(env.as_mut(), q, &mut rng);
            ep.ret()
        }).sum::<f32>() / n as f32
    };
    let lo = anchor(Quality::Random);
    let hi = anchor(Quality::Expert);
    100.0 * (raw - lo) / (hi - lo).max(1e-6)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dataset_shapes_and_stats() {
        let ds = OfflineDataset::build("pointmass", Regime::Medium, 20, 0);
        assert_eq!(ds.episodes.len(), 20);
        assert_eq!(ds.obs_dim, 4);
        assert_eq!(ds.act_dim, 2);
        assert_eq!(ds.feature_dim(), 7);
        assert!(ds.rtg_scale > 0.0);
        // normalization is roughly standardizing
        let ep = &ds.episodes[0];
        let no = ds.norm_obs(&ep.obs[0]);
        assert!(no.iter().all(|v| v.abs() < 20.0));
    }

    #[test]
    fn regime_quality_ordering() {
        let avg = |r: Regime| -> f32 {
            let ds = OfflineDataset::build("pointmass", r, 30, 1);
            ds.episodes.iter().map(|e| e.ret()).sum::<f32>() / 30.0
        };
        let m = avg(Regime::Medium);
        let mr = avg(Regime::MediumReplay);
        let me = avg(Regime::MediumExpert);
        assert!(me > m, "M-E {me} <= M {m}");
        assert!(m > mr, "M {m} <= M-R {mr}");
    }

    #[test]
    fn rtg_decreasing_along_episode() {
        let ds = OfflineDataset::build("pendulum", Regime::Medium, 5, 2);
        let ep = &ds.episodes[0];
        let rtg = ep.rtg();
        assert!((rtg[0] - ep.ret()).abs() < 1e-3);
        assert!((rtg[rtg.len() - 1] - ep.rew[ep.len() - 1]).abs() < 1e-4);
    }

    #[test]
    fn batch_layout() {
        let ds = OfflineDataset::build("walker1d", Regime::MediumExpert,
                                       10, 3);
        let mut rng = Rng::new(4);
        let b = ds.batch(&mut rng, 6, 16);
        assert_eq!(b.x.dims, vec![6, 16, ds.feature_dim()]);
        assert_eq!(b.targets.dims, vec![6, 16, 2]);
        assert_eq!(b.mask.dims, vec![6, 16]);
        // some mask positions on
        let on: f32 = b.mask.data.as_f32().unwrap().iter().sum();
        assert!(on > 0.0);
    }

    #[test]
    fn normalized_score_anchors() {
        // the expert itself should score near 100, random near 0
        let mut env = envs::by_name("pointmass").unwrap();
        let mut rng = Rng::new(9);
        let raw: f32 = (0..8).map(|_| {
            rollout(env.as_mut(), Quality::Expert, &mut rng).ret()
        }).sum::<f32>() / 8.0;
        let score = normalized_score("pointmass", raw, 0);
        assert!(score > 85.0 && score < 115.0, "expert score {score}");
        let rand_score = normalized_score("pointmass", {
            let mut rng = Rng::new(10);
            (0..8).map(|_| rollout(env.as_mut(), Quality::Random, &mut rng)
                       .ret()).sum::<f32>() / 8.0
        }, 0);
        assert!(rand_score.abs() < 20.0, "random score {rand_score}");
    }
}
