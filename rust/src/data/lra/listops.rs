//! ListOps (Nangia & Bowman 2018): evaluate a nested prefix expression.
//!
//! Example: [MAX 2 9 [MIN 4 7] 0] → 9.  Ten classes (digits 0–9).
//!
//! Token map (vocab_in 20): 0 PAD, 1 CLS, digits 0–9 → 2..=11,
//! MAX 12, MIN 13, MED 14, SM 15 (sum mod 10), '[' 16, ']' 17.

use crate::util::rng::Rng;

pub const DIGIT0: i32 = 2;
pub const OP_MAX: i32 = 12;
pub const OP_MIN: i32 = 13;
pub const OP_MED: i32 = 14;
pub const OP_SM: i32 = 15;
pub const OPEN: i32 = 16;
pub const CLOSE: i32 = 17;

#[derive(Clone, Debug)]
pub enum Node {
    Digit(u8),
    Op(i32, Vec<Node>),
}

impl Node {
    pub fn eval(&self) -> u8 {
        match self {
            Node::Digit(d) => *d,
            Node::Op(op, args) => {
                let mut vals: Vec<u8> = args.iter().map(|a| a.eval())
                    .collect();
                match *op {
                    OP_MAX => *vals.iter().max().unwrap(),
                    OP_MIN => *vals.iter().min().unwrap(),
                    OP_MED => {
                        vals.sort_unstable();
                        vals[vals.len() / 2]
                    }
                    OP_SM => (vals.iter().map(|&v| v as u32).sum::<u32>()
                              % 10) as u8,
                    _ => unreachable!("bad op"),
                }
            }
        }
    }

    pub fn tokens(&self, out: &mut Vec<i32>) {
        match self {
            Node::Digit(d) => out.push(DIGIT0 + *d as i32),
            Node::Op(op, args) => {
                out.push(OPEN);
                out.push(*op);
                for a in args {
                    a.tokens(out);
                }
                out.push(CLOSE);
            }
        }
    }

    pub fn token_len(&self) -> usize {
        match self {
            Node::Digit(_) => 1,
            Node::Op(_, args) => 3 + args.iter().map(|a| a.token_len())
                .sum::<usize>(),
        }
    }
}

/// Random expression with at most `budget` tokens and depth ≤ `max_depth`.
pub fn gen_expr(rng: &mut Rng, budget: usize, max_depth: usize) -> Node {
    if budget < 6 || max_depth == 0 {
        return Node::Digit(rng.below(10) as u8);
    }
    let op = [OP_MAX, OP_MIN, OP_MED, OP_SM][rng.usize_below(4)];
    let n_args = 2 + rng.usize_below(4); // 2..=5 args
    let mut remaining = budget - 3;
    let mut args = Vec::with_capacity(n_args);
    for k in 0..n_args {
        let share = remaining / (n_args - k);
        let child = if rng.bool(0.4) && share >= 6 {
            gen_expr(rng, share, max_depth - 1)
        } else {
            Node::Digit(rng.below(10) as u8)
        };
        remaining = remaining.saturating_sub(child.token_len());
        args.push(child);
    }
    Node::Op(op, args)
}

/// One example: (tokens, class label 0..=9).
pub fn sample(rng: &mut Rng, max_tokens: usize) -> (Vec<i32>, i32) {
    let expr = gen_expr(rng, max_tokens, 4);
    let mut tokens = Vec::with_capacity(expr.token_len());
    expr.tokens(&mut tokens);
    (tokens, expr.eval() as i32)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_known_expression() {
        // [MAX 2 9 [MIN 4 7] 0] = 9
        let e = Node::Op(OP_MAX, vec![
            Node::Digit(2), Node::Digit(9),
            Node::Op(OP_MIN, vec![Node::Digit(4), Node::Digit(7)]),
            Node::Digit(0),
        ]);
        assert_eq!(e.eval(), 9);
        // [SM 5 6] = 1; [MED 1 5 9] = 5
        assert_eq!(Node::Op(OP_SM, vec![Node::Digit(5), Node::Digit(6)])
                   .eval(), 1);
        assert_eq!(Node::Op(OP_MED, vec![Node::Digit(1), Node::Digit(5),
                                         Node::Digit(9)]).eval(), 5);
    }

    #[test]
    fn tokens_balanced_and_bounded() {
        let mut rng = Rng::new(0);
        for _ in 0..100 {
            let (tokens, label) = sample(&mut rng, 120);
            assert!(tokens.len() <= 120 + 6, "len {}", tokens.len());
            assert!((0..=9).contains(&label));
            let opens = tokens.iter().filter(|&&t| t == OPEN).count();
            let closes = tokens.iter().filter(|&&t| t == CLOSE).count();
            assert_eq!(opens, closes);
            assert!(tokens.iter().all(|&t| (2..=17).contains(&t)));
        }
    }

    #[test]
    fn token_len_matches() {
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            let e = gen_expr(&mut rng, 80, 3);
            let mut toks = Vec::new();
            e.tokens(&mut toks);
            assert_eq!(toks.len(), e.token_len());
        }
    }
}
