//! G-Image (LRA): classify a grayscale image fed as a flat pixel sequence.
//! Procedural substitution for CIFAR-10-grayscale (DESIGN.md §3): ten
//! visually distinct shape/texture classes rendered at 16×16 with random
//! phase, scale and pixel noise, quantized to 30 gray levels.
//!
//! Token map (vocab_in 32): 0 PAD, 1 CLS, pixel levels → 2..=31.

use crate::util::rng::Rng;

pub const SIDE: usize = 16;
pub const LEVELS: i32 = 30;
pub const N_CLASSES: usize = 10;

fn render(class: usize, rng: &mut Rng) -> Vec<f32> {
    let mut img = vec![0f32; SIDE * SIDE];
    let phase = rng.usize_below(SIDE);
    let period = 2 + rng.usize_below(3);
    let cx = 4.0 + rng.f32() * 8.0;
    let cy = 4.0 + rng.f32() * 8.0;
    let r = 3.0 + rng.f32() * 4.0;
    for y in 0..SIDE {
        for x in 0..SIDE {
            let v = match class {
                0 => ((y + phase) / period % 2) as f32,             // h-stripes
                1 => ((x + phase) / period % 2) as f32,             // v-stripes
                2 => (((x + phase) / period + (y + phase) / period) % 2)
                    as f32,                                          // checker
                3 => x as f32 / (SIDE - 1) as f32,                   // grad→
                4 => y as f32 / (SIDE - 1) as f32,                   // grad↓
                5 => {                                               // disc
                    let d = ((x as f32 - cx).powi(2)
                             + (y as f32 - cy).powi(2)).sqrt();
                    if d < r { 1.0 } else { 0.0 }
                }
                6 => {                                               // ring
                    let d = ((x as f32 - cx).powi(2)
                             + (y as f32 - cy).powi(2)).sqrt();
                    if (d - r).abs() < 1.2 { 1.0 } else { 0.0 }
                }
                7 => {                                               // square
                    let inside = (x as f32 - cx).abs() < r * 0.8
                        && (y as f32 - cy).abs() < r * 0.8;
                    if inside { 1.0 } else { 0.0 }
                }
                8 => if x == y || x + 1 == y { 1.0 } else { 0.0 },   // diag
                _ => ((x * 7 + y * 13 + phase) % 5) as f32 / 4.0,    // texture
            };
            img[y * SIDE + x] = v;
        }
    }
    // pixel noise
    for p in img.iter_mut() {
        *p = (*p + rng.normal_f32(0.0, 0.08)).clamp(0.0, 1.0);
    }
    img
}

/// One example: (pixel tokens, class label).  Sequence length SIDE² = 256
/// (the collate layer reserves the final slot for CLS, so we drop the last
/// pixel — class information is global).
pub fn sample(rng: &mut Rng) -> (Vec<i32>, i32) {
    let class = rng.usize_below(N_CLASSES);
    let img = render(class, rng);
    let tokens: Vec<i32> = img[..SIDE * SIDE - 1].iter()
        .map(|&p| 2 + (p * (LEVELS - 1) as f32).round() as i32)
        .collect();
    (tokens, class as i32)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokens_in_range_all_classes() {
        let mut rng = Rng::new(0);
        let mut seen = [false; N_CLASSES];
        for _ in 0..200 {
            let (tokens, label) = sample(&mut rng);
            assert_eq!(tokens.len(), 255);
            assert!(tokens.iter().all(|&t| (2..=31).contains(&t)));
            seen[label as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "not all classes sampled");
    }

    #[test]
    fn classes_are_distinguishable() {
        // mean pixel intensity separates gradients from stripes on average;
        // check intra-class variance < inter-class distance for two easy
        // classes (0 vs 5) as a sanity proxy for learnability.
        let mut rng = Rng::new(1);
        let mean_of = |class: usize, rng: &mut Rng| -> f32 {
            let mut acc = 0.0;
            for _ in 0..20 {
                let img = render(class, rng);
                // column variance distinguishes h-stripes from discs
                let col0: f32 = (0..SIDE).map(|y| img[y * SIDE]).sum();
                acc += col0;
            }
            acc / 20.0
        };
        let a = mean_of(0, &mut rng);
        let b = mean_of(5, &mut rng);
        assert!((a - b).abs() > 0.2, "classes look identical: {a} vs {b}");
    }
}
