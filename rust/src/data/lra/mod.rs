//! Long Range Arena tasks (Tay et al. 2021), synthetic substitutions per
//! DESIGN.md §3: ListOps (real generator + evaluator), Retrieval
//! (synthetic citation pairs), G-Image (procedural grayscale shapes).
//!
//! All are sequence classification: the answer is predicted at the final
//! (masked) position; targets hold the class id there.

pub mod gimage;
pub mod listops;
pub mod retrieval;

use crate::tensor::{Batch, Tensor};

pub const PAD: i32 = 0;
pub const CLS: i32 = 1;

/// `(vocab_in, n_classes)` of an LRA task by name — sizes the native
/// model's embedding and classification head when training without an
/// artifact manifest.
pub fn task_dims(kind: &str) -> Option<(usize, usize)> {
    match kind {
        // 0 PAD, 1 CLS, digits 2..=11, ops 12..=15, brackets 16/17
        "listops" => Some((20, 10)),
        // 0 PAD, 1 CLS, 2 SEP, body tokens 3..=31; same/different
        "retrieval" => Some((32, 2)),
        // 0 PAD, 1 CLS, pixel levels 2..=31; ten shape classes
        "gimage" => Some((32, gimage::N_CLASSES)),
        _ => None,
    }
}

/// Stack classification examples: inputs padded to `t`, with a CLS answer
/// slot at the last position carrying the label.
pub fn collate_classification(examples: &[(Vec<i32>, i32)],
                              t: usize) -> Batch {
    let b = examples.len();
    let mut x = vec![PAD; b * t];
    let mut y = vec![0i32; b * t];
    let mut m = vec![0f32; b * t];
    for (i, (tokens, label)) in examples.iter().enumerate() {
        assert!(tokens.len() < t, "example len {} >= T {}", tokens.len(), t);
        let off = i * t;
        x[off..off + tokens.len()].copy_from_slice(tokens);
        x[off + t - 1] = CLS;
        y[off + t - 1] = *label;
        m[off + t - 1] = 1.0;
    }
    Batch {
        x: Tensor::i32(vec![b, t], x),
        targets: Tensor::i32(vec![b, t], y),
        mask: Tensor::f32(vec![b, t], m),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collate_puts_label_last() {
        let b = collate_classification(&[(vec![3, 4, 5], 7)], 6);
        assert_eq!(b.x.data.as_i32().unwrap(), &[3, 4, 5, 0, 0, CLS]);
        assert_eq!(b.targets.data.as_i32().unwrap(), &[0, 0, 0, 0, 0, 7]);
        assert_eq!(b.mask.data.as_f32().unwrap(),
                   &[0., 0., 0., 0., 0., 1.]);
    }
}
