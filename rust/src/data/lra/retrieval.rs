//! Retrieval (LRA): decide whether two token sequences refer to the same
//! document.  Synthetic substitution for the ACL Anthology corpus: positive
//! pairs are noisy copies of one "citation", negatives are independent
//! draws (DESIGN.md §3).
//!
//! Token map (vocab_in 32): 0 PAD, 1 CLS, 2 SEP, body tokens 3..=31.

use crate::util::rng::Rng;

pub const SEP: i32 = 2;
pub const BODY_MIN: i32 = 3;
pub const BODY_MAX: i32 = 31;

fn citation(rng: &mut Rng, len: usize) -> Vec<i32> {
    (0..len).map(|_| BODY_MIN
                 + rng.below((BODY_MAX - BODY_MIN + 1) as u64) as i32)
        .collect()
}

fn perturb(rng: &mut Rng, base: &[i32], edits: usize) -> Vec<i32> {
    let mut out = base.to_vec();
    for _ in 0..edits {
        let i = rng.usize_below(out.len());
        out[i] = BODY_MIN + rng.below((BODY_MAX - BODY_MIN + 1) as u64) as i32;
    }
    out
}

/// One example: (tokens = a ++ SEP ++ b, label ∈ {0: different, 1: same}).
/// Each side has length `side_len`.
pub fn sample(rng: &mut Rng, side_len: usize) -> (Vec<i32>, i32) {
    let a = citation(rng, side_len);
    let same = rng.bool(0.5);
    let b = if same {
        // light edit noise, ≤ 10% of tokens
        perturb(rng, &a, (side_len / 10).max(1))
    } else {
        citation(rng, side_len)
    };
    let mut tokens = Vec::with_capacity(2 * side_len + 1);
    tokens.extend(&a);
    tokens.push(SEP);
    tokens.extend(&b);
    (tokens, same as i32)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn structure_and_balance() {
        let mut rng = Rng::new(0);
        let mut pos = 0;
        for _ in 0..200 {
            let (tokens, label) = sample(&mut rng, 20);
            assert_eq!(tokens.len(), 41);
            assert_eq!(tokens[20], SEP);
            pos += label;
            if label == 1 {
                // positives differ in few positions
                let diffs = tokens[..20].iter().zip(&tokens[21..])
                    .filter(|(a, b)| a != b).count();
                assert!(diffs <= 2, "too many edits: {diffs}");
            }
        }
        assert!(pos > 60 && pos < 140, "unbalanced: {pos}/200");
    }

    #[test]
    fn negatives_actually_differ() {
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let (tokens, label) = sample(&mut rng, 30);
            if label == 0 {
                let diffs = tokens[..30].iter().zip(&tokens[31..])
                    .filter(|(a, b)| a != b).count();
                assert!(diffs > 10, "negative pair too similar");
            }
        }
    }
}
