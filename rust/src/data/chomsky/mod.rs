//! Chomsky Hierarchy benchmark tasks (Deletang et al. 2023) plus the two
//! xLSTM additions (Majority, Majority Count) — Tables 4/5.
//!
//! Every task emits `(input, target, mask)` triples of variable length that
//! the batcher pads to the executable's static T.  Shared token map
//! (vocab 16): 0 = PAD, 1 = SEP / answer-slot marker, task symbols from 2.
//!
//! Models train on content lengths ≤ `train_max_content` and are evaluated
//! on longer sequences (length generalization).

use crate::tensor::{Batch, Tensor};
use crate::util::rng::Rng;

pub mod tasks;

pub use tasks::{BucketSort, CycleNav, EvenPairs, Majority, MajorityCount,
                MissingDuplicate};

pub const PAD: i32 = 0;
pub const SEP: i32 = 1;

/// One generated example.
#[derive(Clone, Debug)]
pub struct Example {
    pub input: Vec<i32>,
    pub target: Vec<i32>,
    pub mask: Vec<f32>,
}

impl Example {
    pub fn len(&self) -> usize {
        self.input.len()
    }

    pub fn is_empty(&self) -> bool {
        self.input.is_empty()
    }
}

/// A formal-language transduction task.
pub trait ChomskyTask {
    /// Stable identifier used in artifact names ("bucket_sort", ...).
    fn name(&self) -> &'static str;

    /// Total sequence length for a given content length.
    fn total_len(&self, content: usize) -> usize;

    /// Largest content length whose total fits in `t`.
    fn max_content_for(&self, t: usize) -> usize {
        let mut n = 1;
        while self.total_len(n + 1) <= t {
            n += 1;
        }
        n
    }

    /// Generate one example with the given content length.
    fn sample(&self, rng: &mut Rng, content: usize) -> Example;
}

/// Pad examples to length `t` and stack into a Batch.
pub fn collate(examples: &[Example], t: usize) -> Batch {
    let b = examples.len();
    let mut x = vec![PAD; b * t];
    let mut y = vec![0i32; b * t];
    let mut m = vec![0f32; b * t];
    for (i, ex) in examples.iter().enumerate() {
        assert!(ex.len() <= t, "example len {} > T {}", ex.len(), t);
        let off = i * t;
        x[off..off + ex.len()].copy_from_slice(&ex.input);
        y[off..off + ex.len()].copy_from_slice(&ex.target);
        m[off..off + ex.len()].copy_from_slice(&ex.mask);
    }
    Batch {
        x: Tensor::i32(vec![b, t], x),
        targets: Tensor::i32(vec![b, t], y),
        mask: Tensor::f32(vec![b, t], m),
    }
}

/// Fresh batch with content lengths uniform in [min_content, max_content].
pub fn batch(task: &dyn ChomskyTask, rng: &mut Rng, batch_size: usize,
             t: usize, min_content: usize, max_content: usize) -> Batch {
    let hi = task.max_content_for(t).min(max_content);
    let lo = min_content.min(hi).max(1);
    let examples: Vec<Example> = (0..batch_size).map(|_| {
        let n = lo + rng.usize_below(hi - lo + 1);
        task.sample(rng, n)
    }).collect();
    collate(&examples, t)
}

/// All tasks, boxed, in the paper's Table 5 order.
pub fn all_tasks() -> Vec<Box<dyn ChomskyTask>> {
    vec![Box::new(BucketSort), Box::new(MissingDuplicate),
         Box::new(CycleNav), Box::new(EvenPairs),
         Box::new(Majority), Box::new(MajorityCount)]
}

pub fn by_name(name: &str) -> Option<Box<dyn ChomskyTask>> {
    all_tasks().into_iter().find(|t| t.name() == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collate_pads() {
        let ex = Example {
            input: vec![2, 3, 1],
            target: vec![0, 0, 2],
            mask: vec![0.0, 0.0, 1.0],
        };
        let b = collate(&[ex], 6);
        assert_eq!(b.x.data.as_i32().unwrap(), &[2, 3, 1, 0, 0, 0]);
        assert_eq!(b.mask.data.as_f32().unwrap(),
                   &[0.0, 0.0, 1.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn max_content_roundtrip() {
        for task in all_tasks() {
            let n = task.max_content_for(64);
            assert!(task.total_len(n) <= 64,
                    "{}: total {} > 64", task.name(), task.total_len(n));
            assert!(task.total_len(n + 1) > 64, "{} not maximal", task.name());
        }
    }

    #[test]
    fn all_tasks_generate_within_vocab() {
        let mut rng = Rng::new(0);
        for task in all_tasks() {
            for _ in 0..20 {
                let ex = task.sample(&mut rng, 12);
                assert!(ex.input.iter().all(|&t| (0..16).contains(&t)),
                        "{} input out of vocab", task.name());
                assert!(ex.target.iter().all(|&t| (0..16).contains(&t)),
                        "{} target out of vocab", task.name());
                assert_eq!(ex.input.len(), ex.target.len());
                assert_eq!(ex.input.len(), ex.mask.len());
                assert!(ex.mask.iter().any(|&m| m > 0.0),
                        "{} empty mask", task.name());
            }
        }
    }
}
