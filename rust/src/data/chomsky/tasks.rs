//! The six Chomsky-hierarchy tasks of Table 5.
//!
//! Layouts (0 = PAD, 1 = SEP/answer marker):
//!   BucketSort       : w (n sym ∈ 2..=6)  SEP  n answer slots → sorted w
//!   MissingDuplicate : w (n sym ∈ {2,3})  w-with-one-MASK(4)  SEP  1 slot
//!   CycleNav         : n moves ∈ {2:+1, 3:-1, 4:stay}  SEP  1 slot
//!                      → final position on a 5-cycle as token 5+pos
//!   EvenPairs        : w (n sym ∈ {2,3})  SEP  1 slot → 5 iff first==last
//!                      (⇔ even number of ab/ba boundary pairs) else 6
//!   Majority         : w (n sym ∈ {2,3,4})  SEP  1 slot → majority symbol
//!   MajorityCount    : w (n sym ∈ {2,3})   SEP  9 slots → count of the
//!                      majority symbol, 9-bit binary MSB-first (2=0, 3=1)

use super::{ChomskyTask, Example, SEP};
use crate::util::rng::Rng;

const MASK_TOK: i32 = 4;

fn answer_section(input: &mut Vec<i32>, target: &mut Vec<i32>,
                  mask: &mut Vec<f32>, answers: &[i32]) {
    for &a in answers {
        input.push(SEP);
        target.push(a);
        mask.push(1.0);
    }
}

fn content_section(input: &mut Vec<i32>, target: &mut Vec<i32>,
                   mask: &mut Vec<f32>, content: &[i32]) {
    input.extend_from_slice(content);
    target.extend(std::iter::repeat(0).take(content.len()));
    mask.extend(std::iter::repeat(0.0).take(content.len()));
}

fn sep(input: &mut Vec<i32>, target: &mut Vec<i32>, mask: &mut Vec<f32>) {
    input.push(SEP);
    target.push(0);
    mask.push(0.0);
}

// ---------------------------------------------------------------------------

pub struct BucketSort;

impl ChomskyTask for BucketSort {
    fn name(&self) -> &'static str {
        "bucket_sort"
    }

    fn total_len(&self, n: usize) -> usize {
        2 * n + 1
    }

    fn sample(&self, rng: &mut Rng, n: usize) -> Example {
        let w: Vec<i32> = (0..n).map(|_| 2 + rng.below(5) as i32).collect();
        let mut sorted = w.clone();
        sorted.sort_unstable();
        let (mut i, mut t, mut m) = (Vec::new(), Vec::new(), Vec::new());
        content_section(&mut i, &mut t, &mut m, &w);
        sep(&mut i, &mut t, &mut m);
        answer_section(&mut i, &mut t, &mut m, &sorted);
        Example { input: i, target: t, mask: m }
    }
}

// ---------------------------------------------------------------------------

pub struct MissingDuplicate;

impl ChomskyTask for MissingDuplicate {
    fn name(&self) -> &'static str {
        "missing_duplicate"
    }

    fn total_len(&self, n: usize) -> usize {
        2 * n + 2
    }

    fn sample(&self, rng: &mut Rng, n: usize) -> Example {
        let w: Vec<i32> = (0..n).map(|_| 2 + rng.below(2) as i32).collect();
        let hole = rng.usize_below(n);
        let mut w2 = w.clone();
        let answer = w2[hole];
        w2[hole] = MASK_TOK;
        let (mut i, mut t, mut m) = (Vec::new(), Vec::new(), Vec::new());
        content_section(&mut i, &mut t, &mut m, &w);
        content_section(&mut i, &mut t, &mut m, &w2);
        sep(&mut i, &mut t, &mut m);
        answer_section(&mut i, &mut t, &mut m, &[answer]);
        Example { input: i, target: t, mask: m }
    }
}

// ---------------------------------------------------------------------------

pub struct CycleNav;

pub const CYCLE: i32 = 5;

impl ChomskyTask for CycleNav {
    fn name(&self) -> &'static str {
        "cycle_nav"
    }

    fn total_len(&self, n: usize) -> usize {
        n + 2
    }

    fn sample(&self, rng: &mut Rng, n: usize) -> Example {
        let moves: Vec<i32> = (0..n).map(|_| 2 + rng.below(3) as i32)
            .collect();
        let mut pos: i32 = 0;
        for &mv in &moves {
            pos = match mv {
                2 => (pos + 1).rem_euclid(CYCLE),
                3 => (pos - 1).rem_euclid(CYCLE),
                _ => pos,
            };
        }
        let (mut i, mut t, mut m) = (Vec::new(), Vec::new(), Vec::new());
        content_section(&mut i, &mut t, &mut m, &moves);
        sep(&mut i, &mut t, &mut m);
        answer_section(&mut i, &mut t, &mut m, &[5 + pos]);
        Example { input: i, target: t, mask: m }
    }
}

// ---------------------------------------------------------------------------

pub struct EvenPairs;

impl ChomskyTask for EvenPairs {
    fn name(&self) -> &'static str {
        "even_pairs"
    }

    fn total_len(&self, n: usize) -> usize {
        n + 2
    }

    fn sample(&self, rng: &mut Rng, n: usize) -> Example {
        let w: Vec<i32> = (0..n).map(|_| 2 + rng.below(2) as i32).collect();
        let even = w.first() == w.last();
        let (mut i, mut t, mut m) = (Vec::new(), Vec::new(), Vec::new());
        content_section(&mut i, &mut t, &mut m, &w);
        sep(&mut i, &mut t, &mut m);
        answer_section(&mut i, &mut t, &mut m,
                       &[if even { 5 } else { 6 }]);
        Example { input: i, target: t, mask: m }
    }
}

// ---------------------------------------------------------------------------

pub struct Majority;

impl ChomskyTask for Majority {
    fn name(&self) -> &'static str {
        "majority"
    }

    fn total_len(&self, n: usize) -> usize {
        n + 2
    }

    fn sample(&self, rng: &mut Rng, n: usize) -> Example {
        let w: Vec<i32> = (0..n).map(|_| 2 + rng.below(3) as i32).collect();
        let mut counts = [0usize; 3];
        for &s in &w {
            counts[(s - 2) as usize] += 1;
        }
        let best = (0..3).max_by_key(|&k| (counts[k], 2 - k)).unwrap();
        let (mut i, mut t, mut m) = (Vec::new(), Vec::new(), Vec::new());
        content_section(&mut i, &mut t, &mut m, &w);
        sep(&mut i, &mut t, &mut m);
        answer_section(&mut i, &mut t, &mut m, &[2 + best as i32]);
        Example { input: i, target: t, mask: m }
    }
}

// ---------------------------------------------------------------------------

pub struct MajorityCount;

pub const COUNT_BITS: usize = 9;

impl ChomskyTask for MajorityCount {
    fn name(&self) -> &'static str {
        "majority_count"
    }

    fn total_len(&self, n: usize) -> usize {
        n + 1 + COUNT_BITS
    }

    fn sample(&self, rng: &mut Rng, n: usize) -> Example {
        let w: Vec<i32> = (0..n).map(|_| 2 + rng.below(2) as i32).collect();
        let ones = w.iter().filter(|&&s| s == 3).count();
        let count = ones.max(n - ones);
        let bits: Vec<i32> = (0..COUNT_BITS).rev()
            .map(|b| 2 + ((count >> b) & 1) as i32)
            .collect();
        let (mut i, mut t, mut m) = (Vec::new(), Vec::new(), Vec::new());
        content_section(&mut i, &mut t, &mut m, &w);
        sep(&mut i, &mut t, &mut m);
        answer_section(&mut i, &mut t, &mut m, &bits);
        Example { input: i, target: t, mask: m }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::chomsky::ChomskyTask;

    #[test]
    fn bucket_sort_sorted_answers() {
        let mut rng = Rng::new(0);
        for n in [1usize, 2, 5, 17] {
            let ex = BucketSort.sample(&mut rng, n);
            assert_eq!(ex.input.len(), 2 * n + 1);
            let answers: Vec<i32> = ex.target.iter().zip(&ex.mask)
                .filter(|(_, &m)| m > 0.0).map(|(&t, _)| t).collect();
            assert_eq!(answers.len(), n);
            let mut expect: Vec<i32> = ex.input[..n].to_vec();
            expect.sort_unstable();
            assert_eq!(answers, expect, "n={n}");
        }
    }

    #[test]
    fn missing_duplicate_recoverable() {
        let mut rng = Rng::new(1);
        for _ in 0..30 {
            let ex = MissingDuplicate.sample(&mut rng, 9);
            let w = &ex.input[..9];
            let w2 = &ex.input[9..18];
            let hole = w2.iter().position(|&s| s == MASK_TOK).unwrap();
            let answer = ex.target.iter().zip(&ex.mask)
                .find(|(_, &m)| m > 0.0).unwrap().0;
            assert_eq!(*answer, w[hole]);
            // the two halves agree everywhere else
            for k in 0..9 {
                if k != hole {
                    assert_eq!(w[k], w2[k]);
                }
            }
        }
    }

    #[test]
    fn cycle_nav_known_sequence() {
        // +1 +1 -1 stay +1 → position 2
        let ex = {
            let mut rng = Rng::new(2);
            // generate until we get the desired move pattern? no — compute
            // directly by constructing the example by hand through sample's
            // own logic: instead verify consistency re-simulating.
            CycleNav.sample(&mut rng, 13)
        };
        let moves = &ex.input[..13];
        let mut pos: i32 = 0;
        for &mv in moves {
            pos = match mv {
                2 => (pos + 1).rem_euclid(5),
                3 => (pos - 1).rem_euclid(5),
                _ => pos,
            };
        }
        let ans = ex.target.iter().zip(&ex.mask)
            .find(|(_, &m)| m > 0.0).unwrap().0;
        assert_eq!(*ans, 5 + pos);
    }

    #[test]
    fn even_pairs_first_last() {
        let mut rng = Rng::new(3);
        for _ in 0..30 {
            let ex = EvenPairs.sample(&mut rng, 7);
            let w = &ex.input[..7];
            let ans = *ex.target.iter().zip(&ex.mask)
                .find(|(_, &m)| m > 0.0).unwrap().0;
            assert_eq!(ans == 5, w[0] == w[6]);
        }
    }

    #[test]
    fn majority_is_argmax() {
        let mut rng = Rng::new(4);
        for _ in 0..30 {
            let ex = Majority.sample(&mut rng, 11);
            let w = &ex.input[..11];
            let ans = *ex.target.iter().zip(&ex.mask)
                .find(|(_, &m)| m > 0.0).unwrap().0;
            let count = |s: i32| w.iter().filter(|&&x| x == s).count();
            for s in 2..=4 {
                assert!(count(ans) >= count(s),
                        "answer {ans} not majority in {w:?}");
            }
        }
    }

    #[test]
    fn majority_count_binary() {
        let mut rng = Rng::new(5);
        for _ in 0..30 {
            let ex = MajorityCount.sample(&mut rng, 10);
            let w = &ex.input[..10];
            let ones = w.iter().filter(|&&s| s == 3).count();
            let count = ones.max(10 - ones);
            let bits: Vec<i32> = ex.target.iter().zip(&ex.mask)
                .filter(|(_, &m)| m > 0.0).map(|(&t, _)| t).collect();
            assert_eq!(bits.len(), COUNT_BITS);
            let decoded = bits.iter()
                .fold(0usize, |acc, &b| acc * 2 + (b - 2) as usize);
            assert_eq!(decoded, count);
        }
    }
}
