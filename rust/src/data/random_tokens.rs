//! Uniform random-token batches — the workload for the Figure 1 training
//! cost sweep (cost is shape-dependent, not content-dependent).

use crate::tensor::{Batch, Tensor};
use crate::util::rng::Rng;

pub fn batch(rng: &mut Rng, b: usize, t: usize, vocab: i32) -> Batch {
    let n = b * t;
    let x: Vec<i32> = (0..n).map(|_| rng.below(vocab as u64) as i32)
        .collect();
    let y: Vec<i32> = (0..n).map(|_| rng.below(vocab as u64) as i32)
        .collect();
    Batch {
        x: Tensor::i32(vec![b, t], x),
        targets: Tensor::i32(vec![b, t], y),
        mask: Tensor::f32(vec![b, t], vec![1.0; n]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn in_vocab() {
        let mut rng = Rng::new(0);
        let b = batch(&mut rng, 3, 5, 16);
        assert!(b.x.data.as_i32().unwrap().iter().all(|&v| v < 16 && v >= 0));
        assert_eq!(b.x.dims, vec![3, 5]);
    }
}
