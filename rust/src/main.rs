//! `minrnn` CLI — leader entrypoint.
use minrnn::coordinator::cli_main;

fn main() {
    let code = cli_main(std::env::args().skip(1).collect());
    std::process::exit(code);
}
