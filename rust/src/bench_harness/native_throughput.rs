//! Native-backend throughput benchmark: the repo's perf trajectory for
//! the pure-Rust serving path.
//!
//! Measures, on a seeded random-init backbone (conv + MLP, the full block
//! structure):
//!
//! * **prefill** — parallel context ingestion, tokens/sec;
//! * **decode**  — steady-state lockstep decode, tokens/sec and p95 step
//!   latency, across batch sizes × {1 thread, all threads};
//! * **serve**   — the dynamic-batching loop end to end (continuous lane
//!   refill), tokens/sec + mean/p95 request latency;
//! * **serve_async** — the admission scheduler under an *open-loop*
//!   arrival process (a driver thread submits at 1.25x the closed-loop
//!   request rate), recording **queue-wait and decode latency
//!   separately** — under load, tail latency is queueing, and the split
//!   is what a capacity plan needs;
//! * **session_cache** — warm multi-turn serving: a cold pass serves
//!   each session's first turn through a shared session cache, a warm
//!   pass extends every conversation with a follow-up turn; reports the
//!   warm hit rate, prefill tokens saved, and cold vs warm tok/s;
//!
//! and derives `speedup_batched_threaded`: threaded batch-N decode over
//! single-threaded batch-1 decode — the "fully parallelizable in
//! practice" number the paper's pitch implies.  Results are written as
//! JSON to `BENCH_native.json` (CI uploads it as an artifact and fails on
//! >30% tokens/sec regression against the committed baseline).
//!
//! Entry points: `cargo bench --bench native_throughput` (quick mode;
//! MINRNN_FULL=1 for full) and `minrnn bench` (see `coordinator`).

use std::cell::RefCell;
use std::path::PathBuf;

use anyhow::Result;

use crate::backend::native::quant;
use crate::backend::{NativeBackend, NativeInit, NativeModel};
use crate::coordinator::scheduler::{Backpressure, Scheduler, SchedulerOpts};
use crate::coordinator::server::{self, Request, ServeOpts};
use crate::coordinator::session_cache::SessionCache;
use crate::log_info;
use crate::runtime::Backend;
use crate::tensor::Tensor;
use crate::util::bench::{bench, BenchConfig};
use crate::util::json::{self, Json};
use crate::util::rng::Rng;
use crate::util::simd::{self, Level};
use crate::util::threads;

/// Benchmark profile; `quick()` keeps CI smoke runs in seconds,
/// `full()` is the number to quote.
#[derive(Clone, Debug)]
pub struct Config {
    pub quick: bool,
    pub kind: String,
    pub n_layers: usize,
    pub d_model: usize,
    pub vocab: usize,
    pub prefill_batch: usize,
    pub prefill_t: usize,
    pub decode_batches: Vec<usize>,
    pub serve_requests: usize,
    pub serve_tokens: usize,
    pub max_batch: usize,
    /// Output JSON path (`None` = don't write).
    pub out: Option<PathBuf>,
}

impl Config {
    pub fn quick() -> Config {
        Config {
            quick: true,
            kind: "mingru".to_string(),
            n_layers: 4,
            d_model: 128,
            vocab: 64,
            prefill_batch: 4,
            prefill_t: 64,
            decode_batches: vec![1, 8],
            serve_requests: 12,
            serve_tokens: 12,
            max_batch: 8,
            out: Some(PathBuf::from("BENCH_native.json")),
        }
    }

    pub fn full() -> Config {
        Config {
            quick: false,
            kind: "mingru".to_string(),
            n_layers: 4,
            d_model: 256,
            vocab: 64,
            prefill_batch: 8,
            prefill_t: 256,
            decode_batches: vec![1, 8, 32],
            serve_requests: 24,
            serve_tokens: 32,
            max_batch: 8,
            out: Some(PathBuf::from("BENCH_native.json")),
        }
    }
}

impl Default for Config {
    fn default() -> Self {
        Config::quick()
    }
}

/// Run the benchmark, log a summary, optionally write the JSON report,
/// and return it.
pub fn run(cfg: &Config) -> Result<Json> {
    let bc = if cfg.quick {
        BenchConfig::quick()
    } else {
        BenchConfig::default()
    };
    let model = NativeModel::init_random(&NativeInit {
        kind: cfg.kind.clone(),
        n_layers: cfg.n_layers,
        d_model: cfg.d_model,
        expansion: 1,
        vocab_in: Some(cfg.vocab),
        input_dim: None,
        vocab_out: cfg.vocab,
        conv: true,
        mlp: true,
        mlp_mult: 4,
        forget_bias: 1.0,
        // transformer bench runs need the KV ring to cover the prefill
        // context; harmless for the recurrent kinds
        max_len: cfg.prefill_t.max(256),
        n_heads: 4,
    }, 0x7B)?;
    let backend = NativeBackend::new(model);
    let pool = threads::global();
    let active0 = pool.active();
    let cores = threads::available_threads();
    log_info!("native throughput: {} {}L d{} vocab {} — {} threads \
               ({} cores), {} mode",
              cfg.kind, cfg.n_layers, cfg.d_model, cfg.vocab, active0,
              cores, if cfg.quick { "quick" } else { "full" });

    // -- prefill ------------------------------------------------------------
    let mut rng = Rng::new(0xBE7C);
    let (pb, pt) = (cfg.prefill_batch, cfg.prefill_t);
    let ctx = Tensor::i32(
        vec![pb, pt],
        (0..pb * pt).map(|_| rng.below(cfg.vocab as u64) as i32).collect());
    let r = bench("prefill", &bc, || {
        backend.prefill(&ctx).unwrap();
    });
    let prefill_tok_s = (pb * pt) as f64 / r.mean_s;
    log_info!("  prefill  b{pb} t{pt}: {:>10.0} tok/s  ({:.2} ms/pass)",
              prefill_tok_s, r.mean_ms());
    let prefill = json::obj(vec![
        ("batch", json::num(pb as f64)),
        ("seq_len", json::num(pt as f64)),
        ("tok_s", json::num(prefill_tok_s)),
        ("mean_ms", json::num(r.mean_ms())),
        ("p95_ms", json::num(r.p95_s * 1e3)),
    ]);

    // -- decode: batch × thread grid ----------------------------------------
    let mut decode = Vec::new();
    let mut tok_s_at = |batch: usize, nthr: usize| -> Result<f64> {
        pool.set_active(nthr);
        let x = Tensor::i32(
            vec![batch],
            (0..batch).map(|i| (i % cfg.vocab) as i32).collect());
        let mut state = Some(backend.decode_state(batch)?);
        let r = bench(&format!("decode_b{batch}_thr{nthr}"), &bc, || {
            let s = state.take().unwrap();
            let (_, s2) = backend.decode_step(&x, s).unwrap();
            state = Some(s2);
        });
        pool.set_active(active0);
        let tok_s = batch as f64 / r.mean_s;
        log_info!("  decode   b{batch} x{nthr}thr: {:>8.0} tok/s  \
                   ({:.0} us/step, p95 {:.0} us)",
                  tok_s, r.mean_us(), r.p95_s * 1e6);
        decode.push(json::obj(vec![
            ("batch", json::num(batch as f64)),
            ("threads", json::num(nthr as f64)),
            ("tok_s", json::num(tok_s)),
            ("step_us", json::num(r.mean_us())),
            ("p95_step_us", json::num(r.p95_s * 1e6)),
        ]));
        Ok(tok_s)
    };
    let mut base_b1_seq = f64::NAN;
    let mut best_batched = f64::NAN;
    let largest = cfg.decode_batches.iter().copied().max().unwrap_or(1);
    let target_batch = if cfg.decode_batches.contains(&8) { 8 }
                       else { largest };
    for &batch in &cfg.decode_batches {
        let seq = tok_s_at(batch, 1)?;
        if batch == 1 {
            base_b1_seq = seq;
        }
        let thr = if active0 > 1 {
            tok_s_at(batch, active0)?
        } else {
            seq
        };
        if batch == target_batch {
            // honest "batched + threaded" number: the all-threads run,
            // even if threading hurt at this batch size — never silently
            // substitute the single-threaded result
            best_batched = thr;
        }
    }
    let speedup = best_batched / base_b1_seq;
    log_info!("  speedup  batched+threaded vs single-thread batch-1: \
               {speedup:.2}x");

    // -- serve --------------------------------------------------------------
    pool.set_active(active0);
    let requests: Vec<Request> = (0..cfg.serve_requests).map(|i| Request {
        id: i as u64,
        prompt: (0..8 + rng.usize_below(8))
            .map(|_| rng.below(cfg.vocab as u64) as i32).collect(),
        n_tokens: cfg.serve_tokens,
        session: None,
    }).collect();
    let stats = server::ServeConfig::new()
        .temperature(0.8)
        .seed(7)
        .max_batch(cfg.max_batch)
        .build()?
        .run(&backend, requests)?;
    log_info!("  serve    {} req x {} tok (max-batch {}): {:>8.0} tok/s, \
               mean {:.1} ms, p95 {:.1} ms",
              cfg.serve_requests, cfg.serve_tokens, cfg.max_batch,
              stats.throughput_tok_s(), stats.mean_latency_s() * 1e3,
              stats.p95_latency_s() * 1e3);
    let serve = json::obj(vec![
        ("requests", json::num(cfg.serve_requests as f64)),
        ("tokens_per_request", json::num(cfg.serve_tokens as f64)),
        ("max_batch", json::num(cfg.max_batch as f64)),
        ("tok_s", json::num(stats.throughput_tok_s())),
        ("mean_latency_ms", json::num(stats.mean_latency_s() * 1e3)),
        ("p95_latency_ms", json::num(stats.p95_latency_s() * 1e3)),
    ]);

    // -- async serve: open-loop arrival-rate driver --------------------------
    //
    // Mild overload (1.25x the request rate the closed-loop run sustained)
    // so queue-wait becomes visible, then record it *separately* from
    // decode latency: under load, tail latency is queueing, and a capacity
    // plan needs the split, not the blur.
    let sync_req_s =
        cfg.serve_requests as f64 / stats.total_s.max(1e-9);
    let arrival_req_s = sync_req_s * 1.25;
    let async_requests: Vec<Request> = (0..cfg.serve_requests)
        .map(|i| Request {
            id: i as u64,
            prompt: (0..8 + rng.usize_below(8))
                .map(|_| rng.below(cfg.vocab as u64) as i32).collect(),
            n_tokens: cfg.serve_tokens,
            session: None,
        }).collect();
    let (sched, handle) = Scheduler::new(&backend, SchedulerOpts {
        serve: ServeOpts {
            temperature: 0.8,
            seed: 7,
            max_batch: cfg.max_batch,
        },
        queue_depth: cfg.serve_requests.max(1),
        backpressure: Backpressure::Block,
        default_deadline: None,
        lanes: Some(cfg.max_batch),
        ..Default::default()
    })?;
    let gap = std::time::Duration::from_secs_f64(
        1.0 / arrival_req_s.max(1e-9));
    let submitter = std::thread::spawn(move || {
        for req in async_requests {
            std::thread::sleep(gap);
            if handle.submit(req).is_err() {
                break;
            }
        }
        handle.close();
    });
    let astats = sched.run()?;
    submitter.join()
        .map_err(|_| anyhow::anyhow!("bench submitter thread panicked"))?;
    log_info!("  async    {} req open-loop @ {:.1} req/s: {:>8.0} tok/s, \
               queue-wait mean {:.1} ms p95 {:.1} ms, decode mean {:.1} ms \
               p95 {:.1} ms, {} batch(es)",
              cfg.serve_requests, arrival_req_s, astats.throughput_tok_s(),
              astats.mean_queue_s() * 1e3, astats.p95_queue_s() * 1e3,
              astats.mean_service_s() * 1e3, astats.p95_service_s() * 1e3,
              astats.batches_started);
    let serve_async = json::obj(vec![
        ("requests", json::num(cfg.serve_requests as f64)),
        ("tokens_per_request", json::num(cfg.serve_tokens as f64)),
        ("max_batch", json::num(cfg.max_batch as f64)),
        ("arrival_req_s", json::num(arrival_req_s)),
        ("queue_depth", json::num(cfg.serve_requests.max(1) as f64)),
        ("tok_s", json::num(astats.throughput_tok_s())),
        ("queue_wait_mean_ms", json::num(astats.mean_queue_s() * 1e3)),
        ("queue_wait_p95_ms", json::num(astats.p95_queue_s() * 1e3)),
        ("decode_mean_ms", json::num(astats.mean_service_s() * 1e3)),
        ("decode_p95_ms", json::num(astats.p95_service_s() * 1e3)),
        ("p95_latency_ms", json::num(astats.p95_latency_s() * 1e3)),
        ("submitted", json::num(astats.submitted as f64)),
        ("admitted", json::num(astats.admitted as f64)),
        ("rejected", json::num(astats.rejected as f64)),
        ("expired", json::num(astats.expired.len() as f64)),
        ("max_queue_depth", json::num(astats.max_queue_depth as f64)),
        ("batches_started", json::num(astats.batches_started as f64)),
    ]);

    // -- session cache: warm multi-turn serving ------------------------------
    //
    // Each session's first turn runs cold through a shared cache (greedy,
    // so the comparison is sampling-order independent); the second turn
    // extends prompt + reply with fresh user tokens.  Every warm prompt's
    // prefix must hit the completion state the cold pass exported, so the
    // shared history is never re-prefilled.
    let n_sessions = cfg.serve_requests.max(1);
    let session_cache = RefCell::new(SessionCache::new(8 << 20));
    let greedy = server::ServeConfig::new()
        .temperature(0.0)
        .seed(7)
        .max_batch(cfg.max_batch)
        .build()?;
    let turn1: Vec<Request> = (0..n_sessions).map(|i| Request {
        id: i as u64,
        prompt: (0..8 + rng.usize_below(8))
            .map(|_| rng.below(cfg.vocab as u64) as i32).collect(),
        n_tokens: cfg.serve_tokens,
        session: Some(i as u64),
    }).collect();
    let cold = greedy.run_with_cache(&backend, turn1.clone(),
                                     Some(&session_cache))?;
    let mut turn2 = Vec::new();
    for r in &cold.responses {
        let mut prompt = turn1[r.id as usize].prompt.clone();
        prompt.extend_from_slice(&r.tokens);
        prompt.extend(
            (0..4).map(|_| rng.below(cfg.vocab as u64) as i32));
        turn2.push(Request {
            id: r.id,
            prompt,
            n_tokens: cfg.serve_tokens,
            session: Some(r.id),
        });
    }
    let warm = greedy.run_with_cache(&backend, turn2,
                                     Some(&session_cache))?;
    let lookups = warm.session_hits + warm.session_misses;
    let hit_rate = warm.session_hits as f64 / lookups.max(1) as f64;
    log_info!("  sessions {} warm follow-up turns: hit rate {:.2}, {} \
               prefill tokens saved, cold {:>8.0} tok/s, warm {:>8.0} \
               tok/s",
              n_sessions, hit_rate, warm.prefill_tokens_saved,
              cold.throughput_tok_s(), warm.throughput_tok_s());
    let session_cache_json = json::obj(vec![
        ("sessions", json::num(n_sessions as f64)),
        ("tokens_per_request", json::num(cfg.serve_tokens as f64)),
        ("hit_rate", json::num(hit_rate)),
        ("prefill_tokens_saved",
         json::num(warm.prefill_tokens_saved as f64)),
        ("cold_tok_s", json::num(cold.throughput_tok_s())),
        ("warm_tok_s", json::num(warm.throughput_tok_s())),
        ("evictions", json::num(warm.session_evictions as f64)),
    ]);

    // -- recovery: durability and restart floors ------------------------------
    //
    // What robustness costs (and buys): a durable checkpoint commit
    // (write + fsync file + rename + fsync dir, CRC trailer included), a
    // durable LATEST-pointer commit (the same path on a tiny payload —
    // nearly pure fsync), and the crash-restart floor: scan the ring for
    // the newest *valid* checkpoint and load it into a serving-ready
    // backend.  No faults are injected here — the disabled fault layer is
    // the production configuration being measured.
    let rec_dir = std::env::temp_dir().join("minrnn_bench_recovery");
    std::fs::create_dir_all(&rec_dir)?;
    let trainer = crate::backend::NativeTrainer::new(
        NativeModel::init_random(&NativeInit {
            kind: cfg.kind.clone(),
            n_layers: cfg.n_layers,
            d_model: cfg.d_model,
            expansion: 1,
            vocab_in: Some(cfg.vocab),
            input_dim: None,
            vocab_out: cfg.vocab,
            conv: true,
            mlp: true,
            mlp_mult: 4,
            forget_bias: 1.0,
            max_len: cfg.prefill_t.max(256),
            n_heads: 4,
        }, 0x7C)?, "bench-recovery");
    let ckpt = rec_dir.join("bench-recovery.step00000001.ckpt");
    let rc = bench("ckpt_commit", &bc, || {
        trainer.save(&ckpt).unwrap();
    });
    let ckpt_bytes = std::fs::metadata(&ckpt)?.len();
    let latest = rec_dir.join("bench-recovery.LATEST");
    let rp = bench("pointer_commit", &bc, || {
        crate::util::io::commit_durable(
            &latest, b"bench-recovery.step00000001.ckpt").unwrap();
    });
    let rl = bench("recover_load", &bc, || {
        let found = crate::coordinator::trainer::recover_checkpoint(
            &rec_dir, "bench-recovery").unwrap();
        NativeBackend::from_checkpoint(&found).unwrap();
    });
    let _ = std::fs::remove_dir_all(&rec_dir);
    log_info!("  recovery ckpt commit {:.2} ms ({} KiB), pointer commit \
               {:.2} ms, recover+load {:.2} ms",
              rc.mean_ms(), ckpt_bytes >> 10, rp.mean_ms(), rl.mean_ms());
    let recovery = json::obj(vec![
        ("ckpt_bytes", json::num(ckpt_bytes as f64)),
        ("ckpt_commit_ms", json::num(rc.mean_ms())),
        ("ckpt_commit_p95_ms", json::num(rc.p95_s * 1e3)),
        ("pointer_commit_ms", json::num(rp.mean_ms())),
        ("recover_load_ms", json::num(rl.mean_ms())),
        ("recover_load_p95_ms", json::num(rl.p95_s * 1e3)),
    ]);

    // -- simd: dispatched lane kernels vs forced-scalar ----------------------
    //
    // f32 results are bit-identical across dispatch levels (the invariance
    // contract in ARCHITECTURE.md and tests/simd_props.rs), so this section
    // is pure speed: steady-state batch-1 decode under the forced scalar
    // fallback vs the runtime-detected level.
    let decode_b1 = |bk: &NativeBackend, label: &str| -> Result<f64> {
        let x = Tensor::i32(vec![1], vec![0]);
        let mut state = Some(bk.decode_state(1)?);
        let r = bench(label, &bc, || {
            let s = state.take().unwrap();
            let (_, s2) = bk.decode_step(&x, s).unwrap();
            state = Some(s2);
        });
        Ok(1.0 / r.mean_s)
    };
    let detected = simd::level();
    let lvl_name = |l: Level| match l {
        Level::Scalar => "scalar",
        Level::Avx2 => "avx2",
    };
    simd::set_forced(Some(Level::Scalar));
    let scalar_res = decode_b1(&backend, "decode_b1_forced_scalar");
    simd::set_forced(None);
    let simd_scalar_tok_s = scalar_res?;
    let simd_tok_s = if detected == Level::Scalar {
        simd_scalar_tok_s
    } else {
        decode_b1(&backend, "decode_b1_simd")?
    };
    log_info!("  simd     level {}: decode b1 {:>8.0} tok/s scalar, \
               {:>8.0} tok/s dispatched ({:.2}x)",
              lvl_name(detected), simd_scalar_tok_s, simd_tok_s,
              simd_tok_s / simd_scalar_tok_s);
    let simd_json = json::obj(vec![
        ("level", json::s(lvl_name(detected))),
        ("decode_b1_scalar_tok_s", json::num(simd_scalar_tok_s)),
        ("decode_b1_tok_s", json::num(simd_tok_s)),
        ("speedup", json::num(simd_tok_s / simd_scalar_tok_s)),
    ]);

    // -- quant: int8 weights vs the f32 source -------------------------------
    //
    // Quantize a clone of the bench model, report the golden error the
    // `minrnn quantize` gate uses, the dense weight-byte shrink, and the
    // batch-1 decode throughput on both (decode is bandwidth-bound, so
    // halving weight bytes is the paper-relevant lever).
    let mut qmodel = backend.model.clone();
    quant::quantize_model(&mut qmodel)?;
    let quant_rel_err = quant::probe_rel_err(&backend.model, &qmodel)?;
    let mut bytes_f32 = 0usize;
    backend.model.for_each_dense(&mut |d| {
        bytes_f32 += 4 * (d.w.len() + d.b.len());
    });
    let mut bytes_int8 = 0usize;
    qmodel.for_each_dense(&mut |d| {
        let qd = d.q.as_ref().expect("just quantized");
        bytes_int8 += qd.q.len() + 4 * (qd.scales.len() + d.b.len());
    });
    let qbackend = NativeBackend::new(qmodel);
    let f32_b1_tok_s = decode_b1(&backend, "decode_b1_f32")?;
    let int8_b1_tok_s = decode_b1(&qbackend, "decode_b1_int8")?;
    log_info!("  quant    int8 rel err {:.2e} (budget {}), dense bytes \
               {} -> {}, decode b1 {:>8.0} -> {:>8.0} tok/s",
              quant_rel_err, quant::LOGIT_REL_ERR_BUDGET, bytes_f32,
              bytes_int8, f32_b1_tok_s, int8_b1_tok_s);
    let quant_json = json::obj(vec![
        ("logit_rel_err", json::num(quant_rel_err as f64)),
        ("logit_rel_err_budget",
         json::num(quant::LOGIT_REL_ERR_BUDGET as f64)),
        ("dense_bytes_f32", json::num(bytes_f32 as f64)),
        ("dense_bytes_int8", json::num(bytes_int8 as f64)),
        ("decode_b1_f32_tok_s", json::num(f32_b1_tok_s)),
        ("decode_b1_int8_tok_s", json::num(int8_b1_tok_s)),
    ]);

    let report = json::obj(vec![
        ("schema", json::s("minrnn.native_throughput.v1")),
        ("quick", Json::Bool(cfg.quick)),
        ("cores", json::num(cores as f64)),
        ("threads", json::num(active0 as f64)),
        ("model", json::obj(vec![
            ("kind", json::s(&cfg.kind)),
            ("layers", json::num(cfg.n_layers as f64)),
            ("d_model", json::num(cfg.d_model as f64)),
            ("vocab", json::num(cfg.vocab as f64)),
        ])),
        ("prefill", prefill),
        ("decode", Json::Arr(decode)),
        ("serve", serve),
        ("serve_async", serve_async),
        ("session_cache", session_cache_json),
        ("recovery", recovery),
        ("simd", simd_json),
        ("quant", quant_json),
        ("speedup_batched_threaded", json::num(speedup)),
    ]);
    if let Some(out) = &cfg.out {
        std::fs::write(out, json::to_string(&report) + "\n")?;
        log_info!("wrote {}", out.display());
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_profile_produces_a_complete_report() {
        // minimal model so the full pipeline (prefill + decode grid +
        // serve + JSON) runs in a couple of seconds of quick-mode timing
        let cfg = Config {
            quick: true,
            n_layers: 1,
            d_model: 16,
            vocab: 16,
            prefill_batch: 2,
            prefill_t: 8,
            decode_batches: vec![1, 2],
            serve_requests: 3,
            serve_tokens: 2,
            max_batch: 2,
            out: None,
            ..Config::quick()
        };
        let report = run(&cfg).unwrap();
        assert_eq!(report.req("schema").unwrap().as_str().unwrap(),
                   "minrnn.native_throughput.v1");
        assert!(report.req("prefill").unwrap().req("tok_s").unwrap()
                .as_f64().unwrap() > 0.0);
        // one entry per (batch, thread-count) measured: threads=1 always,
        // plus the all-threads run when the pool had more than one lane
        let threads_used = report.req("threads").unwrap()
            .as_usize().unwrap();
        assert_eq!(report.req("decode").unwrap().as_arr().unwrap().len(),
                   if threads_used > 1 { 4 } else { 2 });
        assert!(report.req("serve").unwrap().req("tok_s").unwrap()
                .as_f64().unwrap() > 0.0);
        // the open-loop async section reports the queue-wait/decode split
        let sa = report.req("serve_async").unwrap();
        assert!(sa.req("tok_s").unwrap().as_f64().unwrap() > 0.0);
        assert!(sa.req("queue_wait_p95_ms").unwrap().as_f64().unwrap()
                >= 0.0);
        assert!(sa.req("decode_p95_ms").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(sa.req("admitted").unwrap().as_usize().unwrap(), 3);
        assert_eq!(sa.req("rejected").unwrap().as_f64().unwrap(), 0.0);
        // warm-session follow-up turns must hit the cache every time:
        // each second-turn prompt extends the completion state its cold
        // first turn exported
        let sc = report.req("session_cache").unwrap();
        assert_eq!(sc.req("hit_rate").unwrap().as_f64().unwrap(), 1.0);
        assert!(sc.req("prefill_tokens_saved").unwrap()
                .as_f64().unwrap() > 0.0);
        assert!(sc.req("warm_tok_s").unwrap().as_f64().unwrap() > 0.0);
        // the recovery section reports the durable-commit and
        // crash-restart floors
        let rec = report.req("recovery").unwrap();
        assert!(rec.req("ckpt_bytes").unwrap().as_f64().unwrap() > 0.0);
        assert!(rec.req("ckpt_commit_ms").unwrap().as_f64().unwrap() > 0.0);
        assert!(rec.req("recover_load_ms").unwrap().as_f64().unwrap()
                > 0.0);
        // simd section: a recognized dispatch level and positive decode
        // throughput under both forced-scalar and dispatched kernels
        let sd = report.req("simd").unwrap();
        let level = sd.req("level").unwrap().as_str().unwrap().to_string();
        assert!(level == "scalar" || level == "avx2", "{level}");
        assert!(sd.req("decode_b1_scalar_tok_s").unwrap()
                .as_f64().unwrap() > 0.0);
        assert!(sd.req("decode_b1_tok_s").unwrap().as_f64().unwrap() > 0.0);
        // quant section: the golden error sits inside the CLI/CI budget
        // and int8 shrinks the dense weight bytes
        let q = report.req("quant").unwrap();
        let rel = q.req("logit_rel_err").unwrap().as_f64().unwrap();
        let budget = q.req("logit_rel_err_budget").unwrap()
            .as_f64().unwrap();
        assert!(rel >= 0.0 && rel < budget,
                "quant rel err {rel} outside [0, {budget})");
        assert!(q.req("dense_bytes_int8").unwrap().as_f64().unwrap()
                < q.req("dense_bytes_f32").unwrap().as_f64().unwrap());
        assert!(q.req("decode_b1_int8_tok_s").unwrap()
                .as_f64().unwrap() > 0.0);
        assert!(report.req("speedup_batched_threaded").unwrap()
                .as_f64().unwrap() > 0.0);
    }
}
