//! Table 3: offline RL — Decision-minRNN on the simulated D4RL-style
//! datasets (3 envs × {Medium, Medium-Replay, Medium-Expert}), scored by
//! expert-normalized return.

use anyhow::Result;

use crate::config::{Schedule, TrainConfig};
use crate::coordinator::infer::rollout_decision;
use crate::coordinator::trainer::{DataSource, Trainer};
use crate::data::rl::{normalized_score, OfflineDataset, Regime};
use crate::runtime::{Model, PjrtBackend};
use crate::tensor::Batch;
use crate::util::rng::Rng;
use crate::util::table::Table;

use super::{pm, Ctx};

struct RlSource<'a> {
    ds: &'a OfflineDataset,
    batch: usize,
    ctx_len: usize,
}

impl<'a> DataSource for RlSource<'a> {
    fn train_batch(&mut self, rng: &mut Rng) -> Batch {
        self.ds.batch(rng, self.batch, self.ctx_len)
    }
}

/// Train on one (env, regime) dataset; return the normalized score.
pub fn run_cell(ctx: &Ctx, env: &str, kind: &str, regime: Regime,
                steps: usize, n_rollouts: usize) -> Result<f32> {
    let name = format!("rl_{env}_{kind}");
    let model = Model::open(&ctx.rt, ctx.manifest.clone(), &name)?;
    let n_episodes = if ctx.quick { 60 } else { 300 };
    let ds = OfflineDataset::build(env, regime, n_episodes, ctx.seed);
    let mut src = RlSource {
        ds: &ds,
        batch: model.variant.batch,
        ctx_len: model.variant.seq_len,
    };
    let cfg = TrainConfig {
        variant: name,
        steps,
        lr: 1e-3,
        schedule: Schedule::WarmupCosine { warmup: steps / 10 },
        seed: ctx.seed,
        eval_every: 0,
        log_every: (steps / 4).max(1),
        ..Default::default()
    };
    let trainer = Trainer::new(&model, cfg);
    let mut state = model.init(ctx.seed as i32, 0.0)?;
    trainer.run(&mut state, &mut src)?;

    let target = ds.target_return();
    let backend = PjrtBackend::new(&model, &state.params);
    let mut total = 0f32;
    for k in 0..n_rollouts {
        total += rollout_decision(&backend, &ds, target,
                                  ctx.seed ^ (1000 + k as u64))?;
    }
    Ok(normalized_score(env, total / n_rollouts as f32, ctx.seed))
}

pub fn run(ctx: &Ctx) -> Result<()> {
    let steps = ctx.steps(100, 1500);
    let n_rollouts = if ctx.quick { 3 } else { 10 };
    let mut table = Table::new(
        "Table 3: offline RL, expert-normalized scores \
         (simulated envs per DESIGN.md §3; paper: D4RL MuJoCo). \
         Paper averages: DT 76.4, DS4 68.6, DMamba 78.8, \
         minLSTM 78.1, minGRU 78.2.",
        &["dataset", "minLSTM", "minGRU"]);
    let mut sums = [0f32; 2];
    let mut count = 0;
    for env in ["pointmass", "pendulum", "walker1d"] {
        for regime in Regime::all() {
            let mut row = vec![format!("{env}-{}", regime.tag())];
            for (i, kind) in ["minlstm", "mingru"].iter().enumerate() {
                let score = run_cell(ctx, env, kind, regime, steps,
                                     n_rollouts)?;
                sums[i] += score;
                row.push(pm(&[score]));
            }
            count += 1;
            table.row(row);
        }
    }
    table.row(vec!["Average".into(),
                   format!("{:.1}", sums[0] / count as f32),
                   format!("{:.1}", sums[1] / count as f32)]);
    ctx.emit("tab3_rl", &[&table])?;
    Ok(())
}
