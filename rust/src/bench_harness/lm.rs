//! Figure 2: character-level language modelling learning curves
//! (minGRU / minLSTM / S6-lite / Transformer on the synthetic corpus), and
//! Figure 5: minLSTM forget-gate bias initialization sweep.

use anyhow::Result;

use crate::config::{Schedule, TrainConfig};
use crate::coordinator::trainer::{DataSource, Trainer};
use crate::data::corpus::LmDataset;
use crate::runtime::Model;
use crate::tensor::Batch;
use crate::util::rng::Rng;
use crate::util::table::{fnum, Table};

use super::Ctx;

pub struct LmSource {
    pub train: LmDataset,
    pub test: LmDataset,
    pub b: usize,
    pub t: usize,
}

impl LmSource {
    pub fn new(b: usize, t: usize) -> Self {
        LmSource {
            train: LmDataset::synthetic(400_000, 0),
            test: LmDataset::synthetic(60_000, 1),
            b,
            t,
        }
    }
}

impl DataSource for LmSource {
    fn train_batch(&mut self, rng: &mut Rng) -> Batch {
        self.train.batch(rng, self.b, self.t)
    }

    fn eval_batch(&mut self, rng: &mut Rng) -> Batch {
        self.test.batch(rng, self.b, self.t)
    }
}

pub struct LmRun {
    pub kind: String,
    pub curve: Vec<(usize, f32)>,       // (step, test loss)
    pub best_loss: f32,
    pub best_step: usize,
    pub steps_per_sec: f64,
}

pub fn train_lm(ctx: &Ctx, variant: &str, steps: usize, forget_bias: f32,
                seed: u64) -> Result<LmRun> {
    let model = Model::open(&ctx.rt, ctx.manifest.clone(), variant)?;
    let mut src = LmSource::new(model.variant.batch, model.variant.seq_len);
    let cfg = TrainConfig {
        variant: variant.to_string(),
        steps,
        lr: 1e-3,
        schedule: Schedule::WarmupCosine { warmup: steps / 10 },
        seed,
        forget_bias,
        eval_every: (steps / 10).max(1),
        eval_batches: 2,
        log_every: (steps / 10).max(1),
        ..Default::default()
    };
    let trainer = Trainer::new(&model, cfg);
    let mut state = model.init(seed as i32, forget_bias)?;
    let report = trainer.run(&mut state, &mut src)?;
    Ok(LmRun {
        kind: variant.to_string(),
        curve: report.eval_curve.iter()
            .map(|(s, e)| (*s, e.loss)).collect(),
        best_loss: report.best_eval_loss,
        best_step: report.best_eval_step,
        steps_per_sec: report.steps_per_sec,
    })
}

pub fn run_fig2(ctx: &Ctx) -> Result<()> {
    let steps = ctx.steps(100, 1200);
    let mut summary = Table::new(
        "Figure 2: char-LM on synthetic corpus (paper: Shakespeare). \
         Test cross-entropy; lower is better.",
        &["model", "best test loss", "best @ step", "steps/s"]);
    let mut curves = Table::new(
        "Figure 2 learning curves: test loss by step",
        &["model", "step", "test loss"]);
    for kind in ["mingru", "minlstm", "s6", "transformer"] {
        let run = train_lm(ctx, &format!("fig2_{kind}"), steps, 0.0,
                           ctx.seed)?;
        summary.row(vec![kind.into(), fnum(run.best_loss as f64),
                         run.best_step.to_string(),
                         fnum(run.steps_per_sec)]);
        for (s, l) in &run.curve {
            curves.row(vec![kind.into(), s.to_string(), fnum(*l as f64)]);
        }
    }
    ctx.emit("fig2_language_model", &[&summary, &curves])?;
    Ok(())
}

pub fn run_fig5(ctx: &Ctx) -> Result<()> {
    let steps = ctx.steps(60, 800);
    let mut table = Table::new(
        "Figure 5: minLSTM forget-gate bias init vs training efficiency",
        &["forget_bias", "best test loss", "loss @ 25% steps",
          "loss @ 100% steps"]);
    for bias in [0.0f32, 1.0, 2.0, 4.0] {
        let run = train_lm(ctx, "fig2_minlstm", steps, bias, ctx.seed)?;
        let early = run.curve.iter()
            .find(|(s, _)| *s >= steps / 4)
            .map(|(_, l)| *l).unwrap_or(f32::NAN);
        let last = run.curve.last().map(|(_, l)| *l).unwrap_or(f32::NAN);
        table.row(vec![format!("{bias}"), fnum(run.best_loss as f64),
                       fnum(early as f64), fnum(last as f64)]);
    }
    ctx.emit("fig5_bias_init", &[&table])?;
    Ok(())
}
