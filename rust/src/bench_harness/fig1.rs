//! Figure 1: training runtime (left), speedup over traditional RNNs
//! (middle), and memory footprint (right) vs sequence length.
//!
//! Hardware adaptation (DESIGN.md §2): the paper's T4 numbers show parallel
//! scan ≈ flat runtime vs BPTT linear-in-T.  On one CPU core wall-clock
//! follows *work*, so alongside measured step time we report the
//! hardware-independent signals: HLO critical-path depth (O(T/tc + log tc)
//! for the scan vs O(T) for BPTT) and the XLA-reported training memory.

use anyhow::Result;

use crate::data::random_tokens;
use crate::util::bench::{bench, BenchConfig};
use crate::util::rng::Rng;
use crate::util::table::{fnum, Table};
use crate::runtime::Model;

use super::Ctx;

pub const KINDS: [&str; 5] = ["mingru", "minlstm", "gru", "lstm", "s6"];
pub const LENGTHS: [usize; 5] = [64, 128, 256, 512, 1024];

pub fn run(ctx: &Ctx) -> Result<()> {
    let lengths: Vec<usize> = if ctx.quick {
        vec![64, 256, 1024]
    } else {
        LENGTHS.to_vec()
    };
    let bcfg = if ctx.quick { BenchConfig::quick() }
               else { BenchConfig::default() };

    let mut runtime_t = Table::new(
        "Figure 1 (left): train-step runtime [ms] vs sequence length \
         (B=8, d=64, 1 layer, CPU PJRT)",
        &{
            let mut h = vec!["model"];
            h.extend(lengths.iter().map(|t| {
                Box::leak(format!("T={t}").into_boxed_str()) as &str
            }));
            h
        });
    let mut speed_t = Table::new(
        "Figure 1 (middle): speedup of minimal RNNs over traditional \
         counterparts (same T)",
        &{
            let mut h = vec!["pair"];
            h.extend(lengths.iter().map(|t| {
                Box::leak(format!("T={t}").into_boxed_str()) as &str
            }));
            h
        });
    let mut mem_t = Table::new(
        "Figure 1 (right): XLA train memory (temp bytes) and graph depth",
        &["model", "T", "temp_bytes", "depth(parallel)", "depth(BPTT)"]);

    let mut rng = Rng::new(ctx.seed);
    let mut ms: std::collections::BTreeMap<(String, usize), f64> =
        Default::default();

    for kind in KINDS {
        let mut row = vec![kind.to_string()];
        for &t in &lengths {
            let name = format!("fig1_{kind}_t{t}");
            let model = Model::open(&ctx.rt, ctx.manifest.clone(), &name)?;
            let mut state = model.init(0, 0.0)?;
            let batch = random_tokens::batch(&mut rng, model.variant.batch,
                                             t, 16);
            // one warm call compiles + caches
            model.train_step(&mut state, &batch, 1e-3, 0)?;
            let r = bench(&name, &bcfg, || {
                model.train_step(&mut state, &batch, 1e-3, 0).unwrap();
            });
            ms.insert((kind.to_string(), t), r.mean_ms());
            row.push(fnum(r.mean_ms()));

            // sequential models (BPTT) have no parallel-scan depth
            let par_depth = if matches!(kind, "gru" | "lstm") {
                "n/a (BPTT)".to_string()
            } else {
                model.variant.depth_parallel.to_string()
            };
            let temp = model.variant.memory.as_ref()
                .and_then(|m| m.get("temp_bytes").copied())
                .map(|b| b.to_string())
                .unwrap_or_else(|| "n/a".into());
            mem_t.row(vec![kind.to_string(), t.to_string(), temp,
                           par_depth,
                           model.variant.depth_sequential.to_string()]);
        }
        runtime_t.row(row);
    }

    for (minimal, trad) in [("mingru", "gru"), ("minlstm", "lstm")] {
        let mut row = vec![format!("{trad}/{minimal}")];
        for &t in &lengths {
            let a = ms[&(trad.to_string(), t)];
            let b = ms[&(minimal.to_string(), t)];
            row.push(format!("{:.2}x", a / b));
        }
        speed_t.row(row);
    }

    ctx.emit("fig1_training_cost", &[&runtime_t, &speed_t, &mem_t])?;
    Ok(())
}
