//! Tables 4/5 (Chomsky Hierarchy + LRA) and Table 6 (architecture
//! ablation on ListOps).

use anyhow::Result;

use crate::config::{Schedule, TrainConfig};
use crate::coordinator::trainer::{DataSource, Trainer};
use crate::data::chomsky::{self, ChomskyTask};
use crate::data::lra::{collate_classification, gimage, listops, retrieval};
use crate::runtime::Model;
use crate::tensor::Batch;
use crate::util::rng::Rng;
use crate::util::table::Table;

use super::Ctx;

// ---------------------------------------------------------------------------
// Chomsky
// ---------------------------------------------------------------------------

struct ChomskySource {
    task: Box<dyn ChomskyTask>,
    batch: usize,
    train_t: usize,
    eval_t: usize,
}

impl DataSource for ChomskySource {
    fn train_batch(&mut self, rng: &mut Rng) -> Batch {
        let max_c = self.task.max_content_for(self.train_t);
        chomsky::batch(self.task.as_ref(), rng, self.batch, self.train_t,
                       1, max_c)
    }

    fn eval_batch(&mut self, rng: &mut Rng) -> Batch {
        // length generalization: contents beyond the training range
        let train_max = self.task.max_content_for(self.train_t);
        let eval_max = self.task.max_content_for(self.eval_t);
        let lo = (train_max + 1).min(eval_max);
        chomsky::batch(self.task.as_ref(), rng, self.batch, self.eval_t,
                       lo, eval_max)
    }
}

/// Train one chm variant; returns (in-dist acc, gen acc per eval length).
fn train_chomsky(ctx: &Ctx, task_name: &str, kind: &str, steps: usize)
                 -> Result<(f32, Vec<(usize, f32)>)> {
    let name = format!("chm_{task_name}_{kind}");
    let model = Model::open(&ctx.rt, ctx.manifest.clone(), &name)?;
    let train_t = model.variant.seq_len;
    let task = chomsky::by_name(task_name)
        .ok_or_else(|| anyhow::anyhow!("unknown task {task_name}"))?;
    let mut src = ChomskySource {
        task,
        batch: model.variant.batch,
        train_t,
        eval_t: train_t,
    };
    let cfg = TrainConfig {
        variant: name.clone(),
        steps,
        lr: 1e-3,
        schedule: Schedule::WarmupCosine { warmup: steps / 10 },
        seed: ctx.seed,
        eval_every: 0,
        log_every: (steps / 5).max(1),
        ..Default::default()
    };
    let trainer = Trainer::new(&model, cfg);
    let mut state = model.init(ctx.seed as i32, 1.0)?;
    trainer.run(&mut state, &mut src)?;

    // in-distribution accuracy at the training length
    let mut rng = Rng::new(ctx.seed ^ 0xE7A1);
    let max_c = src.task.max_content_for(train_t);
    let mut in_acc = 0f32;
    let n_eval = 4;
    for _ in 0..n_eval {
        let b = chomsky::batch(src.task.as_ref(), &mut rng,
                               model.variant.batch, train_t, 1, max_c);
        in_acc += model.eval(&state, &b)?.seq_acc / n_eval as f32;
    }

    // generalization at the longer exported eval lengths
    let mut gen = Vec::new();
    for ef in &model.variant.eval_files {
        if ef.seq_len <= train_t {
            continue;
        }
        let eval_max = src.task.max_content_for(ef.seq_len);
        let lo = (src.task.max_content_for(train_t) + 1).min(eval_max);
        let mut acc = 0f32;
        for _ in 0..n_eval {
            let b = chomsky::batch(src.task.as_ref(), &mut rng, ef.batch,
                                   ef.seq_len, lo, eval_max);
            acc += model.eval(&state, &b)?.seq_acc / n_eval as f32;
        }
        gen.push((ef.seq_len, acc));
    }
    Ok((in_acc, gen))
}

pub fn run_tab45_chomsky(ctx: &Ctx) -> Result<Table> {
    let steps = ctx.steps(80, 2000);
    let mut table = Table::new(
        "Table 4/5 (Chomsky Hierarchy): accuracy; trained content ≤ 30, \
         evaluated beyond training lengths (paper: ≤40 → 40–256)",
        &["task", "model", "in-dist acc", "gen acc (T=128)",
          "gen acc (T=288)"]);
    for task in ["bucket_sort", "missing_duplicate", "cycle_nav",
                 "even_pairs", "majority", "majority_count"] {
        for kind in ["minlstm", "mingru"] {
            let (in_acc, gen) = train_chomsky(ctx, task, kind, steps)?;
            let find = |t: usize| gen.iter().find(|(l, _)| *l == t)
                .map(|(_, a)| format!("{:.2}", a))
                .unwrap_or_else(|| "-".into());
            table.row(vec![task.into(), kind.into(),
                           format!("{in_acc:.2}"),
                           find(128), find(288)]);
        }
    }
    Ok(table)
}

// ---------------------------------------------------------------------------
// LRA
// ---------------------------------------------------------------------------

pub struct LraSource {
    pub kind: String,
    pub batch: usize,
    pub t: usize,
}

impl LraSource {
    /// Smallest sequence length the task's generator can fill — the
    /// single owner of the size formulas in [`DataSource::train_batch`]
    /// below (listops: `t - 10`, retrieval: `(t - 3) / 2` per side,
    /// gimage: the fixed 16×16 pixel grid + CLS).  Callers must check
    /// this up front; below it the generators underflow.
    pub fn min_seq_len(kind: &str) -> usize {
        match kind {
            "listops" => 16,
            "retrieval" => 8,
            _ => gimage::SIDE * gimage::SIDE + 1,
        }
    }
}

impl DataSource for LraSource {
    fn train_batch(&mut self, rng: &mut Rng) -> Batch {
        let b = self.batch;
        let t = self.t;
        let examples: Vec<(Vec<i32>, i32)> = (0..b).map(|_| {
            match self.kind.as_str() {
                "listops" => listops::sample(rng, t - 10),
                "retrieval" => retrieval::sample(rng, (t - 3) / 2),
                _ => gimage::sample(rng),
            }
        }).collect();
        collate_classification(&examples, t)
    }
}

fn train_lra(ctx: &Ctx, variant: &str, task: &str, steps: usize)
             -> Result<f32> {
    let model = Model::open(&ctx.rt, ctx.manifest.clone(), variant)?;
    let mut src = LraSource {
        kind: task.to_string(),
        batch: model.variant.batch,
        t: model.variant.seq_len,
    };
    let cfg = TrainConfig {
        variant: variant.to_string(),
        steps,
        lr: 1e-3,
        schedule: Schedule::WarmupCosine { warmup: steps / 10 },
        seed: ctx.seed,
        eval_every: (steps / 2).max(1),
        eval_batches: 6,
        log_every: (steps / 5).max(1),
        ..Default::default()
    };
    let trainer = Trainer::new(&model, cfg);
    let mut state = model.init(ctx.seed as i32, 1.0)?;
    let report = trainer.run(&mut state, &mut src)?;
    Ok(report.final_eval.map(|e| e.seq_acc).unwrap_or(0.0))
}

pub fn run_tab45_lra(ctx: &Ctx) -> Result<Table> {
    let steps = ctx.steps(80, 2000);
    let mut table = Table::new(
        "Table 4 (LRA, scaled): classification accuracy \
         (paper baselines quoted from the xLSTM paper)",
        &["task", "model", "accuracy", "source"]);
    for (task, paper_rows) in [
        ("retrieval", vec![("Mamba", 0.90), ("xLSTM", 0.91),
                           ("minLSTM (paper)", 0.89)]),
        ("listops", vec![("Mamba", 0.33), ("xLSTM", 0.41),
                         ("minLSTM (paper)", 0.59)]),
        ("gimage", vec![("Mamba", 0.69), ("xLSTM", 0.70),
                        ("minLSTM (paper)", 0.67)]),
    ] {
        for (m, a) in paper_rows {
            table.row(vec![task.into(), m.into(), format!("{a}"),
                           "paper (quoted)".into()]);
        }
        let acc = train_lra(ctx, &format!("lra_{task}_minlstm"), task,
                            steps)?;
        table.row(vec![task.into(), "minLSTM".into(),
                       format!("{acc:.2}"), "measured (scaled)".into()]);
    }
    Ok(table)
}

pub fn run_tab45(ctx: &Ctx) -> Result<()> {
    let ch = run_tab45_chomsky(ctx)?;
    let lra = run_tab45_lra(ctx)?;
    ctx.emit("tab45_chomsky_lra", &[&ch, &lra])?;
    Ok(())
}

pub fn run_tab6(ctx: &Ctx) -> Result<()> {
    let steps = ctx.steps(80, 2000);
    let mut table = Table::new(
        "Table 6: architecture ablation, minLSTM on ListOps \
         (paper: 0.46 / 0.45 / 0.52 / 0.59)",
        &["model", "accuracy"]);
    for (label, variant) in [
        ("minLSTM", "tab6_listops_plain"),
        ("minLSTM (+ Conv)", "tab6_listops_conv"),
        ("minLSTM (+ MLP)", "tab6_listops_mlp"),
        ("minLSTM (+ Conv + MLP)", "lra_listops_minlstm"),
    ] {
        let acc = train_lra(ctx, variant, "listops", steps)?;
        table.row(vec![label.into(), format!("{acc:.2}")]);
    }
    ctx.emit("tab6_ablation", &[&table])?;
    Ok(())
}
