//! Figures 3 & 4: inference runtime.
//!
//! Fig 3 — time to ingest N context tokens then decode: parallelizable
//! models (minGRU/minLSTM/S6/Transformer) use the parallel prefill
//! executable; traditional RNNs (GRU/LSTM) must consume the context
//! sequentially (their prefill HLO is the lax.scan rollout — linear time).
//!
//! Fig 4 — per-token decode cost of minimal vs traditional RNNs across
//! batch sizes.

use anyhow::Result;

use crate::runtime::Model;
use crate::tensor::Tensor;
use crate::util::bench::{bench, BenchConfig};
use crate::util::rng::Rng;
use crate::util::table::{fnum, Table};

use super::Ctx;

const CTXS: [usize; 3] = [64, 256, 1024];
const BATCHES: [usize; 3] = [1, 8, 32];

fn variant_for(kind: &str) -> String {
    match kind {
        "gru" | "lstm" => format!("infer_{kind}"),
        _ => format!("fig2_{kind}"),
    }
}

pub fn run_fig3(ctx: &Ctx) -> Result<()> {
    let bcfg = if ctx.quick { BenchConfig::quick() }
               else { BenchConfig::default() };
    let mut table = Table::new(
        "Figure 3: context ingestion time [ms] (batch 8). Parallel models \
         prefill in one pass; GRU/LSTM scan the context sequentially.",
        &["model", "ctx=64", "ctx=256", "ctx=1024", "scaling"]);
    let mut rng = Rng::new(ctx.seed);
    for kind in ["mingru", "minlstm", "s6", "transformer", "gru", "lstm"] {
        let model = Model::open(&ctx.rt, ctx.manifest.clone(),
                                &variant_for(kind))?;
        let state = model.init(0, 0.0)?;
        let mut row = vec![kind.to_string()];
        let mut times = Vec::new();
        for &t in &CTXS {
            let vocab = model.variant.cfg_usize("vocab_in").unwrap_or(64);
            let tokens: Vec<i32> = (0..8 * t)
                .map(|_| rng.below(vocab as u64) as i32).collect();
            let x = Tensor::i32(vec![8, t], tokens);
            model.prefill(&state.params, &x)?; // warm/compile
            let r = bench(&format!("{kind}@{t}"), &bcfg, || {
                model.prefill(&state.params, &x).unwrap();
            });
            times.push(r.mean_ms());
            row.push(fnum(r.mean_ms()));
        }
        // slope of time vs ctx: ~1.0 → linear, ≪1 → sublinear
        let ratio = times.last().unwrap() / times.first().unwrap();
        let len_ratio = *CTXS.last().unwrap() as f64 / CTXS[0] as f64;
        row.push(format!("{:.2}x over {:.0}x tokens", ratio, len_ratio));
        table.row(row);
    }
    ctx.emit("fig3_inference_context", &[&table])?;
    Ok(())
}

pub fn run_fig4(ctx: &Ctx) -> Result<()> {
    let bcfg = if ctx.quick { BenchConfig::quick() }
               else { BenchConfig::default() };
    let mut table = Table::new(
        "Figure 4: per-decode-step time [ms] across batch sizes \
         (minimal vs traditional RNNs)",
        &["model", "B=1", "B=8", "B=32", "tok/s @ B=32"]);
    let mut rng = Rng::new(ctx.seed);
    for kind in ["mingru", "minlstm", "gru", "lstm", "s6", "transformer"] {
        let model = Model::open(&ctx.rt, ctx.manifest.clone(),
                                &variant_for(kind))?;
        let tstate = model.init(0, 0.0)?;
        let vocab = model.variant.cfg_usize("vocab_in").unwrap_or(64);
        let mut row = vec![kind.to_string()];
        let mut last_ms = 0.0;
        for &b in &BATCHES {
            let x = Tensor::i32(
                vec![b],
                (0..b).map(|_| rng.below(vocab as u64) as i32).collect());
            // thread the state through iterations: measures the pure
            // steady-state decode step, not state allocation
            let warm = model.decode_state_zeros(b)?;
            let (_, st0) = model.decode_step(&tstate.params, &x, warm)?;
            let mut st = Some(st0);
            let r = bench(&format!("{kind}@b{b}"), &bcfg, || {
                let (_, s2) = model.decode_step(&tstate.params, &x,
                                                st.take().unwrap()).unwrap();
                st = Some(s2);
            });
            last_ms = r.mean_ms();
            row.push(fnum(r.mean_ms()));
        }
        row.push(fnum(32.0 / (last_ms / 1e3)));
        table.row(row);
    }
    ctx.emit("fig4_inference_minimal", &[&table])?;
    Ok(())
}
