//! Tables 1 & 2: Selective Copying — layer-count sweep and the comparison
//! against modern recurrent baselines (quoted from the Mamba paper, as the
//! paper itself does).

use anyhow::Result;

use crate::config::{Schedule, TrainConfig};
use crate::coordinator::trainer::{DataSource, Trainer};
use crate::data::selective_copy::SelectiveCopy;
use crate::runtime::Model;
use crate::tensor::Batch;
use crate::util::rng::Rng;
use crate::util::table::Table;

use super::{pm, Ctx};

struct ScSource {
    task: SelectiveCopy,
    batch: usize,
}

impl DataSource for ScSource {
    fn train_batch(&mut self, rng: &mut Rng) -> Batch {
        self.task.batch(rng, self.batch)
    }
}

/// Train one (kind, layers, seed) cell; returns final
/// (token accuracy %, sequence accuracy %) — sequence accuracy is the
/// paper's all-answer-positions-correct criterion; token accuracy gives
/// the partial-credit signal that is visible at quick-mode step budgets.
pub fn train_cell(ctx: &Ctx, kind: &str, layers: usize, seed: u64,
                  steps: usize) -> Result<(f32, f32)> {
    let name = format!("tab1_{kind}_l{layers}");
    let model = Model::open(&ctx.rt, ctx.manifest.clone(), &name)?;
    let wl = &model.variant.workload;
    let ctx_len = wl.get("ctx_len").and_then(|v| v.as_usize()).unwrap_or(256);
    let n_data = wl.get("n_data").and_then(|v| v.as_usize()).unwrap_or(16);
    let mut src = ScSource {
        task: SelectiveCopy::new(ctx_len, n_data),
        batch: model.variant.batch,
    };
    let cfg = TrainConfig {
        variant: name,
        steps,
        lr: 3e-4 * 3.0, // scaled up: far fewer steps than the paper's 400k
        schedule: Schedule::WarmupCosine { warmup: steps / 10 },
        seed,
        eval_every: (steps / 4).max(1),
        eval_batches: 4,
        log_every: (steps / 8).max(1),
        ..Default::default()
    };
    let trainer = Trainer::new(&model, cfg);
    let mut state = model.init(seed as i32, 0.0)?;
    let report = trainer.run(&mut state, &mut src)?;
    let ev = report.final_eval.unwrap_or_default();
    Ok((ev.token_acc * 100.0, ev.seq_acc * 100.0))
}

pub fn run_tab1(ctx: &Ctx) -> Result<()> {
    let steps = ctx.steps(100, 1500);
    let mut table = Table::new(
        "Table 1: layers vs accuracy on Selective Copying \
         (scaled: T=272, this testbed; paper: T=4096, 400k steps)",
        &["model", "layers", "token acc %", "seq acc %"]);
    for kind in ["minlstm", "mingru"] {
        for layers in [1usize, 2, 3] {
            let cells: Vec<(f32, f32)> = ctx.seeds().iter()
                .map(|&s| train_cell(ctx, kind, layers, s, steps))
                .collect::<Result<_>>()?;
            let tok: Vec<f32> = cells.iter().map(|c| c.0).collect();
            let seq: Vec<f32> = cells.iter().map(|c| c.1).collect();
            table.row(vec![format!("min{}", kind[3..].to_uppercase()),
                           layers.to_string(), pm(&tok), pm(&seq)]);
        }
    }
    ctx.emit("tab1_layers", &[&table])?;
    Ok(())
}

pub fn run_tab2(ctx: &Ctx) -> Result<()> {
    let steps = ctx.steps(120, 2000);
    let mut table = Table::new(
        "Table 2: Selective Copying vs modern baselines \
         (paper: rows quoted from Gu & Dao 2024; ours measured)",
        &["model", "layer", "token acc %", "seq acc %", "source"]);
    for (m, l, a) in [("H3", "Hyena", 30.1), ("Mamba", "Hyena", 28.4),
                      ("S4", "S4", 18.3), ("H3", "S4", 57.0),
                      ("Mamba", "S4", 56.4), ("S4", "S6", 97.0),
                      ("H3", "S6", 99.7), ("Mamba", "S6", 99.8)] {
        table.row(vec![m.into(), l.into(), "-".into(), format!("{a}"),
                       "paper (quoted)".into()]);
    }
    for kind in ["mingru", "minlstm"] {
        let cells: Vec<(f32, f32)> = ctx.seeds().iter()
            .map(|&s| train_cell(ctx, kind, 3, s, steps))
            .collect::<Result<_>>()?;
        let tok: Vec<f32> = cells.iter().map(|c| c.0).collect();
        let seq: Vec<f32> = cells.iter().map(|c| c.1).collect();
        let label = format!("min{}", kind[3..].to_uppercase());
        table.row(vec![label.clone(), label, pm(&tok), pm(&seq),
                       "measured (scaled)".into()]);
    }
    ctx.emit("tab2_selective_copy", &[&table])?;
    Ok(())
}
