//! Experiment harness: one module per paper table/figure.  Each entry
//! regenerates the paper's rows (measured on this testbed, with the paper's
//! quoted baselines where the paper itself quotes them) and writes both an
//! ASCII table to stdout and a markdown file under `results/`.

pub mod chomsky_lra;
pub mod fig1;
pub mod inference;
pub mod lm;
pub mod native_throughput;
pub mod rl;
pub mod selective;

use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::Result;

use crate::runtime::{Manifest, Runtime};
use crate::util::table::Table;
use crate::log_info;

pub struct Ctx {
    pub rt: Runtime,
    pub manifest: Rc<Manifest>,
    /// Quick mode: fewer steps/seeds — used by `cargo bench` so the suite
    /// finishes on a single CPU core.  Full mode via MINRNN_FULL=1.
    pub quick: bool,
    pub results_dir: PathBuf,
    pub seed: u64,
}

impl Ctx {
    pub fn new(artifacts: &Path) -> Result<Ctx> {
        crate::util::logging::init();
        let quick = std::env::var("MINRNN_FULL").map(|v| v != "1")
            .unwrap_or(true);
        let rt = Runtime::cpu()?;
        let manifest = Rc::new(Manifest::load(artifacts)?);
        let results_dir = PathBuf::from("results");
        std::fs::create_dir_all(&results_dir)?;
        Ok(Ctx { rt, manifest, quick, results_dir, seed: 0 })
    }

    /// Steps scaled by mode.
    pub fn steps(&self, quick: usize, full: usize) -> usize {
        if self.quick { quick } else { full }
    }

    pub fn seeds(&self) -> Vec<u64> {
        if self.quick { vec![0] } else { vec![0, 1, 2] }
    }

    pub fn emit(&self, id: &str, tables: &[&Table]) -> Result<()> {
        let mut md = String::new();
        for t in tables {
            println!("{}", t.render());
            md.push_str(&t.render_markdown());
            md.push('\n');
        }
        let path = self.results_dir.join(format!("{id}.md"));
        std::fs::write(&path, md)?;
        log_info!("wrote {}", path.display());
        Ok(())
    }
}

/// Format "mean ± std" over per-seed values.
pub fn pm(values: &[f32]) -> String {
    let v64: Vec<f64> = values.iter().map(|&x| x as f64).collect();
    if values.len() <= 1 {
        format!("{:.1}", v64.first().copied().unwrap_or(0.0))
    } else {
        format!("{:.1} ± {:.1}", crate::util::stats::mean(&v64),
                crate::util::stats::std(&v64))
    }
}
