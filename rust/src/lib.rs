//! minrnn — "Were RNNs All We Needed?" (Feng et al., 2024) reproduction.
//!
//! Three-layer architecture:
//! * L1/L2 (build time): Pallas parallel-scan kernels + JAX models, AOT
//!   lowered to `artifacts/*.hlo.txt` by `python/compile/aot.py`.
//! * L3 (this crate): coordinator — data generation, training loops,
//!   evaluation, inference serving, and the bench harness that regenerates
//!   every table and figure of the paper.
//!
//! Inference dispatches through the [`runtime::Backend`] trait with two
//! implementations:
//! * **pjrt** ([`runtime::PjrtBackend`]) — loads AOT artifacts via PJRT
//!   (`xla` crate); Python is never on the request path.  Needs `make
//!   artifacts` output and a real PJRT-capable `xla` dependency (the
//!   default build vendors a host-only stub).
//! * **native** ([`backend::NativeBackend`]) — a pure-Rust CPU
//!   implementation of the minGRU/minLSTM backbone (log-space scan,
//!   sequential decode, prefill) that loads the same MRNN checkpoints and
//!   needs no artifacts at all.  `cargo test` exercises it against golden
//!   vectors exported from the JAX reference (`rust/tests/golden/`).
//!
//! See `rust/README.md` for backend selection and test-gating details.

// Tensor kernels index by (batch, time, channel) on flat buffers; explicit
// index loops are the clearest way to write them.
#![allow(clippy::needless_range_loop)]

pub mod backend;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod runtime;
pub mod tensor;
pub mod util;
pub mod bench_harness;
