//! minrnn — "Were RNNs All We Needed?" (Feng et al., 2024) reproduction.
//!
//! Three-layer architecture:
//! * L1/L2 (build time): Pallas parallel-scan kernels + JAX models, AOT
//!   lowered to `artifacts/*.hlo.txt` by `python/compile/aot.py`.
//! * L3 (this crate): coordinator — data generation, training loops,
//!   evaluation, inference serving, and the bench harness that regenerates
//!   every table and figure of the paper.
//!
//! Inference dispatches through the [`runtime::Backend`] trait with two
//! implementations:
//! * **pjrt** ([`runtime::PjrtBackend`]) — loads AOT artifacts via PJRT
//!   (`xla` crate); Python is never on the request path.  Needs `make
//!   artifacts` output and a real PJRT-capable `xla` dependency (the
//!   default build vendors a host-only stub).
//! * **native** ([`backend::NativeBackend`]) — a pure-Rust CPU
//!   implementation of the minGRU/minLSTM backbone (log-space scan,
//!   sequential decode, prefill) that loads the same MRNN checkpoints and
//!   needs no artifacts at all.  `cargo test` exercises it against golden
//!   vectors exported from the JAX reference (`rust/tests/golden/`).
//!
//! Training mirrors the split behind [`runtime::TrainBackend`]
//! (`backend::NativeTrainer` runs the log-space scan VJP + AdamW fully in
//! Rust), and serving runs through
//! [`coordinator::server::ServeConfig`] — the one builder every serve
//! entrypoint parses into — on top of [`coordinator::scheduler`] (async
//! admission-controlled decode that accepts new requests mid-batch),
//! with a network tier ([`coordinator::http`] over
//! [`coordinator::shard`]) sharding requests across scheduler replicas
//! by consistent hashing on the session key.
//!
//! The shortest useful path through the crate — build a model, decode:
//!
//! ```
//! use minrnn::backend::{NativeBackend, NativeInit, NativeModel};
//! use minrnn::coordinator::infer;
//! use minrnn::util::rng::Rng;
//!
//! // artifact-free: a seeded random init of the paper's backbone
//! let model = NativeModel::init_random(&NativeInit {
//!     kind: "minlstm".to_string(),
//!     vocab_in: Some(16),
//!     vocab_out: 16,
//!     d_model: 8,
//!     n_layers: 2,
//!     ..Default::default()
//! }, 0).unwrap();
//! let backend = NativeBackend::new(model);
//! let mut rng = Rng::new(0);
//! let tokens = infer::generate(&backend, &[1, 2, 3], 8, 0.7, &mut rng)
//!     .unwrap();
//! assert_eq!(tokens.len(), 8);
//! assert!(tokens.iter().all(|&t| (0..16).contains(&t)));
//! ```
//!
//! A module map with the train/serve data flows and the numerical
//! invariants the tests pin lives in `rust/ARCHITECTURE.md`; backend
//! selection and test-gating details in `rust/README.md`.

// Tensor kernels index by (batch, time, channel) on flat buffers; explicit
// index loops are the clearest way to write them.
#![allow(clippy::needless_range_loop)]

pub mod backend;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod runtime;
pub mod tensor;
pub mod util;
pub mod bench_harness;
