//! minrnn — "Were RNNs All We Needed?" (Feng et al., 2024) reproduction.
//!
//! Three-layer architecture:
//! * L1/L2 (build time): Pallas parallel-scan kernels + JAX models, AOT
//!   lowered to `artifacts/*.hlo.txt` by `python/compile/aot.py`.
//! * L3 (this crate): coordinator — data generation, training loops,
//!   evaluation, inference serving, and the bench harness that regenerates
//!   every table and figure of the paper. Loads artifacts via PJRT
//!   (`xla` crate); Python is never on the request path.

pub mod config;
pub mod coordinator;
pub mod data;
pub mod runtime;
pub mod tensor;
pub mod util;
pub mod bench_harness;
