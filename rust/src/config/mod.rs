//! Run configuration: typed training/eval settings assembled from defaults
//! → optional JSON config file → CLI overrides (highest precedence).

use std::path::PathBuf;

use anyhow::Result;

use crate::util::cli::Parsed;
use crate::util::json::{self, Json};

#[derive(Clone, Debug, PartialEq)]
pub enum Schedule {
    Constant,
    /// Linear warmup then cosine decay to 10% of peak.
    WarmupCosine { warmup: usize },
}

#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub artifacts: PathBuf,
    /// Inference backend for decode-path commands: "pjrt" | "native".
    pub backend: String,
    pub variant: String,
    pub steps: usize,
    pub lr: f32,
    pub schedule: Schedule,
    pub seed: u64,
    pub forget_bias: f32,
    /// Residual-branch dropout rate; honored by the native trainer (PJRT
    /// bakes its rate into the exported train-step artifact).
    pub dropout: f32,
    pub eval_every: usize,
    pub eval_batches: usize,
    pub log_every: usize,
    pub checkpoint: Option<PathBuf>,
    /// Commit a crash-recovery checkpoint to the retained ring every N
    /// steps (0 = only best/final checkpoints, no ring).
    pub checkpoint_every: usize,
    /// How many periodic ring checkpoints to retain (best/final are kept
    /// separately).
    pub keep_checkpoints: usize,
    pub resume: Option<PathBuf>,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            artifacts: PathBuf::from("artifacts"),
            backend: "pjrt".to_string(),
            variant: String::new(),
            steps: 200,
            lr: 1e-3,
            schedule: Schedule::WarmupCosine { warmup: 20 },
            seed: 0,
            forget_bias: 0.0,
            dropout: 0.0,
            eval_every: 50,
            eval_batches: 4,
            log_every: 10,
            checkpoint: None,
            checkpoint_every: 0,
            keep_checkpoints: 3,
            resume: None,
        }
    }
}

impl TrainConfig {
    /// Learning rate at a step under the configured schedule.
    pub fn lr_at(&self, step: usize) -> f32 {
        match self.schedule {
            Schedule::Constant => self.lr,
            Schedule::WarmupCosine { warmup } => {
                if step < warmup {
                    self.lr * (step + 1) as f32 / warmup as f32
                } else if self.steps <= warmup {
                    self.lr
                } else {
                    let p = (step - warmup) as f32
                        / (self.steps - warmup).max(1) as f32;
                    let cos = 0.5 * (1.0 + (std::f32::consts::PI
                                            * p.min(1.0)).cos());
                    self.lr * (0.1 + 0.9 * cos)
                }
            }
        }
    }

    /// Apply a parsed JSON config object (keys mirror field names).
    pub fn apply_json(&mut self, j: &Json) -> Result<()> {
        if let Some(v) = j.get("steps").and_then(|v| v.as_usize()) {
            self.steps = v;
        }
        if let Some(v) = j.get("lr").and_then(|v| v.as_f64()) {
            self.lr = v as f32;
        }
        if let Some(v) = j.get("seed").and_then(|v| v.as_i64()) {
            self.seed = v as u64;
        }
        if let Some(v) = j.get("forget_bias").and_then(|v| v.as_f64()) {
            self.forget_bias = v as f32;
        }
        if let Some(v) = j.get("dropout").and_then(|v| v.as_f64()) {
            if !(0.0..1.0).contains(&v) {
                anyhow::bail!("config dropout must be in [0, 1), got {v}");
            }
            self.dropout = v as f32;
        }
        if let Some(v) = j.get("eval_every").and_then(|v| v.as_usize()) {
            self.eval_every = v;
        }
        if let Some(v) = j.get("eval_batches").and_then(|v| v.as_usize()) {
            self.eval_batches = v;
        }
        if let Some(v) = j.get("log_every").and_then(|v| v.as_usize()) {
            self.log_every = v;
        }
        if let Some(v) = j.get("checkpoint_every").and_then(|v| v.as_usize())
        {
            self.checkpoint_every = v;
        }
        if let Some(v) = j.get("keep_checkpoints")
            .and_then(|v| v.as_usize())
        {
            if v == 0 {
                anyhow::bail!("config keep_checkpoints must be >= 1");
            }
            self.keep_checkpoints = v;
        }
        if let Some(v) = j.get("variant").and_then(|v| v.as_str()) {
            self.variant = v.to_string();
        }
        if let Some(v) = j.get("artifacts").and_then(|v| v.as_str()) {
            self.artifacts = PathBuf::from(v);
        }
        if let Some(v) = j.get("backend").and_then(|v| v.as_str()) {
            self.backend = v.to_string();
        }
        if let Some(v) = j.get("schedule").and_then(|v| v.as_str()) {
            self.schedule = match v {
                "constant" => Schedule::Constant,
                _ => Schedule::WarmupCosine {
                    warmup: j.get("warmup").and_then(|w| w.as_usize())
                        .unwrap_or(20),
                },
            };
        }
        Ok(())
    }

    /// Apply CLI options produced by the standard train option set.
    pub fn apply_cli(&mut self, p: &Parsed) -> Result<()> {
        if let Some(path) = p.get("config") {
            let text = std::fs::read_to_string(path)?;
            let j = json::parse(&text)
                .map_err(|e| anyhow::anyhow!("config {path}: {e}"))?;
            self.apply_json(&j)?;
        }
        if let Some(v) = p.get("artifacts") {
            self.artifacts = PathBuf::from(v);
        }
        if let Some(v) = p.get("backend") {
            self.backend = v.to_string();
        }
        if let Some(v) = p.get("steps") {
            self.steps = v.parse()?;
        }
        if let Some(v) = p.get("lr") {
            self.lr = v.parse()?;
        }
        if let Some(v) = p.get("seed") {
            self.seed = v.parse()?;
        }
        if let Some(v) = p.get("forget-bias") {
            self.forget_bias = v.parse()?;
        }
        if let Some(v) = p.get("dropout") {
            self.dropout = v.parse()?;
            if !(0.0..1.0).contains(&self.dropout) {
                anyhow::bail!("--dropout must be in [0, 1), got {v}");
            }
        }
        if let Some(v) = p.get("eval-every") {
            self.eval_every = v.parse()?;
        }
        if let Some(v) = p.get("checkpoint") {
            self.checkpoint = Some(PathBuf::from(v));
        }
        if let Some(v) = p.get("checkpoint-every") {
            self.checkpoint_every = v.parse()?;
        }
        if let Some(v) = p.get("keep-checkpoints") {
            self.keep_checkpoints = v.parse()?;
            if self.keep_checkpoints == 0 {
                anyhow::bail!("--keep-checkpoints must be >= 1");
            }
        }
        if let Some(v) = p.get("resume") {
            self.resume = Some(PathBuf::from(v));
        }
        if p.flag("constant-lr") {
            self.schedule = Schedule::Constant;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_warmup_then_decay() {
        let cfg = TrainConfig { lr: 1.0, steps: 120,
                                schedule: Schedule::WarmupCosine { warmup: 20 },
                                ..Default::default() };
        assert!(cfg.lr_at(0) < 0.1);
        assert!((cfg.lr_at(19) - 1.0).abs() < 1e-6);
        assert!(cfg.lr_at(119) < 0.2);
        assert!(cfg.lr_at(60) < cfg.lr_at(25));
    }

    #[test]
    fn json_overrides() {
        let mut cfg = TrainConfig::default();
        let j = json::parse(
            r#"{"steps": 7, "lr": 0.5, "schedule": "constant"}"#).unwrap();
        cfg.apply_json(&j).unwrap();
        assert_eq!(cfg.steps, 7);
        assert_eq!(cfg.lr, 0.5);
        assert_eq!(cfg.schedule, Schedule::Constant);
        assert_eq!(cfg.lr_at(3), 0.5);
    }

    #[test]
    fn dropout_from_json_and_cli_bounds() {
        let mut cfg = TrainConfig::default();
        assert_eq!(cfg.dropout, 0.0);
        let j = json::parse(r#"{"dropout": 0.15}"#).unwrap();
        cfg.apply_json(&j).unwrap();
        assert!((cfg.dropout - 0.15).abs() < 1e-6);
        // JSON rejects rates outside [0, 1), same as the CLI
        let bad_json = json::parse(r#"{"dropout": 1.0}"#).unwrap();
        assert!(cfg.apply_json(&bad_json).is_err());
        // CLI rejects rates outside [0, 1)
        let cmd = crate::util::cli::Command::new("train", "t")
            .opt("dropout", Some("0"), "rate");
        let bad = cmd.parse(&["--dropout".to_string(), "1.0".to_string()])
            .unwrap();
        assert!(cfg.apply_cli(&bad).is_err());
        let good = cmd.parse(&["--dropout".to_string(), "0.5".to_string()])
            .unwrap();
        cfg.apply_cli(&good).unwrap();
        assert_eq!(cfg.dropout, 0.5);
    }

    #[test]
    fn checkpoint_retention_knobs() {
        let mut cfg = TrainConfig::default();
        assert_eq!(cfg.checkpoint_every, 0);
        assert_eq!(cfg.keep_checkpoints, 3);
        let j = json::parse(
            r#"{"checkpoint_every": 25, "keep_checkpoints": 5}"#).unwrap();
        cfg.apply_json(&j).unwrap();
        assert_eq!(cfg.checkpoint_every, 25);
        assert_eq!(cfg.keep_checkpoints, 5);
        // retaining zero checkpoints would make the ring useless
        let bad = json::parse(r#"{"keep_checkpoints": 0}"#).unwrap();
        assert!(cfg.apply_json(&bad).is_err());
        let cmd = crate::util::cli::Command::new("train", "t")
            .opt("checkpoint-every", Some("0"), "n")
            .opt("keep-checkpoints", Some("3"), "n");
        let p = cmd.parse(&["--checkpoint-every".to_string(),
                            "10".to_string(),
                            "--keep-checkpoints".to_string(),
                            "2".to_string()]).unwrap();
        cfg.apply_cli(&p).unwrap();
        assert_eq!(cfg.checkpoint_every, 10);
        assert_eq!(cfg.keep_checkpoints, 2);
    }

    #[test]
    fn backend_selection_defaults_and_overrides() {
        let mut cfg = TrainConfig::default();
        assert_eq!(cfg.backend, "pjrt");
        let j = json::parse(r#"{"backend": "native"}"#).unwrap();
        cfg.apply_json(&j).unwrap();
        assert_eq!(cfg.backend, "native");
    }
}
