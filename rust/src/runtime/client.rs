//! PJRT client wrapper: loads AOT-compiled HLO text artifacts, caches the
//! compiled executables, and provides a uniform "call with literals, get
//! decomposed tuple back" interface.
//!
//! Pattern follows /opt/xla-example/load_hlo: HLO text →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

pub struct Runtime {
    client: xla::PjRtClient,
    cache: RefCell<HashMap<PathBuf, Rc<xla::PjRtLoadedExecutable>>>,
    pub compile_seconds: RefCell<f64>,
    /// Cumulative time inside `execute` (device compute) — everything else
    /// in `run` is host overhead (output fetch + tuple decomposition).
    pub execute_seconds: RefCell<f64>,
    /// Cumulative time fetching + decomposing outputs.
    pub fetch_seconds: RefCell<f64>,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("create PJRT CPU client: {e:?}"))?;
        Ok(Runtime {
            client,
            cache: RefCell::new(HashMap::new()),
            compile_seconds: RefCell::new(0.0),
            execute_seconds: RefCell::new(0.0),
            fetch_seconds: RefCell::new(0.0),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text artifact (cached by path).
    pub fn load(&self, path: &Path) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(path) {
            return Ok(exe.clone());
        }
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("parse HLO text {}: {e:?}",
                                 path.display()))
            .with_context(|| "is `make artifacts` up to date?")?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)
            .map_err(|e| anyhow!("compile {}: {e:?}", path.display()))?;
        let exe = Rc::new(exe);
        *self.compile_seconds.borrow_mut() += t0.elapsed().as_secs_f64();
        self.cache.borrow_mut().insert(path.to_path_buf(), exe.clone());
        Ok(exe)
    }

    pub fn cached_executables(&self) -> usize {
        self.cache.borrow().len()
    }

    pub fn evict(&self, path: &Path) {
        self.cache.borrow_mut().remove(path);
    }

    pub fn clear_cache(&self) {
        self.cache.borrow_mut().clear();
    }

    /// Execute with literal arguments; returns the decomposed output tuple.
    ///
    /// All exports lower with `return_tuple=True`, so the single output
    /// buffer is a tuple literal which we decompose into its leaves.
    pub fn run(&self, exe: &xla::PjRtLoadedExecutable,
               args: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let t0 = Instant::now();
        let buffers = exe.execute::<&xla::Literal>(args)
            .map_err(|e| anyhow!("execute: {e:?}"))?;
        let t1 = Instant::now();
        let out = buffers
            .first().and_then(|d| d.first())
            .ok_or_else(|| anyhow!("executable produced no outputs"))?;
        let lit = out.to_literal_sync()
            .map_err(|e| anyhow!("fetch output literal: {e:?}"))?;
        let res = lit.to_tuple()
            .map_err(|e| anyhow!("decompose output tuple: {e:?}"));
        *self.execute_seconds.borrow_mut() +=
            (t1 - t0).as_secs_f64();
        *self.fetch_seconds.borrow_mut() += t1.elapsed().as_secs_f64();
        res
    }

    /// Reset the profiling accumulators; returns (execute_s, fetch_s).
    pub fn take_profile(&self) -> (f64, f64) {
        let e = std::mem::take(&mut *self.execute_seconds.borrow_mut());
        let f = std::mem::take(&mut *self.fetch_seconds.borrow_mut());
        (e, f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_comes_up() {
        let rt = Runtime::cpu().unwrap();
        assert!(rt.platform().to_lowercase().contains("cpu")
                || !rt.platform().is_empty());
        assert_eq!(rt.cached_executables(), 0);
    }
}
