//! Per-variant model runtime: owns the parameter/optimizer literals and
//! exposes the train / eval / prefill / decode operations following the
//! calling conventions documented in python/compile/aot.py.

use std::path::Path;
use std::rc::Rc;

use anyhow::{anyhow, bail, Result};

use crate::tensor::{Batch, Tensor, TensorData};
use crate::util::io::{self, NamedTensor};

use super::client::Runtime;
use super::manifest::{LeafSpec, Manifest, Variant};

/// Parameters + optimizer state as device-feedable literals.
pub struct TrainState {
    pub params: Vec<xla::Literal>,
    pub opt: Vec<xla::Literal>,
    pub step: u64,
}

#[derive(Clone, Copy, Debug, Default)]
pub struct StepMetrics {
    pub loss: f32,
    pub grad_norm: f32,
}

#[derive(Clone, Copy, Debug, Default)]
pub struct EvalMetrics {
    pub loss: f32,
    pub token_acc: f32,
    pub seq_acc: f32,
}

pub struct Model<'rt> {
    pub rt: &'rt Runtime,
    pub manifest: Rc<Manifest>,
    pub variant: Variant,
}

fn check_leaves(what: &str, specs: &[LeafSpec],
                lits: &[xla::Literal]) -> Result<()> {
    if specs.len() != lits.len() {
        bail!("{what}: expected {} leaves, executable returned {}",
              specs.len(), lits.len());
    }
    for (spec, lit) in specs.iter().zip(lits) {
        let n = lit.element_count();
        if n != spec.elements() {
            bail!("{what}: leaf '{}' expected {:?} ({} elems), got {} elems",
                  spec.name, spec.shape, spec.elements(), n);
        }
    }
    Ok(())
}

impl<'rt> Model<'rt> {
    pub fn open(rt: &'rt Runtime, manifest: Rc<Manifest>,
                name: &str) -> Result<Model<'rt>> {
        let variant = manifest.variant(name)?.clone();
        Ok(Model { rt, manifest, variant })
    }

    fn path(&self, file: &str) -> std::path::PathBuf {
        self.manifest.file_path(file)
    }

    // ---- init --------------------------------------------------------

    /// Run the exported `init(seed, forget_bias)` executable.
    pub fn init(&self, seed: i32, forget_bias: f32) -> Result<TrainState> {
        let exe = self.rt.load(&self.path(&self.variant.init_file))?;
        let seed_l = Tensor::scalar_i32(seed).to_literal()?;
        let fb_l = Tensor::scalar_f32(forget_bias).to_literal()?;
        let mut out = self.rt.run(&exe, &[&seed_l, &fb_l])?;
        let n_p = self.variant.n_params();
        let n_o = self.variant.n_opt();
        if out.len() != n_p + n_o {
            bail!("init returned {} leaves, manifest says {}+{}",
                  out.len(), n_p, n_o);
        }
        let opt = out.split_off(n_p);
        check_leaves("init params", &self.variant.params, &out)?;
        check_leaves("init opt", &self.variant.opt, &opt)?;
        Ok(TrainState { params: out, opt, step: 0 })
    }

    // ---- train -------------------------------------------------------

    pub fn train_step(&self, state: &mut TrainState, batch: &Batch,
                      lr: f32, drop_seed: i32) -> Result<StepMetrics> {
        let file = self.variant.train_file.as_ref()
            .ok_or_else(|| anyhow!("variant {} exports no train step",
                                   self.variant.name))?;
        let exe = self.rt.load(&self.path(file))?;

        let x = batch.x.to_literal()?;
        let t = batch.targets.to_literal()?;
        let m = batch.mask.to_literal()?;
        let lr_l = Tensor::scalar_f32(lr).to_literal()?;
        let seed_l = Tensor::scalar_i32(drop_seed).to_literal()?;

        let mut args: Vec<&xla::Literal> = Vec::with_capacity(
            state.params.len() + state.opt.len() + 5);
        args.extend(state.params.iter());
        args.extend(state.opt.iter());
        args.extend([&x, &t, &m, &lr_l, &seed_l]);

        let mut out = self.rt.run(&exe, &args)?;
        let n_p = self.variant.n_params();
        let n_o = self.variant.n_opt();
        if out.len() != n_p + n_o + 2 {
            bail!("train step returned {} leaves, expected {}",
                  out.len(), n_p + n_o + 2);
        }
        let gnorm = out.pop().unwrap().get_first_element::<f32>()
            .map_err(|e| anyhow!("read grad_norm: {e:?}"))?;
        let loss = out.pop().unwrap().get_first_element::<f32>()
            .map_err(|e| anyhow!("read loss: {e:?}"))?;
        let opt = out.split_off(n_p);
        state.params = out;
        state.opt = opt;
        state.step += 1;
        if !loss.is_finite() {
            bail!("non-finite loss {loss} at step {} of {}",
                  state.step, self.variant.name);
        }
        Ok(StepMetrics { loss, grad_norm: gnorm })
    }

    // ---- eval --------------------------------------------------------

    /// Evaluate using the eval executable matching the batch's (B, T).
    pub fn eval(&self, state: &TrainState, batch: &Batch)
                -> Result<EvalMetrics> {
        let (b, t) = (batch.batch_size(), batch.seq_len());
        let ef = self.variant.eval_files.iter()
            .find(|e| e.batch == b && e.seq_len == t)
            .ok_or_else(|| anyhow!(
                "no eval executable for batch={b} seq_len={t} in {} \
                 (available: {:?})", self.variant.name,
                self.variant.eval_files.iter()
                    .map(|e| (e.batch, e.seq_len)).collect::<Vec<_>>()))?;
        let exe = self.rt.load(&self.path(&ef.file))?;

        let x = batch.x.to_literal()?;
        let tg = batch.targets.to_literal()?;
        let m = batch.mask.to_literal()?;
        let mut args: Vec<&xla::Literal> = state.params.iter().collect();
        args.extend([&x, &tg, &m]);

        let out = self.rt.run(&exe, &args)?;
        let scalar = |i: usize| -> Result<f32> {
            out.get(i)
                .ok_or_else(|| anyhow!("eval output {i} missing"))?
                .get_first_element::<f32>()
                .map_err(|e| anyhow!("read eval output {i}: {e:?}"))
        };
        if self.variant.task == "masked_ce" {
            Ok(EvalMetrics { loss: scalar(0)?, token_acc: scalar(1)?,
                             seq_acc: scalar(2)? })
        } else {
            Ok(EvalMetrics { loss: scalar(0)?, token_acc: 0.0,
                             seq_acc: 0.0 })
        }
    }

    // ---- decode ------------------------------------------------------

    /// Fresh zero decode state for the step executable at `batch`.
    pub fn decode_state_zeros(&self, batch: usize)
                              -> Result<Vec<xla::Literal>> {
        let sf = self.variant.step_for_batch(batch)
            .ok_or_else(|| anyhow!("no step executable for batch {batch}"))?;
        sf.state.iter().map(|spec| {
            let n = spec.elements();
            let t = match spec.dtype.as_str() {
                "i32" => Tensor::i32(spec.shape.clone(), vec![0; n]),
                _ => {
                    // RNN hidden states start at the positive resting value
                    // used by the log-space formulation (g(0) = 0.5); conv
                    // buffers and the position counter start at zero.
                    let fill = if spec.name.contains("mixer") { 0.5 } else { 0.0 };
                    Tensor::f32(spec.shape.clone(), vec![fill; n])
                }
            };
            t.to_literal()
        }).collect()
    }

    /// One decode step: (logits, new_state).
    pub fn decode_step(&self, params: &[xla::Literal], x_t: &Tensor,
                       state: Vec<xla::Literal>)
                       -> Result<(Tensor, Vec<xla::Literal>)> {
        let batch = if x_t.dims.is_empty() { 1 } else { x_t.dims[0] };
        let sf = self.variant.step_for_batch(batch)
            .ok_or_else(|| anyhow!("no step executable for batch {batch}"))?;
        let exe = self.rt.load(&self.path(&sf.file))?;
        let x_l = x_t.to_literal()?;
        let mut args: Vec<&xla::Literal> = params.iter().collect();
        args.push(&x_l);
        args.extend(state.iter());
        let mut out = self.rt.run(&exe, &args)?;
        if out.len() != 1 + sf.state.len() {
            bail!("step returned {} leaves, expected {}", out.len(),
                  1 + sf.state.len());
        }
        let new_state = out.split_off(1);
        let logits = Tensor::from_literal(&out[0])?;
        Ok((logits, new_state))
    }

    /// Parallel prefill over a context: (last-position logits, state).
    pub fn prefill(&self, params: &[xla::Literal], x: &Tensor)
                   -> Result<(Tensor, Vec<xla::Literal>)> {
        let (b, t) = (x.dims[0], x.dims[1]);
        let pf = self.variant.prefill_for(b, t)
            .ok_or_else(|| anyhow!(
                "no prefill executable for batch={b} seq_len={t} in {}",
                self.variant.name))?;
        let exe = self.rt.load(&self.path(&pf.file))?;
        let x_l = x.to_literal()?;
        let mut args: Vec<&xla::Literal> = params.iter().collect();
        args.push(&x_l);
        let mut out = self.rt.run(&exe, &args)?;
        if out.len() != 1 + pf.state.len() {
            bail!("prefill returned {} leaves, expected {}", out.len(),
                  1 + pf.state.len());
        }
        let state = out.split_off(1);
        let logits = Tensor::from_literal(&out[0])?;
        Ok((logits, state))
    }

    // ---- checkpointing -------------------------------------------------

    pub fn save_checkpoint(&self, state: &TrainState,
                           path: &Path) -> Result<()> {
        let mut tensors = Vec::new();
        let dump = |prefix: &str, specs: &[LeafSpec],
                    lits: &[xla::Literal], out: &mut Vec<NamedTensor>|
                   -> Result<()> {
            for (spec, lit) in specs.iter().zip(lits) {
                let t = Tensor::from_literal(lit)?;
                let name = format!("{prefix}/{}", spec.name);
                out.push(match t.data {
                    TensorData::F32(v) => NamedTensor::f32(&name, t.dims, v),
                    TensorData::I32(v) => NamedTensor::i32(&name, t.dims, v),
                    TensorData::I8(v) => NamedTensor::i8(&name, t.dims, v),
                });
            }
            Ok(())
        };
        dump("params", &self.variant.params, &state.params, &mut tensors)?;
        dump("opt", &self.variant.opt, &state.opt, &mut tensors)?;
        tensors.push(NamedTensor::i32("meta/step", vec![],
                                      vec![state.step as i32]));
        io::save(path, &tensors)
    }

    pub fn load_checkpoint(&self, path: &Path) -> Result<TrainState> {
        let tensors = io::load(path)?;
        let lookup = |name: &str| -> Result<&NamedTensor> {
            tensors.iter().find(|t| t.name == name)
                .ok_or_else(|| anyhow!("checkpoint missing tensor '{name}'"))
        };
        let restore = |prefix: &str, specs: &[LeafSpec]|
                      -> Result<Vec<xla::Literal>> {
            specs.iter().map(|spec| {
                let nt = lookup(&format!("{prefix}/{}", spec.name))?;
                if nt.dims != spec.shape {
                    bail!("checkpoint tensor '{}' shape {:?} != manifest {:?}",
                          spec.name, nt.dims, spec.shape);
                }
                Tensor { dims: nt.dims.clone(), data: nt.data.clone() }
                    .to_literal()
            }).collect()
        };
        let params = restore("params", &self.variant.params)?;
        let opt = restore("opt", &self.variant.opt)?;
        let step = lookup("meta/step")?.data.as_i32()
            .and_then(|v| v.first().copied()).unwrap_or(0) as u64;
        Ok(TrainState { params, opt, step })
    }
}
