//! Runtime layer: PJRT client + artifact manifest + per-variant model ops.
//! Python never runs here — artifacts/*.hlo.txt are loaded directly.

pub mod client;
pub mod manifest;
pub mod model;

pub use client::Runtime;
pub use manifest::{Manifest, Variant};
pub use model::{EvalMetrics, Model, StepMetrics, TrainState};
