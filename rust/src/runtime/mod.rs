//! Runtime layer: the [`Backend`] inference abstraction, the PJRT client +
//! artifact manifest + per-variant model ops behind it.  Python never runs
//! here — artifacts/*.hlo.txt are loaded directly, and the native backend
//! (`crate::backend`) needs no artifacts at all.

pub mod backend;
pub mod client;
pub mod manifest;
pub mod model;

pub use backend::{artifacts_available, artifacts_root, require_artifacts,
                  Backend, PjrtBackend, PjrtTrain, SessionState,
                  TrainBackend, ARTIFACTS_HELP};
pub use client::Runtime;
pub use manifest::{Manifest, Variant};
pub use model::{EvalMetrics, Model, StepMetrics, TrainState};
