//! Typed view of `artifacts/manifest.json` — the contract produced by
//! `python/compile/aot.py`.  See that file's docstring for the calling
//! conventions each executable follows.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::{self, Json};

#[derive(Clone, Debug, PartialEq)]
pub struct LeafSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl LeafSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<LeafSpec> {
        Ok(LeafSpec {
            name: j.req("name")?.as_str().unwrap_or_default().to_string(),
            shape: j.req("shape")?.as_arr().unwrap_or_default().iter()
                .filter_map(|d| d.as_usize()).collect(),
            dtype: j.req("dtype")?.as_str().unwrap_or("f32").to_string(),
        })
    }
}

#[derive(Clone, Debug)]
pub struct IoSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl IoSpec {
    fn from_json(j: &Json) -> Result<IoSpec> {
        Ok(IoSpec {
            shape: j.req("shape")?.as_arr().unwrap_or_default().iter()
                .filter_map(|d| d.as_usize()).collect(),
            dtype: j.req("dtype")?.as_str().unwrap_or("f32").to_string(),
        })
    }
}

#[derive(Clone, Debug)]
pub struct EvalFile {
    pub batch: usize,
    pub seq_len: usize,
    pub file: String,
}

#[derive(Clone, Debug)]
pub struct StepFile {
    pub batch: usize,
    pub file: String,
    pub state: Vec<LeafSpec>,
}

#[derive(Clone, Debug)]
pub struct PrefillFile {
    pub batch: usize,
    pub seq_len: usize,
    pub file: String,
    pub state: Vec<LeafSpec>,
}

#[derive(Clone, Debug)]
pub struct Variant {
    pub name: String,
    pub group: String,
    pub task: String,
    pub batch: usize,
    pub seq_len: usize,
    pub cfg: Json,
    pub workload: Json,
    pub params: Vec<LeafSpec>,
    pub opt: Vec<LeafSpec>,
    pub init_file: String,
    pub train_file: Option<String>,
    pub eval_files: Vec<EvalFile>,
    pub step_files: Vec<StepFile>,
    pub prefill_files: Vec<PrefillFile>,
    pub io: Option<(IoSpec, IoSpec, IoSpec)>,
    pub depth_parallel: usize,
    pub depth_sequential: usize,
    pub memory: Option<BTreeMap<String, i64>>,
}

impl Variant {
    pub fn n_params(&self) -> usize {
        self.params.len()
    }

    pub fn n_opt(&self) -> usize {
        self.opt.len()
    }

    pub fn param_elements(&self) -> usize {
        self.params.iter().map(|p| p.elements()).sum()
    }

    /// Workload kind string, e.g. "char_lm", "chomsky/majority".
    pub fn workload_kind(&self) -> String {
        self.workload.get("kind").and_then(|k| k.as_str())
            .unwrap_or("unknown").to_string()
    }

    pub fn cfg_usize(&self, key: &str) -> Option<usize> {
        self.cfg.get(key).and_then(|v| v.as_usize())
    }

    pub fn cfg_str(&self, key: &str) -> Option<&str> {
        self.cfg.get(key).and_then(|v| v.as_str())
    }

    pub fn step_for_batch(&self, batch: usize) -> Option<&StepFile> {
        self.step_files.iter().find(|s| s.batch == batch)
    }

    pub fn prefill_for(&self, batch: usize, seq_len: usize)
                       -> Option<&PrefillFile> {
        self.prefill_files.iter()
            .find(|p| p.batch == batch && p.seq_len == seq_len)
    }

    fn from_json(name: &str, j: &Json) -> Result<Variant> {
        let files = j.req("files")?;
        let leaf_list = |key: &str| -> Result<Vec<LeafSpec>> {
            j.req(key)?.as_arr().unwrap_or_default().iter()
                .map(LeafSpec::from_json).collect()
        };
        let eval_files = match files.get("eval") {
            Some(Json::Arr(items)) => items.iter().map(|e| {
                Ok(EvalFile {
                    batch: e.req("batch")?.as_usize().unwrap_or(0),
                    seq_len: e.req("seq_len")?.as_usize().unwrap_or(0),
                    file: e.req("file")?.as_str().unwrap_or("").to_string(),
                })
            }).collect::<Result<Vec<_>>>()?,
            _ => Vec::new(),
        };
        let step_files = match files.get("step") {
            Some(Json::Arr(items)) => items.iter().map(|e| {
                Ok(StepFile {
                    batch: e.req("batch")?.as_usize().unwrap_or(0),
                    file: e.req("file")?.as_str().unwrap_or("").to_string(),
                    state: e.req("state")?.as_arr().unwrap_or_default()
                        .iter().map(LeafSpec::from_json)
                        .collect::<Result<Vec<_>>>()?,
                })
            }).collect::<Result<Vec<_>>>()?,
            _ => Vec::new(),
        };
        let prefill_files = match files.get("prefill") {
            Some(Json::Arr(items)) => items.iter().map(|e| {
                Ok(PrefillFile {
                    batch: e.req("batch")?.as_usize().unwrap_or(0),
                    seq_len: e.req("seq_len")?.as_usize().unwrap_or(0),
                    file: e.req("file")?.as_str().unwrap_or("").to_string(),
                    state: e.req("state")?.as_arr().unwrap_or_default()
                        .iter().map(LeafSpec::from_json)
                        .collect::<Result<Vec<_>>>()?,
                })
            }).collect::<Result<Vec<_>>>()?,
            _ => Vec::new(),
        };
        let io = match j.get("io") {
            Some(io) => Some((
                IoSpec::from_json(io.req("x")?)?,
                IoSpec::from_json(io.req("targets")?)?,
                IoSpec::from_json(io.req("mask")?)?,
            )),
            None => None,
        };
        let depth = j.get("depth");
        let memory = j.get("memory").and_then(|m| m.as_obj()).map(|pairs| {
            pairs.iter()
                .filter_map(|(k, v)| v.as_i64().map(|n| (k.clone(), n)))
                .collect()
        });
        Ok(Variant {
            name: name.to_string(),
            group: j.req("group")?.as_str().unwrap_or("").to_string(),
            task: j.req("task")?.as_str().unwrap_or("").to_string(),
            batch: j.req("batch")?.as_usize().unwrap_or(0),
            seq_len: j.req("seq_len")?.as_usize().unwrap_or(0),
            cfg: j.req("cfg")?.clone(),
            workload: j.req("workload")?.clone(),
            params: leaf_list("params")?,
            opt: leaf_list("opt")?,
            init_file: files.req("init")?.as_str().unwrap_or("").to_string(),
            train_file: files.get("train").and_then(|f| f.as_str())
                .map(|s| s.to_string()),
            eval_files,
            step_files,
            prefill_files,
            io,
            depth_parallel: depth.and_then(|d| d.get("parallel_scan"))
                .and_then(|v| v.as_usize()).unwrap_or(0),
            depth_sequential: depth.and_then(|d| d.get("sequential"))
                .and_then(|v| v.as_usize()).unwrap_or(0),
            memory,
        })
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub variants: BTreeMap<String, Variant>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(
            || format!("read {} — run `make artifacts` first",
                       path.display()))?;
        let root = json::parse(&text)
            .map_err(|e| anyhow!("parse {}: {e}", path.display()))?;
        let mut variants = BTreeMap::new();
        for (name, vj) in root.req("variants")?.as_obj()
            .ok_or_else(|| anyhow!("manifest variants not an object"))? {
            variants.insert(name.clone(),
                            Variant::from_json(name, vj)
                                .with_context(|| format!("variant {name}"))?);
        }
        Ok(Manifest { dir: dir.to_path_buf(), variants })
    }

    pub fn variant(&self, name: &str) -> Result<&Variant> {
        self.variants.get(name).ok_or_else(|| anyhow!(
            "variant '{name}' not in manifest (have: {})",
            self.variants.keys().cloned().collect::<Vec<_>>().join(", ")))
    }

    pub fn group(&self, group: &str) -> Vec<&Variant> {
        self.variants.values().filter(|v| v.group == group).collect()
    }

    pub fn file_path(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }
}
