//! The [`Backend`] trait: a uniform decode/prefill interface over the two
//! inference implementations —
//!
//! * [`PjrtBackend`] — the AOT-compiled XLA artifact path (this module),
//! * `crate::backend::NativeBackend` — the pure-Rust CPU path.
//!
//! `coordinator::infer` (generation, RL rollouts) and
//! `coordinator::server` (dynamic batching) are generic over this trait,
//! so the whole serving stack runs identically with or without artifacts.
//!
//! [`TrainBackend`] is the training-side mirror: one optimizer step +
//! evaluation + checkpointing, implemented by [`PjrtTrain`] (the AOT
//! train-step executable) and `crate::backend::NativeTrainer` (log-space
//! scan VJP + AdamW in Rust).  `coordinator::trainer::run_loop` drives
//! either through this trait, making training artifact-optional too.

use anyhow::{bail, Result};

use crate::tensor::{Batch, Tensor};

use super::model::{EvalMetrics, Model, StepMetrics, TrainState};

/// Largest batch a backend without fixed step executables will form when
/// planning dynamic batches.
pub const MAX_DYNAMIC_BATCH: usize = 64;

pub trait Backend {
    /// Opaque per-batch decode state threaded through `decode_step`.
    type State;

    fn name(&self) -> &str;

    /// Batch sizes with a dedicated decode executable; empty means the
    /// backend handles any batch size (the default `plan_batch` then
    /// forms exact-fit batches up to [`MAX_DYNAMIC_BATCH`]).  A backend
    /// whose empty list means "no decode path at all" must override
    /// `plan_batch` to return `None` — see `PjrtBackend`.
    fn step_batches(&self) -> Vec<usize>;

    /// Fresh decode state for `batch` lanes.
    fn decode_state(&self, batch: usize) -> Result<Self::State>;

    /// One decode step: `x_t` is `(B,)` i32 tokens or `(B, F)` f32
    /// features; returns `(logits: (B, vocab_out), state')`.
    fn decode_step(&self, x_t: &Tensor, state: Self::State)
                   -> Result<(Tensor, Self::State)>;

    /// Parallel context ingestion: `(last-position logits, state)`.
    fn prefill(&self, x: &Tensor) -> Result<(Tensor, Self::State)>;

    /// Reset one decode lane of `state` to the fresh position-0 state,
    /// leaving the other lanes untouched.  Returns `true` on success —
    /// backends that support this (native) get continuous batching in
    /// `coordinator::server::serve`: a finished lane is re-seeded with the
    /// next queued request mid-flight instead of idling until the whole
    /// batch drains.  Default: unsupported (`false`), which falls back to
    /// run-to-completion batches.
    fn reset_lane(&self, _state: &mut Self::State, _lane: usize) -> bool {
        false
    }

    /// Whether [`Backend::reset_lane`] actually re-seeds lanes.  The async
    /// scheduler (`coordinator::scheduler`) consults this *before* popping
    /// a request off the admission queue: on a lane-resettable backend it
    /// admits new work into free lanes of the running batch mid-decode; on
    /// a fixed backend it only admits at batch formation and runs each
    /// batch to completion.  Must agree with `reset_lane` (`true` here
    /// while `reset_lane` fails would strand admitted requests).
    fn lane_reset_supported(&self) -> bool {
        false
    }

    /// Fingerprint of the decode-state layout a [`SessionState`] exported
    /// from this backend carries (architecture kind, per-layer hidden
    /// sizes, conv widths).  `Some` promises that
    /// [`Backend::export_state`] / [`Backend::import_state`] work; `None`
    /// (the default, and the PJRT path — its state lives in device
    /// literals) means callers such as `coordinator::session_cache` must
    /// fall back to prefilling from scratch.
    fn state_fingerprint(&self) -> Option<u64> {
        None
    }

    /// Serialize one decode lane of `state` into an opaque, host-portable
    /// [`SessionState`] (the constant-size-state payoff of the paper's
    /// recurrence: a few KB per layer, O(1) in context length).  Default:
    /// unsupported.
    fn export_state(&self, _state: &Self::State, _lane: usize)
                    -> Result<SessionState> {
        bail!("backend '{}' does not support per-lane state export",
              self.name())
    }

    /// Overwrite one decode lane of `state` from a [`SessionState`]
    /// previously produced by [`Backend::export_state`] on an
    /// identically-shaped model.  Must fail cleanly (never panic on
    /// shapes) when the snapshot's fingerprint does not match
    /// [`Backend::state_fingerprint`].  Default: unsupported.
    fn import_state(&self, _state: &mut Self::State, _lane: usize,
                    _snap: &SessionState) -> Result<()> {
        bail!("backend '{}' does not support per-lane state import",
              self.name())
    }

    /// Pick a batch size for `queue_len` waiting requests, or `None` when
    /// the queue is empty.
    fn plan_batch(&self, queue_len: usize) -> Option<usize> {
        if queue_len == 0 {
            return None;
        }
        let available = self.step_batches();
        if available.is_empty() {
            Some(queue_len.min(MAX_DYNAMIC_BATCH))
        } else {
            plan_batch(queue_len, &available)
        }
    }
}

/// Picks batch sizes for fixed-size executables: the largest exported size
/// ≤ queue length, else the smallest exported size (padding idle lanes)
/// once anything is waiting.
pub fn plan_batch(queue_len: usize, available: &[usize]) -> Option<usize> {
    if queue_len == 0 {
        return None;
    }
    let mut sizes: Vec<usize> = available.to_vec();
    sizes.sort_unstable();
    sizes.iter().rev().find(|&&b| b <= queue_len).copied()
        .or_else(|| sizes.first().copied())
}

// ---------------------------------------------------------------------------
// per-lane session state
// ---------------------------------------------------------------------------

/// One decode lane's state, exported for reuse: opaque backend-defined
/// bytes plus the architecture fingerprint of the model that produced
/// them.  Because minGRU/minLSTM decode state is constant-size (no KV
/// cache), this is a few KB per layer regardless of how much context the
/// lane has consumed — small enough to cache per session, clone per
/// request, and persist to disk (`coordinator::session_cache`).
#[derive(Clone, Debug, PartialEq)]
pub struct SessionState {
    /// Decode-state layout fingerprint ([`Backend::state_fingerprint`]);
    /// `import_state` refuses a snapshot whose fingerprint differs from
    /// the importing model's.
    pub fingerprint: u64,
    /// Backend-defined serialization of one decode lane.
    pub bytes: Vec<u8>,
}

impl SessionState {
    /// Serialize to a self-contained little-endian byte string:
    /// `fingerprint u64 | byte_len u32 | bytes`.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(12 + self.bytes.len());
        out.extend_from_slice(&self.fingerprint.to_le_bytes());
        out.extend_from_slice(&(self.bytes.len() as u32).to_le_bytes());
        out.extend_from_slice(&self.bytes);
        out
    }

    /// Inverse of [`SessionState::to_bytes`]; rejects truncated or
    /// trailing-garbage input instead of mis-slicing it.
    pub fn from_bytes(raw: &[u8]) -> Result<SessionState> {
        if raw.len() < 12 {
            bail!("session state truncated: {} bytes < 12-byte header",
                  raw.len());
        }
        let fingerprint = u64::from_le_bytes(raw[..8].try_into().unwrap());
        let len =
            u32::from_le_bytes(raw[8..12].try_into().unwrap()) as usize;
        if raw.len() != 12 + len {
            bail!("session state corrupt: header says {len} payload \
                   bytes, got {}", raw.len() - 12);
        }
        Ok(SessionState { fingerprint, bytes: raw[12..].to_vec() })
    }
}

// ---------------------------------------------------------------------------
// training backends
// ---------------------------------------------------------------------------

/// A training engine: one optimizer step per call, periodic evaluation,
/// checkpointing.  `coordinator::trainer::run_loop` is generic over this,
/// so the host-side loop (batching, LR schedule, early stopping) is shared
/// between the PJRT artifact path and the native Rust path.
pub trait TrainBackend {
    /// Label used in logs and checkpoint file names.
    fn name(&self) -> &str;

    /// One optimizer step on `batch` at learning rate `lr`.  `drop_seed`
    /// keys the step's dropout masks on both backends: PJRT folds it into
    /// the exported train-step's PRNG, the native trainer feeds its
    /// counter-based per-position mask generator (a no-op at rate 0).
    fn train_step(&mut self, batch: &Batch, lr: f32, drop_seed: i32)
                  -> Result<StepMetrics>;

    /// Whether [`TrainBackend::eval`] can run (PJRT needs exported eval
    /// executables; native always can).
    fn supports_eval(&self) -> bool;

    fn eval(&self, batch: &Batch) -> Result<EvalMetrics>;

    fn save_checkpoint(&self, path: &std::path::Path) -> Result<()>;
}

/// [`TrainBackend`] over the AOT train-step executable: borrows the opened
/// [`Model`] and mutates the caller's [`TrainState`] in place, so callers
/// keep ownership of the parameter literals for later inference.
pub struct PjrtTrain<'a, 'rt> {
    pub model: &'a Model<'rt>,
    pub state: &'a mut TrainState,
}

impl TrainBackend for PjrtTrain<'_, '_> {
    fn name(&self) -> &str {
        &self.model.variant.name
    }

    fn train_step(&mut self, batch: &Batch, lr: f32, drop_seed: i32)
                  -> Result<StepMetrics> {
        self.model.train_step(self.state, batch, lr, drop_seed)
    }

    fn supports_eval(&self) -> bool {
        !self.model.variant.eval_files.is_empty()
    }

    fn eval(&self, batch: &Batch) -> Result<EvalMetrics> {
        self.model.eval(self.state, batch)
    }

    fn save_checkpoint(&self, path: &std::path::Path) -> Result<()> {
        self.model.save_checkpoint(self.state, path)
    }
}

/// The PJRT/XLA artifact backend: borrows an opened [`Model`] and its
/// parameter literals.
pub struct PjrtBackend<'a, 'rt> {
    pub model: &'a Model<'rt>,
    pub params: &'a [xla::Literal],
}

impl<'a, 'rt> PjrtBackend<'a, 'rt> {
    pub fn new(model: &'a Model<'rt>, params: &'a [xla::Literal])
               -> PjrtBackend<'a, 'rt> {
        PjrtBackend { model, params }
    }
}

impl Backend for PjrtBackend<'_, '_> {
    type State = Vec<xla::Literal>;

    fn name(&self) -> &str {
        "pjrt"
    }

    fn step_batches(&self) -> Vec<usize> {
        self.model.variant.step_files.iter().map(|s| s.batch).collect()
    }

    /// Unlike the default, an empty `step_batches` here means the variant
    /// exports no decode executables at all — refuse instead of planning
    /// arbitrary batch sizes that would fail deep inside `decode_state`.
    fn plan_batch(&self, queue_len: usize) -> Option<usize> {
        plan_batch(queue_len, &self.step_batches())
    }

    fn decode_state(&self, batch: usize) -> Result<Vec<xla::Literal>> {
        self.model.decode_state_zeros(batch)
    }

    fn decode_step(&self, x_t: &Tensor, state: Vec<xla::Literal>)
                   -> Result<(Tensor, Vec<xla::Literal>)> {
        self.model.decode_step(self.params, x_t, state)
    }

    fn prefill(&self, x: &Tensor) -> Result<(Tensor, Vec<xla::Literal>)> {
        self.model.prefill(self.params, x)
    }
}

// ---------------------------------------------------------------------------
// artifact discovery (shared by CLI and tests)
// ---------------------------------------------------------------------------

/// How to get PJRT tests/commands running; asserted on by the test-suite
/// gating test so the remedy can never silently rot.
pub const ARTIFACTS_HELP: &str =
    "PJRT artifacts not found: run `make artifacts` (python -m compile.aot \
     --out ../artifacts) and/or set MINRNN_ARTIFACTS to the artifact \
     directory; PJRT integration tests additionally need the crate built \
     with `--features artifacts` and a real `xla` dependency";

/// Artifact directory: `$MINRNN_ARTIFACTS` if set, else `artifacts/`.
pub fn artifacts_root() -> std::path::PathBuf {
    std::env::var("MINRNN_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"))
}

/// True when a manifest is present under `root`.
pub fn artifacts_available_at(root: &std::path::Path) -> bool {
    root.join("manifest.json").exists()
}

/// True when a manifest is present under [`artifacts_root`].
pub fn artifacts_available() -> bool {
    artifacts_available_at(&artifacts_root())
}

/// Panic (failing the test) instead of silently passing when artifacts are
/// required but absent under `root`.
pub fn require_artifacts_at(root: &std::path::Path) {
    if !artifacts_available_at(root) {
        panic!("looked in {}: {}", root.display(), ARTIFACTS_HELP);
    }
}

/// Panic (failing the test) instead of silently passing when artifacts are
/// required but absent.
pub fn require_artifacts() {
    require_artifacts_at(&artifacts_root());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_batch_policy() {
        let avail = [1usize, 8, 32];
        assert_eq!(plan_batch(0, &avail), None);
        assert_eq!(plan_batch(1, &avail), Some(1));
        assert_eq!(plan_batch(7, &avail), Some(1));
        assert_eq!(plan_batch(8, &avail), Some(8));
        assert_eq!(plan_batch(31, &avail), Some(8));
        assert_eq!(plan_batch(100, &avail), Some(32));
        // only large batches exported → pad up
        assert_eq!(plan_batch(3, &[8]), Some(8));
    }

    #[test]
    fn artifacts_help_names_the_remedy() {
        assert!(ARTIFACTS_HELP.contains("MINRNN_ARTIFACTS"));
        assert!(ARTIFACTS_HELP.contains("make artifacts"));
    }

    #[test]
    fn session_state_bytes_roundtrip() {
        let snap = SessionState {
            fingerprint: 0xDEAD_BEEF_1234_5678,
            bytes: vec![0, 1, 2, 255, 7],
        };
        let raw = snap.to_bytes();
        assert_eq!(SessionState::from_bytes(&raw).unwrap(), snap);
        // empty payloads are legal (a zero-layer state)
        let empty = SessionState { fingerprint: 3, bytes: Vec::new() };
        let raw = empty.to_bytes();
        assert_eq!(SessionState::from_bytes(&raw).unwrap(), empty);
    }

    #[test]
    fn session_state_rejects_corrupt_bytes() {
        let snap = SessionState { fingerprint: 9, bytes: vec![1, 2, 3] };
        let raw = snap.to_bytes();
        // truncated header, truncated payload, trailing garbage
        assert!(SessionState::from_bytes(&raw[..4]).is_err());
        assert!(SessionState::from_bytes(&raw[..raw.len() - 1]).is_err());
        let mut long = raw.clone();
        long.push(0);
        assert!(SessionState::from_bytes(&long).is_err());
    }
}
