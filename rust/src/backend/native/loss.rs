//! Fused masked softmax-cross-entropy for the native training path,
//! mirroring `python/compile/tasks.py::masked_ce_loss` / `_metrics`:
//!
//! ```text
//! loss      = Σ mask_rt · (logsumexp(logits_rt) - logits_rt[target_rt]) / M
//! dlogits   = mask_rt / M · (softmax(logits_rt) - onehot(target_rt))
//! token_acc = Σ mask · [argmax == target] / M
//! seq_acc   = fraction of masked sequences with every masked position right
//! ```
//!
//! with `M = max(Σ mask, 1)`.  The per-row log-sum-exp and the global
//! reductions accumulate in f64 so the returned loss is stable enough for
//! finite-difference gradient checks; the backward pass is fused — the
//! softmax is never materialized separately from `dlogits`.

use anyhow::{bail, Result};

use crate::runtime::EvalMetrics;

use super::linalg;

/// Loss + metrics for `(batch, t, vocab)` logits against `(batch, t)` i32
/// targets under a `(batch, t)` f32 mask.  When `dlogits` is given it is
/// refitted to `batch * t * vocab` and receives the loss gradient.
pub fn masked_ce(logits: &[f32], targets: &[i32], mask: &[f32],
                 batch: usize, t: usize, vocab: usize,
                 mut dlogits: Option<&mut Vec<f32>>) -> Result<EvalMetrics> {
    let rows = batch * t;
    if logits.len() != rows * vocab {
        bail!("masked_ce: logits {} != {rows} x {vocab}", logits.len());
    }
    if targets.len() != rows || mask.len() != rows {
        bail!("masked_ce: targets/mask {} / {} != {rows}", targets.len(),
              mask.len());
    }
    if let Some(d) = dlogits.as_mut() {
        linalg::reuse(d, rows * vocab);
    }
    let msum: f64 = mask.iter().map(|&m| m as f64).sum();
    let m_norm = msum.max(1.0);

    let mut loss = 0.0f64;
    let mut correct = 0.0f64;
    let mut seq_ok = 0usize;
    let mut seq_with_mask = 0usize;
    for bi in 0..batch {
        let mut all_ok = true;
        let mut any_mask = false;
        for ti in 0..t {
            let r = bi * t + ti;
            let row = &logits[r * vocab..(r + 1) * vocab];
            let tgt = targets[r];
            if tgt < 0 || tgt as usize >= vocab {
                bail!("masked_ce: target {tgt} outside vocab {vocab} at \
                       (b={bi}, t={ti})");
            }
            let w = mask[r] as f64;
            // row max (also the greedy prediction for the accuracy metrics)
            let mut rmax = f64::NEG_INFINITY;
            let mut argmax = 0usize;
            for (j, &v) in row.iter().enumerate() {
                if (v as f64) > rmax {
                    rmax = v as f64;
                    argmax = j;
                }
            }
            let mut sum = 0.0f64;
            for &v in row {
                sum += (v as f64 - rmax).exp();
            }
            let lse = rmax + sum.ln();
            if w > 0.0 {
                any_mask = true;
                loss += w * (lse - row[tgt as usize] as f64);
                if argmax == tgt as usize {
                    correct += w;
                } else {
                    all_ok = false;
                }
            }
            if let Some(d) = dlogits.as_deref_mut() {
                let scale = (w / m_norm) as f32;
                let dr = &mut d[r * vocab..(r + 1) * vocab];
                if scale == 0.0 {
                    dr.fill(0.0);
                } else {
                    for (j, &v) in row.iter().enumerate() {
                        dr[j] = scale * ((v as f64 - lse).exp() as f32);
                    }
                    dr[tgt as usize] -= scale;
                }
            }
        }
        if any_mask {
            seq_with_mask += 1;
            if all_ok {
                seq_ok += 1;
            }
        }
    }
    Ok(EvalMetrics {
        loss: (loss / m_norm) as f32,
        token_acc: (correct / m_norm) as f32,
        seq_acc: (seq_ok as f64 / (seq_with_mask as f64).max(1.0)) as f32,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_logits_give_log_vocab() {
        let (b, t, v) = (2usize, 3usize, 8usize);
        let logits = vec![0.0f32; b * t * v];
        let targets = vec![1i32; b * t];
        let mask = vec![1.0f32; b * t];
        let m = masked_ce(&logits, &targets, &mask, b, t, v, None).unwrap();
        assert!((m.loss - (v as f32).ln()).abs() < 1e-6, "{}", m.loss);
        // argmax of a constant row is index 0 != target 1
        assert_eq!(m.token_acc, 0.0);
        assert_eq!(m.seq_acc, 0.0);
    }

    #[test]
    fn mask_selects_positions_and_grads_vanish_off_mask() {
        let (b, t, v) = (1usize, 2usize, 4usize);
        let logits = vec![5.0, 0.0, 0.0, 0.0, // row 0: confident class 0
                          0.0, 0.0, 9.0, 0.0]; // row 1: masked out
        let targets = vec![0i32, 1];
        let mask = vec![1.0f32, 0.0];
        let mut dl = Vec::new();
        let m = masked_ce(&logits, &targets, &mask, b, t, v,
                          Some(&mut dl)).unwrap();
        assert!(m.loss < 0.05, "{}", m.loss);
        assert_eq!(m.token_acc, 1.0);
        assert_eq!(m.seq_acc, 1.0);
        assert!(dl[v..].iter().all(|&g| g == 0.0),
                "masked-out row must get zero gradient: {dl:?}");
        // masked-in row: gradient sums to ~0 (softmax minus one-hot)
        let s: f32 = dl[..v].iter().sum();
        assert!(s.abs() < 1e-6, "{s}");
        assert!(dl[0] < 0.0, "target logit pushes up");
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let (b, t, v) = (2usize, 2usize, 5usize);
        let mut rng = crate::util::rng::Rng::new(3);
        let logits: Vec<f32> = (0..b * t * v)
            .map(|_| rng.normal_f32(0.0, 2.0)).collect();
        let targets: Vec<i32> = (0..b * t)
            .map(|_| rng.below(v as u64) as i32).collect();
        let mask = vec![1.0, 0.0, 1.0, 1.0];
        let mut dl = Vec::new();
        masked_ce(&logits, &targets, &mask, b, t, v, Some(&mut dl)).unwrap();
        let eps = 1e-3f32;
        for i in 0..logits.len() {
            let mut lp = logits.clone();
            lp[i] += eps;
            let mut lm = logits.clone();
            lm[i] -= eps;
            let fp = masked_ce(&lp, &targets, &mask, b, t, v, None)
                .unwrap().loss as f64;
            let fm = masked_ce(&lm, &targets, &mask, b, t, v, None)
                .unwrap().loss as f64;
            let fd = (fp - fm) / (2.0 * eps as f64);
            assert!((dl[i] as f64 - fd).abs() < 1e-3,
                    "dlogits[{i}] {} vs fd {fd}", dl[i]);
        }
    }

    #[test]
    fn rejects_out_of_vocab_targets() {
        let logits = vec![0.0f32; 4];
        assert!(masked_ce(&logits, &[4], &[1.0], 1, 1, 4, None).is_err());
        assert!(masked_ce(&logits, &[-1], &[1.0], 1, 1, 4, None).is_err());
    }
}
