//! Fused training heads for the native path — one per task family the
//! paper's benchmark suite uses:
//!
//! * [`masked_ce`] — masked softmax-cross-entropy over discrete targets
//!   (language modelling, Selective Copying, Chomsky transduction),
//!   mirroring `python/compile/tasks.py::masked_ce_loss` / `_metrics`;
//! * [`masked_mse`] — masked mean-squared error over continuous targets
//!   (Decision-Transformer-style action regression, Table 3), mirroring
//!   `tasks.py::masked_mse_loss`;
//! * [`seq_ce`] — sequence classification: mask-weighted mean pooling of
//!   the per-position logits followed by softmax-cross-entropy against one
//!   label per sequence (the LRA tasks of Tables 4/6; with the collate's
//!   single-CLS mask this reduces to final-position classification).
//!
//! For masked CE:
//!
//! ```text
//! loss      = Σ mask_rt · (logsumexp(logits_rt) - logits_rt[target_rt]) / M
//! dlogits   = mask_rt / M · (softmax(logits_rt) - onehot(target_rt))
//! token_acc = Σ mask · [argmax == target] / M
//! seq_acc   = fraction of masked sequences with every masked position right
//! ```
//!
//! with `M = max(Σ mask, 1)`.  In every head the per-row log-sum-exp and
//! the global reductions accumulate in f64 so the returned loss is stable
//! enough for finite-difference gradient checks; backward passes are fused
//! — softmaxes are never materialized separately from the gradient.

use std::fmt;

use anyhow::{anyhow, bail, Result};

use crate::runtime::EvalMetrics;

use super::linalg;

/// Which fused loss a [`super::NativeTrainer`] drives — the native
/// counterpart of the manifest's `task` string plus the pooled
/// classification refinement for the LRA workloads.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Head {
    /// Per-position softmax-CE over discrete targets under a mask.
    MaskedCe,
    /// Per-position squared error over continuous targets under a mask.
    MaskedMse,
    /// Mask-pooled softmax-CE: one class label per sequence.
    SeqClassify,
}

impl fmt::Display for Head {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Head::MaskedCe => "masked_ce",
            Head::MaskedMse => "masked_mse",
            Head::SeqClassify => "seq_classify",
        })
    }
}

/// Loss + metrics for `(batch, t, vocab)` logits against `(batch, t)` i32
/// targets under a `(batch, t)` f32 mask.  When `dlogits` is given it is
/// refitted to `batch * t * vocab` and receives the loss gradient.
pub fn masked_ce(logits: &[f32], targets: &[i32], mask: &[f32],
                 batch: usize, t: usize, vocab: usize,
                 mut dlogits: Option<&mut Vec<f32>>) -> Result<EvalMetrics> {
    let rows = batch * t;
    if logits.len() != rows * vocab {
        bail!("masked_ce: logits {} != {rows} x {vocab}", logits.len());
    }
    if targets.len() != rows || mask.len() != rows {
        bail!("masked_ce: targets/mask {} / {} != {rows}", targets.len(),
              mask.len());
    }
    if let Some(d) = dlogits.as_mut() {
        linalg::reuse(d, rows * vocab);
    }
    let msum: f64 = mask.iter().map(|&m| m as f64).sum();
    let m_norm = msum.max(1.0);

    let mut loss = 0.0f64;
    let mut correct = 0.0f64;
    let mut seq_ok = 0usize;
    let mut seq_with_mask = 0usize;
    for bi in 0..batch {
        let mut all_ok = true;
        let mut any_mask = false;
        for ti in 0..t {
            let r = bi * t + ti;
            let row = &logits[r * vocab..(r + 1) * vocab];
            let tgt = targets[r];
            if tgt < 0 || tgt as usize >= vocab {
                bail!("masked_ce: target {tgt} outside vocab {vocab} at \
                       (b={bi}, t={ti})");
            }
            let w = mask[r] as f64;
            // row max (also the greedy prediction for the accuracy metrics)
            let mut rmax = f64::NEG_INFINITY;
            let mut argmax = 0usize;
            for (j, &v) in row.iter().enumerate() {
                if (v as f64) > rmax {
                    rmax = v as f64;
                    argmax = j;
                }
            }
            let mut sum = 0.0f64;
            for &v in row {
                sum += (v as f64 - rmax).exp();
            }
            let lse = rmax + sum.ln();
            if w > 0.0 {
                any_mask = true;
                loss += w * (lse - row[tgt as usize] as f64);
                if argmax == tgt as usize {
                    correct += w;
                } else {
                    all_ok = false;
                }
            }
            if let Some(d) = dlogits.as_deref_mut() {
                let scale = (w / m_norm) as f32;
                let dr = &mut d[r * vocab..(r + 1) * vocab];
                if scale == 0.0 {
                    dr.fill(0.0);
                } else {
                    for (j, &v) in row.iter().enumerate() {
                        dr[j] = scale * ((v as f64 - lse).exp() as f32);
                    }
                    dr[tgt as usize] -= scale;
                }
            }
        }
        if any_mask {
            seq_with_mask += 1;
            if all_ok {
                seq_ok += 1;
            }
        }
    }
    Ok(EvalMetrics {
        loss: (loss / m_norm) as f32,
        token_acc: (correct / m_norm) as f32,
        seq_acc: (seq_ok as f64 / (seq_with_mask as f64).max(1.0)) as f32,
    })
}

/// Masked mean-squared error for `(batch, t, a_dim)` predictions against
/// same-shaped f32 targets under a `(batch, t)` mask (the RL regression
/// head):
///
/// ```text
/// loss  = Σ_rt mask_rt · Σ_a (pred_rta - tgt_rta)² / M
/// dpred = 2 · mask_rt / M · (pred_rta - tgt_rta)
/// ```
///
/// with `M = max(Σ mask, 1)`.  There is no discrete accuracy for a
/// regression head, so `token_acc`/`seq_acc` are 0 (matching the PJRT
/// `masked_mse` eval, which returns loss alone).
pub fn masked_mse(pred: &[f32], targets: &[f32], mask: &[f32],
                  batch: usize, t: usize, a_dim: usize,
                  mut dpred: Option<&mut Vec<f32>>) -> Result<EvalMetrics> {
    let rows = batch * t;
    if pred.len() != rows * a_dim {
        bail!("masked_mse: pred {} != {rows} x {a_dim}", pred.len());
    }
    if targets.len() != pred.len() || mask.len() != rows {
        bail!("masked_mse: targets/mask {} / {} != {} / {rows}",
              targets.len(), mask.len(), pred.len());
    }
    if let Some(d) = dpred.as_mut() {
        linalg::reuse(d, rows * a_dim);
    }
    let msum: f64 = mask.iter().map(|&m| m as f64).sum();
    let m_norm = msum.max(1.0);
    let mut loss = 0.0f64;
    for r in 0..rows {
        let w = mask[r] as f64;
        let pr = &pred[r * a_dim..(r + 1) * a_dim];
        let tr = &targets[r * a_dim..(r + 1) * a_dim];
        if w > 0.0 {
            let mut se = 0.0f64;
            for (&p, &tv) in pr.iter().zip(tr) {
                let e = p as f64 - tv as f64;
                se += e * e;
            }
            loss += w * se;
        }
        if let Some(d) = dpred.as_deref_mut() {
            let dr = &mut d[r * a_dim..(r + 1) * a_dim];
            let scale = (2.0 * w / m_norm) as f32;
            if scale == 0.0 {
                dr.fill(0.0);
            } else {
                for ((dv, &p), &tv) in dr.iter_mut().zip(pr).zip(tr) {
                    *dv = scale * (p - tv);
                }
            }
        }
    }
    Ok(EvalMetrics { loss: (loss / m_norm) as f32, token_acc: 0.0,
                     seq_acc: 0.0 })
}

/// Sequence classification: mask-weighted mean pooling of the per-position
/// logits, then softmax-CE against one label per sequence:
///
/// ```text
/// pool_bv   = Σ_t mask_bt · logits_btv / W_b      W_b = Σ_t mask_bt
/// loss      = Σ_b [W_b > 0] · CE(pool_b, label_b) / B_m
/// dlogits   = mask_bt / W_b · (softmax(pool_b) - onehot(label_b)) / B_m
/// ```
///
/// where `B_m` counts sequences with any masked position and `label_b` is
/// the target at the sequence's first masked position (the LRA collate
/// puts it on the CLS slot; every masked position must agree).  Both
/// `token_acc` and `seq_acc` report pooled classification accuracy.
pub fn seq_ce(logits: &[f32], targets: &[i32], mask: &[f32],
              batch: usize, t: usize, vocab: usize,
              mut dlogits: Option<&mut Vec<f32>>) -> Result<EvalMetrics> {
    let rows = batch * t;
    if logits.len() != rows * vocab {
        bail!("seq_ce: logits {} != {rows} x {vocab}", logits.len());
    }
    if targets.len() != rows || mask.len() != rows {
        bail!("seq_ce: targets/mask {} / {} != {rows}", targets.len(),
              mask.len());
    }
    if let Some(d) = dlogits.as_mut() {
        linalg::reuse(d, rows * vocab);
        d.iter_mut().for_each(|v| *v = 0.0);
    }
    // first pass: which sequences carry a mask (fixes the 1/B_m scale
    // before any gradient is written)
    let mut w_seq = vec![0.0f64; batch];
    let mut labels = vec![0i32; batch];
    let mut b_m = 0usize;
    for bi in 0..batch {
        let mut label: Option<i32> = None;
        for ti in 0..t {
            let r = bi * t + ti;
            if mask[r] > 0.0 {
                w_seq[bi] += mask[r] as f64;
                let tgt = targets[r];
                if tgt < 0 || tgt as usize >= vocab {
                    bail!("seq_ce: target {tgt} outside {vocab} classes at \
                           (b={bi}, t={ti})");
                }
                match label {
                    None => label = Some(tgt),
                    Some(l) if l != tgt => bail!(
                        "seq_ce: sequence {bi} has conflicting labels \
                         {l} and {tgt} on masked positions"),
                    _ => {}
                }
            }
        }
        if let Some(l) = label {
            labels[bi] = l;
            b_m += 1;
        }
    }
    let b_norm = (b_m as f64).max(1.0);

    let mut loss = 0.0f64;
    let mut correct = 0usize;
    let mut pool = vec![0.0f64; vocab];
    let mut soft = vec![0.0f32; vocab];
    for bi in 0..batch {
        if w_seq[bi] <= 0.0 {
            continue;
        }
        pool.iter_mut().for_each(|v| *v = 0.0);
        for ti in 0..t {
            let r = bi * t + ti;
            let w = mask[r] as f64 / w_seq[bi];
            if w > 0.0 {
                let row = &logits[r * vocab..(r + 1) * vocab];
                for (p, &l) in pool.iter_mut().zip(row) {
                    *p += w * l as f64;
                }
            }
        }
        let label = labels[bi] as usize;
        let mut pmax = f64::NEG_INFINITY;
        let mut argmax = 0usize;
        for (j, &p) in pool.iter().enumerate() {
            if p > pmax {
                pmax = p;
                argmax = j;
            }
        }
        let sum: f64 = pool.iter().map(|&p| (p - pmax).exp()).sum();
        let lse = pmax + sum.ln();
        loss += lse - pool[label];
        if argmax == label {
            correct += 1;
        }
        if let Some(d) = dlogits.as_deref_mut() {
            // softmax(pool) − onehot(label) is shared by every masked
            // position of the sequence; compute it once
            for (j, s) in soft.iter_mut().enumerate() {
                let one = if j == label { 1.0 } else { 0.0 };
                *s = ((pool[j] - lse).exp() - one) as f32;
            }
            for ti in 0..t {
                let r = bi * t + ti;
                let w = (mask[r] as f64 / (w_seq[bi] * b_norm)) as f32;
                if w <= 0.0 {
                    continue;
                }
                let dr = &mut d[r * vocab..(r + 1) * vocab];
                for (dv, &s) in dr.iter_mut().zip(&soft) {
                    *dv = w * s;
                }
            }
        }
    }
    let acc = (correct as f64 / b_norm) as f32;
    Ok(EvalMetrics { loss: (loss / b_norm) as f32, token_acc: acc,
                     seq_acc: acc })
}

/// Dispatch `head` on a `(logits, batch)` pair, with the dtype/shape
/// checks phrased as actionable errors (the up-front workload validation
/// in `coordinator` should make these unreachable from the CLI).
#[allow(clippy::too_many_arguments)]
pub fn apply_head(head: Head, logits: &[f32],
                  targets: &crate::tensor::Tensor, mask: &[f32],
                  batch: usize, t: usize, out_dim: usize,
                  dlogits: Option<&mut Vec<f32>>) -> Result<EvalMetrics> {
    match head {
        Head::MaskedCe | Head::SeqClassify => {
            let tg = targets.data.as_i32().ok_or_else(|| anyhow!(
                "{head} head needs i32 targets; this batch has {} targets \
                 — the workload belongs to the masked_mse (regression) \
                 head", targets.dtype_name()))?;
            match head {
                Head::MaskedCe =>
                    masked_ce(logits, tg, mask, batch, t, out_dim, dlogits),
                _ => seq_ce(logits, tg, mask, batch, t, out_dim, dlogits),
            }
        }
        Head::MaskedMse => {
            let tg = targets.data.as_f32().ok_or_else(|| anyhow!(
                "masked_mse head needs f32 targets; this batch has {} \
                 targets — the workload belongs to a discrete \
                 (cross-entropy) head", targets.dtype_name()))?;
            let a = targets.dims.get(2).copied().unwrap_or(1);
            if a != out_dim {
                bail!("masked_mse: batch regresses {a}-dim actions but the \
                       model head is {out_dim}-dim");
            }
            masked_mse(logits, tg, mask, batch, t, out_dim, dlogits)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_logits_give_log_vocab() {
        let (b, t, v) = (2usize, 3usize, 8usize);
        let logits = vec![0.0f32; b * t * v];
        let targets = vec![1i32; b * t];
        let mask = vec![1.0f32; b * t];
        let m = masked_ce(&logits, &targets, &mask, b, t, v, None).unwrap();
        assert!((m.loss - (v as f32).ln()).abs() < 1e-6, "{}", m.loss);
        // argmax of a constant row is index 0 != target 1
        assert_eq!(m.token_acc, 0.0);
        assert_eq!(m.seq_acc, 0.0);
    }

    #[test]
    fn mask_selects_positions_and_grads_vanish_off_mask() {
        let (b, t, v) = (1usize, 2usize, 4usize);
        let logits = vec![5.0, 0.0, 0.0, 0.0, // row 0: confident class 0
                          0.0, 0.0, 9.0, 0.0]; // row 1: masked out
        let targets = vec![0i32, 1];
        let mask = vec![1.0f32, 0.0];
        let mut dl = Vec::new();
        let m = masked_ce(&logits, &targets, &mask, b, t, v,
                          Some(&mut dl)).unwrap();
        assert!(m.loss < 0.05, "{}", m.loss);
        assert_eq!(m.token_acc, 1.0);
        assert_eq!(m.seq_acc, 1.0);
        assert!(dl[v..].iter().all(|&g| g == 0.0),
                "masked-out row must get zero gradient: {dl:?}");
        // masked-in row: gradient sums to ~0 (softmax minus one-hot)
        let s: f32 = dl[..v].iter().sum();
        assert!(s.abs() < 1e-6, "{s}");
        assert!(dl[0] < 0.0, "target logit pushes up");
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let (b, t, v) = (2usize, 2usize, 5usize);
        let mut rng = crate::util::rng::Rng::new(3);
        let logits: Vec<f32> = (0..b * t * v)
            .map(|_| rng.normal_f32(0.0, 2.0)).collect();
        let targets: Vec<i32> = (0..b * t)
            .map(|_| rng.below(v as u64) as i32).collect();
        let mask = vec![1.0, 0.0, 1.0, 1.0];
        let mut dl = Vec::new();
        masked_ce(&logits, &targets, &mask, b, t, v, Some(&mut dl)).unwrap();
        let eps = 1e-3f32;
        for i in 0..logits.len() {
            let mut lp = logits.clone();
            lp[i] += eps;
            let mut lm = logits.clone();
            lm[i] -= eps;
            let fp = masked_ce(&lp, &targets, &mask, b, t, v, None)
                .unwrap().loss as f64;
            let fm = masked_ce(&lm, &targets, &mask, b, t, v, None)
                .unwrap().loss as f64;
            let fd = (fp - fm) / (2.0 * eps as f64);
            assert!((dl[i] as f64 - fd).abs() < 1e-3,
                    "dlogits[{i}] {} vs fd {fd}", dl[i]);
        }
    }

    #[test]
    fn rejects_out_of_vocab_targets() {
        let logits = vec![0.0f32; 4];
        assert!(masked_ce(&logits, &[4], &[1.0], 1, 1, 4, None).is_err());
        assert!(masked_ce(&logits, &[-1], &[1.0], 1, 1, 4, None).is_err());
        assert!(seq_ce(&logits, &[4], &[1.0], 1, 1, 4, None).is_err());
        assert!(seq_ce(&logits, &[-1], &[1.0], 1, 1, 4, None).is_err());
    }

    #[test]
    fn mse_loss_and_gradient_match_finite_differences() {
        let (b, t, a) = (2usize, 3usize, 2usize);
        let mut rng = crate::util::rng::Rng::new(11);
        let pred: Vec<f32> = (0..b * t * a)
            .map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let tgt: Vec<f32> = (0..b * t * a)
            .map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mask = vec![1.0, 0.5, 0.0, 1.0, 1.0, 0.0];
        let mut dp = Vec::new();
        let m = masked_mse(&pred, &tgt, &mask, b, t, a,
                           Some(&mut dp)).unwrap();
        assert!(m.loss > 0.0 && m.loss.is_finite());
        assert_eq!(m.token_acc, 0.0);
        // masked-out rows (t=2 of seq 0, t=2 of seq 1) get zero gradient
        assert!(dp[2 * a..3 * a].iter().all(|&g| g == 0.0));
        let eps = 1e-3f32;
        for i in 0..pred.len() {
            let mut pp = pred.clone();
            pp[i] += eps;
            let mut pm = pred.clone();
            pm[i] -= eps;
            let fp = masked_mse(&pp, &tgt, &mask, b, t, a, None)
                .unwrap().loss as f64;
            let fm = masked_mse(&pm, &tgt, &mask, b, t, a, None)
                .unwrap().loss as f64;
            let fd = (fp - fm) / (2.0 * eps as f64);
            assert!((dp[i] as f64 - fd).abs() < 1e-3,
                    "dpred[{i}] {} vs fd {fd}", dp[i]);
        }
    }

    #[test]
    fn mse_zero_error_is_zero_loss() {
        let pred = vec![0.3f32, -0.7, 1.1, 0.0];
        let m = masked_mse(&pred, &pred, &[1.0, 1.0], 1, 2, 2, None)
            .unwrap();
        assert_eq!(m.loss, 0.0);
    }

    #[test]
    fn seq_ce_single_cls_mask_matches_masked_ce_loss() {
        // with exactly one masked position per sequence and Σ mask = B_m,
        // pooling degenerates to that position and both heads agree on the
        // loss (masked_ce averages over positions, seq_ce over sequences —
        // equal weights here)
        let (b, t, v) = (3usize, 4usize, 5usize);
        let mut rng = crate::util::rng::Rng::new(5);
        let logits: Vec<f32> = (0..b * t * v)
            .map(|_| rng.normal_f32(0.0, 2.0)).collect();
        let mut targets = vec![0i32; b * t];
        let mut mask = vec![0.0f32; b * t];
        for bi in 0..b {
            let r = bi * t + t - 1;
            mask[r] = 1.0;
            targets[r] = rng.below(v as u64) as i32;
        }
        let mut d_pool = Vec::new();
        let a = seq_ce(&logits, &targets, &mask, b, t, v,
                       Some(&mut d_pool)).unwrap();
        let mut d_ce = Vec::new();
        let c = masked_ce(&logits, &targets, &mask, b, t, v,
                          Some(&mut d_ce)).unwrap();
        assert!((a.loss - c.loss).abs() < 1e-5, "{} vs {}", a.loss, c.loss);
        for (x, y) in d_pool.iter().zip(&d_ce) {
            assert!((x - y).abs() < 1e-6, "{x} vs {y}");
        }
    }

    #[test]
    fn seq_ce_gradient_matches_finite_differences_with_pooling() {
        // genuinely pooled: several masked positions per sequence
        let (b, t, v) = (2usize, 3usize, 4usize);
        let mut rng = crate::util::rng::Rng::new(9);
        let logits: Vec<f32> = (0..b * t * v)
            .map(|_| rng.normal_f32(0.0, 1.5)).collect();
        let targets = vec![2i32, 2, 2, 1, 1, 1];
        let mask = vec![1.0f32, 0.5, 0.0, 0.25, 1.0, 1.0];
        let mut dl = Vec::new();
        seq_ce(&logits, &targets, &mask, b, t, v, Some(&mut dl)).unwrap();
        let eps = 1e-3f32;
        for i in 0..logits.len() {
            let mut lp = logits.clone();
            lp[i] += eps;
            let mut lm = logits.clone();
            lm[i] -= eps;
            let fp = seq_ce(&lp, &targets, &mask, b, t, v, None)
                .unwrap().loss as f64;
            let fm = seq_ce(&lm, &targets, &mask, b, t, v, None)
                .unwrap().loss as f64;
            let fd = (fp - fm) / (2.0 * eps as f64);
            assert!((dl[i] as f64 - fd).abs() < 1e-3,
                    "dlogits[{i}] {} vs fd {fd}", dl[i]);
        }
    }

    #[test]
    fn seq_ce_rejects_conflicting_labels_and_skips_unmasked() {
        let logits = vec![0.0f32; 8];
        // two masked positions with different labels: ambiguous example
        assert!(seq_ce(&logits, &[0, 1], &[1.0, 1.0], 1, 2, 4, None)
                .is_err());
        // a fully unmasked sequence contributes nothing (loss over B_m=1)
        let l2 = vec![0.0f32; 16];
        let m = seq_ce(&l2, &[1, 0, 0, 0], &[1.0, 0.0, 0.0, 0.0], 2, 2, 4,
                       None).unwrap();
        assert!((m.loss - (4.0f32).ln()).abs() < 1e-6);
    }

    #[test]
    fn apply_head_rejects_dtype_mismatch_with_clear_error() {
        use crate::tensor::Tensor;
        let logits = vec![0.0f32; 4];
        let mask = vec![1.0f32];
        let cont = Tensor::f32(vec![1, 1, 4], vec![0.0; 4]);
        let disc = Tensor::i32(vec![1, 1], vec![1]);
        let e = apply_head(Head::MaskedCe, &logits, &cont, &mask, 1, 1, 4,
                           None).unwrap_err();
        assert!(e.to_string().contains("masked_mse"), "{e}");
        let e = apply_head(Head::MaskedMse, &logits, &disc, &mask, 1, 1, 4,
                           None).unwrap_err();
        assert!(e.to_string().contains("cross-entropy"), "{e}");
        // and the happy paths dispatch
        assert!(apply_head(Head::MaskedMse, &logits, &cont, &mask, 1, 1, 4,
                           None).is_ok());
        assert!(apply_head(Head::SeqClassify, &logits, &disc, &mask, 1, 1,
                           4, None).is_ok());
    }
}
