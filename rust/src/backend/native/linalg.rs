//! Dense/normalization/activation primitives for the native CPU backend.
//!
//! Everything operates on flat row-major `f32` slices with explicit shapes,
//! mirroring the JAX reference in `python/compile/models/layers.py`:
//! weights are `(d_in, d_out)` row-major, biases `(d_out,)`, activations
//! match the `jax.nn` definitions bit-for-bit up to libm rounding.

use anyhow::{bail, Result};

// ---------------------------------------------------------------------------
// scalar activations
// ---------------------------------------------------------------------------

#[inline]
pub fn sigmoid(x: f32) -> f32 {
    if x >= 0.0 {
        1.0 / (1.0 + (-x).exp())
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

/// Numerically stable `ln(1 + e^x)`.
#[inline]
pub fn softplus(x: f32) -> f32 {
    x.max(0.0) + (-x.abs()).exp().ln_1p()
}

/// `g(x) = x + 0.5` for `x >= 0` else `sigmoid(x)` — the positivity
/// activation of Appendix B (Listing 6).
#[inline]
pub fn g(x: f32) -> f32 {
    if x >= 0.0 {
        x + 0.5
    } else {
        sigmoid(x)
    }
}

/// `log(g(x))` computed stably (Listing 6).
#[inline]
pub fn log_g(x: f32) -> f32 {
    if x >= 0.0 {
        (x + 0.5).ln()
    } else {
        -softplus(-x)
    }
}

#[inline]
pub fn silu(x: f32) -> f32 {
    x * sigmoid(x)
}

/// Tanh-approximate GELU — `jax.nn.gelu`'s default (`approximate=True`).
#[inline]
pub fn gelu(x: f32) -> f32 {
    const SQRT_2_OVER_PI: f32 = 0.797_884_56;
    0.5 * x * (1.0 + (SQRT_2_OVER_PI * (x + 0.044_715 * x * x * x)).tanh())
}

/// Stable `log(e^a + e^b)` in f64 (the scan accumulates in f64).
#[inline]
pub fn logaddexp(a: f64, b: f64) -> f64 {
    if a == f64::NEG_INFINITY {
        return b;
    }
    if b == f64::NEG_INFINITY {
        return a;
    }
    let m = a.max(b);
    m + ((a - m).exp() + (b - m).exp()).ln()
}

/// Elementwise `dst += src`.
#[inline]
pub fn add_assign(dst: &mut [f32], src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    for (d, s) in dst.iter_mut().zip(src) {
        *d += *s;
    }
}

// ---------------------------------------------------------------------------
// dense / embedding
// ---------------------------------------------------------------------------

/// Affine layer `y = x @ w + b`, `w: (d_in, d_out)` row-major.
#[derive(Clone, Debug)]
pub struct Dense {
    pub d_in: usize,
    pub d_out: usize,
    pub w: Vec<f32>,
    pub b: Vec<f32>,
}

impl Dense {
    pub fn new(d_in: usize, d_out: usize, w: Vec<f32>, b: Vec<f32>)
               -> Result<Dense> {
        if w.len() != d_in * d_out || b.len() != d_out {
            bail!("dense shape mismatch: w {} != {}x{}, b {} != {}",
                  w.len(), d_in, d_out, b.len(), d_out);
        }
        Ok(Dense { d_in, d_out, w, b })
    }

    /// Apply to `rows` rows of `d_in` features; returns `rows * d_out`.
    pub fn apply(&self, x: &[f32], rows: usize) -> Vec<f32> {
        assert_eq!(x.len(), rows * self.d_in,
                   "dense input: {} != {} rows x {}", x.len(), rows,
                   self.d_in);
        let mut y = vec![0.0f32; rows * self.d_out];
        for r in 0..rows {
            let xr = &x[r * self.d_in..(r + 1) * self.d_in];
            let yr = &mut y[r * self.d_out..(r + 1) * self.d_out];
            yr.copy_from_slice(&self.b);
            for (k, &xv) in xr.iter().enumerate() {
                let wrow = &self.w[k * self.d_out..(k + 1) * self.d_out];
                for (yo, &wv) in yr.iter_mut().zip(wrow) {
                    *yo += xv * wv;
                }
            }
        }
        y
    }
}

/// Token embedding table `(vocab, d)`.
#[derive(Clone, Debug)]
pub struct Embedding {
    pub vocab: usize,
    pub d: usize,
    pub w: Vec<f32>,
}

impl Embedding {
    pub fn new(vocab: usize, d: usize, w: Vec<f32>) -> Result<Embedding> {
        if w.len() != vocab * d {
            bail!("embedding shape mismatch: {} != {}x{}", w.len(), vocab, d);
        }
        Ok(Embedding { vocab, d, w })
    }

    /// Gather rows; out-of-range ids clamp (like `jnp.take` under jit).
    pub fn lookup(&self, ids: &[i32]) -> Vec<f32> {
        let mut out = vec![0.0f32; ids.len() * self.d];
        for (r, &id) in ids.iter().enumerate() {
            let row = (id.max(0) as usize).min(self.vocab - 1);
            out[r * self.d..(r + 1) * self.d]
                .copy_from_slice(&self.w[row * self.d..(row + 1) * self.d]);
        }
        out
    }
}

// ---------------------------------------------------------------------------
// RMSNorm
// ---------------------------------------------------------------------------

/// `x * rsqrt(mean(x^2) + 1e-6) * scale`, normalized over the last dim.
pub fn rmsnorm(x: &[f32], scale: &[f32], rows: usize, d: usize) -> Vec<f32> {
    assert_eq!(x.len(), rows * d, "rmsnorm input");
    assert_eq!(scale.len(), d, "rmsnorm scale");
    let mut y = vec![0.0f32; rows * d];
    for r in 0..rows {
        let xr = &x[r * d..(r + 1) * d];
        let ms = xr.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let inv = 1.0 / (ms + 1e-6).sqrt();
        let yr = &mut y[r * d..(r + 1) * d];
        for i in 0..d {
            yr[i] = xr[i] * inv * scale[i];
        }
    }
    y
}

// ---------------------------------------------------------------------------
// temporal depthwise causal conv (kernel 4), parallel + ring-buffer step
// ---------------------------------------------------------------------------

pub const CONV_K: usize = 4;

/// Depthwise causal conv over time with SiLU, `w: (k, d)` row-major.
#[derive(Clone, Debug)]
pub struct Conv4 {
    pub k: usize,
    pub d: usize,
    pub w: Vec<f32>,
    pub b: Vec<f32>,
}

impl Conv4 {
    pub fn new(k: usize, d: usize, w: Vec<f32>, b: Vec<f32>) -> Result<Conv4> {
        if w.len() != k * d || b.len() != d {
            bail!("conv shape mismatch: w {} != {}x{}, b {} != {}",
                  w.len(), k, d, b.len(), d);
        }
        Ok(Conv4 { k, d, w, b })
    }

    /// Parallel mode over `(B, T, D)`:
    /// `y_t = silu(b + sum_j w_j * x_(t-k+1+j))`, zero padding on the left.
    pub fn parallel(&self, x: &[f32], batch: usize, t: usize) -> Vec<f32> {
        let d = self.d;
        assert_eq!(x.len(), batch * t * d, "conv input");
        let mut y = vec![0.0f32; batch * t * d];
        for bi in 0..batch {
            for ti in 0..t {
                let yo = (bi * t + ti) * d;
                for di in 0..d {
                    let mut acc = self.b[di];
                    for j in 0..self.k {
                        let src = ti as isize + j as isize
                            - (self.k as isize - 1);
                        if src >= 0 {
                            acc += self.w[j * d + di]
                                * x[(bi * t + src as usize) * d + di];
                        }
                    }
                    y[yo + di] = silu(acc);
                }
            }
        }
        y
    }

    /// The `(B, k-1, D)` buffer a parallel pass leaves behind: the last
    /// `k-1` raw inputs (zero-padded when `T < k-1`).
    pub fn final_state(&self, x: &[f32], batch: usize, t: usize) -> Vec<f32> {
        let d = self.d;
        let km1 = self.k - 1;
        let mut st = vec![0.0f32; batch * km1 * d];
        for bi in 0..batch {
            for j in 0..km1 {
                // buffer slot j holds x at time T - (k-1) + j
                let src = t as isize - km1 as isize + j as isize;
                if src >= 0 {
                    let from = (bi * t + src as usize) * d;
                    let to = (bi * km1 + j) * d;
                    st[to..to + d].copy_from_slice(&x[from..from + d]);
                }
            }
        }
        st
    }

    /// Fresh zero ring buffer for `batch` lanes.
    pub fn zero_state(&self, batch: usize) -> Vec<f32> {
        vec![0.0f32; batch * (self.k - 1) * self.d]
    }

    /// Step mode: consumes `x_t: (B, D)`, returns `y_t` and shifts the
    /// ring buffer `buf: (B, k-1, D)` in place.
    pub fn step(&self, buf: &mut [f32], x_t: &[f32], batch: usize)
                -> Vec<f32> {
        let d = self.d;
        let km1 = self.k - 1;
        assert_eq!(buf.len(), batch * km1 * d, "conv buffer");
        assert_eq!(x_t.len(), batch * d, "conv step input");
        let mut y = vec![0.0f32; batch * d];
        for bi in 0..batch {
            for di in 0..d {
                let mut acc = self.b[di] + self.w[km1 * d + di]
                    * x_t[bi * d + di];
                for j in 0..km1 {
                    acc += self.w[j * d + di] * buf[(bi * km1 + j) * d + di];
                }
                y[bi * d + di] = silu(acc);
            }
            // shift: drop the oldest slot, append x_t
            for j in 0..km1 - 1 {
                let (to, from) = ((bi * km1 + j) * d, (bi * km1 + j + 1) * d);
                buf.copy_within(from..from + d, to);
            }
            let last = (bi * km1 + km1 - 1) * d;
            buf[last..last + d].copy_from_slice(&x_t[bi * d..(bi + 1) * d]);
        }
        y
    }
}

// ---------------------------------------------------------------------------
// MLP block
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
pub struct Mlp {
    pub up: Dense,
    pub down: Dense,
}

impl Mlp {
    pub fn apply(&self, x: &[f32], rows: usize) -> Vec<f32> {
        let mut h = self.up.apply(x, rows);
        for v in h.iter_mut() {
            *v = gelu(*v);
        }
        self.down.apply(&h, rows)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_matches_hand_computation() {
        // w = [[1, 2], [3, 4]], b = [10, 20]; x = [1, 1] → [14, 26]
        let d = Dense::new(2, 2, vec![1.0, 2.0, 3.0, 4.0],
                           vec![10.0, 20.0]).unwrap();
        assert_eq!(d.apply(&[1.0, 1.0], 1), vec![14.0, 26.0]);
        assert!(Dense::new(2, 2, vec![0.0; 3], vec![0.0; 2]).is_err());
    }

    #[test]
    fn activations_sane() {
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-7);
        assert!((softplus(0.0) - std::f32::consts::LN_2).abs() < 1e-6);
        assert!((g(0.0) - 0.5).abs() < 1e-7);
        assert!((g(1.5) - 2.0).abs() < 1e-7);
        assert!((log_g(1.5) - 2.0f32.ln()).abs() < 1e-6);
        // continuity of g at 0 from below
        assert!((g(-1e-4) - 0.5).abs() < 1e-4);
        // logaddexp basics
        assert!((logaddexp(0.0, 0.0) - std::f64::consts::LN_2).abs() < 1e-12);
        assert_eq!(logaddexp(f64::NEG_INFINITY, 3.0), 3.0);
    }

    #[test]
    fn rmsnorm_unit_rows() {
        let y = rmsnorm(&[3.0, 4.0], &[1.0, 1.0], 1, 2);
        // rms = sqrt((9 + 16) / 2) = 3.5355
        assert!((y[0] - 3.0 / 3.535_534).abs() < 1e-5, "{y:?}");
        assert!((y[1] - 4.0 / 3.535_534).abs() < 1e-5, "{y:?}");
    }

    #[test]
    fn conv_step_matches_parallel() {
        let mut rng = crate::util::rng::Rng::new(11);
        let (b, t, d) = (2usize, 7usize, 3usize);
        let conv = Conv4::new(CONV_K, d,
                              (0..CONV_K * d).map(|_| rng.normal_f32(0.0, 0.5))
                                  .collect(),
                              (0..d).map(|_| rng.normal_f32(0.0, 0.1))
                                  .collect()).unwrap();
        let x: Vec<f32> = (0..b * t * d).map(|_| rng.normal_f32(0.0, 1.0))
            .collect();
        let par = conv.parallel(&x, b, t);
        let mut buf = conv.zero_state(b);
        for ti in 0..t {
            // gather x_t rows
            let mut xt = vec![0.0f32; b * d];
            for bi in 0..b {
                xt[bi * d..(bi + 1) * d].copy_from_slice(
                    &x[(bi * t + ti) * d..(bi * t + ti + 1) * d]);
            }
            let y = conv.step(&mut buf, &xt, b);
            for bi in 0..b {
                for di in 0..d {
                    let p = par[(bi * t + ti) * d + di];
                    let s = y[bi * d + di];
                    assert!((p - s).abs() < 1e-5,
                            "t={ti} b={bi} d={di}: {p} vs {s}");
                }
            }
        }
        // buffer after the full pass equals the parallel final state
        let fs = conv.final_state(&x, b, t);
        for (a, c) in buf.iter().zip(&fs) {
            assert!((a - c).abs() < 1e-6);
        }
    }
}
