//! Dense/normalization/activation primitives for the native CPU backend.
//!
//! Everything operates on flat row-major `f32` slices with explicit shapes,
//! mirroring the JAX reference in `python/compile/models/layers.py`:
//! weights are `(d_in, d_out)` row-major, biases `(d_out,)`, activations
//! match the `jax.nn` definitions bit-for-bit up to libm rounding.
//!
//! Hot paths come in two flavors: allocating wrappers (the PR-1 API, kept
//! for tests and casual callers) and `*_into` variants that reuse caller
//! scratch buffers and fan work out across a [`ThreadPool`] —
//! steady-state decode through [`super::model::NativeModel::step`] touches
//! the allocator only for the returned logits tensor.
//!
//! [`Dense::apply`] is a cache/register-blocked tiled GEMM: output columns
//! are processed in register tiles of [`N_TILE`] accumulators so the
//! `(d_in, N_TILE)` weight slab stays hot in L1 across a row block, and
//! the inner update `acc[j] += x[k] * w[k][j]` vectorizes across the tile
//! without reassociating any float sum.  (A transposed-weight dot-product
//! kernel was tried first; under strict IEEE semantics its k-reduction
//! cannot vectorize without changing the summation order, so the
//! broadcast-tile form won.)  The register tile now runs through the
//! dispatched lane kernels in [`crate::util::simd`] — explicit AVX2
//! broadcast-multiply-add across the 16 accumulators, with a scalar twin
//! that performs the identical per-lane op sequence, so f32 results stay
//! bit-for-bit identical across dispatch levels.  A [`Dense`] may also
//! carry per-tile-scaled int8 weights (`q`, see
//! [`super::quant`]); the tile kernel then dequantizes inside the
//! register tile, halving weight bandwidth on the decode hot path.
//! Per-`(row, column)` summation order is k-ascending with the bias folded
//! in first, identical to the naive loop and independent of blocking and
//! thread count, so results are bit-for-bit reproducible.

use anyhow::{bail, Result};

use crate::util::simd::{self, Level};
use crate::util::threads::{self, SlicePtr, ThreadPool};

use super::quant::QuantDense;

/// Output-column register tile of the GEMM micro-kernel.
pub const N_TILE: usize = 16;
/// Rows per parallel task (large-row shapes, e.g. prefill).
const ROW_BLOCK: usize = 32;
/// Output columns per parallel task (small-row shapes, e.g. decode).
const COL_BLOCK: usize = 64;
/// Below this many multiply-adds a GEMM runs inline on the caller.
const PAR_MIN_MACS: usize = 1 << 15;
/// Elementwise maps fan out in chunks of this many elements.
const MAP_CHUNK: usize = 1 << 12;
/// Below this many elements an elementwise map runs inline.
const PAR_MIN_MAP: usize = 1 << 14;

// ---------------------------------------------------------------------------
// scalar activations
// ---------------------------------------------------------------------------

#[inline]
pub fn sigmoid(x: f32) -> f32 {
    if x >= 0.0 {
        1.0 / (1.0 + (-x).exp())
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

/// Numerically stable `ln(1 + e^x)`.
#[inline]
pub fn softplus(x: f32) -> f32 {
    x.max(0.0) + (-x.abs()).exp().ln_1p()
}

/// `g(x) = x + 0.5` for `x >= 0` else `sigmoid(x)` — the positivity
/// activation of Appendix B (Listing 6).
#[inline]
pub fn g(x: f32) -> f32 {
    if x >= 0.0 {
        x + 0.5
    } else {
        sigmoid(x)
    }
}

/// `log(g(x))` computed stably (Listing 6).
#[inline]
pub fn log_g(x: f32) -> f32 {
    if x >= 0.0 {
        (x + 0.5).ln()
    } else {
        -softplus(-x)
    }
}

/// `d g(x) / dx` (see [`g`]): 1 above zero, `σ'(x)` below.
#[inline]
pub fn g_grad(x: f32) -> f32 {
    if x >= 0.0 {
        1.0
    } else {
        let s = sigmoid(x);
        s * (1.0 - s)
    }
}

#[inline]
pub fn silu(x: f32) -> f32 {
    x * sigmoid(x)
}

/// `d silu(x) / dx = σ(x) (1 + x (1 - σ(x)))`.
#[inline]
pub fn silu_grad(x: f32) -> f32 {
    let s = sigmoid(x);
    s * (1.0 + x * (1.0 - s))
}

/// Tanh-approximate GELU — `jax.nn.gelu`'s default (`approximate=True`).
#[inline]
pub fn gelu(x: f32) -> f32 {
    const SQRT_2_OVER_PI: f32 = 0.797_884_56;
    0.5 * x * (1.0 + (SQRT_2_OVER_PI * (x + 0.044_715 * x * x * x)).tanh())
}

/// `d gelu(x) / dx` for the tanh approximation (see [`gelu`]).
#[inline]
pub fn gelu_grad(x: f32) -> f32 {
    const SQRT_2_OVER_PI: f32 = 0.797_884_56;
    const C3: f32 = 0.044_715;
    let inner = SQRT_2_OVER_PI * (x + C3 * x * x * x);
    let t = inner.tanh();
    0.5 * (1.0 + t)
        + 0.5 * x * (1.0 - t * t) * SQRT_2_OVER_PI * (1.0 + 3.0 * C3 * x * x)
}

/// Stable `log(e^a + e^b)` in f64 (reference scan accumulation).
#[inline]
pub fn logaddexp(a: f64, b: f64) -> f64 {
    if a == f64::NEG_INFINITY {
        return b;
    }
    if b == f64::NEG_INFINITY {
        return a;
    }
    let m = a.max(b);
    m + ((a - m).exp() + (b - m).exp()).ln()
}

/// Stable `log(e^a + e^b)` with f64 carriers but the transcendentals in
/// f32 — the chunked scan's fast path.  `max + ln1p(exp(-|a - b|))` needs
/// one `expf` + one `log1pf` against the reference's two f64 `exp` + one
/// f64 `ln`, and because the f32 rounding only touches the *correction*
/// term (≤ ln 2, absolute error ~1e-7) while the running maximum stays
/// f64, accumulators keep full absolute precision even when the scan's
/// `A*` prefix drifts to ±10³ (a pure-f32 accumulator loses ~|p|·6e-8
/// there and measurably fails the a→0 gate oracle — verified against the
/// golden vectors at 1e-5 relative).
#[inline]
pub fn logaddexp_fast(a: f64, b: f64) -> f64 {
    if a == f64::NEG_INFINITY {
        return b;
    }
    if b == f64::NEG_INFINITY {
        return a;
    }
    let m = a.max(b);
    m + ((-(a - b).abs()) as f32).exp().ln_1p() as f64
}

/// Fully-f32 stable `log(e^a + e^b)`, for contexts whose operands are
/// already f32-bounded (unlike the scan accumulators — see
/// [`logaddexp_fast`]).
#[inline]
pub fn logaddexp_f32(a: f32, b: f32) -> f32 {
    if a == f32::NEG_INFINITY {
        return b;
    }
    if b == f32::NEG_INFINITY {
        return a;
    }
    let m = a.max(b);
    m + ((a - m).exp() + (b - m).exp()).ln()
}

/// Elementwise `dst += src`.
#[inline]
pub fn add_assign(dst: &mut [f32], src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    for (d, s) in dst.iter_mut().zip(src) {
        *d += *s;
    }
}

/// Refit a scratch buffer to `n` elements without reallocating once warm.
/// A warm buffer (`len == n`) is untouched — no redundant zero-fill pass —
/// which is sound because every kernel writing through a reused buffer
/// overwrites all `n` positions.
#[inline]
pub fn reuse(buf: &mut Vec<f32>, n: usize) {
    if buf.len() != n {
        buf.clear();
        buf.resize(n, 0.0);
    }
}

// ---------------------------------------------------------------------------
// dense / embedding
// ---------------------------------------------------------------------------

/// Affine layer `y = x @ w + b`, `w: (d_in, d_out)` row-major.
///
/// When `q` is set the layer is inference-only: `w` is empty and the
/// weights live as per-tile-scaled int8 in [`QuantDense`], dequantized
/// inside the register tile (see [`super::quant`]).  The bias stays f32.
#[derive(Clone, Debug)]
pub struct Dense {
    pub d_in: usize,
    pub d_out: usize,
    pub w: Vec<f32>,
    pub b: Vec<f32>,
    pub q: Option<QuantDense>,
}

impl Dense {
    pub fn new(d_in: usize, d_out: usize, w: Vec<f32>, b: Vec<f32>)
               -> Result<Dense> {
        if w.len() != d_in * d_out || b.len() != d_out {
            bail!("dense shape mismatch: w {} != {}x{}, b {} != {}",
                  w.len(), d_in, d_out, b.len(), d_out);
        }
        Ok(Dense { d_in, d_out, w, b, q: None })
    }

    /// Apply to `rows` rows of `d_in` features; returns `rows * d_out`.
    /// Allocating wrapper over [`Dense::apply_pool_into`] on the global
    /// pool.
    pub fn apply(&self, x: &[f32], rows: usize) -> Vec<f32> {
        self.apply_pool(threads::global(), x, rows)
    }

    /// [`Dense::apply`] on an explicit pool (tests pin thread-count
    /// invariance through this entry point).
    pub fn apply_pool(&self, pool: &ThreadPool, x: &[f32], rows: usize)
                      -> Vec<f32> {
        let mut y = Vec::new();
        self.apply_pool_into(pool, x, rows, &mut y);
        y
    }

    /// Allocation-free apply: `y` is cleared and refilled with
    /// `rows * d_out` outputs, reusing its capacity.
    pub fn apply_into(&self, x: &[f32], rows: usize, y: &mut Vec<f32>) {
        self.apply_pool_into(threads::global(), x, rows, y);
    }

    /// Core entry point: tiled GEMM across `pool`.  Large-row shapes
    /// (prefill) split into row blocks; small-row shapes (decode) split
    /// the output columns instead so a batch-8 decode step still uses
    /// every core.
    pub fn apply_pool_into(&self, pool: &ThreadPool, x: &[f32], rows: usize,
                           y: &mut Vec<f32>) {
        assert_eq!(x.len(), rows * self.d_in,
                   "dense input: {} != {} rows x {}", x.len(), rows,
                   self.d_in);
        reuse(y, rows * self.d_out);
        let lvl = simd::level();
        let macs = rows * self.d_in * self.d_out;
        if macs < PAR_MIN_MACS || pool.active() == 1 {
            self.apply_rows(lvl, x, y.as_mut_slice(), 0, rows);
            return;
        }
        if rows >= 2 * ROW_BLOCK {
            let n_blocks = rows.div_ceil(ROW_BLOCK);
            let yp = SlicePtr::new(y.as_mut_slice());
            pool.run(n_blocks, |bi| {
                let r0 = bi * ROW_BLOCK;
                let r1 = (r0 + ROW_BLOCK).min(rows);
                let yb = unsafe {
                    yp.slice(r0 * self.d_out, (r1 - r0) * self.d_out)
                };
                self.apply_rows(lvl, x, yb, r0, r1);
            });
        } else {
            let n_blocks = self.d_out.div_ceil(COL_BLOCK);
            let yp = SlicePtr::new(y.as_mut_slice());
            pool.run(n_blocks, |ci| {
                let o0 = ci * COL_BLOCK;
                let o1 = (o0 + COL_BLOCK).min(self.d_out);
                for r in 0..rows {
                    let yr = unsafe {
                        yp.slice(r * self.d_out + o0, o1 - o0)
                    };
                    self.apply_row_cols(lvl, x, r, o0, o1, yr);
                }
            });
        }
    }

    /// One cache block: all columns for rows `[r0, r1)`, writing into
    /// `yb` (whose row 0 corresponds to `r0`).  Column tiles run in the
    /// outer loop so each `(d_in, N_TILE)` weight slab is reused across
    /// the whole row block from L1.
    fn apply_rows(&self, lvl: Level, x: &[f32], yb: &mut [f32], r0: usize,
                  r1: usize) {
        let d_out = self.d_out;
        let mut o = 0usize;
        while o < d_out {
            let o1 = (o + N_TILE).min(d_out);
            for r in r0..r1 {
                let yr = &mut yb[(r - r0) * d_out + o
                                 ..(r - r0) * d_out + o1];
                self.apply_row_cols(lvl, x, r, o, o1, yr);
            }
            o = o1;
        }
    }

    /// Micro-kernel: one input row times output columns `[o0, o1)` with
    /// `o1 - o0 <= N_TILE` handled as a full register tile and a scalar
    /// tail.  Per-output summation is bias-first then k-ascending —
    /// exactly the naive loop's order; the tile body lives in
    /// [`crate::util::simd`] so scalar and AVX2 dispatch share it.
    /// `o0` is always a multiple of [`N_TILE`] at every call site, so
    /// the quantized path's per-tile scale column is `o / N_TILE`.
    fn apply_row_cols(&self, lvl: Level, x: &[f32], r: usize, o0: usize,
                      o1: usize, yr: &mut [f32]) {
        let d_in = self.d_in;
        let d_out = self.d_out;
        let xr = &x[r * d_in..(r + 1) * d_in];
        let mut o = o0;
        if let Some(qw) = &self.q {
            let n_ct = d_out.div_ceil(N_TILE);
            while o + N_TILE <= o1 {
                let mut acc = [0.0f32; N_TILE];
                simd::dense_tile16_q8(lvl, xr, &qw.q, o, d_out, &qw.scales,
                                      n_ct, o / N_TILE,
                                      &self.b[o..o + N_TILE], &mut acc);
                yr[o - o0..o - o0 + N_TILE].copy_from_slice(&acc);
                o += N_TILE;
            }
            for oo in o..o1 {
                let ct = oo / N_TILE;
                let mut acc = self.b[oo];
                for (k, &xv) in xr.iter().enumerate() {
                    let sc = qw.scales[(k / simd::K_TILE) * n_ct + ct];
                    let wde = sc * (qw.q[k * d_out + oo] as f32);
                    acc += xv * wde;
                }
                yr[oo - o0] = acc;
            }
            return;
        }
        while o + N_TILE <= o1 {
            let mut acc = [0.0f32; N_TILE];
            simd::dense_tile16(lvl, xr, &self.w, o, d_out,
                               &self.b[o..o + N_TILE], &mut acc);
            yr[o - o0..o - o0 + N_TILE].copy_from_slice(&acc);
            o += N_TILE;
        }
        for oo in o..o1 {
            let mut acc = self.b[oo];
            for (k, &xv) in xr.iter().enumerate() {
                acc += xv * self.w[k * d_out + oo];
            }
            yr[oo - o0] = acc;
        }
    }
}

/// Token embedding table `(vocab, d)`.
#[derive(Clone, Debug)]
pub struct Embedding {
    pub vocab: usize,
    pub d: usize,
    pub w: Vec<f32>,
}

impl Embedding {
    pub fn new(vocab: usize, d: usize, w: Vec<f32>) -> Result<Embedding> {
        if w.len() != vocab * d {
            bail!("embedding shape mismatch: {} != {}x{}", w.len(), vocab, d);
        }
        Ok(Embedding { vocab, d, w })
    }

    /// Gather rows; out-of-range ids clamp (like `jnp.take` under jit).
    pub fn lookup(&self, ids: &[i32]) -> Vec<f32> {
        let mut out = Vec::new();
        self.lookup_into(ids, &mut out);
        out
    }

    /// Allocation-free gather into a reused buffer.
    pub fn lookup_into(&self, ids: &[i32], out: &mut Vec<f32>) {
        reuse(out, ids.len() * self.d);
        for (r, &id) in ids.iter().enumerate() {
            let row = (id.max(0) as usize).min(self.vocab - 1);
            out[r * self.d..(r + 1) * self.d]
                .copy_from_slice(&self.w[row * self.d..(row + 1) * self.d]);
        }
    }
}

// ---------------------------------------------------------------------------
// RMSNorm
// ---------------------------------------------------------------------------

/// `x * rsqrt(mean(x^2) + 1e-6) * scale`, normalized over the last dim.
pub fn rmsnorm(x: &[f32], scale: &[f32], rows: usize, d: usize) -> Vec<f32> {
    let mut y = Vec::new();
    rmsnorm_pool_into(threads::global(), x, scale, rows, d, &mut y);
    y
}

/// Allocation-free RMSNorm, row blocks across `pool`.
pub fn rmsnorm_pool_into(pool: &ThreadPool, x: &[f32], scale: &[f32],
                         rows: usize, d: usize, y: &mut Vec<f32>) {
    assert_eq!(x.len(), rows * d, "rmsnorm input");
    assert_eq!(scale.len(), d, "rmsnorm scale");
    reuse(y, rows * d);
    let norm_rows = |ys: &mut [f32], r0: usize, r1: usize| {
        for r in r0..r1 {
            let xr = &x[r * d..(r + 1) * d];
            let ms = xr.iter().map(|v| v * v).sum::<f32>() / d as f32;
            let inv = 1.0 / (ms + 1e-6).sqrt();
            let yr = &mut ys[(r - r0) * d..(r - r0 + 1) * d];
            for i in 0..d {
                yr[i] = xr[i] * inv * scale[i];
            }
        }
    };
    if rows * d < PAR_MIN_MAP || pool.active() == 1 {
        norm_rows(y.as_mut_slice(), 0, rows);
        return;
    }
    let block = ROW_BLOCK.max(1);
    let yp = SlicePtr::new(y.as_mut_slice());
    pool.run(rows.div_ceil(block), |bi| {
        let r0 = bi * block;
        let r1 = (r0 + block).min(rows);
        let yb = unsafe { yp.slice(r0 * d, (r1 - r0) * d) };
        norm_rows(yb, r0, r1);
    });
}

// ---------------------------------------------------------------------------
// temporal depthwise causal conv (kernel 4), parallel + ring-buffer step
// ---------------------------------------------------------------------------

pub const CONV_K: usize = 4;

/// Depthwise causal conv over time with SiLU, `w: (k, d)` row-major.
#[derive(Clone, Debug)]
pub struct Conv4 {
    pub k: usize,
    pub d: usize,
    pub w: Vec<f32>,
    pub b: Vec<f32>,
}

impl Conv4 {
    pub fn new(k: usize, d: usize, w: Vec<f32>, b: Vec<f32>) -> Result<Conv4> {
        if w.len() != k * d || b.len() != d {
            bail!("conv shape mismatch: w {} != {}x{}, b {} != {}",
                  w.len(), k, d, b.len(), d);
        }
        Ok(Conv4 { k, d, w, b })
    }

    /// Parallel mode over `(B, T, D)`:
    /// `y_t = silu(b + sum_j w_j * x_(t-k+1+j))`, zero padding on the left.
    pub fn parallel(&self, x: &[f32], batch: usize, t: usize) -> Vec<f32> {
        let mut y = Vec::new();
        self.parallel_pool_into(threads::global(), x, batch, t, &mut y);
        y
    }

    /// Allocation-free parallel conv, `(bi, ti)` rows across `pool`.
    pub fn parallel_pool_into(&self, pool: &ThreadPool, x: &[f32],
                              batch: usize, t: usize, y: &mut Vec<f32>) {
        let d = self.d;
        assert_eq!(x.len(), batch * t * d, "conv input");
        reuse(y, batch * t * d);
        let conv_row = |yr: &mut [f32], bi: usize, ti: usize| {
            for di in 0..d {
                let mut acc = self.b[di];
                for j in 0..self.k {
                    let src = ti as isize + j as isize
                        - (self.k as isize - 1);
                    if src >= 0 {
                        acc += self.w[j * d + di]
                            * x[(bi * t + src as usize) * d + di];
                    }
                }
                yr[di] = silu(acc);
            }
        };
        let rows = batch * t;
        if rows * d < PAR_MIN_MAP || pool.active() == 1 {
            for bi in 0..batch {
                for ti in 0..t {
                    let yo = (bi * t + ti) * d;
                    conv_row(&mut y[yo..yo + d], bi, ti);
                }
            }
            return;
        }
        let block = ROW_BLOCK.max(1);
        let yp = SlicePtr::new(y.as_mut_slice());
        pool.run(rows.div_ceil(block), |blk| {
            let r0 = blk * block;
            let r1 = (r0 + block).min(rows);
            for r in r0..r1 {
                let yr = unsafe { yp.slice(r * d, d) };
                conv_row(yr, r / t, r % t);
            }
        });
    }

    /// Like [`Conv4::parallel_pool_into`] but writing the **pre-SiLU**
    /// activations — the training path caches these so the backward pass
    /// can evaluate `silu'` without re-running the convolution.
    pub fn parallel_pre_pool_into(&self, pool: &ThreadPool, x: &[f32],
                                  batch: usize, t: usize, y: &mut Vec<f32>) {
        let d = self.d;
        assert_eq!(x.len(), batch * t * d, "conv input");
        reuse(y, batch * t * d);
        let conv_row = |yr: &mut [f32], bi: usize, ti: usize| {
            for di in 0..d {
                let mut acc = self.b[di];
                for j in 0..self.k {
                    let src = ti as isize + j as isize
                        - (self.k as isize - 1);
                    if src >= 0 {
                        acc += self.w[j * d + di]
                            * x[(bi * t + src as usize) * d + di];
                    }
                }
                yr[di] = acc;
            }
        };
        let rows = batch * t;
        if rows * d < PAR_MIN_MAP || pool.active() == 1 {
            for bi in 0..batch {
                for ti in 0..t {
                    let yo = (bi * t + ti) * d;
                    conv_row(&mut y[yo..yo + d], bi, ti);
                }
            }
            return;
        }
        let block = ROW_BLOCK.max(1);
        let yp = SlicePtr::new(y.as_mut_slice());
        pool.run(rows.div_ceil(block), |blk| {
            let r0 = blk * block;
            let r1 = (r0 + block).min(rows);
            for r in r0..r1 {
                let yr = unsafe { yp.slice(r * d, d) };
                conv_row(yr, r / t, r % t);
            }
        });
    }

    /// The `(B, k-1, D)` buffer a parallel pass leaves behind: the last
    /// `k-1` raw inputs (zero-padded when `T < k-1`).
    pub fn final_state(&self, x: &[f32], batch: usize, t: usize) -> Vec<f32> {
        let d = self.d;
        let km1 = self.k - 1;
        let mut st = vec![0.0f32; batch * km1 * d];
        for bi in 0..batch {
            for j in 0..km1 {
                // buffer slot j holds x at time T - (k-1) + j
                let src = t as isize - km1 as isize + j as isize;
                if src >= 0 {
                    let from = (bi * t + src as usize) * d;
                    let to = (bi * km1 + j) * d;
                    st[to..to + d].copy_from_slice(&x[from..from + d]);
                }
            }
        }
        st
    }

    /// Fresh zero ring buffer for `batch` lanes.
    pub fn zero_state(&self, batch: usize) -> Vec<f32> {
        vec![0.0f32; batch * (self.k - 1) * self.d]
    }

    /// Step mode: consumes `x_t: (B, D)`, returns `y_t` and shifts the
    /// ring buffer `buf: (B, k-1, D)` in place.
    pub fn step(&self, buf: &mut [f32], x_t: &[f32], batch: usize)
                -> Vec<f32> {
        let mut y = Vec::new();
        self.step_into(buf, x_t, batch, &mut y);
        y
    }

    /// Allocation-free decode step (sequential — per-token work is tiny).
    pub fn step_into(&self, buf: &mut [f32], x_t: &[f32], batch: usize,
                     y: &mut Vec<f32>) {
        let d = self.d;
        let km1 = self.k - 1;
        assert_eq!(buf.len(), batch * km1 * d, "conv buffer");
        assert_eq!(x_t.len(), batch * d, "conv step input");
        reuse(y, batch * d);
        for bi in 0..batch {
            for di in 0..d {
                let mut acc = self.b[di] + self.w[km1 * d + di]
                    * x_t[bi * d + di];
                for j in 0..km1 {
                    acc += self.w[j * d + di] * buf[(bi * km1 + j) * d + di];
                }
                y[bi * d + di] = silu(acc);
            }
            // shift: drop the oldest slot, append x_t
            for j in 0..km1 - 1 {
                let (to, from) = ((bi * km1 + j) * d, (bi * km1 + j + 1) * d);
                buf.copy_within(from..from + d, to);
            }
            let last = (bi * km1 + km1 - 1) * d;
            buf[last..last + d].copy_from_slice(&x_t[bi * d..(bi + 1) * d]);
        }
    }
}

// ---------------------------------------------------------------------------
// MLP block
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
pub struct Mlp {
    pub up: Dense,
    pub down: Dense,
}

impl Mlp {
    pub fn apply(&self, x: &[f32], rows: usize) -> Vec<f32> {
        let mut h = Vec::new();
        let mut y = Vec::new();
        self.apply_pool_into(threads::global(), x, rows, &mut h, &mut y);
        y
    }

    /// Allocation-free MLP: `h` holds the hidden activations, `y` the
    /// output; both are reused buffers.  The GELU map fans out in fixed
    /// chunks (thread-count invariant).
    pub fn apply_pool_into(&self, pool: &ThreadPool, x: &[f32], rows: usize,
                           h: &mut Vec<f32>, y: &mut Vec<f32>) {
        self.up.apply_pool_into(pool, x, rows, h);
        let n = h.len();
        if n < PAR_MIN_MAP || pool.active() == 1 {
            for v in h.iter_mut() {
                *v = gelu(*v);
            }
        } else {
            let hp = SlicePtr::new(h.as_mut_slice());
            pool.run_chunks(n, MAP_CHUNK, |s, e| {
                let hs = unsafe { hp.slice(s, e - s) };
                for v in hs.iter_mut() {
                    *v = gelu(*v);
                }
            });
        }
        self.down.apply_pool_into(pool, h, rows, y);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::threads::ThreadPool;

    #[test]
    fn dense_matches_hand_computation() {
        // w = [[1, 2], [3, 4]], b = [10, 20]; x = [1, 1] → [14, 26]
        let d = Dense::new(2, 2, vec![1.0, 2.0, 3.0, 4.0],
                           vec![10.0, 20.0]).unwrap();
        assert_eq!(d.apply(&[1.0, 1.0], 1), vec![14.0, 26.0]);
        assert!(Dense::new(2, 2, vec![0.0; 3], vec![0.0; 2]).is_err());
    }

    /// The tiled kernel must agree bit-for-bit with the naive loop on
    /// shapes that straddle every tile/tail boundary.
    #[test]
    fn dense_tiling_is_exact() {
        let mut rng = crate::util::rng::Rng::new(19);
        let pool = ThreadPool::new(3);
        for &(rows, d_in, d_out) in &[(1usize, 1usize, 1usize), (3, 5, 7),
                                      (2, 9, 16), (4, 8, 17), (70, 13, 23),
                                      (65, 16, 33)] {
            let dense = Dense::new(
                d_in, d_out,
                (0..d_in * d_out).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
                (0..d_out).map(|_| rng.normal_f32(0.0, 1.0)).collect())
                .unwrap();
            let x: Vec<f32> = (0..rows * d_in)
                .map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let mut want = vec![0.0f32; rows * d_out];
            for r in 0..rows {
                for o in 0..d_out {
                    let mut acc = dense.b[o];
                    for k in 0..d_in {
                        acc += x[r * d_in + k] * dense.w[k * d_out + o];
                    }
                    want[r * d_out + o] = acc;
                }
            }
            let got = dense.apply_pool(&pool, &x, rows);
            assert_eq!(got, want, "rows={rows} d_in={d_in} d_out={d_out}");
        }
    }

    #[test]
    fn activations_sane() {
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-7);
        assert!((softplus(0.0) - std::f32::consts::LN_2).abs() < 1e-6);
        assert!((g(0.0) - 0.5).abs() < 1e-7);
        assert!((g(1.5) - 2.0).abs() < 1e-7);
        assert!((log_g(1.5) - 2.0f32.ln()).abs() < 1e-6);
        // continuity of g at 0 from below
        assert!((g(-1e-4) - 0.5).abs() < 1e-4);
        // logaddexp basics: reference, fast path, f32
        assert!((logaddexp(0.0, 0.0) - std::f64::consts::LN_2).abs() < 1e-12);
        assert_eq!(logaddexp(f64::NEG_INFINITY, 3.0), 3.0);
        assert!((logaddexp_fast(0.0, 0.0) - std::f64::consts::LN_2).abs()
                < 1e-6);
        assert_eq!(logaddexp_fast(f64::NEG_INFINITY, 3.0), 3.0);
        assert_eq!(logaddexp_fast(3.0, f64::NEG_INFINITY), 3.0);
        // fast path keeps full f64 absolute precision in the max while
        // the correction is f32: large-magnitude operands stay exact
        assert_eq!(logaddexp_fast(5200.0, -5200.0), 5200.0);
        assert!((logaddexp_fast(-3.0, -3.5) - logaddexp(-3.0, -3.5)).abs()
                < 1e-6);
        assert!((logaddexp_f32(0.0, 0.0) - std::f32::consts::LN_2).abs()
                < 1e-6);
        assert_eq!(logaddexp_f32(f32::NEG_INFINITY, 3.0), 3.0);
        assert_eq!(logaddexp_f32(3.0, f32::NEG_INFINITY), 3.0);
        // the LOG_ZERO sentinel is absorbing, not NaN-producing
        let lz = super::super::scan::LOG_ZERO;
        assert!(logaddexp_f32(lz, lz).is_finite());
        assert_eq!(logaddexp_f32(lz, 0.5), 0.5);
        assert!(logaddexp_fast(lz as f64, lz as f64).is_finite());
        assert_eq!(logaddexp_fast(lz as f64, 0.5), 0.5);
    }

    #[test]
    fn activation_grads_match_finite_differences() {
        let check = |f: &dyn Fn(f32) -> f32, df: &dyn Fn(f32) -> f32| {
            for &x in &[-4.0f32, -1.2, -0.3, -1e-3, 1e-3, 0.5, 1.7, 3.0] {
                let e = 1e-3f32;
                let fd = (f(x + e) as f64 - f(x - e) as f64) / (2e-3);
                let got = df(x) as f64;
                assert!((got - fd).abs() < 2e-3 * fd.abs().max(1.0),
                        "x={x}: analytic {got} vs fd {fd}");
            }
        };
        check(&g, &g_grad);
        check(&silu, &silu_grad);
        check(&gelu, &gelu_grad);
    }

    #[test]
    fn conv_pre_activations_match_parallel() {
        let mut rng = crate::util::rng::Rng::new(17);
        let (b, t, d) = (2usize, 6usize, 5usize);
        let conv = Conv4::new(CONV_K, d,
                              (0..CONV_K * d).map(|_| rng.normal_f32(0.0, 0.5))
                                  .collect(),
                              (0..d).map(|_| rng.normal_f32(0.0, 0.1))
                                  .collect()).unwrap();
        let x: Vec<f32> = (0..b * t * d).map(|_| rng.normal_f32(0.0, 1.0))
            .collect();
        let pool = ThreadPool::new(2);
        let mut pre = Vec::new();
        conv.parallel_pre_pool_into(&pool, &x, b, t, &mut pre);
        let mut post = Vec::new();
        conv.parallel_pool_into(&pool, &x, b, t, &mut post);
        for (p, y) in pre.iter().zip(&post) {
            assert_eq!(silu(*p), *y, "silu(pre) must equal the fused path");
        }
    }

    #[test]
    fn rmsnorm_unit_rows() {
        let y = rmsnorm(&[3.0, 4.0], &[1.0, 1.0], 1, 2);
        // rms = sqrt((9 + 16) / 2) = 3.5355
        assert!((y[0] - 3.0 / 3.535_534).abs() < 1e-5, "{y:?}");
        assert!((y[1] - 4.0 / 3.535_534).abs() < 1e-5, "{y:?}");
    }

    #[test]
    fn conv_step_matches_parallel() {
        let mut rng = crate::util::rng::Rng::new(11);
        let (b, t, d) = (2usize, 7usize, 3usize);
        let conv = Conv4::new(CONV_K, d,
                              (0..CONV_K * d).map(|_| rng.normal_f32(0.0, 0.5))
                                  .collect(),
                              (0..d).map(|_| rng.normal_f32(0.0, 0.1))
                                  .collect()).unwrap();
        let x: Vec<f32> = (0..b * t * d).map(|_| rng.normal_f32(0.0, 1.0))
            .collect();
        let par = conv.parallel(&x, b, t);
        let mut buf = conv.zero_state(b);
        for ti in 0..t {
            // gather x_t rows
            let mut xt = vec![0.0f32; b * d];
            for bi in 0..b {
                xt[bi * d..(bi + 1) * d].copy_from_slice(
                    &x[(bi * t + ti) * d..(bi * t + ti + 1) * d]);
            }
            let y = conv.step(&mut buf, &xt, b);
            for bi in 0..b {
                for di in 0..d {
                    let p = par[(bi * t + ti) * d + di];
                    let s = y[bi * d + di];
                    assert!((p - s).abs() < 1e-5,
                            "t={ti} b={bi} d={di}: {p} vs {s}");
                }
            }
        }
        // buffer after the full pass equals the parallel final state
        let fs = conv.final_state(&x, b, t);
        for (a, c) in buf.iter().zip(&fs) {
            assert!((a - c).abs() < 1e-6);
        }
    }
}
