//! AdamW with global-norm gradient clipping for the native training path,
//! mirroring `python/compile/optim.py::adamw_update` (betas (0.9, 0.999),
//! eps 1e-8, weight decay 0, clip 1.0 — the exported train-step defaults).
//!
//! Moments are stored per parameter leaf in the canonical
//! [`NativeModel::leaves_mut`] order; [`AdamState::to_named`] /
//! [`AdamState::from_named`] round-trip them through the MRNN checkpoint
//! format under `opt/adam/...` names, which the inference loader ignores —
//! a training checkpoint loads straight into `NativeBackend`.

use anyhow::{bail, Result};

use crate::util::io::NamedTensor;

use super::model::NativeModel;

#[derive(Clone, Copy, Debug)]
pub struct AdamCfg {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    /// Global-norm clip; `<= 0` disables clipping.
    pub clip_norm: f32,
}

impl Default for AdamCfg {
    fn default() -> Self {
        AdamCfg { beta1: 0.9, beta2: 0.999, eps: 1e-8, weight_decay: 0.0,
                  clip_norm: 1.0 }
    }
}

/// First/second-moment accumulators, one pair per parameter leaf.
#[derive(Clone, Debug)]
pub struct AdamState {
    pub step: u64,
    pub m: Vec<Vec<f32>>,
    pub v: Vec<Vec<f32>>,
}

impl AdamState {
    /// Zero moments shaped like `model`'s leaves.
    pub fn new(model: &NativeModel) -> AdamState {
        let shapes: Vec<usize> = model.leaves().iter().map(|l| l.len())
            .collect();
        AdamState {
            step: 0,
            m: shapes.iter().map(|&n| vec![0.0; n]).collect(),
            v: shapes.iter().map(|&n| vec![0.0; n]).collect(),
        }
    }

    /// One AdamW step: clips `grads` by global norm, updates moments and
    /// parameters in place, returns the **pre-clip** gradient norm (what
    /// the PJRT train step reports).
    pub fn update(&mut self, cfg: &AdamCfg, params: &mut NativeModel,
                  grads: &mut NativeModel, lr: f32) -> Result<f32> {
        let mut gleaves = grads.leaves_mut();
        if gleaves.len() != self.m.len() {
            bail!("adam: {} grad leaves vs {} moment pairs", gleaves.len(),
                  self.m.len());
        }
        let mut norm_sq = 0.0f64;
        for leaf in gleaves.iter() {
            for &g in leaf.iter() {
                norm_sq += g as f64 * g as f64;
            }
        }
        let gnorm = norm_sq.sqrt();
        let scale = if cfg.clip_norm > 0.0 {
            (cfg.clip_norm as f64 / (gnorm + 1e-9)).min(1.0) as f32
        } else {
            1.0
        };

        self.step += 1;
        let sf = self.step as f32;
        let bc1 = 1.0 - cfg.beta1.powf(sf);
        let bc2 = 1.0 - cfg.beta2.powf(sf);
        let mut pleaves = params.leaves_mut();
        if pleaves.len() != gleaves.len() {
            bail!("adam: {} param leaves vs {} grad leaves", pleaves.len(),
                  gleaves.len());
        }
        for (i, (p, gl)) in pleaves.iter_mut().zip(gleaves.iter_mut())
            .enumerate() {
            if p.len() != gl.len() || p.len() != self.m[i].len() {
                bail!("adam: leaf {i} shape mismatch ({} / {} / {})",
                      p.len(), gl.len(), self.m[i].len());
            }
            let (m, v) = (&mut self.m[i], &mut self.v[i]);
            for j in 0..p.len() {
                let g = gl[j] * scale;
                m[j] = cfg.beta1 * m[j] + (1.0 - cfg.beta1) * g;
                v[j] = cfg.beta2 * v[j] + (1.0 - cfg.beta2) * g * g;
                let m_hat = m[j] / bc1;
                let v_hat = v[j] / bc2;
                p[j] -= lr * (m_hat / (v_hat.sqrt() + cfg.eps)
                              + cfg.weight_decay * p[j]);
            }
        }
        Ok(gnorm as f32)
    }

    /// Export moments as named tensors (`opt/adam/{m,v}/<leaf>` +
    /// `opt/adam/step`); `names` are the [`NativeModel::leaf_names`] this
    /// state was built against.
    pub fn to_named(&self, names: &[String]) -> Result<Vec<NamedTensor>> {
        if names.len() != self.m.len() {
            bail!("adam export: {} names vs {} leaves", names.len(),
                  self.m.len());
        }
        let mut out = Vec::with_capacity(2 * names.len() + 1);
        for (which, leaves) in [("m", &self.m), ("v", &self.v)] {
            for (name, leaf) in names.iter().zip(leaves.iter()) {
                let stripped = name.strip_prefix("params/").unwrap_or(name);
                out.push(NamedTensor::f32(
                    &format!("opt/adam/{which}/{stripped}"),
                    vec![leaf.len()], leaf.clone()));
            }
        }
        out.push(NamedTensor::i32("opt/adam/step", vec![],
                                  vec![self.step as i32]));
        Ok(out)
    }

    /// Restore moments from a checkpoint, or `None` when it carries no
    /// native optimizer state (fresh moments are the right fallback —
    /// e.g. a checkpoint written by the PJRT trainer).
    pub fn from_named(tensors: &[NamedTensor], names: &[String],
                      model: &NativeModel) -> Result<Option<AdamState>> {
        let find = |name: &str| tensors.iter().find(|t| t.name == name);
        if find("opt/adam/step").is_none() {
            return Ok(None);
        }
        let mut state = AdamState::new(model);
        state.step = find("opt/adam/step")
            .and_then(|t| t.data.as_i32())
            .and_then(|v| v.first().copied()).unwrap_or(0) as u64;
        for (which, leaves) in [("m", &mut state.m), ("v", &mut state.v)] {
            for (name, leaf) in names.iter().zip(leaves.iter_mut()) {
                let stripped = name.strip_prefix("params/").unwrap_or(name);
                let key = format!("opt/adam/{which}/{stripped}");
                let t = find(&key)
                    .ok_or_else(|| anyhow::anyhow!(
                        "checkpoint has adam state but misses '{key}'"))?;
                let data = t.data.as_f32()
                    .ok_or_else(|| anyhow::anyhow!("'{key}' is not f32"))?;
                if data.len() != leaf.len() {
                    bail!("'{key}': {} elements, model leaf has {}",
                          data.len(), leaf.len());
                }
                leaf.copy_from_slice(data);
            }
        }
        Ok(Some(state))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::model::NativeInit;

    fn tiny() -> NativeModel {
        NativeModel::init_random(&NativeInit {
            d_model: 4,
            vocab_in: Some(5),
            vocab_out: 5,
            n_layers: 1,
            ..Default::default()
        }, 3).unwrap()
    }

    #[test]
    fn update_moves_against_gradient_and_clips() {
        let mut model = tiny();
        let before = model.clone();
        let mut state = AdamState::new(&model);
        let mut grads = model.zeros_like();
        for leaf in grads.leaves_mut() {
            leaf.iter_mut().for_each(|v| *v = 100.0); // huge → clipped
        }
        let cfg = AdamCfg::default();
        let gnorm = state.update(&cfg, &mut model, &mut grads, 0.1).unwrap();
        assert!(gnorm > 100.0, "pre-clip norm reported: {gnorm}");
        assert_eq!(state.step, 1);
        for (a, b) in model.leaves().iter().zip(before.leaves()) {
            for (x, y) in a.iter().zip(b.iter()) {
                // positive gradient → parameter decreases; first step of
                // Adam moves by ~lr regardless of magnitude
                assert!(x < y, "{x} !< {y}");
                assert!((x - y).abs() < 0.11);
            }
        }
    }

    #[test]
    fn named_roundtrip() {
        let model = tiny();
        let names = model.leaf_names();
        let mut state = AdamState::new(&model);
        state.step = 7;
        state.m[0][0] = 0.25;
        state.v[2][1] = 1.5;
        let named = state.to_named(&names).unwrap();
        let back = AdamState::from_named(&named, &names, &model)
            .unwrap().expect("state present");
        assert_eq!(back.step, 7);
        assert_eq!(back.m, state.m);
        assert_eq!(back.v, state.v);
        // a params-only checkpoint yields no adam state
        assert!(AdamState::from_named(&model.to_named(), &names, &model)
                .unwrap().is_none());
    }
}
