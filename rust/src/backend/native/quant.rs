//! Per-tile-scaled int8 weight quantization for inference.
//!
//! Decode is memory-bandwidth-bound — a batch-1 step streams every
//! weight matrix through the core once — so storing [`Dense`] weights
//! as int8 halves the bytes per step (the paper's efficiency argument
//! for minimal RNNs is exactly this bandwidth economy).  The scheme is
//! symmetric linear quantization with one f32 scale per
//! `(K_TILE x N_TILE)` weight tile: `w ≈ scale * q`, `q ∈ [-127, 127]`.
//! Tiles match the GEMM register tile in `linalg.rs`, so the scale for
//! a tile is loaded once per `(k-block, column-tile)` and the dequant
//! `sc * (q as f32)` happens inside the register tile
//! ([`crate::util::simd::dense_tile16_q8`]).
//!
//! Contract (see `ARCHITECTURE.md`): int8 results are **not** bit-equal
//! to f32 — they are gated on the error budgets below instead.  The
//! dequant op sequence itself is identical between scalar and AVX2
//! dispatch, so quantized outputs *are* bit-identical across dispatch
//! levels and thread counts, same as f32.
//!
//! Quantized models are inference-only: `quantize` drops the f32
//! weights, the trainer refuses to resume from such a checkpoint, and
//! biases (plus every non-[`Dense`] leaf: embeddings, conv taps, norm
//! gains, head) stay f32.

use anyhow::{bail, Result};

use crate::tensor::Tensor;
use crate::util::rng::Rng;
use crate::util::simd::K_TILE;

use super::linalg::{Dense, N_TILE};
use super::model::{InputLayer, NativeModel};

/// Max allowed relative logit error after quantization, measured as
/// `max_i |q_i - f_i| / max(1, |f_i|)` over a probe batch.  The tiled
/// scheme lands well under this on trained checkpoints; the budget is
/// the serve/CI gate, not the expected error.
pub const LOGIT_REL_ERR_BUDGET: f32 = 0.05;

/// Max allowed eval-loss increase (mean CE, nats) on a held-out batch
/// after quantization.
pub const EVAL_LOSS_DELTA_BUDGET: f32 = 0.10;

/// Int8 payload for a [`Dense`]: `q` has the same `(d_in, d_out)`
/// row-major layout as `w`; `scales` is an `(n_kt, n_ct)` row-major
/// grid, one f32 per `(K_TILE x N_TILE)` tile of the weight matrix
/// (ragged edge tiles included).  An all-zero tile stores scale 0.
#[derive(Clone, Debug)]
pub struct QuantDense {
    pub q: Vec<i8>,
    pub scales: Vec<f32>,
}

/// Number of `K_TILE`-row blocks covering `d_in`.
pub fn n_kt(d_in: usize) -> usize {
    d_in.div_ceil(K_TILE).max(1)
}

/// Number of `N_TILE`-column blocks covering `d_out`.
pub fn n_ct(d_out: usize) -> usize {
    d_out.div_ceil(N_TILE).max(1)
}

impl QuantDense {
    /// Quantize a row-major `(d_in, d_out)` f32 weight matrix.
    pub fn from_f32(d_in: usize, d_out: usize, w: &[f32]) -> QuantDense {
        assert_eq!(w.len(), d_in * d_out, "quantize: w shape mismatch");
        let (nk, nc) = (n_kt(d_in), n_ct(d_out));
        let mut scales = vec![0.0f32; nk * nc];
        for kt in 0..nk {
            let k1 = ((kt + 1) * K_TILE).min(d_in);
            for ct in 0..nc {
                let j1 = ((ct + 1) * N_TILE).min(d_out);
                let mut maxabs = 0.0f32;
                for k in kt * K_TILE..k1 {
                    for j in ct * N_TILE..j1 {
                        maxabs = maxabs.max(w[k * d_out + j].abs());
                    }
                }
                scales[kt * nc + ct] =
                    if maxabs > 0.0 { maxabs / 127.0 } else { 0.0 };
            }
        }
        let mut q = vec![0i8; d_in * d_out];
        for k in 0..d_in {
            for j in 0..d_out {
                let sc = scales[(k / K_TILE) * nc + j / N_TILE];
                if sc > 0.0 {
                    let v = (w[k * d_out + j] / sc).round();
                    q[k * d_out + j] = v.clamp(-127.0, 127.0) as i8;
                }
            }
        }
        QuantDense { q, scales }
    }

    /// Reconstruct the f32 weights the kernel effectively uses
    /// (`sc * q` per element) — for error accounting and tests.
    pub fn dequant(&self, d_in: usize, d_out: usize) -> Vec<f32> {
        assert_eq!(self.q.len(), d_in * d_out, "dequant: q shape mismatch");
        let nc = n_ct(d_out);
        (0..d_in * d_out)
            .map(|i| {
                let (k, j) = (i / d_out, i % d_out);
                self.scales[(k / K_TILE) * nc + j / N_TILE]
                    * (self.q[i] as f32)
            })
            .collect()
    }
}

/// Convert a [`Dense`] to int8 in place, dropping the f32 weights.
pub fn quantize_dense(d: &mut Dense) -> Result<()> {
    if d.q.is_some() {
        bail!("dense layer is already quantized");
    }
    let qd = QuantDense::from_f32(d.d_in, d.d_out, &d.w);
    if qd.scales.len() != n_kt(d.d_in) * n_ct(d.d_out) {
        bail!("quantize produced a malformed scale grid");
    }
    d.w = Vec::new();
    d.q = Some(qd);
    Ok(())
}

/// Quantize every [`Dense`] leaf of a model in place.  Embeddings,
/// conv taps, norm gains, and biases stay f32.  Fails (leaving the
/// model partially converted is impossible — the check runs first) if
/// the model is already quantized.
pub fn quantize_model(m: &mut NativeModel) -> Result<()> {
    if m.is_quantized() {
        bail!("model is already quantized");
    }
    let mut res = Ok(());
    m.for_each_dense_mut(&mut |d| {
        if res.is_ok() {
            res = quantize_dense(d);
        }
    });
    res
}

/// A deterministic probe input matching the model's input contract:
/// tokens below the embedding vocab for discrete models, unit-normal
/// features for continuous ones.  `t` is clamped to the positional
/// table for transformer backbones.
pub fn probe_input(m: &NativeModel, batch: usize, t: usize,
                   seed: u64) -> Tensor {
    let t = match &m.pos {
        Some(pe) => t.min(pe.vocab).max(1),
        None => t.max(1),
    };
    let mut rng = Rng::new(seed);
    match &m.input {
        InputLayer::Embed(e) => Tensor::i32(
            vec![batch, t],
            (0..batch * t).map(|_| rng.below(e.vocab as u64) as i32)
                .collect()),
        InputLayer::Proj(p) => Tensor::f32(
            vec![batch, t, p.d_in],
            (0..batch * t * p.d_in).map(|_| rng.normal_f32(0.0, 1.0))
                .collect()),
    }
}

/// Golden-error self-check: run the same seeded probe batch through the
/// f32 source and the quantized model and report [`max_rel_err`] over
/// all logits.  Shared by `minrnn quantize`, the bench harness, and the
/// property tests so they gate on one number.
pub fn probe_rel_err(reference: &NativeModel, quantized: &NativeModel)
                     -> Result<f32> {
    let x = probe_input(reference, 2, 16, 0x5138);
    let (lf, _) = reference.forward(&x)?;
    let (lq, _) = quantized.forward(&x)?;
    let (f, q) = (lf.data.as_f32().unwrap(), lq.data.as_f32().unwrap());
    Ok(max_rel_err(f, q))
}

/// `max_i |q_i - f_i| / max(1, |f_i|)` — the golden-error metric the
/// CLI, bench harness, and tests all share.
pub fn max_rel_err(reference: &[f32], quantized: &[f32]) -> f32 {
    assert_eq!(reference.len(), quantized.len(), "rel err: len mismatch");
    let mut worst = 0.0f32;
    for (&f, &q) in reference.iter().zip(quantized) {
        worst = worst.max((q - f).abs() / f.abs().max(1.0));
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_w(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32(0.0, scale)).collect()
    }

    #[test]
    fn per_tile_error_bound_holds() {
        // symmetric rounding to 127 levels: |deq - w| <= scale / 2
        let mut rng = Rng::new(11);
        for &(d_in, d_out) in &[(1usize, 1usize), (7, 5), (64, 16),
                                (65, 17), (130, 48), (40, 33)] {
            let w = random_w(&mut rng, d_in * d_out, 0.3);
            let qd = QuantDense::from_f32(d_in, d_out, &w);
            assert_eq!(qd.scales.len(), n_kt(d_in) * n_ct(d_out));
            let deq = qd.dequant(d_in, d_out);
            let nc = n_ct(d_out);
            for k in 0..d_in {
                for j in 0..d_out {
                    let sc = qd.scales[(k / K_TILE) * nc + j / N_TILE];
                    let err = (deq[k * d_out + j] - w[k * d_out + j]).abs();
                    assert!(err <= 0.5 * sc + 1e-7,
                            "({d_in},{d_out}) [{k},{j}]: err {err} > \
                             scale/2 {}", 0.5 * sc);
                }
            }
        }
    }

    #[test]
    fn zero_tile_quantizes_to_zero() {
        let qd = QuantDense::from_f32(3, 4, &vec![0.0; 12]);
        assert!(qd.scales.iter().all(|&s| s == 0.0));
        assert!(qd.q.iter().all(|&v| v == 0));
        assert!(qd.dequant(3, 4).iter().all(|&v| v == 0.0));
    }

    #[test]
    fn extremes_saturate_to_127() {
        // the max-abs element of each tile must map to exactly +/-127
        let mut w = vec![0.01f32; 64 * 16];
        w[5] = -2.0;
        let qd = QuantDense::from_f32(64, 16, &w);
        assert_eq!(qd.q[5], -127);
        assert!((qd.scales[0] - 2.0 / 127.0).abs() < 1e-9);
    }

    #[test]
    fn quantize_dense_drops_w_and_rejects_twice() {
        let mut rng = Rng::new(5);
        let mut d = Dense::new(20, 18, random_w(&mut rng, 360, 0.2),
                               vec![0.1; 18]).unwrap();
        quantize_dense(&mut d).unwrap();
        assert!(d.w.is_empty());
        assert!(d.q.is_some());
        let err = quantize_dense(&mut d).unwrap_err().to_string();
        assert!(err.contains("already quantized"), "{err}");
    }

    #[test]
    fn quantized_apply_matches_dequant_reference() {
        // the kernel must compute exactly x @ dequant(w) + b (the
        // budgeted error is quantization itself, not the kernel)
        let mut rng = Rng::new(23);
        for &(rows, d_in, d_out) in &[(1usize, 33usize, 17usize),
                                      (3, 70, 48), (2, 64, 16)] {
            let w = random_w(&mut rng, d_in * d_out, 0.3);
            let b = random_w(&mut rng, d_out, 0.1);
            let x = random_w(&mut rng, rows * d_in, 1.0);
            let mut d =
                Dense::new(d_in, d_out, w, b.clone()).unwrap();
            quantize_dense(&mut d).unwrap();
            let deq = d.q.as_ref().unwrap().dequant(d_in, d_out);
            let dref = Dense::new(d_in, d_out, deq, b).unwrap();
            let got = d.apply(&x, rows);
            let want = dref.apply(&x, rows);
            assert_eq!(got.len(), want.len());
            for (i, (&g, &wv)) in got.iter().zip(&want).enumerate() {
                let err = (g - wv).abs();
                assert!(err <= 1e-4 * wv.abs().max(1.0),
                        "({rows},{d_in},{d_out})[{i}]: {g} vs {wv}");
            }
        }
    }

    #[test]
    fn quantized_apply_is_close_to_f32() {
        let mut rng = Rng::new(77);
        let (rows, d_in, d_out) = (4usize, 96usize, 50usize);
        let w = random_w(&mut rng, d_in * d_out, 0.1);
        let b = random_w(&mut rng, d_out, 0.1);
        let x = random_w(&mut rng, rows * d_in, 1.0);
        let f = Dense::new(d_in, d_out, w.clone(), b.clone()).unwrap();
        let mut q = Dense::new(d_in, d_out, w, b).unwrap();
        quantize_dense(&mut q).unwrap();
        let rel = max_rel_err(&f.apply(&x, rows), &q.apply(&x, rows));
        assert!(rel < LOGIT_REL_ERR_BUDGET,
                "single-layer rel err {rel} over budget");
    }

    #[test]
    fn whole_model_probe_is_within_budget_and_deterministic() {
        use crate::backend::native::model::{NativeInit, NativeModel};
        let init = NativeInit {
            n_layers: 2,
            d_model: 16,
            expansion: 2,
            vocab_in: Some(11),
            vocab_out: 11,
            conv: true,
            mlp: true,
            ..Default::default()
        };
        let m = NativeModel::init_random(&init, 9).unwrap();
        let mut qm = m.clone();
        quantize_model(&mut qm).unwrap();
        let rel = probe_rel_err(&m, &qm).unwrap();
        assert!(rel < LOGIT_REL_ERR_BUDGET,
                "probe rel err {rel} over budget");
        assert_eq!(rel, probe_rel_err(&m, &qm).unwrap(),
                   "probe must be deterministic");
    }

    #[test]
    fn max_rel_err_uses_absolute_floor() {
        assert_eq!(max_rel_err(&[0.0, 10.0], &[0.5, 10.0]), 0.5);
        assert_eq!(max_rel_err(&[100.0], &[90.0]), 0.1);
    }
}
