//! S6-lite mixer — the stand-in for Mamba's selective state-space model
//! (`python/compile/models/s6lite.py`), Section 4.2's comparison point:
//! *input-dependent* diagonal transitions through the same parallel scan:
//!
//! ```text
//! Δ_t = softplus(W_Δ x_t + b_Δ)        (input-dependent step size)
//! a_t = exp(-Δ_t ⊙ exp(A_log))         (diagonal transition ∈ (0,1))
//! b_t = Δ_t ⊙ (W_B x_t)                (input-dependent injection)
//! h_t = a_t ⊙ h_{t-1} + b_t            (real-space linear scan)
//! y_t = W_down (h_t ⊙ silu(W_g x_t))   (gated output, as in Mamba)
//! ```
//!
//! Unlike minGRU/minLSTM the transition is not a probability from a
//! gate pair, so the scan runs in real space
//! ([`scan::scan_linear_pool_into`]) with a zero initial state; the
//! thread-invariance machinery (fixed `(batch, D_BLOCK)` channel tasks)
//! is shared with the log-space scan.

use anyhow::{bail, Result};

use crate::util::threads::{SlicePtr, ThreadPool};

use super::autograd;
use super::linalg::{self, sigmoid, silu, silu_grad, softplus, Dense};
use super::mingru::GATE_CHUNK;
use super::mixer::{Mixer, MixerTape};
use super::model::MixerParams;
use super::scan::{self, D_BLOCK};
use super::scratch::MixerScratch;

/// Below this many elements the reverse selective scan runs inline.
const PAR_MIN_MAP: usize = 1 << 14;

#[derive(Clone, Debug)]
pub struct S6Lite {
    /// `W_Δ`: `d_model → d_h` (bias init −1.0: `softplus(−1) ≈ 0.31`).
    pub dt: Dense,
    /// `W_B`: `d_model → d_h`.
    pub b: Dense,
    /// `W_g`: `d_model → d_h` (SiLU output gate).
    pub gate: Dense,
    /// `d_h → d_model` down-projection.
    pub down: Dense,
    /// `A_log` per channel; transitions start near `exp(-Δ·exp(A_log))`
    /// (S4D-real-style init `log(linspace(1, 8, d_h))`).
    pub a_log: Vec<f32>,
}

impl S6Lite {
    pub fn d_hidden(&self) -> usize {
        self.dt.d_out
    }

    /// `(a_t, b_t)` from the `dt`/`b` pre-projections, in fixed
    /// [`GATE_CHUNK`] chunks (channel index is `i mod d_h`).
    fn coeffs_into(&self, pool: &ThreadPool, dt_pre: &[f32], bx: &[f32],
                   a: &mut Vec<f32>, bval: &mut Vec<f32>) {
        let dh = self.d_hidden();
        let n = dt_pre.len();
        linalg::reuse(a, n);
        linalg::reuse(bval, n);
        let ap = SlicePtr::new(a.as_mut_slice());
        let bp = SlicePtr::new(bval.as_mut_slice());
        let al = &self.a_log;
        pool.run_chunks(n, GATE_CHUNK, |s, e| {
            let av = unsafe { ap.slice(s, e - s) };
            let bv = unsafe { bp.slice(s, e - s) };
            for i in 0..e - s {
                let o = s + i;
                let delta = softplus(dt_pre[o]);
                av[i] = (-delta * al[o % dh].exp()).exp();
                bv[i] = delta * bx[o];
            }
        });
    }
}

/// `out = h ⊙ silu(gate_pre)` across the pool in fixed chunks.
fn gate_mul_into(pool: &ThreadPool, h: &[f32], gate_pre: &[f32],
                 out: &mut Vec<f32>) {
    debug_assert_eq!(h.len(), gate_pre.len());
    linalg::reuse(out, h.len());
    let op = SlicePtr::new(out.as_mut_slice());
    pool.run_chunks(h.len(), GATE_CHUNK, |s, e| {
        let ov = unsafe { op.slice(s, e - s) };
        for i in 0..e - s {
            ov[i] = h[s + i] * silu(gate_pre[s + i]);
        }
    });
}

impl Mixer for S6Lite {
    fn kind(&self) -> &'static str {
        "s6lite"
    }

    fn d_hidden(&self) -> usize {
        S6Lite::d_hidden(self)
    }

    fn init_lane(&self, lane: &mut [f32]) {
        lane.fill(0.0);
    }

    fn parallel_into(&self, pool: &ThreadPool, x: &[f32], batch: usize,
                     t: usize, ms: &mut MixerScratch, y: &mut Vec<f32>,
                     state: &mut [f32]) -> Result<()> {
        let rows = batch * t;
        let dh = S6Lite::d_hidden(self);
        self.dt.apply_pool_into(pool, x, rows, &mut ms.k);
        self.b.apply_pool_into(pool, x, rows, &mut ms.pre);
        self.gate.apply_pool_into(pool, x, rows, &mut ms.f);
        self.coeffs_into(pool, &ms.k, &ms.pre, &mut ms.log_a, &mut ms.log_b);
        scan::scan_linear_pool_into(pool, &ms.log_a, &ms.log_b, state,
                                    batch, t, dh, &mut ms.h);
        for bi in 0..batch {
            state[bi * dh..(bi + 1) * dh].copy_from_slice(
                &ms.h[(bi * t + t - 1) * dh..(bi * t + t) * dh]);
        }
        gate_mul_into(pool, &ms.h, &ms.f, &mut ms.tmp);
        self.down.apply_pool_into(pool, &ms.tmp, rows, y);
        Ok(())
    }

    fn step_into(&self, pool: &ThreadPool, x_t: &[f32], batch: usize,
                 _pos: &[u32], state: &mut [f32], ms: &mut MixerScratch,
                 y: &mut Vec<f32>) -> Result<()> {
        let dh = S6Lite::d_hidden(self);
        let n = batch * dh;
        self.dt.apply_pool_into(pool, x_t, batch, &mut ms.k);
        self.b.apply_pool_into(pool, x_t, batch, &mut ms.pre);
        self.gate.apply_pool_into(pool, x_t, batch, &mut ms.f);
        linalg::reuse(&mut ms.tmp, n);
        {
            let sp = SlicePtr::new(&mut *state);
            let tp = SlicePtr::new(ms.tmp.as_mut_slice());
            let (dtv, bxv, gv, al) = (&ms.k, &ms.pre, &ms.f, &self.a_log);
            pool.run_chunks(n, GATE_CHUNK, |s, e| {
                let sv = unsafe { sp.slice(s, e - s) };
                let tv = unsafe { tp.slice(s, e - s) };
                for i in 0..e - s {
                    let o = s + i;
                    let delta = softplus(dtv[o]);
                    let a = (-delta * al[o % dh].exp()).exp();
                    let h = a * sv[i] + delta * bxv[o];
                    sv[i] = h;
                    tv[i] = h * silu(gv[o]);
                }
            });
        }
        self.down.apply_pool_into(pool, &ms.tmp, batch, y);
        Ok(())
    }

    fn forward_tape(&self, pool: &ThreadPool, x: &[f32], batch: usize,
                    t: usize) -> Result<(MixerTape, Vec<f32>)> {
        let rows = batch * t;
        let dh = S6Lite::d_hidden(self);
        let dt_pre = self.dt.apply_pool(pool, x, rows);
        let bx = self.b.apply_pool(pool, x, rows);
        let gate_pre = self.gate.apply_pool(pool, x, rows);
        let mut a = Vec::new();
        let mut bval = Vec::new();
        self.coeffs_into(pool, &dt_pre, &bx, &mut a, &mut bval);
        let h0 = vec![0.0f32; batch * dh];
        let mut h = Vec::new();
        scan::scan_linear_pool_into(pool, &a, &bval, &h0, batch, t, dh,
                                    &mut h);
        let mut gated = Vec::new();
        gate_mul_into(pool, &h, &gate_pre, &mut gated);
        let mut y = Vec::new();
        self.down.apply_pool_into(pool, &gated, rows, &mut y);
        Ok((MixerTape::S6Lite { dt_pre, bx, gate_pre, h }, y))
    }

    fn backward(&self, pool: &ThreadPool, tape: &MixerTape, x: &[f32],
                dy: &[f32], batch: usize, t: usize, dx: &mut Vec<f32>,
                grads: &mut MixerParams) -> Result<()> {
        let (dt_pre, bx, gate_pre, h) = match tape {
            MixerTape::S6Lite { dt_pre, bx, gate_pre, h } =>
                (dt_pre, bx, gate_pre, h),
            _ => bail!("S6-lite backward: tape kind mismatch"),
        };
        let gm = match grads {
            MixerParams::S6Lite(gm) => gm,
            _ => bail!("backward: grads mixer kind mismatch"),
        };
        let rows = batch * t;
        let dh = S6Lite::d_hidden(self);
        let n = rows * dh;

        // y = down(h ⊙ silu(gate_pre)): recompute the gated product,
        // backprop the down-projection, then split into the gate branch
        // and the direct state gradient.
        let mut gated = Vec::new();
        gate_mul_into(pool, h, gate_pre, &mut gated);
        let mut dgated = Vec::new();
        autograd::dense_bwd(pool, &self.down, &gated, dy, rows,
                            Some((&mut dgated, false)), &mut gm.down.w,
                            &mut gm.down.b);
        let mut dgate_pre = vec![0.0f32; n];
        let mut dh_dir = vec![0.0f32; n];
        {
            let gp = SlicePtr::new(dgate_pre.as_mut_slice());
            let hp = SlicePtr::new(dh_dir.as_mut_slice());
            let dg = &dgated;
            pool.run_chunks(n, GATE_CHUNK, |s, e| {
                let gv = unsafe { gp.slice(s, e - s) };
                let hv = unsafe { hp.slice(s, e - s) };
                for i in 0..e - s {
                    let o = s + i;
                    gv[i] = dg[o] * h[o] * silu_grad(gate_pre[o]);
                    hv[i] = dg[o] * silu(gate_pre[o]);
                }
            });
        }

        // Reverse selective scan.  Tasks split the channel axis only
        // (not batch × channel): each task owns its channels' `da_log`
        // entries exclusively, so the a_log accumulation is
        // deterministic at any thread count.
        let mut ddt = vec![0.0f32; n];
        let mut dbx = vec![0.0f32; n];
        let mut da_log = vec![0.0f32; dh];
        let blocks = dh.div_ceil(D_BLOCK);
        {
            let ddtp = SlicePtr::new(ddt.as_mut_slice());
            let dbxp = SlicePtr::new(dbx.as_mut_slice());
            let dalp = SlicePtr::new(da_log.as_mut_slice());
            let task = |ci: usize| {
                let d0 = ci * D_BLOCK;
                let d1 = (d0 + D_BLOCK).min(dh);
                let w = d1 - d0;
                let dal = unsafe { dalp.slice(d0, w) };
                for bi in 0..batch {
                    let mut carry = [0.0f32; D_BLOCK];
                    for ti in (0..t).rev() {
                        let off = (bi * t + ti) * dh + d0;
                        let ddts = unsafe { ddtp.slice(off, w) };
                        let dbxs = unsafe { dbxp.slice(off, w) };
                        for j in 0..w {
                            let o = off + j;
                            let g_tot = carry[j] + dh_dir[o];
                            let delta = softplus(dt_pre[o]);
                            let aj = self.a_log[d0 + j].exp();
                            let a = (-delta * aj).exp();
                            let hprev = if ti > 0 { h[o - dh] } else { 0.0 };
                            let da = g_tot * hprev;
                            let ddelta = -aj * a * da + g_tot * bx[o];
                            dal[j] += da * a * (-delta * aj);
                            dbxs[j] = g_tot * delta;
                            ddts[j] = ddelta * sigmoid(dt_pre[o]);
                            carry[j] = a * g_tot;
                        }
                    }
                }
            };
            if n < PAR_MIN_MAP || pool.active() == 1 {
                for ci in 0..blocks {
                    task(ci);
                }
            } else {
                pool.run(blocks, task);
            }
        }

        autograd::dense_bwd(pool, &self.dt, x, &ddt, rows,
                            Some((dx, false)), &mut gm.dt.w, &mut gm.dt.b);
        autograd::dense_bwd(pool, &self.b, x, &dbx, rows,
                            Some((dx, true)), &mut gm.b.w, &mut gm.b.b);
        autograd::dense_bwd(pool, &self.gate, x, &dgate_pre, rows,
                            Some((dx, true)), &mut gm.gate.w,
                            &mut gm.gate.b);
        for (g, v) in gm.a_log.iter_mut().zip(&da_log) {
            *g += v;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::threads;

    fn tiny(d: usize, dh: usize) -> S6Lite {
        let mut rng = Rng::new(0xA5);
        let mut dense = |d_in: usize, d_out: usize, bias: f32| Dense {
            d_in,
            d_out,
            w: (0..d_in * d_out)
                .map(|_| rng.normal_f32(0.0, 1.0 / (d_in as f32).sqrt()))
                .collect(),
            b: vec![bias; d_out],
            q: None,
        };
        let dt = dense(d, dh, -1.0);
        let b = dense(d, dh, 0.0);
        let gate = dense(d, dh, 0.0);
        let down = dense(dh, d, 0.0);
        let a_log: Vec<f32> = (0..dh)
            .map(|j| {
                let v = if dh > 1 {
                    1.0 + 7.0 * j as f32 / (dh - 1) as f32
                } else {
                    1.0
                };
                v.ln()
            })
            .collect();
        S6Lite { dt, b, gate, down, a_log }
    }

    #[test]
    fn parallel_and_step_agree() {
        // the same parallel/sequential identity the paper proves for the
        // minimal RNNs holds for the selective scan
        let (batch, t, d, dh) = (2usize, 7usize, 5usize, 6usize);
        let m = tiny(d, dh);
        let mut rng = Rng::new(11);
        let x: Vec<f32> = (0..batch * t * d)
            .map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let pool = threads::global();
        let mut ms = MixerScratch::default();
        let mut y = Vec::new();
        let mut state = vec![0.0f32; batch * dh];
        m.parallel_into(pool, &x, batch, t, &mut ms, &mut y, &mut state)
            .unwrap();

        let mut st = vec![0.0f32; batch * dh];
        let mut ms2 = MixerScratch::default();
        let mut yt = Vec::new();
        for ti in 0..t {
            let mut x_t = vec![0.0f32; batch * d];
            for bi in 0..batch {
                x_t[bi * d..(bi + 1) * d].copy_from_slice(
                    &x[(bi * t + ti) * d..(bi * t + ti + 1) * d]);
            }
            m.step_into(pool, &x_t, batch, &[ti as u32; 2], &mut st,
                        &mut ms2, &mut yt).unwrap();
            for bi in 0..batch {
                for i in 0..d {
                    let p = y[(bi * t + ti) * d + i];
                    let s = yt[bi * d + i];
                    assert!((p - s).abs() < 1e-5,
                            "t={ti} b={bi} i={i}: {p} vs {s}");
                }
            }
        }
        for (a, b) in state.iter().zip(&st) {
            assert!((a - b).abs() < 1e-5, "final state drifted");
        }
    }
}
