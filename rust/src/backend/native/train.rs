//! Native training driver: owns a [`NativeModel`], its gradient container
//! and [`AdamState`], and implements [`crate::runtime::TrainBackend`] so
//! `coordinator::trainer::run_loop` drives it exactly like the PJRT
//! artifact path — no artifacts, no Python, no XLA.
//!
//! One [`NativeTrainer::train_step`] is: recording forward
//! ([`autograd::forward`]) → fused masked softmax-cross-entropy
//! ([`loss::masked_ce`]) → reverse pass ([`autograd::backward`]) → AdamW
//! with global-norm clipping ([`AdamState::update`]), all on the shared
//! thread pool.  Checkpoints carry `params/...` (loadable by native *and*
//! PJRT inference) plus `opt/adam/...` moments and `meta/step`.

use std::path::Path;

use anyhow::{anyhow, bail, Result};

use crate::runtime::{EvalMetrics, StepMetrics, TrainBackend};
use crate::tensor::Batch;
use crate::util::io::{self, NamedTensor};

use super::adam::{AdamCfg, AdamState};
use super::autograd;
use super::loss;
use super::model::NativeModel;

pub struct NativeTrainer {
    pub model: NativeModel,
    pub adam: AdamState,
    pub cfg: AdamCfg,
    /// Display / checkpoint-file label (no path separators).
    pub label: String,
    grads: NativeModel,
    dlogits: Vec<f32>,
}

impl NativeTrainer {
    pub fn new(model: NativeModel, label: &str) -> NativeTrainer {
        NativeTrainer {
            adam: AdamState::new(&model),
            cfg: AdamCfg::default(),
            label: label.replace('/', "_"),
            grads: model.zeros_like(),
            dlogits: Vec::new(),
            model,
        }
    }

    /// Resume from a checkpoint: parameters always; Adam moments when the
    /// checkpoint carries them (a PJRT- or inference-written checkpoint
    /// resumes with fresh moments).
    pub fn from_checkpoint(path: &Path, label: &str)
                           -> Result<NativeTrainer> {
        let tensors = io::load(path)?;
        let model = NativeModel::from_named(&tensors)?;
        let names = model.leaf_names();
        let adam = AdamState::from_named(&tensors, &names, &model)?
            .unwrap_or_else(|| AdamState::new(&model));
        Ok(NativeTrainer {
            adam,
            cfg: AdamCfg::default(),
            label: label.replace('/', "_"),
            grads: model.zeros_like(),
            dlogits: Vec::new(),
            model,
        })
    }

    /// Optimizer steps taken (mirrors `TrainState::step`).
    pub fn step(&self) -> u64 {
        self.adam.step
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut tensors = self.model.to_named();
        tensors.extend(self.adam.to_named(&self.model.leaf_names())?);
        tensors.push(NamedTensor::i32("meta/step", vec![],
                                      vec![self.adam.step as i32]));
        io::save(path, &tensors)
    }

    fn batch_targets<'a>(&self, batch: &'a Batch)
                         -> Result<(&'a [i32], &'a [f32], usize, usize)> {
        let targets = batch.targets.data.as_i32()
            .ok_or_else(|| anyhow!(
                "native training covers masked_ce (discrete targets); this \
                 batch has {} targets — use the PJRT train path for \
                 masked_mse workloads", batch.targets.dtype_name()))?;
        let mask = batch.mask.data.as_f32()
            .ok_or_else(|| anyhow!("batch mask is not f32"))?;
        Ok((targets, mask, batch.batch_size(), batch.seq_len()))
    }

    /// One optimizer step; returns loss and pre-clip gradient norm.
    pub fn train_batch(&mut self, batch: &Batch, lr: f32)
                       -> Result<StepMetrics> {
        let (targets, mask, b, t) = self.batch_targets(batch)?;
        let tape = autograd::forward(&self.model, &batch.x)?;
        let metrics = loss::masked_ce(&tape.logits, targets, mask, b, t,
                                      self.model.vocab_out,
                                      Some(&mut self.dlogits))?;
        if !metrics.loss.is_finite() {
            bail!("non-finite loss {} at step {} of {}", metrics.loss,
                  self.adam.step + 1, self.label);
        }
        for leaf in self.grads.leaves_mut() {
            leaf.iter_mut().for_each(|v| *v = 0.0);
        }
        autograd::backward(&self.model, &tape, &batch.x, &self.dlogits,
                           &mut self.grads)?;
        let gnorm = self.adam.update(&self.cfg, &mut self.model,
                                     &mut self.grads, lr)?;
        Ok(StepMetrics { loss: metrics.loss, grad_norm: gnorm })
    }

    /// Forward-only evaluation (loss + token/sequence accuracy) through
    /// the non-recording inference forward — bit-identical logits to the
    /// tape-recording pass (pinned by autograd's tests) without its
    /// per-block activation caches.
    pub fn eval_batch(&self, batch: &Batch) -> Result<EvalMetrics> {
        let (targets, mask, b, t) = self.batch_targets(batch)?;
        let (logits, _) = self.model.forward(&batch.x)?;
        let lv = logits.data.as_f32()
            .ok_or_else(|| anyhow!("logits not f32"))?;
        loss::masked_ce(lv, targets, mask, b, t, self.model.vocab_out, None)
    }
}

impl TrainBackend for NativeTrainer {
    fn name(&self) -> &str {
        &self.label
    }

    fn train_step(&mut self, batch: &Batch, lr: f32, _drop_seed: i32)
                  -> Result<StepMetrics> {
        self.train_batch(batch, lr)
    }

    /// Native eval needs no per-shape executables: any batch works.
    fn supports_eval(&self) -> bool {
        true
    }

    fn eval(&self, batch: &Batch) -> Result<EvalMetrics> {
        self.eval_batch(batch)
    }

    fn save_checkpoint(&self, path: &Path) -> Result<()> {
        self.save(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::model::NativeInit;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    fn echo_batch(rng: &mut Rng, b: usize, t: usize, vocab: usize) -> Batch {
        // trivially learnable: predict the current input token
        let x: Vec<i32> = (0..b * t).map(|_| rng.below(vocab as u64) as i32)
            .collect();
        Batch {
            targets: Tensor::i32(vec![b, t], x.clone()),
            x: Tensor::i32(vec![b, t], x),
            mask: Tensor::f32(vec![b, t], vec![1.0; b * t]),
        }
    }

    #[test]
    fn loss_decreases_on_echo_task() {
        let vocab = 12usize;
        let model = NativeModel::init_random(&NativeInit {
            d_model: 16,
            vocab_in: Some(vocab),
            vocab_out: vocab,
            n_layers: 1,
            ..Default::default()
        }, 11).unwrap();
        let mut tr = NativeTrainer::new(model, "echo");
        let mut rng = Rng::new(4);
        let first = tr.train_batch(&echo_batch(&mut rng, 8, 12, vocab),
                                   5e-3).unwrap();
        let mut last = first;
        for _ in 0..60 {
            last = tr.train_batch(&echo_batch(&mut rng, 8, 12, vocab),
                                  5e-3).unwrap();
        }
        assert!(last.loss < first.loss / 2.0,
                "echo loss {} -> {} (expected >= 2x drop)", first.loss,
                last.loss);
        assert_eq!(tr.step(), 61);
        assert!(last.grad_norm.is_finite());
    }

    #[test]
    fn checkpoint_roundtrip_resumes_params_and_moments() {
        let vocab = 8usize;
        let model = NativeModel::init_random(&NativeInit {
            d_model: 8,
            vocab_in: Some(vocab),
            vocab_out: vocab,
            n_layers: 1,
            ..Default::default()
        }, 2).unwrap();
        let mut tr = NativeTrainer::new(model, "ckpt/label");
        assert_eq!(tr.label, "ckpt_label", "path separators sanitized");
        let mut rng = Rng::new(9);
        for _ in 0..3 {
            tr.train_batch(&echo_batch(&mut rng, 4, 6, vocab), 1e-3)
                .unwrap();
        }
        let dir = std::env::temp_dir().join("minrnn_native_train_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.ckpt");
        tr.save(&path).unwrap();
        let back = NativeTrainer::from_checkpoint(&path, "ckpt_label")
            .unwrap();
        assert_eq!(back.step(), 3);
        assert_eq!(back.adam.m, tr.adam.m);
        // params identical → identical logits
        let x = Tensor::i32(vec![1, 4], vec![1, 2, 3, 4]);
        let (a, _) = tr.model.forward(&x).unwrap();
        let (b, _) = back.model.forward(&x).unwrap();
        assert_eq!(a, b);
        // and the same checkpoint serves through native inference
        let be = crate::backend::NativeBackend::from_checkpoint(&path)
            .unwrap();
        let (c, _) = be.model.forward(&x).unwrap();
        assert_eq!(a, c);
        std::fs::remove_file(&path).unwrap();
    }
}
