//! Native training driver: owns a [`NativeModel`], its gradient container
//! and [`AdamState`], and implements [`crate::runtime::TrainBackend`] so
//! `coordinator::trainer::run_loop` drives it exactly like the PJRT
//! artifact path — no artifacts, no Python, no XLA.
//!
//! One [`NativeTrainer::train_batch`] is: recording forward with dropout
//! ([`autograd::forward_train`]) → the workload's fused head
//! ([`loss::masked_ce`] / [`loss::masked_mse`] / [`loss::seq_ce`], see
//! [`Head`]) → reverse pass ([`autograd::backward`]) → AdamW with
//! global-norm clipping ([`AdamState::update`]), all on the shared thread
//! pool.  The `drop_seed` the loop feeds every step keys the
//! counter-based dropout masks, so a run is reproducible at any thread
//! count.  Checkpoints carry `params/...` (loadable by native *and* PJRT
//! inference) plus `opt/adam/...` moments and `meta/step`.

use std::path::Path;

use anyhow::{anyhow, bail, Result};

use crate::runtime::{EvalMetrics, StepMetrics, TrainBackend};
use crate::tensor::Batch;
use crate::util::io::{self, NamedTensor};

use super::adam::{AdamCfg, AdamState};
use super::autograd;
use super::loss::{self, Head};
use super::model::NativeModel;

pub struct NativeTrainer {
    pub model: NativeModel,
    pub adam: AdamState,
    pub cfg: AdamCfg,
    /// Display / checkpoint-file label (no path separators).
    pub label: String,
    /// Which fused loss this trainer drives (default: masked CE).
    pub head: Head,
    /// Inverted-dropout rate on the residual branches (0 = off; the
    /// recording forward is then bit-identical to the dropout-free path).
    pub drop_rate: f32,
    grads: NativeModel,
    dlogits: Vec<f32>,
}

impl NativeTrainer {
    pub fn new(model: NativeModel, label: &str) -> NativeTrainer {
        NativeTrainer {
            adam: AdamState::new(&model),
            cfg: AdamCfg::default(),
            label: label.replace('/', "_"),
            head: Head::MaskedCe,
            drop_rate: 0.0,
            grads: model.zeros_like(),
            dlogits: Vec::new(),
            model,
        }
    }

    /// Resume from a checkpoint: parameters always; Adam moments when the
    /// checkpoint carries them (a PJRT- or inference-written checkpoint
    /// resumes with fresh moments).
    pub fn from_checkpoint(path: &Path, label: &str)
                           -> Result<NativeTrainer> {
        let tensors = io::load(path)?;
        let model = NativeModel::from_named(&tensors)?;
        if model.is_quantized() {
            bail!("{} holds quantized (int8) weights — quantized \
                   checkpoints are inference-only and cannot resume \
                   training; keep the f32 source checkpoint for that",
                  path.display());
        }
        let names = model.leaf_names();
        let adam = AdamState::from_named(&tensors, &names, &model)?
            .unwrap_or_else(|| AdamState::new(&model));
        Ok(NativeTrainer {
            adam,
            cfg: AdamCfg::default(),
            label: label.replace('/', "_"),
            head: Head::MaskedCe,
            drop_rate: 0.0,
            grads: model.zeros_like(),
            dlogits: Vec::new(),
            model,
        })
    }

    /// Optimizer steps taken (mirrors `TrainState::step`).
    pub fn step(&self) -> u64 {
        self.adam.step
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut tensors = self.model.to_named();
        tensors.extend(self.adam.to_named(&self.model.leaf_names())?);
        tensors.push(NamedTensor::i32("meta/step", vec![],
                                      vec![self.adam.step as i32]));
        io::save(path, &tensors)
    }

    fn head_loss(&self, logits: &[f32], batch: &Batch,
                 dlogits: Option<&mut Vec<f32>>) -> Result<EvalMetrics> {
        let mask = batch.mask.data.as_f32()
            .ok_or_else(|| anyhow!("batch mask is not f32"))?;
        loss::apply_head(self.head, logits, &batch.targets, mask,
                         batch.batch_size(), batch.seq_len(),
                         self.model.vocab_out, dlogits)
    }

    /// One optimizer step; returns loss and pre-clip gradient norm.
    pub fn train_batch(&mut self, batch: &Batch, lr: f32, drop_seed: i32)
                       -> Result<StepMetrics> {
        let tape = autograd::forward_train(&self.model, &batch.x,
                                           self.drop_rate, drop_seed)?;
        let mut dlogits = std::mem::take(&mut self.dlogits);
        let metrics = self.head_loss(&tape.logits, batch,
                                     Some(&mut dlogits));
        self.dlogits = dlogits;
        let metrics = metrics?;
        if !metrics.loss.is_finite() {
            bail!("non-finite loss {} at step {} of {}", metrics.loss,
                  self.adam.step + 1, self.label);
        }
        for leaf in self.grads.leaves_mut() {
            leaf.iter_mut().for_each(|v| *v = 0.0);
        }
        autograd::backward(&self.model, &tape, &batch.x, &self.dlogits,
                           &mut self.grads)?;
        let gnorm = self.adam.update(&self.cfg, &mut self.model,
                                     &mut self.grads, lr)?;
        Ok(StepMetrics { loss: metrics.loss, grad_norm: gnorm })
    }

    /// Forward-only evaluation through the non-recording inference
    /// forward — bit-identical logits to the tape-recording pass (pinned
    /// by autograd's tests) without its per-block activation caches, and
    /// always dropout-free (eval mode).
    pub fn eval_batch(&self, batch: &Batch) -> Result<EvalMetrics> {
        let (logits, _) = self.model.forward(&batch.x)?;
        let lv = logits.data.as_f32()
            .ok_or_else(|| anyhow!("logits not f32"))?;
        self.head_loss(lv, batch, None)
    }
}

impl TrainBackend for NativeTrainer {
    fn name(&self) -> &str {
        &self.label
    }

    fn train_step(&mut self, batch: &Batch, lr: f32, drop_seed: i32)
                  -> Result<StepMetrics> {
        self.train_batch(batch, lr, drop_seed)
    }

    /// Native eval needs no per-shape executables: any batch works.
    fn supports_eval(&self) -> bool {
        true
    }

    fn eval(&self, batch: &Batch) -> Result<EvalMetrics> {
        self.eval_batch(batch)
    }

    fn save_checkpoint(&self, path: &Path) -> Result<()> {
        self.save(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::model::NativeInit;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    fn echo_batch(rng: &mut Rng, b: usize, t: usize, vocab: usize) -> Batch {
        // trivially learnable: predict the current input token
        let x: Vec<i32> = (0..b * t).map(|_| rng.below(vocab as u64) as i32)
            .collect();
        Batch {
            targets: Tensor::i32(vec![b, t], x.clone()),
            x: Tensor::i32(vec![b, t], x),
            mask: Tensor::f32(vec![b, t], vec![1.0; b * t]),
        }
    }

    #[test]
    fn loss_decreases_on_echo_task() {
        let vocab = 12usize;
        let model = NativeModel::init_random(&NativeInit {
            d_model: 16,
            vocab_in: Some(vocab),
            vocab_out: vocab,
            n_layers: 1,
            ..Default::default()
        }, 11).unwrap();
        let mut tr = NativeTrainer::new(model, "echo");
        let mut rng = Rng::new(4);
        let first = tr.train_batch(&echo_batch(&mut rng, 8, 12, vocab),
                                   5e-3, 0).unwrap();
        let mut last = first;
        for s in 0..60 {
            last = tr.train_batch(&echo_batch(&mut rng, 8, 12, vocab),
                                  5e-3, s).unwrap();
        }
        assert!(last.loss < first.loss / 2.0,
                "echo loss {} -> {} (expected >= 2x drop)", first.loss,
                last.loss);
        assert_eq!(tr.step(), 61);
        assert!(last.grad_norm.is_finite());
    }

    #[test]
    fn regression_head_learns_identity_map() {
        // masked_mse end to end: regress targets = features (in_proj +
        // head can represent it), loss must collapse
        let f = 3usize;
        let model = NativeModel::init_random(&NativeInit {
            kind: "minlstm".to_string(),
            d_model: 16,
            vocab_in: None,
            input_dim: Some(f),
            vocab_out: f,
            n_layers: 1,
            forget_bias: 1.0,
            ..Default::default()
        }, 13).unwrap();
        let mut tr = NativeTrainer::new(model, "reg");
        tr.head = Head::MaskedMse;
        let mut rng = Rng::new(6);
        let (b, t) = (8usize, 6usize);
        let mut batch = || {
            let x: Vec<f32> = (0..b * t * f)
                .map(|_| rng.normal_f32(0.0, 1.0)).collect();
            Batch {
                targets: Tensor::f32(vec![b, t, f], x.clone()),
                x: Tensor::f32(vec![b, t, f], x),
                mask: Tensor::f32(vec![b, t], vec![1.0; b * t]),
            }
        };
        let first = tr.train_batch(&batch(), 5e-3, 0).unwrap();
        let mut last = first;
        for s in 0..80 {
            last = tr.train_batch(&batch(), 5e-3, s).unwrap();
        }
        assert!(last.loss < first.loss / 2.0,
                "mse loss {} -> {} (expected >= 2x drop)", first.loss,
                last.loss);
        // and eval agrees with the head (no token accuracy for regression)
        let m = tr.eval_batch(&batch()).unwrap();
        assert!(m.loss.is_finite());
        assert_eq!(m.token_acc, 0.0);
    }

    #[test]
    fn classification_head_learns_repeated_token_rule() {
        // seq_ce end to end: label = the (repeated) content token, answer
        // read at the masked final CLS position
        let vocab = 6usize;
        let model = NativeModel::init_random(&NativeInit {
            d_model: 16,
            vocab_in: Some(vocab),
            vocab_out: vocab,
            n_layers: 1,
            ..Default::default()
        }, 17).unwrap();
        let mut tr = NativeTrainer::new(model, "cls");
        tr.head = Head::SeqClassify;
        let mut rng = Rng::new(8);
        let (b, t) = (8usize, 10usize);
        let mut batch = || {
            let mut x = vec![0i32; b * t];
            let mut tg = vec![0i32; b * t];
            let mut m = vec![0f32; b * t];
            for bi in 0..b {
                let label = rng.below(vocab as u64 - 1) as i32 + 1;
                x[bi * t..bi * t + t - 1].fill(label);
                x[bi * t + t - 1] = 0; // CLS slot
                tg[bi * t + t - 1] = label;
                m[bi * t + t - 1] = 1.0;
            }
            Batch {
                x: Tensor::i32(vec![b, t], x),
                targets: Tensor::i32(vec![b, t], tg),
                mask: Tensor::f32(vec![b, t], m),
            }
        };
        let first = tr.train_batch(&batch(), 5e-3, 0).unwrap();
        let mut last = first;
        for s in 0..120 {
            last = tr.train_batch(&batch(), 5e-3, s).unwrap();
        }
        assert!(last.loss < first.loss / 2.0,
                "cls loss {} -> {} (expected >= 2x drop)", first.loss,
                last.loss);
        let m = tr.eval_batch(&batch()).unwrap();
        assert!(m.seq_acc > 0.5, "classification acc {}", m.seq_acc);
    }

    #[test]
    fn head_target_mismatch_is_a_clear_error_not_a_panic() {
        let model = NativeModel::init_random(&NativeInit {
            d_model: 8,
            vocab_in: Some(8),
            vocab_out: 8,
            n_layers: 1,
            ..Default::default()
        }, 1).unwrap();
        let mut tr = NativeTrainer::new(model, "mismatch");
        tr.head = Head::MaskedMse;
        let mut rng = Rng::new(2);
        let e = tr.train_batch(&echo_batch(&mut rng, 2, 4, 8), 1e-3, 0)
            .unwrap_err();
        assert!(e.to_string().contains("f32 targets"), "{e}");
    }

    #[test]
    fn checkpoint_roundtrip_resumes_params_and_moments() {
        let vocab = 8usize;
        let model = NativeModel::init_random(&NativeInit {
            d_model: 8,
            vocab_in: Some(vocab),
            vocab_out: vocab,
            n_layers: 1,
            ..Default::default()
        }, 2).unwrap();
        let mut tr = NativeTrainer::new(model, "ckpt/label");
        assert_eq!(tr.label, "ckpt_label", "path separators sanitized");
        let mut rng = Rng::new(9);
        for s in 0..3 {
            tr.train_batch(&echo_batch(&mut rng, 4, 6, vocab), 1e-3, s)
                .unwrap();
        }
        let dir = std::env::temp_dir().join("minrnn_native_train_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.ckpt");
        tr.save(&path).unwrap();
        let back = NativeTrainer::from_checkpoint(&path, "ckpt_label")
            .unwrap();
        assert_eq!(back.step(), 3);
        assert_eq!(back.adam.m, tr.adam.m);
        // params identical → identical logits
        let x = Tensor::i32(vec![1, 4], vec![1, 2, 3, 4]);
        let (a, _) = tr.model.forward(&x).unwrap();
        let (b, _) = back.model.forward(&x).unwrap();
        assert_eq!(a, b);
        // and the same checkpoint serves through native inference
        let be = crate::backend::NativeBackend::from_checkpoint(&path)
            .unwrap();
        let (c, _) = be.model.forward(&x).unwrap();
        assert_eq!(a, c);
        std::fs::remove_file(&path).unwrap();
    }
}
