//! Reusable scratch buffers for the native backend's hot paths.
//!
//! Every intermediate a forward/decode pass needs lives here and is
//! recycled across calls (`linalg::reuse` clears + refits without
//! reallocating once capacities warm up).  [`super::model::NativeState`]
//! owns a [`NativeScratch`], so steady-state decode through the
//! `runtime::Backend` trait performs **zero heap allocations** apart from
//! the logits tensor handed back to the caller.

/// Buffers used inside a mixer parallel pass or decode step.  The gate
/// fields are shared across mixer kinds (minGRU/minLSTM gates, S6-lite
/// Δ/B/gate pre-activations); the attention fields are transformer-only.
/// Unused fields stay empty — capacity is only paid for the paths a
/// model actually runs.
#[derive(Clone, Debug, Default)]
pub struct MixerScratch {
    /// `linear_z` (minGRU) / `linear_i` (minLSTM) pre-activations.
    pub k: Vec<f32>,
    /// `linear_h` pre-activations (candidate state).
    pub pre: Vec<f32>,
    /// `linear_f` pre-activations (minLSTM only).
    pub f: Vec<f32>,
    /// Log-space scan coefficients `log a_t`.
    pub log_a: Vec<f32>,
    /// Log-space scan values `log b_t`.
    pub log_b: Vec<f32>,
    /// Log initial state `log h_0`.
    pub log_h0: Vec<f32>,
    /// Scanned hidden-state sequence `(B, T, d_h)`.
    pub h: Vec<f32>,
    /// Gated product (S6-lite) or merged attention context (transformer).
    pub tmp: Vec<f32>,
    /// Fused Q/K/V projections `(rows, 3 d_model)` (transformer).
    pub qkv: Vec<f32>,
    /// Decode attention scores `(B, n_heads, max_len)` (transformer).
    pub att: Vec<f32>,
}

/// Full per-pass scratch: residual stream, normalized inputs, block
/// outputs, MLP hidden activations, and the nested [`MixerScratch`].
#[derive(Clone, Debug, Default)]
pub struct NativeScratch {
    /// Residual stream `(rows, d_model)`.
    pub h: Vec<f32>,
    /// RMSNorm output / block input `(rows, d_model)`.
    pub u: Vec<f32>,
    /// Mixer (or conv) output `(rows, d_model)`.
    pub y: Vec<f32>,
    /// MLP output `(rows, d_model)`.
    pub z: Vec<f32>,
    /// MLP hidden activations `(rows, mlp_mult * d_model)`.
    pub mlp_h: Vec<f32>,
    pub mixer: MixerScratch,
}
