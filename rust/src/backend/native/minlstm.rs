//! minLSTM mixer (Section 3.2, length-independence scaling) for the native
//! backend: parallel mode via the log-space scan (Algorithm 8), sequential
//! decode (Algorithm 7).  Mirrors `python/compile/models/minlstm.py`.
//!
//! Like `mingru`, the `*_into` entry points are allocation-free and fan
//! the GEMMs/gate maps/scan out across the given [`ThreadPool`].

use super::linalg::{self, g, log_g, sigmoid, softplus, Dense};
use super::mingru::{GATE_CHUNK, H0_VALUE};
use super::scan;
use super::scratch::MixerScratch;
use crate::util::threads::{self, SlicePtr, ThreadPool};

#[derive(Clone, Debug)]
pub struct MinLstm {
    pub linear_f: Dense,
    pub linear_i: Dense,
    pub linear_h: Dense,
    pub down: Dense,
}

impl MinLstm {
    pub fn d_hidden(&self) -> usize {
        self.linear_f.d_out
    }

    /// Parallel mode.  `x: (B, T, d_model)`, `h0: (B, d_h)` →
    /// `(y: (B, T, d_model), h_T: (B, d_h))`.
    pub fn parallel(&self, x: &[f32], batch: usize, t: usize, h0: &[f32])
                    -> (Vec<f32>, Vec<f32>) {
        let mut ms = MixerScratch::default();
        let mut y = Vec::new();
        let mut h_last = vec![0.0f32; batch * self.d_hidden()];
        self.parallel_into(threads::global(), x, batch, t, h0, &mut ms,
                           &mut y, &mut h_last);
        (y, h_last)
    }

    /// Allocation-free parallel mode (see [`super::mingru::MinGru`]).
    #[allow(clippy::too_many_arguments)]
    pub fn parallel_into(&self, pool: &ThreadPool, x: &[f32], batch: usize,
                         t: usize, h0: &[f32], ms: &mut MixerScratch,
                         y: &mut Vec<f32>, h_last: &mut [f32]) {
        let rows = batch * t;
        let dh = self.d_hidden();
        debug_assert_eq!(h0.len(), batch * dh);
        debug_assert_eq!(h_last.len(), batch * dh);
        self.linear_f.apply_pool_into(pool, x, rows, &mut ms.f);
        self.linear_i.apply_pool_into(pool, x, rows, &mut ms.k);
        self.linear_h.apply_pool_into(pool, x, rows, &mut ms.pre);
        let n = rows * dh;
        // Algorithm 8: diff = softplus(-p) - softplus(-k);
        //   log f' = -softplus(diff); log i' = -softplus(-diff)
        linalg::reuse(&mut ms.log_a, n);
        linalg::reuse(&mut ms.log_b, n);
        {
            let lap = SlicePtr::new(ms.log_a.as_mut_slice());
            let lbp = SlicePtr::new(ms.log_b.as_mut_slice());
            let p = &ms.f;
            let k = &ms.k;
            let pre = &ms.pre;
            pool.run_chunks(n, GATE_CHUNK, |s, e| {
                let la = unsafe { lap.slice(s, e - s) };
                let lb = unsafe { lbp.slice(s, e - s) };
                for i in 0..e - s {
                    let diff = softplus(-p[s + i]) - softplus(-k[s + i]);
                    la[i] = -softplus(diff);
                    lb[i] = -softplus(-diff) + log_g(pre[s + i]);
                }
            });
        }
        linalg::reuse(&mut ms.log_h0, batch * dh);
        for (l, &v) in ms.log_h0.iter_mut().zip(h0) {
            // clamp non-positive channels to the absorbing log-zero
            // sentinel (see MinGru::parallel_into)
            *l = if v > 0.0 { v.ln() } else { scan::LOG_ZERO };
        }
        scan::scan_log_pool_into(pool, &ms.log_a, &ms.log_b, &ms.log_h0,
                                 batch, t, dh, &mut ms.h);
        self.down.apply_pool_into(pool, &ms.h, rows, y);
        for bi in 0..batch {
            h_last[bi * dh..(bi + 1) * dh].copy_from_slice(
                &ms.h[(bi * t + t - 1) * dh..(bi * t + t) * dh]);
        }
    }

    /// One decode step (Algorithm 7): `f' = f/(f+i)`, `i' = i/(f+i)`,
    /// `h' = f' ⊙ h + i' ⊙ g(pre)`.  Updates `h` in place, returns `y`.
    ///
    /// The normalized gates are evaluated as `f' = σ(-diff)`,
    /// `i' = σ(diff)` with `diff = softplus(-p) - softplus(-k)` — the
    /// mathematically identical form the parallel path uses — because the
    /// naive `f/(f+i)` yields `0/0 = NaN` once both sigmoids underflow
    /// (pre-activations below ≈ -103 in f32).
    pub fn step(&self, x_t: &[f32], batch: usize, h: &mut [f32]) -> Vec<f32> {
        let mut ms = MixerScratch::default();
        let mut y = Vec::new();
        self.step_into(threads::global(), x_t, batch, h, &mut ms, &mut y);
        y
    }

    /// Allocation-free decode step.
    pub fn step_into(&self, pool: &ThreadPool, x_t: &[f32], batch: usize,
                     h: &mut [f32], ms: &mut MixerScratch,
                     y: &mut Vec<f32>) {
        self.linear_f.apply_pool_into(pool, x_t, batch, &mut ms.f);
        self.linear_i.apply_pool_into(pool, x_t, batch, &mut ms.k);
        self.linear_h.apply_pool_into(pool, x_t, batch, &mut ms.pre);
        debug_assert_eq!(h.len(), batch * self.d_hidden());
        for idx in 0..h.len() {
            let diff = softplus(-ms.f[idx]) - softplus(-ms.k[idx]);
            let fp = sigmoid(-diff);
            let ip = sigmoid(diff);
            h[idx] = fp * h[idx] + ip * g(ms.pre[idx]);
        }
        self.down.apply_pool_into(pool, h, batch, y);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_dense(rng: &mut Rng, d_in: usize, d_out: usize,
                    bias: f32) -> Dense {
        let scale = 1.0 / (d_in as f32).sqrt();
        Dense::new(d_in, d_out,
                   (0..d_in * d_out).map(|_| rng.normal_f32(0.0, scale))
                       .collect(),
                   vec![bias; d_out]).unwrap()
    }

    #[test]
    fn zero_h0_parallel_matches_sequential_decode() {
        // regression: ln(0) = -inf / ln(negative) = NaN in log_h0 (see
        // MinGru's twin test); clamped channels must match sequential
        // decode from h = 0
        let mut rng = Rng::new(53);
        let (batch, t, d, dh) = (1usize, 9usize, 3usize, 5usize);
        let cell = MinLstm {
            linear_f: random_dense(&mut rng, d, dh, 0.5),
            linear_i: random_dense(&mut rng, d, dh, 0.0),
            linear_h: random_dense(&mut rng, d, dh, 0.0),
            down: random_dense(&mut rng, dh, d, 0.0),
        };
        let x: Vec<f32> = (0..batch * t * d)
            .map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let h0 = vec![0.0f32; batch * dh];
        let (y_par, h_last) = cell.parallel(&x, batch, t, &h0);
        assert!(y_par.iter().all(|v| v.is_finite()));
        assert!(h_last.iter().all(|v| v.is_finite()));
        let mut h = h0.clone();
        for ti in 0..t {
            let xt = &x[ti * d..(ti + 1) * d];
            let y_t = cell.step(xt, batch, &mut h);
            for di in 0..d {
                let p = y_par[ti * d + di];
                let s = y_t[di];
                assert!((p - s).abs() < 1e-4,
                        "h0=0 t={ti} d={di}: {p} vs {s}");
            }
        }
        // negative h0 must clamp, not NaN
        let h0_neg = vec![-1.0f32; batch * dh];
        let (y_neg, _) = cell.parallel(&x, batch, t, &h0_neg);
        assert!(y_neg.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn parallel_matches_sequential_decode() {
        let mut rng = Rng::new(41);
        let (batch, t, d, dh) = (2usize, 20usize, 3usize, 5usize);
        // non-zero forget bias exercises the Figure-5 init path
        let cell = MinLstm {
            linear_f: random_dense(&mut rng, d, dh, 1.0),
            linear_i: random_dense(&mut rng, d, dh, 0.0),
            linear_h: random_dense(&mut rng, d, dh, 0.0),
            down: random_dense(&mut rng, dh, d, 0.0),
        };
        let x: Vec<f32> = (0..batch * t * d)
            .map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let h0 = vec![H0_VALUE; batch * dh];
        let (y_par, h_last) = cell.parallel(&x, batch, t, &h0);

        // saturated gates must not NaN the decode step (0/0 guard)
        let mut h_sat = vec![H0_VALUE; dh];
        let x_sat = vec![1e4f32; d];
        let y_sat = cell.step(&x_sat, 1, &mut h_sat);
        assert!(h_sat.iter().all(|v| v.is_finite()),
                "saturated-gate decode produced non-finite state");
        assert!(y_sat.iter().all(|v| v.is_finite()));

        let mut h = h0.clone();
        for ti in 0..t {
            let mut xt = vec![0.0f32; batch * d];
            for bi in 0..batch {
                xt[bi * d..(bi + 1) * d].copy_from_slice(
                    &x[(bi * t + ti) * d..(bi * t + ti + 1) * d]);
            }
            let y_t = cell.step(&xt, batch, &mut h);
            for bi in 0..batch {
                for di in 0..d {
                    let p = y_par[(bi * t + ti) * d + di];
                    let s = y_t[bi * d + di];
                    assert!((p - s).abs() < 1e-4,
                            "t={ti} b={bi} d={di}: {p} vs {s}");
                }
            }
        }
        for i in 0..h.len() {
            assert!((h[i] - h_last[i]).abs() < 1e-4);
        }
    }
}
