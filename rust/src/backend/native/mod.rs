//! Pure-Rust CPU implementations of the minGRU/minLSTM inference path:
//! scan primitives, mixer cells, and the backbone model.  No PJRT, no
//! artifacts — everything here runs from a checkpoint (or random init)
//! alone.

pub mod linalg;
pub mod mingru;
pub mod minlstm;
pub mod model;
pub mod scan;
pub mod scratch;

pub use mingru::{MinGru, H0_VALUE};
pub use minlstm::MinLstm;
pub use model::{NativeInit, NativeModel, NativeState};
pub use scratch::{MixerScratch, NativeScratch};
