//! Pure-Rust CPU implementations of the minGRU/minLSTM paths:
//! scan primitives, mixer cells, the backbone model, and — since the
//! training subsystem landed — reverse-mode gradients with dropout
//! (`autograd`), the fused training heads (`loss`: masked CE, masked MSE,
//! pooled sequence classification), AdamW (`adam`), and the
//! [`NativeTrainer`] driving them.  No PJRT, no artifacts — everything
//! here runs from a checkpoint (or random init) alone.

pub mod adam;
pub mod autograd;
pub mod linalg;
pub mod loss;
pub mod mingru;
pub mod minlstm;
pub mod model;
pub mod scan;
pub mod scratch;
pub mod train;

pub use adam::{AdamCfg, AdamState};
pub use loss::Head;
pub use mingru::{MinGru, H0_VALUE};
pub use minlstm::MinLstm;
pub use model::{NativeInit, NativeModel, NativeState};
pub use scratch::{MixerScratch, NativeScratch};
pub use train::NativeTrainer;
