//! Pure-Rust CPU implementations of the paper's comparison matrix:
//! scan primitives ([`scan`]), the four sequence mixers behind the
//! [`mixer::Mixer`] trait ([`mingru`], [`minlstm`], the [`s6lite`]
//! selective scan, and the causal-attention [`transformer`] with its
//! per-lane KV ring cache), the backbone model ([`model`]) with its
//! zero-allocation decode scratch ([`scratch`]), the dense/conv/norm
//! kernels ([`linalg`], whose int8 inference payload lives in
//! [`quant`]), and — since the training subsystem landed —
//! reverse-mode gradients with dropout ([`autograd`]), the fused
//! training heads ([`loss`]: masked CE, masked MSE, pooled sequence
//! classification), AdamW ([`adam`]), and the [`NativeTrainer`] driving
//! them.  No PJRT, no artifacts — everything here runs from a
//! checkpoint (or random init) alone.
//!
//! Two invariants hold across the whole module (see
//! `rust/ARCHITECTURE.md`): results — including gradients and dropout
//! masks — are **bit-for-bit identical at any thread count** (task
//! granularity is a fixed constant of each kernel), and the log-space
//! scan carries f64 accumulators with f32 transcendentals, pinned to
//! the JAX reference by the golden-vector tests.

pub mod adam;
pub mod autograd;
pub mod linalg;
pub mod loss;
pub mod mingru;
pub mod minlstm;
pub mod mixer;
pub mod model;
pub mod quant;
pub mod s6lite;
pub mod scan;
pub mod scratch;
pub mod train;
pub mod transformer;

pub use adam::{AdamCfg, AdamState};
pub use loss::Head;
pub use mingru::{MinGru, H0_VALUE};
pub use minlstm::MinLstm;
pub use mixer::{kinds_help, Mixer, MixerTape, MIXER_KINDS};
pub use model::{NativeInit, NativeModel, NativeState};
pub use quant::QuantDense;
pub use s6lite::S6Lite;
pub use scratch::{MixerScratch, NativeScratch};
pub use train::NativeTrainer;
pub use transformer::Transformer;
