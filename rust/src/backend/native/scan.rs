//! The paper's core recurrence `v_t = a_t ⊙ v_{t-1} + b_t` on the host:
//! real-space sequential scan, log-space sequential scan (Appendix B.1),
//! and the chunked Heinsen-form log-space scan mirroring the structure of
//! the Pallas kernel in `python/compile/kernels/scan.py` (prefix
//! log-sum-exp inside a chunk + per-channel carries across chunks).
//!
//! All variants take flat row-major `(B, T, D)` coefficient/value slices
//! and a `(B, D)` initial state, and return the `(B, T, D)` state sequence
//! `h_1..h_T`.
//!
//! The production chunked scan (`scan_log`) fans the independent `B×D`
//! channel grid out across a [`ThreadPool`] in fixed blocks of
//! [`D_BLOCK`] channels — f64 carriers (the `A*` prefix can drift to
//! ±10³, where any f32 accumulator loses absolute precision) with the
//! transcendentals dropped to f32, where the cycles actually go.  The
//! f32 transcendentals run through the dispatched lane kernels in
//! [`crate::util::simd`]: each time step stages its `logaddexp`
//! correction terms and final exponentials into small f32 buffers and
//! sweeps them with `log1p_exp_inplace`/`exp_inplace`, so the scalar and
//! AVX2 paths evaluate the identical polynomial op sequence and results
//! stay bit-for-bit identical across dispatch levels.  Per-channel
//! operation order is fixed, so results are also bit-for-bit identical
//! across thread counts.  `scan_log_seq` keeps full-f64 accumulation as
//! the reference oracle.

use super::linalg::logaddexp;
use crate::util::simd;
use crate::util::threads::{self, SlicePtr, ThreadPool};

/// Stand-in for `log(0)` that keeps padded/zero positions inert without
/// producing `inf - inf = nan` (mirrors `scan.py::LOG_ZERO`).
pub const LOG_ZERO: f32 = -1e30;

/// Chunk length of the chunked scan (the Pallas kernel's `time_chunk`).
pub const TIME_CHUNK: usize = 64;

/// Channels per parallel task of the chunked/linear scans.  A fixed
/// constant (never derived from the thread count) so task boundaries —
/// and therefore results — are independent of parallelism.
pub const D_BLOCK: usize = 32;

/// Below this many `B*T*D` elements a scan runs inline on the caller.
const PAR_MIN: usize = 1 << 14;

/// Sequential real-space scan: `h_t = a_t * h_{t-1} + b_t`, `h_0 = h0`.
pub fn scan_linear(a: &[f32], b: &[f32], h0: &[f32], batch: usize, t: usize,
                   d: usize) -> Vec<f32> {
    scan_linear_pool(threads::global(), a, b, h0, batch, t, d)
}

/// [`scan_linear`] on an explicit pool: the `B×D` channel grid splits
/// into `(batch, D_BLOCK)` tasks, each sequential over time.
pub fn scan_linear_pool(pool: &ThreadPool, a: &[f32], b: &[f32], h0: &[f32],
                        batch: usize, t: usize, d: usize) -> Vec<f32> {
    let mut out = Vec::new();
    scan_linear_pool_into(pool, a, b, h0, batch, t, d, &mut out);
    out
}

/// Allocation-free core of the real-space scan (the S6-lite selective
/// scan runs through here with input-dependent `a_t`).
#[allow(clippy::too_many_arguments)]
pub fn scan_linear_pool_into(pool: &ThreadPool, a: &[f32], b: &[f32],
                             h0: &[f32], batch: usize, t: usize, d: usize,
                             out: &mut Vec<f32>) {
    assert_eq!(a.len(), batch * t * d, "scan_linear a");
    assert_eq!(b.len(), batch * t * d, "scan_linear b");
    assert_eq!(h0.len(), batch * d, "scan_linear h0");
    super::linalg::reuse(out, batch * t * d);
    let blocks = d.div_ceil(D_BLOCK);
    let op = SlicePtr::new(out.as_mut_slice());
    let task = |idx: usize| {
        let bi = idx / blocks;
        let d0 = (idx % blocks) * D_BLOCK;
        let d1 = (d0 + D_BLOCK).min(d);
        let w = d1 - d0;
        let mut v = [0.0f32; D_BLOCK];
        v[..w].copy_from_slice(&h0[bi * d + d0..bi * d + d1]);
        for ti in 0..t {
            let off = (bi * t + ti) * d + d0;
            let av = &a[off..off + w];
            let bv = &b[off..off + w];
            let ov = unsafe { op.slice(off, w) };
            for j in 0..w {
                v[j] = av[j] * v[j] + bv[j];
                ov[j] = v[j];
            }
        }
    };
    if batch * t * d < PAR_MIN || pool.active() == 1 {
        for idx in 0..batch * blocks {
            task(idx);
        }
    } else {
        pool.run(batch * blocks, task);
    }
}

/// Sequential log-space scan (Appendix B.1):
/// `log h_t = logaddexp(log_a_t + log h_{t-1}, log_b_t)`; returns real h.
/// Full-f64 accumulation — the reference oracle for `scan_log`.
pub fn scan_log_seq(log_a: &[f32], log_b: &[f32], log_h0: &[f32],
                    batch: usize, t: usize, d: usize) -> Vec<f32> {
    assert_eq!(log_a.len(), batch * t * d, "scan_log_seq log_a");
    assert_eq!(log_b.len(), batch * t * d, "scan_log_seq log_b");
    assert_eq!(log_h0.len(), batch * d, "scan_log_seq log_h0");
    let mut out = vec![0.0f32; batch * t * d];
    for bi in 0..batch {
        for di in 0..d {
            let mut lh = log_h0[bi * d + di] as f64;
            for ti in 0..t {
                let off = (bi * t + ti) * d + di;
                lh = logaddexp(log_a[off] as f64 + lh, log_b[off] as f64);
                out[off] = lh.exp() as f32;
            }
        }
    }
    out
}

/// Chunked Heinsen-form log-space scan — the same algebra the Pallas
/// kernel evaluates per grid step:
///
/// within a chunk, with `A_i = Σ_{j≤i} log_a_j` (local prefix sum) and
/// carries `(carry_A, carry_S)` from previous chunks,
///
/// ```text
/// x_i     = log_b_i - A_i
/// p_i     = logsumexp_{j≤i} x_j              (prefix log-sum-exp)
/// S_i     = logaddexp(carry_S, p_i - carry_A)
/// log h_i = carry_A + A_i + S_i
/// ```
///
/// and at a chunk boundary `carry_A += A_last`, `carry_S = S_last`.
pub fn scan_log(log_a: &[f32], log_b: &[f32], log_h0: &[f32], batch: usize,
                t: usize, d: usize) -> Vec<f32> {
    scan_log_pool(threads::global(), log_a, log_b, log_h0, batch, t, d)
}

/// [`scan_log`] on an explicit pool.
pub fn scan_log_pool(pool: &ThreadPool, log_a: &[f32], log_b: &[f32],
                     log_h0: &[f32], batch: usize, t: usize, d: usize)
                     -> Vec<f32> {
    let mut out = Vec::new();
    scan_log_pool_into(pool, log_a, log_b, log_h0, batch, t, d, &mut out);
    out
}

/// Allocation-free core of the chunked scan.
#[allow(clippy::too_many_arguments)]
pub fn scan_log_pool_into(pool: &ThreadPool, log_a: &[f32], log_b: &[f32],
                          log_h0: &[f32], batch: usize, t: usize, d: usize,
                          out: &mut Vec<f32>) {
    assert_eq!(log_a.len(), batch * t * d, "scan_log log_a");
    assert_eq!(log_b.len(), batch * t * d, "scan_log log_b");
    assert_eq!(log_h0.len(), batch * d, "scan_log log_h0");
    super::linalg::reuse(out, batch * t * d);
    let blocks = d.div_ceil(D_BLOCK);
    let op = SlicePtr::new(out.as_mut_slice());
    let task = |idx: usize| {
        let bi = idx / blocks;
        let d0 = (idx % blocks) * D_BLOCK;
        let d1 = (d0 + D_BLOCK).min(d);
        scan_log_block(log_a, log_b, log_h0, bi, t, d, d0, d1, &op);
    };
    if batch * t * d < PAR_MIN || pool.active() == 1 {
        for idx in 0..batch * blocks {
            task(idx);
        }
    } else {
        pool.run(batch * blocks, task);
    }
}

/// `-|a - b|` as the f32 argument of the `logaddexp` correction term.
/// Both-`-inf` operands would produce `NaN`; map that to `-inf` so the
/// correction is exactly `0.0` and `logaddexp(-inf, -inf) = -inf`.
#[inline]
fn lae_arg(a: f64, b: f64) -> f32 {
    let arg = (-(a - b).abs()) as f32;
    if arg.is_nan() { f32::NEG_INFINITY } else { arg }
}

/// One `(batch row, channel block)` of the chunked scan: time-major over
/// the block so reads/writes stay contiguous.  All carriers (`A*` prefix,
/// prefix log-sum-exp `p`, carries) are f64 — the recombination
/// `carry_A + A_i + S_i` cancels a potentially huge `A*` against `S_i`,
/// which must happen at f64 absolute precision — while every
/// transcendental runs in f32.  Each time step stages the two
/// `logaddexp` corrections (`m + log1p(exp(-|a-b|))` with the max kept
/// in f64) and the output exponential into f32 buffers swept by the
/// dispatched [`simd`] kernels; a `-inf` operand clamps through
/// `exp`/`log1p` to a correction of exactly `0.0`, so the branch-free
/// form is exact where the old short-circuit was.
#[allow(clippy::too_many_arguments)]
fn scan_log_block(log_a: &[f32], log_b: &[f32], log_h0: &[f32], bi: usize,
                  t: usize, d: usize, d0: usize, d1: usize,
                  out: &SlicePtr<f32>) {
    let lvl = simd::level();
    let w = d1 - d0;
    let mut carry_a = [0.0f64; D_BLOCK];
    let mut carry_s = [0.0f64; D_BLOCK];
    for j in 0..w {
        carry_s[j] = log_h0[bi * d + d0 + j] as f64;
    }
    let mut a_star = [0.0f64; D_BLOCK];
    let mut p = [0.0f64; D_BLOCK];
    let mut s_last = [0.0f64; D_BLOCK];
    let mut m1 = [0.0f64; D_BLOCK];
    let mut m2 = [0.0f64; D_BLOCK];
    let mut t1 = [0.0f32; D_BLOCK];
    let mut t2 = [0.0f32; D_BLOCK];
    let mut ex = [0.0f32; D_BLOCK];
    let mut chunk_start = 0usize;
    while chunk_start < t {
        let chunk_end = (chunk_start + TIME_CHUNK).min(t);
        for j in 0..w {
            a_star[j] = 0.0;
            p[j] = f64::NEG_INFINITY;
            s_last[j] = carry_s[j];
        }
        for ti in chunk_start..chunk_end {
            let off = (bi * t + ti) * d + d0;
            let la = &log_a[off..off + w];
            let lb = &log_b[off..off + w];
            let ov = unsafe { out.slice(off, w) };
            for j in 0..w {
                a_star[j] += la[j] as f64;
                let x = lb[j] as f64 - a_star[j];
                m1[j] = if p[j] > x { p[j] } else { x };
                t1[j] = lae_arg(p[j], x);
            }
            simd::log1p_exp_inplace(lvl, &mut t1[..w]);
            for j in 0..w {
                p[j] = m1[j] + t1[j] as f64;
                let q = p[j] - carry_a[j];
                m2[j] = if carry_s[j] > q { carry_s[j] } else { q };
                t2[j] = lae_arg(carry_s[j], q);
            }
            simd::log1p_exp_inplace(lvl, &mut t2[..w]);
            for j in 0..w {
                let s = m2[j] + t2[j] as f64;
                ex[j] = (carry_a[j] + a_star[j] + s) as f32;
                s_last[j] = s;
            }
            simd::exp_inplace(lvl, &mut ex[..w]);
            ov.copy_from_slice(&ex[..w]);
        }
        for j in 0..w {
            carry_a[j] += a_star[j];
            carry_s[j] = s_last[j];
        }
        chunk_start = chunk_end;
    }
}

#[cfg(test)]
mod tests {
    // Agreement with the naive sequential recurrence (and the a_t → 0/1
    // edge cases) is property-tested in rust/tests/substrate_props.rs,
    // and thread-count invariance in rust/tests/parallel_props.rs; here
    // we pin only the seam the chunked form introduces.
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn chunk_boundaries_are_seamless() {
        // T straddling several chunks with adversarial magnitudes; the
        // fast-path chunked form must track the full-f64 sequential
        // oracle to 1e-5 relative (observed worst ~1e-7: the f32 rounding
        // only touches logaddexp correction terms, never the carriers)
        let mut rng = Rng::new(22);
        let (batch, t, d) = (1usize, 3 * TIME_CHUNK + 7, 2usize);
        let la: Vec<f32> = (0..batch * t * d)
            .map(|_| rng.range_f32(-8.0, 0.0)).collect();
        let lb: Vec<f32> = (0..batch * t * d)
            .map(|_| rng.range_f32(-8.0, 2.0)).collect();
        let lh0 = vec![0.5f32.ln(); batch * d];
        let seq = scan_log_seq(&la, &lb, &lh0, batch, t, d);
        let chunked = scan_log(&la, &lb, &lh0, batch, t, d);
        for i in 0..seq.len() {
            let tol = 1e-5 * seq[i].abs().max(1.0);
            assert!((seq[i] - chunked[i]).abs() < tol,
                    "[{i}] {} vs {}", seq[i], chunked[i]);
        }
    }

    #[test]
    fn strong_forgetting_cancellation_is_exact() {
        // a→0 with long T drives the A* prefix to ±10³; h_t must still
        // equal b_t to 1e-5 relative — this is the case that rules out
        // f32 carriers in the fast path (they lose ~|A*|·6e-8 absolute)
        let mut rng = Rng::new(91);
        let (batch, t, d) = (1usize, 2 * TIME_CHUNK + 3, 3usize);
        let n = batch * t * d;
        let la = vec![-40.0f32; n];
        let lb: Vec<f32> = (0..n).map(|_| rng.range_f32(-3.0, 2.0))
            .collect();
        let lh0 = vec![0.0f32; batch * d];
        let h = scan_log(&la, &lb, &lh0, batch, t, d);
        for i in 0..n {
            let want = lb[i].exp();
            assert!((h[i] - want).abs() < 1e-5 * want.max(1.0),
                    "[{i}] {} vs {want}", h[i]);
        }
    }
}
