//! The paper's core recurrence `v_t = a_t ⊙ v_{t-1} + b_t` on the host:
//! real-space sequential scan, log-space sequential scan (Appendix B.1),
//! and the chunked Heinsen-form log-space scan mirroring the structure of
//! the Pallas kernel in `python/compile/kernels/scan.py` (prefix
//! log-sum-exp inside a chunk + per-channel carries across chunks).
//!
//! All variants take flat row-major `(B, T, D)` coefficient/value slices
//! and a `(B, D)` initial state, and return the `(B, T, D)` state sequence
//! `h_1..h_T`.  Log-space accumulation runs in f64 internally — on CPU
//! this is nearly free and removes the catastrophic-cancellation worry the
//! TPU kernel handles with padding conventions.

use super::linalg::logaddexp;

/// Stand-in for `log(0)` that keeps padded/zero positions inert without
/// producing `inf - inf = nan` (mirrors `scan.py::LOG_ZERO`).
pub const LOG_ZERO: f32 = -1e30;

/// Chunk length of the chunked scan (the Pallas kernel's `time_chunk`).
pub const TIME_CHUNK: usize = 64;

/// Sequential real-space scan: `h_t = a_t * h_{t-1} + b_t`, `h_0 = h0`.
pub fn scan_linear(a: &[f32], b: &[f32], h0: &[f32], batch: usize, t: usize,
                   d: usize) -> Vec<f32> {
    assert_eq!(a.len(), batch * t * d, "scan_linear a");
    assert_eq!(b.len(), batch * t * d, "scan_linear b");
    assert_eq!(h0.len(), batch * d, "scan_linear h0");
    let mut out = vec![0.0f32; batch * t * d];
    for bi in 0..batch {
        let mut v: Vec<f32> = h0[bi * d..(bi + 1) * d].to_vec();
        for ti in 0..t {
            let off = (bi * t + ti) * d;
            for di in 0..d {
                v[di] = a[off + di] * v[di] + b[off + di];
                out[off + di] = v[di];
            }
        }
    }
    out
}

/// Sequential log-space scan (Appendix B.1):
/// `log h_t = logaddexp(log_a_t + log h_{t-1}, log_b_t)`; returns real h.
pub fn scan_log_seq(log_a: &[f32], log_b: &[f32], log_h0: &[f32],
                    batch: usize, t: usize, d: usize) -> Vec<f32> {
    assert_eq!(log_a.len(), batch * t * d, "scan_log_seq log_a");
    assert_eq!(log_b.len(), batch * t * d, "scan_log_seq log_b");
    assert_eq!(log_h0.len(), batch * d, "scan_log_seq log_h0");
    let mut out = vec![0.0f32; batch * t * d];
    for bi in 0..batch {
        for di in 0..d {
            let mut lh = log_h0[bi * d + di] as f64;
            for ti in 0..t {
                let off = (bi * t + ti) * d + di;
                lh = logaddexp(log_a[off] as f64 + lh, log_b[off] as f64);
                out[off] = lh.exp() as f32;
            }
        }
    }
    out
}

/// Chunked Heinsen-form log-space scan — the same algebra the Pallas
/// kernel evaluates per grid step:
///
/// within a chunk, with `A_i = Σ_{j≤i} log_a_j` (local prefix sum) and
/// carries `(carry_A, carry_S)` from previous chunks,
///
/// ```text
/// x_i     = log_b_i - A_i
/// p_i     = logsumexp_{j≤i} x_j              (prefix log-sum-exp)
/// S_i     = logaddexp(carry_S, p_i - carry_A)
/// log h_i = carry_A + A_i + S_i
/// ```
///
/// and at a chunk boundary `carry_A += A_last`, `carry_S = S_last`.
pub fn scan_log(log_a: &[f32], log_b: &[f32], log_h0: &[f32], batch: usize,
                t: usize, d: usize) -> Vec<f32> {
    assert_eq!(log_a.len(), batch * t * d, "scan_log log_a");
    assert_eq!(log_b.len(), batch * t * d, "scan_log log_b");
    assert_eq!(log_h0.len(), batch * d, "scan_log log_h0");
    let mut out = vec![0.0f32; batch * t * d];
    for bi in 0..batch {
        for di in 0..d {
            let mut carry_a = 0.0f64;
            let mut carry_s = log_h0[bi * d + di] as f64;
            let mut chunk_start = 0usize;
            while chunk_start < t {
                let chunk_end = (chunk_start + TIME_CHUNK).min(t);
                let mut a_star = 0.0f64;
                let mut p = f64::NEG_INFINITY;
                let mut s = carry_s;
                for ti in chunk_start..chunk_end {
                    let off = (bi * t + ti) * d + di;
                    a_star += log_a[off] as f64;
                    let x = log_b[off] as f64 - a_star;
                    p = logaddexp(p, x);
                    s = logaddexp(carry_s, p - carry_a);
                    out[off] = (carry_a + a_star + s).exp() as f32;
                }
                carry_a += a_star;
                carry_s = s;
                chunk_start = chunk_end;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    // Agreement with the naive sequential recurrence (and the a_t → 0/1
    // edge cases) is property-tested in rust/tests/substrate_props.rs;
    // here we pin only the seam the chunked form introduces.
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn chunk_boundaries_are_seamless() {
        // T straddling several chunks with adversarial magnitudes
        let mut rng = Rng::new(22);
        let (batch, t, d) = (1usize, 3 * TIME_CHUNK + 7, 2usize);
        let la: Vec<f32> = (0..batch * t * d)
            .map(|_| rng.range_f32(-8.0, 0.0)).collect();
        let lb: Vec<f32> = (0..batch * t * d)
            .map(|_| rng.range_f32(-8.0, 2.0)).collect();
        let lh0 = vec![0.5f32.ln(); batch * d];
        let seq = scan_log_seq(&la, &lb, &lh0, batch, t, d);
        let chunked = scan_log(&la, &lb, &lh0, batch, t, d);
        for i in 0..seq.len() {
            let tol = 1e-5 * seq[i].abs().max(1.0);
            assert!((seq[i] - chunked[i]).abs() < tol,
                    "[{i}] {} vs {}", seq[i], chunked[i]);
        }
    }
}
