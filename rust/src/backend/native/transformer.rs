//! Causal multi-head self-attention mixer — the Transformer baseline of
//! Figure 2 (`python/compile/models/transformer.py`), and the codebase's
//! first attention path.  Positional information is added by the
//! backbone (learned absolute embeddings, `params/pos/w`).
//!
//! Decode keeps a **per-lane KV ring cache** of capacity
//! `max_len`: position `p` writes slot `p mod max_len`, and attention
//! runs over the last `min(p+1, max_len)` tokens in chronological
//! order, so a lane's numbers are a pure function of its cache content
//! and position — exported lanes re-attend bit-identically after
//! import.  Past `max_len` the cache degrades to a sliding window (the
//! JAX reference instead clamps its write cursor; the two agree on all
//! contexts that fit).
//!
//! This is the backend's perf foil: every recurrent mixer carries O(1)
//! state per lane, the transformer carries O(max_len) — the
//! session-cache export cost difference the paper's comparison matrix
//! is about.

use anyhow::{bail, Result};

use crate::util::threads::{SlicePtr, ThreadPool};

use super::autograd;
use super::linalg::{self, Dense};
use super::mixer::{Mixer, MixerTape};
use super::model::MixerParams;
use super::scratch::MixerScratch;

/// Below this many multiply-adds the attention loops run inline.
const PAR_MIN_ATT: usize = 1 << 15;

#[derive(Clone, Debug)]
pub struct Transformer {
    /// Fused `d_model → 3·d_model` Q/K/V projection.
    pub qkv: Dense,
    /// `d_model → d_model` output projection.
    pub proj: Dense,
    pub n_heads: usize,
    /// KV cache capacity (and the backbone's positional-table length).
    pub max_len: usize,
}

impl Transformer {
    pub fn d_model(&self) -> usize {
        self.proj.d_out
    }

    /// Construction-time validation shared by random init and
    /// checkpoint load.
    pub fn check(&self) -> Result<()> {
        let d = self.d_model();
        if self.n_heads == 0 || d % self.n_heads != 0 {
            bail!("transformer: d_model {d} not divisible by n_heads {}",
                  self.n_heads);
        }
        if self.max_len == 0 {
            bail!("transformer: max_len must be >= 1");
        }
        if self.qkv.d_out != 3 * d {
            bail!("transformer: qkv is {}x{}, want {d}x{}", self.qkv.d_in,
                  self.qkv.d_out, 3 * d);
        }
        Ok(())
    }

    fn scale(&self) -> f32 {
        1.0 / ((self.d_model() / self.n_heads) as f32).sqrt()
    }
}

impl Mixer for Transformer {
    fn kind(&self) -> &'static str {
        "transformer"
    }

    /// The attention path has no expanded hidden state; its "hidden
    /// width" is the residual width.
    fn d_hidden(&self) -> usize {
        self.d_model()
    }

    /// Per-lane K cache then V cache, each `max_len × d_model`, slot
    /// `p mod max_len` holding position `p`'s row.
    fn state_len(&self) -> usize {
        2 * self.max_len * self.d_model()
    }

    fn init_lane(&self, lane: &mut [f32]) {
        lane.fill(0.0);
    }

    fn parallel_into(&self, pool: &ThreadPool, x: &[f32], batch: usize,
                     t: usize, ms: &mut MixerScratch, y: &mut Vec<f32>,
                     state: &mut [f32]) -> Result<()> {
        let d = self.d_model();
        let l = self.max_len;
        if t > l {
            bail!("transformer: context length {t} exceeds max_len {l}");
        }
        let hh = self.n_heads;
        let hd = d / hh;
        let rows = batch * t;
        let scale = self.scale();
        self.qkv.apply_pool_into(pool, x, rows, &mut ms.qkv);
        linalg::reuse(&mut ms.tmp, rows * d);
        {
            let qkv: &[f32] = &ms.qkv;
            let cp = SlicePtr::new(ms.tmp.as_mut_slice());
            let task = |idx: usize| {
                let bi = idx / hh;
                let hi = idx % hh;
                let (qo, ko, vo) = (hi * hd, d + hi * hd, 2 * d + hi * hd);
                let mut scores = vec![0.0f32; t];
                for ti in 0..t {
                    let q = &qkv[(bi * t + ti) * 3 * d + qo..][..hd];
                    let mut m = f32::NEG_INFINITY;
                    for (tj, sc) in scores.iter_mut().enumerate().take(ti + 1) {
                        let k = &qkv[(bi * t + tj) * 3 * d + ko..][..hd];
                        let mut dot = 0.0f32;
                        for u in 0..hd {
                            dot += q[u] * k[u];
                        }
                        *sc = dot * scale;
                        m = m.max(*sc);
                    }
                    let mut denom = 0.0f32;
                    for sc in scores.iter_mut().take(ti + 1) {
                        *sc = (*sc - m).exp();
                        denom += *sc;
                    }
                    let inv = 1.0 / denom;
                    let ctx = unsafe {
                        cp.slice((bi * t + ti) * d + hi * hd, hd)
                    };
                    ctx.fill(0.0);
                    for (tj, sc) in scores.iter().enumerate().take(ti + 1) {
                        let p = sc * inv;
                        let v = &qkv[(bi * t + tj) * 3 * d + vo..][..hd];
                        for u in 0..hd {
                            ctx[u] += p * v[u];
                        }
                    }
                }
            };
            if batch * hh * t * t * hd < PAR_MIN_ATT || pool.active() == 1 {
                for idx in 0..batch * hh {
                    task(idx);
                }
            } else {
                pool.run(batch * hh, task);
            }
        }
        self.proj.apply_pool_into(pool, &ms.tmp, rows, y);
        // prefill the KV ring: position ti lands in slot ti (t <= L)
        let sl = 2 * l * d;
        for bi in 0..batch {
            for ti in 0..t {
                let row = &ms.qkv[(bi * t + ti) * 3 * d..][d..3 * d];
                state[bi * sl + ti * d..bi * sl + (ti + 1) * d]
                    .copy_from_slice(&row[..d]);
                state[bi * sl + (l + ti) * d..bi * sl + (l + ti + 1) * d]
                    .copy_from_slice(&row[d..]);
            }
        }
        Ok(())
    }

    fn step_into(&self, pool: &ThreadPool, x_t: &[f32], batch: usize,
                 pos: &[u32], state: &mut [f32], ms: &mut MixerScratch,
                 y: &mut Vec<f32>) -> Result<()> {
        let d = self.d_model();
        let l = self.max_len;
        let hh = self.n_heads;
        let hd = d / hh;
        let sl = 2 * l * d;
        if pos.len() != batch {
            bail!("transformer step: {} lane positions for batch {batch}",
                  pos.len());
        }
        let scale = self.scale();
        self.qkv.apply_pool_into(pool, x_t, batch, &mut ms.qkv);
        // write this token's K/V row into its lane's ring slot
        for bi in 0..batch {
            let slot = pos[bi] as usize % l;
            let row = &ms.qkv[bi * 3 * d..][d..3 * d];
            state[bi * sl + slot * d..bi * sl + (slot + 1) * d]
                .copy_from_slice(&row[..d]);
            state[bi * sl + (l + slot) * d..bi * sl + (l + slot + 1) * d]
                .copy_from_slice(&row[d..]);
        }
        linalg::reuse(&mut ms.tmp, batch * d);
        linalg::reuse(&mut ms.att, batch * hh * l);
        {
            let st: &[f32] = state;
            let qkv: &[f32] = &ms.qkv;
            let cp = SlicePtr::new(ms.tmp.as_mut_slice());
            let ap = SlicePtr::new(ms.att.as_mut_slice());
            let task = |idx: usize| {
                let bi = idx / hh;
                let hi = idx % hh;
                let p = pos[bi] as usize;
                let count = (p + 1).min(l);
                // oldest kept position is p+1-count; walk chronologically
                let start = (p + 1 - count) % l;
                let q = &qkv[bi * 3 * d + hi * hd..][..hd];
                let scores = unsafe {
                    ap.slice((bi * hh + hi) * l, count)
                };
                let mut m = f32::NEG_INFINITY;
                for (i, sc) in scores.iter_mut().enumerate() {
                    let slot = (start + i) % l;
                    let k = &st[bi * sl + slot * d + hi * hd..][..hd];
                    let mut dot = 0.0f32;
                    for u in 0..hd {
                        dot += q[u] * k[u];
                    }
                    *sc = dot * scale;
                    m = m.max(*sc);
                }
                let mut denom = 0.0f32;
                for sc in scores.iter_mut() {
                    *sc = (*sc - m).exp();
                    denom += *sc;
                }
                let inv = 1.0 / denom;
                let ctx = unsafe { cp.slice(bi * d + hi * hd, hd) };
                ctx.fill(0.0);
                for (i, sc) in scores.iter().enumerate() {
                    let slot = (start + i) % l;
                    let p_att = sc * inv;
                    let v = &st[bi * sl + (l + slot) * d + hi * hd..][..hd];
                    for u in 0..hd {
                        ctx[u] += p_att * v[u];
                    }
                }
            };
            if batch * hh * l * hd < PAR_MIN_ATT || pool.active() == 1 {
                for idx in 0..batch * hh {
                    task(idx);
                }
            } else {
                pool.run(batch * hh, task);
            }
        }
        self.proj.apply_pool_into(pool, &ms.tmp, batch, y);
        Ok(())
    }

    fn forward_tape(&self, pool: &ThreadPool, x: &[f32], batch: usize,
                    t: usize) -> Result<(MixerTape, Vec<f32>)> {
        let d = self.d_model();
        let l = self.max_len;
        if t > l {
            bail!("transformer: context length {t} exceeds max_len {l}");
        }
        let hh = self.n_heads;
        let hd = d / hh;
        let rows = batch * t;
        let scale = self.scale();
        let qkv = self.qkv.apply_pool(pool, x, rows);
        let mut att = vec![0.0f32; batch * hh * t * t];
        let mut ctx = vec![0.0f32; rows * d];
        {
            let qr: &[f32] = &qkv;
            let apx = SlicePtr::new(att.as_mut_slice());
            let cp = SlicePtr::new(ctx.as_mut_slice());
            let task = |idx: usize| {
                let bi = idx / hh;
                let hi = idx % hh;
                let (qo, ko, vo) = (hi * hd, d + hi * hd, 2 * d + hi * hd);
                for ti in 0..t {
                    let q = &qr[(bi * t + ti) * 3 * d + qo..][..hd];
                    let probs = unsafe {
                        apx.slice(((bi * hh + hi) * t + ti) * t, ti + 1)
                    };
                    let mut m = f32::NEG_INFINITY;
                    for (tj, sc) in probs.iter_mut().enumerate() {
                        let k = &qr[(bi * t + tj) * 3 * d + ko..][..hd];
                        let mut dot = 0.0f32;
                        for u in 0..hd {
                            dot += q[u] * k[u];
                        }
                        *sc = dot * scale;
                        m = m.max(*sc);
                    }
                    let mut denom = 0.0f32;
                    for sc in probs.iter_mut() {
                        *sc = (*sc - m).exp();
                        denom += *sc;
                    }
                    let inv = 1.0 / denom;
                    let cv = unsafe {
                        cp.slice((bi * t + ti) * d + hi * hd, hd)
                    };
                    for (tj, sc) in probs.iter_mut().enumerate() {
                        *sc *= inv;
                        let v = &qr[(bi * t + tj) * 3 * d + vo..][..hd];
                        for u in 0..hd {
                            cv[u] += *sc * v[u];
                        }
                    }
                }
            };
            if batch * hh * t * t * hd < PAR_MIN_ATT || pool.active() == 1 {
                for idx in 0..batch * hh {
                    task(idx);
                }
            } else {
                pool.run(batch * hh, task);
            }
        }
        let mut y = Vec::new();
        self.proj.apply_pool_into(pool, &ctx, rows, &mut y);
        Ok((MixerTape::Transformer { qkv, att, ctx }, y))
    }

    fn backward(&self, pool: &ThreadPool, tape: &MixerTape, x: &[f32],
                dy: &[f32], batch: usize, t: usize, dx: &mut Vec<f32>,
                grads: &mut MixerParams) -> Result<()> {
        let (qkv, att, ctx) = match tape {
            MixerTape::Transformer { qkv, att, ctx } => (qkv, att, ctx),
            _ => bail!("transformer backward: tape kind mismatch"),
        };
        let gm = match grads {
            MixerParams::Transformer(gm) => gm,
            _ => bail!("backward: grads mixer kind mismatch"),
        };
        let d = self.d_model();
        let hh = self.n_heads;
        let hd = d / hh;
        let rows = batch * t;
        let scale = self.scale();
        let mut dctx = Vec::new();
        autograd::dense_bwd(pool, &self.proj, ctx, dy, rows,
                            Some((&mut dctx, false)), &mut gm.proj.w,
                            &mut gm.proj.b);
        let mut dqkv = vec![0.0f32; rows * 3 * d];
        {
            let dq: &[f32] = &dctx;
            let dp = SlicePtr::new(dqkv.as_mut_slice());
            let task = |idx: usize| {
                let bi = idx / hh;
                let hi = idx % hh;
                let (qo, ko, vo) = (hi * hd, d + hi * hd, 2 * d + hi * hd);
                let mut dprobs = vec![0.0f32; t];
                for ti in 0..t {
                    let dc = &dq[(bi * t + ti) * d + hi * hd..][..hd];
                    let probs = &att[((bi * hh + hi) * t + ti) * t..][..=ti];
                    // dv_tj += p_tj · dctx; dprobs_tj = dctx · v_tj
                    let mut psum = 0.0f32;
                    for (tj, &p) in probs.iter().enumerate() {
                        let v = &qkv[(bi * t + tj) * 3 * d + vo..][..hd];
                        let dv = unsafe {
                            dp.slice((bi * t + tj) * 3 * d + vo, hd)
                        };
                        let mut dot = 0.0f32;
                        for u in 0..hd {
                            dv[u] += p * dc[u];
                            dot += dc[u] * v[u];
                        }
                        dprobs[tj] = dot;
                        psum += dot * p;
                    }
                    // softmax VJP, then through the scaled dot product
                    let q = &qkv[(bi * t + ti) * 3 * d + qo..][..hd];
                    let dqr = unsafe {
                        dp.slice((bi * t + ti) * 3 * d + qo, hd)
                    };
                    for (tj, &p) in probs.iter().enumerate() {
                        let ds = p * (dprobs[tj] - psum) * scale;
                        if ds == 0.0 {
                            continue;
                        }
                        let k = &qkv[(bi * t + tj) * 3 * d + ko..][..hd];
                        let dk = unsafe {
                            dp.slice((bi * t + tj) * 3 * d + ko, hd)
                        };
                        for u in 0..hd {
                            dqr[u] += ds * k[u];
                            dk[u] += ds * q[u];
                        }
                    }
                }
            };
            if batch * hh * t * t * hd < PAR_MIN_ATT || pool.active() == 1 {
                for idx in 0..batch * hh {
                    task(idx);
                }
            } else {
                pool.run(batch * hh, task);
            }
        }
        autograd::dense_bwd(pool, &self.qkv, x, &dqkv, rows,
                            Some((dx, false)), &mut gm.qkv.w,
                            &mut gm.qkv.b);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::threads;

    fn tiny(d: usize, n_heads: usize, max_len: usize) -> Transformer {
        let mut rng = Rng::new(0x7F);
        let mut dense = |d_in: usize, d_out: usize, scale: f32| Dense {
            d_in,
            d_out,
            w: (0..d_in * d_out).map(|_| rng.normal_f32(0.0, scale))
                .collect(),
            b: vec![0.0; d_out],
            q: None,
        };
        let qkv = dense(d, 3 * d, 1.0 / (d as f32).sqrt());
        let proj = dense(d, d, 0.02);
        let m = Transformer { qkv, proj, n_heads, max_len };
        m.check().unwrap();
        m
    }

    #[test]
    fn parallel_and_step_agree() {
        let (batch, t, d) = (2usize, 6usize, 8usize);
        let m = tiny(d, 4, 16);
        let mut rng = Rng::new(13);
        let x: Vec<f32> = (0..batch * t * d)
            .map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let pool = threads::global();
        let mut ms = MixerScratch::default();
        let mut y = Vec::new();
        let mut state = vec![0.0f32; batch * m.state_len()];
        m.parallel_into(pool, &x, batch, t, &mut ms, &mut y, &mut state)
            .unwrap();

        let mut st = vec![0.0f32; batch * m.state_len()];
        let mut ms2 = MixerScratch::default();
        let mut yt = Vec::new();
        for ti in 0..t {
            let mut x_t = vec![0.0f32; batch * d];
            for bi in 0..batch {
                x_t[bi * d..(bi + 1) * d].copy_from_slice(
                    &x[(bi * t + ti) * d..(bi * t + ti + 1) * d]);
            }
            m.step_into(pool, &x_t, batch, &[ti as u32; 2], &mut st,
                        &mut ms2, &mut yt).unwrap();
            for bi in 0..batch {
                for i in 0..d {
                    let p = y[(bi * t + ti) * d + i];
                    let s = yt[bi * d + i];
                    assert!((p - s).abs() < 1e-4,
                            "t={ti} b={bi} i={i}: {p} vs {s}");
                }
            }
        }
        // the prefilled ring must match the step-built one exactly
        for (a, b) in state.iter().zip(&st) {
            assert!((a - b).abs() < 1e-5, "KV ring drifted");
        }
    }

    #[test]
    fn ring_wraps_into_a_sliding_window() {
        // decoding past max_len keeps attending over the last max_len
        // tokens: numbers stay finite and depend only on that window
        let (batch, d, l) = (1usize, 4usize, 3usize);
        let m = tiny(d, 2, l);
        let pool = threads::global();
        let mut rng = Rng::new(17);
        let mut st = vec![0.0f32; batch * m.state_len()];
        let mut ms = MixerScratch::default();
        let mut y = Vec::new();
        let mut last = Vec::new();
        for ti in 0..l as u32 + 4 {
            let x_t: Vec<f32> = (0..batch * d)
                .map(|_| rng.normal_f32(0.0, 1.0)).collect();
            m.step_into(pool, &x_t, batch, &[ti], &mut st, &mut ms, &mut y)
                .unwrap();
            assert!(y.iter().all(|v| v.is_finite()), "step {ti}");
            last = y.clone();
        }
        assert!(last.iter().any(|v| *v != 0.0));
    }

    #[test]
    fn rejects_contexts_beyond_capacity() {
        let m = tiny(4, 2, 4);
        let pool = threads::global();
        let mut ms = MixerScratch::default();
        let mut y = Vec::new();
        let mut state = vec![0.0f32; m.state_len()];
        let x = vec![0.1f32; 5 * 4];
        let err = m.parallel_into(pool, &x, 1, 5, &mut ms, &mut y,
                                  &mut state);
        assert!(err.is_err());
        assert!(format!("{:#}", err.unwrap_err()).contains("max_len"));
    }
}
